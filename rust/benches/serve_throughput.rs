//! `cargo bench --bench serve_throughput` — closed-loop throughput of the
//! projection service engine on the acceptance workload (256×256 f64,
//! η = 1), in four configurations:
//!
//! 1. **direct**   — single-threaded one-shot library calls (the baseline
//!    the engine must beat: it has no queue, no threads, no batching);
//! 2. **unbatched** — engine with `max_batch = 1` (sharding only);
//! 3. **batched**  — engine with opportunistic micro-batching;
//! 4. **cached**   — batched engine plus the LRU threshold cache on the
//!    repeated-pool workload (reports the hit-rate).
//!
//! Also cross-checks that engine results stay bit-identical to the direct
//! library calls. Set `BILEVEL_BENCH_QUICK=1` for a shortened run.

use bilevel_sparse::bench::black_box;
use bilevel_sparse::config::ServeConfig;
use bilevel_sparse::projection::bilevel::bilevel_l1inf_with;
use bilevel_sparse::projection::l1::L1Algorithm;
use bilevel_sparse::projection::ProjectionKind;
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::serve::{run_loadgen, Engine, LoadReport, LoadgenConfig};
use bilevel_sparse::tensor::Matrix;

const N: usize = 256;
const ETA: f64 = 1.0;
const POOL: usize = 8;

fn engine_cfg(shards: usize, max_batch: usize, cache: usize) -> ServeConfig {
    ServeConfig {
        shards,
        workers_per_shard: 1,
        queue_capacity: 256,
        max_batch,
        min_fill: 1, // opportunistic: batch whatever is queued, never wait
        max_wait_micros: 200,
        cache_capacity: cache,
        ..ServeConfig::default()
    }
}

fn report_line(label: &str, rps: f64, baseline: f64, extra: &str) {
    println!("  {label:<26} {rps:>10.0} req/s   ({:>5.2}x direct){extra}", rps / baseline);
}

fn run_engine(cfg: &ServeConfig, load: &LoadgenConfig) -> (LoadReport, f64, f64) {
    let engine = Engine::start(cfg).expect("engine start");
    let report = run_loadgen(&engine, load);
    let stats = engine.shutdown();
    assert_eq!(report.failed, 0, "engine dropped requests");
    (report, stats.mean_batch(), stats.hit_rate())
}

fn main() {
    let quick = std::env::var("BILEVEL_BENCH_QUICK").is_ok();
    let requests: usize = if quick { 256 } else { 2048 };
    let clients: usize = 8;
    let shards: usize = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8)
        .max(2);

    println!(
        "== serve_throughput: {requests} requests of {N}x{N} f64 bilevel-l1inf, eta = {ETA} =="
    );
    println!("   {clients} clients, {shards} shards, pool of {POOL} matrices\n");

    // -------- 0. pre-kernel scalar baseline, single thread -------------
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let pool: Vec<Matrix<f64>> =
        (0..POOL).map(|_| Matrix::randn(N, N, &mut rng)).collect();
    let t0 = std::time::Instant::now();
    for i in 0..requests {
        black_box(bilevel_sparse::bench::kernels::bilevel_l1inf_scalar_baseline(
            &pool[i % POOL],
            ETA,
            L1Algorithm::Condat,
        ));
    }
    let scalar_rps = requests as f64 / t0.elapsed().as_secs_f64();

    // -------- 1. direct one-shot library calls, single thread ----------
    let t0 = std::time::Instant::now();
    for i in 0..requests {
        black_box(bilevel_l1inf_with(&pool[i % POOL], ETA, L1Algorithm::Condat));
    }
    let direct_rps = requests as f64 / t0.elapsed().as_secs_f64();
    report_line("scalar baseline (pre-kernel)", scalar_rps, direct_rps, "");
    report_line("direct one-shot (1 thread)", direct_rps, direct_rps, "");

    let load = LoadgenConfig {
        clients,
        requests_per_client: requests / clients,
        rows: N,
        cols: N,
        eta: ETA,
        mix: vec![ProjectionKind::BilevelL1Inf],
        pool: POOL,
        f32_every: 0,
        seed: 1,
        ..LoadgenConfig::default()
    };

    // -------- 2. engine, sharding only (max_batch = 1, no cache) -------
    let (unbatched, _, _) = run_engine(&engine_cfg(shards, 1, 0), &load);
    report_line("engine unbatched", unbatched.throughput_rps(), direct_rps, "");
    println!("  {:<26} {}", "", unbatched.latency_summary());

    // -------- 3. engine, micro-batching (no cache) ---------------------
    let (batched, mean_batch, _) = run_engine(&engine_cfg(shards, 16, 0), &load);
    report_line(
        "engine batched",
        batched.throughput_rps(),
        direct_rps,
        &format!("   mean batch {mean_batch:.2}"),
    );
    println!("  {:<26} {}", "", batched.latency_summary());

    // -------- 4. engine, batching + threshold cache --------------------
    let (cached, _, hit_rate) = run_engine(&engine_cfg(shards, 16, 64), &load);
    report_line(
        "engine batched + cache",
        cached.throughput_rps(),
        direct_rps,
        &format!("   hit-rate {:.1}%", hit_rate * 100.0),
    );
    println!("  {:<26} {}", "", cached.latency_summary());

    // -------- acceptance lines -----------------------------------------
    let ok_tput = batched.throughput_rps() >= direct_rps;
    println!(
        "\n  batched engine >= direct one-shot: {}",
        if ok_tput { "PASS" } else { "FAIL" }
    );
    println!(
        "  cache hit-rate > 0 on repeated workload: {}",
        if hit_rate > 0.0 { "PASS" } else { "FAIL" }
    );

    // -------- bit-identical spot check ---------------------------------
    let engine = Engine::start(&engine_cfg(shards, 16, 64)).expect("engine start");
    let mut identical = true;
    for (i, y) in pool.iter().enumerate() {
        let resp = engine
            .submit_wait(bilevel_sparse::serve::ProjectionRequest::f64(
                ProjectionKind::BilevelL1Inf,
                ETA,
                y.clone(),
            ))
            .expect("submit");
        let direct = bilevel_l1inf_with(y, ETA, L1Algorithm::Condat);
        let x = resp.payload.as_f64().expect("f64 payload");
        if x.max_abs_diff(&direct.x) != 0.0 {
            identical = false;
            eprintln!("  matrix {i}: serve result differs from library!");
        }
    }
    engine.shutdown();
    println!(
        "  serve results bit-identical to library: {}",
        if identical { "PASS" } else { "FAIL" }
    );
}
