//! `cargo bench --bench kernels` — the kernel-layer microbench suite
//! (same engine as `bilevel bench kernels`): end-to-end `BP¹,∞` scalar
//! baseline vs SIMD kernel path, sequential vs parking-pool, per-kernel
//! micro rows, and the `min_elems` crossover probe. Writes
//! `BENCH_kernels.json` in the working directory (repo root under cargo).
//!
//! Set `BILEVEL_BENCH_QUICK=1` for a shortened sweep.

use bilevel_sparse::bench::kernels;

fn main() {
    let quick = std::env::var("BILEVEL_BENCH_QUICK").is_ok();
    let report = kernels::run(quick);
    println!("{}", report.markdown());
    std::fs::write("BENCH_kernels.json", report.to_json())
        .expect("writing BENCH_kernels.json");
    println!("wrote BENCH_kernels.json");
}
