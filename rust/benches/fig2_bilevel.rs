//! `cargo bench --bench fig2_bilevel` — paper Fig. 2: the three bi-level
//! variants (ℓ1,∞ / ℓ1,1 / ℓ1,2) share the same linear growth.

use bilevel_sparse::bench::{fit_linear, time_fn, BenchConfig};
use bilevel_sparse::projection::bilevel::{bilevel_l11, bilevel_l12, bilevel_l1inf};
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::tensor::Matrix;

fn main() {
    let quick = std::env::var("BILEVEL_BENCH_QUICK").is_ok();
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let sizes: Vec<usize> = if quick {
        vec![500, 1000, 2000]
    } else {
        vec![500, 1000, 2000, 4000, 8000, 16000]
    };

    for axis in ["features", "samples"] {
        println!("\n== fig2: bilevel variants, time vs {axis} (eta = 1) ==");
        let mut xs = Vec::new();
        let mut series: [Vec<f64>; 3] = [vec![], vec![], vec![]];
        for &size in &sizes {
            let mut rng = Xoshiro256pp::seed_from_u64(size as u64 ^ 2);
            let y = match axis {
                "features" => Matrix::<f64>::randn(1000, size, &mut rng),
                _ => Matrix::<f64>::randn(size, 1000, &mut rng),
            };
            let t = [
                time_fn(&cfg, || bilevel_l1inf(&y, 1.0)).median,
                time_fn(&cfg, || bilevel_l11(&y, 1.0)).median,
                time_fn(&cfg, || bilevel_l12(&y, 1.0)).median,
            ];
            println!(
                "fig2/{axis}/{size:<6} l1inf: {:>8.3} ms   l11: {:>8.3} ms   l12: {:>8.3} ms",
                t[0] * 1e3,
                t[1] * 1e3,
                t[2] * 1e3
            );
            xs.push(size as f64);
            for (s, v) in series.iter_mut().zip(t) {
                s.push(v);
            }
        }
        for (name, s) in ["l1inf", "l11", "l12"].iter().zip(&series) {
            let (a, _, r2) = fit_linear(&xs, s);
            println!("fit: bp-{name} linear slope {a:.3e} (R2 {r2:.5})");
        }
    }
}
