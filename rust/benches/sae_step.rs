//! `cargo bench --bench sae_step` — end-to-end hot-path latency of the
//! training runtime: one `train_step` dispatch, one `train_epoch` (lax.scan)
//! dispatch, the Pallas projection artifact, and the native projection, per
//! preset. This is the L3 "coordinator should not be the bottleneck" check
//! (EXPERIMENTS.md §Perf).
//!
//! Requires `make artifacts`; exits cleanly when they are missing.

use bilevel_sparse::bench::{time_fn, BenchConfig};
use bilevel_sparse::model::{SaeDims, SaeParams};
use bilevel_sparse::projection::bilevel::bilevel_l1inf;
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::runtime::{literal_f32, literal_scalar, Runtime};

fn main() {
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP sae_step bench: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let quick = std::env::var("BILEVEL_BENCH_QUICK").is_ok();
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let presets: &[&str] = if quick { &["tiny", "synth"] } else { &["tiny", "synth", "hif2"] };

    for preset in presets {
        let Some(e) = rt.manifest().get(&format!("{preset}_train_step")).cloned() else {
            continue;
        };
        let dims = SaeDims { features: e.features, hidden: e.hidden, classes: e.classes };
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let params = SaeParams::init(dims, &mut rng);
        let zeros = params.zeros_like();
        let (b, f, k, nb) = (e.batch, e.features, e.classes, e.epoch_batches);
        let x = vec![0.1f32; b * f];
        let y = {
            let mut y = vec![0.0f32; b * k];
            for r in 0..b {
                y[r * k] = 1.0;
            }
            y
        };
        let xs = vec![0.1f32; nb * b * f];
        let ys = {
            let mut ys = vec![0.0f32; nb * b * k];
            for r in 0..nb * b {
                ys[r * k] = 1.0;
            }
            ys
        };
        let mask = vec![1.0f32; f];

        let build_step_inputs = || {
            let mut inputs = Vec::with_capacity(30);
            for p in [&params, &zeros, &zeros] {
                for (tensor, shape) in p.tensors.iter().zip(dims.shapes().iter()) {
                    let d: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
                    inputs.push(literal_f32(tensor, &d).unwrap());
                }
            }
            inputs.push(literal_scalar(0.0));
            inputs
        };

        // train_step: one batch
        let s = time_fn(&cfg, || {
            let mut inputs = build_step_inputs();
            inputs.push(literal_f32(&x, &[b as i64, f as i64]).unwrap());
            inputs.push(literal_f32(&y, &[b as i64, k as i64]).unwrap());
            inputs.push(literal_f32(&mask, &[f as i64]).unwrap());
            inputs.push(literal_scalar(1e-3));
            inputs.push(literal_scalar(1.0));
            rt.execute(&format!("{preset}_train_step"), &inputs).unwrap()
        });
        println!(
            "sae/{preset}/train_step            {:>9.3} ms ± {:>7.3} ({} samples/dispatch)",
            s.median * 1e3,
            s.std * 1e3,
            b
        );

        // train_epoch: NB batches in one dispatch
        let s_epoch = time_fn(&cfg, || {
            let mut inputs = build_step_inputs();
            inputs.push(literal_f32(&xs, &[nb as i64, b as i64, f as i64]).unwrap());
            inputs.push(literal_f32(&ys, &[nb as i64, b as i64, k as i64]).unwrap());
            inputs.push(literal_f32(&mask, &[f as i64]).unwrap());
            inputs.push(literal_scalar(1e-3));
            inputs.push(literal_scalar(1.0));
            rt.execute(&format!("{preset}_train_epoch"), &inputs).unwrap()
        });
        println!(
            "sae/{preset}/train_epoch ({nb:>2} steps) {:>9.3} ms ± {:>7.3} ({:.3} ms/step — {:.1}x vs stepwise)",
            s_epoch.median * 1e3,
            s_epoch.std * 1e3,
            s_epoch.median * 1e3 / nb as f64,
            s.median * nb as f64 / s_epoch.median
        );

        // projection: pallas artifact vs native
        let s_pallas = time_fn(&cfg, || {
            let w1 = literal_f32(&params.tensors[0], &[f as i64, e.hidden as i64]).unwrap();
            rt.execute(&format!("{preset}_project"), &[w1, literal_scalar(0.5)]).unwrap()
        });
        let s_native = time_fn(&cfg, || {
            let w = params.w1_as_feature_columns();
            bilevel_l1inf(&w, 0.5)
        });
        println!(
            "sae/{preset}/project pallas        {:>9.3} ms   native {:>9.3} ms",
            s_pallas.median * 1e3,
            s_native.median * 1e3
        );
    }
}
