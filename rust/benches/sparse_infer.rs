//! `cargo bench --bench sparse_infer` — dense vs compacted structured-
//! sparse encode across column-sparsity levels 0–99%, f32/f64 (same engine
//! as `bilevel bench sparse`). Verifies bitwise dense ≡ compact agreement
//! per entry and writes `BENCH_sparse.json` in the working directory (repo
//! root under cargo).
//!
//! Set `BILEVEL_BENCH_QUICK=1` for a shortened sweep.

use bilevel_sparse::bench::sparse;

fn main() {
    let quick = std::env::var("BILEVEL_BENCH_QUICK").is_ok();
    let report = sparse::run(quick);
    println!("{}", report.markdown());
    std::fs::write("BENCH_sparse.json", report.to_json())
        .expect("writing BENCH_sparse.json");
    println!("wrote BENCH_sparse.json");
    assert!(
        report.all_bit_identical(),
        "sparse encode diverged bitwise from dense encode"
    );
}
