//! `cargo bench --bench fig1_time` — paper Fig. 1: bi-level ℓ1,∞ vs the
//! Chu et al. semismooth-Newton exact projection, time vs features and vs
//! samples (η = 1). Prints per-size medians and the growth-rate fits.
//!
//! Set `BILEVEL_BENCH_QUICK=1` for a shortened sweep.

use bilevel_sparse::bench::kernels as kernel_bench;
use bilevel_sparse::bench::{fit_linear, fit_nlogn, time_fn, BenchConfig};
use bilevel_sparse::projection::bilevel::bilevel_l1inf;
use bilevel_sparse::projection::l1inf::{project_l1inf, L1InfAlgorithm};
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::tensor::Matrix;

fn main() {
    let quick = std::env::var("BILEVEL_BENCH_QUICK").is_ok();
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };

    // Kernel-layer section: the same `bp1inf/seq` + `bp1inf/pool` rows
    // `bilevel bench kernels` records in BENCH_kernels.json, measured by
    // the shared bench::kernels helper so the two never drift.
    let kernel_sizes: &[usize] = if quick { &[256, 512] } else { &[512, 1024, 2048] };
    println!("== fig1 addendum: scalar baseline vs kernel layer (eta = 1) ==");
    for e in kernel_bench::bp1inf_entries(&cfg, kernel_sizes) {
        println!(
            "fig1/{:<12} {:>4}x{:<4} baseline: {:>8.3} ms   kernel: {:>8.3} ms   ({:.2}x)",
            e.name,
            e.rows,
            e.cols,
            e.baseline_ms,
            e.kernel_ms,
            e.speedup(),
        );
    }
    let sizes: Vec<usize> = if quick {
        vec![500, 1000, 2000]
    } else {
        vec![500, 1000, 2000, 4000, 8000, 16000]
    };

    for axis in ["features", "samples"] {
        println!("\n== fig1: time vs {axis} (eta = 1) ==");
        let mut xs = Vec::new();
        let mut t_bp = Vec::new();
        let mut t_ssn = Vec::new();
        for &size in &sizes {
            let mut rng = Xoshiro256pp::seed_from_u64(size as u64);
            let y = match axis {
                "features" => Matrix::<f64>::randn(1000, size, &mut rng),
                _ => Matrix::<f64>::randn(size, 1000, &mut rng),
            };
            let bp = time_fn(&cfg, || bilevel_l1inf(&y, 1.0));
            let ssn = time_fn(&cfg, || project_l1inf(&y, 1.0, L1InfAlgorithm::Ssn));
            println!(
                "fig1/{axis}/{size:<6} bilevel: {:>9.3} ms ± {:>7.3}   ssn: {:>9.3} ms ± {:>7.3}   ({:.1}x)",
                bp.median * 1e3,
                bp.std * 1e3,
                ssn.median * 1e3,
                ssn.std * 1e3,
                ssn.median / bp.median
            );
            xs.push(size as f64);
            t_bp.push(bp.median);
            t_ssn.push(ssn.median);
        }
        let (a_l, _, r2_l) = fit_linear(&xs, &t_bp);
        let (a_n, _, r2_n) = fit_nlogn(&xs, &t_ssn);
        println!("fit: bilevel linear slope {a_l:.3e} (R2 {r2_l:.5}); ssn nlogn slope {a_n:.3e} (R2 {r2_n:.5})");
    }
}
