//! `cargo bench --bench l1_algorithms` — the four ℓ1-ball threshold
//! algorithms (sort / Michelot / Condat / bucket) across vector sizes.
//! Condat's O(n) expected algorithm is the repo default; this bench is the
//! evidence (and the ablation for DESIGN.md's inner-solver choice).

use bilevel_sparse::bench::{time_fn, BenchConfig};
use bilevel_sparse::projection::l1::{project_l1, L1Algorithm};
use bilevel_sparse::rng::{Rng, Xoshiro256pp};

fn main() {
    let quick = std::env::var("BILEVEL_BENCH_QUICK").is_ok();
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let sizes: Vec<usize> = if quick {
        vec![1_000, 10_000, 100_000]
    } else {
        vec![1_000, 10_000, 100_000, 1_000_000]
    };

    for &n in &sizes {
        let mut rng = Xoshiro256pp::seed_from_u64(n as u64);
        let v: Vec<f64> = (0..n).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let norm: f64 = v.iter().map(|x| x.abs()).sum();
        let eta = norm * 0.05;
        print!("l1/{n:<9}");
        for algo in L1Algorithm::all() {
            let s = time_fn(&cfg, || project_l1(&v, eta, *algo));
            print!("  {}: {:>9.4} ms", algo.name(), s.median * 1e3);
        }
        println!();
    }
}
