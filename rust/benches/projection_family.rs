//! `cargo bench --bench projection_family` — the projection-family suite
//! (same engine as `bilevel bench projection-family`): every flat
//! [`ProjectionKind`] over f32/f64 at representative shapes, plus the
//! multilevel tree's depth-vs-threads speedup curve. Writes
//! `BENCH_projection_family.json` in the working directory (repo root
//! under cargo).
//!
//! Set `BILEVEL_BENCH_QUICK=1` for a shortened sweep.
//!
//! [`ProjectionKind`]: bilevel_sparse::projection::ProjectionKind

use bilevel_sparse::bench::projection_family;

fn main() {
    let quick = std::env::var("BILEVEL_BENCH_QUICK").is_ok();
    let report = projection_family::run(quick);
    println!("{}", report.markdown());
    std::fs::write("BENCH_projection_family.json", report.to_json())
        .expect("writing BENCH_projection_family.json");
    println!("wrote BENCH_projection_family.json");
}
