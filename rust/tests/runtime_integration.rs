//! Runtime integration: load the AOT artifacts, execute on PJRT, and
//! cross-check numerics against the native Rust implementations.
//!
//! Requires `make artifacts` (skipped gracefully when absent so that pure
//! projection work doesn't need Python).

use bilevel_sparse::model::{SaeDims, SaeParams};
use bilevel_sparse::norms::l1inf_norm;
use bilevel_sparse::projection::bilevel::bilevel_l1inf;
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::runtime::{literal_f32, literal_scalar, to_scalar_f32, to_vec_f32, Runtime};

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP runtime tests ({e:#}) — run `make artifacts`");
            None
        }
    }
}

#[test]
fn manifest_lists_all_presets() {
    let Some(rt) = runtime() else { return };
    for preset in ["tiny", "synth", "hif2"] {
        let arts = rt.manifest().preset(preset);
        assert_eq!(arts.len(), 4, "preset {preset}: {:?}", rt.manifest().names());
        for kind in ["train_step", "train_epoch", "eval", "project"] {
            assert!(
                rt.manifest().get(&format!("{preset}_{kind}")).is_some(),
                "{preset}_{kind} missing"
            );
        }
    }
}

#[test]
fn pallas_project_artifact_matches_native_projection() {
    let Some(rt) = runtime() else { return };
    let e = rt.manifest().get("tiny_project").unwrap().clone();
    let dims = SaeDims { features: e.features, hidden: e.hidden, classes: e.classes };
    let mut rng = Xoshiro256pp::seed_from_u64(99);
    let params = SaeParams::init(dims, &mut rng);
    let eta = 0.75f32;

    let w1 = literal_f32(&params.tensors[0], &[dims.features as i64, dims.hidden as i64]).unwrap();
    let out = rt.execute("tiny_project", &[w1, literal_scalar(eta)]).unwrap();
    assert_eq!(out.len(), 2);
    let w1_pallas = to_vec_f32(&out[0]).unwrap();
    let u = to_vec_f32(&out[1]).unwrap();
    assert_eq!(u.len(), dims.features);

    // Native reference: (H,F) column-major view == (F,H) row-major data.
    let w = params.w1_as_feature_columns();
    let native = bilevel_l1inf(&w, eta);
    assert!(l1inf_norm(&native) <= eta + 1e-5);

    // Compare element-wise: pallas output is (F,H) row-major = native
    // column-major storage order.
    let native_flat = native.as_slice();
    assert_eq!(native_flat.len(), w1_pallas.len());
    let mut max_diff = 0.0f32;
    for (a, b) in native_flat.iter().zip(w1_pallas.iter()) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 1e-5, "pallas vs native projection: max diff {max_diff}");

    // Thresholds sum to eta when the input was outside the ball.
    let s: f32 = u.iter().sum();
    assert!((s - eta).abs() < 1e-4, "sum(u) = {s}");
}

#[test]
fn eval_artifact_runs_and_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let e = rt.manifest().get("tiny_eval").unwrap().clone();
    let dims = SaeDims { features: e.features, hidden: e.hidden, classes: e.classes };
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let params = SaeParams::init(dims, &mut rng);

    let mut inputs = Vec::new();
    for (tensor, shape) in params.tensors.iter().zip(dims.shapes().iter()) {
        let d: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
        inputs.push(literal_f32(tensor, &d).unwrap());
    }
    let x: Vec<f32> = (0..e.eval_batch * dims.features)
        .map(|i| ((i % 17) as f32 - 8.0) / 8.0)
        .collect();
    inputs.push(literal_f32(&x, &[e.eval_batch as i64, dims.features as i64]).unwrap());

    let out1 = rt.execute("tiny_eval", &inputs).unwrap();
    assert_eq!(out1.len(), 2);
    let logits1 = to_vec_f32(&out1[0]).unwrap();
    assert_eq!(logits1.len(), e.eval_batch * dims.classes);
    let xhat = to_vec_f32(&out1[1]).unwrap();
    assert_eq!(xhat.len(), e.eval_batch * dims.features);
    assert!(logits1.iter().all(|v| v.is_finite()));

    // Literals are reusable: re-running must give identical outputs.
    let out2 = rt.execute("tiny_eval", &inputs).unwrap();
    let logits2 = to_vec_f32(&out2[0]).unwrap();
    assert_eq!(logits1, logits2);
}

#[test]
fn train_step_artifact_decreases_loss() {
    let Some(rt) = runtime() else { return };
    let e = rt.manifest().get("tiny_train_step").unwrap().clone();
    let dims = SaeDims { features: e.features, hidden: e.hidden, classes: e.classes };
    let mut rng = Xoshiro256pp::seed_from_u64(13);
    let mut params = SaeParams::init(dims, &mut rng);
    let mut m = params.zeros_like();
    let mut v = params.zeros_like();

    // Fixed batch with a learnable signal: class = sign of feature 0.
    let b = e.batch;
    let mut x = vec![0.0f32; b * dims.features];
    let mut y = vec![0.0f32; b * dims.classes];
    let mut rng2 = Xoshiro256pp::seed_from_u64(14);
    for r in 0..b {
        for c in 0..dims.features {
            x[r * dims.features + c] = (bilevel_sparse::rng::Rng::next_f32(&mut rng2) - 0.5) * 2.0;
        }
        let cls = usize::from(x[r * dims.features] > 0.0);
        y[r * dims.classes + cls] = 1.0;
    }
    let mask = vec![1.0f32; dims.features];

    let mut losses = Vec::new();
    let mut step = 0.0f32;
    for _ in 0..40 {
        let mut inputs = Vec::new();
        for p in [&params, &m, &v] {
            for (tensor, shape) in p.tensors.iter().zip(dims.shapes().iter()) {
                let d: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
                inputs.push(literal_f32(tensor, &d).unwrap());
            }
        }
        inputs.push(literal_scalar(step));
        inputs.push(literal_f32(&x, &[b as i64, dims.features as i64]).unwrap());
        inputs.push(literal_f32(&y, &[b as i64, dims.classes as i64]).unwrap());
        inputs.push(literal_f32(&mask, &[dims.features as i64]).unwrap());
        inputs.push(literal_scalar(5e-3));
        inputs.push(literal_scalar(1.0));
        let out = rt.execute("tiny_train_step", &inputs).unwrap();
        assert_eq!(out.len(), 26);
        params.set_from(out[0..8].iter().map(|l| to_vec_f32(l).unwrap()).collect());
        m.set_from(out[8..16].iter().map(|l| to_vec_f32(l).unwrap()).collect());
        v.set_from(out[16..24].iter().map(|l| to_vec_f32(l).unwrap()).collect());
        step += 1.0;
        losses.push(to_scalar_f32(&out[24]).unwrap());
    }
    assert!(
        losses[39] < losses[0] * 0.8,
        "loss should decrease: {} -> {}",
        losses[0],
        losses[39]
    );
}
