//! End-to-end tests of the HTTP front-end over real sockets: wire results
//! bit-identical to in-process library calls, 429 + Retry-After
//! backpressure honoured by the network loadgen, quota vs overload tag
//! distinction, SSE monotonic stats snapshots, and graceful drain
//! composing with `swap_model` under live client traffic with zero lost
//! accepted requests.

use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bilevel_sparse::config::{HttpConfig, ServeConfig};
use bilevel_sparse::model::{SaeDims, SaeParams};
use bilevel_sparse::net::http::{
    read_chunk, read_response, read_response_head, write_request, HttpError, HttpLimits,
    Response,
};
use bilevel_sparse::net::{wire, Server};
use bilevel_sparse::projection::ProjectionKind;
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::serve::{
    run_loadgen_net, Engine, LoadgenConfig, Payload, ProjectionRequest,
};
use bilevel_sparse::sparse::{CompactEncoder, CompactPlan};
use bilevel_sparse::tensor::Matrix;

/// One keep-alive client connection (test side — deliberately independent
/// of the loadgen's client so the two implementations cross-check).
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    fn open(addr: SocketAddr) -> Conn {
        let s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let _ = s.set_nodelay(true);
        Conn { reader: BufReader::new(s.try_clone().unwrap()), writer: s }
    }

    fn send(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(String, String)],
        body: &[u8],
    ) -> Result<Response, HttpError> {
        write_request(&mut self.writer, method, path, headers, body)?;
        read_response(&mut self.reader, &HttpLimits::default())
    }
}

fn http_cfg() -> HttpConfig {
    HttpConfig { listen: "127.0.0.1:0".into(), ..HttpConfig::default() }
}

fn base_serve_cfg() -> ServeConfig {
    ServeConfig { shards: 2, workers_per_shard: 1, cache_capacity: 32, ..ServeConfig::default() }
}

fn bits_equal(a: &Matrix<f64>, b: &Matrix<f64>) -> bool {
    a.rows() == b.rows()
        && a.cols() == b.cols()
        && a.as_slice().iter().zip(b.as_slice()).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// A 10-feature / 4-hidden encoder with a seed-dependent pruned support,
/// mirroring the engine's own registry tests.
fn test_encoder<T: bilevel_sparse::scalar::Scalar>(seed: u64) -> CompactEncoder<T> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut p = SaeParams::init(SaeDims { features: 10, hidden: 4, classes: 2 }, &mut rng);
    let mut mask = vec![1.0f32; 10];
    for f in [1usize, 3, 8] {
        mask[f] = 0.0;
    }
    p.apply_feature_mask(&mask);
    let plan = CompactPlan::from_mask(&mask);
    CompactEncoder::<T>::from_params(&p, &plan)
}

fn body_str(resp: &Response) -> &str {
    std::str::from_utf8(&resp.body).expect("response body must be UTF-8")
}

#[test]
fn project_and_encode_over_socket_bit_identical_to_in_process() {
    let engine = Arc::new(Engine::start(&base_serve_cfg()).unwrap());
    let enc64 = test_encoder::<f64>(301);
    let enc32 = test_encoder::<f32>(302);
    let id64 = engine.register_encoder_f64(enc64.clone());
    let id32 = engine.register_encoder_f32(enc32.clone());
    let server = Server::start(Arc::clone(&engine), &http_cfg()).unwrap();
    let mut conn = Conn::open(server.addr());
    let mut rng = Xoshiro256pp::seed_from_u64(300);

    // projections: every wire round trip must equal the direct library call
    let eta = 1.5;
    for kind in [
        ProjectionKind::BilevelL1Inf,
        ProjectionKind::BilevelL11,
        ProjectionKind::BilevelL12,
        ProjectionKind::ExactL1InfSsn,
    ] {
        let y = Matrix::<f64>::randn(24, 16, &mut rng);
        let body = wire::project_request_body(&ProjectionRequest::f64(kind, eta, y.clone()));
        let resp = conn.send("POST", "/v1/project", &[], body.as_bytes()).unwrap();
        assert_eq!(resp.status, 200, "{}: {}", kind.name(), body_str(&resp));
        let over_wire = wire::decode_response(body_str(&resp)).unwrap();
        let direct = kind.apply(&y, eta);
        assert!(
            bits_equal(over_wire.payload.as_f64().unwrap(), &direct),
            "{}: socket result must be bit-identical to the library",
            kind.name()
        );
    }

    // f32 projection round trip
    let y32: Matrix<f32> = Matrix::<f64>::randn(12, 10, &mut rng).cast();
    let body = wire::project_request_body(&ProjectionRequest::f32(
        ProjectionKind::BilevelL1Inf,
        1.0,
        y32.clone(),
    ));
    let resp = conn.send("POST", "/v1/project", &[], body.as_bytes()).unwrap();
    assert_eq!(resp.status, 200);
    let over_wire = wire::decode_response(body_str(&resp)).unwrap();
    let direct32 = ProjectionKind::BilevelL1Inf.apply(&y32, 1.0f32);
    let x32 = over_wire.payload.as_f32().unwrap();
    assert!(
        x32.as_slice().iter().zip(direct32.as_slice()).all(|(a, b)| a.to_bits() == b.to_bits()),
        "f32 socket result must be bit-identical"
    );

    // sparse encode through both registered models
    let x = Matrix::<f64>::randn(10, 5, &mut rng);
    let body = wire::encode_request_body(&Payload::F64(x.clone()));
    let resp = conn.send("POST", &format!("/v1/encode/{id64}"), &[], body.as_bytes()).unwrap();
    assert_eq!(resp.status, 200, "{}", body_str(&resp));
    let over_wire = wire::decode_response(body_str(&resp)).unwrap();
    assert!(bits_equal(over_wire.payload.as_f64().unwrap(), &enc64.encode(&x)));

    let xf: Matrix<f32> = x.cast();
    let body = wire::encode_request_body(&Payload::F32(xf.clone()));
    let resp = conn.send("POST", &format!("/v1/encode/{id32}"), &[], body.as_bytes()).unwrap();
    assert_eq!(resp.status, 200, "{}", body_str(&resp));
    let over_wire = wire::decode_response(body_str(&resp)).unwrap();
    let direct = enc32.encode(&xf);
    let h = over_wire.payload.as_f32().unwrap();
    assert!(h.as_slice().iter().zip(direct.as_slice()).all(|(a, b)| a.to_bits() == b.to_bits()));

    // inventory + stats routes agree with the engine
    let resp = conn.send("GET", "/v1/models", &[], b"").unwrap();
    assert_eq!(resp.status, 200);
    let v = wire::Json::parse(body_str(&resp)).unwrap();
    let models = v.get("models").and_then(wire::Json::as_arr).unwrap();
    assert_eq!(models.len(), 2);
    assert!(models.iter().any(|m| m.get("id").and_then(wire::Json::as_u64) == Some(id64)));

    let resp = conn.send("GET", "/v1/stats", &[], b"").unwrap();
    assert_eq!(resp.status, 200);
    let v = wire::Json::parse(body_str(&resp)).unwrap();
    let completed = v.get("completed").and_then(wire::Json::as_u64).unwrap();
    assert_eq!(completed, 7, "5 projections + 2 encodes served");

    drop(conn);
    server.join();
    Arc::try_unwrap(engine).ok().unwrap().shutdown();
}

#[test]
fn network_loadgen_honours_429_retry_after() {
    // One worker parked in a batch-fill window on one kind while the other
    // kind piles into a depth-1 queue: overload 429s are a certainty, and
    // the loadgen must absorb every one of them via the advertised backoff
    // and still complete the full workload.
    let engine = Arc::new(
        Engine::start(&ServeConfig {
            shards: 1,
            workers_per_shard: 1,
            queue_capacity: 1,
            max_batch: 8,
            min_fill: 8,
            max_wait_micros: 20_000,
            cache_capacity: 0,
            ..ServeConfig::default()
        })
        .unwrap(),
    );
    let server = Server::start(Arc::clone(&engine), &http_cfg()).unwrap();
    let cfg = LoadgenConfig {
        clients: 4,
        requests_per_client: 16,
        rows: 12,
        cols: 8,
        eta: 1.0,
        mix: vec![ProjectionKind::BilevelL1Inf, ProjectionKind::BilevelL11],
        pool: 2,
        f32_every: 0,
        seed: 7,
        ..LoadgenConfig::default()
    };
    let report = run_loadgen_net(&server.addr().to_string(), &cfg).unwrap();
    assert_eq!(report.completed, 64, "every request must eventually complete");
    assert_eq!(report.failed, 0);
    assert!(report.retries > 0, "contended depth-1 queue must shed load at least once");
    assert_eq!(report.latency.count(), 64);
    assert!(report.p50_micros() <= report.p99_micros());
    assert!(report.p99_micros() <= report.p999_micros());

    let http_report = server.join();
    assert_eq!(http_report.overloaded, report.retries, "every 429 the clients saw was engine overload");
    assert_eq!(http_report.quota_rejected, 0);
    let stats = Arc::try_unwrap(engine).ok().unwrap().shutdown();
    assert_eq!(stats.completed(), 64);
    assert_eq!(stats.rejected(), report.retries);
}

#[test]
fn overload_429_advertises_exact_backoff_headers() {
    // Deterministic single overflow: worker parked on kind/shape A, one
    // same-shard B request occupying the depth-1 queue, a second B must be
    // shed with the engine's exact retry-after on the wire.
    let engine = Arc::new(
        Engine::start(&ServeConfig {
            shards: 1,
            workers_per_shard: 1,
            queue_capacity: 1,
            max_batch: 64,
            min_fill: 64,
            max_wait_micros: 300_000,
            cache_capacity: 0,
            ..ServeConfig::default()
        })
        .unwrap(),
    );
    let server = Server::start(Arc::clone(&engine), &http_cfg()).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(310);
    let a = Matrix::<f64>::randn(8, 6, &mut rng);
    let b1 = Matrix::<f64>::randn(6, 8, &mut rng);
    let b2 = Matrix::<f64>::randn(6, 8, &mut rng);

    // A is picked up by the worker and parks in the 300ms batch window.
    let a_handle = engine
        .submit(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, a))
        .unwrap();
    std::thread::sleep(Duration::from_millis(50));

    // B1 (different shape => different batch key) fills the queue; its
    // connection blocks in submit_wait on the handler thread.
    let addr = server.addr();
    let b1_body =
        wire::project_request_body(&ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, b1));
    let blocked = std::thread::spawn(move || {
        let mut conn = Conn::open(addr);
        conn.send("POST", "/v1/project", &[], b1_body.as_bytes()).unwrap()
    });
    std::thread::sleep(Duration::from_millis(50));

    // B2 overflows: 429 now, with the engine's exact backoff surfaced.
    let mut conn = Conn::open(addr);
    let b2_body =
        wire::project_request_body(&ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, b2));
    let resp = conn.send("POST", "/v1/project", &[], b2_body.as_bytes()).unwrap();
    assert_eq!(resp.status, 429);
    assert!(body_str(&resp).contains("\"error\":\"overloaded\""), "{}", body_str(&resp));
    // engine retry_after = 2 * max_wait = 600ms
    assert_eq!(resp.header("x-retry-after-micros"), Some("600000"));
    assert_eq!(resp.header("retry-after"), Some("1"), "600ms rounds up to 1s");

    let b1_resp = blocked.join().unwrap();
    assert_eq!(b1_resp.status, 200, "the queued request still completes");
    assert!(a_handle.wait().is_ok());
    drop(conn);
    server.join();
    let stats = Arc::try_unwrap(engine).ok().unwrap().shutdown();
    assert_eq!(stats.rejected(), 1);
    assert_eq!(stats.completed(), 2);
}

#[test]
fn quota_429_is_distinct_from_overload_and_per_client() {
    let engine = Arc::new(Engine::start(&base_serve_cfg()).unwrap());
    let cfg = HttpConfig {
        quota_rps: 0.01, // effectively no refill within the test
        quota_burst: 2.0,
        ..http_cfg()
    };
    let server = Server::start(Arc::clone(&engine), &cfg).unwrap();
    let mut conn = Conn::open(server.addr());
    let mut rng = Xoshiro256pp::seed_from_u64(320);
    let y = Matrix::<f64>::randn(6, 6, &mut rng);
    let body =
        wire::project_request_body(&ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, y));
    let tenant = |name: &str| vec![("X-Client-Id".to_string(), name.to_string())];

    // burst of 2 admitted, third rejected with the quota tag
    for i in 0..2 {
        let resp = conn.send("POST", "/v1/project", &tenant("tenant-a"), body.as_bytes()).unwrap();
        assert_eq!(resp.status, 200, "burst request {i}");
    }
    let resp = conn.send("POST", "/v1/project", &tenant("tenant-a"), body.as_bytes()).unwrap();
    assert_eq!(resp.status, 429);
    assert!(body_str(&resp).contains("\"error\":\"quota\""), "{}", body_str(&resp));
    assert!(resp.header("retry-after").is_some());
    assert!(resp.header("x-retry-after-micros").is_some());

    // a different client id on the same connection is a different bucket
    let resp = conn.send("POST", "/v1/project", &tenant("tenant-b"), body.as_bytes()).unwrap();
    assert_eq!(resp.status, 200);

    // read-only routes are never quota-gated
    for _ in 0..4 {
        let resp = conn.send("GET", "/healthz", &tenant("tenant-a"), b"").unwrap();
        assert_eq!(resp.status, 200);
    }

    drop(conn);
    let report = server.join();
    assert_eq!(report.quota_rejected, 1);
    assert_eq!(report.overloaded, 0, "quota and overload counters must not mix");
    Arc::try_unwrap(engine).ok().unwrap().shutdown();
}

#[test]
fn stalled_reader_trips_write_timeout_and_is_counted() {
    let engine = Arc::new(Engine::start(&base_serve_cfg()).unwrap());
    let cfg = HttpConfig { write_timeout_ms: 150, ..http_cfg() };
    let server = Server::start(Arc::clone(&engine), &cfg).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(350);
    // A response far larger than the combined socket buffers, so the
    // server's response write stalls once the client stops reading and
    // SO_SNDTIMEO (write_timeout_ms) must break the stall.
    let y = Matrix::<f64>::randn(1024, 512, &mut rng);
    let body =
        wire::project_request_body(&ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, y));
    let conn = TcpStream::connect(server.addr()).unwrap();
    let mut writer = conn.try_clone().unwrap();
    write_request(&mut writer, "POST", "/v1/project", &[], body.as_bytes()).unwrap();
    // deliberately never read the response; give the server time to
    // compute, fill the socket buffers, and hit the write timeout
    std::thread::sleep(Duration::from_millis(2_000));
    drop(writer);
    drop(conn);
    server.drain();
    server.wait_for_drain();
    let report = server.join();
    assert!(report.write_timeouts >= 1, "stalled reader must be counted: {report:?}");
    Arc::try_unwrap(engine).ok().unwrap().shutdown();
}

#[test]
fn sse_events_stream_monotonic_snapshots_over_socket() {
    let engine = Arc::new(Engine::start(&base_serve_cfg()).unwrap());
    let cfg = HttpConfig { sse_interval_ms: 30, ..http_cfg() };
    let server = Server::start(Arc::clone(&engine), &cfg).unwrap();

    // traffic in the background so the counters actually move mid-stream
    let bg_engine = Arc::clone(&engine);
    let bg = std::thread::spawn(move || {
        let mut rng = Xoshiro256pp::seed_from_u64(330);
        for _ in 0..30 {
            let y = Matrix::<f64>::randn(8, 8, &mut rng);
            let _ = bg_engine
                .submit_wait(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, y));
            std::thread::sleep(Duration::from_millis(2));
        }
    });

    let mut conn = Conn::open(server.addr());
    write_request(&mut conn.writer, "GET", "/v1/events?n=4", &[], b"").unwrap();
    let limits = HttpLimits::default();
    let (status, headers) = read_response_head(&mut conn.reader, &limits).unwrap();
    assert_eq!(status, 200);
    assert!(headers
        .iter()
        .any(|(k, v)| k == "content-type" && v.starts_with("text/event-stream")));

    let mut text = String::new();
    while let Some(chunk) = read_chunk(&mut conn.reader).unwrap() {
        text.push_str(std::str::from_utf8(&chunk).unwrap());
    }
    bg.join().unwrap();

    let mut seqs = Vec::new();
    let mut submitted = Vec::new();
    for line in text.lines().filter(|l| l.starts_with("data: {\"seq\":")) {
        let json = wire::Json::parse(&line["data: ".len()..]).unwrap();
        seqs.push(json.get("seq").and_then(wire::Json::as_u64).unwrap());
        submitted.push(json.get("submitted").and_then(wire::Json::as_u64).unwrap());
    }
    assert_eq!(seqs, vec![0, 1, 2, 3], "snapshots must be sequenced");
    assert!(
        submitted.windows(2).all(|w| w[0] <= w[1]),
        "submitted counter must be monotonic: {submitted:?}"
    );
    assert!(
        *submitted.last().unwrap() > submitted[0],
        "counters should move under background traffic: {submitted:?}"
    );

    drop(conn);
    server.join();
    Arc::try_unwrap(engine).ok().unwrap().shutdown();
}

#[test]
fn drain_composes_with_encoder_hot_swap_zero_lost_requests() {
    let engine = Arc::new(Engine::start(&base_serve_cfg()).unwrap());
    let enc_a = test_encoder::<f64>(341);
    let enc_b = test_encoder::<f64>(342);
    let id = engine.register_encoder_f64(enc_a.clone());

    let mut rng = Xoshiro256pp::seed_from_u64(340);
    let x = Matrix::<f64>::randn(10, 5, &mut rng);
    let expect_a = enc_a.encode(&x);
    let expect_b = enc_b.encode(&x);
    assert!(!bits_equal(&expect_a, &expect_b), "the two encoders must be distinguishable");

    let server = Server::start(Arc::clone(&engine), &http_cfg()).unwrap();
    let addr = server.addr();
    let body = wire::encode_request_body(&Payload::F64(x.clone()));
    let a_seen = AtomicU64::new(0);
    let b_seen = AtomicU64::new(0);

    std::thread::scope(|s| {
        for _ in 0..4 {
            let body = body.as_str();
            let (a_seen, b_seen) = (&a_seen, &b_seen);
            let expect_a = &expect_a;
            let expect_b = &expect_b;
            s.spawn(move || {
                let mut conn = Conn::open(addr);
                let path = format!("/v1/encode/{id}");
                for _ in 0..100_000 {
                    match conn.send("POST", &path, &[], body.as_bytes()) {
                        Ok(resp) if resp.status == 200 => {
                            let wire_resp = wire::decode_response(body_str(&resp)).unwrap();
                            let h = wire_resp.payload.as_f64().unwrap();
                            if bits_equal(h, expect_a) {
                                a_seen.fetch_add(1, Ordering::Relaxed);
                            } else if bits_equal(h, expect_b) {
                                b_seen.fetch_add(1, Ordering::Relaxed);
                            } else {
                                panic!("200 response matched neither encoder");
                            }
                        }
                        Ok(resp) if resp.status == 429 => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        // 503 = drained; Err = connection closed by drain
                        Ok(_) | Err(_) => return,
                    }
                }
                panic!("drain never arrived");
            });
        }

        // let traffic run on encoder A, hot-swap to B mid-flight, let it
        // run some more, then drain over the wire — all under load
        std::thread::sleep(Duration::from_millis(150));
        engine.swap_encoder_f64(id, enc_b.clone()).unwrap();
        std::thread::sleep(Duration::from_millis(150));
        let mut conn = Conn::open(addr);
        let resp = conn.send("POST", "/v1/drain", &[], b"").unwrap();
        assert_eq!(resp.status, 200);
    });

    server.wait_for_drain();
    let report = server.join();
    let (a_n, b_n) = (a_seen.load(Ordering::Relaxed), b_seen.load(Ordering::Relaxed));
    assert!(a_n > 0, "some responses must come from the pre-swap encoder");
    assert!(b_n > 0, "some responses must come from the post-swap encoder");
    // zero lost accepted requests: every 200 the server wrote was read and
    // verified by a client (+1 for the drain acknowledgement itself), and
    // every engine completion was delivered
    assert_eq!(report.served_ok, a_n + b_n + 1, "{report:?}");
    let stats = Arc::try_unwrap(engine).ok().unwrap().shutdown();
    assert_eq!(stats.completed(), a_n + b_n);
}
