//! Acceptance suite for the structured-sparse inference subsystem:
//!
//! * compact → decompact is the identity on alive features and zero
//!   elsewhere (property, random pruned SAEs);
//! * sparse encode ≡ dense encode **bit-identically** for f32 and f64 at
//!   every sparsity level, including 0% (nothing pruned) and 100% (all
//!   columns dead);
//! * plan / mask consistency with `SaeParams::alive_features`;
//! * the serve engine's sparse-encode job kind returns exactly the
//!   library's sparse encode, end to end.

use bilevel_sparse::config::ServeConfig;
use bilevel_sparse::model::{SaeDims, SaeParams};
use bilevel_sparse::projection::bilevel::bilevel_l1inf_inplace_cols;
use bilevel_sparse::projection::l1::L1Algorithm;
use bilevel_sparse::proptest::{forall, PropConfig, SparseSaeCase};
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::scalar::Scalar;
use bilevel_sparse::serve::{Engine, JobKind, Payload};
use bilevel_sparse::sparse::{
    compact_params, decompact_params, linalg, CompactEncoder, CompactPlan,
};
use bilevel_sparse::tensor::Matrix;

fn assert_bits_eq<T: Scalar>(a: &[T], b: &[T], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(
            x.to_f64().to_bits(),
            y.to_f64().to_bits(),
            "{what}: element {i}: {x} vs {y}"
        );
    }
}

#[test]
fn prop_compact_decompact_roundtrip() {
    forall::<SparseSaeCase>(PropConfig { cases: 200, ..Default::default() }, |case| {
        let plan = CompactPlan::from_mask(&case.mask);
        let compact = compact_params(&case.params, &plan);
        if compact.dims.features != plan.alive() {
            return Err("compact feature count != plan alive".into());
        }
        let back = decompact_params(&compact, &plan);
        if back.dims != case.params.dims {
            return Err("decompact dims changed".into());
        }
        let h = case.params.dims.hidden;
        let m = case.params.dims.features;
        for f in 0..m {
            if plan.is_alive(f) {
                for k in 0..h {
                    let (a, b) =
                        (back.tensors[0][f * h + k], case.params.tensors[0][f * h + k]);
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("w1 row {f} not identical: {a} vs {b}"));
                    }
                }
                for i in 0..h {
                    let (a, b) =
                        (back.tensors[6][i * m + f], case.params.tensors[6][i * m + f]);
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("w4 col {f} not identical: {a} vs {b}"));
                    }
                }
                if back.tensors[7][f].to_bits() != case.params.tensors[7][f].to_bits() {
                    return Err(format!("b4[{f}] not identical"));
                }
            } else {
                // pruned features come back zero in every tensor the plan
                // touches (the source W4/b4 may be non-zero — the mask
                // only zeroes W1 rows, so dropping them is by design)
                if back.tensors[0][f * h..(f + 1) * h].iter().any(|&v| v != 0.0) {
                    return Err(format!("pruned w1 row {f} not zero"));
                }
                if (0..h).any(|i| back.tensors[6][i * m + f] != 0.0) {
                    return Err(format!("pruned w4 col {f} not zero"));
                }
                if back.tensors[7][f] != 0.0 {
                    return Err(format!("pruned b4[{f}] not zero"));
                }
            }
        }
        // feature-free tensors round-trip untouched
        for t in [1usize, 2, 3, 4, 5] {
            if back.tensors[t] != case.params.tensors[t] {
                return Err(format!("tensor {t} changed in round-trip"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_encode_bit_identical_to_dense_f32_and_f64() {
    forall::<SparseSaeCase>(PropConfig { cases: 200, ..Default::default() }, |case| {
        let plan = CompactPlan::from_mask(&case.mask);
        let p = &case.params;
        let hidden = p.dims.hidden;
        // f32: the model's native dtype.
        let x32: Matrix<f32> = case.x.cast();
        let enc32 = CompactEncoder::<f32>::from_params(p, &plan);
        let sparse32 = enc32.encode(&x32);
        let mut dense32 = Matrix::zeros(0, 0);
        linalg::encode_batch_dense_into(&x32, &p.tensors[0], &p.tensors[1], hidden, &mut dense32);
        for (a, b) in sparse32.as_slice().iter().zip(dense32.as_slice().iter()) {
            if a.to_bits() != b.to_bits() {
                return Err(format!("f32 sparse {a} != dense {b}"));
            }
        }
        // f64: widened weights (exact), f64 inputs.
        let enc64 = CompactEncoder::<f64>::from_params(p, &plan);
        let w1_64: Vec<f64> = p.tensors[0].iter().map(|&v| v as f64).collect();
        let b1_64: Vec<f64> = p.tensors[1].iter().map(|&v| v as f64).collect();
        let sparse64 = enc64.encode(&case.x);
        let mut dense64 = Matrix::zeros(0, 0);
        linalg::encode_batch_dense_into(&case.x, &w1_64, &b1_64, hidden, &mut dense64);
        for (a, b) in sparse64.as_slice().iter().zip(dense64.as_slice().iter()) {
            if a.to_bits() != b.to_bits() {
                return Err(format!("f64 sparse {a} != dense {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_plan_mask_consistency_with_alive_features() {
    forall::<SparseSaeCase>(PropConfig { cases: 200, ..Default::default() }, |case| {
        let plan = CompactPlan::from_mask(&case.mask);
        if plan.mask() != case.mask {
            return Err("plan.mask() != source mask".into());
        }
        // He-init rows are non-zero, so after masking the alive count is
        // exactly the mask's support.
        if plan.alive() != case.params.alive_features() {
            return Err(format!(
                "plan alive {} != params alive_features {}",
                plan.alive(),
                case.params.alive_features()
            ));
        }
        let compact = compact_params(&case.params, &plan);
        if compact.alive_features() != plan.alive() {
            return Err("compacted model lost alive features".into());
        }
        for (c, &f) in plan.alive_indices().iter().enumerate() {
            if plan.compact_of(f) != Some(c) || plan.original_of(c) != f {
                return Err(format!("index maps disagree at compact {c} / original {f}"));
            }
        }
        Ok(())
    });
}

/// Deterministic sweep of sparsity levels (incl. both extremes) for both
/// dtypes — the fixed-grid complement of the property tests.
#[test]
fn sparse_encode_matches_dense_at_every_sparsity_level() {
    let (features, hidden, batch) = (40usize, 7usize, 5usize);
    for pct in [0usize, 25, 50, 90, 100] {
        let mut rng = Xoshiro256pp::seed_from_u64(4242 + pct as u64);
        let mut p =
            SaeParams::init(SaeDims { features, hidden, classes: 2 }, &mut rng);
        let n_dead = features * pct / 100;
        let mask: Vec<f32> =
            (0..features).map(|f| if f < n_dead { 0.0 } else { 1.0 }).collect();
        p.apply_feature_mask(&mask);
        let plan = CompactPlan::from_mask(&mask);
        assert_eq!(plan.alive(), features - n_dead, "{pct}%");

        let x64 = Matrix::<f64>::randn(features, batch, &mut rng);
        let x32: Matrix<f32> = x64.cast();
        let enc32 = CompactEncoder::<f32>::from_params(&p, &plan);
        let sparse = enc32.encode(&x32);
        let mut dense = Matrix::zeros(0, 0);
        linalg::encode_batch_dense_into(
            &x32,
            &p.tensors[0],
            &p.tensors[1],
            hidden,
            &mut dense,
        );
        assert_bits_eq(sparse.as_slice(), dense.as_slice(), &format!("f32 {pct}%"));
        // 100%: output is exactly the bias for every sample
        if pct == 100 {
            for j in 0..batch {
                assert_bits_eq(sparse.col(j), &p.tensors[1], "100% = bias");
            }
        }
    }
}

/// The full pipeline the `sparsify` CLI runs: project → plan from
/// thresholds → compact → sparse encode ≡ dense encode bitwise.
#[test]
fn projected_model_compacts_and_encodes_bit_identically() {
    let (features, hidden) = (96usize, 11usize);
    let mut rng = Xoshiro256pp::seed_from_u64(9001);
    let mut p = SaeParams::init(SaeDims { features, hidden, classes: 2 }, &mut rng);
    let mut ws = bilevel_sparse::kernels::Workspace::new();
    // Radius far below the init norm ⇒ the projection kills many columns.
    bilevel_l1inf_inplace_cols(&mut p.tensors[0], hidden, 0.5f32, L1Algorithm::Condat, &mut ws);
    let plan = CompactPlan::from_thresholds(ws.thresholds(), 0.0);
    assert!(plan.alive() < features, "projection should prune columns");
    assert!((plan.sparsity_percent() - 100.0 * (features - plan.alive()) as f64
        / features as f64)
        .abs()
        < 1e-12);

    let x = Matrix::<f32>::randn(features, 6, &mut rng);
    let enc = CompactEncoder::<f32>::from_params(&p, &plan);
    let sparse = enc.encode(&x);
    let mut dense = Matrix::zeros(0, 0);
    linalg::encode_batch_dense_into(&x, &p.tensors[0], &p.tensors[1], hidden, &mut dense);
    assert_bits_eq(sparse.as_slice(), dense.as_slice(), "projected model encode");

    // The compacted model re-expanded: bitwise on alive rows; pruned rows
    // are numerically zero (the projection may leave -0.0 there, the
    // decompaction writes +0.0 — equal as numbers, not always as bits).
    let back = decompact_params(&compact_params(&p, &plan), &plan);
    for f in 0..features {
        let (a, b) = (
            &back.tensors[0][f * hidden..(f + 1) * hidden],
            &p.tensors[0][f * hidden..(f + 1) * hidden],
        );
        if plan.is_alive(f) {
            assert_bits_eq(a, b, &format!("projected w1 row {f} round-trip"));
        } else {
            assert!(a.iter().all(|&v| v == 0.0), "decompacted dead row {f} not zero");
            assert!(b.iter().all(|&v| v == 0.0), "projected dead row {f} not zero");
        }
    }
}

#[test]
fn serve_sparse_encode_end_to_end_matches_library() {
    let cfg = ServeConfig {
        shards: 2,
        workers_per_shard: 1,
        queue_capacity: 32,
        max_batch: 4,
        min_fill: 1,
        max_wait_micros: 100,
        cache_capacity: 8,
        ..ServeConfig::default()
    };
    let engine = Engine::start(&cfg).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(515);
    let mut p = SaeParams::init(SaeDims { features: 20, hidden: 6, classes: 2 }, &mut rng);
    let mask: Vec<f32> = (0..20).map(|f| if f % 3 == 0 { 0.0 } else { 1.0 }).collect();
    p.apply_feature_mask(&mask);
    let plan = CompactPlan::from_mask(&mask);
    let enc64 = CompactEncoder::<f64>::from_params(&p, &plan);
    let enc32 = CompactEncoder::<f32>::from_params(&p, &plan);
    let m64 = engine.register_encoder_f64(enc64.clone());
    let m32 = engine.register_encoder_f32(enc32.clone());
    assert_eq!(engine.encoder_count(), 2);

    for i in 0..6u64 {
        let x = Matrix::<f64>::randn(20, 3, &mut Xoshiro256pp::seed_from_u64(600 + i));
        let resp = engine.submit_encode_wait(m64, Payload::F64(x.clone())).unwrap();
        assert_eq!(resp.kind, JobKind::SparseEncode { model: m64 });
        let Payload::F64(h) = &resp.payload else { panic!("dtype changed") };
        assert_bits_eq(h.as_slice(), enc64.encode(&x).as_slice(), "served f64 encode");

        let x32: Matrix<f32> = x.cast();
        let resp = engine.submit_encode_wait(m32, Payload::F32(x32.clone())).unwrap();
        let Payload::F32(h) = &resp.payload else { panic!("dtype changed") };
        assert_bits_eq(h.as_slice(), enc32.encode(&x32).as_slice(), "served f32 encode");
    }
    let stats = engine.shutdown();
    assert_eq!(stats.completed(), 12);
    assert_eq!(stats.submitted(), 12);
    // encode traffic never counts against the threshold cache
    assert_eq!(stats.cache_hits() + stats.cache_misses(), 0);
}
