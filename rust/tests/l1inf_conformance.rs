//! Differential conformance suite for the ℓ1,∞ solver family.
//!
//! Cross-checks every exact solver (`quattoni`, `newton`, `ssn`) against
//! the others (and the bisection golden reference) over random matrices
//! spanning shapes, dtypes, and radii — including degenerate cases (η = 0,
//! η ≥ ‖Y‖₁,∞, duplicate column norms, single row/column) — and checks the
//! bi-level `BP¹,∞` against the exact family on the paper's claims:
//!
//! * feasibility `‖BP(Y)‖₁,∞ ≤ η`;
//! * the Prop. III.3 identity
//!   `‖Y − BP(Y)‖₁,∞ + ‖BP(Y)‖₁,∞ = ‖Y‖₁,∞`;
//! * structured sparsity no worse than the exact projection on the
//!   paper's scale-separated ensembles (the Fig. 2 claim — empirical on
//!   that matrix family, not an instance-wise theorem, so the ensemble
//!   mirrors the paper's).
//!
//! Referenced from `rust/src/projection/bilevel/mod.rs`.

use bilevel_sparse::norms::l1inf_norm;
use bilevel_sparse::projection::bilevel::bilevel_l1inf_with;
use bilevel_sparse::projection::l1::L1Algorithm;
use bilevel_sparse::projection::l1inf::{project_l1inf_with, L1InfAlgorithm};
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::scalar::Scalar;
use bilevel_sparse::tensor::Matrix;

const EXACT: [L1InfAlgorithm; 3] =
    [L1InfAlgorithm::Quattoni, L1InfAlgorithm::Newton, L1InfAlgorithm::Ssn];

/// The shape grid: tall, wide, square, and single-row / single-column.
const SHAPES: [(usize, usize); 7] =
    [(1, 1), (1, 24), (24, 1), (8, 8), (40, 12), (12, 40), (30, 30)];

/// Radius fractions of ‖Y‖₁,∞, spanning tight → inside-the-ball.
const ETA_FRACS: [f64; 4] = [0.05, 0.3, 0.8, 1.5];

fn randmat(n: usize, m: usize, seed: u64) -> Matrix<f64> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    Matrix::randn(n, m, &mut rng)
}

/// A matrix with exact duplicate columns (and therefore duplicate column
/// norms) — the tie-handling stressor.
fn dupmat(n: usize, m: usize, seed: u64) -> Matrix<f64> {
    let mut y = randmat(n, m, seed);
    for j in (1..m).step_by(2) {
        let src = y.col(j - 1).to_vec();
        y.col_mut(j).copy_from_slice(&src);
    }
    y
}

/// Solver-agreement check at one (matrix, η) point. `tol` is absolute on
/// entries (inputs are standard-normal scale).
fn check_exact_agreement<T: Scalar>(y: &Matrix<T>, eta: T, tol: f64, what: &str) {
    let golden = project_l1inf_with(y, eta, L1InfAlgorithm::Bisection);
    for algo in EXACT {
        let r = project_l1inf_with(y, eta, algo);
        let diff = golden.x.max_abs_diff(&r.x);
        assert!(
            diff < tol,
            "{what}: {} disagrees with bisection by {diff:e} (eta {eta})",
            algo.name()
        );
        // μ levels drive the clip, so they must agree wherever they matter.
        for (j, (a, b)) in golden.mu.iter().zip(r.mu.iter()).enumerate() {
            assert!(
                (a.to_f64() - b.to_f64()).abs() < tol,
                "{what}: {} mu[{j}] {b} vs golden {a}",
                algo.name()
            );
        }
    }
}

/// Feasibility + Prop. III.3 identity for `BP¹,∞` at one point, and
/// feasibility cross-checked against the exact family's ball.
fn check_bilevel_claims<T: Scalar>(y: &Matrix<T>, eta: T, tol: f64, what: &str) {
    let r = bilevel_l1inf_with(y, eta, L1Algorithm::Condat);
    let norm = l1inf_norm(&r.x).to_f64();
    let slack = tol * (1.0 + eta.to_f64());
    assert!(
        norm <= eta.to_f64() + slack,
        "{what}: BP infeasible: ||BP(Y)|| = {norm} > eta = {eta}"
    );
    let lhs = l1inf_norm(&y.sub(&r.x)).to_f64() + norm;
    let rhs = l1inf_norm(y).to_f64();
    assert!(
        (lhs - rhs).abs() < tol * (1.0 + rhs),
        "{what}: Prop. III.3 identity violated: {lhs} vs {rhs}"
    );
    // The exact projection at the same radius is feasible too (sanity that
    // both families talk about the same ball).
    let exact = project_l1inf_with(y, eta, L1InfAlgorithm::Ssn);
    assert!(
        l1inf_norm(&exact.x).to_f64() <= eta.to_f64() + slack,
        "{what}: exact infeasible"
    );
}

#[test]
fn exact_solvers_agree_across_shapes_and_radii_f64() {
    for (i, &(n, m)) in SHAPES.iter().enumerate() {
        let y = randmat(n, m, 1000 + i as u64);
        let norm = l1inf_norm(&y);
        for &frac in &ETA_FRACS {
            check_exact_agreement(&y, norm * frac, 1e-6, &format!("{n}x{m} frac {frac}"));
        }
    }
}

#[test]
fn exact_solvers_agree_across_shapes_and_radii_f32() {
    // f32 convergence is EPSILON-scaled; the agreement bound scales
    // accordingly (≈ 5e-3 absolute on standard-normal entries).
    for (i, &(n, m)) in SHAPES.iter().enumerate() {
        let y: Matrix<f32> = randmat(n, m, 2000 + i as u64).cast();
        let norm = l1inf_norm(&y);
        for &frac in &[0.1f32, 0.5] {
            check_exact_agreement(&y, norm * frac, 5e-3, &format!("f32 {n}x{m} frac {frac}"));
        }
    }
}

#[test]
fn exact_solvers_agree_on_duplicate_column_norms() {
    for (n, m, seed) in [(10usize, 8usize, 1u64), (6, 12, 2), (20, 6, 3)] {
        let y = dupmat(n, m, 3000 + seed);
        let norm = l1inf_norm(&y);
        for &frac in &[0.1, 0.4, 0.9] {
            check_exact_agreement(&y, norm * frac, 1e-6, &format!("dup {n}x{m} frac {frac}"));
        }
        // constant matrix: every column norm tied
        let c = Matrix::<f64>::full(n, m, 1.25);
        check_exact_agreement(&c, l1inf_norm(&c) * 0.5, 1e-6, &format!("const {n}x{m}"));
    }
}

#[test]
fn degenerate_radii_are_consistent_across_all_solvers() {
    let y = randmat(9, 7, 4000);
    // η = 0 ⇒ zero matrix from every solver and from BP.
    for algo in L1InfAlgorithm::all() {
        let r = project_l1inf_with(&y, 0.0, *algo);
        assert_eq!(r.x.count_zeros(0.0), 63, "{}: eta=0", algo.name());
    }
    let bp0 = bilevel_l1inf_with(&y, 0.0, L1Algorithm::Condat);
    assert_eq!(bp0.x.count_zeros(0.0), 63, "BP eta=0");
    assert!(bp0.thresholds.iter().all(|&u| u == 0.0));
    // η ≥ ‖Y‖ ⇒ identity from every solver and from BP.
    let big = l1inf_norm(&y) * 1.5;
    for algo in L1InfAlgorithm::all() {
        let r = project_l1inf_with(&y, big, *algo);
        assert_eq!(y.max_abs_diff(&r.x), 0.0, "{}: eta>=norm", algo.name());
    }
    let bp = bilevel_l1inf_with(&y, big, L1Algorithm::Condat);
    assert!(y.max_abs_diff(&bp.x) < 1e-12, "BP eta>=norm");
}

#[test]
fn bilevel_feasibility_and_identity_f64() {
    for (i, &(n, m)) in SHAPES.iter().enumerate() {
        let y = randmat(n, m, 5000 + i as u64);
        let norm = l1inf_norm(&y);
        for &frac in &ETA_FRACS {
            check_bilevel_claims(&y, norm * frac, 1e-9, &format!("{n}x{m} frac {frac}"));
        }
        check_bilevel_claims(&y, 0.0, 1e-9, &format!("{n}x{m} eta=0"));
        // duplicate-column ties
        let d = dupmat(n, m.max(2), 6000 + i as u64);
        check_bilevel_claims(&d, l1inf_norm(&d) * 0.2, 1e-9, &format!("dup {n}x{m}"));
    }
}

#[test]
fn bilevel_feasibility_and_identity_f32() {
    for (i, &(n, m)) in SHAPES.iter().enumerate() {
        let y: Matrix<f32> = randmat(n, m, 7000 + i as u64).cast();
        let norm = l1inf_norm(&y);
        for &frac in &[0.05f32, 0.3, 0.8] {
            check_bilevel_claims(&y, norm * frac, 1e-3, &format!("f32 {n}x{m} frac {frac}"));
        }
    }
}

#[test]
fn bilevel_every_inner_solver_satisfies_the_claims() {
    let y = randmat(25, 18, 8000);
    let eta = l1inf_norm(&y) * 0.25;
    let base = bilevel_l1inf_with(&y, eta, L1Algorithm::Sort);
    for algo in L1Algorithm::all() {
        let r = bilevel_l1inf_with(&y, eta, *algo);
        assert!(l1inf_norm(&r.x) <= eta + 1e-9, "{} infeasible", algo.name());
        assert!(
            base.x.max_abs_diff(&r.x) < 1e-8,
            "{} diverges from sort inner solver",
            algo.name()
        );
    }
}

/// The paper's Fig. 2 matrix family: gaussian columns with a few boosted
/// (scale-separated) ones, aggressive radius — the regime where the
/// bi-level projection's sparsity advantage shows.
fn boosted(n: usize, m: usize, boost: usize, factor: f64, seed: u64) -> Matrix<f64> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut y = Matrix::<f64>::randn(n, m, &mut rng);
    for j in 0..boost.min(m) {
        for v in y.col_mut(j) {
            *v *= factor;
        }
    }
    y
}

#[test]
fn bilevel_sparsity_no_worse_than_exact_on_paper_ensembles() {
    let mut total_bp = 0usize;
    let mut total_exact = 0usize;
    for (case, (n, m, boost, factor, frac)) in [
        (50usize, 40usize, 6usize, 20.0f64, 0.05f64),
        (50, 40, 6, 50.0, 0.05),
        (30, 60, 8, 30.0, 0.03),
        (80, 25, 4, 25.0, 0.08),
        (64, 64, 10, 40.0, 0.04),
    ]
    .into_iter()
    .enumerate()
    {
        for seed in 0..4u64 {
            let y = boosted(n, m, boost, factor, 9000 + 17 * case as u64 + seed);
            let eta = l1inf_norm(&y) * frac;
            let bp = bilevel_l1inf_with(&y, eta, L1Algorithm::Condat);
            let exact = project_l1inf_with(&y, eta, L1InfAlgorithm::Ssn);
            let s_bp = bp.x.zero_columns(1e-12).len();
            let s_exact = exact.x.zero_columns(1e-12).len();
            assert!(
                s_bp >= s_exact,
                "case {case} seed {seed}: BP zero-cols {s_bp} < exact {s_exact}"
            );
            // a zero threshold always means a zeroed column (the reverse
            // can miss epsilon-sized thresholds, so inclusion, not
            // equality)
            assert!(bp.zero_columns().len() <= s_bp, "case {case} seed {seed}");
            total_bp += s_bp;
            total_exact += s_exact;
        }
    }
    assert!(
        total_bp > total_exact,
        "BP should be strictly sparser in aggregate: {total_bp} vs {total_exact}"
    );
}
