//! Zero-allocation proof for the workspace projection path.
//!
//! A counting global allocator wraps the system allocator; after a warm-up
//! call sizes every buffer, repeated `bilevel_l1inf_into` calls (varying
//! radius and matrix contents, fixed shape) must not touch the allocator
//! at all. Lives in its own integration-test binary so no concurrently
//! running test can pollute the counter; the single `#[test]` keeps the
//! harness quiet while the measurement runs.

// Integration tests are separate crates, so the crate-wide lint from
// lib.rs must be restated here for the allocator below.
#![deny(unsafe_op_in_unsafe_fn)]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

// SAFETY: a pure pass-through to the system allocator — identical layout
// contract, identical returned pointers; the atomic counter is the only
// addition and has no effect on allocation behaviour.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: forwarded verbatim; the caller's layout contract transfers.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: same layout, same contract as this call received.
        unsafe { System.alloc(layout) }
    }
    // SAFETY: forwarded verbatim; the caller's layout contract transfers.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: same layout, same contract as this call received.
        unsafe { System.alloc_zeroed(layout) }
    }
    // SAFETY: forwarded verbatim; the caller's layout contract transfers.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: same pointer/layout/size, same contract as received.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
    // SAFETY: forwarded verbatim; the caller's layout contract transfers.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: same pointer and layout, same contract as received.
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOC_CALLS.load(Ordering::SeqCst)
}

use bilevel_sparse::kernels::Workspace;
use bilevel_sparse::projection::bilevel::{bilevel_l1inf_into, bilevel_l1inf_with};
use bilevel_sparse::projection::l1::L1Algorithm;
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::tensor::Matrix;

#[test]
fn steady_state_projection_allocates_nothing() {
    let (n, m) = (96, 64);
    let mut rng = Xoshiro256pp::seed_from_u64(31);
    let matrices: Vec<Matrix<f64>> =
        (0..4).map(|_| Matrix::randn(n, m, &mut rng)).collect();

    let mut ws = Workspace::new();
    let mut out = Matrix::zeros(n, m);

    // Warm-up: sizes the norm/threshold buffers, the Condat scratch, and
    // the output buffer for this shape.
    for y in &matrices {
        bilevel_l1inf_into(y, 2.0, L1Algorithm::Condat, &mut ws, &mut out);
    }

    // Steady state: vary matrix contents and radius (covering the tight,
    // loose, and zero-radius execution paths) at a fixed shape.
    let before = alloc_count();
    for round in 0..50 {
        let y = &matrices[round % matrices.len()];
        for eta in [0.0, 1.5, 40.0, 1e9] {
            bilevel_l1inf_into(y, eta, L1Algorithm::Condat, &mut ws, &mut out);
        }
    }
    let delta = alloc_count() - before;
    assert_eq!(
        delta, 0,
        "steady-state bilevel_l1inf_into must not allocate (saw {delta} allocator calls)"
    );

    // Sanity: the workspace path still computes the right answer.
    let reference = bilevel_l1inf_with(&matrices[3], 1e9, L1Algorithm::Condat);
    bilevel_l1inf_into(&matrices[3], 1e9, L1Algorithm::Condat, &mut ws, &mut out);
    assert_eq!(reference.x.max_abs_diff(&out), 0.0);
}
