//! Differential conformance suite for the projection-family operators
//! grown around the paper's bi-level core: the flat ℓ2,1 and ℓ∞,1 balls
//! and the multilevel projection tree.
//!
//! Mirrors `l1inf_conformance.rs`: every operator is checked over a shape
//! grid spanning tall/wide/square and single-row/column, radius fractions
//! spanning tight → inside-the-ball, f32 and f64, duplicate/constant
//! rows, and the η = 0 / η ≥ ‖Y‖ edges, against independent in-test
//! oracles:
//!
//! * ℓ2,1 — a structural port of the reference `proj_l21ball`
//!   (SNIPPETS.md): aggregate per row, ℓ1-project the aggregate vector,
//!   radially rescale each row. (The snippet aggregates *squared* sums
//!   per column of a transposed layout; the shipped operator and this
//!   oracle aggregate row ℓ2 norms — the standard ℓ2,1 group lasso.)
//! * ℓ∞,1 — the exact per-column ℓ1-ball threshold from the breakpoint
//!   profile (`ColumnProfile::mu_at`), independent of the production
//!   Newton iteration.
//! * multilevel — a property pinning the depth-2 `l1/linf` tree
//!   **bitwise** to `bilevel_l1inf`, sequential and pool-parallel.
//!
//! The serve tier is covered end to end: the new kinds submit through the
//! engine (provably bypassing the threshold cache — no thresholds, no
//! replay) and round-trip `POST /v1/project` over a real socket
//! bit-identical to the in-process library calls.

use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use bilevel_sparse::config::{HttpConfig, ServeConfig};
use bilevel_sparse::net::http::{read_response, write_request, HttpError, HttpLimits, Response};
use bilevel_sparse::net::{wire, Server};
use bilevel_sparse::norms::{l1inf_norm, l21_norm, linf1_norm};
use bilevel_sparse::projection::bilevel::{bilevel_l1inf_with, ParallelPolicy};
use bilevel_sparse::projection::l1::{project_l1, L1Algorithm};
use bilevel_sparse::projection::l1inf::profile::ColumnProfile;
use bilevel_sparse::projection::l21::project_l21_with;
use bilevel_sparse::projection::linf1::project_linf1;
use bilevel_sparse::projection::multilevel::{project_multilevel_with, MultilevelSpec};
use bilevel_sparse::projection::ProjectionKind;
use bilevel_sparse::proptest::{forall, MatrixAndRadius, PropConfig};
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::scalar::Scalar;
use bilevel_sparse::serve::{Engine, ProjectionRequest};
use bilevel_sparse::tensor::Matrix;

/// The shape grid: tall, wide, square, and single-row / single-column.
const SHAPES: [(usize, usize); 7] =
    [(1, 1), (1, 24), (24, 1), (8, 8), (40, 12), (12, 40), (30, 30)];

/// Radius fractions of the operator's own norm, tight → inside-the-ball.
const ETA_FRACS: [f64; 4] = [0.05, 0.3, 0.8, 1.5];

fn randmat(n: usize, m: usize, seed: u64) -> Matrix<f64> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    Matrix::randn(n, m, &mut rng)
}

/// Exact duplicate *rows* (duplicate row ℓ2 norms) — the ℓ2,1
/// tie-handling stressor, the row-wise dual of `dupmat` in
/// `l1inf_conformance.rs`.
fn duprowmat(n: usize, m: usize, seed: u64) -> Matrix<f64> {
    let mut y = randmat(n, m, seed);
    for i in (1..n).step_by(2) {
        for j in 0..m {
            let v = y.get(i - 1, j);
            y.set(i, j, v);
        }
    }
    y
}

fn bits_equal<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>) -> bool {
    a.rows() == b.rows()
        && a.cols() == b.cols()
        && a.as_slice()
            .iter()
            .zip(b.as_slice())
            .all(|(x, y)| x.to_f64().to_bits() == y.to_f64().to_bits())
}

// ------------------------------------------------------------- ℓ2,1 oracle

/// Structural port of the reference `proj_l21ball`: aggregate per group,
/// ℓ1-project the aggregate vector, project each group onto the ℓ2 ball
/// of its projected aggregate (here a pure radial rescale, since the
/// soft-thresholded aggregate never exceeds the original row norm).
fn l21_oracle(y: &Matrix<f64>, eta: f64) -> Matrix<f64> {
    let n = y.rows();
    let mut sumsq = vec![0.0f64; n];
    for col in y.columns() {
        for (acc, &v) in sumsq.iter_mut().zip(col.iter()) {
            *acc += v * v;
        }
    }
    let w: Vec<f64> = sumsq.into_iter().map(f64::sqrt).collect();
    if eta <= 0.0 {
        return Matrix::zeros(n, y.cols());
    }
    if w.iter().sum::<f64>() <= eta {
        return y.clone();
    }
    let pw = project_l1(&w, eta, L1Algorithm::Sort);
    let mut out = y.clone();
    for j in 0..y.cols() {
        for i in 0..n {
            let s = if w[i] > 0.0 { pw[i] / w[i] } else { 0.0 };
            out.set(i, j, y.get(i, j) * s);
        }
    }
    out
}

// ------------------------------------------------------------ ℓ∞,1 oracle

/// Exact per-column ℓ1-ball projection via the breakpoint profile:
/// `mu_at(η)` inverts the clipped-mass function, so the soft threshold it
/// returns leaves the column with ℓ1 norm exactly η.
fn linf1_oracle(y: &Matrix<f64>, eta: f64) -> Matrix<f64> {
    let mut out = y.clone();
    for j in 0..y.cols() {
        let col = y.col(j);
        let s: f64 = col.iter().map(|v| v.abs()).sum();
        if s <= eta {
            continue;
        }
        let tau = ColumnProfile::new(col).mu_at(eta).0;
        for (i, &v) in col.iter().enumerate() {
            out.set(i, j, v.signum() * (v.abs() - tau).max(0.0));
        }
    }
    out
}

// ----------------------------------------------------------------- ℓ2,1

#[test]
fn l21_feasible_idempotent_and_matches_oracle_f64() {
    for (i, &(n, m)) in SHAPES.iter().enumerate() {
        let y = randmat(n, m, 1000 + i as u64);
        let total = l21_norm(&y);
        for &frac in &ETA_FRACS {
            let eta = total * frac;
            let x = project_l21_with(&y, eta, L1Algorithm::Condat);
            let what = format!("{n}x{m} frac {frac}");
            assert!(l21_norm(&x) <= eta * (1.0 + 1e-9) + 1e-12, "{what}: infeasible");
            assert!(x.max_abs_diff(&l21_oracle(&y, eta)) < 1e-9, "{what}: oracle mismatch");
            let xx = project_l21_with(&x, eta, L1Algorithm::Condat);
            assert!(x.max_abs_diff(&xx) < 1e-9, "{what}: not idempotent");
            // The matched-norm identity is exact for ℓ2,1.
            let gap = l21_norm(&y.sub(&x)) + l21_norm(&x) - total;
            assert!(gap.abs() < 1e-9 * (1.0 + total), "{what}: identity gap {gap:e}");
        }
    }
}

#[test]
fn l21_feasible_and_matches_oracle_f32() {
    for (i, &(n, m)) in SHAPES.iter().enumerate() {
        let y64 = randmat(n, m, 2000 + i as u64);
        let y: Matrix<f32> = y64.cast();
        let total = l21_norm(&y);
        for &frac in &[0.1f32, 0.5] {
            let eta = total * frac;
            let x = project_l21_with(&y, eta, L1Algorithm::Condat);
            let what = format!("f32 {n}x{m} frac {frac}");
            assert!(l21_norm(&x) <= eta * (1.0 + 1e-3), "{what}: infeasible");
            let oracle: Matrix<f32> = l21_oracle(&y64, (total * frac) as f64).cast();
            assert!(x.max_abs_diff(&oracle) < 5e-3, "{what}: oracle mismatch");
        }
    }
}

#[test]
fn l21_inner_solvers_agree_on_duplicate_and_constant_rows() {
    for (n, m, seed) in [(10usize, 8usize, 1u64), (12, 6, 2), (6, 20, 3)] {
        let y = duprowmat(n, m, 3000 + seed);
        let eta = l21_norm(&y) * 0.3;
        let base = project_l21_with(&y, eta, L1Algorithm::Sort);
        for algo in L1Algorithm::all() {
            let x = project_l21_with(&y, eta, *algo);
            assert!(
                base.max_abs_diff(&x) < 1e-8,
                "dup rows {n}x{m}: {} diverges from sort",
                algo.name()
            );
        }
        assert!(base.max_abs_diff(&l21_oracle(&y, eta)) < 1e-9, "dup rows {n}x{m}: oracle");
        // Constant matrix: every row norm tied.
        let c = Matrix::<f64>::full(n, m, 1.25);
        let eta_c = l21_norm(&c) * 0.5;
        let xc = project_l21_with(&c, eta_c, L1Algorithm::Condat);
        assert!(xc.max_abs_diff(&l21_oracle(&c, eta_c)) < 1e-9, "const {n}x{m}: oracle");
    }
}

#[test]
fn l21_edge_radii() {
    let y = randmat(9, 7, 4000);
    // η = 0 ⇒ zero matrix.
    let x0 = project_l21_with(&y, 0.0, L1Algorithm::Condat);
    assert!(x0.as_slice().iter().all(|&v| v == 0.0), "eta=0 must zero");
    // η ≥ ‖Y‖₂,₁ ⇒ bitwise no-op.
    let x = project_l21_with(&y, l21_norm(&y) * 1.5, L1Algorithm::Condat);
    assert!(bits_equal(&x, &y), "inside ball must be the bitwise identity");
}

// ----------------------------------------------------------------- ℓ∞,1

#[test]
fn linf1_feasible_idempotent_and_matches_oracle_f64() {
    for (i, &(n, m)) in SHAPES.iter().enumerate() {
        let y = randmat(n, m, 5000 + i as u64);
        let total = linf1_norm(&y);
        for &frac in &ETA_FRACS {
            let eta = total * frac;
            let x = project_linf1(&y, eta);
            let what = format!("{n}x{m} frac {frac}");
            assert!(linf1_norm(&x) <= eta * (1.0 + 1e-9) + 1e-12, "{what}: infeasible");
            assert!(x.max_abs_diff(&linf1_oracle(&y, eta)) < 1e-9, "{what}: oracle mismatch");
            let xx = project_linf1(&x, eta);
            assert!(x.max_abs_diff(&xx) < 1e-9, "{what}: not idempotent");
        }
    }
}

#[test]
fn linf1_feasible_and_matches_oracle_f32() {
    for (i, &(n, m)) in SHAPES.iter().enumerate() {
        let y64 = randmat(n, m, 6000 + i as u64);
        let y: Matrix<f32> = y64.cast();
        let total = linf1_norm(&y);
        for &frac in &[0.1f32, 0.5] {
            let eta = total * frac;
            let x = project_linf1(&y, eta);
            let what = format!("f32 {n}x{m} frac {frac}");
            assert!(linf1_norm(&x) <= eta * (1.0 + 1e-3), "{what}: infeasible");
            let oracle: Matrix<f32> = linf1_oracle(&y64, (total * frac) as f64).cast();
            assert!(x.max_abs_diff(&oracle) < 5e-3, "{what}: oracle mismatch");
        }
    }
}

#[test]
fn linf1_handles_duplicate_columns_and_edge_radii() {
    let mut y = randmat(10, 8, 7000);
    for j in (1..8).step_by(2) {
        let src = y.col(j - 1).to_vec();
        y.col_mut(j).copy_from_slice(&src);
    }
    let eta = linf1_norm(&y) * 0.3;
    let x = project_linf1(&y, eta);
    assert!(x.max_abs_diff(&linf1_oracle(&y, eta)) < 1e-9, "dup cols: oracle mismatch");
    // Duplicate inputs stay duplicates (per-column operator).
    for j in (1..8).step_by(2) {
        for i in 0..10 {
            assert_eq!(x.get(i, j).to_bits(), x.get(i, j - 1).to_bits());
        }
    }
    // η = 0 ⇒ zero matrix; η ≥ ‖Y‖∞,1 ⇒ bitwise no-op.
    let x0 = project_linf1(&y, 0.0);
    assert!(x0.as_slice().iter().all(|&v| v == 0.0));
    let xi = project_linf1(&y, linf1_norm(&y) * 1.5);
    assert!(bits_equal(&xi, &y));
}

// ----------------------------------------------------------- multilevel

#[test]
fn multilevel_depth2_is_bitwise_bilevel_l1inf_property() {
    let spec = MultilevelSpec::parse("l1/linf").unwrap();
    let seq = ParallelPolicy { threads: 1, min_elems: usize::MAX };
    let pool = ParallelPolicy { threads: 7, min_elems: 0 };
    let cfg = PropConfig { cases: 120, seed: 0x5EED_FA31, max_shrink_steps: 32 };
    forall::<MatrixAndRadius>(cfg, |case| {
        let bl = bilevel_l1inf_with(&case.y, case.eta, L1Algorithm::Condat);
        for (label, policy) in [("seq", seq), ("pool", pool)] {
            let ml =
                project_multilevel_with(&case.y, case.eta, &spec, L1Algorithm::Condat, policy);
            if !bits_equal(&ml, &bl.x) {
                return Err(format!(
                    "depth-2 l1/linf ({label}) diverges bitwise from bilevel_l1inf \
                     (max abs diff {:e})",
                    ml.max_abs_diff(&bl.x)
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn multilevel_deep_trees_feasible_in_leaf_flat_norms() {
    // Sanity beyond the in-module tests: a depth-3 tree with ℓ∞ leaves is
    // feasible in the flat ℓ1,∞ norm too (the tree ball is contained in
    // the flat ball at the same radius by the monotone aggregation).
    let y = randmat(24, 30, 8000);
    let spec = MultilevelSpec::parse("l1/l2:6/linf").unwrap();
    let eta = l1inf_norm(&y) * 0.2;
    let x = project_multilevel_with(
        &y,
        eta,
        &spec,
        L1Algorithm::Condat,
        ParallelPolicy::default(),
    );
    assert!(l1inf_norm(&x) <= l1inf_norm(&y) * (1.0 + 1e-12), "tree must not grow the norm");
    assert_eq!(x.rows(), 24);
    assert_eq!(x.cols(), 30);
}

// ---------------------------------------------------------- serve tier

fn small_serve_cfg() -> ServeConfig {
    ServeConfig { shards: 1, workers_per_shard: 1, cache_capacity: 32, ..ServeConfig::default() }
}

#[test]
fn new_kinds_submit_through_the_engine_and_bypass_the_cache() {
    let engine = Engine::start(&small_serve_cfg()).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(31);
    for kind in [ProjectionKind::L21, ProjectionKind::Linf1Newton] {
        let y = Matrix::<f64>::randn(18, 12, &mut rng);
        let eta = kind.matched_norm(&y).unwrap() * 0.3;
        let direct = kind.apply(&y, eta);
        // Same request twice: a cacheable kind would replay the second
        // time; these kinds must bypass cleanly — no thresholds, never a
        // cache hit, bit-identical both times.
        for round in 0..2 {
            let resp = engine
                .submit_wait(ProjectionRequest::f64(kind, eta, y.clone()))
                .unwrap_or_else(|e| panic!("{}: submit failed: {e:?}", kind.name()));
            let x = resp.payload.as_f64().unwrap();
            assert!(bits_equal(x, &direct), "{} round {round}: diverges", kind.name());
            assert!(
                resp.thresholds.is_none(),
                "{} has no bi-level thresholds to report",
                kind.name()
            );
            assert!(!resp.cache_hit, "{} round {round}: must bypass the cache", kind.name());
        }
    }
    let stats = engine.shutdown();
    assert_eq!(stats.completed(), 4);
    assert_eq!(stats.cache_hits(), 0, "non-cacheable kinds must never hit");
}

/// One keep-alive client connection (same idiom as `net_integration.rs`).
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    fn open(addr: SocketAddr) -> Conn {
        let s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let _ = s.set_nodelay(true);
        Conn { reader: BufReader::new(s.try_clone().unwrap()), writer: s }
    }

    fn send(&mut self, path: &str, body: &[u8]) -> Result<Response, HttpError> {
        write_request(&mut self.writer, "POST", path, &[], body)?;
        read_response(&mut self.reader, &HttpLimits::default())
    }
}

#[test]
fn new_kinds_round_trip_post_v1_project_bit_identical() {
    let engine = Arc::new(Engine::start(&small_serve_cfg()).unwrap());
    let http = HttpConfig { listen: "127.0.0.1:0".into(), ..HttpConfig::default() };
    let server = Server::start(Arc::clone(&engine), &http).unwrap();
    let mut conn = Conn::open(server.addr());
    let mut rng = Xoshiro256pp::seed_from_u64(32);
    for kind in [ProjectionKind::L21, ProjectionKind::Linf1Newton] {
        let y = Matrix::<f64>::randn(20, 14, &mut rng);
        let eta = kind.matched_norm(&y).unwrap() * 0.4;
        let body = wire::project_request_body(&ProjectionRequest::f64(kind, eta, y.clone()));
        let resp = conn.send("/v1/project", body.as_bytes()).unwrap();
        let text = std::str::from_utf8(&resp.body).expect("UTF-8 body");
        assert_eq!(resp.status, 200, "{}: {text}", kind.name());
        let over_wire = wire::decode_response(text).unwrap();
        let direct = kind.apply(&y, eta);
        assert!(
            bits_equal(over_wire.payload.as_f64().unwrap(), &direct),
            "{}: socket result must be bit-identical to the library",
            kind.name()
        );
    }
    drop(conn);
    server.join();
    Arc::try_unwrap(engine).ok().unwrap().shutdown();
}
