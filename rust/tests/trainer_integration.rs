//! End-to-end trainer integration on the tiny preset: full double-descent
//! runs through PJRT, projection backends cross-checked.

use bilevel_sparse::config::{DatasetKind, ProjectionBackend, TrainConfig};
use bilevel_sparse::coordinator::{run_seeds, SaeTrainer};
use bilevel_sparse::projection::ProjectionKind;
use bilevel_sparse::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP trainer tests ({e:#}) — run `make artifacts`");
            None
        }
    }
}

fn tiny_cfg() -> TrainConfig {
    TrainConfig {
        dataset: DatasetKind::Tiny,
        projection: ProjectionKind::BilevelL1Inf,
        backend: ProjectionBackend::Native,
        eta: 2.0,
        epochs_phase1: 6,
        epochs_phase2: 4,
        lr: 5e-3,
        alpha: 0.5,
        test_fraction: 0.25,
        ..TrainConfig::default()
    }
}

#[test]
fn double_descent_learns_tiny_dataset() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig { epochs_phase1: 6, epochs_phase2: 12, ..tiny_cfg() };
    let trainer = SaeTrainer::new(&rt, cfg).unwrap();
    let out = trainer.run(1).unwrap();
    assert!(
        out.best_accuracy > 0.75,
        "accuracy {} too low; history: {:?}",
        out.best_accuracy,
        out.history.iter().map(|h| h.test_accuracy).collect::<Vec<_>>()
    );
    assert_eq!(out.history.len(), 18); // 6 + 12 epochs
    assert!(out.sparsity_percent > 0.0, "projection should remove features");
    assert!(!out.selected_features.is_empty());
    assert!(out.history.iter().all(|h| h.train_loss.is_finite()));
    // phase 2 only trains surviving features
    assert_eq!(
        out.selected_features.len(),
        out.history.last().unwrap().alive_features
    );
    // structured-sparse artifacts: plan speaks the mask, the compacted
    // model drops exactly the pruned features and encodes bit-identically
    // to the dense final weights.
    assert_eq!(out.plan.alive_indices(), &out.selected_features[..]);
    assert_eq!(out.compact.dims.features, out.plan.alive());
    assert_eq!(out.compact.dims.hidden, out.dims.hidden);
    let enc = bilevel_sparse::sparse::CompactEncoder::<f32>::from_params(
        &bilevel_sparse::sparse::decompact_params(&out.compact, &out.plan),
        &out.plan,
    );
    let mut rng = bilevel_sparse::rng::Xoshiro256pp::seed_from_u64(99);
    let x = bilevel_sparse::tensor::Matrix::<f32>::randn(out.dims.features, 3, &mut rng);
    let sparse = enc.encode(&x);
    let mut dense = bilevel_sparse::tensor::Matrix::zeros(0, 0);
    bilevel_sparse::sparse::linalg::encode_batch_dense_into(
        &x,
        &out.w1,
        &out.compact.tensors[1],
        out.dims.hidden,
        &mut dense,
    );
    for (a, b) in sparse.as_slice().iter().zip(dense.as_slice().iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "trained sparse encode != dense encode");
    }
}

#[test]
fn baseline_without_projection_keeps_all_features() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig {
        projection: ProjectionKind::None,
        epochs_phase1: 4,
        epochs_phase2: 2,
        ..tiny_cfg()
    };
    let trainer = SaeTrainer::new(&rt, cfg).unwrap();
    let out = trainer.run(2).unwrap();
    assert_eq!(out.sparsity_percent, 0.0);
    assert_eq!(out.selected_features.len(), out.dims.features);
    assert_eq!(out.history.len(), 6); // merged into one phase
    assert!(out.history.iter().all(|h| h.phase == 1));
}

#[test]
fn pallas_and_native_backends_agree() {
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg();
    cfg.epochs_phase1 = 3;
    cfg.epochs_phase2 = 2;

    cfg.backend = ProjectionBackend::Native;
    let native = SaeTrainer::new(&rt, cfg.clone()).unwrap().run(3).unwrap();
    cfg.backend = ProjectionBackend::Pallas;
    let pallas = SaeTrainer::new(&rt, cfg).unwrap().run(3).unwrap();

    // Identical data, init and schedule; the two projection paths compute
    // the same operator, so the runs must match almost exactly.
    assert_eq!(native.selected_features, pallas.selected_features);
    assert!(
        (native.final_accuracy - pallas.final_accuracy).abs() < 1e-6,
        "native {} vs pallas {}",
        native.final_accuracy,
        pallas.final_accuracy
    );
}

#[test]
fn epoch_artifact_matches_stepwise_training() {
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg();
    cfg.epochs_phase1 = 2;
    cfg.epochs_phase2 = 1;

    cfg.use_epoch_artifact = true;
    let scan = SaeTrainer::new(&rt, cfg.clone()).unwrap().run(5).unwrap();
    cfg.use_epoch_artifact = false;
    let steps = SaeTrainer::new(&rt, cfg).unwrap().run(5).unwrap();

    // The scan path recycles samples to fill NB*B; the step path drops the
    // tail batch — they see slightly different data, so require agreement
    // in outcome quality, not bitwise equality.
    assert!((scan.final_accuracy - steps.final_accuracy).abs() < 0.35);
    assert!(scan.history.iter().all(|h| h.train_loss.is_finite()));
    assert!(steps.history.iter().all(|h| h.train_loss.is_finite()));
}

#[test]
fn exact_projection_trains_too() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig {
        projection: ProjectionKind::ExactL1InfSsn,
        epochs_phase1: 4,
        epochs_phase2: 2,
        ..tiny_cfg()
    };
    let out = SaeTrainer::new(&rt, cfg).unwrap().run(6).unwrap();
    assert!(out.final_accuracy > 0.5);
    assert!(out.history.iter().all(|h| h.train_loss.is_finite()));
}

#[test]
fn multi_seed_aggregation() {
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg();
    cfg.epochs_phase1 = 3;
    cfg.epochs_phase2 = 2;
    let summary = run_seeds(&rt, &cfg, &[11, 12, 13]).unwrap();
    assert_eq!(summary.outcomes.len(), 3);
    assert!(summary.mean_accuracy > 50.0, "mean acc {}", summary.mean_accuracy);
    assert!(summary.std_accuracy >= 0.0);
    // different seeds -> different splits -> (almost surely) some variance
    let accs: Vec<f64> = summary.outcomes.iter().map(|o| o.final_accuracy).collect();
    assert!(accs.iter().any(|&a| (a - accs[0]).abs() > 0.0) || summary.std_accuracy == 0.0);
}

#[test]
fn dataset_shapes_validated() {
    let Some(rt) = runtime() else { return };
    // synth preset expects 1000 features; tiny dataset has 64 — the
    // trainer must reject the mismatch cleanly.
    let cfg = TrainConfig {
        dataset: DatasetKind::Tiny,
        ..tiny_cfg()
    };
    let trainer = SaeTrainer::new(&rt, cfg).unwrap();
    assert_eq!(trainer.dims().features, 64);
}
