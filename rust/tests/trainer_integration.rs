//! End-to-end trainer integration on the tiny preset: full double-descent
//! runs through PJRT, projection backends cross-checked.

use bilevel_sparse::config::{DatasetKind, ProjectionBackend, TrainConfig};
use bilevel_sparse::coordinator::{run_seeds, RunOptions, SaeTrainer};
use bilevel_sparse::persist::Checkpoint;
use bilevel_sparse::projection::ProjectionKind;
use bilevel_sparse::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP trainer tests ({e:#}) — run `make artifacts`");
            None
        }
    }
}

fn tiny_cfg() -> TrainConfig {
    TrainConfig {
        dataset: DatasetKind::Tiny,
        projection: ProjectionKind::BilevelL1Inf,
        backend: ProjectionBackend::Native,
        eta: 2.0,
        epochs_phase1: 6,
        epochs_phase2: 4,
        lr: 5e-3,
        alpha: 0.5,
        test_fraction: 0.25,
        ..TrainConfig::default()
    }
}

#[test]
fn double_descent_learns_tiny_dataset() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig { epochs_phase1: 6, epochs_phase2: 12, ..tiny_cfg() };
    let trainer = SaeTrainer::new(&rt, cfg).unwrap();
    let out = trainer.run(1).unwrap();
    assert!(
        out.best_accuracy > 0.75,
        "accuracy {} too low; history: {:?}",
        out.best_accuracy,
        out.history.iter().map(|h| h.test_accuracy).collect::<Vec<_>>()
    );
    assert_eq!(out.history.len(), 18); // 6 + 12 epochs
    assert!(out.sparsity_percent > 0.0, "projection should remove features");
    assert!(!out.selected_features.is_empty());
    assert!(out.history.iter().all(|h| h.train_loss.is_finite()));
    // phase 2 only trains surviving features
    assert_eq!(
        out.selected_features.len(),
        out.history.last().unwrap().alive_features
    );
    // structured-sparse artifacts: plan speaks the mask, the compacted
    // model drops exactly the pruned features and encodes bit-identically
    // to the dense final weights.
    assert_eq!(out.plan.alive_indices(), &out.selected_features[..]);
    assert_eq!(out.compact.dims.features, out.plan.alive());
    assert_eq!(out.compact.dims.hidden, out.dims.hidden);
    let enc = bilevel_sparse::sparse::CompactEncoder::<f32>::from_params(
        &bilevel_sparse::sparse::decompact_params(&out.compact, &out.plan),
        &out.plan,
    );
    let mut rng = bilevel_sparse::rng::Xoshiro256pp::seed_from_u64(99);
    let x = bilevel_sparse::tensor::Matrix::<f32>::randn(out.dims.features, 3, &mut rng);
    let sparse = enc.encode(&x);
    let mut dense = bilevel_sparse::tensor::Matrix::zeros(0, 0);
    bilevel_sparse::sparse::linalg::encode_batch_dense_into(
        &x,
        &out.w1,
        &out.compact.tensors[1],
        out.dims.hidden,
        &mut dense,
    );
    for (a, b) in sparse.as_slice().iter().zip(dense.as_slice().iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "trained sparse encode != dense encode");
    }
}

#[test]
fn baseline_without_projection_keeps_all_features() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig {
        projection: ProjectionKind::None,
        epochs_phase1: 4,
        epochs_phase2: 2,
        ..tiny_cfg()
    };
    let trainer = SaeTrainer::new(&rt, cfg).unwrap();
    let out = trainer.run(2).unwrap();
    assert_eq!(out.sparsity_percent, 0.0);
    assert_eq!(out.selected_features.len(), out.dims.features);
    assert_eq!(out.history.len(), 6); // merged into one phase
    assert!(out.history.iter().all(|h| h.phase == 1));
}

#[test]
fn pallas_and_native_backends_agree() {
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg();
    cfg.epochs_phase1 = 3;
    cfg.epochs_phase2 = 2;

    cfg.backend = ProjectionBackend::Native;
    let native = SaeTrainer::new(&rt, cfg.clone()).unwrap().run(3).unwrap();
    cfg.backend = ProjectionBackend::Pallas;
    let pallas = SaeTrainer::new(&rt, cfg).unwrap().run(3).unwrap();

    // Identical data, init and schedule; the two projection paths compute
    // the same operator, so the runs must match almost exactly.
    assert_eq!(native.selected_features, pallas.selected_features);
    assert!(
        (native.final_accuracy - pallas.final_accuracy).abs() < 1e-6,
        "native {} vs pallas {}",
        native.final_accuracy,
        pallas.final_accuracy
    );
}

#[test]
fn epoch_artifact_matches_stepwise_training() {
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg();
    cfg.epochs_phase1 = 2;
    cfg.epochs_phase2 = 1;

    cfg.use_epoch_artifact = true;
    let scan = SaeTrainer::new(&rt, cfg.clone()).unwrap().run(5).unwrap();
    cfg.use_epoch_artifact = false;
    let steps = SaeTrainer::new(&rt, cfg).unwrap().run(5).unwrap();

    // Both paths now cover every sample per epoch (the step path pads its
    // tail batch with recycled samples), but the scan path's fixed NB*B
    // grid still repeats data differently — so require agreement in
    // outcome quality, not bitwise equality.
    assert!((scan.final_accuracy - steps.final_accuracy).abs() < 0.35);
    assert!(scan.history.iter().all(|h| h.train_loss.is_finite()));
    assert!(steps.history.iter().all(|h| h.train_loss.is_finite()));
}

#[test]
fn exact_projection_trains_too() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig {
        projection: ProjectionKind::ExactL1InfSsn,
        epochs_phase1: 4,
        epochs_phase2: 2,
        ..tiny_cfg()
    };
    let out = SaeTrainer::new(&rt, cfg).unwrap().run(6).unwrap();
    assert!(out.final_accuracy > 0.5);
    assert!(out.history.iter().all(|h| h.train_loss.is_finite()));
}

#[test]
fn multi_seed_aggregation() {
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg();
    cfg.epochs_phase1 = 3;
    cfg.epochs_phase2 = 2;
    let summary = run_seeds(&rt, &cfg, &[11, 12, 13]).unwrap();
    assert_eq!(summary.outcomes.len(), 3);
    assert!(summary.mean_accuracy > 50.0, "mean acc {}", summary.mean_accuracy);
    assert!(summary.std_accuracy >= 0.0);
    // different seeds -> different splits -> (almost surely) some variance
    let accs: Vec<f64> = summary.outcomes.iter().map(|o| o.final_accuracy).collect();
    assert!(accs.iter().any(|&a| (a - accs[0]).abs() > 0.0) || summary.std_accuracy == 0.0);
}

#[test]
fn checkpoint_resume_reproduces_uninterrupted_run() {
    let Some(rt) = runtime() else { return };
    let cfg = tiny_cfg(); // 6 + 4 epochs
    let trainer = SaeTrainer::new(&rt, cfg.clone()).unwrap();
    let base = trainer.run(3).unwrap();

    let dir = std::env::temp_dir()
        .join(format!("bilevel-resume-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("roll.ckpt");

    // Checkpointing must not perturb the trajectory.
    let opts = RunOptions {
        checkpoint_every: 4,
        checkpoint_path: Some(path.clone()),
        ..RunOptions::default()
    };
    let full = trainer.run_with(3, &opts).unwrap();
    assert_eq!(full.history, base.history, "checkpoint IO changed the run");
    let bits = |w: &[f32]| w.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&full.w1), bits(&base.w1));

    // The rolling file holds the last cadence snapshot: epoch 8 of 10 =
    // phase 2, 2 epochs done.
    let ck = Checkpoint::load(&path).unwrap();
    let ts = ck.train_state.as_ref().expect("rolling checkpoint carries train state");
    assert_eq!((ts.phase, ts.epochs_done), (2, 2));
    assert_eq!(ck.history.len(), 8);
    assert_eq!(ck.seed, 3);

    // Resume the interrupted run: the final trajectory must be
    // bit-identical to the uninterrupted one.
    let resumed = trainer
        .run_with(3, &RunOptions { resume_from: Some(ck), ..RunOptions::default() })
        .unwrap();
    assert_eq!(resumed.history, base.history, "resumed trajectory diverged");
    assert_eq!(
        resumed.final_accuracy.to_bits(),
        base.final_accuracy.to_bits(),
        "resumed final accuracy diverged"
    );
    assert_eq!(bits(&resumed.w1), bits(&base.w1), "resumed weights diverged");
    assert_eq!(resumed.plan.alive_indices(), base.plan.alive_indices());
    assert_eq!(resumed.selected_features, base.selected_features);

    // Guard rails: a wrong seed or a drifted config is refused.
    let ck2 = Checkpoint::load(&path).unwrap();
    assert!(trainer.run_with(4, &RunOptions { resume_from: Some(ck2), ..RunOptions::default() })
        .is_err());
    let drifted = TrainConfig { eta: cfg.eta * 2.0, ..cfg.clone() };
    let other = SaeTrainer::new(&rt, drifted).unwrap();
    let ck3 = Checkpoint::load(&path).unwrap();
    assert!(other.run_with(3, &RunOptions { resume_from: Some(ck3), ..RunOptions::default() })
        .is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn exported_checkpoint_serves_the_trained_model() {
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg();
    cfg.epochs_phase1 = 3;
    cfg.epochs_phase2 = 2;
    let trainer = SaeTrainer::new(&rt, cfg.clone()).unwrap();
    let out = trainer.run(7).unwrap();

    let dir = std::env::temp_dir()
        .join(format!("bilevel-export-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.ckpt");
    out.to_checkpoint(cfg.digest(), true).save(&path).unwrap();

    // train → export → import → serve: byte-for-byte the in-memory model.
    let engine = bilevel_sparse::serve::Engine::start(
        &bilevel_sparse::config::ServeConfig {
            shards: 1,
            workers_per_shard: 1,
            queue_capacity: 16,
            max_batch: 2,
            min_fill: 1,
            max_wait_micros: 50,
            cache_capacity: 0,
            ..bilevel_sparse::config::ServeConfig::default()
        },
    )
    .unwrap();
    let id = engine.load_model(&path, bilevel_sparse::serve::Dtype::F32).unwrap();
    let mut rng = bilevel_sparse::rng::Xoshiro256pp::seed_from_u64(8);
    let x = bilevel_sparse::tensor::Matrix::<f32>::randn(out.dims.features, 5, &mut rng);
    let resp = engine
        .submit_encode_wait(id, bilevel_sparse::serve::Payload::F32(x.clone()))
        .unwrap();
    let bilevel_sparse::serve::Payload::F32(h) = &resp.payload else { panic!("dtype") };
    let mem = bilevel_sparse::sparse::CompactEncoder::<f32>::from_params(&out.params, &out.plan);
    for (a, b) in h.as_slice().iter().zip(mem.encode(&x).as_slice().iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "served encode != trained in-memory encode");
    }
    engine.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn dataset_shapes_validated() {
    let Some(rt) = runtime() else { return };
    // synth preset expects 1000 features; tiny dataset has 64 — the
    // trainer must reject the mismatch cleanly.
    let cfg = TrainConfig {
        dataset: DatasetKind::Tiny,
        ..tiny_cfg()
    };
    let trainer = SaeTrainer::new(&rt, cfg).unwrap();
    assert_eq!(trainer.dims().features, 64);
}
