//! Experiment harness integration: every runner executes in `--quick` mode
//! and produces well-formed CSV output. (The SAE experiments need
//! `make artifacts` and are skipped gracefully without them.)

use std::sync::{Mutex, OnceLock};

use bilevel_sparse::experiments::{run, ExpContext};
use bilevel_sparse::report::read_csv;

/// results/ must be isolated per test binary AND the env var is process
/// global — serialise the experiment tests.
fn lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner()) // a failed test poisons; carry on
}

fn ctx() -> ExpContext {
    let dir = std::env::temp_dir().join("bilevel_exp_test_results");
    std::env::set_var("BILEVEL_RESULTS_DIR", &dir);
    ExpContext::new(true, vec![42, 43], "artifacts".into())
}

fn results_file(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join("bilevel_exp_test_results").join(name)
}

fn has_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

#[test]
fn fig1_produces_timing_csv_with_linear_bilevel() {
    let _g = lock();
    let c = ctx();
    run("fig1", &c).unwrap();
    let (header, rows) = read_csv(&results_file("fig1_time.csv")).unwrap();
    assert_eq!(header, vec!["axis", "size", "bilevel_s", "ssn_s", "ratio"]);
    assert!(rows.len() >= 8, "expected >= 8 sweep points, got {}", rows.len());
    // every timing positive
    for r in &rows {
        assert!(r[2].parse::<f64>().unwrap() > 0.0);
        assert!(r[3].parse::<f64>().unwrap() > 0.0);
    }
}

#[test]
fn fig2_csv_has_three_variants() {
    let _g = lock();
    let c = ctx();
    run("fig2", &c).unwrap();
    let (header, rows) = read_csv(&results_file("fig2_bilevel.csv")).unwrap();
    assert_eq!(header.len(), 5);
    assert!(!rows.is_empty());
}

#[test]
fn fig3_identity_gap_is_numerically_zero() {
    let _g = lock();
    let c = ctx();
    run("fig3", &c).unwrap();
    let (header, rows) = read_csv(&results_file("fig3_identity.csv")).unwrap();
    let gap_col = header.iter().position(|h| h == "gap").unwrap();
    for r in &rows {
        let gap: f64 = r[gap_col].parse().unwrap();
        assert!(gap < 1e-6, "identity gap {gap} too large");
    }
    // both methods present
    assert!(rows.iter().any(|r| r[1] == "bilevel"));
    assert!(rows.iter().any(|r| r[1] == "exact"));
}

#[test]
fn fig4_l22_sum_exceeds_total() {
    let _g = lock();
    let c = ctx();
    run("fig4", &c).unwrap();
    let (header, rows) = read_csv(&results_file("fig4_l22.csv")).unwrap();
    let sum_col = header.iter().position(|h| h == "sum_l22").unwrap();
    let tot_col = header.iter().position(|h| h == "total_l22").unwrap();
    for r in &rows {
        let sum: f64 = r[sum_col].parse().unwrap();
        let tot: f64 = r[tot_col].parse().unwrap();
        assert!(sum >= tot - 1e-9, "l2,2 identity should NOT hold: {sum} < {tot}");
    }
}

#[test]
fn table1_ordering_matches_paper() {
    let _g = lock();
    let c = ctx();
    run("table1", &c).unwrap();
    let (_, rows) = read_csv(&results_file("table1_cum_sparsity.csv")).unwrap();
    let get = |ds: &str, m: &str| -> f64 {
        rows.iter()
            .find(|r| r[0] == ds && r[1] == m)
            .unwrap_or_else(|| panic!("missing {ds}/{m}"))[2]
            .parse()
            .unwrap()
    };
    for ds in ["data-64", "data-16"] {
        // The paper's headline ordering: bilevel l1inf sparser than exact.
        assert!(
            get(ds, "bilevel-l1inf") > get(ds, "l1inf"),
            "{ds}: bilevel should out-sparsify the exact projection"
        );
    }
}

#[test]
fn fig5_fig6_curves_cover_all_methods() {
    let _g = lock();
    let c = ctx();
    run("fig5", &c).unwrap();
    run("fig6", &c).unwrap();
    for f in ["fig5_sparsity_data64.csv", "fig6_sparsity_data16.csv"] {
        let (_, rows) = read_csv(&results_file(f)).unwrap();
        for m in ["bilevel-l1inf", "bilevel-l11", "bilevel-l12", "l1inf"] {
            assert!(rows.iter().any(|r| r[0] == m), "{f}: missing {m}");
        }
    }
}

#[test]
fn fig9_runs_with_artifacts() {
    let _g = lock();
    if !has_artifacts() {
        eprintln!("SKIP fig9 (no artifacts)");
        return;
    }
    let c = ctx();
    run("fig9", &c).unwrap();
    let (_, rows) = read_csv(&results_file("fig9_w1_feature_norms.csv")).unwrap();
    assert!(!rows.is_empty());
    // at least one suppressed feature in quick mode
    assert!(rows.iter().any(|r| r[1].parse::<f64>().unwrap() == 0.0));
}

#[test]
fn sparse_experiment_reports_bitwise_dense_compact_agreement() {
    let _g = lock();
    let c = ctx();
    run("sparse", &c).unwrap();
    let (header, rows) = read_csv(&results_file("sparse_infer.csv")).unwrap();
    let bit_col = header.iter().position(|h| h == "bit_identical").unwrap();
    let sp_col = header.iter().position(|h| h == "sparsity_pct").unwrap();
    assert!(!rows.is_empty());
    for r in &rows {
        assert_eq!(r[bit_col], "true", "sparse encode diverged at sparsity {}", r[sp_col]);
    }
    // both dtypes and the extreme levels are present
    for dtype in ["f32", "f64"] {
        assert!(rows.iter().any(|r| r[0] == dtype), "missing {dtype} rows");
    }
    for level in ["0", "99"] {
        assert!(rows.iter().any(|r| r[sp_col] == level), "missing {level}% level");
    }
}

#[test]
fn family_experiment_covers_every_kind_and_renders_the_baseline_as_na() {
    let _g = lock();
    let c = ctx();
    run("family", &c).unwrap();
    let (header, rows) = read_csv(&results_file("family_projection.csv")).unwrap();
    let feas_col = header.iter().position(|h| h == "feasible").unwrap();
    let eta_col = header.iter().position(|h| h == "eta").unwrap();
    for r in &rows {
        assert_eq!(r[feas_col], "true", "kind {} infeasible", r[0]);
    }
    // Every flat kind appears, plus the tree row.
    for kind in bilevel_sparse::projection::ProjectionKind::all() {
        assert!(rows.iter().any(|r| r[0] == kind.name()), "missing {}", kind.name());
    }
    assert!(rows.iter().any(|r| r[0].starts_with("multilevel(")), "missing multilevel row");
    // The identity baseline has no matched norm: its row must render as
    // n/a (the matched_norm == None report-path regression check), not
    // crash the runner.
    let baseline = rows.iter().find(|r| r[0] == "none").expect("baseline row present");
    assert_eq!(baseline[eta_col], "n/a");
}

#[test]
fn unknown_id_is_error() {
    let _g = lock();
    assert!(run("fig99", &ctx()).is_err());
}
