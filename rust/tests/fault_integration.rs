//! End-to-end tests of the fault-injection harness and the recovery
//! machinery it exercises: seeded schedules replay byte for byte, an
//! injected worker panic surfaces as a typed error while the supervisor
//! respawns the worker and restores shard capacity, and a chaos loadgen
//! run under injected connection resets and worker panics (plus an
//! encoder hot-swap mid-flight) loses zero accepted requests.
//!
//! The failpoint registry is process-global, so every test serializes on
//! `gate()` before installing a plan and clears it before releasing.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use bilevel_sparse::config::{HttpConfig, ServeConfig};
use bilevel_sparse::fault::{self, FaultPlan, FaultSite};
use bilevel_sparse::model::{SaeDims, SaeParams};
use bilevel_sparse::net::Server;
use bilevel_sparse::projection::ProjectionKind;
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::serve::{
    run_loadgen_net, Engine, JobError, LoadgenConfig, Payload, ProjectionRequest, SubmitError,
};
use bilevel_sparse::sparse::{CompactEncoder, CompactPlan};
use bilevel_sparse::tensor::Matrix;

fn gate() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    GATE.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn bits_equal(a: &Matrix<f64>, b: &Matrix<f64>) -> bool {
    a.rows() == b.rows()
        && a.cols() == b.cols()
        && a.as_slice().iter().zip(b.as_slice()).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// A 10-feature / 4-hidden encoder with a seed-dependent pruned support
/// (mirrors the net integration tests).
fn test_encoder(seed: u64) -> CompactEncoder<f64> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut p = SaeParams::init(SaeDims { features: 10, hidden: 4, classes: 2 }, &mut rng);
    let mut mask = vec![1.0f32; 10];
    for f in [1usize, 3, 8] {
        mask[f] = 0.0;
    }
    p.apply_feature_mask(&mask);
    let plan = CompactPlan::from_mask(&mask);
    CompactEncoder::<f64>::from_params(&p, &plan)
}

#[test]
fn seeded_fault_schedule_replays_exactly() {
    let _g = gate();
    fault::clear();
    let plan = FaultPlan::parse_sites(
        99,
        "conn.slow_read:p=0.3,param=64;worker.stall:every=3,limit=4,param=1",
    )
    .unwrap();
    let run = || {
        let inj = fault::install(plan.clone());
        let mut trace = Vec::with_capacity(128);
        for _ in 0..64 {
            trace.push(fault::fire(FaultSite::ConnSlowRead));
            trace.push(fault::fire(FaultSite::WorkerStall));
        }
        let counts = (
            inj.hits(FaultSite::ConnSlowRead),
            inj.fired(FaultSite::ConnSlowRead),
            inj.fired(FaultSite::WorkerStall),
        );
        fault::clear();
        (trace, counts)
    };
    let (t1, c1) = run();
    let (t2, c2) = run();
    assert_eq!(t1, t2, "same seed, same plan must replay byte for byte");
    assert_eq!(c1, c2);
    assert_eq!(c1.0, 64, "every call is a hit");
    assert!(c1.1 > 0, "p=0.3 over 64 draws must fire");
    assert!(c1.1 < 64, "p=0.3 must not fire on every draw");
    assert_eq!(c1.2, 4, "limit=4 caps worker.stall fires");

    // a different seed yields a different schedule for the same site
    let other = FaultPlan::parse_sites(100, "conn.slow_read:p=0.3,param=64").unwrap();
    fault::install(other);
    let t3: Vec<Option<u64>> = (0..64).map(|_| fault::fire(FaultSite::ConnSlowRead)).collect();
    fault::clear();
    let t1_slow: Vec<Option<u64>> = t1.iter().step_by(2).cloned().collect();
    assert_ne!(t1_slow, t3, "a different seed must reschedule");

    // with the registry cleared the sites are inert again
    assert!(!fault::active());
    assert_eq!(fault::fire(FaultSite::ConnSlowRead), None);
}

#[test]
fn injected_worker_panic_is_typed_and_respawn_restores_capacity() {
    let _g = gate();
    fault::clear();
    let inj = fault::install(FaultPlan::parse_sites(11, "worker.panic:every=1,limit=1").unwrap());
    let engine = Engine::start(&ServeConfig {
        shards: 1,
        workers_per_shard: 1,
        ..ServeConfig::default()
    })
    .unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(401);
    let y = Matrix::<f64>::randn(16, 8, &mut rng);

    // the first executed job hits the armed panic site: its waiter gets a
    // typed error instead of a hang or a dropped channel
    let err = engine
        .submit_wait(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, y.clone()))
        .unwrap_err();
    match err {
        SubmitError::Failed(JobError::WorkerPanic { shard }) => assert_eq!(shard, 0),
        other => panic!("expected a typed worker panic, got: {other}"),
    }
    assert_eq!(inj.fired(FaultSite::WorkerPanic), 1);

    // the supervisor respawned the sole worker in place: the shard keeps
    // serving, bit-identical to the library
    let direct = ProjectionKind::BilevelL1Inf.apply(&y, 1.0);
    for i in 0..8 {
        let resp = engine
            .submit_wait(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, y.clone()))
            .unwrap_or_else(|e| panic!("post-respawn request {i} failed: {e}"));
        assert!(
            bits_equal(resp.payload.as_f64().unwrap(), &direct),
            "post-respawn result must be bit-identical"
        );
    }

    let stats = engine.shutdown();
    assert_eq!(stats.worker_panics(), 1);
    assert_eq!(stats.worker_restarts(), 1);
    assert_eq!(stats.completed(), 8);
    fault::clear();
}

#[test]
fn chaos_load_with_hot_swap_and_drain_loses_no_accepted_requests() {
    let _g = gate();
    fault::clear();
    let plan = FaultPlan::parse_sites(
        7,
        "worker.panic:every=10,limit=2;conn.reset:every=2,param=400,limit=3",
    )
    .unwrap();
    let inj = fault::install(plan);

    let engine = Arc::new(
        Engine::start(&ServeConfig {
            shards: 2,
            workers_per_shard: 1,
            cache_capacity: 16,
            ..ServeConfig::default()
        })
        .unwrap(),
    );
    let enc_a = test_encoder(341);
    let enc_b = test_encoder(342);
    let id = engine.register_encoder_f64(enc_a);
    let server = Server::start(
        Arc::clone(&engine),
        &HttpConfig { listen: "127.0.0.1:0".into(), ..HttpConfig::default() },
    )
    .unwrap();
    let addr = server.addr().to_string();

    let cfg = LoadgenConfig {
        clients: 3,
        requests_per_client: 24,
        rows: 12,
        cols: 10,
        eta: 1.0,
        mix: vec![ProjectionKind::BilevelL1Inf, ProjectionKind::BilevelL11],
        pool: 2,
        f32_every: 3,
        seed: 9,
        backoff_cap_ms: 20,
        chaos: true,
        ..LoadgenConfig::default()
    };
    let total = (cfg.clients * cfg.requests_per_client) as u64;
    let lg = std::thread::spawn(move || run_loadgen_net(&addr, &cfg).unwrap());

    // hot-swap the live encoder while the chaos load is in flight
    std::thread::sleep(Duration::from_millis(50));
    engine.swap_encoder_f64(id, enc_b.clone()).unwrap();

    let report = lg.join().unwrap();
    assert_eq!(
        report.completed, total,
        "every accepted request must eventually complete ({} failed)",
        report.failed
    );
    assert_eq!(report.failed, 0);
    assert!(inj.fired(FaultSite::ConnReset) > 0, "the reset site must actually fire");
    assert!(
        report.redials > 0,
        "injected connection resets must surface as redials, not losses"
    );
    assert_eq!(inj.fired(FaultSite::WorkerPanic), 2, "both scheduled panics fire under load");

    // the swapped-in encoder serves after the chaos run, bit-identical
    let mut rng = Xoshiro256pp::seed_from_u64(343);
    let x = Matrix::<f64>::randn(10, 5, &mut rng);
    let resp = engine.submit_encode_wait(id, Payload::F64(x.clone())).unwrap();
    assert!(bits_equal(resp.payload.as_f64().unwrap(), &enc_b.encode(&x)));

    server.drain();
    server.wait_for_drain();
    server.join();
    let stats = Arc::try_unwrap(engine).ok().unwrap().shutdown();
    assert!(stats.worker_restarts() >= 2, "each injected panic must respawn its worker");
    assert!(
        stats.completed() >= total,
        "redialed requests re-execute; the engine completes at least the client total"
    );
    fault::clear();
}
