//! Model-lifecycle integration: property-based checkpoint round-trips
//! (export → import bit-identical across sparsity levels and dtypes),
//! file-level error paths, serve-side model loading (including dims
//! mismatch at admission), hot-swap under live traffic, and the recovery
//! chain's guarantees under exhaustive truncation and single-bit-flip
//! damage (quarantine + bit-exact fallback, never wrong bits).

use std::time::Duration;

use bilevel_sparse::config::ServeConfig;
use bilevel_sparse::model::{SaeDims, SaeParams};
use bilevel_sparse::persist::{
    read_header, recover_latest, Checkpoint, ModelBundle, PersistError,
};
use bilevel_sparse::proptest::{forall, PropConfig, SparseSaeCase};
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::serve::{Dtype, Engine, Payload, SubmitError};
use bilevel_sparse::sparse::{compact_params, CompactEncoder, CompactPlan};
use bilevel_sparse::tensor::Matrix;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("bilevel-persist-it-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn bits_eq_params(a: &SaeParams, b: &SaeParams) -> Result<(), String> {
    if a.dims != b.dims {
        return Err(format!("dims {:?} != {:?}", a.dims, b.dims));
    }
    for (i, (ta, tb)) in a.tensors.iter().zip(b.tensors.iter()).enumerate() {
        if ta.len() != tb.len() {
            return Err(format!("tensor {i} length {} != {}", ta.len(), tb.len()));
        }
        for (j, (x, y)) in ta.iter().zip(tb.iter()).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("tensor {i}[{j}]: {x:?} != {y:?} (bit pattern)"));
            }
        }
    }
    Ok(())
}

fn bits_eq_matrix<T: bilevel_sparse::scalar::Scalar>(
    a: &Matrix<T>,
    b: &Matrix<T>,
) -> Result<(), String> {
    if (a.rows(), a.cols()) != (b.rows(), b.cols()) {
        return Err("shape mismatch".into());
    }
    for (x, y) in a.as_slice().iter().zip(b.as_slice().iter()) {
        if x.to_f64().to_bits() != y.to_f64().to_bits() {
            return Err(format!("entry {x:?} != {y:?}"));
        }
    }
    Ok(())
}

fn checkpoint_of(case: &SparseSaeCase, seed: u64) -> (Checkpoint, CompactPlan) {
    let plan = CompactPlan::from_mask(&case.mask);
    let compact = compact_params(&case.params, &plan);
    let ck = Checkpoint {
        seed,
        config_digest: 0xD1CE57,
        dims: case.params.dims,
        history: Vec::new(),
        model: Some(ModelBundle {
            plan: plan.clone(),
            compact,
            dense: Some(case.params.clone()),
        }),
        train_state: None,
    };
    (ck, plan)
}

#[test]
fn export_import_is_bit_identical_for_params_plan_compact() {
    // Property over random pruned SAEs spanning 0–100 % sparsity: the
    // serialized checkpoint reproduces plan, compact, and dense tensors
    // bit-for-bit, and encoders built from the loaded bundle encode the
    // case's batch identically to in-memory encoders — in both dtypes.
    forall::<SparseSaeCase>(PropConfig { cases: 120, ..Default::default() }, |case| {
        let (ck, plan) = checkpoint_of(case, 5);
        let back = Checkpoint::from_bytes(&ck.to_bytes())
            .map_err(|e| format!("reload failed: {e}"))?;
        let mb0 = ck.model.as_ref().unwrap();
        let mb1 = back.model.as_ref().ok_or("model bundle lost")?;
        if mb1.plan != plan {
            return Err("plan changed across the round-trip".into());
        }
        bits_eq_params(&mb1.compact, &mb0.compact)?;
        bits_eq_params(mb1.dense.as_ref().ok_or("dense lost")?, &case.params)?;

        // dtype sweep: loaded encoder ≡ in-memory encoder, bitwise
        let mem64 = CompactEncoder::<f64>::from_params(&case.params, &plan);
        bits_eq_matrix(&mb1.encoder::<f64>().encode(&case.x), &mem64.encode(&case.x))?;
        let x32: Matrix<f32> = case.x.cast();
        let mem32 = CompactEncoder::<f32>::from_params(&case.params, &plan);
        bits_eq_matrix(&mb1.encoder::<f32>().encode(&x32), &mem32.encode(&x32))?;
        Ok(())
    });
}

#[test]
fn file_error_paths_are_typed() {
    let dir = tmp_dir("errors");
    let path = dir.join("m.ckpt");
    let mut rng = Xoshiro256pp::seed_from_u64(71);
    let p = SaeParams::init(SaeDims { features: 9, hidden: 3, classes: 2 }, &mut rng);
    let plan = CompactPlan::dense(9);
    let ck = Checkpoint {
        seed: 71,
        config_digest: 1,
        dims: p.dims,
        history: Vec::new(),
        model: Some(ModelBundle { plan, compact: compact_params(&p, &CompactPlan::dense(9)), dense: None }),
        train_state: None,
    };
    ck.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // truncated file
    let trunc = dir.join("trunc.ckpt");
    std::fs::write(&trunc, &bytes[..bytes.len() / 2]).unwrap();
    assert!(matches!(Checkpoint::load(&trunc), Err(PersistError::Truncated { .. })));

    // corrupted checksum (payload bit flip)
    let mut corrupt = bytes.clone();
    corrupt[100] ^= 0x40;
    let bad = dir.join("bad.ckpt");
    std::fs::write(&bad, &corrupt).unwrap();
    assert!(matches!(Checkpoint::load(&bad), Err(PersistError::ChecksumMismatch)));

    // wrong format version (header is read first, so inspect fails too)
    let mut vers = bytes.clone();
    vers[8] = 0xEE;
    let old = dir.join("old.ckpt");
    std::fs::write(&old, &vers).unwrap();
    assert!(matches!(Checkpoint::load(&old), Err(PersistError::UnsupportedVersion(0xEE))));
    assert!(matches!(read_header(&old), Err(PersistError::UnsupportedVersion(0xEE))));

    // not a checkpoint at all
    let junk = dir.join("junk.ckpt");
    std::fs::write(&junk, b"definitely not a checkpoint").unwrap();
    assert!(matches!(read_header(&junk), Err(PersistError::BadMagic)));

    // the engine surfaces these as load errors, not panics
    let engine = Engine::start(&small_cfg()).unwrap();
    assert!(engine.load_model(&bad, Dtype::F32).is_err());
    assert!(engine.load_model(&trunc, Dtype::F64).is_err());
    assert_eq!(engine.encoder_count(), 0);
    engine.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

fn small_cfg() -> ServeConfig {
    ServeConfig {
        shards: 2,
        workers_per_shard: 1,
        queue_capacity: 256,
        max_batch: 4,
        min_fill: 1,
        max_wait_micros: 100,
        cache_capacity: 8,
        ..ServeConfig::default()
    }
}

fn pruned_model(seed: u64, features: usize, hidden: usize) -> (SaeParams, CompactPlan) {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut p =
        SaeParams::init(SaeDims { features, hidden, classes: 2 }, &mut rng);
    let mut mask = vec![1.0f32; features];
    for f in (0..features).step_by(3) {
        mask[f] = 0.0;
    }
    p.apply_feature_mask(&mask);
    (p, CompactPlan::from_mask(&mask))
}

fn export_model(seed: u64, path: &std::path::Path) -> (SaeParams, CompactPlan) {
    let (p, plan) = pruned_model(seed, 12, 5);
    let compact = compact_params(&p, &plan);
    Checkpoint {
        seed,
        config_digest: 2,
        dims: p.dims,
        history: Vec::new(),
        model: Some(ModelBundle { plan: plan.clone(), compact, dense: None }),
        train_state: None,
    }
    .save(path)
    .unwrap();
    (p, plan)
}

#[test]
fn export_import_serve_roundtrip_bit_identical_both_dtypes() {
    let dir = tmp_dir("serve");
    let path = dir.join("m.ckpt");
    let (p, plan) = export_model(91, &path);
    let engine = Engine::start(&small_cfg()).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(92);

    let id64 = engine.load_model(&path, Dtype::F64).unwrap();
    let x = Matrix::<f64>::randn(12, 7, &mut rng);
    let resp = engine.submit_encode_wait(id64, Payload::F64(x.clone())).unwrap();
    let Payload::F64(h) = &resp.payload else { panic!("dtype changed") };
    let mem = CompactEncoder::<f64>::from_params(&p, &plan);
    bits_eq_matrix(h, &mem.encode(&x)).expect("f64 serve output must be bit-identical");

    let id32 = engine.load_model(&path, Dtype::F32).unwrap();
    let x32: Matrix<f32> = x.cast();
    let resp = engine.submit_encode_wait(id32, Payload::F32(x32.clone())).unwrap();
    let Payload::F32(h) = &resp.payload else { panic!("dtype changed") };
    let mem32 = CompactEncoder::<f32>::from_params(&p, &plan);
    bits_eq_matrix(h, &mem32.encode(&x32)).expect("f32 serve output must be bit-identical");

    // dims mismatch at serve admission: wrong row count is rejected with
    // a typed Invalid, not a panic or a silent misread.
    let err = engine
        .submit_encode(id64, Payload::F64(Matrix::randn(11, 7, &mut rng)))
        .unwrap_err();
    assert!(matches!(err, SubmitError::Invalid(_)), "dims mismatch must be Invalid");
    // dtype mismatch against a loaded model likewise
    let err = engine
        .submit_encode(id64, Payload::F32(Matrix::<f32>::zeros(12, 2)))
        .unwrap_err();
    assert!(matches!(err, SubmitError::Invalid(_)));

    engine.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn hot_swap_under_live_traffic_completes_everything() {
    // Acceptance: swapping a model id under closed-loop traffic completes
    // every in-flight request with zero rejects attributable to the swap;
    // each response matches one of the two encoder generations bitwise.
    let engine = Engine::start(&ServeConfig {
        shards: 2,
        workers_per_shard: 2,
        queue_capacity: 1024,
        max_batch: 4,
        min_fill: 1,
        max_wait_micros: 50,
        cache_capacity: 0,
        ..ServeConfig::default()
    })
    .unwrap();
    let (pa, plan_a) = pruned_model(101, 10, 4);
    let (pb, plan_b) = pruned_model(102, 10, 4);
    let enc_a = CompactEncoder::<f64>::from_params(&pa, &plan_a);
    let enc_b = CompactEncoder::<f64>::from_params(&pb, &plan_b);
    let model = engine.register_encoder_f64(enc_a.clone());
    let mut rng = Xoshiro256pp::seed_from_u64(103);
    let x = Matrix::<f64>::randn(10, 6, &mut rng);
    let out_a = enc_a.encode(&x);
    let out_b = enc_b.encode(&x);
    assert!(bits_eq_matrix(&out_a, &out_b).is_err(), "fixture models must differ");

    const CLIENTS: usize = 4;
    const REQS: usize = 60;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..CLIENTS {
            let engine = &engine;
            let (x, out_a, out_b) = (&x, &out_a, &out_b);
            handles.push(s.spawn(move || {
                for i in 0..REQS {
                    match engine.submit_encode_wait(model, Payload::F64(x.clone())) {
                        Ok(resp) => {
                            let Payload::F64(h) = &resp.payload else {
                                panic!("dtype changed")
                            };
                            let matches_a = bits_eq_matrix(h, out_a).is_ok();
                            let matches_b = bits_eq_matrix(h, out_b).is_ok();
                            assert!(
                                matches_a || matches_b,
                                "request {i}: response matches neither encoder generation"
                            );
                        }
                        Err(e) => panic!("request {i} rejected during hot-swap: {e}"),
                    }
                }
                REQS
            }));
        }
        // Flip the model back and forth while the clients hammer it.
        for round in 0..8 {
            std::thread::sleep(Duration::from_millis(2));
            let res = if round % 2 == 0 {
                engine.swap_encoder_f64(model, enc_b.clone())
            } else {
                engine.swap_encoder_f64(model, enc_a.clone())
            };
            res.expect("swap of a live id must succeed");
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, CLIENTS * REQS);
    });
    let stats = engine.shutdown();
    assert_eq!(stats.completed(), (CLIENTS * REQS) as u64);
    assert_eq!(stats.submitted(), (CLIENTS * REQS) as u64);
}

/// Shared fixture for the recovery property tests: a directory holding an
/// older valid snapshot plus the serialized bytes of a newer one. The
/// names make name-descending tie-breaking pick `z-newest` first even
/// when both files land in the same mtime granule.
fn recovery_fixture(tag: &str) -> (std::path::PathBuf, Vec<u8>, Vec<u8>) {
    let dir = tmp_dir(tag);
    let (p_old, plan_old) = pruned_model(121, 12, 5);
    let old = Checkpoint {
        seed: 121,
        config_digest: 4,
        dims: p_old.dims,
        history: Vec::new(),
        model: Some(ModelBundle {
            plan: plan_old.clone(),
            compact: compact_params(&p_old, &plan_old),
            dense: None,
        }),
        train_state: None,
    };
    old.save(&dir.join("a-old.ckpt")).unwrap();
    let old_bytes = old.to_bytes();
    let (p_new, plan_new) = pruned_model(122, 12, 5);
    let new = Checkpoint {
        seed: 122,
        config_digest: 4,
        dims: p_new.dims,
        history: Vec::new(),
        model: Some(ModelBundle {
            plan: plan_new.clone(),
            compact: compact_params(&p_new, &plan_new),
            dense: None,
        }),
        train_state: None,
    };
    (dir, old_bytes, new.to_bytes())
}

/// Run one recovery round against a damaged newest checkpoint and verify
/// the chain's guarantees: the damaged file is quarantined, the prior
/// snapshot comes back byte for byte, and wrong bits are never returned.
fn assert_falls_back(
    dir: &std::path::Path,
    old_bytes: &[u8],
    damaged: &[u8],
    what: &str,
) {
    let newest = dir.join("z-newest.ckpt");
    std::fs::write(&newest, damaged).unwrap();
    let out = recover_latest(dir).unwrap();
    let (path, ck) = out
        .recovered
        .unwrap_or_else(|| panic!("{what}: prior snapshot must be recoverable"));
    assert!(path.ends_with("a-old.ckpt"), "{what}: recovered {path:?}");
    assert_eq!(
        ck.to_bytes(),
        old_bytes,
        "{what}: recovery must be bit-exact, never wrong bits"
    );
    assert_eq!(out.quarantined.len(), 1, "{what}: {:?}", out.quarantined);
    assert!(!newest.exists(), "{what}: damaged file must be moved aside");
    let corrupt = dir.join("z-newest.ckpt.corrupt");
    assert!(corrupt.exists(), "{what}: quarantine sibling must exist");
    std::fs::remove_file(&corrupt).unwrap();
}

#[test]
fn recovery_survives_truncation_at_every_offset() {
    // Property: however many trailing bytes a torn write loses — from the
    // whole file down to a single byte — loading never yields wrong bits;
    // the chain quarantines the stump and falls back to the prior
    // snapshot bit-exactly.
    let (dir, old_bytes, new_bytes) = recovery_fixture("truncate");
    for cut in 0..new_bytes.len() {
        assert_falls_back(&dir, &old_bytes, &new_bytes[..cut], &format!("truncated to {cut}"));
    }
    // The undamaged file at full length recovers as itself.
    std::fs::write(dir.join("z-newest.ckpt"), &new_bytes).unwrap();
    let out = recover_latest(&dir).unwrap();
    let (path, ck) = out.recovered.unwrap();
    assert!(path.ends_with("z-newest.ckpt"));
    assert_eq!(ck.to_bytes(), new_bytes);
    assert!(out.quarantined.is_empty());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn recovery_survives_any_single_bit_flip() {
    // Property: one flipped bit anywhere in the newest checkpoint —
    // magic, version, dims, payload, or the checksum itself — is always
    // detected (the 128-bit checksum covers everything before it), the
    // file is quarantined, and the prior snapshot is restored bit-exactly.
    let (dir, old_bytes, new_bytes) = recovery_fixture("bitflip");
    for i in 0..new_bytes.len() {
        let mut damaged = new_bytes.clone();
        damaged[i] ^= 1u8 << (i % 8);
        assert_falls_back(&dir, &old_bytes, &damaged, &format!("bit flip in byte {i}"));
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn mid_train_state_checkpoint_roundtrips_and_refuses_serving() {
    // A rolling trainer checkpoint (train state, no model bundle) must
    // round-trip its optimizer tensors bit-exactly and be rejected by the
    // serve loader with a clear error.
    use bilevel_sparse::persist::TrainStateSnapshot;
    let dir = tmp_dir("state");
    let path = dir.join("roll.ckpt");
    let mut rng = Xoshiro256pp::seed_from_u64(111);
    let p = SaeParams::init(SaeDims { features: 8, hidden: 4, classes: 2 }, &mut rng);
    let ck = Checkpoint {
        seed: 111,
        config_digest: 3,
        dims: p.dims,
        history: Vec::new(),
        model: None,
        train_state: Some(TrainStateSnapshot {
            phase: 1,
            epochs_done: 2,
            step: 34.0,
            mask: vec![1.0; 8],
            params: p.clone(),
            m: p.zeros_like(),
            v: p.zeros_like(),
        }),
    };
    ck.save(&path).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    let ts = back.train_state.as_ref().unwrap();
    assert_eq!((ts.phase, ts.epochs_done), (1, 2));
    assert_eq!(ts.step.to_bits(), 34.0f32.to_bits());
    bits_eq_params(&ts.params, &p).unwrap();
    let header = read_header(&path).unwrap();
    assert!(header.has_train_state() && !header.has_model());

    let engine = Engine::start(&small_cfg()).unwrap();
    let err = engine.load_model(&path, Dtype::F32).unwrap_err();
    assert!(err.contains("no model bundle"), "got: {err}");
    engine.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}
