//! The `bilevel audit` rules, enforced by plain `cargo test`.
//!
//! Two layers: the repository itself must audit clean (the same check the
//! CLI subcommand and the CI step run), and minimal fixtures pin each
//! rule's behaviour — exactly one finding per seeded violation, zero on a
//! clean fixture, spans anchored to the right line, and no firing on rule
//! tokens that only appear inside strings or comments (every fixture
//! below holds its violation in a string literal precisely so this file
//! audits clean).

use std::path::Path;

use bilevel_sparse::analysis::rules::{
    check_registration, check_source, RULE_ALLOWLIST, RULE_BANNED, RULE_CLIPPY, RULE_LOCK,
    RULE_REGISTERED, RULE_SAFETY, UNSAFE_ALLOWLIST,
};
use bilevel_sparse::analysis::{audit_repo, render};

#[test]
fn repository_audits_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = audit_repo(root).expect("audit must be able to read the repo");
    assert!(
        report.files_scanned > 30,
        "suspiciously few files scanned ({}); wrong root?",
        report.files_scanned
    );
    assert!(report.is_clean(), "repository must audit clean:\n{}", render(&report));
}

#[test]
fn uncommented_unsafe_in_an_allowlisted_file_is_one_finding() {
    let src = "pub fn f(x: &[f64]) -> f64 {\n    unsafe { *x.get_unchecked(0) }\n}\n";
    let findings = check_source(UNSAFE_ALLOWLIST[0], src);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, RULE_SAFETY);
    assert_eq!(findings[0].line, 2, "span must anchor on the unsafe line");
}

#[test]
fn safety_comment_immediately_above_satisfies_the_rule() {
    let src = concat!(
        "pub fn f(x: &[f64]) -> f64 {\n",
        "    // SAFETY: caller guarantees non-empty.\n",
        "    unsafe { *x.get_unchecked(0) }\n",
        "}\n",
    );
    let findings = check_source(UNSAFE_ALLOWLIST[0], src);
    assert!(findings.is_empty(), "commented site must pass: {findings:?}");
}

#[test]
fn unsafe_outside_the_allowlist_is_one_finding() {
    let src = concat!(
        "pub fn f() {\n",
        "    // SAFETY: fixture.\n",
        "    unsafe { std::hint::unreachable_unchecked() }\n",
        "}\n",
    );
    let findings = check_source("rust/src/tensor.rs", src);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, RULE_ALLOWLIST);
    assert_eq!(findings[0].line, 3);
}

#[test]
fn lock_unwrap_in_src_is_one_finding_anchored_at_the_lock_call() {
    // The unwrap sits on the next line: the span must point at `.lock()`.
    let src = "pub fn f(m: &std::sync::Mutex<u8>) -> u8 {\n    *m.lock()\n        .unwrap()\n}\n";
    let findings = check_source("rust/src/serve/engine.rs", src);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, RULE_LOCK);
    assert_eq!(findings[0].line, 2, "span must anchor where .lock() is called");
}

#[test]
fn lock_unwrap_in_test_code_and_outside_src_is_allowed() {
    let src = concat!(
        "pub fn ok() {}\n\n",
        "#[cfg(test)]\n",
        "mod tests {\n",
        "    #[test]\n",
        "    fn t() {\n",
        "        let m = std::sync::Mutex::new(1u8);\n",
        "        assert_eq!(*m.lock().unwrap(), 1);\n",
        "    }\n",
        "}\n",
    );
    let in_tests = check_source("rust/src/serve/engine.rs", src);
    assert!(in_tests.is_empty(), "{in_tests:?}");
    let bare = "pub fn f(m: &std::sync::Mutex<u8>) -> u8 {\n    *m.lock().unwrap()\n}\n";
    let outside = check_source("rust/tests/some_suite.rs", bare);
    assert!(outside.is_empty(), "{outside:?}");
}

#[test]
fn banned_macro_in_src_is_one_finding() {
    let src = "pub fn f() {\n    todo!(\"later\")\n}\n";
    let findings = check_source("rust/src/report.rs", src);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, RULE_BANNED);
    assert_eq!(findings[0].line, 2);
}

#[test]
fn missing_clippy_deny_on_a_module_header_is_one_finding() {
    let src = "#[deny(clippy::all)]\npub mod good;\npub mod bad;\n";
    let findings = check_source("rust/src/lib.rs", src);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, RULE_CLIPPY);
    assert_eq!(findings[0].line, 3, "span must anchor on the unpinned module line");
}

#[test]
fn rule_tokens_inside_strings_and_comments_never_fire() {
    // Every rule token below sits in a comment or a string literal; the
    // lexer must blank them all before the rules scan the code channel.
    let src = concat!(
        "// this comment says unsafe and todo! and .lock().unwrap()\n",
        "pub fn f() -> String {\n",
        "    let s = \"unsafe { nope } .lock().unwrap() todo!()\";\n",
        "    /* unsafe block comment */\n",
        "    let r = r#\"raw unsafe .lock().unwrap()\"#;\n",
        "    format!(\"{s}{r}\")\n",
        "}\n",
    );
    let findings = check_source("rust/src/report.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn clean_fixture_yields_zero_findings() {
    let src = concat!(
        "pub fn f(m: &std::sync::Mutex<u8>) -> u8 {\n",
        "    *crate::sync::lock_unpoisoned(m)\n",
        "}\n",
    );
    let findings = check_source("rust/src/serve/engine.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn unregistered_suite_is_flagged_and_registered_one_is_not() {
    let cargo = concat!(
        "[package]\nname = \"x\"\nautotests = false\nautobenches = false\n\n",
        "[[test]]\nname = \"registered\"\npath = \"rust/tests/registered.rs\"\n",
    );
    let tests = ["registered.rs".to_string(), "forgotten.rs".to_string()];
    let findings = check_registration(cargo, &tests, &[]);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, RULE_REGISTERED);
    assert_eq!(findings[0].path, "rust/tests/forgotten.rs");
}

#[test]
fn auto_discovery_left_on_is_flagged() {
    let cargo = "[package]\nname = \"x\"\n";
    let findings = check_registration(cargo, &[], &[]);
    let msgs: Vec<&str> = findings.iter().map(|f| f.message.as_str()).collect();
    assert_eq!(findings.len(), 2, "{findings:?}");
    assert!(msgs.iter().any(|m| m.contains("autotests")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("autobenches")), "{msgs:?}");
}
