//! Cross-module integration & property tests for the projection library.
//!
//! These are the paper's mathematical claims, checked end-to-end on random
//! inputs via the in-repo property harness (`bilevel_sparse::proptest`):
//! feasibility, tightness, the ℓ1,∞/ℓ1,1/ℓ1,2 identities (Props. III.3,
//! III.5, IV.1, IV.2), the contraction bounds (Remark III.1), the clipping
//! characterisation (Remark III.4), and the sparsity/ℓ2-error trade-off
//! between `BP¹,∞` and the exact projection (Remark III.6).

use bilevel_sparse::norms::*;
use bilevel_sparse::projection::bilevel::*;
use bilevel_sparse::projection::l1::{project_l1, L1Algorithm};
use bilevel_sparse::projection::l1inf::{project_l1inf, project_l1inf_with, L1InfAlgorithm};
use bilevel_sparse::proptest::{forall, MatrixAndRadius, PropConfig, VectorAndRadius};
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::tensor::{vec_ops, Matrix};

fn cfg(seed: u64) -> PropConfig {
    PropConfig { cases: 300, seed, max_shrink_steps: 24 }
}

// ---------------------------------------------------------------- l1 ball

#[test]
fn prop_l1_feasibility_all_algorithms() {
    forall::<VectorAndRadius>(cfg(1), |input| {
        for algo in L1Algorithm::all() {
            let x = project_l1(&input.v, input.eta, *algo);
            let norm = vec_ops::l1(&x);
            if norm > input.eta * (1.0 + 1e-9) + 1e-9 {
                return Err(format!("{}: ||x||_1 = {norm} > eta = {}", algo.name(), input.eta));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_l1_algorithms_agree() {
    forall::<VectorAndRadius>(cfg(2), |input| {
        let base = project_l1(&input.v, input.eta, L1Algorithm::Sort);
        for algo in [L1Algorithm::Michelot, L1Algorithm::Condat, L1Algorithm::Bucket] {
            let x = project_l1(&input.v, input.eta, algo);
            for (i, (a, b)) in base.iter().zip(x.iter()).enumerate() {
                if (a - b).abs() > 1e-7 * (1.0 + a.abs()) {
                    return Err(format!(
                        "{} disagrees with sort at {i}: {b} vs {a}",
                        algo.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_l1_nonexpansive() {
    // Projections onto convex sets are 1-Lipschitz.
    forall::<VectorAndRadius>(cfg(3), |input| {
        let other: Vec<f64> = input
            .v
            .iter()
            .enumerate()
            .map(|(i, &x)| x + ((i as f64 * 0.7).sin()) * 0.5)
            .collect();
        let px = project_l1(&input.v, input.eta, L1Algorithm::Condat);
        let py = project_l1(&other, input.eta, L1Algorithm::Condat);
        let before = vec_ops::dist2(&input.v, &other);
        let after = vec_ops::dist2(&px, &py);
        if after > before * (1.0 + 1e-9) + 1e-9 {
            return Err(format!("expansion: {after} > {before}"));
        }
        Ok(())
    });
}

// ----------------------------------------------------- bilevel projections

#[test]
fn prop_bilevel_l1inf_feasible_and_tight() {
    forall::<MatrixAndRadius>(cfg(4), |input| {
        let x = bilevel_l1inf(&input.y, input.eta);
        let norm = l1inf_norm(&x);
        let orig = l1inf_norm(&input.y);
        if norm > input.eta * (1.0 + 1e-8) + 1e-8 {
            return Err(format!("infeasible: {norm} > {}", input.eta));
        }
        // Tight when the input was outside the ball.
        if orig > input.eta && (norm - input.eta).abs() > 1e-6 * (1.0 + input.eta) {
            return Err(format!("not tight: {norm} vs {}", input.eta));
        }
        Ok(())
    });
}

#[test]
fn prop_identity_l1inf_bilevel_and_exact() {
    // Props. III.3 and III.5: the identity holds for BOTH projections.
    forall::<MatrixAndRadius>(cfg(5), |input| {
        let rhs = l1inf_norm(&input.y);
        let bp = bilevel_l1inf(&input.y, input.eta);
        let lhs_bp = l1inf_norm(&input.y.sub(&bp)) + l1inf_norm(&bp);
        if (lhs_bp - rhs).abs() > 1e-7 * (1.0 + rhs) {
            return Err(format!("BP identity: {lhs_bp} != {rhs}"));
        }
        let p = project_l1inf(&input.y, input.eta, L1InfAlgorithm::Newton);
        let lhs_p = l1inf_norm(&input.y.sub(&p)) + l1inf_norm(&p);
        if (lhs_p - rhs).abs() > 1e-6 * (1.0 + rhs) {
            return Err(format!("P identity: {lhs_p} != {rhs}"));
        }
        Ok(())
    });
}

#[test]
fn prop_identity_l11_and_l12() {
    forall::<MatrixAndRadius>(cfg(6), |input| {
        let y = &input.y;
        // Scale radius to each norm's range.
        let r11 = bilevel_l11(y, input.eta * l11_norm(y).max(1.0) / l1inf_norm(y).max(1e-12));
        let lhs = l11_norm(&y.sub(&r11)) + l11_norm(&r11);
        let rhs = l11_norm(y);
        if (lhs - rhs).abs() > 1e-7 * (1.0 + rhs) {
            return Err(format!("l11 identity: {lhs} != {rhs}"));
        }
        let r12 = bilevel_l12(y, input.eta * l12_norm(y).max(1.0) / l1inf_norm(y).max(1e-12));
        let lhs = l12_norm(&y.sub(&r12)) + l12_norm(&r12);
        let rhs = l12_norm(y);
        if (lhs - rhs).abs() > 1e-7 * (1.0 + rhs) {
            return Err(format!("l12 identity: {lhs} != {rhs}"));
        }
        Ok(())
    });
}

#[test]
fn prop_contraction_remark_iii_1() {
    forall::<MatrixAndRadius>(cfg(7), |input| {
        let r = bilevel_l1inf_with(&input.y, input.eta, L1Algorithm::Condat);
        for (j, col) in input.y.columns().enumerate() {
            let linf = vec_ops::linf(col);
            let u = r.thresholds[j];
            if !(0.0..=linf + 1e-10).contains(&u) {
                return Err(format!("column {j}: u = {u} not in [0, {linf}]"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_exact_is_clipping_operator_remark_iii_4() {
    // The exact projection equals column-clipping at its own mu, and the mu
    // vector is feasible: sums to eta (when outside) with 0<=mu_j<=||y_j||inf.
    forall::<MatrixAndRadius>(cfg(8), |input| {
        let r = project_l1inf_with(&input.y, input.eta, L1InfAlgorithm::Ssn);
        let orig = l1inf_norm(&input.y);
        if orig > input.eta {
            let s: f64 = r.mu.iter().sum();
            if (s - input.eta).abs() > 1e-6 * (1.0 + input.eta) {
                return Err(format!("sum(mu) = {s} != eta = {}", input.eta));
            }
        }
        for (j, col) in input.y.columns().enumerate() {
            if r.mu[j] < -1e-12 || r.mu[j] > vec_ops::linf(col) + 1e-9 {
                return Err(format!("mu[{j}] = {} out of bounds", r.mu[j]));
            }
            // verify clip form
            for (i, &v) in col.iter().enumerate() {
                let want = v.signum() * v.abs().min(r.mu[j]);
                let got = r.x.get(i, j);
                if (want - got).abs() > 1e-9 && v != 0.0 {
                    return Err(format!("not a clip at ({i},{j}): {got} vs {want}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bilevel_sparser_exact_better_l2_remark_iii_6() {
    // BP gives >= column sparsity; P gives <= Frobenius error.
    forall::<MatrixAndRadius>(cfg(9), |input| {
        if l1inf_norm(&input.y) <= input.eta {
            return Ok(()); // both identities — nothing to compare
        }
        let bp = bilevel_l1inf(&input.y, input.eta);
        let p = project_l1inf(&input.y, input.eta, L1InfAlgorithm::Newton);
        let sbp = bp.zero_columns(1e-12).len();
        let sp = p.zero_columns(1e-12).len();
        if sbp + 1 < sp {
            // Allow a 1-column slack for boundary ties; the paper's claim is
            // aggregate, and exact ties can flip single columns.
            return Err(format!("BP sparsity {sbp} << exact sparsity {sp}"));
        }
        let ebp = frobenius_norm(&input.y.sub(&bp));
        let ep = frobenius_norm(&input.y.sub(&p));
        if ep > ebp * (1.0 + 1e-7) + 1e-9 {
            return Err(format!("exact l2 error {ep} > bilevel {ebp}"));
        }
        Ok(())
    });
}

#[test]
fn prop_exact_algorithms_cross_agree() {
    forall::<MatrixAndRadius>(
        PropConfig { cases: 120, seed: 10, max_shrink_steps: 24 },
        |input| {
            let golden = project_l1inf(&input.y, input.eta, L1InfAlgorithm::Bisection);
            for algo in [L1InfAlgorithm::Quattoni, L1InfAlgorithm::Newton, L1InfAlgorithm::Ssn] {
                let x = project_l1inf(&input.y, input.eta, algo);
                let diff = golden.max_abs_diff(&x);
                if diff > 1e-5 {
                    return Err(format!("{} differs from bisection by {diff}", algo.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_idempotence() {
    forall::<MatrixAndRadius>(cfg(11), |input| {
        let once = bilevel_l1inf(&input.y, input.eta);
        let twice = bilevel_l1inf(&once, input.eta);
        let d = once.max_abs_diff(&twice);
        if d > 1e-8 {
            return Err(format!("BP not idempotent: {d}"));
        }
        let p1 = project_l1inf(&input.y, input.eta, L1InfAlgorithm::Ssn);
        let p2 = project_l1inf(&p1, input.eta, L1InfAlgorithm::Ssn);
        let d = p1.max_abs_diff(&p2);
        if d > 1e-8 {
            return Err(format!("P not idempotent: {d}"));
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_matches_sequential() {
    // `bilevel_l1inf_parallel` ≡ `bilevel_l1inf_with` over random shapes,
    // radii, thread counts, and both sides of the `min_elems` sequential
    // fallback — matrices *and* threshold vectors.
    forall::<MatrixAndRadius>(
        PropConfig { cases: 100, seed: 12, max_shrink_steps: 16 },
        |input| {
            let seq = bilevel_l1inf_with(&input.y, input.eta, L1Algorithm::Condat);
            let elems = input.y.rows() * input.y.cols();
            for threads in [1usize, 2, 3, 8] {
                // min_elems 0 forces the threaded path, a huge value forces
                // the sequential fallback, and `elems` sits exactly on the
                // boundary (`elems < min_elems` is false ⇒ threaded).
                for min_elems in [0usize, elems, usize::MAX] {
                    let par = bilevel_l1inf_parallel(
                        &input.y,
                        input.eta,
                        L1Algorithm::Condat,
                        ParallelPolicy { threads, min_elems },
                    );
                    let d = seq.x.max_abs_diff(&par.x);
                    if d > 1e-12 {
                        return Err(format!(
                            "threads={threads} min_elems={min_elems}: matrix differs by {d}"
                        ));
                    }
                    if par.thresholds.len() != seq.thresholds.len() {
                        return Err(format!(
                            "threads={threads} min_elems={min_elems}: {} thresholds vs {}",
                            par.thresholds.len(),
                            seq.thresholds.len()
                        ));
                    }
                    for (j, (a, b)) in
                        seq.thresholds.iter().zip(par.thresholds.iter()).enumerate()
                    {
                        if (a - b).abs() > 1e-12 {
                            return Err(format!(
                                "threads={threads} min_elems={min_elems}: threshold {j} \
                                 differs ({a} vs {b})"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn parallel_min_elems_boundary_is_exact() {
    // n*m == min_elems takes the threaded path (`<` comparison); one more
    // element of slack takes the sequential fallback. Both must agree with
    // the sequential reference bit-for-bit on this f64 input.
    let mut rng = Xoshiro256pp::seed_from_u64(31);
    let y = Matrix::<f64>::randn(16, 16, &mut rng); // 256 elements
    let seq = bilevel_l1inf_with(&y, 2.0, L1Algorithm::Condat);
    let on_boundary = bilevel_l1inf_parallel(
        &y,
        2.0,
        L1Algorithm::Condat,
        ParallelPolicy { threads: 4, min_elems: 256 },
    );
    let below_boundary = bilevel_l1inf_parallel(
        &y,
        2.0,
        L1Algorithm::Condat,
        ParallelPolicy { threads: 4, min_elems: 257 },
    );
    assert_eq!(seq.x.max_abs_diff(&on_boundary.x), 0.0);
    assert_eq!(seq.x.max_abs_diff(&below_boundary.x), 0.0);
    assert_eq!(seq.thresholds, on_boundary.thresholds);
    assert_eq!(seq.thresholds, below_boundary.thresholds);
}

#[test]
fn parallel_more_threads_than_columns() {
    // threads > m exercises the `hw.min(work_items)` clamp and ragged
    // chunking together.
    let mut rng = Xoshiro256pp::seed_from_u64(32);
    let y = Matrix::<f64>::randn(64, 3, &mut rng);
    let seq = bilevel_l1inf_with(&y, 1.0, L1Algorithm::Condat);
    let par = bilevel_l1inf_parallel(
        &y,
        1.0,
        L1Algorithm::Condat,
        ParallelPolicy { threads: 16, min_elems: 0 },
    );
    assert!(seq.x.max_abs_diff(&par.x) < 1e-15);
    assert_eq!(seq.thresholds.len(), par.thresholds.len());
}

// ------------------------------------------------------------- regressions

#[test]
fn paper_example_shapes_run_fast_smoke() {
    // The paper's benchmark shape: 1000x1000, eta = 1.
    let mut rng = Xoshiro256pp::seed_from_u64(2024);
    let y = Matrix::<f64>::randn(1000, 1000, &mut rng);
    let t0 = std::time::Instant::now();
    let bp = bilevel_l1inf(&y, 1.0);
    let t_bp = t0.elapsed();
    let t0 = std::time::Instant::now();
    let p = project_l1inf(&y, 1.0, L1InfAlgorithm::Ssn);
    let t_ssn = t0.elapsed();
    assert!(l1inf_norm(&bp) <= 1.0 + 1e-8);
    assert!(l1inf_norm(&p) <= 1.0 + 1e-6);
    eprintln!("1000x1000: bilevel {t_bp:?}, ssn {t_ssn:?}");
}

#[test]
fn eta_one_on_gaussian_kills_most_columns() {
    // With eta=1 on a gaussian matrix, the inner l1 projection concentrates
    // mass on few columns — the regime of the paper's Fig. 1.
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let y = Matrix::<f64>::randn(500, 500, &mut rng);
    let bp = bilevel_l1inf(&y, 1.0);
    let zeros = bp.zero_columns(0.0).len();
    assert!(zeros > 400, "expected heavy sparsification, got {zeros} zero columns");
}
