//! Kernel-layer acceptance suite: the lane-chunked SIMD paths are
//! bit-identical to their scalar references across dtypes and edge shapes,
//! the workspace (`*_into`) entry points reproduce the one-shot entry
//! points exactly, and the pool-parallel path reproduces the sequential
//! path exactly.

use bilevel_sparse::kernels::{self, Workspace};
use bilevel_sparse::projection::bilevel::{
    bilevel_l1inf_into, bilevel_l1inf_parallel, bilevel_l1inf_parallel_into,
    bilevel_l1inf_with, ParallelPolicy,
};
use bilevel_sparse::projection::l1::L1Algorithm;
use bilevel_sparse::proptest::{forall, MatrixAndRadius, PropConfig};
use bilevel_sparse::rng::{Rng, Xoshiro256pp};
use bilevel_sparse::scalar::Scalar;
use bilevel_sparse::tensor::Matrix;

fn assert_bits_eq<T: Scalar>(a: &[T], b: &[T], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(
            x.to_f64().to_bits(),
            y.to_f64().to_bits(),
            "{what}: element {i}: {x} vs {y}"
        );
    }
}

/// Lengths straddling every lane boundary, plus degenerate ones.
fn edge_lens() -> Vec<usize> {
    let l = kernels::LANES;
    vec![1, 2, l - 1, l, l + 1, 2 * l - 1, 2 * l, 3 * l + 1, 127, 128, 129]
}

fn kernel_equivalence_for<T: Scalar>(seed: u64) {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    for n in edge_lens() {
        let v: Vec<T> =
            (0..n).map(|_| T::from_f64(rng.uniform(-3.0, 3.0))).collect();
        assert_eq!(
            kernels::colmax(&v).to_f64().to_bits(),
            kernels::colmax_ref(&v).to_f64().to_bits(),
            "colmax n={n}"
        );
        assert_eq!(
            kernels::sum_abs(&v).to_f64().to_bits(),
            kernels::sum_abs_ref(&v).to_f64().to_bits(),
            "sum_abs n={n}"
        );
        assert_eq!(
            kernels::sumsq(&v).to_f64().to_bits(),
            kernels::sumsq_ref(&v).to_f64().to_bits(),
            "sumsq n={n}"
        );
        // Clip at a strict threshold, at zero, and exactly at the column
        // max (the copy-vs-clip boundary of the fused stage).
        for c in [T::ZERO, T::from_f64(0.5), kernels::colmax(&v)] {
            let mut a = vec![T::ZERO; n];
            let mut b = vec![T::ZERO; n];
            kernels::clip_into(&v, c, &mut a);
            kernels::clip_into_ref(&v, c, &mut b);
            assert_bits_eq(&a, &b, "clip");
        }
        let mut a = v.clone();
        let mut b = v.clone();
        kernels::soft_threshold_inplace(&mut a, T::from_f64(0.7));
        kernels::soft_threshold_inplace_ref(&mut b, T::from_f64(0.7));
        assert_bits_eq(&a, &b, "soft_threshold");
        let mut a = v.clone();
        let mut b = v.clone();
        kernels::scale_inplace(&mut a, T::from_f64(0.37));
        kernels::scale_inplace_ref(&mut b, T::from_f64(0.37));
        assert_bits_eq(&a, &b, "scale");
        // axpy — the sparse-encode row update
        let row: Vec<T> =
            (0..n).map(|_| T::from_f64(rng.uniform(-3.0, 3.0))).collect();
        let mut a = v.clone();
        let mut b = v;
        kernels::axpy(&mut a, T::from_f64(-0.83), &row);
        kernels::axpy_ref(&mut b, T::from_f64(-0.83), &row);
        assert_bits_eq(&a, &b, "axpy");
    }
}

#[test]
fn chunked_kernels_bit_identical_to_scalar_reference_f64() {
    kernel_equivalence_for::<f64>(11);
}

#[test]
fn chunked_kernels_bit_identical_to_scalar_reference_f32() {
    kernel_equivalence_for::<f32>(12);
}

fn into_matches_with_for<T: Scalar>(y: &Matrix<T>, eta: T) {
    let mut ws = Workspace::new();
    let mut out = Matrix::zeros(0, 0);
    for algo in L1Algorithm::all() {
        let r = bilevel_l1inf_with(y, eta, *algo);
        bilevel_l1inf_into(y, eta, *algo, &mut ws, &mut out);
        assert_bits_eq(r.x.as_slice(), out.as_slice(), "into vs with (matrix)");
        assert_bits_eq(&r.thresholds, ws.thresholds(), "into vs with (thresholds)");
    }
}

#[test]
fn prop_into_matches_with_exactly() {
    forall::<MatrixAndRadius>(PropConfig { cases: 150, ..Default::default() }, |case| {
        let mut ws = Workspace::new();
        let mut out = Matrix::zeros(0, 0);
        let r = bilevel_l1inf_with(&case.y, case.eta, L1Algorithm::Condat);
        bilevel_l1inf_into(&case.y, case.eta, L1Algorithm::Condat, &mut ws, &mut out);
        for (a, b) in r.x.as_slice().iter().zip(out.as_slice().iter()) {
            if a.to_bits() != b.to_bits() {
                return Err(format!("matrix bits differ: {a} vs {b}"));
            }
        }
        for (a, b) in r.thresholds.iter().zip(ws.thresholds().iter()) {
            if a.to_bits() != b.to_bits() {
                return Err(format!("threshold bits differ: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn into_matches_with_on_edge_shapes() {
    // n=1, m=1, non-lane-multiple rows, and a column exactly at its
    // threshold (eta large enough that one column is untouched).
    let mut rng = Xoshiro256pp::seed_from_u64(77);
    for (n, m) in [(1, 1), (1, 9), (9, 1), (13, 7), (31, 33), (64, 5)] {
        let y64 = Matrix::<f64>::randn(n, m, &mut rng);
        for eta in [0.0, 0.3, 5.0, 1e6] {
            into_matches_with_for(&y64, eta);
            let y32: Matrix<f32> = y64.cast();
            into_matches_with_for(&y32, eta as f32);
        }
    }
}

#[test]
fn into_handles_columns_exactly_at_threshold() {
    // A constant-magnitude matrix makes every column norm equal, so the
    // inner projection puts thresholds exactly at (or symmetrically
    // below) the norms — the `û_j >= ‖y_j‖∞` copy branch is exercised in
    // both directions.
    let n = 12;
    let m = 8;
    let y = Matrix::<f64>::full(n, m, -1.5);
    // eta = m * 1.5 → inside the ball, all columns copied verbatim.
    into_matches_with_for(&y, 12.0);
    // eta tight → all columns clipped at the same threshold.
    into_matches_with_for(&y, 3.0);
}

#[test]
fn prop_pool_parallel_matches_sequential_exactly() {
    forall::<MatrixAndRadius>(PropConfig { cases: 80, ..Default::default() }, |case| {
        let seq = bilevel_l1inf_with(&case.y, case.eta, L1Algorithm::Condat);
        for threads in [2usize, 5] {
            let par = bilevel_l1inf_parallel(
                &case.y,
                case.eta,
                L1Algorithm::Condat,
                ParallelPolicy { threads, min_elems: 0 },
            );
            for (a, b) in seq.x.as_slice().iter().zip(par.x.as_slice().iter()) {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "threads={threads}: matrix bits differ: {a} vs {b}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn parallel_into_reuses_buffers_across_shapes() {
    let mut rng = Xoshiro256pp::seed_from_u64(88);
    let mut ws = Workspace::new();
    let mut out = Matrix::zeros(0, 0);
    for (n, m) in [(40, 200), (8, 64), (100, 30)] {
        let y = Matrix::<f64>::randn(n, m, &mut rng);
        bilevel_l1inf_parallel_into(
            &y,
            1.7,
            L1Algorithm::Condat,
            ParallelPolicy { threads: 4, min_elems: 0 },
            &mut ws,
            &mut out,
        );
        let seq = bilevel_l1inf_with(&y, 1.7, L1Algorithm::Condat);
        assert_bits_eq(seq.x.as_slice(), out.as_slice(), "parallel_into");
        assert_eq!(out.rows(), n);
        assert_eq!(out.cols(), m);
    }
}
