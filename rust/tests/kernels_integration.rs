//! Kernel-layer acceptance suite: every kernel's three flavours —
//! scalar reference, portable chunked, and runtime-dispatched explicit
//! SIMD — agree bitwise across dtypes and lane-boundary shapes (modulo
//! the documented zero-sign delta of clip/soft-threshold at a threshold
//! of exactly 0), the workspace (`*_into`) entry points reproduce the
//! one-shot entry points exactly, and the pool-parallel path reproduces
//! the sequential path exactly. Inputs include `-0.0`, subnormals, and
//! values exactly at the threshold.
//!
//! CI runs this suite twice: once on the detected ISA and once with
//! `BILEVEL_FORCE_SCALAR=1` pinning the portable path; the forced-ISA
//! tests below additionally call the per-ISA tables directly, so the
//! explicit SIMD kernels are exercised even under force-scalar.

use bilevel_sparse::kernels::{self, Workspace};
use bilevel_sparse::projection::bilevel::{
    bilevel_l1inf_into, bilevel_l1inf_parallel, bilevel_l1inf_parallel_into,
    bilevel_l1inf_with, ParallelPolicy,
};
use bilevel_sparse::projection::l1::L1Algorithm;
use bilevel_sparse::proptest::{forall, MatrixAndRadius, PropConfig};
use bilevel_sparse::rng::{Rng, Xoshiro256pp};
use bilevel_sparse::scalar::Scalar;
use bilevel_sparse::tensor::Matrix;

fn assert_bits_eq<T: Scalar>(a: &[T], b: &[T], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(
            x.to_f64().to_bits(),
            y.to_f64().to_bits(),
            "{what}: element {i}: {x} vs {y}"
        );
    }
}

/// Bitwise equality except both-zero (any sign) is accepted — the
/// documented zero-sign delta of the explicit-SIMD clip/soft-threshold at
/// a threshold of exactly 0 (see the `kernels` module docs).
fn assert_bits_eq_mod_zero_sign<T: Scalar>(a: &[T], b: &[T], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let same_bits = x.to_f64().to_bits() == y.to_f64().to_bits();
        let both_zero = x.to_f64() == 0.0 && y.to_f64() == 0.0;
        assert!(same_bits || both_zero, "{what}: element {i}: {x} vs {y}");
    }
}

/// Lengths straddling every lane boundary, plus degenerate ones.
fn edge_lens() -> Vec<usize> {
    let l = kernels::LANES;
    vec![1, 2, l - 1, l, l + 1, 2 * l - 1, 2 * l, 3 * l + 1, 127, 128, 129]
}

fn kernel_equivalence_for<T: Scalar>(seed: u64) {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    for n in edge_lens() {
        let v: Vec<T> =
            (0..n).map(|_| T::from_f64(rng.uniform(-3.0, 3.0))).collect();
        assert_eq!(
            kernels::colmax(&v).to_f64().to_bits(),
            kernels::colmax_ref(&v).to_f64().to_bits(),
            "colmax n={n}"
        );
        assert_eq!(
            kernels::sum_abs(&v).to_f64().to_bits(),
            kernels::sum_abs_ref(&v).to_f64().to_bits(),
            "sum_abs n={n}"
        );
        assert_eq!(
            kernels::sumsq(&v).to_f64().to_bits(),
            kernels::sumsq_ref(&v).to_f64().to_bits(),
            "sumsq n={n}"
        );
        // Clip at a strict threshold, at zero, and exactly at the column
        // max (the copy-vs-clip boundary of the fused stage). At c = 0
        // every output is a zero whose sign is the documented
        // path-dependent delta, so that case compares modulo zero sign.
        for c in [T::ZERO, T::from_f64(0.5), kernels::colmax(&v)] {
            let mut a = vec![T::ZERO; n];
            let mut b = vec![T::ZERO; n];
            kernels::clip_into(&v, c, &mut a);
            kernels::clip_into_ref(&v, c, &mut b);
            if c > T::ZERO {
                assert_bits_eq(&a, &b, "clip");
            } else {
                assert_bits_eq_mod_zero_sign(&a, &b, "clip(c=0)");
            }
        }
        let mut a = v.clone();
        let mut b = v.clone();
        kernels::soft_threshold_inplace(&mut a, T::from_f64(0.7));
        kernels::soft_threshold_inplace_ref(&mut b, T::from_f64(0.7));
        assert_bits_eq(&a, &b, "soft_threshold");
        let mut a = v.clone();
        let mut b = v.clone();
        kernels::scale_inplace(&mut a, T::from_f64(0.37));
        kernels::scale_inplace_ref(&mut b, T::from_f64(0.37));
        assert_bits_eq(&a, &b, "scale");
        // axpy — the sparse-encode row update
        let row: Vec<T> =
            (0..n).map(|_| T::from_f64(rng.uniform(-3.0, 3.0))).collect();
        let mut a = v.clone();
        let mut b = v;
        kernels::axpy(&mut a, T::from_f64(-0.83), &row);
        kernels::axpy_ref(&mut b, T::from_f64(-0.83), &row);
        assert_bits_eq(&a, &b, "axpy");
    }
}

#[test]
fn chunked_kernels_bit_identical_to_scalar_reference_f64() {
    kernel_equivalence_for::<f64>(11);
}

#[test]
fn chunked_kernels_bit_identical_to_scalar_reference_f32() {
    kernel_equivalence_for::<f32>(12);
}

fn into_matches_with_for<T: Scalar>(y: &Matrix<T>, eta: T) {
    let mut ws = Workspace::new();
    let mut out = Matrix::zeros(0, 0);
    for algo in L1Algorithm::all() {
        let r = bilevel_l1inf_with(y, eta, *algo);
        bilevel_l1inf_into(y, eta, *algo, &mut ws, &mut out);
        assert_bits_eq(r.x.as_slice(), out.as_slice(), "into vs with (matrix)");
        assert_bits_eq(&r.thresholds, ws.thresholds(), "into vs with (thresholds)");
    }
}

#[test]
fn prop_into_matches_with_exactly() {
    forall::<MatrixAndRadius>(PropConfig { cases: 150, ..Default::default() }, |case| {
        let mut ws = Workspace::new();
        let mut out = Matrix::zeros(0, 0);
        let r = bilevel_l1inf_with(&case.y, case.eta, L1Algorithm::Condat);
        bilevel_l1inf_into(&case.y, case.eta, L1Algorithm::Condat, &mut ws, &mut out);
        for (a, b) in r.x.as_slice().iter().zip(out.as_slice().iter()) {
            if a.to_bits() != b.to_bits() {
                return Err(format!("matrix bits differ: {a} vs {b}"));
            }
        }
        for (a, b) in r.thresholds.iter().zip(ws.thresholds().iter()) {
            if a.to_bits() != b.to_bits() {
                return Err(format!("threshold bits differ: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn into_matches_with_on_edge_shapes() {
    // n=1, m=1, non-lane-multiple rows, and a column exactly at its
    // threshold (eta large enough that one column is untouched).
    let mut rng = Xoshiro256pp::seed_from_u64(77);
    for (n, m) in [(1, 1), (1, 9), (9, 1), (13, 7), (31, 33), (64, 5)] {
        let y64 = Matrix::<f64>::randn(n, m, &mut rng);
        for eta in [0.0, 0.3, 5.0, 1e6] {
            into_matches_with_for(&y64, eta);
            let y32: Matrix<f32> = y64.cast();
            into_matches_with_for(&y32, eta as f32);
        }
    }
}

#[test]
fn into_handles_columns_exactly_at_threshold() {
    // A constant-magnitude matrix makes every column norm equal, so the
    // inner projection puts thresholds exactly at (or symmetrically
    // below) the norms — the `û_j >= ‖y_j‖∞` copy branch is exercised in
    // both directions.
    let n = 12;
    let m = 8;
    let y = Matrix::<f64>::full(n, m, -1.5);
    // eta = m * 1.5 → inside the ball, all columns copied verbatim.
    into_matches_with_for(&y, 12.0);
    // eta tight → all columns clipped at the same threshold.
    into_matches_with_for(&y, 3.0);
}

#[test]
fn prop_pool_parallel_matches_sequential_exactly() {
    forall::<MatrixAndRadius>(PropConfig { cases: 80, ..Default::default() }, |case| {
        let seq = bilevel_l1inf_with(&case.y, case.eta, L1Algorithm::Condat);
        for threads in [2usize, 5] {
            let par = bilevel_l1inf_parallel(
                &case.y,
                case.eta,
                L1Algorithm::Condat,
                ParallelPolicy { threads, min_elems: 0 },
            );
            for (a, b) in seq.x.as_slice().iter().zip(par.x.as_slice().iter()) {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "threads={threads}: matrix bits differ: {a} vs {b}"
                    ));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Three-path SIMD conformance suite: scalar ref × portable chunked ×
// runtime-dispatched explicit SIMD, per kernel, per dtype, across
// lane-boundary lengths, with signed zeros / subnormals / at-threshold
// values injected.
// ---------------------------------------------------------------------

/// The lane-boundary lengths the conformance contract names.
fn conformance_lens() -> Vec<usize> {
    let l = kernels::LANES;
    vec![0, 1, l - 1, l, l + 1, 4 * l + 3]
}

/// Random values with special cases injected at the head: both zero
/// signs, values exactly at the 0.5 thresholds used below, and
/// subnormals.
fn conformance_vec<T: Scalar>(n: usize, seed: u64) -> Vec<T> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut v: Vec<T> = (0..n).map(|_| T::from_f64(rng.uniform(-3.0, 3.0))).collect();
    let sub = T::MIN_POSITIVE / T::from_f64(4.0);
    let specials =
        [T::ZERO, -T::ZERO, T::from_f64(0.5), T::from_f64(-0.5), sub, -sub, T::MIN_POSITIVE];
    for (k, s) in specials.into_iter().enumerate() {
        if k < n {
            v[k] = s;
        }
    }
    v
}

fn three_path_conformance_for<T: Scalar>(seed: u64) {
    for (k, n) in conformance_lens().into_iter().enumerate() {
        let v = conformance_vec::<T>(n, seed + k as u64);

        // Reductions: dispatched == portable == ref bitwise, always (the
        // explicit SIMD paths reproduce the lane decomposition exactly).
        let triples = [
            ("colmax", kernels::colmax(&v), kernels::colmax_portable(&v), kernels::colmax_ref(&v)),
            (
                "sum_abs",
                kernels::sum_abs(&v),
                kernels::sum_abs_portable(&v),
                kernels::sum_abs_ref(&v),
            ),
            ("sumsq", kernels::sumsq(&v), kernels::sumsq_portable(&v), kernels::sumsq_ref(&v)),
        ];
        for (what, d, p, r) in triples {
            let (db, pb, rb) = (d.to_f64().to_bits(), p.to_f64().to_bits(), r.to_f64().to_bits());
            assert_eq!(db, pb, "{what} dispatched vs portable, n={n}");
            assert_eq!(pb, rb, "{what} portable vs ref, n={n}");
        }

        // Clip: strict for c > 0 (including elements exactly at the
        // threshold), modulo zero sign at c == 0.
        for c in [T::ZERO, T::from_f64(0.5), T::from_f64(2.0)] {
            let mut d = vec![T::ZERO; n];
            let mut p = vec![T::ZERO; n];
            let mut r = vec![T::ZERO; n];
            kernels::clip_into(&v, c, &mut d);
            kernels::clip_into_portable(&v, c, &mut p);
            kernels::clip_into_ref(&v, c, &mut r);
            assert_bits_eq(&p, &r, "clip portable vs ref");
            if c > T::ZERO {
                assert_bits_eq(&d, &p, "clip dispatched vs portable");
            } else {
                assert_bits_eq_mod_zero_sign(&d, &p, "clip(c=0) dispatched vs portable");
            }
            let mut inplace = v.clone();
            kernels::clip_inplace(&mut inplace, c);
            assert_bits_eq(&inplace, &d, "clip_inplace vs clip_into");
        }

        // Soft-threshold: strict for tau > 0, modulo zero sign at 0.
        for tau in [T::ZERO, T::from_f64(0.5), T::from_f64(0.7)] {
            let mut d = v.clone();
            let mut p = v.clone();
            let mut r = v.clone();
            kernels::soft_threshold_inplace(&mut d, tau);
            kernels::soft_threshold_inplace_portable(&mut p, tau);
            kernels::soft_threshold_inplace_ref(&mut r, tau);
            assert_bits_eq(&p, &r, "soft portable vs ref");
            if tau > T::ZERO {
                assert_bits_eq(&d, &p, "soft dispatched vs portable");
            } else {
                assert_bits_eq_mod_zero_sign(&d, &p, "soft(tau=0) dispatched vs portable");
            }
        }

        // Scale and axpy: elementwise without FMA — strict always.
        let mut d = v.clone();
        let mut p = v.clone();
        let mut r = v.clone();
        kernels::scale_inplace(&mut d, T::from_f64(-0.37));
        kernels::scale_inplace_portable(&mut p, T::from_f64(-0.37));
        kernels::scale_inplace_ref(&mut r, T::from_f64(-0.37));
        assert_bits_eq(&d, &p, "scale dispatched vs portable");
        assert_bits_eq(&p, &r, "scale portable vs ref");

        let row = conformance_vec::<T>(n, (seed ^ 0xABCD) + k as u64);
        let mut d = v.clone();
        let mut p = v.clone();
        let mut r = v.clone();
        kernels::axpy(&mut d, T::from_f64(-0.83), &row);
        kernels::axpy_portable(&mut p, T::from_f64(-0.83), &row);
        kernels::axpy_ref(&mut r, T::from_f64(-0.83), &row);
        assert_bits_eq(&d, &p, "axpy dispatched vs portable");
        assert_bits_eq(&p, &r, "axpy portable vs ref");
    }
}

#[test]
fn three_path_conformance_f64() {
    three_path_conformance_for::<f64>(21);
}

#[test]
fn three_path_conformance_f32() {
    three_path_conformance_for::<f32>(22);
}

#[test]
fn dispatch_is_consistent_with_environment() {
    let forced =
        matches!(std::env::var("BILEVEL_FORCE_SCALAR"), Ok(v) if !v.is_empty() && v != "0");
    let isa = kernels::active_isa();
    if forced {
        assert_eq!(
            isa,
            kernels::Isa::Portable,
            "BILEVEL_FORCE_SCALAR must pin the portable path"
        );
    }
    #[cfg(target_arch = "x86_64")]
    if !forced && std::arch::is_x86_feature_detected!("avx2") {
        assert_eq!(isa, kernels::Isa::Avx2, "AVX2 detected but not dispatched");
    }
    #[cfg(target_arch = "aarch64")]
    if !forced && std::arch::is_aarch64_feature_detected!("neon") {
        assert_eq!(isa, kernels::Isa::Neon, "NEON detected but not dispatched");
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    assert_eq!(isa, kernels::Isa::Portable);
}

/// Calls the AVX2 table directly (not through the cached dispatcher), so
/// this coverage survives `BILEVEL_FORCE_SCALAR=1` runs too.
#[cfg(target_arch = "x86_64")]
#[test]
fn avx2_table_matches_portable_when_detected() {
    if !std::arch::is_x86_feature_detected!("avx2") {
        eprintln!("skipping: no AVX2 on this CPU");
        return;
    }
    let ops = &kernels::avx2::OPS;
    for (k, n) in conformance_lens().into_iter().enumerate() {
        let v64 = conformance_vec::<f64>(n, 31 + k as u64);
        let v32 = conformance_vec::<f32>(n, 33 + k as u64);

        assert_eq!((ops.colmax_f64)(&v64).to_bits(), kernels::colmax_portable(&v64).to_bits());
        assert_eq!((ops.colmax_f32)(&v32).to_bits(), kernels::colmax_portable(&v32).to_bits());
        assert_eq!((ops.sum_abs_f64)(&v64).to_bits(), kernels::sum_abs_portable(&v64).to_bits());
        assert_eq!((ops.sum_abs_f32)(&v32).to_bits(), kernels::sum_abs_portable(&v32).to_bits());
        assert_eq!((ops.sumsq_f64)(&v64).to_bits(), kernels::sumsq_portable(&v64).to_bits());
        assert_eq!((ops.sumsq_f32)(&v32).to_bits(), kernels::sumsq_portable(&v32).to_bits());

        let mut a = vec![0.0f64; n];
        let mut b = vec![0.0f64; n];
        (ops.clip_into_f64)(&v64, 0.5, &mut a);
        kernels::clip_into_portable(&v64, 0.5, &mut b);
        assert_bits_eq(&a, &b, "avx2 clip_into_f64");
        let mut a32 = vec![0.0f32; n];
        let mut b32 = vec![0.0f32; n];
        (ops.clip_into_f32)(&v32, 0.5, &mut a32);
        kernels::clip_into_portable(&v32, 0.5, &mut b32);
        assert_bits_eq(&a32, &b32, "avx2 clip_into_f32");

        let mut a = v64.clone();
        let mut b = v64.clone();
        (ops.clip_inplace_f64)(&mut a, 2.0);
        kernels::clip_inplace_portable(&mut b, 2.0);
        assert_bits_eq(&a, &b, "avx2 clip_inplace_f64");
        let mut a32 = v32.clone();
        let mut b32 = v32.clone();
        (ops.clip_inplace_f32)(&mut a32, 2.0);
        kernels::clip_inplace_portable(&mut b32, 2.0);
        assert_bits_eq(&a32, &b32, "avx2 clip_inplace_f32");

        let mut a = v64.clone();
        let mut b = v64.clone();
        (ops.soft_threshold_f64)(&mut a, 0.5);
        kernels::soft_threshold_inplace_portable(&mut b, 0.5);
        assert_bits_eq(&a, &b, "avx2 soft_f64");
        let mut a32 = v32.clone();
        let mut b32 = v32.clone();
        (ops.soft_threshold_f32)(&mut a32, 0.5);
        kernels::soft_threshold_inplace_portable(&mut b32, 0.5);
        assert_bits_eq(&a32, &b32, "avx2 soft_f32");

        let mut a = v64.clone();
        let mut b = v64.clone();
        (ops.scale_f64)(&mut a, -0.37);
        kernels::scale_inplace_portable(&mut b, -0.37);
        assert_bits_eq(&a, &b, "avx2 scale_f64");
        let mut a32 = v32.clone();
        let mut b32 = v32.clone();
        (ops.scale_f32)(&mut a32, -0.37);
        kernels::scale_inplace_portable(&mut b32, -0.37);
        assert_bits_eq(&a32, &b32, "avx2 scale_f32");

        let row64 = conformance_vec::<f64>(n, 35 + k as u64);
        let mut a = v64.clone();
        let mut b = v64.clone();
        (ops.axpy_f64)(&mut a, -0.83, &row64);
        kernels::axpy_portable(&mut b, -0.83, &row64);
        assert_bits_eq(&a, &b, "avx2 axpy_f64");
        let row32 = conformance_vec::<f32>(n, 37 + k as u64);
        let mut a32 = v32.clone();
        let mut b32 = v32.clone();
        (ops.axpy_f32)(&mut a32, -0.83, &row32);
        kernels::axpy_portable(&mut b32, -0.83, &row32);
        assert_bits_eq(&a32, &b32, "avx2 axpy_f32");

        // The documented zero-threshold corner, pinned to its AVX2 shape:
        // every clipped element comes out exactly +0.0.
        let mut z = v64.clone();
        (ops.clip_inplace_f64)(&mut z, 0.0);
        for (i, x) in z.iter().enumerate() {
            assert_eq!(x.to_bits(), 0.0f64.to_bits(), "avx2 clip(c=0) element {i} not +0.0");
        }
    }
}

/// NEON mirror of the AVX2 table test (compile-gated to aarch64).
#[cfg(target_arch = "aarch64")]
#[test]
fn neon_table_matches_portable_when_detected() {
    if !std::arch::is_aarch64_feature_detected!("neon") {
        eprintln!("skipping: no NEON on this CPU");
        return;
    }
    let ops = &kernels::neon::OPS;
    for (k, n) in conformance_lens().into_iter().enumerate() {
        let v64 = conformance_vec::<f64>(n, 41 + k as u64);
        let v32 = conformance_vec::<f32>(n, 43 + k as u64);

        assert_eq!((ops.colmax_f64)(&v64).to_bits(), kernels::colmax_portable(&v64).to_bits());
        assert_eq!((ops.colmax_f32)(&v32).to_bits(), kernels::colmax_portable(&v32).to_bits());
        assert_eq!((ops.sum_abs_f64)(&v64).to_bits(), kernels::sum_abs_portable(&v64).to_bits());
        assert_eq!((ops.sum_abs_f32)(&v32).to_bits(), kernels::sum_abs_portable(&v32).to_bits());
        assert_eq!((ops.sumsq_f64)(&v64).to_bits(), kernels::sumsq_portable(&v64).to_bits());
        assert_eq!((ops.sumsq_f32)(&v32).to_bits(), kernels::sumsq_portable(&v32).to_bits());

        let mut a = vec![0.0f64; n];
        let mut b = vec![0.0f64; n];
        (ops.clip_into_f64)(&v64, 0.5, &mut a);
        kernels::clip_into_portable(&v64, 0.5, &mut b);
        assert_bits_eq(&a, &b, "neon clip_into_f64");
        let mut a32 = vec![0.0f32; n];
        let mut b32 = vec![0.0f32; n];
        (ops.clip_into_f32)(&v32, 0.5, &mut a32);
        kernels::clip_into_portable(&v32, 0.5, &mut b32);
        assert_bits_eq(&a32, &b32, "neon clip_into_f32");

        let mut a = v64.clone();
        let mut b = v64.clone();
        (ops.soft_threshold_f64)(&mut a, 0.5);
        kernels::soft_threshold_inplace_portable(&mut b, 0.5);
        assert_bits_eq(&a, &b, "neon soft_f64");
        let mut a32 = v32.clone();
        let mut b32 = v32.clone();
        (ops.soft_threshold_f32)(&mut a32, 0.5);
        kernels::soft_threshold_inplace_portable(&mut b32, 0.5);
        assert_bits_eq(&a32, &b32, "neon soft_f32");

        let mut a = v64.clone();
        let mut b = v64.clone();
        (ops.scale_f64)(&mut a, -0.37);
        kernels::scale_inplace_portable(&mut b, -0.37);
        assert_bits_eq(&a, &b, "neon scale_f64");

        let row64 = conformance_vec::<f64>(n, 45 + k as u64);
        let mut a = v64.clone();
        let mut b = v64.clone();
        (ops.axpy_f64)(&mut a, -0.83, &row64);
        kernels::axpy_portable(&mut b, -0.83, &row64);
        assert_bits_eq(&a, &b, "neon axpy_f64");

        // NEON's zero-threshold shape: magnitude 0 with the input's sign
        // direction preserved (FMAX/FMIN order -0.0 < +0.0).
        let mut z = v64.clone();
        (ops.clip_inplace_f64)(&mut z, 0.0);
        for (i, (x, orig)) in z.iter().zip(v64.iter()).enumerate() {
            assert_eq!(*x, 0.0, "neon clip(c=0) element {i} not zero");
            assert_eq!(
                x.is_sign_negative(),
                orig.is_sign_negative(),
                "neon clip(c=0) element {i} lost its sign direction"
            );
        }
    }
}

#[test]
fn parallel_into_reuses_buffers_across_shapes() {
    let mut rng = Xoshiro256pp::seed_from_u64(88);
    let mut ws = Workspace::new();
    let mut out = Matrix::zeros(0, 0);
    for (n, m) in [(40, 200), (8, 64), (100, 30)] {
        let y = Matrix::<f64>::randn(n, m, &mut rng);
        bilevel_l1inf_parallel_into(
            &y,
            1.7,
            L1Algorithm::Condat,
            ParallelPolicy { threads: 4, min_elems: 0 },
            &mut ws,
            &mut out,
        );
        let seq = bilevel_l1inf_with(&y, 1.7, L1Algorithm::Condat);
        assert_bits_eq(seq.x.as_slice(), out.as_slice(), "parallel_into");
        assert_eq!(out.rows(), n);
        assert_eq!(out.cols(), m);
    }
}
