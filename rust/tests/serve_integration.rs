//! End-to-end tests of the serve subsystem: request/response correctness
//! against direct library calls (bit-identical), micro-batch coalescing,
//! threshold-cache hits (including replay equivalence for every bi-level
//! variant), and backpressure rejection at the queue high-water mark.

use std::time::Duration;

use bilevel_sparse::config::ServeConfig;
use bilevel_sparse::norms::l1inf_norm;
use bilevel_sparse::projection::bilevel::{bilevel, BilevelVariant};
use bilevel_sparse::projection::l1::L1Algorithm;
use bilevel_sparse::projection::ProjectionKind;
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::serve::{
    run_loadgen, Engine, LoadgenConfig, Payload, ProjectionRequest, SubmitError,
};
use bilevel_sparse::tensor::Matrix;

fn base_cfg() -> ServeConfig {
    ServeConfig {
        shards: 2,
        workers_per_shard: 1,
        queue_capacity: 64,
        max_batch: 8,
        min_fill: 1,
        max_wait_micros: 200,
        cache_capacity: 64,
        ..ServeConfig::default()
    }
}

fn f64_payload(p: &Payload) -> &Matrix<f64> {
    p.as_f64().expect("expected f64 payload")
}

#[test]
fn serve_results_bit_identical_to_library_calls() {
    let engine = Engine::start(&base_cfg()).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(100);
    let eta = 2.0;
    for kind in ProjectionKind::all() {
        let y = Matrix::<f64>::randn(40, 30, &mut rng);
        let resp = engine
            .submit_wait(ProjectionRequest::f64(*kind, eta, y.clone()))
            .unwrap();
        let direct = kind.apply(&y, eta);
        assert_eq!(
            f64_payload(&resp.payload).max_abs_diff(&direct),
            0.0,
            "{} serve result differs from library",
            kind.name()
        );
        assert_eq!(resp.kind, bilevel_sparse::serve::JobKind::Project(*kind));
        assert_eq!(resp.thresholds.is_some(), kind.bilevel_variant().is_some());
    }
    // identity kind round-trips too
    let y = Matrix::<f64>::randn(5, 5, &mut rng);
    let resp = engine
        .submit_wait(ProjectionRequest::f64(ProjectionKind::None, eta, y.clone()))
        .unwrap();
    assert_eq!(f64_payload(&resp.payload).max_abs_diff(&y), 0.0);
    engine.shutdown();
}

#[test]
fn serve_f32_requests_match_f32_library_calls() {
    let engine = Engine::start(&base_cfg()).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(101);
    let y: Matrix<f32> = Matrix::<f64>::randn(24, 18, &mut rng).cast();
    let resp = engine
        .submit_wait(ProjectionRequest::f32(ProjectionKind::BilevelL1Inf, 1.5, y.clone()))
        .unwrap();
    let direct = ProjectionKind::BilevelL1Inf.apply(&y, 1.5f32);
    let x = resp.payload.as_f32().expect("expected f32 payload");
    assert_eq!(x.max_abs_diff(&direct), 0.0);
    engine.shutdown();
}

#[test]
fn alternate_inner_solvers_are_threaded_through() {
    let engine = Engine::start(&base_cfg()).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(102);
    let y = Matrix::<f64>::randn(30, 20, &mut rng);
    for algo in L1Algorithm::all() {
        let resp = engine
            .submit_wait(
                ProjectionRequest::f64(ProjectionKind::BilevelL11, 3.0, y.clone())
                    .with_algo(*algo),
            )
            .unwrap();
        let direct = bilevel(&y, 3.0, BilevelVariant::L11, *algo);
        assert_eq!(
            f64_payload(&resp.payload).max_abs_diff(&direct.x),
            0.0,
            "inner algo {} not honoured",
            algo.name()
        );
    }
    engine.shutdown();
}

#[test]
fn micro_batching_coalesces_concurrent_same_key_requests() {
    // One shard, batch window long enough that 12 rapidly-submitted
    // same-key requests coalesce: the worker holds its first job while the
    // batch is below min_fill, then drains everything that arrived.
    let cfg = ServeConfig {
        shards: 1,
        workers_per_shard: 1,
        queue_capacity: 64,
        max_batch: 16,
        min_fill: 16,
        max_wait_micros: 200_000,
        cache_capacity: 0,
        ..ServeConfig::default()
    };
    let engine = Engine::start(&cfg).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(103);
    let eta = 1.0;
    let mut inputs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..12 {
        let y = Matrix::<f64>::randn(16, 12, &mut rng);
        inputs.push(y.clone());
        handles.push(
            engine
                .submit(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, eta, y))
                .unwrap(),
        );
    }
    let mut max_batch_seen = 0;
    for (h, y) in handles.into_iter().zip(inputs.iter()) {
        let resp = h.wait().expect("response");
        max_batch_seen = max_batch_seen.max(resp.batch_size);
        let direct = ProjectionKind::BilevelL1Inf.apply(y, eta);
        assert_eq!(f64_payload(&resp.payload).max_abs_diff(&direct), 0.0);
    }
    assert!(
        max_batch_seen >= 2,
        "expected some coalescing, saw max batch {max_batch_seen}"
    );
    let stats = engine.shutdown();
    assert_eq!(stats.completed(), 12);
    assert!(
        stats.mean_batch() > 1.0,
        "mean batch {} should exceed 1",
        stats.mean_batch()
    );
}

#[test]
fn threshold_cache_hits_and_replays_bit_identically() {
    let cfg = ServeConfig { shards: 1, ..base_cfg() };
    let engine = Engine::start(&cfg).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(104);
    for (kind, variant) in [
        (ProjectionKind::BilevelL1Inf, BilevelVariant::L1Inf),
        (ProjectionKind::BilevelL11, BilevelVariant::L11),
        (ProjectionKind::BilevelL12, BilevelVariant::L12),
    ] {
        let y = Matrix::<f64>::randn(32, 20, &mut rng);
        let eta = 1.25;
        let req = ProjectionRequest::f64(kind, eta, y.clone());
        let cold = engine.submit_wait(req.clone()).unwrap();
        assert!(!cold.cache_hit, "{}: first request must miss", kind.name());
        let warm = engine.submit_wait(req).unwrap();
        assert!(warm.cache_hit, "{}: repeat request must hit", kind.name());
        let direct = bilevel(&y, eta, variant, L1Algorithm::Condat);
        assert_eq!(f64_payload(&cold.payload).max_abs_diff(&direct.x), 0.0);
        assert_eq!(
            f64_payload(&warm.payload).max_abs_diff(&direct.x),
            0.0,
            "{}: cache replay must be bit-identical",
            kind.name()
        );
        assert_eq!(cold.thresholds, warm.thresholds);
        // a different radius is a different cache entry
        let other = engine
            .submit_wait(ProjectionRequest::f64(kind, eta * 0.5, y.clone()))
            .unwrap();
        assert!(!other.cache_hit);
    }
    assert!(engine.cache_len() > 0);
    let stats = engine.shutdown();
    assert_eq!(stats.cache_hits(), 3);
    assert!(stats.hit_rate() > 0.0);
}

#[test]
fn exact_kinds_bypass_the_cache() {
    let cfg = ServeConfig { shards: 1, ..base_cfg() };
    let engine = Engine::start(&cfg).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(105);
    let y = Matrix::<f64>::randn(20, 10, &mut rng);
    for _ in 0..2 {
        let resp = engine
            .submit_wait(ProjectionRequest::f64(ProjectionKind::ExactL1InfSsn, 2.0, y.clone()))
            .unwrap();
        assert!(!resp.cache_hit);
        assert!(resp.thresholds.is_none());
    }
    assert_eq!(engine.cache_len(), 0);
    let stats = engine.shutdown();
    assert_eq!(stats.cache_hits() + stats.cache_misses(), 0);
}

#[test]
fn backpressure_rejects_with_retry_after_at_high_water() {
    // A single shard whose worker is parked in a long batch-fill window on
    // key A; same-shaped key-B requests cannot join A's batch, so they pile
    // up in the bounded queue and overflow it deterministically.
    let cfg = ServeConfig {
        shards: 1,
        workers_per_shard: 1,
        queue_capacity: 2,
        max_batch: 64,
        min_fill: 64,
        max_wait_micros: 150_000,
        cache_capacity: 0,
        ..ServeConfig::default()
    };
    let engine = Engine::start(&cfg).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(106);
    let a = Matrix::<f64>::randn(8, 6, &mut rng);
    let first = engine
        .submit(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, a))
        .unwrap();
    // Different batch key (different shape): never drained into A's batch.
    let mut accepted = vec![first];
    let mut rejected = 0;
    for _ in 0..4 {
        let b = Matrix::<f64>::randn(6, 8, &mut rng);
        match engine.submit(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, b)) {
            Ok(h) => accepted.push(h),
            Err(SubmitError::Overloaded { shard, depth, retry_after }) => {
                rejected += 1;
                assert_eq!(shard, 0);
                assert_eq!(depth, 2);
                assert!(retry_after > Duration::ZERO);
            }
            Err(other) => panic!("unexpected submit error: {other}"),
        }
    }
    // Queue holds at most 2 + 1 in-flight: of 5 submissions at least 2
    // must have been shed.
    assert!(rejected >= 2, "expected >= 2 rejections, got {rejected}");
    // Accepted work still completes after the batch window expires.
    for h in accepted {
        assert!(h.wait().is_ok());
    }
    let stats = engine.shutdown();
    assert_eq!(stats.rejected(), rejected);
    assert_eq!(stats.completed() + stats.rejected(), 5);
}

#[test]
fn loadgen_sustains_mixed_workload_with_cache_hits() {
    let engine = Engine::start(&ServeConfig { shards: 2, ..base_cfg() }).unwrap();
    let cfg = LoadgenConfig {
        clients: 4,
        requests_per_client: 40,
        rows: 24,
        cols: 16,
        eta: 1.5,
        mix: vec![
            ProjectionKind::BilevelL1Inf,
            ProjectionKind::BilevelL11,
            ProjectionKind::BilevelL12,
            ProjectionKind::ExactL1InfSsn,
            ProjectionKind::None,
        ],
        pool: 4,
        f32_every: 4,
        seed: 9,
        ..LoadgenConfig::default()
    };
    let report = run_loadgen(&engine, &cfg);
    assert_eq!(report.completed, 160);
    assert_eq!(report.failed, 0);
    assert!(report.cache_hits > 0, "repeated-pool workload must hit the cache");
    assert!(report.throughput_rps() > 0.0);
    let stats = engine.shutdown();
    assert_eq!(stats.completed(), 160);
    assert!(stats.hit_rate() > 0.0);
    assert_eq!(stats.submitted(), 160);
}

#[test]
fn invalid_submissions_are_refused_without_side_effects() {
    let engine = Engine::start(&base_cfg()).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(107);
    let y = Matrix::<f64>::randn(4, 4, &mut rng);
    for bad_eta in [-0.5, f64::NAN, f64::INFINITY] {
        let err = engine
            .submit(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, bad_eta, y.clone()))
            .unwrap_err();
        assert!(matches!(err, SubmitError::Invalid(_)), "eta {bad_eta} accepted");
    }
    let err = engine
        .submit(ProjectionRequest::f64(
            ProjectionKind::BilevelL1Inf,
            1.0,
            Matrix::<f64>::zeros(0, 3),
        ))
        .unwrap_err();
    assert!(matches!(err, SubmitError::Invalid(_)));
    let stats = engine.shutdown();
    assert_eq!(stats.submitted(), 0);
    assert_eq!(stats.completed(), 0);
}

#[test]
fn served_projection_is_feasible() {
    // Sanity on the maths through the full engine path.
    let engine = Engine::start(&base_cfg()).unwrap();
    let mut rng = Xoshiro256pp::seed_from_u64(108);
    let y = Matrix::<f64>::randn(64, 48, &mut rng);
    let eta = l1inf_norm(&y) * 0.25;
    let resp = engine
        .submit_wait(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, eta, y))
        .unwrap();
    let norm = l1inf_norm(f64_payload(&resp.payload));
    assert!((norm - eta).abs() < 1e-9, "projection not tight: {norm} vs {eta}");
    engine.shutdown();
}
