//! Parser for `artifacts/manifest.txt` (the trivial `key=value` records
//! emitted by `python/compile/aot.py`; entries separated by `---`).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Result};

/// One artifact record.
#[derive(Clone, Debug, Default)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub preset: String,
    pub features: usize,
    pub hidden: usize,
    pub classes: usize,
    pub batch: usize,
    pub epoch_batches: usize,
    pub eval_batch: usize,
}

/// All artifacts, indexed by name.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: HashMap<String, ArtifactEntry>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("{}: {e} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = HashMap::new();
        let mut cur = ArtifactEntry::default();
        // Tracks whether `cur` holds any parsed fields, so a trailing
        // record without a closing `---` is flushed (and validated) at
        // EOF instead of silently dropped.
        let mut in_entry = false;
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if line == "---" {
                if cur.name.is_empty() || cur.file.is_empty() {
                    return Err(anyhow!("manifest line {}: incomplete entry", ln + 1));
                }
                entries.insert(cur.name.clone(), std::mem::take(&mut cur));
                in_entry = false;
                continue;
            }
            in_entry = true;
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("manifest line {}: expected key=value", ln + 1))?;
            let usize_v = || {
                v.parse::<usize>()
                    .map_err(|_| anyhow!("manifest line {}: bad number {v:?}", ln + 1))
            };
            match k {
                "artifact" => cur.name = v.to_string(),
                "file" => cur.file = v.to_string(),
                "kind" => cur.kind = v.to_string(),
                "preset" => cur.preset = v.to_string(),
                "features" => cur.features = usize_v()?,
                "hidden" => cur.hidden = usize_v()?,
                "classes" => cur.classes = usize_v()?,
                "batch" => cur.batch = usize_v()?,
                "epoch_batches" => cur.epoch_batches = usize_v()?,
                "eval_batch" => cur.eval_batch = usize_v()?,
                _ => {} // forward compatible
            }
        }
        if in_entry {
            // Separator-less trailing record: same validation as on `---`.
            if cur.name.is_empty() || cur.file.is_empty() {
                return Err(anyhow!("manifest: incomplete trailing entry (missing artifact/file)"));
            }
            entries.insert(cur.name.clone(), cur);
        }
        Ok(Self { entries })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// All artifacts of one preset, e.g. `synth`.
    pub fn preset(&self, preset: &str) -> Vec<&ArtifactEntry> {
        let mut v: Vec<&ArtifactEntry> =
            self.entries.values().filter(|e| e.preset == preset).collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact=tiny_train_step
file=tiny_train_step.hlo.txt
kind=train_step
preset=tiny
features=64
hidden=16
classes=2
batch=8
epoch_batches=4
eval_batch=16
---
artifact=tiny_eval
file=tiny_eval.hlo.txt
kind=eval
preset=tiny
features=64
hidden=16
classes=2
batch=8
epoch_batches=4
eval_batch=16
---
";

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 2);
        let e = m.get("tiny_train_step").unwrap();
        assert_eq!(e.features, 64);
        assert_eq!(e.batch, 8);
        assert_eq!(e.kind, "train_step");
        assert_eq!(m.preset("tiny").len(), 2);
        assert_eq!(m.names(), vec!["tiny_eval", "tiny_train_step"]);
    }

    #[test]
    fn unknown_keys_ignored() {
        let m = Manifest::parse("artifact=a\nfile=f\nfuture_key=zzz\n---\n").unwrap();
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn incomplete_entry_rejected() {
        assert!(Manifest::parse("artifact=a\n---\n").is_err());
        assert!(Manifest::parse("junk line\n").is_err());
    }

    #[test]
    fn empty_manifest_ok() {
        let m = Manifest::parse("").unwrap();
        assert!(m.is_empty());
    }

    #[test]
    fn trailing_entry_without_separator_is_kept() {
        // Regression: the final record used to be committed only on a
        // `---` line, so a manifest not ending with the separator silently
        // dropped its last artifact.
        let text = SAMPLE.trim_end_matches("---\n").trim_end_matches('\n');
        assert!(!text.ends_with("---"), "fixture must end mid-record");
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.len(), 2, "trailing record must be flushed at EOF");
        let e = m.get("tiny_eval").unwrap();
        assert_eq!((e.features, e.eval_batch, e.kind.as_str()), (64, 16, "eval"));
    }

    #[test]
    fn incomplete_trailing_entry_rejected() {
        // EOF flush applies the same name/file validation as `---`.
        assert!(Manifest::parse("artifact=a\nkind=eval").is_err());
        assert!(Manifest::parse("file=f.hlo.txt").is_err());
        // trailing blank lines after the last separator stay fine
        assert!(Manifest::parse("artifact=a\nfile=f\n---\n\n\n").is_ok());
    }
}
