//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `compile` → `execute`). Compiled executables are
//! cached per artifact name; each jax-lowered module returns ONE tuple
//! which we decompose into per-output literals.
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serialized protos use
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids.

mod manifest;

pub use manifest::{ArtifactEntry, Manifest};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// Cached PJRT runtime over one artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifacts directory (compiles lazily on first use).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact by manifest name.
    pub fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on literal inputs; returns the decomposed tuple
    /// outputs as host literals.
    ///
    /// NOTE: prefer [`Runtime::execute_args`] on any hot path — the
    /// underlying `c_lib::execute` **leaks the device buffers it creates
    /// from input literals** (~size-of-inputs per call; see EXPERIMENTS.md
    /// §Perf). This literal path is kept for tests and one-shot calls.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        execute_exe(&exe, inputs)
    }

    /// Leak-free execution: uploads host slices as self-owned device
    /// buffers (`buffer_from_host_buffer`), runs `execute_b`, decomposes
    /// the output tuple. The input buffers drop (and free) here.
    pub fn execute_args(&self, name: &str, args: &[HostArg]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let mut bufs = Vec::with_capacity(args.len());
        for (i, arg) in args.iter().enumerate() {
            let buf = match arg {
                HostArg::Tensor { data, dims } => {
                    let elems: usize = dims.iter().product();
                    if elems != data.len() {
                        return Err(anyhow!(
                            "{name} arg {i}: {} elems vs dims {:?}",
                            data.len(),
                            dims
                        ));
                    }
                    self.client
                        .buffer_from_host_buffer::<f32>(data, dims, None)
                        .map_err(|e| anyhow!("{name} arg {i} upload: {e:?}"))?
                }
                HostArg::Scalar(v) => self
                    .client
                    .buffer_from_host_buffer::<f32>(std::slice::from_ref(v), &[], None)
                    .map_err(|e| anyhow!("{name} arg {i} scalar upload: {e:?}"))?,
            };
            bufs.push(buf);
        }
        let out = exe.execute_b(&bufs).map_err(|e| anyhow!("{name} execute_b: {e:?}"))?;
        let mut lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{name} to_literal: {e:?}"))?;
        lit.decompose_tuple().map_err(|e| anyhow!("{name} decompose: {e:?}"))
    }
}

/// A host-side argument for [`Runtime::execute_args`]: borrowed f32 data
/// plus its dims (row-major), or a scalar.
pub enum HostArg<'a> {
    Tensor { data: &'a [f32], dims: &'a [usize] },
    Scalar(f32),
}

impl<'a> HostArg<'a> {
    pub fn tensor(data: &'a [f32], dims: &'a [usize]) -> Self {
        Self::Tensor { data, dims }
    }
}

/// Execute a compiled module (jax modules return one tuple output).
pub fn execute_exe(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let out = exe
        .execute::<xla::Literal>(inputs)
        .map_err(|e| anyhow!("execute: {e:?}"))?;
    let mut lit = out[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e:?}"))?;
    let parts = lit.decompose_tuple().map_err(|e| anyhow!("decompose: {e:?}"))?;
    Ok(parts)
}

// ---------------------------------------------------------- literal utils

/// Row-major f32 tensor → literal of the given dims.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let elems: i64 = dims.iter().product();
    if elems as usize != data.len() {
        return Err(anyhow!("literal_f32: {} elems vs dims {:?}", data.len(), dims));
    }
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// f32 scalar literal (shape `()`).
pub fn literal_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Literal → host f32 vector.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
}

/// Literal → single f32.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("scalar: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_f32_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = literal_f32(&data, &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn literal_f32_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0f32], &[2, 3]).is_err());
    }

    #[test]
    fn scalar_literals() {
        let lit = literal_scalar(2.5);
        assert_eq!(to_scalar_f32(&lit).unwrap(), 2.5);
        assert_eq!(lit.element_count(), 1);
    }

    #[test]
    fn missing_manifest_is_error() {
        assert!(Runtime::open("/nonexistent/dir").is_err());
    }
}
