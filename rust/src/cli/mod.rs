//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `bilevel <subcommand> [positional...] [--key value | --key=value | --flag]`.

use std::collections::{HashMap, HashSet};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: HashSet<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]). A leading
    /// non-option token becomes the subcommand; options-only invocations
    /// (the examples) leave it empty.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self, String> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        if it.peek().is_some_and(|first| !first.starts_with('-')) {
            args.subcommand = it.next().unwrap();
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    return Err("bare `--` not supported".into());
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|next| !next.starts_with("--")) {
                    args.options.insert(body.to_string(), it.next().unwrap());
                } else {
                    args.flags.insert(body.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.contains(name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{name}: invalid number {s:?}")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{name}: invalid integer {s:?}")),
        }
    }

    /// Comma-separated u64 list, e.g. `--seeds 1,2,3`.
    pub fn u64_list_or(&self, name: &str, default: &[u64]) -> Result<Vec<u64>, String> {
        match self.opt(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| p.trim().parse().map_err(|_| format!("--{name}: bad entry {p:?}")))
                .collect(),
        }
    }
}

pub const USAGE: &str = "\
bilevel — linear-time bi-level l1,inf projection & sparse supervised autoencoders
(reproduction of Barlaud, Perez, Marmorat 2024)

USAGE:
  bilevel <COMMAND> [OPTIONS]

COMMANDS:
  project      project a random matrix, print norms/sparsity/timing
               --rows N --cols M --eta E --method <name> [--seed S] [--algo condat]
               [--threads N] [--config file.toml] reads defaults from the
               file's [projection] section; --method multilevel takes a
               root->leaf tree spec --levels \"l1/l2:8/linf\" (a level is
               <norm>[:group] with norm l1|l2|linf; the last level is the
               leaf) and projects the whole tree bottom-up
  train        train the sparse SAE end to end (needs `make artifacts`)
               --dataset synth64|synth16|hif2|tiny --projection <name> --eta E
               [--backend native|pallas] [--epochs1 N] [--epochs2 N] [--lr F]
               [--alpha F] [--seeds 1,2,3] [--config file.toml]
               model lifecycle: [--checkpoint-every N] [--checkpoint-dir D]
               [--resume model.ckpt] [--export model.ckpt] [--export-dense]
               (a resumed run continues the interrupted trajectory exactly)
  experiment   regenerate a paper table/figure (fig1..fig9, table1..table4,
               sparse, family, all)
               bilevel experiment fig1 [--quick] [--seeds 1,2,3]
  artifacts    list the AOT artifacts in the manifest [--dir artifacts]
  bench        run the in-process benchmark suites; `bench kernels`
               measures the SIMD kernel layer vs the scalar baseline and
               the pool vs sequential crossover, prints the §Perf table,
               and records BENCH_kernels.json for the perf trajectory
               bilevel bench kernels [--quick] [--out BENCH_kernels.json]
               `bench sparse` measures dense vs compacted structured-sparse
               encode across sparsity levels (f32/f64), verifies bitwise
               agreement, and records BENCH_sparse.json
               bilevel bench sparse [--quick] [--out BENCH_sparse.json]
               `bench projection-family` times every flat projection kind
               (f32/f64) plus the multilevel tree's depth-vs-threads
               speedup curve and records BENCH_projection_family.json
               bilevel bench projection-family [--quick]
               [--out BENCH_projection_family.json]
               `bench compare` is the perf-regression gate: a fresh quick
               run diffed against the committed snapshots; exits nonzero
               when any overlapping row regresses beyond the tolerance
               bilevel bench compare [--tolerance 2.0] [--min-ms 0.02]
               [--kernels BENCH_kernels.json] [--sparse BENCH_sparse.json]
               [--projection-family BENCH_projection_family.json]
               env: BILEVEL_FORCE_SCALAR=1 pins the portable kernel path
               (no AVX2/NEON dispatch); BILEVEL_MIN_ELEMS=N overrides the
               pool-vs-sequential crossover threshold
  sparsify     project a synthetic SAE's W1 with BP1,inf, derive the
               support plan, compact the model, verify sparse encode ==
               dense encode bitwise, and time both (no artifacts needed)
               [--features N] [--hidden H] [--batch B] [--eta E]
               [--seed S] [--reps R]
  export       write a versioned, checksummed model checkpoint
               --out model.ckpt [--dense] plus either --synthetic
               [--features N] [--hidden H] [--eta E] [--seed S]
               (artifact-free: init -> project -> plan -> compact) or the
               `train` flags for a single-seed trained export
  import       load + fully validate a checkpoint (checksum, structure)
               and print its contents; --verify re-derives the compact
               tensors and exercises both encoder dtypes
               bilevel import model.ckpt [--verify]
  inspect      dump a checkpoint's fixed header without reading the
               payload (format version, dtype, dims, seed, sections)
               bilevel inspect model.ckpt
  serve        start the projection service engine (sharded workers,
               micro-batching, LRU threshold cache) and validate it with a
               short in-process smoke workload; prints per-shard stats
               [--config configs/serve.toml] [--shards N]
               [--workers-per-shard W] [--queue N] [--batch N]
               [--min-fill N] [--wait-us U] [--cache N] [--clients C]
               [--requests N] [--rows N] [--cols M] [--eta E] [--pool P]
               [--f32-every K] [--mix k1,k2,...] [--seed S]
               [--model model.ckpt] [--model-dtype f32|f64] loads the
               checkpoint into the encoder registry and proves one served
               SparseEncode == the in-memory encoder bit-for-bit
               network mode: --listen IP:PORT puts the dependency-free
               HTTP/1.1 front-end on the engine (POST /v1/project,
               POST /v1/encode/{model}, GET /v1/stats|/v1/models|/healthz,
               GET /v1/events SSE, POST /v1/drain for graceful drain;
               per-client quotas from [serve.http]); --addr-file F writes
               the resolved address (useful with --listen 127.0.0.1:0);
               a [fault] config section (or --faults/--fault-seed) arms
               the seeded fault-injection layer for chaos testing
  loadgen      closed-loop load generator against an in-process engine:
               sustains a mixed-kind workload, honours backpressure
               retry-after with jittered capped exponential backoff
               ([--retry-budget N] [--backoff-cap-ms MS]), reports client
               latency/throughput (mean + p50/p99/p999) + engine-side
               shard counters (same options as serve, bigger defaults);
               --connect IP:PORT drives a `serve --listen` server over
               real sockets instead, obeying HTTP 429 Retry-After;
               --chaos also retries 500/503 recovery errors, injects
               client-side slow reads from the fault plan, and counts
               redials separately from backpressure retries
  chaos        deterministic fault-injection drill, one process: install
               the seeded fault plan (--faults \"site:spec;...\"
               [--fault-seed S], or the [fault] section of --config, or a
               built-in default), serve over a real socket under the
               chaos loadgen, drain, then corrupt the newest rolling
               checkpoint on disk and prove bit-exact recovery from the
               prior snapshot; exits nonzero if any request is lost, a
               worker panic goes unrespawned, or recovery diverges
               sites: persist.short_write|short_read|torn_rename|
               checksum_flip, worker.panic|stall, conn.reset|slow_read
               spec keys: p=F every=N after=N limit=N param=N
  audit        repo-aware static analysis over this repository's own
               sources: a lightweight Rust lexer (strings/comments
               stripped so rules cannot misfire on literals) feeding a
               rule engine — SAFETY-comment coverage for every unsafe,
               the unsafe file allowlist, no .lock().unwrap() outside
               tests, Cargo.toml target registration (autotests=false
               means an unregistered suite never runs), banned macros
               (todo!/unimplemented!/dbg!), and per-module
               deny(clippy::all) pinning; prints file:line findings and
               exits nonzero on any [--root DIR] (the same rules gate
               `cargo test --test audit_integration`)
  help         print this help

PROJECTION METHODS:
  bilevel-l1inf (Alg.1) | bilevel-l11 (Alg.2) | bilevel-l12 (Alg.3)
  l1inf-ssn (Chu et al.) | l1inf-newton (Chau et al.) | l1inf-quattoni
  l21 (row-wise l2 onto an l1 budget) | linf1-newton (per-column dual
  Newton, Chau-Wohlberg-Rodriguez) | none (identity baseline)
  multilevel (--levels tree spec; depth-2 l1/linf == bilevel-l1inf
  bit-for-bit)
  note: the bare alias \"newton\" is deprecated — it still resolves to
  l1inf-newton (the exact l1,inf Newton), NOT linf1-newton; spell out
  the full name to disambiguate
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = parse(&["train", "--eta", "0.5", "--quick", "--dataset=hif2"]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.f64_or("eta", 0.0).unwrap(), 0.5);
        assert!(a.flag("quick"));
        assert_eq!(a.str_or("dataset", ""), "hif2");
    }

    #[test]
    fn positional_arguments() {
        let a = parse(&["experiment", "fig1", "--quick"]);
        assert_eq!(a.positional, vec!["fig1"]);
        assert!(a.flag("quick"));
    }

    #[test]
    fn seed_lists() {
        let a = parse(&["train", "--seeds", "1,2,3"]);
        assert_eq!(a.u64_list_or("seeds", &[9]).unwrap(), vec![1, 2, 3]);
        let a = parse(&["train"]);
        assert_eq!(a.u64_list_or("seeds", &[9]).unwrap(), vec![9]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--quick", "--verbose"]);
        assert!(a.flag("quick") && a.flag("verbose"));
    }

    #[test]
    fn errors_on_bad_values() {
        let a = parse(&["x", "--eta", "abc"]);
        assert!(a.f64_or("eta", 0.0).is_err());
    }

    #[test]
    fn options_only_invocation_has_empty_subcommand() {
        let a = parse(&["--preset", "tiny", "--quick"]);
        assert_eq!(a.subcommand, "");
        assert_eq!(a.str_or("preset", ""), "tiny");
        assert!(a.flag("quick"));
    }
}
