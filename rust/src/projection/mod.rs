//! Projection operators.
//!
//! * [`l1`] — four algorithms for the ℓ1-ball / simplex projection (sort,
//!   Michelot, Condat, bucket-filter). These are the inner solvers of every
//!   bi-level method and the O(m) piece of the paper's complexity claim.
//! * [`linf`], [`l2`] — the trivial column projections (clip / rescale).
//! * [`bilevel`] — **the paper's contribution**: `BP¹,∞` (Alg. 1), `BP¹,¹`
//!   (Alg. 2), `BP¹,²` (Alg. 3), all O(nm).
//! * [`l1inf`] — exact ℓ1,∞-ball projections the paper benchmarks against:
//!   Quattoni et al. 2009 (sort + breakpoint merge, O(nm log nm)), Chau et
//!   al. 2019 (Newton root search), Chu et al. 2020 (semismooth Newton, the
//!   paper's main comparator), plus a slow bisection golden reference.
//! * [`l21`], [`linf1`] — the rest of the mixed-norm ball family: the
//!   row-group-lasso ℓ2,1 ball and the dual ℓ∞,1 ball (per-column Newton
//!   root search, Chau–Wohlberg–Rodriguez 2019).
//! * [`multilevel`] — recursive projection trees generalizing the bi-level
//!   operators to arbitrary depth (sequel paper, arXiv 2405.02086); the
//!   depth-2 `l1/linf` tree is bit-identical to [`bilevel`].

pub mod bilevel;
pub mod grouped;
pub mod l1;
pub mod l1inf;
pub mod l2;
pub mod l21;
pub mod linf;
pub mod linf1;
pub mod multilevel;

use crate::scalar::Scalar;
use crate::tensor::Matrix;

/// A matrix-ball projection operator, the common interface the trainer and
/// the benchmark harness dispatch over.
pub trait MatrixProjection<T: Scalar>: Send + Sync {
    /// Human-readable identifier (used in CSV headers and CLI).
    fn name(&self) -> &'static str;
    /// Project `y` onto the ball of radius `eta`.
    fn project(&self, y: &Matrix<T>, eta: T) -> Matrix<T>;
    /// The norm this operator projects onto, evaluated at `y` (used by the
    /// identity experiments to pair operator ↔ norm).
    fn norm(&self, y: &Matrix<T>) -> T;
}

/// Enumeration of all projection operators exposed by the CLI / config.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ProjectionKind {
    /// Bi-level ℓ1,∞ (paper Alg. 1) — the contribution.
    BilevelL1Inf,
    /// Bi-level ℓ1,1 (paper Alg. 2).
    BilevelL11,
    /// Bi-level ℓ1,2 (paper Alg. 3).
    BilevelL12,
    /// Exact ℓ1,∞, Quattoni et al. 2009.
    ExactL1InfQuattoni,
    /// Exact ℓ1,∞, Chau et al. 2019 Newton root search.
    ExactL1InfNewton,
    /// Exact ℓ1,∞, Chu et al. 2020 semismooth Newton.
    ExactL1InfSsn,
    /// ℓ2,1 ball (row-wise ℓ2 norms onto an ℓ1 budget — group lasso over
    /// rows).
    L21,
    /// ℓ∞,1 ball via per-column Newton root search on the dual
    /// (Chau–Wohlberg–Rodriguez 2019, arXiv 1806.10041).
    Linf1Newton,
    /// No projection (baseline rows of Tables II–IV).
    None,
}

impl ProjectionKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "bilevel-l1inf" | "bilevel_l1inf" | "bilevel" | "bp1inf" => Some(Self::BilevelL1Inf),
            "bilevel-l11" | "bilevel_l11" | "bp11" => Some(Self::BilevelL11),
            "bilevel-l12" | "bilevel_l12" | "bp12" => Some(Self::BilevelL12),
            "l1inf-quattoni" | "quattoni" => Some(Self::ExactL1InfQuattoni),
            // Bare "newton" predates the ℓ∞,1 Newton kind and stays an
            // alias of the exact ℓ1,∞ solver for compatibility (deprecated
            // — see the CLI help); the two Newton methods are unambiguous
            // under their "l1inf-newton" / "linf1-newton" names.
            "l1inf-newton" | "chau" | "newton" => Some(Self::ExactL1InfNewton),
            "l1inf" | "l1inf-ssn" | "chu" | "ssn" => Some(Self::ExactL1InfSsn),
            "l21" | "l2,1" | "l21-ball" => Some(Self::L21),
            "linf1-newton" | "linf1" | "linf,1" => Some(Self::Linf1Newton),
            "none" | "baseline" => Some(Self::None),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::BilevelL1Inf => "bilevel-l1inf",
            Self::BilevelL11 => "bilevel-l11",
            Self::BilevelL12 => "bilevel-l12",
            Self::ExactL1InfQuattoni => "l1inf-quattoni",
            Self::ExactL1InfNewton => "l1inf-newton",
            Self::ExactL1InfSsn => "l1inf-ssn",
            Self::L21 => "l21",
            Self::Linf1Newton => "linf1-newton",
            Self::None => "none",
        }
    }

    /// The bi-level variant behind this kind, if it is one of the paper's
    /// bi-level projections (the kinds whose thresholds the serve cache can
    /// replay).
    pub fn bilevel_variant(&self) -> Option<bilevel::BilevelVariant> {
        match self {
            Self::BilevelL1Inf => Some(bilevel::BilevelVariant::L1Inf),
            Self::BilevelL11 => Some(bilevel::BilevelVariant::L11),
            Self::BilevelL12 => Some(bilevel::BilevelVariant::L12),
            _ => None,
        }
    }

    /// Apply this projection to a matrix. `None` is the identity.
    pub fn apply<T: Scalar>(&self, y: &Matrix<T>, eta: T) -> Matrix<T> {
        self.apply_with(y, eta, l1::L1Algorithm::Condat)
    }

    /// [`ProjectionKind::apply`] with an explicit inner ℓ1 solver for the
    /// bi-level kinds (the exact ℓ1,∞ methods have no inner ℓ1 step and
    /// ignore `algo`).
    pub fn apply_with<T: Scalar>(
        &self,
        y: &Matrix<T>,
        eta: T,
        algo: l1::L1Algorithm,
    ) -> Matrix<T> {
        match self {
            Self::BilevelL1Inf => bilevel::bilevel_l1inf_with(y, eta, algo).x,
            Self::BilevelL11 => bilevel::bilevel_l11_with(y, eta, algo).x,
            Self::BilevelL12 => bilevel::bilevel_l12_with(y, eta, algo).x,
            Self::ExactL1InfQuattoni => {
                l1inf::project_l1inf(y, eta, l1inf::L1InfAlgorithm::Quattoni)
            }
            Self::ExactL1InfNewton => {
                l1inf::project_l1inf(y, eta, l1inf::L1InfAlgorithm::Newton)
            }
            Self::ExactL1InfSsn => l1inf::project_l1inf(y, eta, l1inf::L1InfAlgorithm::Ssn),
            Self::L21 => l21::project_l21_with(y, eta, algo),
            Self::Linf1Newton => linf1::project_linf1(y, eta),
            Self::None => y.clone(),
        }
    }

    /// The norm matched to this projection (for identity experiments),
    /// evaluated at `y`. `None` — the radius-free identity baseline —
    /// projects onto no ball and therefore has no matched norm.
    pub fn matched_norm<T: Scalar>(&self, y: &Matrix<T>) -> Option<T> {
        use crate::norms::*;
        match self {
            Self::BilevelL1Inf | Self::ExactL1InfQuattoni | Self::ExactL1InfNewton
            | Self::ExactL1InfSsn => Some(l1inf_norm(y)),
            Self::BilevelL11 => Some(l11_norm(y)),
            Self::BilevelL12 => Some(l12_norm(y)),
            Self::L21 => Some(l21_norm(y)),
            Self::Linf1Newton => Some(linf1_norm(y)),
            Self::None => Option::None,
        }
    }

    pub fn all() -> &'static [ProjectionKind] {
        &[
            Self::BilevelL1Inf,
            Self::BilevelL11,
            Self::BilevelL12,
            Self::ExactL1InfQuattoni,
            Self::ExactL1InfNewton,
            Self::ExactL1InfSsn,
            Self::L21,
            Self::Linf1Newton,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::norms::l1inf_norm;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn parse_roundtrip_is_exhaustive_over_all_kinds() {
        // `all()` lists every real projection; `None` round-trips too.
        // Names must be mutually unique so future kinds can't shadow each
        // other the way a bare "newton" alias would have.
        let mut seen = std::collections::HashSet::new();
        for kind in ProjectionKind::all() {
            assert_eq!(ProjectionKind::parse(kind.name()), Some(*kind));
            assert!(seen.insert(kind.name()), "duplicate name {}", kind.name());
        }
        assert_eq!(ProjectionKind::parse("none"), Some(ProjectionKind::None));
        assert_eq!(ProjectionKind::parse(ProjectionKind::None.name()), Some(ProjectionKind::None));
        assert_eq!(ProjectionKind::parse("nope"), None);
        assert_eq!(ProjectionKind::parse("baseline"), Some(ProjectionKind::None));
    }

    #[test]
    fn newton_aliases_stay_unambiguous() {
        // The deprecated bare alias keeps meaning the exact ℓ1,∞ solver;
        // both Newton methods stay reachable under their full names.
        assert_eq!(ProjectionKind::parse("newton"), Some(ProjectionKind::ExactL1InfNewton));
        assert_eq!(ProjectionKind::parse("l1inf-newton"), Some(ProjectionKind::ExactL1InfNewton));
        assert_eq!(ProjectionKind::parse("linf1-newton"), Some(ProjectionKind::Linf1Newton));
        assert_eq!(ProjectionKind::parse("linf1"), Some(ProjectionKind::Linf1Newton));
        assert_eq!(ProjectionKind::parse("l21"), Some(ProjectionKind::L21));
    }

    #[test]
    fn apply_dispatches_and_is_feasible() {
        let mut rng = Xoshiro256pp::seed_from_u64(123);
        let y = crate::tensor::Matrix::<f64>::randn(20, 10, &mut rng);
        let eta = 2.5;
        for kind in ProjectionKind::all() {
            let x = kind.apply(&y, eta);
            if kind.name().contains("l1inf") || kind.name().contains("bilevel-l1inf") {
                assert!(
                    l1inf_norm(&x) <= eta + 1e-8,
                    "{} violates feasibility: {}",
                    kind.name(),
                    l1inf_norm(&x)
                );
            }
            // Every real kind projects into its own matched-norm ball.
            let after = kind.matched_norm(&x).expect("all() kinds have a matched norm");
            assert!(after <= eta + 1e-8, "{}: matched norm {after} > {eta}", kind.name());
        }
    }

    #[test]
    fn matched_norm_is_none_only_for_the_identity_baseline() {
        let mut rng = Xoshiro256pp::seed_from_u64(126);
        let y = crate::tensor::Matrix::<f64>::randn(6, 4, &mut rng);
        assert_eq!(ProjectionKind::None.matched_norm(&y), Option::None);
        for kind in ProjectionKind::all() {
            assert!(kind.matched_norm(&y).is_some(), "{}", kind.name());
        }
    }

    #[test]
    fn apply_with_threads_inner_algorithm() {
        let mut rng = Xoshiro256pp::seed_from_u64(125);
        let y = crate::tensor::Matrix::<f64>::randn(20, 10, &mut rng);
        for kind in ProjectionKind::all() {
            let base = kind.apply(&y, 2.0);
            for algo in l1::L1Algorithm::all() {
                let x = kind.apply_with(&y, 2.0, *algo);
                assert!(
                    base.max_abs_diff(&x) < 1e-8,
                    "{} with inner {} diverges from default",
                    kind.name(),
                    algo.name()
                );
            }
        }
    }

    #[test]
    fn bilevel_variant_mapping() {
        assert_eq!(
            ProjectionKind::BilevelL1Inf.bilevel_variant(),
            Some(bilevel::BilevelVariant::L1Inf)
        );
        assert_eq!(
            ProjectionKind::BilevelL11.bilevel_variant(),
            Some(bilevel::BilevelVariant::L11)
        );
        assert_eq!(
            ProjectionKind::BilevelL12.bilevel_variant(),
            Some(bilevel::BilevelVariant::L12)
        );
        assert_eq!(ProjectionKind::ExactL1InfSsn.bilevel_variant(), None);
        // The new flat kinds are not bi-level: the serve threshold cache
        // must bypass them, never replay them.
        assert_eq!(ProjectionKind::L21.bilevel_variant(), None);
        assert_eq!(ProjectionKind::Linf1Newton.bilevel_variant(), None);
        assert_eq!(ProjectionKind::None.bilevel_variant(), None);
    }

    #[test]
    fn none_is_identity() {
        let mut rng = Xoshiro256pp::seed_from_u64(124);
        let y = crate::tensor::Matrix::<f64>::randn(5, 5, &mut rng);
        assert_eq!(ProjectionKind::None.apply(&y, 1.0), y);
    }
}
