//! Semismooth-Newton exact ℓ1,∞ projection — Rust port of the approach of
//! Chu, Zhang, Sun, Tao, *“Semismooth Newton algorithm for efficient
//! projections onto ℓ1,∞-norm ball”*, ICML 2020 [25] — the fastest exact
//! method and the paper's head-to-head comparator in Fig. 1.
//!
//! Unlike [`super::quattoni`]/[`super::newton`] there is **no pre-sorting**:
//! the outer semismooth Newton iterates on the dual scalar `θ` and each
//! evaluation of `μ_j(θ)` runs a per-column active-set (Michelot-style)
//! fixed-point — a generalized-Jacobian step on the nonsmooth per-column
//! optimality system. Cost is O(nm) per outer iteration with a small
//! iteration count in practice, which is what makes the method fast — and
//! what Fig. 1 of the paper contrasts with the one-shot O(nm) of `BP¹,∞`.
//!
//! Port notes (C++ → Rust): the reference implementation's column scan
//! fuses the active-set refinement over a flat array; we keep that
//! structure (`solve_column` over contiguous column slices of the
//! column-major [`Matrix`]), hoist all allocations out of the outer loop,
//! and preserve the monotone full-set warm start that guarantees finite
//! termination of the inner fixed point.

use crate::scalar::Scalar;
use crate::tensor::Matrix;

const MAX_OUTER: usize = 100;
const MAX_INNER: usize = 64;
/// Joint-iteration cap before falling back to the (guaranteed) nested
/// solver; generously above the ~10–20 iterations seen in practice.
const MAX_JOINT: usize = 60;

/// Solve for `(μ, θ)` with `Σ_j μ_j(θ) = eta`; `0 < eta < ‖Y‖₁,∞`.
///
/// **Joint semismooth iteration** (the structure of Chu et al.'s method,
/// and the §Perf optimization over the naive nested version): instead of
/// solving every per-column subproblem to convergence at each trial `θ`,
/// one generalized-Jacobian update is applied to *all* variables per
/// sweep — each column takes a single active-set refinement
/// `μ_j ← (Σ_{i∈A_j}|a_i| − θ)/|A_j|`, then `θ` takes its Newton step from
/// the current counts. One O(nm) pass per iteration, ~10–20 iterations on
/// gaussian workloads (vs ~40 passes × outer iterations for the nested
/// variant). Falls back to the provably-convergent nested solver if the
/// joint iteration has not settled after [`MAX_JOINT`] sweeps.
pub fn solve<T: Scalar>(y: &Matrix<T>, eta: T) -> (Vec<T>, T) {
    let m = y.cols();
    let mut mu = vec![T::ZERO; m];
    let mut dead = vec![false; m];

    // Pre-compute column totals (detects dead columns in O(1) later) and
    // initialise μ at the full-active-set level for θ = 0.
    let mut totals = vec![T::ZERO; m];
    for (j, col) in y.columns().enumerate() {
        let mut sum = T::ZERO;
        let mut mx = T::ZERO;
        for &x in col {
            let a = x.abs();
            sum += a;
            mx = mx.max_s(a);
        }
        totals[j] = sum;
        mu[j] = mx;
    }

    let mut theta = T::ZERO;
    let tol = T::EPSILON * eta.max_s(T::ONE) * T::from_f64(64.0);

    let mut converged = false;
    let mut prev_gap = T::INFINITY;
    for _ in 0..MAX_JOINT {
        let mut s = T::ZERO;
        let mut d = T::ZERO;
        for (j, col) in y.columns().enumerate() {
            if dead[j] {
                continue;
            }
            if totals[j] <= theta {
                dead[j] = true;
                mu[j] = T::ZERO;
                continue;
            }
            // One active-set refinement at the current (μ_j, θ).
            let mut sum = T::ZERO;
            let mut cnt = 0usize;
            for &x in col {
                let a = x.abs();
                if a > mu[j] {
                    sum += a;
                    cnt += 1;
                }
            }
            if cnt == 0 {
                // μ_j sits at/above the column max (θ still ~0 for this
                // column): re-seed from the full set.
                sum = totals[j];
                cnt = col.len();
            }
            let next = (sum - theta) / T::from_usize(cnt);
            mu[j] = next.max_s(T::ZERO);
            s += mu[j];
            if mu[j] > T::ZERO {
                d += T::ONE / T::from_usize(cnt);
            }
        }
        let gap = s - eta;
        if gap.abs() <= tol {
            converged = true;
            break;
        }
        if d > T::ZERO {
            theta = (theta + gap / d).max_s(T::ZERO);
        }
        // Track stagnation: the joint iteration contracts |gap| rapidly;
        // if it stops improving, bail to the nested solver.
        if gap.abs() >= prev_gap && gap.abs() > tol * T::from_f64(1e3) {
            break;
        }
        prev_gap = gap.abs();
    }

    let _ = converged;
    // Finish with exact Newton warm-started at the joint iteration's θ —
    // typically 1–3 outer iterations from here.
    solve_nested_from(y, eta, theta)
}

/// The original nested solver from θ = 0 (used in cross-checking tests).
pub fn solve_nested<T: Scalar>(y: &Matrix<T>, eta: T) -> (Vec<T>, T) {
    solve_nested_from(y, eta, T::ZERO)
}

/// Nested solver from an arbitrary starting θ: per-column subproblems to
/// convergence at each trial θ, bidirectional Newton on θ. For the convex
/// piecewise-linear `S(θ)`, a step from the right of the root lands at or
/// left of it, after which convergence is monotone and finite.
pub fn solve_nested_from<T: Scalar>(y: &Matrix<T>, eta: T, theta0: T) -> (Vec<T>, T) {
    let m = y.cols();
    let mut mu = vec![T::ZERO; m];

    let mut theta = theta0.max_s(T::ZERO);
    let tol = T::EPSILON * eta.max_s(T::ONE) * T::from_f64(64.0);

    for _ in 0..MAX_OUTER {
        // Evaluate μ_j(θ) and active counts for every column.
        let mut s = T::ZERO;
        let mut d = T::ZERO;
        for (j, col) in y.columns().enumerate() {
            let (m_j, k_j) = solve_column(col, theta);
            mu[j] = m_j;
            s += m_j;
            if k_j > 0 && m_j > T::ZERO {
                d += T::ONE / T::from_usize(k_j);
            }
        }
        let gap = s - eta;
        if gap.abs() <= tol || d <= T::ZERO {
            break;
        }
        let step = gap / d; // generalized-Jacobian (semismooth Newton) step
        let next = (theta + step).max_s(T::ZERO);
        if (next - theta).abs() <= T::EPSILON * theta.max_s(T::ONE) {
            break;
        }
        theta = next;
    }
    (mu, theta)
}

/// Per-column subproblem: find `μ ≥ 0` with `Σ_i max(|a_i| − μ, 0) = θ`
/// (or `μ = 0` when `‖a‖₁ ≤ θ`), plus the active count `|{i : |a_i| > μ}|`.
///
/// Active-set fixed point from the full set: `μ ← (Σ_{i∈A} |a_i| − θ)/|A|`,
/// `A ← {i : |a_i| > μ}`. The waterline only rises, the set only shrinks ⇒
/// finite convergence (Michelot's argument).
#[inline]
pub(crate) fn solve_column<T: Scalar>(col: &[T], theta: T) -> (T, usize) {
    if theta <= T::ZERO {
        // μ = max |a_i|, one active entry (generic position).
        let mx = col.iter().fold(T::ZERO, |m, &x| m.max_s(x.abs()));
        return (mx, usize::from(mx > T::ZERO));
    }
    // Full-set initialisation.
    let mut sum = T::ZERO;
    let mut cnt = 0usize;
    for &x in col {
        let a = x.abs();
        if a > T::ZERO {
            sum += a;
            cnt += 1;
        }
    }
    if cnt == 0 || sum <= theta {
        return (T::ZERO, 0); // dead column
    }
    let mut mu = (sum - theta) / T::from_usize(cnt);
    for _ in 0..MAX_INNER {
        let mut new_sum = T::ZERO;
        let mut new_cnt = 0usize;
        for &x in col {
            let a = x.abs();
            if a > mu {
                new_sum += a;
                new_cnt += 1;
            }
        }
        if new_cnt == cnt {
            break; // fixed point
        }
        if new_cnt == 0 {
            return (T::ZERO, 0);
        }
        cnt = new_cnt;
        sum = new_sum;
        mu = (sum - theta) / T::from_usize(cnt);
    }
    (mu.max_s(T::ZERO), cnt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::norms::l1inf_norm;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn column_solver_matches_profile() {
        use crate::projection::l1inf::profile::ColumnProfile;
        let col = [3.0f64, -1.5, 2.0, 0.25, -2.75, 0.0];
        let p = ColumnProfile::new(&col);
        for theta in [0.0, 0.2, 1.0, 3.0, 6.0, 9.0, 9.5, 12.0] {
            let (mu_ssn, _) = solve_column(&col, theta);
            let (mu_prof, _) = p.mu_at(theta);
            assert!(
                (mu_ssn - mu_prof).abs() < 1e-10,
                "theta={theta}: ssn={mu_ssn}, profile={mu_prof}"
            );
        }
    }

    #[test]
    fn agrees_with_newton_on_random_matrices() {
        let mut rng = Xoshiro256pp::seed_from_u64(1200);
        for _ in 0..20 {
            let y = Matrix::<f64>::randn(30, 20, &mut rng);
            let eta = l1inf_norm(&y) * 0.3;
            let (mu_ssn, theta_ssn) = solve(&y, eta);
            let (mu_newton, theta_newton) = crate::projection::l1inf::newton::solve(&y, eta);
            assert!((theta_ssn - theta_newton).abs() < 1e-7);
            for (a, b) in mu_ssn.iter().zip(mu_newton.iter()) {
                assert!((a - b).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn radius_attained() {
        let mut rng = Xoshiro256pp::seed_from_u64(1201);
        let y = Matrix::<f64>::randn(64, 48, &mut rng);
        let eta = l1inf_norm(&y) * 0.15;
        let (mu, _) = solve(&y, eta);
        let s: f64 = mu.iter().sum();
        assert!((s - eta).abs() < 1e-8);
    }

    #[test]
    fn joint_matches_nested_solver() {
        let mut rng = Xoshiro256pp::seed_from_u64(1234);
        for trial in 0..30 {
            let n = 2 + (trial % 40);
            let m = 1 + (trial % 25);
            let y = Matrix::<f64>::randn(n, m, &mut rng);
            let eta = l1inf_norm(&y) * (0.05 + 0.03 * trial as f64 % 0.9);
            if eta <= 0.0 {
                continue;
            }
            let (mu_j, th_j) = solve(&y, eta);
            let (mu_n, th_n) = solve_nested(&y, eta);
            assert!((th_j - th_n).abs() < 1e-7, "trial {trial}: theta {th_j} vs {th_n}");
            for (a, b) in mu_j.iter().zip(mu_n.iter()) {
                assert!((a - b).abs() < 1e-7, "trial {trial}: mu {a} vs {b}");
            }
        }
    }

    #[test]
    fn all_zero_matrix() {
        let y = Matrix::<f64>::zeros(10, 5);
        let (mu, _) = solve(&y, 1.0);
        assert!(mu.iter().all(|&v| v == 0.0));
    }
}
