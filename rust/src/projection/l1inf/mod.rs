//! Exact projection onto the ℓ1,∞ ball — the baselines the paper compares
//! `BP¹,∞` against (§II and §V.A).
//!
//! All exact algorithms solve the same KKT system: there is a dual scalar
//! `θ ≥ 0` (the mass clipped off each active column) and per-column levels
//! `μ_j ≥ 0` such that
//!
//! ```text
//! Σ_i max(|Y_ij| − μ_j, 0) = θ     for every active column (μ_j > 0),
//! μ_j = 0                         when ‖y_j‖₁ ≤ θ,
//! Σ_j μ_j = η,
//! X_ij = sign(Y_ij)·min(|Y_ij|, μ_j).
//! ```
//!
//! (so the exact projection is *also* a clipping operator — Remark III.4 —
//! just with a different threshold vector than `BP¹,∞`.)
//!
//! `S(θ) = Σ_j μ_j(θ)` is convex, piecewise-linear, strictly decreasing on
//! the active region, with `S(0) = ‖Y‖₁,∞`; the algorithms differ in how
//! they find the root of `S(θ) = η`:
//!
//! * [`quattoni`] — merge-sort all `nm` breakpoints and sweep
//!   (O(nm log nm)), Quattoni, Carreras, Collins, Darrell, ICML 2009 [22];
//! * [`newton`] — per-column sort once, then Newton root search with
//!   binary-search evaluation (Chau, Wohlberg, Rodriguez, SIIMS 2019 [24]);
//! * [`ssn`] — semismooth Newton without any pre-sorting, per-column
//!   active-set evaluation, O(nm) per iteration (Chu, Zhang, Sun, Tao,
//!   ICML 2020 [25] — the paper's main comparator, its C++ implementation
//!   ported to Rust);
//! * [`bisection`] — slow golden reference for the test-suite.

pub mod newton;
pub mod profile;
pub mod quattoni;
pub mod ssn;

use crate::norms::l1inf_norm;
use crate::scalar::Scalar;
use crate::tensor::Matrix;

/// Exact-projection algorithm selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum L1InfAlgorithm {
    /// Breakpoint merge sweep, O(nm log nm).
    Quattoni,
    /// Newton root search over pre-sorted column profiles.
    Newton,
    /// Semismooth Newton (Chu et al.), no pre-sort.
    Ssn,
    /// Bisection golden reference (tests only; slow).
    Bisection,
}

impl L1InfAlgorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Self::Quattoni => "quattoni",
            Self::Newton => "newton",
            Self::Ssn => "ssn",
            Self::Bisection => "bisection",
        }
    }

    pub fn all() -> &'static [L1InfAlgorithm] {
        &[Self::Quattoni, Self::Newton, Self::Ssn, Self::Bisection]
    }
}

/// Result of an exact ℓ1,∞ projection: the matrix, the per-column clipping
/// levels `μ`, and the dual scalar `θ`.
#[derive(Clone, Debug)]
pub struct L1InfResult<T: Scalar> {
    pub x: Matrix<T>,
    pub mu: Vec<T>,
    pub theta: T,
}

/// Project `y` onto `{X : ‖X‖₁,∞ ≤ eta}` exactly.
pub fn project_l1inf_with<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    algo: L1InfAlgorithm,
) -> L1InfResult<T> {
    assert!(eta >= T::ZERO, "project_l1inf: radius must be non-negative");
    let m = y.cols();
    if eta == T::ZERO {
        return L1InfResult {
            x: Matrix::zeros(y.rows(), m),
            mu: vec![T::ZERO; m],
            theta: T::INFINITY,
        };
    }
    if l1inf_norm(y) <= eta {
        let mu = crate::norms::column_linf(y);
        return L1InfResult { x: y.clone(), mu, theta: T::ZERO };
    }
    let (mu, theta) = match algo {
        L1InfAlgorithm::Quattoni => quattoni::solve(y, eta),
        L1InfAlgorithm::Newton => newton::solve(y, eta),
        L1InfAlgorithm::Ssn => ssn::solve(y, eta),
        L1InfAlgorithm::Bisection => bisection_solve(y, eta),
    };
    let x = apply_clip(y, &mu);
    L1InfResult { x, mu, theta }
}

/// Convenience wrapper returning only the projected matrix.
pub fn project_l1inf<T: Scalar>(y: &Matrix<T>, eta: T, algo: L1InfAlgorithm) -> Matrix<T> {
    project_l1inf_with(y, eta, algo).x
}

/// `X_ij = sign(Y_ij) · min(|Y_ij|, μ_j)` — the clipping operator shared by
/// every exact algorithm (and by `BP¹,∞`).
pub fn apply_clip<T: Scalar>(y: &Matrix<T>, mu: &[T]) -> Matrix<T> {
    assert_eq!(mu.len(), y.cols());
    let mut x = y.clone();
    for (j, &c) in mu.iter().enumerate() {
        crate::projection::linf::project_linf_inplace(x.col_mut(j), c.max_s(T::ZERO));
    }
    x
}

/// Golden reference: bisection on `θ` using exact per-column profiles.
fn bisection_solve<T: Scalar>(y: &Matrix<T>, eta: T) -> (Vec<T>, T) {
    let profiles: Vec<profile::ColumnProfile<T>> =
        y.columns().map(profile::ColumnProfile::new).collect();
    let mut lo = T::ZERO; // S(lo) = ||Y||_{1,inf} > eta
    let mut hi = profiles
        .iter()
        .map(|p| p.total())
        .fold(T::ZERO, |a, b| a.max_s(b)); // S(hi) = 0 <= eta
    for _ in 0..200 {
        let mid = (lo + hi) / (T::ONE + T::ONE);
        let s: T = profiles.iter().map(|p| p.mu_at(mid).0).sum();
        if s > eta {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo <= T::EPSILON * hi.max_s(T::ONE) {
            break;
        }
    }
    let theta = (lo + hi) / (T::ONE + T::ONE);
    let mu = profiles.iter().map(|p| p.mu_at(theta).0).collect();
    (mu, theta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::norms::*;
    use crate::rng::Xoshiro256pp;

    fn randmat(n: usize, m: usize, seed: u64) -> Matrix<f64> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Matrix::randn(n, m, &mut rng)
    }

    #[test]
    fn all_algorithms_agree_with_bisection() {
        for seed in 0..20 {
            let n = 3 + (seed as usize % 20);
            let m = 2 + (seed as usize % 15);
            let y = randmat(n, m, 400 + seed);
            let eta = l1inf_norm(&y) * 0.3;
            let golden = project_l1inf_with(&y, eta, L1InfAlgorithm::Bisection);
            for algo in [L1InfAlgorithm::Quattoni, L1InfAlgorithm::Newton, L1InfAlgorithm::Ssn] {
                let r = project_l1inf_with(&y, eta, algo);
                assert!(
                    golden.x.max_abs_diff(&r.x) < 1e-6,
                    "{} disagrees with bisection (seed {seed}): diff={}",
                    algo.name(),
                    golden.x.max_abs_diff(&r.x)
                );
                assert!((r.theta - golden.theta).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn feasibility_is_tight() {
        let y = randmat(40, 25, 500);
        let eta = l1inf_norm(&y) * 0.25;
        for algo in L1InfAlgorithm::all() {
            let x = project_l1inf(&y, eta, *algo);
            let norm = l1inf_norm(&x);
            assert!(
                (norm - eta).abs() < 1e-7 * (1.0 + eta),
                "{}: ||x||={norm} vs eta={eta}",
                algo.name()
            );
        }
    }

    #[test]
    fn identity_proposition_iii_5() {
        // The usual projection also satisfies the l1,inf identity (19).
        for seed in 0..10 {
            let y = randmat(12, 9, 600 + seed);
            let eta = l1inf_norm(&y) * 0.4;
            let x = project_l1inf(&y, eta, L1InfAlgorithm::Quattoni);
            let lhs = l1inf_norm(&y.sub(&x)) + l1inf_norm(&x);
            let rhs = l1inf_norm(&y);
            assert!((lhs - rhs).abs() < 1e-8, "identity (19) violated: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn exact_has_lower_l2_error_than_bilevel() {
        // P is THE Euclidean projection; BP is not. (Fig. 4 of the paper.)
        let y = randmat(30, 30, 700);
        let eta = l1inf_norm(&y) * 0.2;
        let xp = project_l1inf(&y, eta, L1InfAlgorithm::Newton);
        let xbp = crate::projection::bilevel::bilevel_l1inf(&y, eta);
        let ep = frobenius_norm(&y.sub(&xp));
        let ebp = frobenius_norm(&y.sub(&xbp));
        assert!(ep <= ebp + 1e-9, "exact {ep} should beat bilevel {ebp} in l2");
    }

    #[test]
    fn bilevel_is_sparser_than_exact() {
        // The headline sparsity claim (Table I): same radius, more zero
        // columns from the bi-level projection.
        let mut rng = Xoshiro256pp::seed_from_u64(800);
        let mut y = Matrix::<f64>::randn(50, 40, &mut rng);
        for j in 0..6 {
            for v in y.col_mut(j) {
                *v *= 20.0;
            }
        }
        let eta = l1inf_norm(&y) * 0.05;
        let xp = project_l1inf(&y, eta, L1InfAlgorithm::Ssn);
        let xbp = crate::projection::bilevel::bilevel_l1inf(&y, eta);
        let sp = xp.zero_columns(1e-12).len();
        let sbp = xbp.zero_columns(1e-12).len();
        assert!(
            sbp >= sp,
            "bilevel zero-cols {sbp} should be >= exact zero-cols {sp}"
        );
    }

    #[test]
    fn inside_ball_identity_and_theta_zero() {
        let y = randmat(6, 6, 900);
        let eta = l1inf_norm(&y) * 1.5;
        for algo in L1InfAlgorithm::all() {
            let r = project_l1inf_with(&y, eta, *algo);
            assert!(y.max_abs_diff(&r.x) < 1e-15, "{}", algo.name());
            assert_eq!(r.theta, 0.0);
        }
    }

    #[test]
    fn zero_radius() {
        let y = randmat(4, 4, 901);
        for algo in L1InfAlgorithm::all() {
            let r = project_l1inf_with(&y, 0.0, *algo);
            assert_eq!(r.x.count_zeros(0.0), 16);
        }
    }

    #[test]
    fn optimality_euclidean_vi() {
        // Variational inequality: <Y - X*, Z - X*> <= 0 for feasible Z.
        let mut rng = Xoshiro256pp::seed_from_u64(902);
        let y = randmat(10, 8, 903);
        let eta = 3.0;
        let x = project_l1inf(&y, eta, L1InfAlgorithm::Newton);
        for _ in 0..50 {
            let z0 = Matrix::<f64>::randn(10, 8, &mut rng);
            let z = project_l1inf(&z0, eta, L1InfAlgorithm::Bisection);
            let ip: f64 = y
                .as_slice()
                .iter()
                .zip(x.as_slice().iter())
                .zip(z.as_slice().iter())
                .map(|((&yi, &xi), &zi)| (yi - xi) * (zi - xi))
                .sum();
            assert!(ip <= 1e-6, "VI violated: {ip}");
        }
    }

    #[test]
    fn columns_with_zeros_handled() {
        let mut y = randmat(10, 6, 904);
        for v in y.col_mut(2) {
            *v = 0.0;
        }
        let eta = l1inf_norm(&y) * 0.3;
        for algo in L1InfAlgorithm::all() {
            let r = project_l1inf_with(&y, eta, *algo);
            assert!(r.x.col(2).iter().all(|&v| v == 0.0), "{}", algo.name());
        }
    }

    #[test]
    fn wide_and_tall_extremes() {
        for (n, m, seed) in [(1usize, 50usize, 905u64), (50, 1, 906), (1, 1, 907)] {
            let y = randmat(n, m, seed);
            let eta = l1inf_norm(&y) * 0.5;
            if eta == 0.0 {
                continue;
            }
            let golden = project_l1inf(&y, eta, L1InfAlgorithm::Bisection);
            for algo in [L1InfAlgorithm::Quattoni, L1InfAlgorithm::Newton, L1InfAlgorithm::Ssn] {
                let x = project_l1inf(&y, eta, algo);
                assert!(
                    golden.max_abs_diff(&x) < 1e-6,
                    "{} fails on {n}x{m}",
                    algo.name()
                );
            }
        }
    }
}
