//! Per-column piecewise-linear profile `θ ↦ μ_j(θ)`.
//!
//! For a column with magnitudes sorted descending `s₀ ≥ s₁ ≥ … ≥ s_{n−1}`
//! and prefix sums `C_k = Σ_{i<k} s_i`, the clipped-mass function
//! `r(μ) = Σ_i max(s_i − μ, 0)` is piecewise linear decreasing; its inverse
//! `μ(θ)` satisfies, for `θ ∈ [θ_k, θ_{k+1}]` with breakpoints
//! `θ_k = C_k − k·s_k`:
//!
//! ```text
//! μ(θ) = (C_{k+1} − θ) / (k+1)      (k+1 entries above the level)
//! μ(θ) = 0                          for θ ≥ C_n = ‖column‖₁
//! ```
//!
//! Shared by the Newton and bisection solvers; Quattoni's sweep consumes the
//! breakpoints directly.

use crate::scalar::Scalar;

#[derive(Clone, Debug)]
pub struct ColumnProfile<T: Scalar> {
    /// Magnitudes sorted descending.
    pub sorted: Vec<T>,
    /// `prefix[k] = Σ_{i<k} sorted[i]`, length n+1.
    pub prefix: Vec<T>,
}

impl<T: Scalar> ColumnProfile<T> {
    pub fn new(col: &[T]) -> Self {
        let mut sorted: Vec<T> = col.iter().map(|&x| x.abs()).collect();
        sorted.sort_by(|a, b| b.partial_cmp(a).expect("NaN in projection input"));
        let mut prefix = Vec::with_capacity(sorted.len() + 1);
        let mut acc = T::ZERO;
        prefix.push(acc);
        for &s in &sorted {
            acc += s;
            prefix.push(acc);
        }
        Self { sorted, prefix }
    }

    /// `‖column‖₁` — the θ beyond which the column is fully clipped to 0.
    #[inline]
    pub fn total(&self) -> T {
        *self.prefix.last().unwrap()
    }

    /// `‖column‖∞`.
    #[inline]
    pub fn max(&self) -> T {
        self.sorted.first().copied().unwrap_or(T::ZERO)
    }

    /// Breakpoint `θ_k = C_k − k·s_k` for `k` in `0..n`.
    #[inline]
    pub fn breakpoint(&self, k: usize) -> T {
        self.prefix[k] - T::from_usize(k) * self.sorted[k]
    }

    /// Evaluate `(μ(θ), active_count)`; `active_count = 0` when the column
    /// is fully clipped (μ = 0, dead for the Newton derivative).
    pub fn mu_at(&self, theta: T) -> (T, usize) {
        let n = self.sorted.len();
        if n == 0 || theta >= self.total() {
            return (T::ZERO, 0);
        }
        if theta <= T::ZERO {
            return (self.max(), 1.max(n.min(1)));
        }
        // Binary search: largest k in 0..n with breakpoint(k) <= theta.
        // (breakpoints are non-decreasing in k; breakpoint(0) = 0.)
        let (mut lo, mut hi) = (0usize, n - 1);
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if self.breakpoint(mid) <= theta {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        let k = lo; // piece with k+1 active entries
        let cnt = k + 1;
        let mu = (self.prefix[cnt] - theta) / T::from_usize(cnt);
        (mu.max_s(T::ZERO), cnt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn brute_mu(col: &[f64], theta: f64) -> f64 {
        // invert r(mu) = theta by dense scan over a fine grid + refine.
        let hi = col.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        let r = |mu: f64| -> f64 { col.iter().map(|&x| (x.abs() - mu).max(0.0)).sum() };
        if theta >= r(0.0) {
            return 0.0;
        }
        let (mut lo, mut hi) = (0.0, hi);
        for _ in 0..100 {
            let mid = 0.5 * (lo + hi);
            if r(mid) > theta {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    #[test]
    fn mu_matches_brute_force() {
        let col = [3.0f64, -1.0, 2.0, 0.5, -2.5];
        let p = ColumnProfile::new(&col);
        for theta in [0.0, 0.1, 0.5, 1.0, 2.0, 4.0, 8.0, 8.9, 9.0, 20.0] {
            let (mu, _) = p.mu_at(theta);
            let want = brute_mu(&col, theta);
            assert!((mu - want).abs() < 1e-9, "theta={theta}: mu={mu}, want={want}");
        }
    }

    #[test]
    fn breakpoints_non_decreasing() {
        let col = [5.0f64, 4.0, 4.0, 1.0, 0.0];
        let p = ColumnProfile::new(&col);
        for k in 1..col.len() {
            assert!(p.breakpoint(k) >= p.breakpoint(k - 1) - 1e-15);
        }
        assert_eq!(p.breakpoint(0), 0.0);
    }

    #[test]
    fn total_and_max() {
        let p = ColumnProfile::new(&[1.0f64, -2.0, 3.0]);
        assert_eq!(p.total(), 6.0);
        assert_eq!(p.max(), 3.0);
    }

    #[test]
    fn dead_column_beyond_total() {
        let p = ColumnProfile::new(&[1.0f64, 1.0]);
        let (mu, cnt) = p.mu_at(2.0);
        assert_eq!(mu, 0.0);
        assert_eq!(cnt, 0);
        let (mu, cnt) = p.mu_at(5.0);
        assert_eq!(mu, 0.0);
        assert_eq!(cnt, 0);
    }

    #[test]
    fn zero_theta_returns_max() {
        let p = ColumnProfile::new(&[1.0f64, 7.0, 3.0]);
        assert_eq!(p.mu_at(0.0).0, 7.0);
    }

    #[test]
    fn empty_column() {
        let p = ColumnProfile::new(&[]);
        assert_eq!(p.mu_at(1.0), (0.0, 0));
        assert_eq!(p.total(), 0.0);
    }

    #[test]
    fn mu_continuity_at_breakpoints() {
        let col = [4.0f64, 3.0, 2.0, 1.0];
        let p = ColumnProfile::new(&col);
        for k in 1..col.len() {
            let t = p.breakpoint(k);
            let (lo, _) = p.mu_at(t - 1e-9);
            let (hi, _) = p.mu_at(t + 1e-9);
            assert!((lo - hi).abs() < 1e-6, "discontinuity at breakpoint {k}");
        }
    }
}
