//! Quattoni et al. (ICML 2009) exact ℓ1,∞ projection: global breakpoint
//! merge + linear sweep. Worst-case **O(nm log nm)** — the complexity the
//! paper's abstract quotes for the state of the art.
//!
//! `S(θ) = Σ_j μ_j(θ)` is piecewise linear with at most `nm + m`
//! breakpoints (each column contributes one per sorted entry plus a death
//! point at `θ = ‖y_j‖₁`). Between breakpoints `S(θ) = A − B·θ`; we sort
//! all breakpoints, sweep left→right maintaining `(A, B)`, and stop in the
//! segment containing the root `S(θ*) = η`.

use super::profile::ColumnProfile;
use crate::scalar::Scalar;
use crate::tensor::Matrix;

/// Solve for `(μ, θ)` with `Σ_j μ_j(θ) = eta`.
/// Precondition (enforced by the dispatcher): `0 < eta < ‖Y‖₁,∞`.
pub fn solve<T: Scalar>(y: &Matrix<T>, eta: T) -> (Vec<T>, T) {
    let profiles: Vec<ColumnProfile<T>> = y.columns().map(ColumnProfile::new).collect();

    // Event = (θ, ΔA, ΔB) applied when the sweep passes θ.
    let mut events: Vec<(T, T, T)> = Vec::with_capacity(y.rows() * y.cols() + y.cols());
    let mut a = T::ZERO; // A = Σ_j C_{k+1}/(k+1) over alive columns
    let mut b = T::ZERO; // B = Σ_j 1/(k+1)

    for p in &profiles {
        let n = p.sorted.len();
        if n == 0 || p.max() <= T::ZERO {
            continue; // zero column never contributes
        }
        // Piece k=0 active from θ=0: μ = C₁ − θ.
        a += p.prefix[1];
        b += T::ONE;
        // Piece transitions k−1 → k at θ_k, k = 1..n−1.
        for k in 1..n {
            let theta_k = p.breakpoint(k);
            let prev = p.prefix[k] / T::from_usize(k);
            let next = p.prefix[k + 1] / T::from_usize(k + 1);
            let db = T::ONE / T::from_usize(k + 1) - T::ONE / T::from_usize(k);
            events.push((theta_k, next - prev, db));
        }
        // Death at θ = ‖column‖₁ (from piece k = n−1).
        let last_a = p.prefix[n] / T::from_usize(n);
        let last_b = T::ONE / T::from_usize(n);
        events.push((p.total(), -last_a, -last_b));
    }

    events.sort_by(|x, y| x.0.partial_cmp(&y.0).expect("NaN breakpoint"));

    // Sweep. In segment [θ_prev, θ_event], S(θ) = A − B·θ.
    let mut theta_prev = T::ZERO;
    let mut theta_star = None;
    for &(theta_e, da, db) in &events {
        if b > T::ZERO {
            let cand = (a - eta) / b;
            // Tolerate tiny negative drift at the segment edges.
            if cand >= theta_prev - T::EPSILON && cand <= theta_e + T::EPSILON {
                theta_star = Some(cand.max_s(theta_prev).min_s(theta_e));
                break;
            }
        }
        a += da;
        b += db;
        theta_prev = theta_e;
    }
    let theta = theta_star.unwrap_or(theta_prev);

    let mu = profiles.iter().map(|p| p.mu_at(theta).0).collect();
    (mu, theta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::norms::l1inf_norm;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn sum_of_mu_equals_eta() {
        let mut rng = Xoshiro256pp::seed_from_u64(1000);
        let y = Matrix::<f64>::randn(20, 15, &mut rng);
        let eta = l1inf_norm(&y) * 0.3;
        let (mu, theta) = solve(&y, eta);
        let s: f64 = mu.iter().sum();
        assert!((s - eta).abs() < 1e-9, "sum mu = {s} != eta = {eta}");
        assert!(theta > 0.0);
    }

    #[test]
    fn per_column_kkt_mass_condition() {
        // Every active column must clip exactly theta of mass.
        let mut rng = Xoshiro256pp::seed_from_u64(1001);
        let y = Matrix::<f64>::randn(25, 10, &mut rng);
        let eta = l1inf_norm(&y) * 0.4;
        let (mu, theta) = solve(&y, eta);
        for (j, col) in y.columns().enumerate() {
            if mu[j] > 1e-12 {
                let clipped: f64 = col.iter().map(|&v| (v.abs() - mu[j]).max(0.0)).sum();
                assert!(
                    (clipped - theta).abs() < 1e-8,
                    "column {j}: clipped {clipped} != theta {theta}"
                );
            }
        }
    }

    #[test]
    fn dead_columns_when_eta_tiny() {
        let mut rng = Xoshiro256pp::seed_from_u64(1002);
        let mut y = Matrix::<f64>::randn(30, 8, &mut rng);
        for v in y.col_mut(0) {
            *v *= 100.0; // dominant column
        }
        let (mu, _) = solve(&y, 0.01);
        // weak columns should be zeroed entirely once theta > ||y_j||_1
        assert!(mu[0] > 0.0);
    }

    #[test]
    fn handles_duplicate_magnitudes() {
        let y = Matrix::from_row_major(3, 2, &[2.0f64, 2.0, 2.0, 2.0, 2.0, 2.0]);
        let eta = 1.0;
        let (mu, _) = solve(&y, eta);
        let s: f64 = mu.iter().sum();
        assert!((s - eta).abs() < 1e-9);
        assert!((mu[0] - mu[1]).abs() < 1e-12, "symmetric columns same mu");
    }
}
