//! Newton root-search exact ℓ1,∞ projection (Chau, Wohlberg, Rodriguez,
//! SIAM J. Imaging Sci. 2019 [24]).
//!
//! Pre-sort each column once (O(nm log n)); then Newton on the convex,
//! piecewise-linear, strictly-decreasing `S(θ) = Σ_j μ_j(θ)`:
//!
//! ```text
//! θ ← θ + (S(θ) − η) / D(θ),    D(θ) = Σ_{active j} 1/(k_j+1) = −S′(θ)
//! ```
//!
//! Starting at θ = 0, convexity makes the iterates increase monotonically
//! toward the root, and piecewise-linearity makes convergence finite (each
//! step lands exactly on the root of the current tangent, which either is
//! the answer or crosses into a later segment). Each evaluation costs
//! O(m log n) via binary search over the column profiles.

use super::profile::ColumnProfile;
use crate::scalar::Scalar;
use crate::tensor::Matrix;

const MAX_ITERS: usize = 200;

/// Solve for `(μ, θ)` with `Σ_j μ_j(θ) = eta`; `0 < eta < ‖Y‖₁,∞`.
pub fn solve<T: Scalar>(y: &Matrix<T>, eta: T) -> (Vec<T>, T) {
    let profiles: Vec<ColumnProfile<T>> = y.columns().map(ColumnProfile::new).collect();
    let theta = newton_root(&profiles, eta);
    let mu = profiles.iter().map(|p| p.mu_at(theta).0).collect();
    (mu, theta)
}

pub(crate) fn newton_root<T: Scalar>(profiles: &[ColumnProfile<T>], eta: T) -> T {
    let mut theta = T::ZERO;
    let tol = T::EPSILON * eta.max_s(T::ONE) * T::from_f64(64.0);
    for _ in 0..MAX_ITERS {
        let mut s = T::ZERO;
        let mut d = T::ZERO;
        for p in profiles {
            let (mu, cnt) = p.mu_at(theta);
            s += mu;
            if cnt > 0 && mu > T::ZERO {
                d += T::ONE / T::from_usize(cnt);
            }
        }
        let gap = s - eta;
        if gap.abs() <= tol || d <= T::ZERO {
            break;
        }
        let step = gap / d;
        if step <= T::ZERO {
            break; // overshot (numerical); theta is within tolerance
        }
        theta += step;
    }
    theta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::norms::l1inf_norm;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn converges_to_feasible_theta() {
        let mut rng = Xoshiro256pp::seed_from_u64(1100);
        let y = Matrix::<f64>::randn(50, 30, &mut rng);
        let eta = l1inf_norm(&y) * 0.2;
        let (mu, _) = solve(&y, eta);
        let s: f64 = mu.iter().sum();
        assert!((s - eta).abs() < 1e-8, "sum mu {s} vs eta {eta}");
    }

    #[test]
    fn few_iterations_on_typical_input() {
        // finite convergence: piecewise-linear Newton should need far fewer
        // than MAX_ITERS steps — sanity-check via agreement with bisection.
        let mut rng = Xoshiro256pp::seed_from_u64(1101);
        for _ in 0..10 {
            let y = Matrix::<f64>::randn(40, 12, &mut rng);
            let eta = l1inf_norm(&y) * 0.35;
            let (_, theta_newton) = solve(&y, eta);
            let r = crate::projection::l1inf::project_l1inf_with(
                &y,
                eta,
                crate::projection::l1inf::L1InfAlgorithm::Bisection,
            );
            assert!((theta_newton - r.theta).abs() < 1e-6);
        }
    }

    #[test]
    fn monotone_theta_in_eta() {
        // Smaller radius => more mass clipped => larger theta.
        let mut rng = Xoshiro256pp::seed_from_u64(1102);
        let y = Matrix::<f64>::randn(30, 10, &mut rng);
        let norm = l1inf_norm(&y);
        let mut last = 0.0;
        for frac in [0.8, 0.6, 0.4, 0.2, 0.1] {
            let (_, theta) = solve(&y, norm * frac);
            assert!(theta >= last - 1e-12, "theta not monotone");
            last = theta;
        }
    }
}
