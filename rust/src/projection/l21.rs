//! Projection onto the ℓ2,1 ball `{X : Σ_i ‖X_{i,:}‖₂ ≤ η}` — the
//! group-lasso ball over *rows* (features), the structured-sparsity
//! scenario of `proj_l21ball` in the reference implementations.
//!
//! Exact in two stages, like the paper's bi-level operators: project the
//! row ℓ2-norm vector onto the ℓ1 ball (any of the [`crate::projection::l1`]
//! solvers), then rescale each row to its projected norm. The identity
//! `‖Y − X‖₂,₁ + ‖X‖₂,₁ = ‖Y‖₂,₁` holds because each row moves radially.
//! Row norms are accumulated column-by-column so the column-major storage
//! is walked contiguously.

use crate::kernels::{self, Workspace};
use crate::projection::l1::{self, L1Algorithm};
use crate::scalar::Scalar;
use crate::tensor::{vec_ops, Matrix};

/// Workspace-based `P²,¹_η(Y)` — zero allocations at steady state.
/// `ws.thresholds` holds the projected row norms; `ws.norms` is consumed
/// as scratch (row norms, then per-row scale factors).
pub fn project_l21_into<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    algo: L1Algorithm,
    ws: &mut Workspace<T>,
    out: &mut Matrix<T>,
) {
    assert!(eta >= T::ZERO, "l21 projection: radius must be non-negative");
    let (n, m) = (y.rows(), y.cols());
    out.resize_reuse(n, m);
    if y.is_empty() {
        return;
    }
    if eta <= T::ZERO {
        out.as_mut_slice().fill(T::ZERO);
        return;
    }
    // Row ℓ2 norms (sums of squares first, column-major friendly).
    ws.norms.clear();
    ws.norms.resize(n, T::ZERO);
    for j in 0..m {
        for (acc, &v) in ws.norms.iter_mut().zip(y.col(j).iter()) {
            *acc = *acc + v * v;
        }
    }
    for v in ws.norms.iter_mut() {
        *v = v.sqrt();
    }
    if kernels::sum_abs(&ws.norms) <= eta {
        out.as_mut_slice().copy_from_slice(y.as_slice());
        ws.thresholds.clear();
        ws.thresholds.extend_from_slice(&ws.norms);
        return;
    }
    // Inner ℓ1 projection of the (non-negative) row-norm vector.
    ws.thresholds.clear();
    ws.thresholds.extend_from_slice(&ws.norms);
    l1::project_l1_nonneg_inplace_with(&mut ws.thresholds, eta, algo, &mut ws.condat);
    // Per-row radial scale p_i/v_i, written destructively over the norms
    // (soft-thresholding guarantees p_i ≤ v_i; zero rows stay at scale 1).
    for (s, &p) in ws.norms.iter_mut().zip(ws.thresholds.iter()) {
        *s = if *s > T::ZERO { p / *s } else { T::ONE };
    }
    for j in 0..m {
        let dst = out.col_mut(j);
        for ((d, &v), &s) in dst.iter_mut().zip(y.col(j).iter()).zip(ws.norms.iter()) {
            *d = v * s;
        }
    }
}

/// `P²,¹_η(Y)`: allocate-and-return convenience wrapper around
/// [`project_l21_into`].
pub fn project_l21<T: Scalar>(y: &Matrix<T>, eta: T) -> Matrix<T> {
    project_l21_with(y, eta, L1Algorithm::Condat)
}

/// [`project_l21`] with an explicit inner ℓ1 solver.
pub fn project_l21_with<T: Scalar>(y: &Matrix<T>, eta: T, algo: L1Algorithm) -> Matrix<T> {
    let mut ws = Workspace::new();
    let mut out = Matrix::zeros(0, 0);
    project_l21_into(y, eta, algo, &mut ws, &mut out);
    out
}

/// Scalar reference: row norms via [`Matrix::row`] copies and the
/// sort-based ℓ1 solver. Golden oracle for the workspace path.
pub fn project_l21_ref<T: Scalar>(y: &Matrix<T>, eta: T) -> Matrix<T> {
    assert!(eta >= T::ZERO);
    let n = y.rows();
    if y.is_empty() {
        return y.clone();
    }
    if eta <= T::ZERO {
        return Matrix::zeros(n, y.cols());
    }
    let norms: Vec<T> = (0..n).map(|i| vec_ops::l2(&y.row(i))).collect();
    if norms.iter().copied().sum::<T>() <= eta {
        return y.clone();
    }
    let proj = l1::project_l1(&norms, eta, L1Algorithm::Sort);
    let mut out = y.clone();
    for j in 0..y.cols() {
        for (i, x) in out.col_mut(j).iter_mut().enumerate() {
            if norms[i] > T::ZERO {
                *x = *x * (proj[i] / norms[i]);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::norms::l21_norm;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn feasible_matches_reference_and_identity_holds() {
        let mut rng = Xoshiro256pp::seed_from_u64(81);
        for &(n, m) in &[(1usize, 1usize), (9, 17), (40, 12), (30, 30)] {
            let y = Matrix::<f64>::randn(n, m, &mut rng);
            let eta = 0.35 * l21_norm(&y);
            let x = project_l21(&y, eta);
            assert!(l21_norm(&x) <= eta * (1.0 + 1e-10), "{n}x{m}");
            let r = project_l21_ref(&y, eta);
            assert!(x.max_abs_diff(&r) < 1e-10, "{n}x{m}");
            // Radial moves make the bi-level identity exact.
            let gap = (l21_norm(&y.sub(&x)) + l21_norm(&x) - l21_norm(&y)).abs();
            assert!(gap < 1e-9, "{n}x{m}: identity gap {gap}");
        }
    }

    #[test]
    fn inside_ball_is_identity_and_inner_solvers_agree() {
        let mut rng = Xoshiro256pp::seed_from_u64(82);
        let y = Matrix::<f64>::randn(10, 8, &mut rng);
        assert_eq!(project_l21(&y, l21_norm(&y) * 1.001), y);
        let base = project_l21_with(&y, 1.3, L1Algorithm::Condat);
        for algo in L1Algorithm::all() {
            let x = project_l21_with(&y, 1.3, *algo);
            assert!(base.max_abs_diff(&x) < 1e-9, "inner {}", algo.name());
        }
    }

    #[test]
    fn zero_radius_projects_to_zero() {
        let mut rng = Xoshiro256pp::seed_from_u64(83);
        let y = Matrix::<f64>::randn(5, 7, &mut rng);
        assert!(project_l21(&y, 0.0).as_slice().iter().all(|&v| v == 0.0));
    }
}
