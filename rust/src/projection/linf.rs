//! Projection onto the ℓ∞ ball — elementwise clipping (paper eq. 13).
//!
//! `P^∞_c(y)_i = sign(y_i)·min(|y_i|, c)`. This is the O(n) outer step of
//! `BP¹,∞` and the reason the whole bi-level projection is a *clipping
//! operator* (Remark III.2).

use crate::kernels;
use crate::scalar::Scalar;

/// Project onto `{x : ‖x‖∞ ≤ c}` in place — the lane-chunked clip kernel.
pub fn project_linf_inplace<T: Scalar>(y: &mut [T], c: T) {
    debug_assert!(c >= T::ZERO);
    kernels::clip_inplace(y, c);
}

/// Out-of-place variant.
pub fn project_linf<T: Scalar>(y: &[T], c: T) -> Vec<T> {
    let mut out = y.to_vec();
    project_linf_inplace(&mut out, c);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::vec_ops;

    #[test]
    fn clips_to_radius() {
        let x = project_linf(&[3.0f64, -4.0, 0.5], 1.0);
        assert_eq!(x, vec![1.0, -1.0, 0.5]);
        assert!(vec_ops::linf(&x) <= 1.0);
    }

    #[test]
    fn zero_radius_zeroes_vector() {
        let x = project_linf(&[3.0f64, -4.0], 0.0);
        assert_eq!(x, vec![0.0, 0.0]);
    }

    #[test]
    fn inside_ball_unchanged() {
        let y = vec![0.1f64, -0.9];
        assert_eq!(project_linf(&y, 1.0), y);
    }

    #[test]
    fn idempotent() {
        let y = vec![5.0f64, -3.0, 2.0];
        let once = project_linf(&y, 2.5);
        let twice = project_linf(&once, 2.5);
        assert_eq!(once, twice);
    }

    #[test]
    fn residual_infinity_identity_eq16() {
        // ||y - x||_inf = ||y||_inf - ||x||_inf for clipping (paper eq. 16).
        let y = vec![3.0f64, -4.0, 0.5];
        let c = 1.5;
        let x = project_linf(&y, c);
        let resid: Vec<f64> = y.iter().zip(x.iter()).map(|(a, b)| a - b).collect();
        let lhs = vec_ops::linf(&resid);
        let rhs = vec_ops::linf(&y) - vec_ops::linf(&x);
        assert!((lhs - rhs).abs() < 1e-12);
    }
}
