//! **The paper's contribution**: bi-level structured projections
//! (§III–§IV, Algorithms 1–3).
//!
//! The bi-level ℓ1,∞ projection `BP¹,∞_η` (Alg. 1) splits the matrix
//! problem into two exactly-solvable stages:
//!
//! 1. **inner** — aggregate each column to its ∞-norm and project the
//!    resulting `m`-vector `v_∞` onto the ℓ1 ball of radius `η`
//!    (O(m) with Condat): `û = P¹_η(v_∞)`;
//! 2. **outer** — clip every column at its own threshold:
//!    `x_j = P^∞_{û_j}(y_j)`, i.e. `X_ij = sign(Y_ij)·min(|Y_ij|, û_j)`
//!    (eq. 13), O(nm).
//!
//! Total **O(nm)** vs O(nm log nm) for the exact projection, converging in
//! a single pass (no iteration). `BP¹,¹` and `BP¹,²` replace the column
//! aggregator / outer ball by ℓ1/ℓ1 and ℓ2/ℓ2 respectively.
//!
//! Properties verified by the test-suite (by the differential conformance
//! suite `rust/tests/l1inf_conformance.rs` — which also cross-checks every
//! exact ℓ1,∞ solver against the others and `BP¹,∞` against them across
//! shapes, dtypes, and radii — and by `experiments::fig3`):
//!
//! * feasibility: `‖BP¹,∞(Y)‖₁,∞ ≤ η`;
//! * contraction (Remark III.1): `0 ≤ û_j ≤ ‖y_j‖∞`;
//! * the ℓ1,∞ identity (Prop. III.3):
//!   `‖Y − BP(Y)‖₁,∞ + ‖BP(Y)‖₁,∞ = ‖Y‖₁,∞`;
//! * structured sparsity: columns whose ∞-norm falls below the inner
//!   waterline are zeroed *entirely*, and on the paper's scale-separated
//!   ensembles no fewer columns than the exact projection zeroes (Fig. 2).

mod parallel;

pub use parallel::{bilevel_l1inf_parallel, bilevel_l1inf_parallel_into, ParallelPolicy};

use crate::kernels::{self, Workspace};
use crate::projection::l1::{self, L1Algorithm};
use crate::projection::l2;
use crate::scalar::Scalar;
use crate::tensor::Matrix;

/// Which column aggregator / outer ball a bi-level projection uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BilevelVariant {
    /// Alg. 1 — aggregate by ‖·‖∞, clip columns.
    L1Inf,
    /// Alg. 2 — aggregate by ‖·‖₁, soft-threshold columns.
    L11,
    /// Alg. 3 — aggregate by ‖·‖₂, rescale columns.
    L12,
}

impl BilevelVariant {
    pub fn name(&self) -> &'static str {
        match self {
            Self::L1Inf => "bilevel-l1inf",
            Self::L11 => "bilevel-l11",
            Self::L12 => "bilevel-l12",
        }
    }

    pub fn all() -> &'static [BilevelVariant] {
        &[Self::L1Inf, Self::L11, Self::L12]
    }
}

/// Full result of a bi-level projection: the projected matrix plus the
/// per-column thresholds `û` (the clipping thresholds of Remark III.2 —
/// exactly what the trainer needs to derive column masks).
#[derive(Clone, Debug)]
pub struct BilevelResult<T: Scalar> {
    pub x: Matrix<T>,
    /// Inner-stage solution `û` (û_j = ‖x_j‖ in the variant's column norm).
    pub thresholds: Vec<T>,
}

impl<T: Scalar> BilevelResult<T> {
    /// Columns zeroed by the projection (û_j == 0) — the structured
    /// sparsity pattern.
    pub fn zero_columns(&self) -> Vec<usize> {
        self.thresholds
            .iter()
            .enumerate()
            .filter(|(_, &u)| u <= T::ZERO)
            .map(|(j, _)| j)
            .collect()
    }
}

/// Generic bi-level driver: `aggregate` maps a column to its scalar norm,
/// `shrink` projects a column onto the variant's ball of radius `û_j`.
fn bilevel_generic<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    algo: L1Algorithm,
    aggregate: impl Fn(&[T]) -> T,
    shrink: impl Fn(&mut [T], T),
) -> BilevelResult<T> {
    assert!(eta >= T::ZERO, "bilevel projection: radius must be non-negative");
    let m = y.cols();
    // Stage 1: column norms, then l1-ball projection of the norm vector.
    let v: Vec<T> = y.columns().map(|c| aggregate(c)).collect();
    let u = l1::project_l1(&v, eta, algo);
    debug_assert_eq!(u.len(), m);

    // Stage 2: per-column shrink to radius u_j.
    let mut x = y.clone();
    for j in 0..m {
        shrink(x.col_mut(j), u[j]);
    }
    BilevelResult { x, thresholds: u }
}

/// `BP¹,∞_η(Y)` — paper Algorithm 1, with the threshold vector. O(nm).
///
/// One-shot wrapper over [`bilevel_l1inf_into`]: allocates a workspace and
/// output for this call. Hot paths keep a [`Workspace`] alive and use the
/// workspace variants directly — the serve engine calls
/// [`bilevel_l1inf_into`], the trainer projects W1 in place with
/// [`bilevel_l1inf_inplace_cols`] — which perform zero heap allocations in
/// steady state.
pub fn bilevel_l1inf_with<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    algo: L1Algorithm,
) -> BilevelResult<T> {
    assert!(eta >= T::ZERO, "bilevel projection: radius must be non-negative");
    let mut ws = Workspace::new();
    l1inf_thresholds_into(y, eta, algo, &mut ws);
    // Extend-based build: the output is written exactly once (no
    // zero-fill pass), through the same shared copy-or-clip kernel ops as
    // the `_into` path, so the two stay bit-identical.
    let mut data: Vec<T> = Vec::with_capacity(y.len());
    for (j, col) in y.columns().enumerate() {
        kernels::extend_clipped(&mut data, col, ws.thresholds[j], ws.norms[j]);
    }
    BilevelResult {
        x: Matrix::from_col_major(y.rows(), y.cols(), data),
        thresholds: std::mem::take(&mut ws.thresholds),
    }
}

/// Stage 1 (column ∞-norms) plus the inner ℓ1 projection, into the
/// workspace — the shared front half of every `BP¹,∞` entry point.
fn l1inf_thresholds_into<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    algo: L1Algorithm,
    ws: &mut Workspace<T>,
) {
    ws.norms.clear();
    ws.norms.extend(y.columns().map(kernels::colmax));
    ws.thresholds.clear();
    ws.thresholds.extend_from_slice(&ws.norms);
    l1::project_l1_nonneg_inplace_with(&mut ws.thresholds, eta, algo, &mut ws.condat);
}

/// Workspace-based `BP¹,∞_η(Y)` (EXPERIMENTS.md §Perf): projects `y` into
/// `out`, leaving the per-column thresholds `û` in `ws.thresholds`.
///
/// All four hot loops run through the lane-chunked [`crate::kernels`]
/// layer, and every intermediate lives in `ws` — with a warm workspace and
/// a right-sized `out` (both sized by any previous call of the same
/// shape), a call performs **zero heap allocations** (proven by the
/// `kernels_alloc` integration test). The clip stage is fused: one read of
/// `Y`, one write of `X`, with untouched columns (`û_j ≥ ‖y_j‖∞`)
/// degenerating to a `memcpy`.
pub fn bilevel_l1inf_into<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    algo: L1Algorithm,
    ws: &mut Workspace<T>,
    out: &mut Matrix<T>,
) {
    assert!(eta >= T::ZERO, "bilevel projection: radius must be non-negative");
    let n = y.rows();
    // Stage 1 + inner l1 projection (allocation-free via the Condat
    // scratch; the norm vector is non-negative by construction).
    l1inf_thresholds_into(y, eta, algo, ws);
    // Stage 2 (fused): single read of Y, single write of X; untouched
    // columns degenerate to a plain copy inside the shared kernel.
    out.resize_reuse(n, y.cols());
    kernels::clip_groups_into(
        y.as_slice(),
        n.max(1), // group size must be non-zero even for 0-row matrices
        &ws.thresholds,
        &ws.norms,
        out.as_mut_slice(),
    );
}

/// In-place workspace `BP¹,∞` over a flat column-major buffer (`rows`
/// elements per column) — the trainer's W1 path, where the weights live
/// in a flat tensor and cloning them into a [`Matrix`] would defeat the
/// zero-allocation step. Bit-identical to [`bilevel_l1inf_into`] on the
/// same data (same kernels per column; the untouched-column copy branch
/// becomes a no-op in place). Thresholds land in `ws.thresholds`.
pub fn bilevel_l1inf_inplace_cols<T: Scalar>(
    data: &mut [T],
    rows: usize,
    eta: T,
    algo: L1Algorithm,
    ws: &mut Workspace<T>,
) {
    assert!(eta >= T::ZERO, "bilevel projection: radius must be non-negative");
    assert!(rows > 0, "bilevel_l1inf_inplace_cols: rows must be positive");
    assert_eq!(data.len() % rows, 0, "bilevel_l1inf_inplace_cols: ragged buffer");
    ws.norms.clear();
    ws.norms.extend(data.chunks_exact(rows).map(kernels::colmax));
    ws.thresholds.clear();
    ws.thresholds.extend_from_slice(&ws.norms);
    l1::project_l1_nonneg_inplace_with(&mut ws.thresholds, eta, algo, &mut ws.condat);
    for (j, col) in data.chunks_exact_mut(rows).enumerate() {
        if ws.thresholds[j] < ws.norms[j] {
            kernels::clip_inplace(col, ws.thresholds[j]);
        }
    }
}

/// `BP¹,¹_η(Y)` — paper Algorithm 2 (inner ℓ1 projection per column).
pub fn bilevel_l11_with<T: Scalar>(y: &Matrix<T>, eta: T, algo: L1Algorithm) -> BilevelResult<T> {
    bilevel_generic(y, eta, algo, crate::tensor::vec_ops::l1, |col, r| {
        l1::project_l1_inplace(col, r, algo)
    })
}

/// `BP¹,²_η(Y)` — paper Algorithm 3 (column rescale).
pub fn bilevel_l12_with<T: Scalar>(y: &Matrix<T>, eta: T, algo: L1Algorithm) -> BilevelResult<T> {
    bilevel_generic(y, eta, algo, crate::tensor::vec_ops::l2, l2::project_l2_inplace)
}

/// Convenience wrapper: `BP¹,∞` with the default (Condat) inner solver.
pub fn bilevel_l1inf<T: Scalar>(y: &Matrix<T>, eta: T) -> Matrix<T> {
    bilevel_l1inf_with(y, eta, L1Algorithm::Condat).x
}

/// Convenience wrapper: `BP¹,¹` with the default inner solver.
pub fn bilevel_l11<T: Scalar>(y: &Matrix<T>, eta: T) -> Matrix<T> {
    bilevel_l11_with(y, eta, L1Algorithm::Condat).x
}

/// Convenience wrapper: `BP¹,²` with the default inner solver.
pub fn bilevel_l12<T: Scalar>(y: &Matrix<T>, eta: T) -> Matrix<T> {
    bilevel_l12_with(y, eta, L1Algorithm::Condat).x
}

/// Dispatch by variant.
pub fn bilevel<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    variant: BilevelVariant,
    algo: L1Algorithm,
) -> BilevelResult<T> {
    match variant {
        BilevelVariant::L1Inf => bilevel_l1inf_with(y, eta, algo),
        BilevelVariant::L11 => bilevel_l11_with(y, eta, algo),
        BilevelVariant::L12 => bilevel_l12_with(y, eta, algo),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::norms::*;
    use crate::rng::Xoshiro256pp;

    fn randmat(n: usize, m: usize, seed: u64) -> Matrix<f64> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Matrix::randn(n, m, &mut rng)
    }

    #[test]
    fn l1inf_feasible_and_tight() {
        let y = randmat(30, 20, 1);
        let norm0 = l1inf_norm(&y);
        let eta = norm0 * 0.3;
        let r = bilevel_l1inf_with(&y, eta, L1Algorithm::Condat);
        let norm1 = l1inf_norm(&r.x);
        assert!((norm1 - eta).abs() < 1e-9, "projection should be tight: {norm1} vs {eta}");
    }

    #[test]
    fn inside_ball_is_identity() {
        let y = randmat(10, 8, 2);
        let eta = l1inf_norm(&y) * 2.0;
        let r = bilevel_l1inf_with(&y, eta, L1Algorithm::Condat);
        assert!(y.max_abs_diff(&r.x) < 1e-12);
    }

    #[test]
    fn contraction_property_remark_iii_1() {
        let y = randmat(25, 40, 3);
        let r = bilevel_l1inf_with(&y, 2.0, L1Algorithm::Condat);
        for (j, col) in y.columns().enumerate() {
            let linf = crate::tensor::vec_ops::linf(col);
            assert!(r.thresholds[j] >= 0.0);
            assert!(r.thresholds[j] <= linf + 1e-12);
        }
    }

    #[test]
    fn identity_proposition_iii_3() {
        // ||Y - BP(Y)||_{1,inf} + ||BP(Y)||_{1,inf} == ||Y||_{1,inf}
        for seed in 0..10 {
            let y = randmat(15, 12, 100 + seed);
            let eta = l1inf_norm(&y) * 0.2;
            let x = bilevel_l1inf(&y, eta);
            let lhs = l1inf_norm(&y.sub(&x)) + l1inf_norm(&x);
            let rhs = l1inf_norm(&y);
            assert!((lhs - rhs).abs() < 1e-9, "identity violated: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn identity_proposition_iv_1_l11() {
        for seed in 0..10 {
            let y = randmat(15, 12, 200 + seed);
            let eta = l11_norm(&y) * 0.2;
            let x = bilevel_l11(&y, eta);
            let lhs = l11_norm(&y.sub(&x)) + l11_norm(&x);
            let rhs = l11_norm(&y);
            assert!((lhs - rhs).abs() < 1e-9, "l11 identity violated: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn identity_proposition_iv_2_l12() {
        for seed in 0..10 {
            let y = randmat(15, 12, 300 + seed);
            let eta = l12_norm(&y) * 0.2;
            let x = bilevel_l12(&y, eta);
            let lhs = l12_norm(&y.sub(&x)) + l12_norm(&x);
            let rhs = l12_norm(&y);
            assert!((lhs - rhs).abs() < 1e-9, "l12 identity violated: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn produces_structured_column_sparsity() {
        // With a small radius, weak columns must be zeroed entirely.
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let mut y = Matrix::<f64>::randn(50, 30, &mut rng);
        // boost a few columns so the others get killed
        for j in 0..5 {
            for v in y.col_mut(j) {
                *v *= 50.0;
            }
        }
        let r = bilevel_l1inf_with(&y, 10.0, L1Algorithm::Condat);
        let zeros = r.x.zero_columns(0.0);
        assert!(zeros.len() >= 20, "expected many zero columns, got {}", zeros.len());
        // thresholds report the same pattern
        assert_eq!(r.zero_columns(), zeros);
    }

    #[test]
    fn all_inner_algorithms_agree() {
        let y = randmat(40, 25, 7);
        let eta = 3.0;
        let base = bilevel_l1inf_with(&y, eta, L1Algorithm::Sort).x;
        for algo in L1Algorithm::all() {
            let x = bilevel_l1inf_with(&y, eta, *algo).x;
            assert!(
                base.max_abs_diff(&x) < 1e-8,
                "{} disagrees with sort",
                algo.name()
            );
        }
    }

    #[test]
    fn thresholds_equal_projected_column_norms() {
        // û_j = ||x_j||_inf (for non-zeroed columns) — the paper uses this
        // right after eq. (15).
        let y = randmat(20, 15, 8);
        let r = bilevel_l1inf_with(&y, 2.5, L1Algorithm::Condat);
        for (j, col) in r.x.columns().enumerate() {
            let got = crate::tensor::vec_ops::linf(col);
            // clipping attains the threshold whenever the original column
            // exceeded it; otherwise the column is untouched and below it.
            assert!(got <= r.thresholds[j] + 1e-12);
        }
    }

    #[test]
    fn zero_radius_zeroes_matrix() {
        let y = randmat(5, 5, 9);
        for variant in BilevelVariant::all() {
            let r = bilevel(&y, 0.0, *variant, L1Algorithm::Condat);
            assert_eq!(r.x.count_zeros(0.0), 25, "{}", variant.name());
        }
    }

    #[test]
    fn single_column_reduces_to_vector_projection() {
        // With m=1 the inner projection maps v to min(v, eta) ... i.e. the
        // column is clipped at eta.
        let y = Matrix::from_row_major(4, 1, &[3.0f64, -2.0, 0.5, -4.0]);
        let x = bilevel_l1inf(&y, 1.0);
        assert_eq!(x.col(0), &[1.0, -1.0, 0.5, -1.0]);
    }

    #[test]
    fn single_row_reduces_to_l1_projection() {
        // With n=1 the column inf-norms are |y_j|, clipping reproduces the
        // plain l1-ball projection of the row.
        let y = Matrix::from_row_major(1, 4, &[3.0f64, -2.0, 0.5, -4.0]);
        let x = bilevel_l1inf(&y, 2.0);
        let direct = crate::projection::l1::project_l1(
            &[3.0, -2.0, 0.5, -4.0],
            2.0,
            L1Algorithm::Sort,
        );
        for j in 0..4 {
            assert!((x.get(0, j) - direct[j]).abs() < 1e-9);
        }
    }

    #[test]
    fn inplace_cols_matches_with_bitwise() {
        let mut ws = Workspace::new();
        for (seed, n, m, eta) in
            [(1u64, 16, 24, 1.5), (2, 1, 9, 0.2), (3, 33, 7, 4.0), (4, 8, 8, 1e6)]
        {
            let y = randmat(n, m, 500 + seed);
            let r = bilevel_l1inf_with(&y, eta, L1Algorithm::Condat);
            let mut flat = y.as_slice().to_vec();
            bilevel_l1inf_inplace_cols(&mut flat, n, eta, L1Algorithm::Condat, &mut ws);
            for (a, b) in r.x.as_slice().iter().zip(flat.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{n}x{m}");
            }
            for (a, b) in r.thresholds.iter().zip(ws.thresholds().iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{n}x{m} thresholds");
            }
        }
    }

    #[test]
    fn into_matches_with_bitwise_and_reuses_buffers() {
        let mut ws = Workspace::new();
        let mut out = Matrix::zeros(0, 0);
        // Varying shapes through one workspace: buffers grow monotonically
        // and results stay bit-identical to the one-shot entry point.
        for (seed, n, m, eta) in
            [(1u64, 30, 20, 2.0), (2, 1, 17, 0.5), (3, 17, 1, 0.1), (4, 64, 48, 5.0)]
        {
            let y = randmat(n, m, 400 + seed);
            let r = bilevel_l1inf_with(&y, eta, L1Algorithm::Condat);
            bilevel_l1inf_into(&y, eta, L1Algorithm::Condat, &mut ws, &mut out);
            assert_eq!((out.rows(), out.cols()), (n, m));
            for (a, b) in r.x.as_slice().iter().zip(out.as_slice().iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{n}x{m}");
            }
            assert_eq!(r.thresholds.len(), ws.thresholds().len());
            for (a, b) in r.thresholds.iter().zip(ws.thresholds().iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{n}x{m} thresholds");
            }
        }
    }

    #[test]
    fn f32_matches_f64_loosely() {
        let y64 = randmat(30, 20, 11);
        let y32: Matrix<f32> = y64.cast();
        let x64 = bilevel_l1inf(&y64, 2.0);
        let x32 = bilevel_l1inf(&y32, 2.0f32);
        let x32u: Matrix<f64> = x32.cast();
        assert!(x64.max_abs_diff(&x32u) < 1e-4);
    }
}
