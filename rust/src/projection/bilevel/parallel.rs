//! Multi-threaded `BP¹,∞` for large matrices.
//!
//! Both stages parallelize trivially over columns (the only cross-column
//! coupling is the m-dimensional inner ℓ1 projection, which is cheap):
//! stage 1 computes column ∞-norms in parallel, the inner projection runs
//! single-threaded, stage 2 clips columns in parallel — straight from the
//! source into the output buffer, so the old clone-then-clip extra write
//! pass is gone.
//!
//! Work is dispatched through the persistent parking
//! [`crate::kernels::pool`] (spawned once, condvar-parked between jobs)
//! instead of the seed's scoped spawn-per-call threads. A dispatch costs a
//! mutex/condvar wake (typically ~1–5 µs) instead of a thread spawn
//! (~20–50 µs), which is why the [`ParallelPolicy::min_elems`] default
//! dropped from the measured `1 << 16` of the spawn era to an estimated
//! `1 << 13` — re-measure the crossover on your hardware with
//! `bilevel bench kernels` (EXPERIMENTS.md §Perf) and override the policy
//! if it lands elsewhere.

use crate::kernels::pool::{self, SendPtr};
use crate::kernels::{self, Workspace};
use crate::projection::l1::{self, L1Algorithm};
use crate::scalar::Scalar;
use crate::tensor::Matrix;

use super::BilevelResult;

/// Threading policy for the parallel bi-level projection.
#[derive(Clone, Copy, Debug)]
pub struct ParallelPolicy {
    /// Maximum parallel parts a projection is split into (0 ⇒
    /// `available_parallelism`). The parts execute on the shared kernel
    /// pool; this caps the split, not the pool size.
    pub threads: usize,
    /// Below this element count, run sequentially. Default `1 << 13`
    /// (8 192 elements, e.g. 64×128): the spawn-per-call implementation
    /// this pool replaced had its crossover measured at `1 << 16`, and a
    /// pool dispatch costs roughly an order of magnitude less than a
    /// spawn, so the default scales that measurement down accordingly —
    /// an estimate until `bilevel bench kernels` is run on the target
    /// hardware (its `crossover/probe` rows re-measure it).
    pub min_elems: usize,
}

impl Default for ParallelPolicy {
    fn default() -> Self {
        Self { threads: 0, min_elems: 1 << 13 }
    }
}

impl ParallelPolicy {
    /// The default policy with `min_elems` overridden by the
    /// `BILEVEL_MIN_ELEMS` environment variable when it is set to a valid
    /// `usize` (anything else — unset, empty, non-numeric — leaves the
    /// built-in default). This is how a crossover measured by
    /// `bilevel bench kernels --autotune` (its `recommended_min_elems`
    /// output) is fed back into production without a recompile; the CLI
    /// and the serve engine construct their policies through this.
    pub fn from_env_or_default() -> Self {
        let mut policy = Self::default();
        if let Ok(v) = std::env::var("BILEVEL_MIN_ELEMS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                policy.min_elems = n;
            }
        }
        policy
    }

    pub(crate) fn effective_threads(&self, work_items: usize) -> usize {
        let hw = if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        };
        hw.min(work_items).max(1)
    }
}

/// Parallel `BP¹,∞_η(Y)`. Semantically identical to
/// [`super::bilevel_l1inf_with`]; used by the trainer and the benches for
/// large matrices.
pub fn bilevel_l1inf_parallel<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    algo: L1Algorithm,
    policy: ParallelPolicy,
) -> BilevelResult<T> {
    let mut ws = Workspace::new();
    let mut out = Matrix::zeros(y.rows(), y.cols());
    bilevel_l1inf_parallel_into(y, eta, algo, policy, &mut ws, &mut out);
    BilevelResult { x: out, thresholds: std::mem::take(&mut ws.thresholds) }
}

/// Workspace-based parallel `BP¹,∞` — the zero-allocation steady-state
/// variant of [`bilevel_l1inf_parallel`]; bit-identical to the sequential
/// [`super::bilevel_l1inf_into`] (same kernels, per column).
pub fn bilevel_l1inf_parallel_into<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    algo: L1Algorithm,
    policy: ParallelPolicy,
    ws: &mut Workspace<T>,
    out: &mut Matrix<T>,
) {
    assert!(eta >= T::ZERO);
    let (n, m) = (y.rows(), y.cols());
    if n == 0 || n * m < policy.min_elems || m < 2 {
        return super::bilevel_l1inf_into(y, eta, algo, ws, out);
    }
    let parts = policy.effective_threads(m);
    let chunk = m.div_ceil(parts);
    let pool = pool::global();

    // Stage 1: column inf-norms, parallel over column chunks. Each part
    // derives a disjoint slice of the norm buffer from its index.
    ws.norms.clear();
    ws.norms.resize(m, T::ZERO);
    {
        let norms_ptr = SendPtr(ws.norms.as_mut_ptr());
        pool.run(parts, |t| {
            let j0 = t * chunk;
            if j0 >= m {
                return;
            }
            let j1 = (j0 + chunk).min(m);
            let base = norms_ptr.get();
            // SAFETY: parts derive disjoint [j0, j1) column ranges from
            // `t`, and `ws.norms` outlives the blocking `run` call.
            let norms = unsafe { std::slice::from_raw_parts_mut(base.add(j0), j1 - j0) };
            for (dj, o) in norms.iter_mut().enumerate() {
                *o = kernels::colmax(y.col(j0 + dj));
            }
        });
    }

    // Inner l1 projection of the norm vector (cheap, sequential).
    ws.thresholds.clear();
    ws.thresholds.extend_from_slice(&ws.norms);
    l1::project_l1_nonneg_inplace_with(&mut ws.thresholds, eta, algo, &mut ws.condat);

    // Stage 2: fused clip, parallel over disjoint column ranges of the
    // output buffer.
    out.resize_reuse(n, m);
    {
        let src = y.as_slice();
        let u = &ws.thresholds;
        let v = &ws.norms;
        let dst_ptr = SendPtr(out.as_mut_slice().as_mut_ptr());
        pool.run(parts, |t| {
            let j0 = t * chunk;
            if j0 >= m {
                return;
            }
            let j1 = (j0 + chunk).min(m);
            // SAFETY: parts derive disjoint [j0*n, j1*n) element ranges
            // from `t`, and `out` outlives the blocking `run` call.
            let dst = unsafe {
                std::slice::from_raw_parts_mut(dst_ptr.get().add(j0 * n), (j1 - j0) * n)
            };
            kernels::clip_groups_into(
                &src[j0 * n..j1 * n],
                n,
                &u[j0..j1],
                &v[j0..j1],
                dst,
            );
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn matches_sequential() {
        // min_elems: 0 keeps the pool path engaged even at the small
        // Miri-friendly shape, so the interpreter still checks the raw
        // split-borrow writes.
        let (n, m) = if cfg!(miri) { (16, 33) } else { (128, 300) };
        let mut rng = Xoshiro256pp::seed_from_u64(55);
        let y = Matrix::<f64>::randn(n, m, &mut rng);
        let seq = crate::projection::bilevel::bilevel_l1inf_with(&y, 5.0, L1Algorithm::Condat);
        let par = bilevel_l1inf_parallel(
            &y,
            5.0,
            L1Algorithm::Condat,
            ParallelPolicy { threads: 4, min_elems: 0 },
        );
        assert!(seq.x.max_abs_diff(&par.x) < 1e-12);
        assert_eq!(seq.thresholds.len(), par.thresholds.len());
        for (a, b) in seq.thresholds.iter().zip(par.thresholds.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn parallel_is_bit_identical_to_sequential() {
        // Stronger than `matches_sequential`: the pool path runs the same
        // kernels per column, so results agree to the last bit.
        let mut rng = Xoshiro256pp::seed_from_u64(60);
        let shapes: &[(usize, usize)] =
            if cfg!(miri) { &[(8, 37)] } else { &[(64, 129), (200, 33), (16, 1024)] };
        for &(n, m) in shapes {
            let y = Matrix::<f64>::randn(n, m, &mut rng);
            let seq =
                crate::projection::bilevel::bilevel_l1inf_with(&y, 3.0, L1Algorithm::Condat);
            let par = bilevel_l1inf_parallel(
                &y,
                3.0,
                L1Algorithm::Condat,
                ParallelPolicy { threads: 7, min_elems: 0 },
            );
            for (a, b) in seq.x.as_slice().iter().zip(par.x.as_slice().iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{n}x{m}");
            }
            for (a, b) in seq.thresholds.iter().zip(par.thresholds.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{n}x{m} thresholds");
            }
        }
    }

    #[test]
    fn small_input_falls_back_to_sequential() {
        let mut rng = Xoshiro256pp::seed_from_u64(56);
        let y = Matrix::<f64>::randn(4, 3, &mut rng);
        let r = bilevel_l1inf_parallel(&y, 1.0, L1Algorithm::Condat, ParallelPolicy::default());
        let seq = crate::projection::bilevel::bilevel_l1inf_with(&y, 1.0, L1Algorithm::Condat);
        assert!(r.x.max_abs_diff(&seq.x) < 1e-15);
    }

    #[test]
    fn ragged_chunking_covers_all_columns() {
        // m not divisible by threads exercises the tail chunk.
        let mut rng = Xoshiro256pp::seed_from_u64(57);
        let y = Matrix::<f64>::randn(16, 97, &mut rng);
        let par = bilevel_l1inf_parallel(
            &y,
            2.0,
            L1Algorithm::Condat,
            ParallelPolicy { threads: 5, min_elems: 0 },
        );
        let seq = crate::projection::bilevel::bilevel_l1inf_with(&y, 2.0, L1Algorithm::Condat);
        assert!(par.x.max_abs_diff(&seq.x) < 1e-12);
    }

    #[test]
    fn single_thread_policy() {
        let mut rng = Xoshiro256pp::seed_from_u64(58);
        let y = Matrix::<f64>::randn(32, 32, &mut rng);
        let par = bilevel_l1inf_parallel(
            &y,
            1.5,
            L1Algorithm::Condat,
            ParallelPolicy { threads: 1, min_elems: 0 },
        );
        let seq = crate::projection::bilevel::bilevel_l1inf_with(&y, 1.5, L1Algorithm::Condat);
        assert!(par.x.max_abs_diff(&seq.x) < 1e-15);
    }

    #[test]
    fn policy_from_env_honours_min_elems_override() {
        // No other test reads BILEVEL_MIN_ELEMS, and `from_env_or_default`
        // reads it fresh on every call (unlike the cached ISA dispatch),
        // so setting and removing it here is race-free.
        std::env::remove_var("BILEVEL_MIN_ELEMS");
        assert_eq!(
            ParallelPolicy::from_env_or_default().min_elems,
            ParallelPolicy::default().min_elems
        );
        std::env::set_var("BILEVEL_MIN_ELEMS", "4096");
        assert_eq!(ParallelPolicy::from_env_or_default().min_elems, 4096);
        std::env::set_var("BILEVEL_MIN_ELEMS", "not-a-number");
        assert_eq!(
            ParallelPolicy::from_env_or_default().min_elems,
            ParallelPolicy::default().min_elems
        );
        std::env::remove_var("BILEVEL_MIN_ELEMS");
    }

    #[test]
    fn parallel_into_reuses_workspace() {
        let mut rng = Xoshiro256pp::seed_from_u64(59);
        let mut ws = Workspace::new();
        let mut out = Matrix::zeros(0, 0);
        let (n, m) = if cfg!(miri) { (12, 40) } else { (48, 160) };
        for _ in 0..3 {
            let y = Matrix::<f64>::randn(n, m, &mut rng);
            bilevel_l1inf_parallel_into(
                &y,
                2.5,
                L1Algorithm::Condat,
                ParallelPolicy { threads: 3, min_elems: 0 },
                &mut ws,
                &mut out,
            );
            let seq =
                crate::projection::bilevel::bilevel_l1inf_with(&y, 2.5, L1Algorithm::Condat);
            assert!(out.max_abs_diff(&seq.x) == 0.0);
        }
    }
}
