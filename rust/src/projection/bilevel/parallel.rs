//! Multi-threaded `BP¹,∞` for large matrices.
//!
//! Both stages parallelize trivially over columns (the only cross-column
//! coupling is the m-dimensional inner ℓ1 projection, which is cheap):
//! stage 1 computes column ∞-norms in parallel, the inner projection runs
//! single-threaded, stage 2 clips columns in parallel. Scoped std threads —
//! no rayon offline.
//!
//! The sequential path is kept for small inputs where thread spawn overhead
//! dominates (crossover measured in `benches/fig1_time.rs`, see
//! EXPERIMENTS.md §Perf).

use crate::projection::l1::{self, L1Algorithm};
use crate::scalar::Scalar;
use crate::tensor::{vec_ops, Matrix};

use super::BilevelResult;

/// Threading policy for the parallel bi-level projection.
#[derive(Clone, Copy, Debug)]
pub struct ParallelPolicy {
    /// Number of worker threads (0 ⇒ `available_parallelism`).
    pub threads: usize,
    /// Below this element count, run sequentially.
    pub min_elems: usize,
}

impl Default for ParallelPolicy {
    fn default() -> Self {
        Self { threads: 0, min_elems: 1 << 16 }
    }
}

impl ParallelPolicy {
    fn effective_threads(&self, work_items: usize) -> usize {
        let hw = if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        };
        hw.min(work_items).max(1)
    }
}

/// Parallel `BP¹,∞_η(Y)`. Semantically identical to
/// [`super::bilevel_l1inf_with`]; used by the trainer and the benches for
/// large matrices.
pub fn bilevel_l1inf_parallel<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    algo: L1Algorithm,
    policy: ParallelPolicy,
) -> BilevelResult<T> {
    assert!(eta >= T::ZERO);
    let (n, m) = (y.rows(), y.cols());
    if n * m < policy.min_elems || m < 2 {
        return super::bilevel_l1inf_with(y, eta, algo);
    }
    let threads = policy.effective_threads(m);

    // Stage 1: column inf-norms, parallel over column chunks.
    let mut v = vec![T::ZERO; m];
    let chunk = m.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, out_chunk) in v.chunks_mut(chunk).enumerate() {
            let y_ref = &y;
            s.spawn(move || {
                let j0 = t * chunk;
                for (dj, o) in out_chunk.iter_mut().enumerate() {
                    *o = vec_ops::linf(y_ref.col(j0 + dj));
                }
            });
        }
    });

    // Inner l1 projection of the norm vector (cheap, sequential).
    let u = l1::project_l1(&v, eta, algo);

    // Stage 2: clip columns in parallel. Work directly on the column-major
    // buffer so each worker owns a disjoint contiguous region.
    let mut x = y.clone();
    let rows = n;
    std::thread::scope(|s| {
        let data = x.as_mut_slice();
        for (t, cols_chunk) in data.chunks_mut(chunk * rows).enumerate() {
            let u_ref = &u;
            s.spawn(move || {
                let j0 = t * chunk;
                for (dj, col) in cols_chunk.chunks_mut(rows).enumerate() {
                    let c = u_ref[j0 + dj];
                    for val in col.iter_mut() {
                        *val = val.signum_s() * val.abs().min_s(c);
                    }
                }
            });
        }
    });

    BilevelResult { x, thresholds: u }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn matches_sequential() {
        let mut rng = Xoshiro256pp::seed_from_u64(55);
        let y = Matrix::<f64>::randn(128, 300, &mut rng);
        let seq = crate::projection::bilevel::bilevel_l1inf_with(&y, 5.0, L1Algorithm::Condat);
        let par = bilevel_l1inf_parallel(
            &y,
            5.0,
            L1Algorithm::Condat,
            ParallelPolicy { threads: 4, min_elems: 0 },
        );
        assert!(seq.x.max_abs_diff(&par.x) < 1e-12);
        assert_eq!(seq.thresholds.len(), par.thresholds.len());
        for (a, b) in seq.thresholds.iter().zip(par.thresholds.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn small_input_falls_back_to_sequential() {
        let mut rng = Xoshiro256pp::seed_from_u64(56);
        let y = Matrix::<f64>::randn(4, 3, &mut rng);
        let r = bilevel_l1inf_parallel(&y, 1.0, L1Algorithm::Condat, ParallelPolicy::default());
        let seq = crate::projection::bilevel::bilevel_l1inf_with(&y, 1.0, L1Algorithm::Condat);
        assert!(r.x.max_abs_diff(&seq.x) < 1e-15);
    }

    #[test]
    fn ragged_chunking_covers_all_columns() {
        // m not divisible by threads exercises the tail chunk.
        let mut rng = Xoshiro256pp::seed_from_u64(57);
        let y = Matrix::<f64>::randn(16, 97, &mut rng);
        let par = bilevel_l1inf_parallel(
            &y,
            2.0,
            L1Algorithm::Condat,
            ParallelPolicy { threads: 5, min_elems: 0 },
        );
        let seq = crate::projection::bilevel::bilevel_l1inf_with(&y, 2.0, L1Algorithm::Condat);
        assert!(par.x.max_abs_diff(&seq.x) < 1e-12);
    }

    #[test]
    fn single_thread_policy() {
        let mut rng = Xoshiro256pp::seed_from_u64(58);
        let y = Matrix::<f64>::randn(32, 32, &mut rng);
        let par = bilevel_l1inf_parallel(
            &y,
            1.5,
            L1Algorithm::Condat,
            ParallelPolicy { threads: 1, min_elems: 0 },
        );
        let seq = crate::projection::bilevel::bilevel_l1inf_with(&y, 1.5, L1Algorithm::Condat);
        assert!(par.x.max_abs_diff(&seq.x) < 1e-15);
    }
}
