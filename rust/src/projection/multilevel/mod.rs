//! Multi-level projection trees — the recursive generalization of the
//! paper's bi-level operators (sequel paper: "Multi-level projection with
//! exponential parallel speedup", arXiv 2405.02086).
//!
//! A [`MultilevelSpec`] is a root-to-leaf list of levels, each carrying a
//! norm (ℓ1 / ℓ2 / ℓ∞) and, for intermediate levels, a fanout that groups
//! the level below into contiguous blocks. Leaves are the matrix columns
//! (their norm is taken over the column's entries); the root is a single
//! node whose ball radius is the projection radius η. The tree norm is the
//! nested composition, e.g. `l1/linf` is exactly the paper's
//! `‖Y‖₁,∞ = Σ_j ‖y_j‖∞` and `linf/l1` its dual `‖Y‖∞,₁`.
//!
//! Projection runs in three passes, mirroring Algorithm 1 level by level:
//!
//! 1. **Upward** — aggregate each column by the leaf norm (dispatched onto
//!    the persistent [`crate::kernels::pool`] over column chunks), then
//!    fold intermediate levels bottom-up (short vectors, sequential).
//! 2. **Downward** — the root projects its children's aggregate vector
//!    onto the level-0 norm ball of radius η; each resulting child radius
//!    recursively constrains its own children, down to a per-column
//!    target radius.
//! 3. **Leaf apply** — every column is projected onto the leaf-norm ball
//!    of its target radius (pool-parallel over column chunks, through the
//!    same shared kernels as the bi-level path).
//!
//! The depth-2 `l1/linf` tree runs the *identical* kernel sequence as
//! [`crate::projection::bilevel::bilevel_l1inf_into`] (per-column `colmax`,
//! one inner non-negative ℓ1 projection, one fused `clip_groups_into`), so
//! its output is bit-identical to `bilevel_l1inf` — pinned by the tests
//! here and the `projection_family_conformance` proptest.

use crate::kernels::pool::{self, SendPtr};
use crate::kernels::{self, CondatScratch};
use crate::projection::bilevel::ParallelPolicy;
use crate::projection::l1::{self, L1Algorithm};
use crate::projection::l2;
use crate::projection::linf1::newton_l1_threshold;
use crate::scalar::Scalar;
use crate::tensor::Matrix;

/// The norm attached to one level of the tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LevelNorm {
    L1,
    L2,
    LInf,
}

impl LevelNorm {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "l1" => Some(Self::L1),
            "l2" => Some(Self::L2),
            "linf" | "inf" => Some(Self::LInf),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::L1 => "l1",
            Self::L2 => "l2",
            Self::LInf => "linf",
        }
    }
}

/// One level of a [`MultilevelSpec`]: its norm and, for intermediate
/// levels, how many nodes of the level below each node groups.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Level {
    pub norm: LevelNorm,
    /// Children per node. `None` on the root (it owns the whole level
    /// below) and on the leaf level (columns own their entries).
    pub fanout: Option<usize>,
}

/// A root-to-leaf projection-tree specification, parsed from strings like
/// `"l1/linf"` (the paper's bi-level ℓ1,∞) or `"l1/l2:8/linf"` (a depth-3
/// tree whose middle ℓ2 nodes each group 8 columns).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MultilevelSpec {
    pub levels: Vec<Level>,
}

impl MultilevelSpec {
    /// Parse `norm[:fanout]/.../norm`. Depth must be ≥ 2; fanout is
    /// required on intermediate levels and rejected on the root and leaf
    /// levels (their groupings are implied).
    pub fn parse(s: &str) -> Result<Self, String> {
        let segs: Vec<&str> = s.split('/').collect();
        if segs.len() < 2 {
            return Err(format!(
                "multilevel spec {s:?} has depth {}, need at least 2 (e.g. \"l1/linf\")",
                segs.len()
            ));
        }
        let last = segs.len() - 1;
        let mut levels = Vec::with_capacity(segs.len());
        for (i, seg) in segs.iter().enumerate() {
            let (name, fanout) = match seg.split_once(':') {
                Some((name, f)) => {
                    let f: usize = f
                        .parse()
                        .ok()
                        .filter(|&f| f >= 1)
                        .ok_or_else(|| format!("level {seg:?}: fanout must be a positive integer"))?;
                    (name, Some(f))
                }
                None => (*seg, None),
            };
            let norm = LevelNorm::parse(name)
                .ok_or_else(|| format!("level {seg:?}: unknown norm {name:?} (l1|l2|linf)"))?;
            if fanout.is_some() && (i == 0 || i == last) {
                return Err(format!(
                    "level {seg:?}: fanout is only valid on intermediate levels \
                     (the root spans the whole level below, leaves span their column)"
                ));
            }
            if fanout.is_none() && i != 0 && i != last {
                return Err(format!(
                    "level {seg:?}: intermediate levels need an explicit fanout, e.g. \"{name}:8\""
                ));
            }
            levels.push(Level { norm, fanout });
        }
        Ok(Self { levels })
    }

    /// The canonical string form; `parse(format())` round-trips.
    pub fn format(&self) -> String {
        self.levels
            .iter()
            .map(|l| match l.fanout {
                Some(f) => format!("{}:{f}", l.norm.name()),
                None => l.norm.name().to_string(),
            })
            .collect::<Vec<_>>()
            .join("/")
    }

    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// The paper's bi-level ℓ1,∞ tree, `"l1/linf"`.
    pub fn bilevel_l1inf() -> Self {
        Self {
            levels: vec![
                Level { norm: LevelNorm::L1, fanout: None },
                Level { norm: LevelNorm::LInf, fanout: None },
            ],
        }
    }

    /// Node counts per level for an `m`-column matrix: `counts[depth-1]
    /// = m` (leaves are columns), each intermediate level has
    /// `ceil(below / fanout)` nodes, the root is a single node.
    pub fn counts(&self, m: usize) -> Vec<usize> {
        let d = self.levels.len();
        let mut counts = vec![1usize; d];
        counts[d - 1] = m;
        for i in (1..d - 1).rev() {
            let f = self.levels[i].fanout.unwrap_or(counts[i + 1]).max(1);
            counts[i] = counts[i + 1].div_ceil(f);
        }
        counts
    }
}

/// Reusable per-level buffers: `agg[i]` holds the upward aggregates of
/// level `i`, `radii[i]` the downward target radii (index 0 is unused —
/// the root's radius is η). Zero heap allocations at steady state.
pub struct MultilevelWorkspace<T: Scalar> {
    agg: Vec<Vec<T>>,
    radii: Vec<Vec<T>>,
    condat: CondatScratch<T>,
}

impl<T: Scalar> MultilevelWorkspace<T> {
    pub fn new() -> Self {
        Self { agg: Vec::new(), radii: Vec::new(), condat: CondatScratch::new() }
    }

    fn prepare(&mut self, depth: usize) {
        if self.agg.len() < depth {
            self.agg.resize_with(depth, Vec::new);
            self.radii.resize_with(depth, Vec::new);
        }
    }
}

impl<T: Scalar> Default for MultilevelWorkspace<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// A level norm applied to a plain vector (aggregates are non-negative,
/// so ℓ1 degenerates to `sum_abs`).
fn vec_norm<T: Scalar>(norm: LevelNorm, xs: &[T]) -> T {
    match norm {
        LevelNorm::L1 => kernels::sum_abs(xs),
        LevelNorm::L2 => kernels::l2_norm(xs),
        LevelNorm::LInf => kernels::colmax(xs),
    }
}

/// Project a non-negative aggregate vector onto the `norm`-ball of radius
/// `r`, in place.
fn project_vec_ball<T: Scalar>(
    norm: LevelNorm,
    v: &mut [T],
    r: T,
    algo: L1Algorithm,
    scratch: &mut CondatScratch<T>,
) {
    match norm {
        LevelNorm::L1 => l1::project_l1_nonneg_inplace_with(v, r, algo, scratch),
        LevelNorm::L2 => l2::project_l2_inplace(v, r),
        LevelNorm::LInf => kernels::clip_inplace(v, r),
    }
}

/// Leaf apply over columns `[j0, j1)`: project each column of `src` onto
/// the leaf-norm ball of its target radius, writing into `dst` (which
/// covers exactly those columns). Shared by the sequential path and each
/// pool part, so chunked and whole-matrix runs are bit-identical.
fn apply_leaf_range<T: Scalar>(
    leaf: LevelNorm,
    src: &[T],
    n: usize,
    j0: usize,
    j1: usize,
    radii: &[T],
    agg: &[T],
    dst: &mut [T],
) {
    match leaf {
        LevelNorm::LInf => kernels::clip_groups_into(
            &src[j0 * n..j1 * n],
            n.max(1),
            &radii[j0..j1],
            &agg[j0..j1],
            dst,
        ),
        LevelNorm::L1 => {
            for j in j0..j1 {
                let col = &src[j * n..(j + 1) * n];
                let d = &mut dst[(j - j0) * n..(j - j0 + 1) * n];
                d.copy_from_slice(col);
                let (w, a) = (radii[j], agg[j]);
                if a <= w {
                    continue;
                }
                if w <= T::ZERO {
                    d.fill(T::ZERO);
                } else {
                    kernels::soft_threshold_inplace(d, newton_l1_threshold(col, w));
                }
            }
        }
        LevelNorm::L2 => {
            for j in j0..j1 {
                let d = &mut dst[(j - j0) * n..(j - j0 + 1) * n];
                d.copy_from_slice(&src[j * n..(j + 1) * n]);
                let (w, a) = (radii[j], agg[j]);
                if a > w {
                    let scale = if a > T::ZERO { w / a } else { T::ZERO };
                    kernels::scale_inplace(d, scale);
                }
            }
        }
    }
}

/// The tree norm `‖Y‖_spec` — the upward pass alone, without projecting.
/// `tree_norm(y, "l1/linf") == l1inf_norm(y)` and
/// `tree_norm(y, "linf/l1") == linf1_norm(y)`.
pub fn tree_norm<T: Scalar>(y: &Matrix<T>, spec: &MultilevelSpec) -> T {
    let d = spec.levels.len();
    assert!(d >= 2, "multilevel spec must have depth >= 2");
    if y.is_empty() {
        return T::ZERO;
    }
    let mut cur: Vec<T> =
        y.columns().map(|c| vec_norm(spec.levels[d - 1].norm, c)).collect();
    for i in (1..d - 1).rev() {
        let f = spec.levels[i].fanout.unwrap_or(cur.len()).max(1);
        cur = cur.chunks(f).map(|c| vec_norm(spec.levels[i].norm, c)).collect();
    }
    vec_norm(spec.levels[0].norm, &cur)
}

/// Workspace-based multi-level projection — zero heap allocations at
/// steady state. Leaf stages (column aggregation, leaf apply) run on the
/// kernel pool when the matrix clears `policy.min_elems`; internal levels
/// are short vectors and stay sequential.
pub fn project_multilevel_into<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    spec: &MultilevelSpec,
    algo: L1Algorithm,
    policy: ParallelPolicy,
    ws: &mut MultilevelWorkspace<T>,
    out: &mut Matrix<T>,
) {
    assert!(eta >= T::ZERO, "multilevel projection: radius must be non-negative");
    let d = spec.levels.len();
    assert!(d >= 2, "multilevel spec must have depth >= 2");
    let (n, m) = (y.rows(), y.cols());
    out.resize_reuse(n, m);
    if y.is_empty() {
        return;
    }
    let counts = spec.counts(m);
    ws.prepare(d);
    let parallel = n * m >= policy.min_elems && m >= 2;
    let parts = if parallel { policy.effective_threads(m) } else { 1 };
    let chunk = m.div_ceil(parts);
    let leaf = spec.levels[d - 1].norm;

    // ---- upward pass: per-column leaf aggregates --------------------
    {
        let agg = &mut ws.agg[d - 1];
        agg.clear();
        if parallel {
            agg.resize(m, T::ZERO);
            let agg_ptr = SendPtr(agg.as_mut_ptr());
            pool::global().run(parts, |t| {
                let j0 = t * chunk;
                if j0 >= m {
                    return;
                }
                let j1 = (j0 + chunk).min(m);
                let base = agg_ptr.get();
                // SAFETY: parts derive disjoint [j0, j1) ranges of the
                // aggregate buffer from `t`, and `agg` outlives the
                // blocking `run` call.
                let dst = unsafe { std::slice::from_raw_parts_mut(base.add(j0), j1 - j0) };
                for (dj, o) in dst.iter_mut().enumerate() {
                    *o = vec_norm(leaf, y.col(j0 + dj));
                }
            });
        } else {
            agg.extend(y.columns().map(|c| vec_norm(leaf, c)));
        }
    }

    // Intermediate aggregates, bottom-up (short vectors, sequential).
    for i in (1..d - 1).rev() {
        let f = spec.levels[i].fanout.unwrap_or(counts[i + 1]).max(1);
        let norm = spec.levels[i].norm;
        let (upper, lower) = ws.agg.split_at_mut(i + 1);
        let dst = &mut upper[i];
        dst.clear();
        dst.extend(lower[0].chunks(f).map(|c| vec_norm(norm, c)));
    }

    // ---- downward pass: target radii --------------------------------
    {
        let radii = &mut ws.radii[1];
        radii.clear();
        radii.extend_from_slice(&ws.agg[1]);
        project_vec_ball(spec.levels[0].norm, radii, eta, algo, &mut ws.condat);
    }
    for i in 1..d - 1 {
        let f = spec.levels[i].fanout.unwrap_or(counts[i + 1]).max(1);
        let norm = spec.levels[i].norm;
        let (upper, lower) = ws.radii.split_at_mut(i + 1);
        let parent = &upper[i];
        let child = &mut lower[0];
        child.clear();
        child.extend_from_slice(&ws.agg[i + 1]);
        for (g, block) in child.chunks_mut(f).enumerate() {
            project_vec_ball(norm, block, parent[g], algo, &mut ws.condat);
        }
    }

    // ---- leaf apply --------------------------------------------------
    let radii = &ws.radii[d - 1];
    let agg = &ws.agg[d - 1];
    if parallel {
        let src = y.as_slice();
        let dst_ptr = SendPtr(out.as_mut_slice().as_mut_ptr());
        pool::global().run(parts, |t| {
            let j0 = t * chunk;
            if j0 >= m {
                return;
            }
            let j1 = (j0 + chunk).min(m);
            // SAFETY: parts derive disjoint [j0*n, j1*n) element ranges
            // of the output from `t`, and `out` outlives the blocking
            // `run` call.
            let dst = unsafe {
                std::slice::from_raw_parts_mut(dst_ptr.get().add(j0 * n), (j1 - j0) * n)
            };
            apply_leaf_range(leaf, src, n, j0, j1, radii, agg, dst);
        });
    } else {
        apply_leaf_range(leaf, y.as_slice(), n, 0, m, radii, agg, out.as_mut_slice());
    }
}

/// [`project_multilevel_into`] with a fresh workspace and output.
pub fn project_multilevel_with<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    spec: &MultilevelSpec,
    algo: L1Algorithm,
    policy: ParallelPolicy,
) -> Matrix<T> {
    let mut ws = MultilevelWorkspace::new();
    let mut out = Matrix::zeros(0, 0);
    project_multilevel_into(y, eta, spec, algo, policy, &mut ws, &mut out);
    out
}

/// Multi-level projection with the default inner solver and threading
/// policy.
pub fn project_multilevel<T: Scalar>(y: &Matrix<T>, eta: T, spec: &MultilevelSpec) -> Matrix<T> {
    project_multilevel_with(y, eta, spec, L1Algorithm::Condat, ParallelPolicy::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::norms::{l1inf_norm, linf1_norm};
    use crate::projection::bilevel::{bilevel_l1inf_with, bilevel_l12_with};
    use crate::rng::Xoshiro256pp;

    const SEQ: ParallelPolicy = ParallelPolicy { threads: 1, min_elems: usize::MAX };
    const POOL: ParallelPolicy = ParallelPolicy { threads: 7, min_elems: 0 };

    #[test]
    fn parse_and_format_round_trip() {
        for s in ["l1/linf", "linf/l1", "l1/l2:8/linf", "l2/l1:3/l1:5/linf"] {
            let spec = MultilevelSpec::parse(s).unwrap();
            assert_eq!(spec.format(), s);
            assert_eq!(MultilevelSpec::parse(&spec.format()).unwrap(), spec);
        }
        assert_eq!(MultilevelSpec::parse("l1/linf").unwrap(), MultilevelSpec::bilevel_l1inf());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for s in [
            "l1",          // depth 1
            "",            // empty
            "l1/l3",       // unknown norm
            "l1:4/linf",   // fanout on root
            "l1/linf:2",   // fanout on leaf
            "l1/l2/linf",  // intermediate without fanout
            "l1/l2:0/linf", // zero fanout
            "l1/l2:x/linf", // non-numeric fanout
        ] {
            assert!(MultilevelSpec::parse(s).is_err(), "{s:?} should be rejected");
        }
    }

    #[test]
    fn counts_cover_all_columns() {
        let spec = MultilevelSpec::parse("l1/l2:8/linf").unwrap();
        assert_eq!(spec.counts(20), vec![1, 3, 20]);
        assert_eq!(spec.counts(16), vec![1, 2, 16]);
        let bi = MultilevelSpec::bilevel_l1inf();
        assert_eq!(bi.counts(7), vec![1, 7]);
    }

    #[test]
    fn tree_norm_matches_flat_norms() {
        let mut rng = Xoshiro256pp::seed_from_u64(71);
        let y = Matrix::<f64>::randn(13, 9, &mut rng);
        let l1linf = MultilevelSpec::parse("l1/linf").unwrap();
        assert!((tree_norm(&y, &l1linf) - l1inf_norm(&y)).abs() < 1e-12);
        let linfl1 = MultilevelSpec::parse("linf/l1").unwrap();
        assert!((tree_norm(&y, &linfl1) - linf1_norm(&y)).abs() < 1e-12);
    }

    #[test]
    fn depth2_bit_identical_to_bilevel_sequential_and_pool() {
        let (n, m) = if cfg!(miri) { (12, 31) } else { (64, 150) };
        let mut rng = Xoshiro256pp::seed_from_u64(72);
        let spec = MultilevelSpec::bilevel_l1inf();
        for &eta in &[0.5, 3.0, 50.0] {
            let y = Matrix::<f64>::randn(n, m, &mut rng);
            let reference = bilevel_l1inf_with(&y, eta, L1Algorithm::Condat);
            for policy in [SEQ, POOL] {
                let x = project_multilevel_with(&y, eta, &spec, L1Algorithm::Condat, policy);
                for (a, b) in reference.x.as_slice().iter().zip(x.as_slice()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "eta={eta}");
                }
            }
        }
    }

    #[test]
    fn depth2_l1_l2_matches_bilevel_l12() {
        let mut rng = Xoshiro256pp::seed_from_u64(73);
        let y = Matrix::<f64>::randn(20, 14, &mut rng);
        let spec = MultilevelSpec::parse("l1/l2").unwrap();
        let x = project_multilevel_with(&y, 2.0, &spec, L1Algorithm::Condat, SEQ);
        let reference = bilevel_l12_with(&y, 2.0, L1Algorithm::Condat);
        assert!(x.max_abs_diff(&reference.x) < 1e-10);
    }

    #[test]
    fn deep_trees_are_feasible_and_idempotent() {
        let (n, m) = if cfg!(miri) { (8, 24) } else { (32, 96) };
        let mut rng = Xoshiro256pp::seed_from_u64(74);
        for s in ["l1/l2:4/linf", "linf/l1:6/l1", "l2/linf:5/l2", "l1/l1:3/l2:4/linf"] {
            let spec = MultilevelSpec::parse(s).unwrap();
            let y = Matrix::<f64>::randn(n, m, &mut rng);
            let full = tree_norm(&y, &spec);
            let eta = 0.3 * full;
            for policy in [SEQ, POOL] {
                let x = project_multilevel_with(&y, eta, &spec, L1Algorithm::Condat, policy);
                let after = tree_norm(&x, &spec);
                assert!(after <= eta * (1.0 + 1e-9) + 1e-12, "{s}: {after} > {eta}");
                // Idempotence: a feasible point is (numerically) fixed.
                let xx = project_multilevel_with(&x, eta, &spec, L1Algorithm::Condat, policy);
                assert!(x.max_abs_diff(&xx) < 1e-8, "{s} not idempotent");
            }
            // Inside the ball: identity.
            let id = project_multilevel_with(&y, full * 1.01, &spec, L1Algorithm::Condat, SEQ);
            assert!(id.max_abs_diff(&y) == 0.0, "{s} inside-ball must be identity");
        }
    }

    #[test]
    fn zero_radius_and_empty_matrix() {
        let mut rng = Xoshiro256pp::seed_from_u64(75);
        let spec = MultilevelSpec::parse("l1/l2:4/linf").unwrap();
        let y = Matrix::<f64>::randn(6, 10, &mut rng);
        let x = project_multilevel(&y, 0.0, &spec);
        assert!(x.as_slice().iter().all(|&v| v == 0.0));
        let e = Matrix::<f64>::zeros(0, 0);
        assert_eq!(project_multilevel(&e, 1.0, &spec).len(), 0);
    }

    #[test]
    fn workspace_reuse_matches_fresh() {
        let mut rng = Xoshiro256pp::seed_from_u64(76);
        let spec = MultilevelSpec::parse("l1/l2:4/linf").unwrap();
        let mut ws = MultilevelWorkspace::new();
        let mut out = Matrix::zeros(0, 0);
        let (n, m) = if cfg!(miri) { (8, 20) } else { (24, 64) };
        for _ in 0..3 {
            let y = Matrix::<f64>::randn(n, m, &mut rng);
            project_multilevel_into(&y, 1.5, &spec, L1Algorithm::Condat, POOL, &mut ws, &mut out);
            assert_eq!(out, project_multilevel_with(&y, 1.5, &spec, L1Algorithm::Condat, SEQ));
        }
    }

    #[test]
    fn ragged_chunking_covers_tail_columns() {
        // m = 97 with 5 parts exercises the tail chunk on both pool stages.
        let mut rng = Xoshiro256pp::seed_from_u64(77);
        let y = Matrix::<f64>::randn(16, 97, &mut rng);
        let spec = MultilevelSpec::parse("l1/l2:9/linf").unwrap();
        let par = project_multilevel_with(
            &y,
            2.0,
            &spec,
            L1Algorithm::Condat,
            ParallelPolicy { threads: 5, min_elems: 0 },
        );
        let seq = project_multilevel_with(&y, 2.0, &spec, L1Algorithm::Condat, SEQ);
        for (a, b) in seq.as_slice().iter().zip(par.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
