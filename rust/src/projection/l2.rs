//! Projection onto the ℓ2 ball — radial rescale (Parikh & Boyd §6.5.1).
//!
//! `P²_c(y) = y·min(1, c/‖y‖₂)`. The outer step of `BP¹,²` (paper Alg. 3).

use crate::kernels;
use crate::scalar::Scalar;

/// Project onto `{x : ‖x‖₂ ≤ c}` in place. Norm reduction and rescale run
/// through the lane-chunked [`crate::kernels`] layer.
pub fn project_l2_inplace<T: Scalar>(y: &mut [T], c: T) {
    debug_assert!(c >= T::ZERO);
    let norm = kernels::l2_norm(y);
    if norm > c {
        let scale = if norm > T::ZERO { c / norm } else { T::ZERO };
        kernels::scale_inplace(y, scale);
    }
}

/// Out-of-place variant.
pub fn project_l2<T: Scalar>(y: &[T], c: T) -> Vec<T> {
    let mut out = y.to_vec();
    project_l2_inplace(&mut out, c);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::vec_ops;

    #[test]
    fn rescales_outside_ball() {
        let x = project_l2(&[3.0f64, 4.0], 1.0);
        assert!((vec_ops::l2(&x) - 1.0).abs() < 1e-12);
        // direction preserved
        assert!((x[1] / x[0] - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn inside_ball_unchanged() {
        let y = vec![0.3f64, 0.4];
        assert_eq!(project_l2(&y, 1.0), y);
    }

    #[test]
    fn zero_vector_stays_zero() {
        assert_eq!(project_l2(&[0.0f64, 0.0], 0.0), vec![0.0, 0.0]);
    }

    #[test]
    fn residual_identity_eq26() {
        // ||y - x||_2 = ||y||_2 - ||x||_2 for the radial projection.
        let y = vec![3.0f64, 4.0, -1.0];
        let c = 2.0;
        let x = project_l2(&y, c);
        let resid: Vec<f64> = y.iter().zip(x.iter()).map(|(a, b)| a - b).collect();
        let lhs = vec_ops::l2(&resid);
        let rhs = vec_ops::l2(&y) - vec_ops::l2(&x);
        assert!((lhs - rhs).abs() < 1e-12);
    }

    #[test]
    fn idempotent() {
        let y = vec![5.0f64, -3.0];
        let once = project_l2(&y, 2.0);
        let twice = project_l2(&once, 2.0);
        for (a, b) in once.iter().zip(twice.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
