//! Filtered bucket-clustering threshold (Perez, Barlaud, Fillatre, Régin,
//! Mathematical Programming 2019 — reference [21] of the paper).
//!
//! The waterline `τ` is located by histogramming the candidate values into
//! `B` equal-width buckets over their range, scanning buckets from the top
//! while the cumulative waterline stays below the bucket's lower edge, and
//! recursing into the single bucket that straddles the waterline. Values in
//! higher buckets contribute only their (sum, count) aggregates; values in
//! lower buckets are filtered out. Expected O(n) for non-adversarial inputs
//! (each level shrinks the candidate set geometrically).

use crate::scalar::Scalar;

const BUCKETS: usize = 128;
/// Below this candidate count, fall back to the exact sort-based threshold.
const SMALL: usize = 64;

pub fn threshold<T: Scalar>(a: &[T], radius: T) -> T {
    debug_assert!(!a.is_empty());
    let mut candidates: Vec<T> = a.iter().map(|&x| x.max_s(T::ZERO)).collect();
    // (sum, count) of values already known to lie above the waterline.
    let mut hi_sum = T::ZERO;
    let mut hi_cnt: usize = 0;

    loop {
        if candidates.len() <= SMALL {
            return finish_small(&candidates, hi_sum, hi_cnt, radius);
        }
        let (mut lo, mut hi) = (T::INFINITY, T::NEG_INFINITY);
        for &x in &candidates {
            lo = lo.min_s(x);
            hi = hi.max_s(x);
        }
        if hi - lo <= T::EPSILON * hi.max_s(T::ONE) {
            // All candidates (numerically) equal: closed form.
            let k = T::from_usize(hi_cnt + candidates.len());
            let tau = (hi_sum + T::from_usize(candidates.len()) * hi - radius) / k;
            return tau.max_s(T::ZERO);
        }
        let width = (hi - lo) / T::from_usize(BUCKETS);

        let mut sums = [T::ZERO; BUCKETS];
        let mut cnts = [0usize; BUCKETS];
        for &x in &candidates {
            let mut b = ((x - lo) / width).to_f64() as usize;
            if b >= BUCKETS {
                b = BUCKETS - 1;
            }
            sums[b] += x;
            cnts[b] += 1;
        }

        // Scan from the top bucket down. `acc_*` aggregates buckets strictly
        // above the current one.
        let mut acc_sum = hi_sum;
        let mut acc_cnt = hi_cnt;
        let mut target = None;
        for b in (0..BUCKETS).rev() {
            if cnts[b] == 0 {
                continue;
            }
            let lower_edge = lo + width * T::from_usize(b);
            // Waterline if every value >= lower_edge were active:
            let s = acc_sum + sums[b];
            let k = acc_cnt + cnts[b];
            let tau = (s - radius) / T::from_usize(k);
            if tau < lower_edge {
                // Waterline below this bucket: all its values are active,
                // keep descending.
                acc_sum = s;
                acc_cnt = k;
            } else {
                // Waterline falls inside this bucket: recurse into it.
                target = Some((b, lower_edge));
                break;
            }
        }

        match target {
            None => {
                // Waterline below the lowest non-empty bucket: every
                // candidate is active.
                let tau = (acc_sum - radius) / T::from_usize(acc_cnt);
                return tau.max_s(T::ZERO);
            }
            Some((b, lower_edge)) => {
                let upper_edge = lo + width * T::from_usize(b + 1);
                // Keep only values inside bucket b as candidates; values
                // above are aggregated, values below are discarded.
                hi_sum = acc_sum;
                hi_cnt = acc_cnt;
                candidates.retain(|&x| x >= lower_edge && x < upper_edge || {
                    // top bucket includes its upper edge
                    b == BUCKETS - 1 && x == upper_edge
                });
                if candidates.is_empty() {
                    // Numerical corner: resolve with what we have.
                    let tau = (hi_sum - radius) / T::from_usize(hi_cnt.max(1));
                    return tau.max_s(T::ZERO);
                }
            }
        }
    }
}

/// Exact finish: sort the remaining candidates and account for the
/// aggregated mass above them.
fn finish_small<T: Scalar>(cands: &[T], hi_sum: T, hi_cnt: usize, radius: T) -> T {
    let mut s = cands.to_vec();
    s.sort_by(|x, y| y.partial_cmp(x).expect("NaN in projection input"));
    let mut cum = hi_sum;
    let mut best = if hi_cnt > 0 {
        (cum - radius) / T::from_usize(hi_cnt)
    } else {
        T::ZERO
    };
    for (k, &v) in s.iter().enumerate() {
        cum += v;
        let t = (cum - radius) / T::from_usize(hi_cnt + k + 1);
        if t < v {
            best = t;
        } else {
            break;
        }
    }
    best.max_s(T::ZERO)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    #[test]
    fn matches_sort_on_random_inputs() {
        let mut rng = Xoshiro256pp::seed_from_u64(999);
        for _ in 0..300 {
            let n = 1 + rng.next_below(2000) as usize;
            let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 100.0)).collect();
            let total: f64 = a.iter().sum();
            if total < 1e-9 {
                continue;
            }
            let radius = rng.uniform(total * 0.001, total * 0.9);
            let want = super::super::sort::threshold(&a, radius);
            let got = threshold(&a, radius);
            assert!(
                (got - want).abs() < 1e-7 * (1.0 + want.abs()),
                "got {got}, want {want} (n={n})"
            );
        }
    }

    #[test]
    fn heavy_tail_input() {
        // One huge value among many tiny ones exercises bucket recursion.
        let mut a = vec![0.001f64; 5000];
        a[123] = 1e6;
        let want = super::super::sort::threshold(&a, 10.0);
        let got = threshold(&a, 10.0);
        assert!((got - want).abs() < 1e-6 * (1.0 + want), "got {got}, want {want}");
    }

    #[test]
    fn constant_vector_closed_form() {
        let a = vec![2.0f64; 1000];
        let got = threshold(&a, 1000.0);
        assert!((got - 1.0).abs() < 1e-9, "got {got}");
    }

    #[test]
    fn small_input_delegates_to_sort() {
        let a = [5.0f64, 1.0, 0.5];
        let want = super::super::sort::threshold(&a, 2.0);
        assert!((threshold(&a, 2.0) - want).abs() < 1e-12);
    }
}
