//! Condat's O(n)-expected simplex threshold.
//!
//! L. Condat, *“Fast projection onto the simplex and the ℓ1 ball”*,
//! Mathematical Programming 158(1), 2016 — reference [20] of the paper and
//! the inner solver its C++ extension uses. This is Algorithm 3 of that
//! paper (“improved filter”): a single online pass maintains a candidate
//! active set `v` and waterline `ρ = (Σv − η)/|v|`; values that cannot be
//! active are shunted to a waste list and revisited once; a final
//! Michelot-style cleanup removes stragglers.
//!
//! The default algorithm of the whole repo: `BP¹,∞`'s O(m) inner step.

use crate::kernels::CondatScratch;
use crate::scalar::Scalar;

/// One-shot entry point: allocates a fresh scratch per call. Hot paths use
/// [`threshold_with`] with a reused [`CondatScratch`] instead.
pub fn threshold<T: Scalar>(a: &[T], radius: T) -> T {
    threshold_with(a, radius, &mut CondatScratch::new())
}

/// Allocation-free variant: the candidate set `v` and the `waste` list
/// live in the caller's scratch. Both are bounded by `a.len()` (every
/// input element enters `v` at most once from the scan and moves to
/// `waste` at most once), so they are reserved to that worst case up
/// front — after the first call at a given size the scratch never grows
/// again. (The seed version seeded `v` with `with_capacity(len.min(64))`,
/// which guaranteed mid-scan reallocations for every m > 64.)
pub fn threshold_with<T: Scalar>(a: &[T], radius: T, scratch: &mut CondatScratch<T>) -> T {
    debug_assert!(!a.is_empty());
    // Work on the non-negative part; the simplex problem ignores negatives.
    let v = &mut scratch.v;
    let waste = &mut scratch.waste;
    v.clear();
    waste.clear();
    v.reserve(a.len());
    waste.reserve(a.len());

    // Seed with the first non-negative-clamped value.
    let y0 = a[0].max_s(T::ZERO);
    v.push(y0);
    let mut rho = y0 - radius;

    for &raw in &a[1..] {
        let y = raw.max_s(T::ZERO);
        if y > rho {
            // Tentatively admit y.
            rho += (y - rho) / T::from_usize(v.len() + 1);
            if rho > y - radius {
                v.push(y);
            } else {
                // Everything collected so far may be inactive; restart the
                // candidate set from y, park the old candidates for review.
                waste.append(v);
                v.push(y);
                rho = y - radius;
            }
        }
    }

    // Second chance for the waste list.
    for &y in waste.iter() {
        if y > rho {
            v.push(y);
            rho += (y - rho) / T::from_usize(v.len());
        }
    }

    // Michelot-style cleanup: remove candidates at or below the waterline.
    loop {
        let before = v.len();
        let mut i = 0;
        while i < v.len() {
            if v[i] <= rho {
                let y = v.swap_remove(i);
                if v.is_empty() {
                    return T::ZERO;
                }
                rho += (rho - y) / T::from_usize(v.len());
            } else {
                i += 1;
            }
        }
        if v.len() == before {
            break;
        }
    }
    rho.max_s(T::ZERO)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    #[test]
    fn matches_sort_threshold_extensively() {
        let mut rng = Xoshiro256pp::seed_from_u64(31337);
        for _ in 0..500 {
            let n = 1 + rng.next_below(256) as usize;
            let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 4.0)).collect();
            let total: f64 = a.iter().sum();
            if total < 1e-9 {
                continue;
            }
            let radius = rng.uniform(total * 0.01, total * 0.95);
            let want = super::super::sort::threshold(&a, radius);
            let got = threshold(&a, radius);
            assert!(
                (got - want).abs() < 1e-9 * (1.0 + want.abs()),
                "got {got}, want {want} (n={n}, radius={radius})"
            );
        }
    }

    #[test]
    fn adversarial_increasing_sequence() {
        // Strictly increasing input maximizes candidate-set restarts.
        let a: Vec<f64> = (1..=1000).map(|i| i as f64 / 10.0).collect();
        let want = super::super::sort::threshold(&a, 7.0);
        assert!((threshold(&a, 7.0) - want).abs() < 1e-9);
    }

    #[test]
    fn adversarial_decreasing_sequence() {
        let a: Vec<f64> = (1..=1000).rev().map(|i| i as f64 / 10.0).collect();
        let want = super::super::sort::threshold(&a, 7.0);
        assert!((threshold(&a, 7.0) - want).abs() < 1e-9);
    }

    #[test]
    fn scratch_reuse_is_bit_identical_and_stops_growing() {
        let mut rng = Xoshiro256pp::seed_from_u64(99);
        let mut scratch = CondatScratch::new();
        let mut cases: Vec<(Vec<f64>, f64)> = Vec::new();
        for _ in 0..100 {
            let n = 1 + rng.next_below(300) as usize;
            let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 4.0)).collect();
            let total: f64 = a.iter().sum();
            if total < 1e-9 {
                continue;
            }
            let radius = rng.uniform(total * 0.01, total * 0.95);
            cases.push((a, radius));
        }
        for (trial, (a, radius)) in cases.iter().enumerate() {
            let fresh = threshold(a, *radius);
            let reused = threshold_with(a, *radius, &mut scratch);
            assert_eq!(fresh.to_bits(), reused.to_bits(), "trial {trial}");
        }
        // The contract: once the largest input has been seen, replaying
        // any of the inputs never grows the scratch again (zero-alloc
        // steady state), regardless of std's amortized-growth policy.
        let cap_v = scratch.v.capacity();
        let cap_waste = scratch.waste.capacity();
        for (a, radius) in &cases {
            threshold_with(a, *radius, &mut scratch);
        }
        assert_eq!(scratch.v.capacity(), cap_v, "candidate scratch grew on reuse");
        assert_eq!(scratch.waste.capacity(), cap_waste, "waste scratch grew on reuse");
    }

    #[test]
    fn handles_zeros_and_duplicates() {
        let a = [0.0f64, 0.0, 2.0, 2.0, 2.0, 0.0];
        let want = super::super::sort::threshold(&a, 3.0);
        assert!((threshold(&a, 3.0) - want).abs() < 1e-12);
    }
}
