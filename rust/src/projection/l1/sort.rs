//! Sort-based simplex threshold (Held–Wolfe–Crowder 1974).
//!
//! Sort magnitudes descending, take the largest `k` such that the implied
//! waterline `(Σ_{i≤k} s_i − η)/k` stays below `s_k`. O(n log n) — the
//! classical baseline the linear-time algorithms are measured against.

use crate::scalar::Scalar;

/// Threshold `τ` with `Σ max(a_i − τ, 0) = radius` for non-negative-ish `a`
/// (negative entries are treated as 0, consistent with the simplex problem).
pub fn threshold<T: Scalar>(a: &[T], radius: T) -> T {
    debug_assert!(!a.is_empty());
    let mut s: Vec<T> = a.iter().map(|&x| x.max_s(T::ZERO)).collect();
    // Descending sort; NaNs are rejected upstream.
    s.sort_by(|x, y| y.partial_cmp(x).expect("NaN in projection input"));
    let mut cum = T::ZERO;
    let mut tau = T::ZERO;
    for (k, &v) in s.iter().enumerate() {
        cum += v;
        let t = (cum - radius) / T::from_usize(k + 1);
        if t < v {
            tau = t;
        } else {
            break;
        }
    }
    tau.max_s(T::ZERO)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_small_case() {
        // a = [3,1], radius 2: waterline tau=1 -> (3-1) + (1-1) = 2.
        let tau = threshold(&[3.0f64, 1.0], 2.0);
        assert!((tau - 1.0).abs() < 1e-12);
    }

    #[test]
    fn radius_larger_than_needed_gives_small_tau() {
        // a = [2, 2], radius 3: tau = (4-3)/2 = 0.5
        let tau = threshold(&[2.0f64, 2.0], 3.0);
        assert!((tau - 0.5).abs() < 1e-12);
    }

    #[test]
    fn negative_entries_ignored() {
        let tau = threshold(&[3.0f64, -5.0, 1.0], 2.0);
        assert!((tau - 1.0).abs() < 1e-12);
    }

    #[test]
    fn all_mass_in_one_entry() {
        let tau = threshold(&[10.0f64, 0.1, 0.1], 1.0);
        // waterline above 0.1: tau = 10 - 1 = 9
        assert!((tau - 9.0).abs() < 1e-12);
    }
}
