//! Michelot's iterative set-reduction threshold (Michelot 1986).
//!
//! Repeatedly average the active set and discard entries below the implied
//! waterline. Worst case O(n²) but typically a handful of passes; kept both
//! as a cross-check and because the per-column inner solver of the Chu-style
//! semismooth Newton baseline is exactly this iteration.

use crate::scalar::Scalar;

pub fn threshold<T: Scalar>(a: &[T], radius: T) -> T {
    debug_assert!(!a.is_empty());
    // Active set starts as all strictly-positive entries.
    let mut active: Vec<T> = a.iter().map(|&x| x.max_s(T::ZERO)).collect();
    let mut sum: T = active.iter().copied().sum();
    let mut tau = (sum - radius) / T::from_usize(active.len());
    loop {
        let prev_len = active.len();
        let mut kept_sum = T::ZERO;
        active.retain(|&x| {
            if x > tau {
                kept_sum += x;
                true
            } else {
                false
            }
        });
        if active.is_empty() {
            // Degenerate: radius >= sum of positives was excluded upstream,
            // but guard anyway.
            return T::ZERO;
        }
        sum = kept_sum;
        tau = (sum - radius) / T::from_usize(active.len());
        if active.len() == prev_len {
            return tau.max_s(T::ZERO);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_sort_on_small_case() {
        let a = [3.0f64, 1.0, 0.2];
        let want = super::super::sort::threshold(&a, 2.0);
        assert!((threshold(&a, 2.0) - want).abs() < 1e-12);
    }

    #[test]
    fn converges_on_uniform_vector() {
        let a = vec![1.0f64; 100];
        let tau = threshold(&a, 50.0);
        assert!((tau - 0.5).abs() < 1e-12);
    }

    #[test]
    fn two_pass_reduction() {
        // Entries far below the first waterline get discarded in pass 1.
        let a = [10.0f64, 9.0, 0.01, 0.01];
        let want = super::super::sort::threshold(&a, 4.0);
        assert!((threshold(&a, 4.0) - want).abs() < 1e-12);
    }
}
