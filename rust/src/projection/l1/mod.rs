//! Projection onto the ℓ1 ball `B¹_η = {x : Σ|x_i| ≤ η}`.
//!
//! All algorithms reduce to finding the *threshold* `τ ≥ 0` such that
//! `Σ_i max(|y_i| − τ, 0) = η` (when `‖y‖₁ > η`); the projection is then the
//! soft-thresholding `x_i = sign(y_i)·max(|y_i| − τ, 0)`.
//!
//! Four algorithms are provided (they agree to machine precision; the
//! benchmark `benches/l1_algorithms.rs` compares them):
//!
//! | algorithm | complexity | reference |
//! |-----------|------------|-----------|
//! | [`sort`]     | O(n log n)      | Held–Wolfe–Crowder 1974 |
//! | [`michelot`] | O(n²) worst, fast in practice | Michelot 1986 |
//! | [`condat`]   | O(n) expected   | Condat, Math. Prog. 158, 2016 [20] |
//! | [`bucket`]   | O(n) expected   | Perez–Barlaud–Fillatre–Régin 2019 [21] |
//!
//! [`L1Algorithm::Condat`] is the default everywhere (it is what the paper's
//! PyTorch C++ extension uses for the inner step of the bi-level method).

pub mod bucket;
pub mod condat;
pub mod michelot;
pub mod sort;

use crate::kernels::{self, CondatScratch};
use crate::scalar::Scalar;
use crate::tensor::vec_ops;

/// Selector for the ℓ1 threshold algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum L1Algorithm {
    Sort,
    Michelot,
    Condat,
    Bucket,
}

impl L1Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Self::Sort => "sort",
            Self::Michelot => "michelot",
            Self::Condat => "condat",
            Self::Bucket => "bucket",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sort" => Some(Self::Sort),
            "michelot" => Some(Self::Michelot),
            "condat" => Some(Self::Condat),
            "bucket" => Some(Self::Bucket),
            _ => None,
        }
    }

    pub fn all() -> &'static [L1Algorithm] {
        &[Self::Sort, Self::Michelot, Self::Condat, Self::Bucket]
    }
}

/// Threshold `τ` of the projection of the *non-negative* vector `a` onto the
/// simplex-like constraint `Σ max(a_i − τ, 0) = radius`.
///
/// Precondition: `Σ a_i > radius` and `radius > 0` (callers handle the
/// trivial cases). `a` may be in any order; it is not modified.
pub fn simplex_threshold<T: Scalar>(a: &[T], radius: T, algo: L1Algorithm) -> T {
    debug_assert!(radius > T::ZERO);
    match algo {
        L1Algorithm::Sort => sort::threshold(a, radius),
        L1Algorithm::Michelot => michelot::threshold(a, radius),
        L1Algorithm::Condat => condat::threshold(a, radius),
        L1Algorithm::Bucket => bucket::threshold(a, radius),
    }
}

/// [`simplex_threshold`] with caller-provided scratch: the default Condat
/// solver runs allocation-free through it; the other algorithms keep their
/// own (allocating) scratch — they exist for cross-checks and benchmarks,
/// not the hot path.
pub fn simplex_threshold_with<T: Scalar>(
    a: &[T],
    radius: T,
    algo: L1Algorithm,
    scratch: &mut CondatScratch<T>,
) -> T {
    match algo {
        L1Algorithm::Condat => condat::threshold_with(a, radius, scratch),
        other => simplex_threshold(a, radius, other),
    }
}

/// In-place ℓ1-ball projection of a **non-negative** vector with caller
/// scratch — the inner stage of the workspace (`*_into`) bi-level path.
/// For non-negative input this is bit-identical to [`project_l1_inplace`]
/// (the `|v|` copy is the identity and soft-thresholding reduces to
/// `(v-τ)₊`), but performs zero allocations with a warm scratch.
pub fn project_l1_nonneg_inplace_with<T: Scalar>(
    v: &mut [T],
    eta: T,
    algo: L1Algorithm,
    scratch: &mut CondatScratch<T>,
) {
    debug_assert!(v.iter().all(|&x| x >= T::ZERO));
    assert!(eta >= T::ZERO, "project_l1: radius must be non-negative");
    if eta == T::ZERO {
        v.iter_mut().for_each(|x| *x = T::ZERO);
        return;
    }
    if kernels::sum_abs(v) <= eta {
        return; // already inside the ball
    }
    let tau = simplex_threshold_with(v, eta, algo, scratch);
    kernels::soft_threshold_inplace(v, tau);
}

/// Project `y` onto the ℓ1 ball of radius `eta`. Returns a fresh vector.
pub fn project_l1<T: Scalar>(y: &[T], eta: T, algo: L1Algorithm) -> Vec<T> {
    let mut out = y.to_vec();
    project_l1_inplace(&mut out, eta, algo);
    out
}

/// In-place ℓ1-ball projection (the hot-path variant).
pub fn project_l1_inplace<T: Scalar>(y: &mut [T], eta: T, algo: L1Algorithm) {
    assert!(eta >= T::ZERO, "project_l1: radius must be non-negative");
    if eta == T::ZERO {
        y.iter_mut().for_each(|x| *x = T::ZERO);
        return;
    }
    if vec_ops::l1(y) <= eta {
        return; // already inside the ball
    }
    let abs: Vec<T> = y.iter().map(|&x| x.abs()).collect();
    let tau = simplex_threshold(&abs, eta, algo);
    soft_threshold_inplace(y, tau);
}

/// `x_i ← sign(x_i)·max(|x_i| − tau, 0)` — the lane-chunked kernel.
/// Requires `tau ≥ 0` (thresholds from [`simplex_threshold`] always are).
pub fn soft_threshold_inplace<T: Scalar>(y: &mut [T], tau: T) {
    debug_assert!(tau >= T::ZERO, "soft_threshold_inplace: tau must be non-negative");
    kernels::soft_threshold_inplace(y, tau);
}

/// Projection onto the probability-simplex-like set `{x ≥ 0, Σx = radius}`
/// for a non-negative input: `x_i = max(a_i − τ, 0)`.
pub fn project_simplex<T: Scalar>(a: &[T], radius: T, algo: L1Algorithm) -> Vec<T> {
    assert!(radius >= T::ZERO);
    if radius == T::ZERO {
        return vec![T::ZERO; a.len()];
    }
    let total: T = a.iter().fold(T::ZERO, |s, &x| s + x.max_s(T::ZERO));
    if total <= radius {
        // Inside: for the l1-ball semantics used by the bi-level methods the
        // input is returned unchanged (inequality constraint).
        return a.iter().map(|&x| x.max_s(T::ZERO)).collect();
    }
    let tau = simplex_threshold(a, radius, algo);
    a.iter().map(|&x| (x - tau).pos()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    /// Golden reference: exhaustive sort-based threshold in f64.
    fn golden_threshold(a: &[f64], radius: f64) -> f64 {
        let mut s: Vec<f64> = a.iter().map(|&x| x.max(0.0)).collect();
        s.sort_by(|x, y| y.partial_cmp(x).unwrap());
        let mut cum = 0.0;
        let mut tau = 0.0;
        for (k, &v) in s.iter().enumerate() {
            cum += v;
            let t = (cum - radius) / (k + 1) as f64;
            if t < v {
                tau = t;
            } else {
                break;
            }
        }
        tau.max(0.0)
    }

    #[test]
    fn all_algorithms_agree_on_random_vectors() {
        let mut rng = Xoshiro256pp::seed_from_u64(2024);
        for trial in 0..200 {
            let n = 1 + rng.next_below(400) as usize;
            let a: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 10.0)).collect();
            let total: f64 = a.iter().sum();
            let radius = rng.uniform(1e-6, total * 0.99);
            let want = golden_threshold(&a, radius);
            for algo in L1Algorithm::all() {
                let got = simplex_threshold(&a, radius, *algo);
                assert!(
                    (got - want).abs() < 1e-8 * (1.0 + want),
                    "trial {trial}: {} gave {got}, golden {want} (n={n}, radius={radius})",
                    algo.name()
                );
            }
        }
    }

    #[test]
    fn projection_satisfies_radius_exactly_when_outside() {
        let mut rng = Xoshiro256pp::seed_from_u64(2025);
        for _ in 0..100 {
            let n = 2 + rng.next_below(100) as usize;
            let y: Vec<f64> = (0..n).map(|_| rng.uniform(-5.0, 5.0)).collect();
            let eta = 0.25 * crate::tensor::vec_ops::l1(&y);
            for algo in L1Algorithm::all() {
                let x = project_l1(&y, eta, *algo);
                let got: f64 = crate::tensor::vec_ops::l1(&x);
                assert!((got - eta).abs() < 1e-8 * (1.0 + eta), "{}: {got} != {eta}", algo.name());
                // sign preservation
                for (xi, yi) in x.iter().zip(y.iter()) {
                    assert!(*xi == 0.0 || xi.signum() == yi.signum());
                }
            }
        }
    }

    #[test]
    fn inside_ball_is_identity() {
        let y = vec![0.1f64, -0.2, 0.3];
        for algo in L1Algorithm::all() {
            assert_eq!(project_l1(&y, 1.0, *algo), y);
        }
    }

    #[test]
    fn zero_radius_gives_zero() {
        let y = vec![1.0f64, -2.0, 3.0];
        for algo in L1Algorithm::all() {
            assert_eq!(project_l1(&y, 0.0, *algo), vec![0.0; 3]);
        }
    }

    #[test]
    fn single_element() {
        for algo in L1Algorithm::all() {
            assert_eq!(project_l1(&[5.0f64], 2.0, *algo), vec![2.0]);
            assert_eq!(project_l1(&[-5.0f64], 2.0, *algo), vec![-2.0]);
            assert_eq!(project_l1(&[1.0f64], 2.0, *algo), vec![1.0]);
        }
    }

    #[test]
    fn ties_are_handled() {
        // All entries equal: threshold distributes mass evenly.
        let y = vec![1.0f64; 10];
        for algo in L1Algorithm::all() {
            let x = project_l1(&y, 5.0, *algo);
            for xi in &x {
                assert!((xi - 0.5).abs() < 1e-12, "{}: {xi}", algo.name());
            }
        }
    }

    #[test]
    fn optimality_via_variational_inequality() {
        // x* is the projection iff <y - x*, z - x*> <= 0 for all z in ball.
        // Spot-check with random feasible z.
        let mut rng = Xoshiro256pp::seed_from_u64(2026);
        let y: Vec<f64> = (0..50).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let eta = 4.0;
        let x = project_l1(&y, eta, L1Algorithm::Condat);
        for _ in 0..100 {
            let mut z: Vec<f64> = (0..50).map(|_| rng.uniform(-1.0, 1.0)).collect();
            project_l1_inplace(&mut z, eta, L1Algorithm::Sort);
            let ip: f64 = y
                .iter()
                .zip(x.iter())
                .zip(z.iter())
                .map(|((&yi, &xi), &zi)| (yi - xi) * (zi - xi))
                .sum();
            assert!(ip <= 1e-8, "VI violated: {ip}");
        }
    }

    #[test]
    fn nonneg_inplace_with_scratch_matches_project_l1_bitwise() {
        let mut rng = Xoshiro256pp::seed_from_u64(2028);
        let mut scratch = CondatScratch::new();
        for algo in L1Algorithm::all() {
            for trial in 0..50 {
                let n = 1 + rng.next_below(200) as usize;
                let v: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 3.0)).collect();
                let total: f64 = v.iter().sum();
                // Cover inside-ball, tight, and zero radii.
                for eta in [0.0, total * 0.4, total * 2.0] {
                    let want = project_l1(&v, eta, *algo);
                    let mut got = v.clone();
                    project_l1_nonneg_inplace_with(&mut got, eta, *algo, &mut scratch);
                    for (a, b) in want.iter().zip(got.iter()) {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{} trial {trial} eta {eta}",
                            algo.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn project_simplex_nonnegative_and_sums() {
        let mut rng = Xoshiro256pp::seed_from_u64(2027);
        let a: Vec<f64> = (0..30).map(|_| rng.uniform(0.0, 2.0)).collect();
        let x = project_simplex(&a, 3.0, L1Algorithm::Condat);
        assert!(x.iter().all(|&v| v >= 0.0));
        let s: f64 = x.iter().sum();
        assert!((s - 3.0).abs() < 1e-9);
    }

    #[test]
    fn f32_path_works() {
        let y = vec![3.0f32, -4.0, 1.0, 0.5];
        for algo in L1Algorithm::all() {
            let x = project_l1(&y, 2.0, *algo);
            let s: f32 = x.iter().map(|v| v.abs()).sum();
            assert!((s - 2.0).abs() < 1e-4, "{}: sum={s}", algo.name());
        }
    }
}
