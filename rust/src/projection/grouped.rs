//! Grouped bi-level projection — the paper's §VI extension to tensors and
//! convolutional layers.
//!
//! The matrix `BP¹,∞` treats *columns* as groups. Nothing in Algorithm 1
//! requires the groups to be columns: for any partition of the entries
//! into disjoint groups, aggregate each group by its ∞-norm, project the
//! group-norm vector onto the ℓ1 ball, clip each group at its threshold.
//! This covers:
//!
//! * convolutional kernels `(C_out, C_in, k, k)` grouped by input channel
//!   → channel pruning (the paper's JPEG-AI application [46]);
//! * attention matrices grouped by head or by key block (§VI third
//!   application);
//! * arbitrary tensor mode-n fibres.
//!
//! The identity (Prop. III.3) transfers verbatim: clipping is per-group,
//! so `Σ_g (max|resid_g|) + Σ_g (max|proj_g|) = Σ_g max|y_g|`.

use crate::kernels;
use crate::projection::l1::{self, L1Algorithm};
use crate::scalar::Scalar;

/// A partition of `0..len` into contiguous, equally-sized groups.
/// (Non-contiguous grouping: permute the buffer first — the projection is
/// permutation-equivariant.)
#[derive(Clone, Copy, Debug)]
pub struct GroupSpec {
    pub group_size: usize,
    pub n_groups: usize,
}

impl GroupSpec {
    pub fn new(group_size: usize, n_groups: usize) -> Self {
        assert!(group_size > 0, "group_size must be positive");
        Self { group_size, n_groups }
    }

    /// Groups = trailing-dim slices of a conv weight `(c_out, c_in, k, k)`
    /// grouped by input channel: each group collects the `c_out × k × k`
    /// weights that read channel `c`. Requires the buffer laid out with
    /// the channel as the leading dimension of each group, i.e.
    /// `(c_in, c_out*k*k)` — use [`regroup_conv_by_in_channel`] to build it.
    pub fn conv_in_channels(c_out: usize, c_in: usize, k: usize) -> Self {
        Self::new(c_out * k * k, c_in)
    }

    pub fn len(&self) -> usize {
        self.group_size * self.n_groups
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Result of a grouped bi-level projection.
#[derive(Clone, Debug)]
pub struct GroupedResult<T: Scalar> {
    pub x: Vec<T>,
    /// Per-group clipping thresholds (0 ⇒ group entirely removed).
    pub thresholds: Vec<T>,
}

/// `BP¹,∞` over arbitrary contiguous groups. O(len) + O(n_groups).
pub fn bilevel_l1inf_grouped<T: Scalar>(
    y: &[T],
    spec: GroupSpec,
    eta: T,
    algo: L1Algorithm,
) -> GroupedResult<T> {
    assert_eq!(y.len(), spec.len(), "buffer does not match the group spec");
    assert!(eta >= T::ZERO);
    // Stage 1: per-group inf-norms (lane-chunked kernel reduction).
    let v: Vec<T> = y.chunks_exact(spec.group_size).map(kernels::colmax).collect();
    let u = l1::project_l1(&v, eta, algo);
    // Stage 2: fused clip through the shared kernel helper, so a
    // column-shaped GroupSpec reproduces `bilevel_l1inf` bit-for-bit;
    // extend-based fill keeps the output single-write (no zero-fill pass).
    let mut x = Vec::with_capacity(y.len());
    for (g, chunk) in y.chunks_exact(spec.group_size).enumerate() {
        kernels::extend_clipped(&mut x, chunk, u[g], v[g]);
    }
    GroupedResult { x, thresholds: u }
}

/// Reorder a conv weight `(c_out, c_in, k, k)` (row-major) so that all
/// weights reading input channel `c` are contiguous: output layout
/// `(c_in, c_out, k, k)`. Returns the regrouped buffer.
pub fn regroup_conv_by_in_channel<T: Scalar>(
    w: &[T],
    c_out: usize,
    c_in: usize,
    k: usize,
) -> Vec<T> {
    assert_eq!(w.len(), c_out * c_in * k * k);
    let kk = k * k;
    let mut out = vec![T::ZERO; w.len()];
    for o in 0..c_out {
        for c in 0..c_in {
            let src = (o * c_in + c) * kk;
            let dst = (c * c_out + o) * kk;
            out[dst..dst + kk].copy_from_slice(&w[src..src + kk]);
        }
    }
    out
}

/// Inverse of [`regroup_conv_by_in_channel`].
pub fn ungroup_conv_by_in_channel<T: Scalar>(
    g: &[T],
    c_out: usize,
    c_in: usize,
    k: usize,
) -> Vec<T> {
    assert_eq!(g.len(), c_out * c_in * k * k);
    let kk = k * k;
    let mut out = vec![T::ZERO; g.len()];
    for c in 0..c_in {
        for o in 0..c_out {
            let src = (c * c_out + o) * kk;
            let dst = (o * c_in + c) * kk;
            out[dst..dst + kk].copy_from_slice(&g[src..src + kk]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    fn grouped_l1inf_norm(y: &[f64], gs: usize) -> f64 {
        y.chunks_exact(gs).map(crate::tensor::vec_ops::linf).sum()
    }

    #[test]
    fn matches_matrix_bilevel_when_groups_are_columns() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let (n, m) = (17, 9);
        let y = crate::tensor::Matrix::<f64>::randn(n, m, &mut rng);
        let eta = 2.0;
        let mat = crate::projection::bilevel::bilevel_l1inf(&y, eta);
        let grouped = bilevel_l1inf_grouped(
            y.as_slice(),
            GroupSpec::new(n, m),
            eta,
            L1Algorithm::Condat,
        );
        for (a, b) in mat.as_slice().iter().zip(grouped.x.iter()) {
            assert!((a - b).abs() < 1e-15);
        }
    }

    #[test]
    fn identity_holds_for_arbitrary_groups() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let spec = GroupSpec::new(12, 33);
        let y: Vec<f64> = (0..spec.len()).map(|_| rng.uniform(-3.0, 3.0)).collect();
        let total = grouped_l1inf_norm(&y, spec.group_size);
        let eta = total * 0.3;
        let r = bilevel_l1inf_grouped(&y, spec, eta, L1Algorithm::Condat);
        let resid: Vec<f64> = y.iter().zip(r.x.iter()).map(|(a, b)| a - b).collect();
        let lhs = grouped_l1inf_norm(&resid, spec.group_size)
            + grouped_l1inf_norm(&r.x, spec.group_size);
        assert!((lhs - total).abs() < 1e-9 * total);
        // feasibility + tightness
        assert!((grouped_l1inf_norm(&r.x, spec.group_size) - eta).abs() < 1e-9 * eta);
    }

    #[test]
    fn conv_channel_pruning_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let (c_out, c_in, k) = (8, 6, 3);
        let mut w: Vec<f64> =
            (0..c_out * c_in * k * k).map(|_| rng.uniform(-1.0, 1.0)).collect();
        // Boost input-channel 2 so the others get pruned at a tight radius.
        for o in 0..c_out {
            let base = (o * c_in + 2) * k * k;
            for v in &mut w[base..base + k * k] {
                *v *= 10.0;
            }
        }
        let g = regroup_conv_by_in_channel(&w, c_out, c_in, k);
        assert_eq!(ungroup_conv_by_in_channel(&g, c_out, c_in, k), w);

        let spec = GroupSpec::conv_in_channels(c_out, c_in, k);
        let r = bilevel_l1inf_grouped(&g, spec, 0.5, L1Algorithm::Condat);
        let pruned_channels = r.thresholds.iter().filter(|&&u| u <= 0.0).count();
        assert!(pruned_channels > 0, "tight radius must prune whole input channels");
        // every pruned channel is entirely zero after ungrouping
        let back = ungroup_conv_by_in_channel(&r.x, c_out, c_in, k);
        for (c, &u) in r.thresholds.iter().enumerate() {
            if u <= 0.0 {
                for o in 0..c_out {
                    let base = (o * c_in + c) * k * k;
                    assert!(back[base..base + k * k].iter().all(|&v| v == 0.0));
                }
            }
        }
    }

    #[test]
    fn zero_eta_and_inside_ball() {
        let spec = GroupSpec::new(4, 3);
        let y = vec![0.5f64; 12];
        let r = bilevel_l1inf_grouped(&y, spec, 0.0, L1Algorithm::Condat);
        assert!(r.x.iter().all(|&v| v == 0.0));
        let r = bilevel_l1inf_grouped(&y, spec, 100.0, L1Algorithm::Condat);
        assert_eq!(r.x, y);
    }
}
