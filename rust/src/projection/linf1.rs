//! Projection onto the ℓ∞,1 ball `{X : max_j ‖x_j‖₁ ≤ η}` (the dual ball
//! of the paper's ℓ1,∞ norm, eq. 4).
//!
//! The constraint is column-separable: a column with `‖y_j‖₁ ≤ η` is
//! untouched, every other column is independently projected onto the
//! ℓ1 ball of radius η. The production path finds each column's
//! soft-threshold by Newton root search on the dual residual
//! `r(τ) = Σ_i (|y_ij| − τ)₊ − η` (Chau, Wohlberg, Rodriguez 2019,
//! arXiv 1806.10041) — sort-free, O(n) per iteration, monotonically
//! convergent from the left since `r` is convex and decreasing. The
//! reference oracle recovers the same threshold from the exact sorted
//! breakpoint profile ([`crate::projection::l1inf::profile::ColumnProfile`]).

use crate::kernels::{self, Workspace};
use crate::projection::l1inf::profile::ColumnProfile;
use crate::scalar::Scalar;
use crate::tensor::Matrix;

/// Soft-threshold `τ ≥ 0` with `Σ_i (|v_i| − τ)₊ = eta`, by Newton root
/// search started left of the root at `(Σ|v_i| − eta)/n`. Caller
/// guarantees `Σ|v_i| > eta > 0` (otherwise the projection is a no-op and
/// no threshold is needed). Shared with the multilevel tree's ℓ1 leaves.
pub(crate) fn newton_l1_threshold<T: Scalar>(v: &[T], eta: T) -> T {
    let mut tau = (kernels::sum_abs(v) - eta) / T::from_usize(v.len());
    let tol = T::EPSILON * eta.max_s(T::ONE) * T::from_f64(64.0);
    for _ in 0..v.len() + 2 {
        let mut r = T::ZERO;
        let mut active = 0usize;
        for &x in v {
            let d = x.abs() - tau;
            if d > T::ZERO {
                r = r + d;
                active += 1;
            }
        }
        if active == 0 {
            // τ overshot every magnitude (possible only through rounding);
            // the projection of this column is then exactly zero.
            return tau;
        }
        let step = (r - eta) / T::from_usize(active);
        tau = tau + step;
        if step.abs() <= tol {
            break;
        }
    }
    tau.max_s(T::ZERO)
}

/// Workspace-based `P^∞,¹_η(Y)` — zero allocations at steady state.
/// `ws.norms` holds the column ℓ1 norms, `ws.thresholds` the per-column
/// soft-thresholds (0 for untouched columns).
pub fn project_linf1_into<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    ws: &mut Workspace<T>,
    out: &mut Matrix<T>,
) {
    assert!(eta >= T::ZERO, "linf1 projection: radius must be non-negative");
    let (n, m) = (y.rows(), y.cols());
    out.resize_reuse(n, m);
    ws.norms.clear();
    ws.thresholds.clear();
    if y.is_empty() {
        return;
    }
    for j in 0..m {
        let col = y.col(j);
        let s = kernels::sum_abs(col);
        let tau = if s <= eta {
            T::ZERO
        } else if eta <= T::ZERO {
            kernels::colmax(col)
        } else {
            newton_l1_threshold(col, eta)
        };
        ws.norms.push(s);
        ws.thresholds.push(tau);
    }
    for j in 0..m {
        let tau = ws.thresholds[j];
        let dst = out.col_mut(j);
        dst.copy_from_slice(y.col(j));
        if tau > T::ZERO {
            kernels::soft_threshold_inplace(dst, tau);
        }
    }
}

/// `P^∞,¹_η(Y)`: allocate-and-return convenience wrapper around
/// [`project_linf1_into`].
pub fn project_linf1<T: Scalar>(y: &Matrix<T>, eta: T) -> Matrix<T> {
    let mut ws = Workspace::new();
    let mut out = Matrix::zeros(0, 0);
    project_linf1_into(y, eta, &mut ws, &mut out);
    out
}

/// Sort-based reference: each over-budget column's threshold comes from
/// its exact breakpoint profile (`r(mu_at(η)) = η`), then one
/// soft-threshold pass. Golden oracle for the Newton path.
pub fn project_linf1_ref<T: Scalar>(y: &Matrix<T>, eta: T) -> Matrix<T> {
    assert!(eta >= T::ZERO);
    let mut out = y.clone();
    for j in 0..y.cols() {
        let col = out.col_mut(j);
        if kernels::sum_abs(col) <= eta {
            continue;
        }
        let tau = ColumnProfile::new(col).mu_at(eta).0;
        kernels::soft_threshold_inplace_ref(col, tau);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::norms::linf1_norm;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn feasible_and_matches_reference() {
        let mut rng = Xoshiro256pp::seed_from_u64(91);
        for &(n, m) in &[(1usize, 1usize), (17, 9), (40, 12), (5, 30)] {
            let y = Matrix::<f64>::randn(n, m, &mut rng);
            let eta = 0.4 * linf1_norm(&y);
            let x = project_linf1(&y, eta);
            assert!(linf1_norm(&x) <= eta * (1.0 + 1e-12) + 1e-12, "{n}x{m}");
            let r = project_linf1_ref(&y, eta);
            assert!(x.max_abs_diff(&r) < 1e-10, "{n}x{m}: {}", x.max_abs_diff(&r));
        }
    }

    #[test]
    fn inside_ball_is_identity() {
        let mut rng = Xoshiro256pp::seed_from_u64(92);
        let y = Matrix::<f64>::randn(8, 6, &mut rng);
        let x = project_linf1(&y, linf1_norm(&y) * 1.01);
        assert_eq!(x, y);
    }

    #[test]
    fn zero_radius_projects_to_zero() {
        let mut rng = Xoshiro256pp::seed_from_u64(93);
        let y = Matrix::<f64>::randn(6, 4, &mut rng);
        let x = project_linf1(&y, 0.0);
        assert!(x.as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn workspace_reuse_matches_fresh() {
        let mut rng = Xoshiro256pp::seed_from_u64(94);
        let mut ws = Workspace::new();
        let mut out = Matrix::zeros(0, 0);
        for _ in 0..3 {
            let y = Matrix::<f64>::randn(12, 20, &mut rng);
            project_linf1_into(&y, 1.7, &mut ws, &mut out);
            assert_eq!(out, project_linf1(&y, 1.7));
        }
    }
}
