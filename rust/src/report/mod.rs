//! Result reporting: CSV files, markdown tables, ASCII plots.
//!
//! Every experiment writes a CSV under `results/` (machine-readable, used
//! by EXPERIMENTS.md) and prints a markdown table / ASCII chart so a run is
//! interpretable straight from the terminal.

use std::fmt::Write as _;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Directory for experiment outputs (created on demand).
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("BILEVEL_RESULTS_DIR").unwrap_or_else(|_| "results".into());
    let p = PathBuf::from(dir);
    let _ = fs::create_dir_all(&p);
    p
}

/// Minimal CSV writer (quotes nothing — all outputs are numeric/idents).
pub struct CsvWriter {
    file: fs::File,
    pub path: PathBuf,
    cols: usize,
}

impl CsvWriter {
    pub fn create(name: &str, header: &[&str]) -> std::io::Result<Self> {
        let path = results_dir().join(name);
        let mut file = fs::File::create(&path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(Self { file, path, cols: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "CSV row arity mismatch");
        writeln!(self.file, "{}", values.join(","))
    }

    pub fn row_f64(&mut self, values: &[f64]) -> std::io::Result<()> {
        let v: Vec<String> = values.iter().map(|x| format!("{x:.6}")).collect();
        self.row(&v)
    }
}

/// Render a markdown table.
pub fn markdown_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "| {} |", header.join(" | "));
    let _ = writeln!(s, "|{}|", header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for r in rows {
        let _ = writeln!(s, "| {} |", r.join(" | "));
    }
    s
}

/// Tiny ASCII line chart: one row per series, log-x optional.
pub fn ascii_chart(
    title: &str,
    xs: &[f64],
    series: &[(&str, Vec<f64>)],
    width: usize,
    height: usize,
) -> String {
    let mut s = format!("{title}\n");
    if xs.is_empty() || series.is_empty() {
        return s;
    }
    let ymax = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .fold(f64::NEG_INFINITY, f64::max);
    let ymin = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .fold(f64::INFINITY, f64::min);
    let yrange = (ymax - ymin).max(1e-12);
    let xmin = xs[0];
    let xmax = *xs.last().unwrap();
    let xrange = (xmax - xmin).max(1e-12);

    let mut grid = vec![vec![b' '; width]; height];
    let marks = [b'*', b'o', b'+', b'x', b'#'];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (x, y) in xs.iter().zip(ys.iter()) {
            let cx = (((x - xmin) / xrange) * (width - 1) as f64).round() as usize;
            let cy = (((ymax - y) / yrange) * (height - 1) as f64).round() as usize;
            grid[cy.min(height - 1)][cx.min(width - 1)] = marks[si % marks.len()];
        }
    }
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{ymax:>10.3}")
        } else if r == height - 1 {
            format!("{ymin:>10.3}")
        } else {
            " ".repeat(10)
        };
        let _ = writeln!(s, "{label} |{}", String::from_utf8_lossy(row));
    }
    let _ = writeln!(s, "{:>10}  {xmin:<12.4}{:>w$.4}", "", xmax, w = width.saturating_sub(12));
    for (si, (name, _)) in series.iter().enumerate() {
        let _ = writeln!(s, "  {} = {}", marks[si % marks.len()] as char, name);
    }
    s
}

/// Convenience: write a text file into results/.
pub fn write_text(name: &str, content: &str) -> std::io::Result<PathBuf> {
    let path = results_dir().join(name);
    fs::write(&path, content)?;
    Ok(path)
}

/// Read a results CSV back (for tests and report assembly).
pub fn read_csv(path: &Path) -> std::io::Result<(Vec<String>, Vec<Vec<String>>)> {
    let text = fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines
        .next()
        .unwrap_or("")
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let rows = lines
        .map(|l| l.split(',').map(|s| s.to_string()).collect())
        .collect();
    Ok((header, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        std::env::set_var("BILEVEL_RESULTS_DIR", std::env::temp_dir().join("bl_test_results"));
        let mut w = CsvWriter::create("unit_test.csv", &["a", "b"]).unwrap();
        w.row_f64(&[1.0, 2.5]).unwrap();
        w.row(&["x".into(), "y".into()]).unwrap();
        let (header, rows) = read_csv(&w.path).unwrap();
        assert_eq!(header, vec!["a", "b"]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1], vec!["x", "y"]);
        std::env::remove_var("BILEVEL_RESULTS_DIR");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn csv_arity_checked() {
        std::env::set_var("BILEVEL_RESULTS_DIR", std::env::temp_dir().join("bl_test_results"));
        let mut w = CsvWriter::create("unit_test2.csv", &["a", "b"]).unwrap();
        let _ = w.row(&["only-one".into()]);
    }

    #[test]
    fn markdown_table_renders() {
        let t = markdown_table(&["x", "y"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| x | y |"));
        assert!(t.contains("| 1 | 2 |"));
        assert!(t.contains("|---|---|"));
    }

    #[test]
    fn ascii_chart_contains_series_markers() {
        let xs = vec![0.0, 1.0, 2.0, 3.0];
        let chart = ascii_chart(
            "test",
            &xs,
            &[("up", vec![0.0, 1.0, 2.0, 3.0]), ("down", vec![3.0, 2.0, 1.0, 0.0])],
            40,
            10,
        );
        assert!(chart.contains('*'));
        assert!(chart.contains('o'));
        assert!(chart.contains("up"));
    }

    #[test]
    fn ascii_chart_empty_safe() {
        let chart = ascii_chart("empty", &[], &[], 10, 5);
        assert!(chart.starts_with("empty"));
    }
}
