//! Dense matrix / vector substrate.
//!
//! [`Matrix`] is **column-major** (`data[j*n + i]` for row `i`, column `j`):
//! every algorithm in this repo is column-structured (the ℓ1,∞ norm sums
//! per-column maxima), so columns must be contiguous for vectorization and
//! cache locality. Row-major interop (PJRT literals are row-major) goes
//! through [`Matrix::from_row_major`] / [`Matrix::to_row_major`].

use crate::rng::{Normal, Rng};
use crate::scalar::Scalar;

/// A plain dense vector.
pub type Vector<T> = Vec<T>;

/// Column-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix<T: Scalar> {
    /// `rows * cols` values, column-major.
    data: Vec<T>,
    rows: usize,
    cols: usize,
}

impl<T: Scalar> Matrix<T> {
    /// Zero matrix of shape `rows × cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { data: vec![T::ZERO; rows * cols], rows, cols }
    }

    /// Matrix filled with a constant.
    pub fn full(rows: usize, cols: usize, value: T) -> Self {
        Self { data: vec![value; rows * cols], rows, cols }
    }

    /// Build from column-major storage.
    pub fn from_col_major(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_col_major: size mismatch");
        Self { data, rows, cols }
    }

    /// Build from row-major storage (PJRT literal layout). Blocked
    /// transpose — see [`transpose_into`].
    pub fn from_row_major(rows: usize, cols: usize, data: &[T]) -> Self {
        assert_eq!(data.len(), rows * cols, "from_row_major: size mismatch");
        let mut out = Self::zeros(rows, cols);
        transpose_into(data, rows, cols, &mut out.data);
        out
    }

    /// Export to row-major storage. Blocked transpose — see
    /// [`transpose_into`].
    pub fn to_row_major(&self) -> Vec<T> {
        let mut out = vec![T::ZERO; self.data.len()];
        transpose_into(&self.data, self.cols, self.rows, &mut out);
        out
    }

    /// i.i.d. standard normal entries.
    pub fn randn<R: Rng + ?Sized>(rows: usize, cols: usize, rng: &mut R) -> Self {
        let mut normal = Normal::standard();
        let data = (0..rows * cols)
            .map(|_| T::from_f64(normal.sample(rng)))
            .collect();
        Self { data, rows, cols }
    }

    /// i.i.d. uniform entries in `[lo, hi)`.
    pub fn rand_uniform<R: Rng + ?Sized>(
        rows: usize,
        cols: usize,
        lo: f64,
        hi: f64,
        rng: &mut R,
    ) -> Self {
        let data = (0..rows * cols)
            .map(|_| T::from_f64(rng.uniform(lo, hi)))
            .collect();
        Self { data, rows, cols }
    }

    #[inline(always)]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline(always)]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline(always)]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline(always)]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> T {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i]
    }

    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: T) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] = v;
    }

    /// Contiguous view of column `j`.
    #[inline(always)]
    pub fn col(&self, j: usize) -> &[T] {
        debug_assert!(j < self.cols);
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable contiguous view of column `j`.
    #[inline(always)]
    pub fn col_mut(&mut self, j: usize) -> &mut [T] {
        debug_assert!(j < self.cols);
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Iterator over column slices.
    pub fn columns(&self) -> impl Iterator<Item = &[T]> {
        self.data.chunks_exact(self.rows.max(1))
    }

    /// Parallel-safe raw storage access (column-major).
    #[inline(always)]
    pub fn as_slice(&self) -> &[T] {
        &self.data
    }

    #[inline(always)]
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume into column-major storage.
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Row `i` gathered into a fresh vector (strided access).
    pub fn row(&self, i: usize) -> Vec<T> {
        (0..self.cols).map(|j| self.get(i, j)).collect()
    }

    /// Transpose (fresh allocation).
    pub fn transpose(&self) -> Self {
        let mut out = Self::zeros(self.cols, self.rows);
        transpose_into(&self.data, self.cols, self.rows, &mut out.data);
        out
    }

    /// Reshape in place, reusing the existing allocation: after a warm-up
    /// call at a given size, repeated reshapes to the same (or a smaller)
    /// shape allocate nothing. The contents after a growth are
    /// unspecified-but-initialized; callers overwrite every entry.
    pub fn resize_reuse(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, T::ZERO);
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(T) -> T) -> Self {
        Self {
            data: self.data.iter().map(|&x| f(x)).collect(),
            rows: self.rows,
            cols: self.cols,
        }
    }

    /// `self - other`, elementwise.
    pub fn sub(&self, other: &Self) -> Self {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols), "sub: shape mismatch");
        Self {
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(&a, &b)| a - b)
                .collect(),
            rows: self.rows,
            cols: self.cols,
        }
    }

    /// Number of entries with `|x| <= tol`.
    pub fn count_zeros(&self, tol: T) -> usize {
        self.data.iter().filter(|&&x| x.abs() <= tol).count()
    }

    /// Indices of columns whose every entry is `|x| <= tol` (the structured
    /// sparsity the paper optimizes for).
    pub fn zero_columns(&self, tol: T) -> Vec<usize> {
        (0..self.cols)
            .filter(|&j| self.col(j).iter().all(|&x| x.abs() <= tol))
            .collect()
    }

    /// Cast between scalar types (f32 ↔ f64).
    pub fn cast<U: Scalar>(&self) -> Matrix<U> {
        Matrix {
            data: self.data.iter().map(|&x| U::from_f64(x.to_f64())).collect(),
            rows: self.rows,
            cols: self.cols,
        }
    }

    /// Max absolute entrywise difference against another matrix.
    pub fn max_abs_diff(&self, other: &Self) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| (a - b).abs().to_f64())
            .fold(0.0, f64::max)
    }
}

/// Tile edge for the blocked transposes. 32×32 `f64` tiles are 8 KiB —
/// a source tile plus a destination tile sit comfortably in L1.
const TRANSPOSE_BLOCK: usize = 32;

/// Blocked (tiled) transpose: `dst[j*r + i] = src[i*c + j]` for an `r × c`
/// row-major source. The naive strided sweep misses cache once per element
/// as soon as a matrix dimension outgrows L1; walking `BLOCK × BLOCK`
/// tiles keeps both the source rows and the destination columns resident,
/// which is what makes the PJRT row-major interop (`from_row_major` /
/// `to_row_major`) cheap for large weight matrices.
fn transpose_into<T: Scalar>(src: &[T], r: usize, c: usize, dst: &mut [T]) {
    debug_assert_eq!(src.len(), r * c);
    debug_assert_eq!(dst.len(), r * c);
    let mut ib = 0;
    while ib < r {
        let imax = (ib + TRANSPOSE_BLOCK).min(r);
        let mut jb = 0;
        while jb < c {
            let jmax = (jb + TRANSPOSE_BLOCK).min(c);
            for i in ib..imax {
                for j in jb..jmax {
                    dst[j * r + i] = src[i * c + j];
                }
            }
            jb = jmax;
        }
        ib = imax;
    }
}

/// Dense vector helpers shared by the projection algorithms. Thin wrappers
/// over the lane-chunked [`crate::kernels`] reductions, so every caller
/// (norms, projections, the serve replay path) agrees bit-for-bit on the
/// aggregates.
pub mod vec_ops {
    use crate::kernels;
    use crate::scalar::Scalar;

    /// Σ|x_i|
    pub fn l1<T: Scalar>(xs: &[T]) -> T {
        kernels::sum_abs(xs)
    }

    /// √Σx_i²
    pub fn l2<T: Scalar>(xs: &[T]) -> T {
        kernels::l2_norm(xs)
    }

    /// max|x_i| (0 for empty)
    pub fn linf<T: Scalar>(xs: &[T]) -> T {
        kernels::colmax(xs)
    }

    /// Euclidean distance.
    pub fn dist2<T: Scalar>(a: &[T], b: &[T]) -> T {
        debug_assert_eq!(a.len(), b.len());
        a.iter()
            .zip(b.iter())
            .map(|(&x, &y)| (x - y) * (x - y))
            .sum::<T>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn col_major_layout() {
        let m = Matrix::from_row_major(2, 3, &[1.0f64, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(0, 2), 3.0);
        assert_eq!(m.get(1, 1), 5.0);
        assert_eq!(m.col(1), &[2.0, 5.0]);
        assert_eq!(m.to_row_major(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let m = Matrix::<f64>::randn(7, 4, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn row_gather() {
        let m = Matrix::from_row_major(2, 3, &[1.0f64, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.row(0), vec![1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn zero_columns_detection() {
        let mut m = Matrix::<f64>::zeros(3, 4);
        m.set(1, 2, 0.5);
        m.set(0, 0, 1e-12);
        assert_eq!(m.zero_columns(1e-9), vec![0, 1, 3]);
        assert_eq!(m.count_zeros(0.0), 10);
    }

    #[test]
    fn sub_and_map() {
        let a = Matrix::from_row_major(2, 2, &[1.0f64, 2.0, 3.0, 4.0]);
        let b = Matrix::from_row_major(2, 2, &[0.5f64, 0.5, 0.5, 0.5]);
        let d = a.sub(&b);
        assert_eq!(d.get(1, 1), 3.5);
        let m = a.map(|x| x * 2.0);
        assert_eq!(m.get(0, 1), 4.0);
    }

    #[test]
    fn cast_roundtrip_f32() {
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let m = Matrix::<f64>::randn(5, 5, &mut rng);
        let m32: Matrix<f32> = m.cast();
        let back: Matrix<f64> = m32.cast();
        assert!(m.max_abs_diff(&back) < 1e-6);
    }

    #[test]
    fn vec_ops_norms() {
        let v = [3.0f64, -4.0];
        assert_eq!(vec_ops::l1(&v), 7.0);
        assert_eq!(vec_ops::l2(&v), 5.0);
        assert_eq!(vec_ops::linf(&v), 4.0);
        assert_eq!(vec_ops::dist2(&v, &[0.0, 0.0]), 5.0);
    }

    #[test]
    fn blocked_transpose_matches_naive_on_awkward_shapes() {
        // Shapes straddling the tile edge exercise every partial-tile path.
        for (n, m) in [(1, 1), (1, 7), (7, 1), (31, 33), (32, 32), (33, 31), (65, 40)] {
            let mut rng = Xoshiro256pp::seed_from_u64((n * 1000 + m) as u64);
            let row_major: Vec<f64> =
                (0..n * m).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mat = Matrix::from_row_major(n, m, &row_major);
            for i in 0..n {
                for j in 0..m {
                    assert_eq!(mat.get(i, j), row_major[i * m + j], "({i},{j}) of {n}x{m}");
                }
            }
            assert_eq!(mat.to_row_major(), row_major, "{n}x{m} roundtrip");
        }
    }

    #[test]
    fn resize_reuse_keeps_capacity() {
        let mut m = Matrix::<f64>::zeros(8, 8);
        let cap = m.data.capacity();
        m.resize_reuse(4, 4);
        assert_eq!((m.rows(), m.cols(), m.len()), (4, 4, 16));
        m.resize_reuse(8, 8);
        assert_eq!(m.len(), 64);
        assert_eq!(m.data.capacity(), cap, "shrink+regrow must reuse the allocation");
    }

    #[test]
    fn randn_moments() {
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let m = Matrix::<f64>::randn(100, 100, &mut rng);
        let mean: f64 = m.as_slice().iter().sum::<f64>() / 10_000.0;
        assert!(mean.abs() < 0.05, "mean={mean}");
    }
}
