//! Dense matrix / vector substrate.
//!
//! [`Matrix`] is **column-major** (`data[j*n + i]` for row `i`, column `j`):
//! every algorithm in this repo is column-structured (the ℓ1,∞ norm sums
//! per-column maxima), so columns must be contiguous for vectorization and
//! cache locality. Row-major interop (PJRT literals are row-major) goes
//! through [`Matrix::from_row_major`] / [`Matrix::to_row_major`].

use crate::rng::{Normal, Rng};
use crate::scalar::Scalar;

/// A plain dense vector.
pub type Vector<T> = Vec<T>;

/// Column-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix<T: Scalar> {
    /// `rows * cols` values, column-major.
    data: Vec<T>,
    rows: usize,
    cols: usize,
}

impl<T: Scalar> Matrix<T> {
    /// Zero matrix of shape `rows × cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { data: vec![T::ZERO; rows * cols], rows, cols }
    }

    /// Matrix filled with a constant.
    pub fn full(rows: usize, cols: usize, value: T) -> Self {
        Self { data: vec![value; rows * cols], rows, cols }
    }

    /// Build from column-major storage.
    pub fn from_col_major(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_col_major: size mismatch");
        Self { data, rows, cols }
    }

    /// Build from row-major storage (PJRT literal layout).
    pub fn from_row_major(rows: usize, cols: usize, data: &[T]) -> Self {
        assert_eq!(data.len(), rows * cols, "from_row_major: size mismatch");
        let mut out = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                out.data[j * rows + i] = data[i * cols + j];
            }
        }
        out
    }

    /// Export to row-major storage.
    pub fn to_row_major(&self) -> Vec<T> {
        let mut out = vec![T::ZERO; self.data.len()];
        for j in 0..self.cols {
            for i in 0..self.rows {
                out[i * self.cols + j] = self.data[j * self.rows + i];
            }
        }
        out
    }

    /// i.i.d. standard normal entries.
    pub fn randn<R: Rng + ?Sized>(rows: usize, cols: usize, rng: &mut R) -> Self {
        let mut normal = Normal::standard();
        let data = (0..rows * cols)
            .map(|_| T::from_f64(normal.sample(rng)))
            .collect();
        Self { data, rows, cols }
    }

    /// i.i.d. uniform entries in `[lo, hi)`.
    pub fn rand_uniform<R: Rng + ?Sized>(
        rows: usize,
        cols: usize,
        lo: f64,
        hi: f64,
        rng: &mut R,
    ) -> Self {
        let data = (0..rows * cols)
            .map(|_| T::from_f64(rng.uniform(lo, hi)))
            .collect();
        Self { data, rows, cols }
    }

    #[inline(always)]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline(always)]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline(always)]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline(always)]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> T {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i]
    }

    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: T) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] = v;
    }

    /// Contiguous view of column `j`.
    #[inline(always)]
    pub fn col(&self, j: usize) -> &[T] {
        debug_assert!(j < self.cols);
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable contiguous view of column `j`.
    #[inline(always)]
    pub fn col_mut(&mut self, j: usize) -> &mut [T] {
        debug_assert!(j < self.cols);
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Iterator over column slices.
    pub fn columns(&self) -> impl Iterator<Item = &[T]> {
        self.data.chunks_exact(self.rows.max(1))
    }

    /// Parallel-safe raw storage access (column-major).
    #[inline(always)]
    pub fn as_slice(&self) -> &[T] {
        &self.data
    }

    #[inline(always)]
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume into column-major storage.
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Row `i` gathered into a fresh vector (strided access).
    pub fn row(&self, i: usize) -> Vec<T> {
        (0..self.cols).map(|j| self.get(i, j)).collect()
    }

    /// Transpose (fresh allocation).
    pub fn transpose(&self) -> Self {
        let mut out = Self::zeros(self.cols, self.rows);
        for j in 0..self.cols {
            for i in 0..self.rows {
                out.set(j, i, self.get(i, j));
            }
        }
        out
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(T) -> T) -> Self {
        Self {
            data: self.data.iter().map(|&x| f(x)).collect(),
            rows: self.rows,
            cols: self.cols,
        }
    }

    /// `self - other`, elementwise.
    pub fn sub(&self, other: &Self) -> Self {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols), "sub: shape mismatch");
        Self {
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(&a, &b)| a - b)
                .collect(),
            rows: self.rows,
            cols: self.cols,
        }
    }

    /// Number of entries with `|x| <= tol`.
    pub fn count_zeros(&self, tol: T) -> usize {
        self.data.iter().filter(|&&x| x.abs() <= tol).count()
    }

    /// Indices of columns whose every entry is `|x| <= tol` (the structured
    /// sparsity the paper optimizes for).
    pub fn zero_columns(&self, tol: T) -> Vec<usize> {
        (0..self.cols)
            .filter(|&j| self.col(j).iter().all(|&x| x.abs() <= tol))
            .collect()
    }

    /// Cast between scalar types (f32 ↔ f64).
    pub fn cast<U: Scalar>(&self) -> Matrix<U> {
        Matrix {
            data: self.data.iter().map(|&x| U::from_f64(x.to_f64())).collect(),
            rows: self.rows,
            cols: self.cols,
        }
    }

    /// Max absolute entrywise difference against another matrix.
    pub fn max_abs_diff(&self, other: &Self) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| (a - b).abs().to_f64())
            .fold(0.0, f64::max)
    }
}

/// Dense vector helpers shared by the projection algorithms.
pub mod vec_ops {
    use crate::scalar::Scalar;

    /// Σ|x_i|
    pub fn l1<T: Scalar>(xs: &[T]) -> T {
        xs.iter().map(|&x| x.abs()).sum()
    }

    /// √Σx_i²
    pub fn l2<T: Scalar>(xs: &[T]) -> T {
        xs.iter().map(|&x| x * x).sum::<T>().sqrt()
    }

    /// max|x_i| (0 for empty)
    pub fn linf<T: Scalar>(xs: &[T]) -> T {
        xs.iter().fold(T::ZERO, |acc, &x| acc.max_s(x.abs()))
    }

    /// Euclidean distance.
    pub fn dist2<T: Scalar>(a: &[T], b: &[T]) -> T {
        debug_assert_eq!(a.len(), b.len());
        a.iter()
            .zip(b.iter())
            .map(|(&x, &y)| (x - y) * (x - y))
            .sum::<T>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn col_major_layout() {
        let m = Matrix::from_row_major(2, 3, &[1.0f64, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(0, 2), 3.0);
        assert_eq!(m.get(1, 1), 5.0);
        assert_eq!(m.col(1), &[2.0, 5.0]);
        assert_eq!(m.to_row_major(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let m = Matrix::<f64>::randn(7, 4, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn row_gather() {
        let m = Matrix::from_row_major(2, 3, &[1.0f64, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.row(0), vec![1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn zero_columns_detection() {
        let mut m = Matrix::<f64>::zeros(3, 4);
        m.set(1, 2, 0.5);
        m.set(0, 0, 1e-12);
        assert_eq!(m.zero_columns(1e-9), vec![0, 1, 3]);
        assert_eq!(m.count_zeros(0.0), 10);
    }

    #[test]
    fn sub_and_map() {
        let a = Matrix::from_row_major(2, 2, &[1.0f64, 2.0, 3.0, 4.0]);
        let b = Matrix::from_row_major(2, 2, &[0.5f64, 0.5, 0.5, 0.5]);
        let d = a.sub(&b);
        assert_eq!(d.get(1, 1), 3.5);
        let m = a.map(|x| x * 2.0);
        assert_eq!(m.get(0, 1), 4.0);
    }

    #[test]
    fn cast_roundtrip_f32() {
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let m = Matrix::<f64>::randn(5, 5, &mut rng);
        let m32: Matrix<f32> = m.cast();
        let back: Matrix<f64> = m32.cast();
        assert!(m.max_abs_diff(&back) < 1e-6);
    }

    #[test]
    fn vec_ops_norms() {
        let v = [3.0f64, -4.0];
        assert_eq!(vec_ops::l1(&v), 7.0);
        assert_eq!(vec_ops::l2(&v), 5.0);
        assert_eq!(vec_ops::linf(&v), 4.0);
        assert_eq!(vec_ops::dist2(&v, &[0.0, 0.0]), 5.0);
    }

    #[test]
    fn randn_moments() {
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let m = Matrix::<f64>::randn(100, 100, &mut rng);
        let mean: f64 = m.as_slice().iter().sum::<f64>() / 10_000.0;
        assert!(mean.abs() < 0.05, "mean={mean}");
    }
}
