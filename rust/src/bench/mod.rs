//! Benchmark harness (criterion is unavailable offline, so this is the
//! in-repo equivalent): warmup + repeated timing with robust statistics,
//! plus the least-squares growth-rate fits the paper's Fig. 1 uses
//! (linear for `BP¹,∞`, `n log n` for the exact projection).

pub mod compare;
pub mod kernels;
pub mod projection_family;
pub mod sparse;

use std::time::{Duration, Instant};

/// Machine metadata stamped into every committed `BENCH_*.json` snapshot
/// so a perf number is never read without knowing what produced it.
#[derive(Clone, Debug)]
pub struct MachineInfo {
    /// CPU model string (`/proc/cpuinfo` on Linux, `"unknown"` elsewhere).
    pub cpu_model: String,
    /// `std::env::consts::ARCH` of the bench binary.
    pub arch: &'static str,
    /// `std::env::consts::OS` of the bench binary.
    pub os: &'static str,
    /// The kernel ISA the dispatcher selected for this process
    /// (`portable` / `avx2` / `neon`) — see [`crate::kernels::active_isa`].
    pub isa: &'static str,
    /// `std::thread::available_parallelism()`.
    pub hardware_threads: usize,
}

impl MachineInfo {
    /// Render as a JSON object (the `"machine"` block of the reports).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"cpu_model\": {:?}, \"arch\": {:?}, \"os\": {:?}, \"isa\": {:?}, \"hardware_threads\": {}}}",
            self.cpu_model, self.arch, self.os, self.isa, self.hardware_threads
        )
    }
}

/// Probe the machine the bench is running on.
pub fn machine_info() -> MachineInfo {
    MachineInfo {
        cpu_model: cpu_model(),
        arch: std::env::consts::ARCH,
        os: std::env::consts::OS,
        isa: crate::kernels::active_isa().name(),
        hardware_threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

fn cpu_model() -> String {
    #[cfg(target_os = "linux")]
    {
        if let Ok(info) = std::fs::read_to_string("/proc/cpuinfo") {
            for line in info.lines() {
                if let Some(rest) = line.strip_prefix("model name") {
                    if let Some((_, model)) = rest.split_once(':') {
                        return model.trim().to_string();
                    }
                }
            }
        }
    }
    "unknown".to_string()
}

/// Timing statistics over repeated runs (seconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub mean: f64,
    pub median: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub iters: usize,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
        Self {
            mean,
            median: samples[n / 2],
            std: var.sqrt(),
            min: samples[0],
            max: samples[n - 1],
            iters: n,
        }
    }
}

/// Benchmark policy.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            target_time: Duration::from_millis(400),
        }
    }
}

impl BenchConfig {
    /// Faster settings for `--quick` runs and tests.
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            target_time: Duration::from_millis(60),
        }
    }
}

/// Time a closure: warmup, then run until both `min_iters` and
/// `target_time` are satisfied (or `max_iters` hit).
pub fn time_fn<T>(cfg: &BenchConfig, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..cfg.warmup_iters {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(cfg.min_iters);
    let start = Instant::now();
    while samples.len() < cfg.min_iters
        || (start.elapsed() < cfg.target_time && samples.len() < cfg.max_iters)
    {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::from_samples(samples)
}

/// Optimizer barrier.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// ------------------------------------------------------------ curve fits

/// Least-squares fit of `y ≈ a·g(x) + b`; returns `(a, b, r²)`.
pub fn fit(xs: &[f64], ys: &[f64], g: impl Fn(f64) -> f64) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let gx: Vec<f64> = xs.iter().map(|&x| g(x)).collect();
    let mean_g = gx.iter().sum::<f64>() / n;
    let mean_y = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (gi, yi) in gx.iter().zip(ys.iter()) {
        sxy += (gi - mean_g) * (yi - mean_y);
        sxx += (gi - mean_g) * (gi - mean_g);
    }
    let a = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let b = mean_y - a * mean_g;
    let mut ss_res = 0.0;
    let mut ss_tot = 0.0;
    for (gi, yi) in gx.iter().zip(ys.iter()) {
        let pred = a * gi + b;
        ss_res += (yi - pred) * (yi - pred);
        ss_tot += (yi - mean_y) * (yi - mean_y);
    }
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, b, r2)
}

/// Fit `y = a·x + b` (the bi-level projection's expected growth).
pub fn fit_linear(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    fit(xs, ys, |x| x)
}

/// Fit `y = a·x·log(x) + b` (the exact projection's expected growth).
pub fn fit_nlogn(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    fit(xs, ys, |x| x * x.max(2.0).ln())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn time_fn_runs_minimum_iterations() {
        let cfg = BenchConfig::quick();
        let mut count = 0;
        let s = time_fn(&cfg, || {
            count += 1;
            count
        });
        assert!(s.iters >= cfg.min_iters);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn linear_fit_recovers_coefficients() {
        let xs: Vec<f64> = (1..=50).map(|i| i as f64 * 100.0).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 3.5 * x + 7.0).collect();
        let (a, b, r2) = fit_linear(&xs, &ys);
        assert!((a - 3.5).abs() < 1e-9);
        assert!((b - 7.0).abs() < 1e-6);
        assert!(r2 > 0.999999);
    }

    #[test]
    fn nlogn_fit_recovers_coefficients() {
        let xs: Vec<f64> = (2..=50).map(|i| i as f64 * 100.0).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 0.25 * x * x.ln() - 3.0).collect();
        let (a, b, r2) = fit_nlogn(&xs, &ys);
        assert!((a - 0.25).abs() < 1e-9);
        assert!((b + 3.0).abs() < 1e-5);
        assert!(r2 > 0.999999);
    }

    #[test]
    fn linear_data_fits_linear_better_than_nlogn() {
        let xs: Vec<f64> = (1..=40).map(|i| i as f64 * 500.0).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 2.0 * x).collect();
        let (_, _, r2_lin) = fit_linear(&xs, &ys);
        let (_, _, r2_nlogn) = fit_nlogn(&xs, &ys);
        assert!(r2_lin >= r2_nlogn);
    }

    #[test]
    fn machine_info_is_populated_and_renders() {
        let m = machine_info();
        assert!(!m.cpu_model.is_empty());
        assert!(m.hardware_threads >= 1);
        assert_eq!(m.isa, crate::kernels::active_isa().name());
        let json = m.to_json();
        assert!(json.contains("\"cpu_model\""));
        assert!(json.contains("\"isa\""));
        assert!(json.contains(m.isa));
    }

    #[test]
    fn degenerate_fit_safe() {
        let (a, _, r2) = fit_linear(&[1.0], &[2.0]);
        assert_eq!(a, 0.0);
        assert_eq!(r2, 1.0);
    }
}
