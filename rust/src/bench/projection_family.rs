//! Projection-family benchmarks — `bilevel bench projection-family` and
//! `cargo bench --bench projection_family`.
//!
//! Times every flat [`ProjectionKind`] over f32/f64 at representative
//! shapes, plus the multilevel projection tree's depth-vs-threads speedup
//! curve (the sequel paper's scaling claim: per-subtree work on the
//! persistent kernel pool). Results render as a markdown table and
//! serialize to `BENCH_projection_family.json` (repo root), which
//! `bilevel bench compare` gates against — see EXPERIMENTS.md §Projection
//! family for how to regenerate.

use crate::bench::{black_box, machine_info, time_fn, BenchConfig, MachineInfo};
use crate::projection::bilevel::ParallelPolicy;
use crate::projection::l1::L1Algorithm;
use crate::projection::multilevel::{project_multilevel_with, tree_norm, MultilevelSpec};
use crate::projection::ProjectionKind;
use crate::rng::Xoshiro256pp;
use crate::scalar::Scalar;
use crate::tensor::Matrix;

/// One timed row. Unlike the kernel suite there is no baseline column:
/// the family rows are absolute medians, compared across PRs by
/// `bench compare` rather than against an in-process scalar twin.
#[derive(Clone, Debug)]
pub struct FamilyBenchEntry {
    /// `project/<kind>/<dtype>` for flat kinds,
    /// `multilevel/d<depth>/t<threads>` for the tree curve.
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// Median wall time, ms.
    pub ms: f64,
}

/// Full report of one `bench projection-family` run.
#[derive(Clone, Debug)]
pub struct FamilyBenchReport {
    pub quick: bool,
    /// What produced these numbers (CPU, arch/OS, dispatched ISA, threads).
    pub machine: MachineInfo,
    pub entries: Vec<FamilyBenchEntry>,
}

impl FamilyBenchReport {
    /// Hand-rolled JSON (no serde offline). Stable key order, fixed
    /// notation — diff-friendly for the perf trajectory.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"machine\": {},\n", self.machine.to_json()));
        s.push_str("  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"rows\": {}, \"cols\": {}, \"ms\": {:.6}}}{}\n",
                e.name,
                e.rows,
                e.cols,
                e.ms,
                if i + 1 < self.entries.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Terminal rendering: the §Projection family markdown table.
    pub fn markdown(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .entries
            .iter()
            .map(|e| {
                vec![e.name.clone(), format!("{}x{}", e.rows, e.cols), format!("{:.3}", e.ms)]
            })
            .collect();
        let mut s = crate::report::markdown_table(&["bench", "shape", "ms"], &rows);
        s.push_str(&format!(
            "\nmachine: {} ({}/{}, {} threads), kernel isa: {}\n",
            self.machine.cpu_model,
            self.machine.arch,
            self.machine.os,
            self.machine.hardware_threads,
            self.machine.isa
        ));
        s
    }
}

/// Time one flat kind at one shape for scalar type `T`. Radius = half the
/// matched norm so every kind does real shrinking work (the identity
/// baseline has no ball and is skipped by [`run`]).
fn flat_entry<T: Scalar>(
    cfg: &BenchConfig,
    kind: ProjectionKind,
    dtype: &str,
    rows: usize,
    cols: usize,
) -> FamilyBenchEntry {
    let mut rng = Xoshiro256pp::seed_from_u64((rows * 31 + cols) as u64);
    let y = Matrix::<T>::randn(rows, cols, &mut rng);
    let eta = kind
        .matched_norm(&y)
        .map(|n| n * T::from_f64(0.5))
        .unwrap_or(T::ONE);
    let stats = time_fn(cfg, || black_box(kind.apply_with(&y, eta, L1Algorithm::Condat)));
    FamilyBenchEntry {
        name: format!("project/{}/{dtype}", kind.name()),
        rows,
        cols,
        ms: stats.median * 1e3,
    }
}

/// The tree specs of the depth-vs-threads curve, root→leaf, one per depth
/// 2..=4. Depth 2 `l1/linf` is exactly the paper's bi-level projection, so
/// the `t1` row of that spec doubles as the sequential reference the
/// speedups are read against.
pub const CURVE_SPECS: &[&str] = &["l1/linf", "l1/l2:8/linf", "l1/l1:4/l2:8/linf"];

/// Thread counts probed per tree spec.
pub const CURVE_THREADS: &[usize] = &[1, 2, 4, 8];

/// Measure the multilevel depth-vs-threads curve at one shape. The pool is
/// forced on (`min_elems: 0`) so each row is a genuine split at that
/// thread count, not the sequential fallback.
pub fn multilevel_curve(cfg: &BenchConfig, rows: usize, cols: usize) -> Vec<FamilyBenchEntry> {
    let mut rng = Xoshiro256pp::seed_from_u64(0xC0DE + rows as u64);
    let y = Matrix::<f64>::randn(rows, cols, &mut rng);
    let mut entries = Vec::new();
    for spec_s in CURVE_SPECS {
        let spec = MultilevelSpec::parse(spec_s).expect("curve spec parses");
        let eta = tree_norm(&y, &spec) * 0.5;
        for &threads in CURVE_THREADS {
            let policy = ParallelPolicy { threads, min_elems: 0 };
            let stats = time_fn(cfg, || {
                black_box(project_multilevel_with(&y, eta, &spec, L1Algorithm::Condat, policy))
            });
            entries.push(FamilyBenchEntry {
                name: format!("multilevel/d{}/t{}", spec.depth(), threads),
                rows,
                cols,
                ms: stats.median * 1e3,
            });
        }
    }
    entries
}

/// Run the full projection-family suite. `quick` shrinks shapes and timing
/// budgets for CI-sized runs; quick shapes are a strict subset of the full
/// shapes so `bench compare` always finds overlapping rows against the
/// committed full-mode snapshot.
pub fn run(quick: bool) -> FamilyBenchReport {
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let shapes: &[(usize, usize)] =
        if quick { &[(256, 256)] } else { &[(256, 256), (512, 512)] };

    let mut entries = Vec::new();
    for &(rows, cols) in shapes {
        for &kind in ProjectionKind::all() {
            entries.push(flat_entry::<f32>(&cfg, kind, "f32", rows, cols));
            entries.push(flat_entry::<f64>(&cfg, kind, "f64", rows, cols));
        }
        entries.extend(multilevel_curve(&cfg, rows, cols));
    }

    FamilyBenchReport { quick, machine: machine_info(), entries }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> BenchConfig {
        BenchConfig {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 1,
            target_time: std::time::Duration::from_millis(1),
        }
    }

    #[test]
    fn report_serializes_to_valid_shape() {
        let report = FamilyBenchReport {
            quick: true,
            machine: crate::bench::machine_info(),
            entries: vec![FamilyBenchEntry {
                name: "project/l21/f64".into(),
                rows: 8,
                cols: 8,
                ms: 0.25,
            }],
        };
        let json = report.to_json();
        assert!(json.contains("\"name\": \"project/l21/f64\""));
        assert!(json.contains("\"ms\": 0.250000"));
        assert!(json.contains("\"machine\": {\"cpu_model\""));
        assert!(json.trim_end().ends_with('}'));
        let md = report.markdown();
        assert!(md.contains("project/l21/f64"));
        assert!(md.contains("8x8"));
        assert!(md.contains(crate::kernels::active_isa().name()));
    }

    #[test]
    fn flat_entries_cover_every_kind_and_dtype() {
        let cfg = tiny_cfg();
        for &kind in ProjectionKind::all() {
            let e32 = flat_entry::<f32>(&cfg, kind, "f32", 6, 5);
            let e64 = flat_entry::<f64>(&cfg, kind, "f64", 6, 5);
            assert_eq!(e32.name, format!("project/{}/f32", kind.name()));
            assert_eq!(e64.name, format!("project/{}/f64", kind.name()));
            assert!(e32.ms >= 0.0 && e64.ms >= 0.0);
        }
    }

    #[test]
    fn multilevel_curve_emits_depth_by_thread_grid() {
        let cfg = tiny_cfg();
        let entries = multilevel_curve(&cfg, 6, 8);
        assert_eq!(entries.len(), CURVE_SPECS.len() * CURVE_THREADS.len());
        assert!(entries.iter().any(|e| e.name == "multilevel/d2/t1"));
        assert!(entries.iter().any(|e| e.name == "multilevel/d4/t8"));
        assert!(entries.iter().all(|e| e.rows == 6 && e.cols == 8));
    }

    #[test]
    fn quick_shapes_are_a_subset_of_full_shapes() {
        // The compare gate matches (name, rows, cols); a quick shape
        // missing from the full/committed set would silently gate nothing.
        let quick: &[(usize, usize)] = &[(256, 256)];
        let full: &[(usize, usize)] = &[(256, 256), (512, 512)];
        for s in quick {
            assert!(full.contains(s));
        }
    }
}
