//! Perf-regression comparison: a fresh bench run vs a committed
//! `BENCH_*.json` snapshot — the engine behind `bilevel bench compare`
//! and the CI `Perf regression gate`.
//!
//! A row **regresses** when the fresh kernel-side median exceeds
//! `tolerance ×` the committed one *and* the committed number is at least
//! `min_ms` (sub-`min_ms` rows are dominated by timer noise on shared CI
//! runners, so they are compared but never gate). Rows present only in
//! one side are skipped and counted, never failed: the committed
//! snapshots are full-mode runs, a fresh `--quick` run covers a subset of
//! their (name, shape) keys by construction.

use crate::bench::kernels::KernelBenchReport;
use crate::bench::projection_family::FamilyBenchReport;
use crate::bench::sparse::SparseBenchReport;
use crate::net::wire::Json;

/// One (name, shape)-matched pair of committed vs fresh medians.
#[derive(Clone, Debug)]
pub struct CompareRow {
    pub name: String,
    /// Human-readable shape key, e.g. `512x512` or `512x64 b8 @90%`.
    pub shape: String,
    pub committed_ms: f64,
    pub fresh_ms: f64,
    /// `fresh > tolerance × committed` with `committed >= min_ms`.
    pub regressed: bool,
}

impl CompareRow {
    /// `fresh / committed` (0 when the committed median is 0).
    pub fn ratio(&self) -> f64 {
        if self.committed_ms > 0.0 {
            self.fresh_ms / self.committed_ms
        } else {
            0.0
        }
    }
}

/// Outcome of one suite comparison.
#[derive(Clone, Debug)]
pub struct CompareReport {
    /// `kernels` or `sparse`.
    pub suite: &'static str,
    pub tolerance: f64,
    pub min_ms: f64,
    pub rows: Vec<CompareRow>,
    /// Fresh rows with no committed counterpart (ignored, reported).
    pub skipped_fresh_only: usize,
}

impl CompareReport {
    /// The rows that exceeded tolerance.
    pub fn regressions(&self) -> Vec<&CompareRow> {
        self.rows.iter().filter(|r| r.regressed).collect()
    }

    /// Terminal rendering of the comparison.
    pub fn markdown(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.name.clone(),
                    r.shape.clone(),
                    format!("{:.3}", r.committed_ms),
                    format!("{:.3}", r.fresh_ms),
                    format!("{:.2}x", r.ratio()),
                    if r.regressed { "REGRESSED".into() } else { "ok".into() },
                ]
            })
            .collect();
        let mut s = crate::report::markdown_table(
            &["bench", "shape", "committed ms", "fresh ms", "ratio", "verdict"],
            &rows,
        );
        s.push_str(&format!(
            "\nsuite: {} — {} rows compared, {} regression(s), tolerance {:.2}x, \
             min gate {:.3} ms, {} fresh-only row(s) skipped\n",
            self.suite,
            self.rows.len(),
            self.regressions().len(),
            self.tolerance,
            self.min_ms,
            self.skipped_fresh_only
        ));
        s
    }
}

fn committed_entries(committed_json: &str) -> Result<Vec<Json>, String> {
    let doc = Json::parse(committed_json)?;
    let entries = doc
        .get("entries")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| "committed snapshot has no \"entries\" array".to_string())?;
    Ok(entries.to_vec())
}

fn gate(committed_ms: f64, fresh_ms: f64, tolerance: f64, min_ms: f64) -> bool {
    committed_ms >= min_ms && fresh_ms > tolerance * committed_ms
}

/// Compare a fresh kernel bench run against a committed
/// `BENCH_kernels.json`. Entries match on `(name, rows, cols)`; the gated
/// quantity is `kernel_ms` (the production path — baselines drift with
/// the baseline code, not the kernels).
pub fn compare_kernels(
    committed_json: &str,
    fresh: &KernelBenchReport,
    tolerance: f64,
    min_ms: f64,
) -> Result<CompareReport, String> {
    let entries = committed_entries(committed_json)?;
    let mut rows = Vec::new();
    let mut skipped = 0usize;
    for f in &fresh.entries {
        let hit = entries.iter().find(|e| {
            e.get("name").and_then(|v| v.as_str()) == Some(f.name.as_str())
                && e.get("rows").and_then(|v| v.as_usize()) == Some(f.rows)
                && e.get("cols").and_then(|v| v.as_usize()) == Some(f.cols)
        });
        let Some(hit) = hit else {
            skipped += 1;
            continue;
        };
        let committed_ms = hit
            .get("kernel_ms")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("committed entry {} has no kernel_ms", f.name))?;
        rows.push(CompareRow {
            name: f.name.clone(),
            shape: format!("{}x{}", f.rows, f.cols),
            committed_ms,
            fresh_ms: f.kernel_ms,
            regressed: gate(committed_ms, f.kernel_ms, tolerance, min_ms),
        });
    }
    if rows.is_empty() {
        return Err("no comparable kernel rows between fresh run and committed snapshot".into());
    }
    Ok(CompareReport { suite: "kernels", tolerance, min_ms, rows, skipped_fresh_only: skipped })
}

/// Compare a fresh sparse bench run against a committed
/// `BENCH_sparse.json`. Entries match on
/// `(name, features, hidden, batch, sparsity_pct)`; the gated quantity is
/// `compact_ms` (the production sparse path).
pub fn compare_sparse(
    committed_json: &str,
    fresh: &SparseBenchReport,
    tolerance: f64,
    min_ms: f64,
) -> Result<CompareReport, String> {
    let entries = committed_entries(committed_json)?;
    let mut rows = Vec::new();
    let mut skipped = 0usize;
    for f in &fresh.entries {
        let hit = entries.iter().find(|e| {
            e.get("name").and_then(|v| v.as_str()) == Some(f.name.as_str())
                && e.get("features").and_then(|v| v.as_usize()) == Some(f.features)
                && e.get("hidden").and_then(|v| v.as_usize()) == Some(f.hidden)
                && e.get("batch").and_then(|v| v.as_usize()) == Some(f.batch)
                && e.get("sparsity_pct").and_then(|v| v.as_usize()) == Some(f.sparsity_pct)
        });
        let Some(hit) = hit else {
            skipped += 1;
            continue;
        };
        let committed_ms = hit
            .get("compact_ms")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("committed entry {} has no compact_ms", f.name))?;
        rows.push(CompareRow {
            name: f.name.clone(),
            shape: format!("{}x{} b{} @{}%", f.features, f.hidden, f.batch, f.sparsity_pct),
            committed_ms,
            fresh_ms: f.compact_ms,
            regressed: gate(committed_ms, f.compact_ms, tolerance, min_ms),
        });
    }
    if rows.is_empty() {
        return Err("no comparable sparse rows between fresh run and committed snapshot".into());
    }
    Ok(CompareReport { suite: "sparse", tolerance, min_ms, rows, skipped_fresh_only: skipped })
}

/// Compare a fresh projection-family bench run against a committed
/// `BENCH_projection_family.json`. Entries match on `(name, rows, cols)`;
/// the gated quantity is `ms` (the family rows are absolute medians — no
/// baseline column).
pub fn compare_projection_family(
    committed_json: &str,
    fresh: &FamilyBenchReport,
    tolerance: f64,
    min_ms: f64,
) -> Result<CompareReport, String> {
    let entries = committed_entries(committed_json)?;
    let mut rows = Vec::new();
    let mut skipped = 0usize;
    for f in &fresh.entries {
        let hit = entries.iter().find(|e| {
            e.get("name").and_then(|v| v.as_str()) == Some(f.name.as_str())
                && e.get("rows").and_then(|v| v.as_usize()) == Some(f.rows)
                && e.get("cols").and_then(|v| v.as_usize()) == Some(f.cols)
        });
        let Some(hit) = hit else {
            skipped += 1;
            continue;
        };
        let committed_ms = hit
            .get("ms")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("committed entry {} has no ms", f.name))?;
        rows.push(CompareRow {
            name: f.name.clone(),
            shape: format!("{}x{}", f.rows, f.cols),
            committed_ms,
            fresh_ms: f.ms,
            regressed: gate(committed_ms, f.ms, tolerance, min_ms),
        });
    }
    if rows.is_empty() {
        return Err(
            "no comparable projection-family rows between fresh run and committed snapshot".into(),
        );
    }
    Ok(CompareReport {
        suite: "projection-family",
        tolerance,
        min_ms,
        rows,
        skipped_fresh_only: skipped,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::kernels::KernelBenchEntry;
    use crate::bench::projection_family::FamilyBenchEntry;
    use crate::bench::machine_info;
    use crate::bench::sparse::SparseBenchEntry;
    use crate::projection::bilevel::ParallelPolicy;

    fn kernel_report(entries: Vec<KernelBenchEntry>) -> KernelBenchReport {
        let d = ParallelPolicy::default().min_elems;
        KernelBenchReport {
            quick: true,
            machine: machine_info(),
            entries,
            crossover_elems: 0,
            default_min_elems: d,
            recommended_min_elems: d,
            effective_min_elems: d,
        }
    }

    fn kentry(name: &str, n: usize, kernel_ms: f64) -> KernelBenchEntry {
        KernelBenchEntry {
            name: name.into(),
            rows: n,
            cols: n,
            baseline_ms: kernel_ms * 2.0,
            kernel_ms,
        }
    }

    const COMMITTED_KERNELS: &str = r#"{
      "quick": false,
      "crossover_elems": 9216,
      "default_min_elems": 8192,
      "entries": [
        {"name": "bp1inf/seq", "rows": 128, "cols": 128, "baseline_ms": 0.1, "kernel_ms": 0.05, "speedup": 2.0},
        {"name": "bp1inf/seq", "rows": 256, "cols": 256, "baseline_ms": 0.4, "kernel_ms": 0.2, "speedup": 2.0},
        {"name": "kernel/colmax", "rows": 65536, "cols": 1, "baseline_ms": 0.06, "kernel_ms": 0.015, "speedup": 4.0}
      ]
    }"#;

    #[test]
    fn within_tolerance_passes() {
        let fresh =
            kernel_report(vec![kentry("bp1inf/seq", 128, 0.08), kentry("bp1inf/seq", 256, 0.3)]);
        let rep = compare_kernels(COMMITTED_KERNELS, &fresh, 2.0, 0.02).unwrap();
        assert_eq!(rep.rows.len(), 2);
        assert!(rep.regressions().is_empty(), "{}", rep.markdown());
    }

    #[test]
    fn beyond_tolerance_regresses() {
        let fresh = kernel_report(vec![kentry("bp1inf/seq", 128, 0.2)]);
        let rep = compare_kernels(COMMITTED_KERNELS, &fresh, 2.0, 0.02).unwrap();
        assert_eq!(rep.regressions().len(), 1);
        assert!(rep.markdown().contains("REGRESSED"));
    }

    #[test]
    fn sub_min_ms_rows_never_gate() {
        // Committed colmax is 0.015 ms < min_ms 0.02 — even a 10x-slower
        // fresh run is noise-exempt.
        let fresh = kernel_report(vec![
            kentry("bp1inf/seq", 128, 0.05),
            kentry("kernel/colmax", 65536, 0.15),
        ]);
        let rep = compare_kernels(COMMITTED_KERNELS, &fresh, 2.0, 0.02).unwrap();
        assert_eq!(rep.rows.len(), 2);
        assert!(rep.regressions().is_empty());
    }

    #[test]
    fn fresh_only_rows_are_skipped_not_failed() {
        let fresh = kernel_report(vec![
            kentry("bp1inf/seq", 128, 0.05),
            kentry("crossover/probe", 32, 0.001),
        ]);
        let rep = compare_kernels(COMMITTED_KERNELS, &fresh, 2.0, 0.02).unwrap();
        assert_eq!(rep.rows.len(), 1);
        assert_eq!(rep.skipped_fresh_only, 1);
    }

    #[test]
    fn zero_overlap_is_an_error() {
        let fresh = kernel_report(vec![kentry("bp1inf/seq", 999, 0.05)]);
        assert!(compare_kernels(COMMITTED_KERNELS, &fresh, 2.0, 0.02).is_err());
    }

    #[test]
    fn malformed_committed_json_is_an_error() {
        let fresh = kernel_report(vec![kentry("bp1inf/seq", 128, 0.05)]);
        assert!(compare_kernels("{\"quick\": true}", &fresh, 2.0, 0.02).is_err());
        assert!(compare_kernels("not json", &fresh, 2.0, 0.02).is_err());
    }

    #[test]
    fn projection_family_compare_gates_on_ms() {
        let committed = r#"{
          "entries": [
            {"name": "project/l21/f64", "rows": 256, "cols": 256, "ms": 0.4},
            {"name": "multilevel/d3/t4", "rows": 256, "cols": 256, "ms": 0.2},
            {"name": "project/linf1-newton/f32", "rows": 256, "cols": 256, "ms": 0.01}
          ]
        }"#;
        let entry = |name: &str, ms: f64| FamilyBenchEntry {
            name: name.into(),
            rows: 256,
            cols: 256,
            ms,
        };
        let fresh = FamilyBenchReport {
            quick: true,
            machine: machine_info(),
            entries: vec![
                entry("project/l21/f64", 0.5),
                entry("multilevel/d3/t4", 0.9),
                // Committed 0.01 ms < min gate 0.02 — noise-exempt even 20x slower.
                entry("project/linf1-newton/f32", 0.2),
                // No committed counterpart — skipped, not failed.
                entry("multilevel/d4/t8", 0.3),
            ],
        };
        let rep = compare_projection_family(committed, &fresh, 2.0, 0.02).unwrap();
        assert_eq!(rep.suite, "projection-family");
        assert_eq!(rep.rows.len(), 3);
        assert_eq!(rep.skipped_fresh_only, 1);
        let regs = rep.regressions();
        assert_eq!(regs.len(), 1, "{}", rep.markdown());
        assert_eq!(regs[0].name, "multilevel/d3/t4");

        let none = FamilyBenchReport {
            quick: true,
            machine: machine_info(),
            entries: vec![entry("multilevel/d9/t9", 0.1)],
        };
        assert!(compare_projection_family(committed, &none, 2.0, 0.02).is_err());
    }

    #[test]
    fn sparse_compare_matches_on_full_shape_key() {
        let committed = r#"{
          "entries": [
            {"name": "encode/f32", "features": 512, "hidden": 64, "batch": 8,
             "sparsity_pct": 90, "alive": 52, "dense_ms": 0.056, "compact_ms": 0.008,
             "speedup": 7.0, "bit_identical": true}
          ]
        }"#;
        let entry = |sparsity: usize, compact_ms: f64| SparseBenchEntry {
            name: "encode/f32".into(),
            features: 512,
            hidden: 64,
            batch: 8,
            sparsity_pct: sparsity,
            alive: 52,
            dense_ms: 0.06,
            compact_ms,
            bit_identical: true,
        };
        let fresh = SparseBenchReport {
            quick: true,
            machine: machine_info(),
            entries: vec![entry(90, 0.012), entry(95, 0.004)],
        };
        let rep = compare_sparse(committed, &fresh, 2.0, 0.002).unwrap();
        // 95% row has no committed counterpart; 90% row is within 2x.
        assert_eq!(rep.rows.len(), 1);
        assert_eq!(rep.skipped_fresh_only, 1);
        assert!(rep.regressions().is_empty());

        let slow = SparseBenchReport {
            quick: true,
            machine: machine_info(),
            entries: vec![entry(90, 0.05)],
        };
        let rep = compare_sparse(committed, &slow, 2.0, 0.002).unwrap();
        assert_eq!(rep.regressions().len(), 1);
    }
}
