//! Kernel-layer microbenchmarks — `bilevel bench kernels` and
//! `cargo bench --bench kernels`.
//!
//! Measures the lane-chunked kernel layer against the seed's scalar path
//! (kept here, verbatim, as [`bilevel_l1inf_scalar_baseline`]), the
//! parking-pool parallel path against the sequential kernel path, and the
//! individual kernels against their naive loops; then re-probes the
//! sequential/parallel crossover that calibrates
//! `ParallelPolicy::min_elems`. Results render as a markdown table and
//! serialize to `BENCH_kernels.json` (repo root) so the perf trajectory is
//! tracked across PRs — see EXPERIMENTS.md §Perf for how to regenerate.

use crate::bench::{black_box, time_fn, BenchConfig};
use crate::kernels;
use crate::projection::bilevel::{
    bilevel_l1inf_parallel, bilevel_l1inf_with, BilevelResult, ParallelPolicy,
};
use crate::projection::l1::{self, L1Algorithm};
use crate::rng::{Rng, Xoshiro256pp};
use crate::scalar::Scalar;
use crate::tensor::Matrix;

/// The seed's scalar `BP¹,∞`: naive fold reduction, branchy `signum·min`
/// clip, fresh buffers every call. This is the "before" every kernel
/// speedup in `BENCH_kernels.json` is measured against.
pub fn bilevel_l1inf_scalar_baseline<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    algo: L1Algorithm,
) -> BilevelResult<T> {
    let (n, m) = (y.rows(), y.cols());
    let v: Vec<T> = y
        .columns()
        .map(|col| col.iter().fold(T::ZERO, |acc, &x| acc.max_s(x.abs())))
        .collect();
    let u = l1::project_l1(&v, eta, algo);
    let mut data: Vec<T> = Vec::with_capacity(n * m);
    for (j, col) in y.columns().enumerate() {
        let c = u[j];
        if c >= v[j] {
            data.extend_from_slice(col);
        } else {
            data.extend(col.iter().map(|&x| x.signum_s() * x.abs().min_s(c)));
        }
    }
    BilevelResult { x: Matrix::from_col_major(n, m, data), thresholds: u }
}

/// The seed's clip loop, for the per-kernel micro rows.
fn clip_signum_baseline<T: Scalar>(src: &[T], c: T, dst: &mut [T]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s.signum_s() * s.abs().min_s(c);
    }
}

/// One measured comparison: `baseline_ms / kernel_ms = speedup`.
#[derive(Clone, Debug)]
pub struct KernelBenchEntry {
    /// e.g. `bp1inf/seq`, `bp1inf/pool`, `kernel/colmax`.
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// Median of the pre-kernel (scalar / sequential) implementation, ms.
    pub baseline_ms: f64,
    /// Median of the kernel-layer implementation, ms.
    pub kernel_ms: f64,
}

impl KernelBenchEntry {
    pub fn speedup(&self) -> f64 {
        if self.kernel_ms > 0.0 {
            self.baseline_ms / self.kernel_ms
        } else {
            0.0
        }
    }
}

/// Full report of one `bench kernels` run.
#[derive(Clone, Debug)]
pub struct KernelBenchReport {
    pub quick: bool,
    pub hardware_threads: usize,
    pub entries: Vec<KernelBenchEntry>,
    /// Smallest probed element count where the pool-parallel path beat the
    /// sequential kernel path (the measured `min_elems` candidate); 0 if
    /// it never won on the probed sizes.
    pub crossover_elems: usize,
    /// The `ParallelPolicy::min_elems` default compiled into the library.
    pub default_min_elems: usize,
}

impl KernelBenchReport {
    /// Hand-rolled JSON (no serde offline). Stable key order, numbers in
    /// fixed notation — diff-friendly for the perf trajectory.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"hardware_threads\": {},\n", self.hardware_threads));
        s.push_str(&format!("  \"crossover_elems\": {},\n", self.crossover_elems));
        s.push_str(&format!("  \"default_min_elems\": {},\n", self.default_min_elems));
        s.push_str("  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"rows\": {}, \"cols\": {}, \
                 \"baseline_ms\": {:.6}, \"kernel_ms\": {:.6}, \"speedup\": {:.3}}}{}\n",
                e.name,
                e.rows,
                e.cols,
                e.baseline_ms,
                e.kernel_ms,
                e.speedup(),
                if i + 1 < self.entries.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Terminal rendering: the §Perf markdown table.
    pub fn markdown(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .entries
            .iter()
            .map(|e| {
                vec![
                    e.name.clone(),
                    format!("{}x{}", e.rows, e.cols),
                    format!("{:.3}", e.baseline_ms),
                    format!("{:.3}", e.kernel_ms),
                    format!("{:.2}x", e.speedup()),
                ]
            })
            .collect();
        let mut s = crate::report::markdown_table(
            &["bench", "shape", "baseline ms", "kernel ms", "speedup"],
            &rows,
        );
        s.push_str(&format!(
            "\ncrossover: pool wins from {} elements (library default min_elems = {})\n",
            self.crossover_elems, self.default_min_elems
        ));
        s
    }
}

/// Measure the end-to-end `BP¹,∞` comparison rows for square sizes:
/// `bp1inf/seq` (seed scalar baseline vs kernel layer, sequential) and
/// `bp1inf/pool` (sequential kernel vs parking pool). Shared by [`run`]
/// and `benches/fig1_time.rs` so both report the same comparison.
pub fn bp1inf_entries(cfg: &BenchConfig, sizes: &[usize]) -> Vec<KernelBenchEntry> {
    let mut entries = Vec::new();
    for &n in sizes {
        let mut rng = Xoshiro256pp::seed_from_u64(n as u64);
        let y = Matrix::<f64>::randn(n, n, &mut rng);
        let base = time_fn(cfg, || {
            black_box(bilevel_l1inf_scalar_baseline(&y, 1.0, L1Algorithm::Condat))
        });
        let kern =
            time_fn(cfg, || black_box(bilevel_l1inf_with(&y, 1.0, L1Algorithm::Condat)));
        entries.push(KernelBenchEntry {
            name: "bp1inf/seq".into(),
            rows: n,
            cols: n,
            baseline_ms: base.median * 1e3,
            kernel_ms: kern.median * 1e3,
        });
        let pool = time_fn(cfg, || {
            black_box(bilevel_l1inf_parallel(
                &y,
                1.0,
                L1Algorithm::Condat,
                ParallelPolicy { threads: 0, min_elems: 0 },
            ))
        });
        entries.push(KernelBenchEntry {
            name: "bp1inf/pool".into(),
            rows: n,
            cols: n,
            baseline_ms: kern.median * 1e3,
            kernel_ms: pool.median * 1e3,
        });
    }
    entries
}

/// Run the full kernel benchmark suite. `quick` shrinks sizes and timing
/// budgets for CI-sized runs.
pub fn run(quick: bool) -> KernelBenchReport {
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let sizes: &[usize] = if quick { &[128, 256, 512] } else { &[256, 512, 1024, 2048] };

    // ---- end-to-end BP¹,∞: seed scalar vs kernel, sequential vs pool ----
    let mut entries = bp1inf_entries(&cfg, sizes);

    // ---- per-kernel micro rows on a flat 64k-element buffer ------------
    let len = 1 << 16;
    let mut rng = Xoshiro256pp::seed_from_u64(0xBE7C);
    let v: Vec<f64> = (0..len).map(|_| rng.uniform(-2.0, 2.0)).collect();
    let mut dst = vec![0.0f64; len];

    let base = time_fn(&cfg, || black_box(kernels::colmax_ref(&v)));
    let kern = time_fn(&cfg, || black_box(kernels::colmax(&v)));
    entries.push(KernelBenchEntry {
        name: "kernel/colmax".into(),
        rows: len,
        cols: 1,
        baseline_ms: base.median * 1e3,
        kernel_ms: kern.median * 1e3,
    });

    let base = time_fn(&cfg, || {
        clip_signum_baseline(&v, 0.5, &mut dst);
        black_box(dst[0])
    });
    let kern = time_fn(&cfg, || {
        kernels::clip_into(&v, 0.5, &mut dst);
        black_box(dst[0])
    });
    entries.push(KernelBenchEntry {
        name: "kernel/clip".into(),
        rows: len,
        cols: 1,
        baseline_ms: base.median * 1e3,
        kernel_ms: kern.median * 1e3,
    });

    // One buffer, thresholded repeatedly in place: `soft1` is branch-free,
    // so its cost is data-independent and no per-iteration refill (which
    // would dominate this memory-bound row) is needed.
    let mut w = v.clone();
    let base = time_fn(&cfg, || {
        kernels::soft_threshold_inplace_ref(&mut w, 0.3);
        black_box(w[0])
    });
    let kern = time_fn(&cfg, || {
        kernels::soft_threshold_inplace(&mut w, 0.3);
        black_box(w[0])
    });
    entries.push(KernelBenchEntry {
        name: "kernel/soft_threshold".into(),
        rows: len,
        cols: 1,
        baseline_ms: base.median * 1e3,
        kernel_ms: kern.median * 1e3,
    });

    let base = time_fn(&cfg, || black_box(kernels::sumsq_ref(&v)));
    let kern = time_fn(&cfg, || black_box(kernels::sumsq(&v)));
    entries.push(KernelBenchEntry {
        name: "kernel/sumsq".into(),
        rows: len,
        cols: 1,
        baseline_ms: base.median * 1e3,
        kernel_ms: kern.median * 1e3,
    });

    // ---- sequential/parallel crossover probe ---------------------------
    let probe: &[usize] = if quick { &[32, 64, 96, 128] } else { &[32, 48, 64, 96, 128, 192, 256] };
    let mut crossover_elems = 0usize;
    for &n in probe {
        let mut rng = Xoshiro256pp::seed_from_u64(7000 + n as u64);
        let y = Matrix::<f64>::randn(n, n, &mut rng);
        let seq =
            time_fn(&cfg, || black_box(bilevel_l1inf_with(&y, 1.0, L1Algorithm::Condat)));
        let par = time_fn(&cfg, || {
            black_box(bilevel_l1inf_parallel(
                &y,
                1.0,
                L1Algorithm::Condat,
                ParallelPolicy { threads: 0, min_elems: 0 },
            ))
        });
        entries.push(KernelBenchEntry {
            name: "crossover/probe".into(),
            rows: n,
            cols: n,
            baseline_ms: seq.median * 1e3,
            kernel_ms: par.median * 1e3,
        });
        if crossover_elems == 0 && par.median < seq.median {
            crossover_elems = n * n;
        }
    }

    KernelBenchReport {
        quick,
        hardware_threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        entries,
        crossover_elems,
        default_min_elems: ParallelPolicy::default().min_elems,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_matches_kernel_path_numerically() {
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let y = Matrix::<f64>::randn(40, 30, &mut rng);
        let base = bilevel_l1inf_scalar_baseline(&y, 2.0, L1Algorithm::Condat);
        let kern = bilevel_l1inf_with(&y, 2.0, L1Algorithm::Condat);
        assert!(base.x.max_abs_diff(&kern.x) < 1e-12);
        for (a, b) in base.thresholds.iter().zip(kern.thresholds.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn report_serializes_to_valid_shape() {
        let report = KernelBenchReport {
            quick: true,
            hardware_threads: 4,
            entries: vec![KernelBenchEntry {
                name: "bp1inf/seq".into(),
                rows: 8,
                cols: 8,
                baseline_ms: 2.0,
                kernel_ms: 1.0,
            }],
            crossover_elems: 4096,
            default_min_elems: 8192,
        };
        let json = report.to_json();
        assert!(json.contains("\"speedup\": 2.000"));
        assert!(json.contains("\"crossover_elems\": 4096"));
        assert!(json.trim_end().ends_with('}'));
        let md = report.markdown();
        assert!(md.contains("bp1inf/seq"));
        assert!(md.contains("2.00x"));
    }
}
