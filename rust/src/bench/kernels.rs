//! Kernel-layer microbenchmarks — `bilevel bench kernels` and
//! `cargo bench --bench kernels`.
//!
//! Measures the lane-chunked kernel layer against the seed's scalar path
//! (kept here, verbatim, as [`bilevel_l1inf_scalar_baseline`]), the
//! parking-pool parallel path against the sequential kernel path, and the
//! individual kernels against their naive loops; then re-probes the
//! sequential/parallel crossover that calibrates
//! `ParallelPolicy::min_elems`. Results render as a markdown table and
//! serialize to `BENCH_kernels.json` (repo root) so the perf trajectory is
//! tracked across PRs — see EXPERIMENTS.md §Perf for how to regenerate.

use crate::bench::{black_box, machine_info, time_fn, BenchConfig, MachineInfo};
use crate::kernels;
use crate::projection::bilevel::{
    bilevel_l1inf_parallel, bilevel_l1inf_with, BilevelResult, ParallelPolicy,
};
use crate::projection::l1::{self, L1Algorithm};
use crate::rng::{Rng, Xoshiro256pp};
use crate::scalar::Scalar;
use crate::tensor::Matrix;

/// The seed's scalar `BP¹,∞`: naive fold reduction, branchy `signum·min`
/// clip, fresh buffers every call. This is the "before" every kernel
/// speedup in `BENCH_kernels.json` is measured against.
pub fn bilevel_l1inf_scalar_baseline<T: Scalar>(
    y: &Matrix<T>,
    eta: T,
    algo: L1Algorithm,
) -> BilevelResult<T> {
    let (n, m) = (y.rows(), y.cols());
    let v: Vec<T> = y
        .columns()
        .map(|col| col.iter().fold(T::ZERO, |acc, &x| acc.max_s(x.abs())))
        .collect();
    let u = l1::project_l1(&v, eta, algo);
    let mut data: Vec<T> = Vec::with_capacity(n * m);
    for (j, col) in y.columns().enumerate() {
        let c = u[j];
        if c >= v[j] {
            data.extend_from_slice(col);
        } else {
            data.extend(col.iter().map(|&x| x.signum_s() * x.abs().min_s(c)));
        }
    }
    BilevelResult { x: Matrix::from_col_major(n, m, data), thresholds: u }
}

/// The seed's clip loop, for the per-kernel micro rows.
fn clip_signum_baseline<T: Scalar>(src: &[T], c: T, dst: &mut [T]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s.signum_s() * s.abs().min_s(c);
    }
}

/// One measured comparison: `baseline_ms / kernel_ms = speedup`.
#[derive(Clone, Debug)]
pub struct KernelBenchEntry {
    /// e.g. `bp1inf/seq`, `bp1inf/pool`, `kernel/colmax`.
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// Median of the pre-kernel (scalar / sequential) implementation, ms.
    pub baseline_ms: f64,
    /// Median of the kernel-layer implementation, ms.
    pub kernel_ms: f64,
}

impl KernelBenchEntry {
    pub fn speedup(&self) -> f64 {
        if self.kernel_ms > 0.0 {
            self.baseline_ms / self.kernel_ms
        } else {
            0.0
        }
    }
}

/// Full report of one `bench kernels` run.
#[derive(Clone, Debug)]
pub struct KernelBenchReport {
    pub quick: bool,
    /// What produced these numbers: CPU model, arch/OS, dispatched ISA,
    /// hardware threads. Stamped into `BENCH_kernels.json`.
    pub machine: MachineInfo,
    pub entries: Vec<KernelBenchEntry>,
    /// Smallest probed element count where the pool-parallel path beat the
    /// sequential kernel path (the measured `min_elems` candidate); 0 if
    /// it never won on the probed sizes.
    pub crossover_elems: usize,
    /// The `ParallelPolicy::min_elems` default compiled into the library.
    pub default_min_elems: usize,
    /// The autotune verdict: [`crossover_elems`](Self::crossover_elems)
    /// when the pool won somewhere, else the library default. Export it as
    /// `BILEVEL_MIN_ELEMS` to apply without a recompile.
    pub recommended_min_elems: usize,
    /// What `ParallelPolicy::from_env_or_default()` resolves to in this
    /// process (the library default unless `BILEVEL_MIN_ELEMS` overrides).
    pub effective_min_elems: usize,
}

impl KernelBenchReport {
    /// Hand-rolled JSON (no serde offline). Stable key order, numbers in
    /// fixed notation — diff-friendly for the perf trajectory.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"machine\": {},\n", self.machine.to_json()));
        s.push_str(&format!("  \"crossover_elems\": {},\n", self.crossover_elems));
        s.push_str(&format!("  \"default_min_elems\": {},\n", self.default_min_elems));
        s.push_str(&format!("  \"recommended_min_elems\": {},\n", self.recommended_min_elems));
        s.push_str(&format!("  \"effective_min_elems\": {},\n", self.effective_min_elems));
        s.push_str("  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"rows\": {}, \"cols\": {}, \
                 \"baseline_ms\": {:.6}, \"kernel_ms\": {:.6}, \"speedup\": {:.3}}}{}\n",
                e.name,
                e.rows,
                e.cols,
                e.baseline_ms,
                e.kernel_ms,
                e.speedup(),
                if i + 1 < self.entries.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Terminal rendering: the §Perf markdown table.
    pub fn markdown(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .entries
            .iter()
            .map(|e| {
                vec![
                    e.name.clone(),
                    format!("{}x{}", e.rows, e.cols),
                    format!("{:.3}", e.baseline_ms),
                    format!("{:.3}", e.kernel_ms),
                    format!("{:.2}x", e.speedup()),
                ]
            })
            .collect();
        let mut s = crate::report::markdown_table(
            &["bench", "shape", "baseline ms", "kernel ms", "speedup"],
            &rows,
        );
        s.push_str(&format!(
            "\nmachine: {} ({}/{}, {} threads), kernel isa: {}\n",
            self.machine.cpu_model,
            self.machine.arch,
            self.machine.os,
            self.machine.hardware_threads,
            self.machine.isa
        ));
        s.push_str(&format!(
            "crossover: pool wins from {} elements (library default min_elems = {})\n",
            self.crossover_elems, self.default_min_elems
        ));
        s.push_str(&format!(
            "autotune: recommended min_elems = {} (effective in this process: {})\n",
            self.recommended_min_elems, self.effective_min_elems
        ));
        s
    }
}

/// Measure the end-to-end `BP¹,∞` comparison rows for square sizes:
/// `bp1inf/seq` (seed scalar baseline vs kernel layer, sequential) and
/// `bp1inf/pool` (sequential kernel vs parking pool). Shared by [`run`]
/// and `benches/fig1_time.rs` so both report the same comparison.
pub fn bp1inf_entries(cfg: &BenchConfig, sizes: &[usize]) -> Vec<KernelBenchEntry> {
    let mut entries = Vec::new();
    for &n in sizes {
        let mut rng = Xoshiro256pp::seed_from_u64(n as u64);
        let y = Matrix::<f64>::randn(n, n, &mut rng);
        let base = time_fn(cfg, || {
            black_box(bilevel_l1inf_scalar_baseline(&y, 1.0, L1Algorithm::Condat))
        });
        let kern =
            time_fn(cfg, || black_box(bilevel_l1inf_with(&y, 1.0, L1Algorithm::Condat)));
        entries.push(KernelBenchEntry {
            name: "bp1inf/seq".into(),
            rows: n,
            cols: n,
            baseline_ms: base.median * 1e3,
            kernel_ms: kern.median * 1e3,
        });
        let pool = time_fn(cfg, || {
            black_box(bilevel_l1inf_parallel(
                &y,
                1.0,
                L1Algorithm::Condat,
                ParallelPolicy { threads: 0, min_elems: 0 },
            ))
        });
        entries.push(KernelBenchEntry {
            name: "bp1inf/pool".into(),
            rows: n,
            cols: n,
            baseline_ms: kern.median * 1e3,
            kernel_ms: pool.median * 1e3,
        });
    }
    entries
}

/// Result of the sequential/parallel crossover autotune pass.
#[derive(Clone, Debug)]
pub struct Autotune {
    /// One `crossover/probe` row per probed square size (`baseline_ms` =
    /// sequential kernel path, `kernel_ms` = pool path forced on).
    pub entries: Vec<KernelBenchEntry>,
    /// Smallest probed element count where the pool won; 0 if it never
    /// did.
    pub crossover_elems: usize,
    /// The `min_elems` this machine should run with: the measured
    /// crossover when the pool won somewhere, else the library default
    /// (no evidence the default is wrong).
    pub recommended_min_elems: usize,
}

/// Measure the sequential/parallel crossover over `probe` square sizes
/// and derive a recommended `ParallelPolicy::min_elems`. The pool path is
/// forced on (`min_elems: 0`) so each probe is a genuine seq-vs-pool race
/// at that size.
pub fn autotune(cfg: &BenchConfig, probe: &[usize]) -> Autotune {
    let mut entries = Vec::new();
    let mut crossover_elems = 0usize;
    for &n in probe {
        let mut rng = Xoshiro256pp::seed_from_u64(7000 + n as u64);
        let y = Matrix::<f64>::randn(n, n, &mut rng);
        let seq =
            time_fn(cfg, || black_box(bilevel_l1inf_with(&y, 1.0, L1Algorithm::Condat)));
        let par = time_fn(cfg, || {
            black_box(bilevel_l1inf_parallel(
                &y,
                1.0,
                L1Algorithm::Condat,
                ParallelPolicy { threads: 0, min_elems: 0 },
            ))
        });
        entries.push(KernelBenchEntry {
            name: "crossover/probe".into(),
            rows: n,
            cols: n,
            baseline_ms: seq.median * 1e3,
            kernel_ms: par.median * 1e3,
        });
        if crossover_elems == 0 && par.median < seq.median {
            crossover_elems = n * n;
        }
    }
    let recommended_min_elems = if crossover_elems > 0 {
        crossover_elems
    } else {
        ParallelPolicy::default().min_elems
    };
    Autotune { entries, crossover_elems, recommended_min_elems }
}

/// Run the full kernel benchmark suite. `quick` shrinks sizes and timing
/// budgets for CI-sized runs.
pub fn run(quick: bool) -> KernelBenchReport {
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let sizes: &[usize] =
        if quick { &[128, 256, 512] } else { &[128, 256, 512, 1024, 2048] };

    // ---- end-to-end BP¹,∞: seed scalar vs kernel, sequential vs pool ----
    let mut entries = bp1inf_entries(&cfg, sizes);

    // ---- per-kernel micro rows on a flat 64k-element buffer ------------
    let len = 1 << 16;
    let mut rng = Xoshiro256pp::seed_from_u64(0xBE7C);
    let v: Vec<f64> = (0..len).map(|_| rng.uniform(-2.0, 2.0)).collect();
    let mut dst = vec![0.0f64; len];

    let base = time_fn(&cfg, || black_box(kernels::colmax_ref(&v)));
    let kern = time_fn(&cfg, || black_box(kernels::colmax(&v)));
    entries.push(KernelBenchEntry {
        name: "kernel/colmax".into(),
        rows: len,
        cols: 1,
        baseline_ms: base.median * 1e3,
        kernel_ms: kern.median * 1e3,
    });

    let base = time_fn(&cfg, || {
        clip_signum_baseline(&v, 0.5, &mut dst);
        black_box(dst[0])
    });
    let kern = time_fn(&cfg, || {
        kernels::clip_into(&v, 0.5, &mut dst);
        black_box(dst[0])
    });
    entries.push(KernelBenchEntry {
        name: "kernel/clip".into(),
        rows: len,
        cols: 1,
        baseline_ms: base.median * 1e3,
        kernel_ms: kern.median * 1e3,
    });

    // One buffer, thresholded repeatedly in place: `soft1` is branch-free,
    // so its cost is data-independent and no per-iteration refill (which
    // would dominate this memory-bound row) is needed.
    let mut w = v.clone();
    let base = time_fn(&cfg, || {
        kernels::soft_threshold_inplace_ref(&mut w, 0.3);
        black_box(w[0])
    });
    let kern = time_fn(&cfg, || {
        kernels::soft_threshold_inplace(&mut w, 0.3);
        black_box(w[0])
    });
    entries.push(KernelBenchEntry {
        name: "kernel/soft_threshold".into(),
        rows: len,
        cols: 1,
        baseline_ms: base.median * 1e3,
        kernel_ms: kern.median * 1e3,
    });

    let base = time_fn(&cfg, || black_box(kernels::sumsq_ref(&v)));
    let kern = time_fn(&cfg, || black_box(kernels::sumsq(&v)));
    entries.push(KernelBenchEntry {
        name: "kernel/sumsq".into(),
        rows: len,
        cols: 1,
        baseline_ms: base.median * 1e3,
        kernel_ms: kern.median * 1e3,
    });

    // ---- sequential/parallel crossover autotune ------------------------
    let probe: &[usize] = if quick { &[32, 64, 96, 128] } else { &[32, 48, 64, 96, 128, 192, 256] };
    let tune = autotune(&cfg, probe);
    entries.extend(tune.entries);

    KernelBenchReport {
        quick,
        machine: machine_info(),
        entries,
        crossover_elems: tune.crossover_elems,
        default_min_elems: ParallelPolicy::default().min_elems,
        recommended_min_elems: tune.recommended_min_elems,
        effective_min_elems: ParallelPolicy::from_env_or_default().min_elems,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_matches_kernel_path_numerically() {
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let y = Matrix::<f64>::randn(40, 30, &mut rng);
        let base = bilevel_l1inf_scalar_baseline(&y, 2.0, L1Algorithm::Condat);
        let kern = bilevel_l1inf_with(&y, 2.0, L1Algorithm::Condat);
        assert!(base.x.max_abs_diff(&kern.x) < 1e-12);
        for (a, b) in base.thresholds.iter().zip(kern.thresholds.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn report_serializes_to_valid_shape() {
        // The default comes from the policy, not a hardcoded copy of it —
        // a hardcoded 8192 here would keep passing-while-wrong the moment
        // autotuning moves `ParallelPolicy::default().min_elems`.
        let default_min = ParallelPolicy::default().min_elems;
        let report = KernelBenchReport {
            quick: true,
            machine: crate::bench::machine_info(),
            entries: vec![KernelBenchEntry {
                name: "bp1inf/seq".into(),
                rows: 8,
                cols: 8,
                baseline_ms: 2.0,
                kernel_ms: 1.0,
            }],
            crossover_elems: 4096,
            default_min_elems: default_min,
            recommended_min_elems: 4096,
            effective_min_elems: default_min,
        };
        let json = report.to_json();
        assert!(json.contains("\"speedup\": 2.000"));
        assert!(json.contains("\"crossover_elems\": 4096"));
        assert!(json.contains(&format!("\"default_min_elems\": {default_min}")));
        assert!(json.contains("\"recommended_min_elems\": 4096"));
        assert!(json.contains("\"machine\": {\"cpu_model\""));
        assert!(json.trim_end().ends_with('}'));
        let md = report.markdown();
        assert!(md.contains("bp1inf/seq"));
        assert!(md.contains("2.00x"));
        assert!(md.contains(&format!("library default min_elems = {default_min}")));
        assert!(md.contains("recommended min_elems = 4096"));
        assert!(md.contains(crate::kernels::active_isa().name()));
    }

    #[test]
    fn autotune_probes_every_size_and_recommends_a_positive_min_elems() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 1,
            target_time: std::time::Duration::from_millis(1),
        };
        let tune = autotune(&cfg, &[8, 16]);
        assert_eq!(tune.entries.len(), 2);
        assert!(tune.entries.iter().all(|e| e.name == "crossover/probe"));
        // Either a measured crossover (some probed n*n) or the library
        // default — never zero.
        assert!(tune.recommended_min_elems > 0);
        if tune.crossover_elems > 0 {
            assert_eq!(tune.recommended_min_elems, tune.crossover_elems);
            assert!([64, 256].contains(&tune.crossover_elems));
        } else {
            assert_eq!(tune.recommended_min_elems, ParallelPolicy::default().min_elems);
        }
    }
}
