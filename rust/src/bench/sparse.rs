//! Sparse-inference benchmarks — `bilevel bench sparse` and
//! `cargo bench --bench sparse_infer`.
//!
//! Measures the structured-sparse encode path ([`crate::sparse::linalg`])
//! against the dense encode across column-sparsity levels 0–99%, for f32
//! and f64, and verifies per entry that the two paths agree **bitwise**
//! (the subsystem's core claim — a row that fails it is reported and fails
//! the suite's consumers). Results render as a markdown table and
//! serialize to `BENCH_sparse.json` (repo root) so the dense-vs-compact
//! crossover is tracked across PRs — see EXPERIMENTS.md §Sparse inference.

use crate::bench::{black_box, machine_info, time_fn, BenchConfig, MachineInfo};
use crate::rng::{Rng, Xoshiro256pp};
use crate::scalar::Scalar;
use crate::sparse::{linalg, CompactPlan};
use crate::tensor::Matrix;

/// One measured dense-vs-compact comparison.
#[derive(Clone, Debug)]
pub struct SparseBenchEntry {
    /// `encode/f32` or `encode/f64`.
    pub name: String,
    pub features: usize,
    pub hidden: usize,
    pub batch: usize,
    /// Requested column sparsity in percent (0 = fully dense model).
    pub sparsity_pct: usize,
    /// Alive features after pruning.
    pub alive: usize,
    /// Median dense encode time, ms.
    pub dense_ms: f64,
    /// Median compacted encode time, ms.
    pub compact_ms: f64,
    /// Whether compact and dense outputs matched bit-for-bit.
    pub bit_identical: bool,
}

impl SparseBenchEntry {
    pub fn speedup(&self) -> f64 {
        if self.compact_ms > 0.0 {
            self.dense_ms / self.compact_ms
        } else {
            0.0
        }
    }
}

/// Full report of one `bench sparse` run.
#[derive(Clone, Debug)]
pub struct SparseBenchReport {
    pub quick: bool,
    /// What produced these numbers — see [`MachineInfo`]. Stamped into
    /// `BENCH_sparse.json`.
    pub machine: MachineInfo,
    pub entries: Vec<SparseBenchEntry>,
}

impl SparseBenchReport {
    /// Every entry's sparse path reproduced the dense path bit-for-bit.
    pub fn all_bit_identical(&self) -> bool {
        self.entries.iter().all(|e| e.bit_identical)
    }

    /// Hand-rolled JSON (no serde offline). Stable key order,
    /// diff-friendly — the tracked `BENCH_sparse.json` artifact.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"machine\": {},\n", self.machine.to_json()));
        s.push_str(&format!("  \"all_bit_identical\": {},\n", self.all_bit_identical()));
        s.push_str("  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"features\": {}, \"hidden\": {}, \"batch\": {}, \
                 \"sparsity_pct\": {}, \"alive\": {}, \"dense_ms\": {:.6}, \
                 \"compact_ms\": {:.6}, \"speedup\": {:.3}, \"bit_identical\": {}}}{}\n",
                e.name,
                e.features,
                e.hidden,
                e.batch,
                e.sparsity_pct,
                e.alive,
                e.dense_ms,
                e.compact_ms,
                e.speedup(),
                e.bit_identical,
                if i + 1 < self.entries.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Terminal rendering: the §Sparse inference markdown table.
    pub fn markdown(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .entries
            .iter()
            .map(|e| {
                vec![
                    e.name.clone(),
                    format!("{}x{} b{}", e.features, e.hidden, e.batch),
                    format!("{}%", e.sparsity_pct),
                    e.alive.to_string(),
                    format!("{:.3}", e.dense_ms),
                    format!("{:.3}", e.compact_ms),
                    format!("{:.2}x", e.speedup()),
                    if e.bit_identical { "yes".into() } else { "NO".into() },
                ]
            })
            .collect();
        let header =
            ["bench", "shape", "sparsity", "alive", "dense ms", "compact ms", "speedup", "bitwise"];
        crate::report::markdown_table(&header, &rows)
    }
}

/// The column-sparsity levels of the sweep (percent of pruned features).
pub const SPARSITY_LEVELS: [usize; 5] = [0, 50, 90, 95, 99];

/// Build a pruned model slice: `(features, hidden)` row-major weights with
/// a seeded `sparsity_pct`% of the rows exactly zeroed, plus the matching
/// plan, compacted weights, and bias.
#[allow(clippy::type_complexity)]
fn pruned_model<T: Scalar>(
    features: usize,
    hidden: usize,
    sparsity_pct: usize,
    seed: u64,
) -> (Vec<T>, Vec<T>, Vec<T>, CompactPlan) {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut w1: Vec<T> = (0..features * hidden)
        .map(|_| T::from_f64(rng.uniform(-1.0, 1.0)))
        .collect();
    let n_dead = features * sparsity_pct / 100;
    // Seeded shuffle picks which features die; strictly-increasing alive
    // list falls out of a linear scan.
    let mut order: Vec<usize> = (0..features).collect();
    rng.shuffle(&mut order);
    let mut mask = vec![1.0f32; features];
    for &f in order.iter().take(n_dead) {
        mask[f] = 0.0;
        w1[f * hidden..(f + 1) * hidden].fill(T::ZERO);
    }
    let plan = CompactPlan::from_mask(&mask);
    let mut w1c = Vec::with_capacity(plan.alive() * hidden);
    for &f in plan.alive_indices() {
        w1c.extend_from_slice(&w1[f * hidden..(f + 1) * hidden]);
    }
    let b1: Vec<T> = (0..hidden).map(|_| T::from_f64(rng.uniform(-0.5, 0.5))).collect();
    (w1, w1c, b1, plan)
}

/// Measure one (dtype, shape, sparsity) point.
fn encode_entry<T: Scalar>(
    cfg: &BenchConfig,
    name: &str,
    features: usize,
    hidden: usize,
    batch: usize,
    sparsity_pct: usize,
    seed: u64,
) -> SparseBenchEntry {
    let (w1, w1c, b1, plan) = pruned_model::<T>(features, hidden, sparsity_pct, seed);
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0x5AE5);
    let x = Matrix::<T>::rand_uniform(features, batch, -2.0, 2.0, &mut rng);
    let mut dense_out = Matrix::<T>::zeros(hidden, batch);
    let mut compact_out = Matrix::<T>::zeros(hidden, batch);

    linalg::encode_batch_dense_into(&x, &w1, &b1, hidden, &mut dense_out);
    linalg::encode_batch_compact_into(&x, &w1c, &b1, hidden, &plan, &mut compact_out);
    let bit_identical = dense_out
        .as_slice()
        .iter()
        .zip(compact_out.as_slice().iter())
        .all(|(a, b)| a.to_f64().to_bits() == b.to_f64().to_bits());

    let dense = time_fn(cfg, || {
        linalg::encode_batch_dense_into(&x, &w1, &b1, hidden, &mut dense_out);
        black_box(dense_out.as_slice()[0])
    });
    let compact = time_fn(cfg, || {
        linalg::encode_batch_compact_into(&x, &w1c, &b1, hidden, &plan, &mut compact_out);
        black_box(compact_out.as_slice()[0])
    });
    SparseBenchEntry {
        name: name.into(),
        features,
        hidden,
        batch,
        sparsity_pct,
        alive: plan.alive(),
        dense_ms: dense.median * 1e3,
        compact_ms: compact.median * 1e3,
        bit_identical,
    }
}

/// Run the full sparse-inference benchmark suite. `quick` shrinks shapes
/// and timing budgets for CI-sized runs.
pub fn run(quick: bool) -> SparseBenchReport {
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    // The quick shape is also the first full shape so `bench compare` has
    // overlapping (name, shape, sparsity) keys between a committed full
    // snapshot and a fresh quick run.
    let shapes: &[(usize, usize, usize)] = if quick {
        &[(512, 64, 8)]
    } else {
        &[(512, 64, 8), (2048, 128, 32), (8192, 256, 32)]
    };
    let mut entries = Vec::new();
    for &(features, hidden, batch) in shapes {
        for &sparsity in &SPARSITY_LEVELS {
            let seed = (features ^ hidden ^ sparsity) as u64;
            entries.push(encode_entry::<f32>(
                &cfg, "encode/f32", features, hidden, batch, sparsity, seed,
            ));
            entries.push(encode_entry::<f64>(
                &cfg, "encode/f64", features, hidden, batch, sparsity, seed + 1,
            ));
        }
    }
    SparseBenchReport { quick, machine: machine_info(), entries }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entries_are_bit_identical_and_alive_counts_match() {
        let cfg =
            BenchConfig { warmup_iters: 0, min_iters: 1, max_iters: 1, ..BenchConfig::quick() };
        for sparsity in SPARSITY_LEVELS {
            let e = encode_entry::<f64>(&cfg, "encode/f64", 64, 8, 2, sparsity, 7);
            assert!(e.bit_identical, "sparsity {sparsity}% diverged");
            assert_eq!(e.alive, 64 - 64 * sparsity / 100);
            assert!(e.dense_ms >= 0.0 && e.compact_ms >= 0.0);
        }
    }

    #[test]
    fn report_serializes_and_renders() {
        let report = SparseBenchReport {
            quick: true,
            machine: machine_info(),
            entries: vec![SparseBenchEntry {
                name: "encode/f32".into(),
                features: 512,
                hidden: 64,
                batch: 8,
                sparsity_pct: 90,
                alive: 52,
                dense_ms: 2.0,
                compact_ms: 0.5,
                bit_identical: true,
            }],
        };
        assert!(report.all_bit_identical());
        let json = report.to_json();
        assert!(json.contains("\"speedup\": 4.000"));
        assert!(json.contains("\"all_bit_identical\": true"));
        assert!(json.contains("\"machine\": {\"cpu_model\""));
        assert!(json.trim_end().ends_with('}'));
        let md = report.markdown();
        assert!(md.contains("encode/f32"));
        assert!(md.contains("4.00x"));
    }

    #[test]
    fn quick_suite_runs_end_to_end() {
        // Tiny but real: exercises pruned_model + both timed paths.
        let report = run(true);
        assert_eq!(report.entries.len(), 2 * SPARSITY_LEVELS.len());
        assert!(report.all_bit_identical());
    }
}
