//! # bilevel-sparse
//!
//! Reproduction of *“A new Linear Time Bi-level ℓ1,∞ projection; Application
//! to the sparsification of auto-encoders neural networks”* (Barlaud, Perez,
//! Marmorat, 2024) as a three-layer Rust + JAX + Pallas stack.
//!
//! ## Layers
//!
//! * **L3 (this crate)** — the projection library (bi-level projections and
//!   every exact ℓ1,∞ baseline the paper compares against), dataset
//!   substrates, the double-descent training coordinator, the PJRT runtime
//!   that executes AOT-compiled JAX/Pallas artifacts, the experiment /
//!   benchmark harness regenerating every table and figure of the paper,
//!   and the [`serve`] subsystem — a sharded, micro-batching projection
//!   service engine (bounded queues with backpressure, an LRU threshold
//!   cache, per-shard telemetry) that turns the one-shot library calls
//!   into a sustained request/response service (`bilevel serve` /
//!   `bilevel loadgen`) with a dependency-free HTTP/1.1 front-end
//!   ([`net`]: SSE telemetry, per-client quotas, graceful drain),
//!   the [`sparse`] subsystem — structured-sparse
//!   inference (compact plans, feature-dropping model compaction, and
//!   column-support encode kernels whose cost scales with alive features),
//!   and the [`persist`] subsystem — versioned, checksummed model
//!   checkpoints (train-once / serve-forever: export, import, inspect,
//!   trainer resume, and serve-side model loading + hot-swap), hardened
//!   by the [`fault`] subsystem — deterministic seeded fault injection
//!   (`bilevel chaos`) plus the recovery machinery it exercises
//!   (supervised worker respawn, per-model circuit breakers, and the
//!   newest-valid-snapshot checkpoint recovery chain).
//! * **L2 (`python/compile/model.py`)** — the supervised autoencoder
//!   forward/backward + Adam, lowered once to HLO text.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels (bi-level
//!   projection, fused dense-SiLU), `interpret=True`, validated against a
//!   pure-jnp oracle.
//!
//! ## Quick start
//!
//! ```no_run
//! use bilevel_sparse::prelude::*;
//!
//! let mut rng = Xoshiro256pp::seed_from_u64(0);
//! let y = Matrix::<f64>::randn(100, 50, &mut rng);
//! let x = bilevel_l1inf(&y, 1.0);               // O(nm) bi-level projection
//! assert!(l1inf_norm(&x) <= 1.0 + 1e-9);
//! ```

// Every `unsafe` operation inside an `unsafe fn` must sit in an explicit
// `unsafe {}` block with its own `// SAFETY:` justification — the
// fn-level `unsafe` is a contract with the caller, not a blanket license
// for the body. Enforced here and audited by `bilevel audit` (see
// [`analysis`]).
#![deny(unsafe_op_in_unsafe_fn)]

// Every module is individually pinned to `deny(clippy::all)` so a lint
// regression is caught even when a developer runs clippy on one module
// path; the `clippy-deny` rule of `bilevel audit` keeps this list
// complete as modules are added.
#[deny(clippy::all)]
pub mod analysis;
#[deny(clippy::all)]
pub mod bench;
#[deny(clippy::all)]
pub mod cli;
#[deny(clippy::all)]
pub mod config;
#[deny(clippy::all)]
pub mod coordinator;
#[deny(clippy::all)]
pub mod data;
#[deny(clippy::all)]
pub mod experiments;
#[deny(clippy::all)]
pub mod fault;
#[deny(clippy::all)]
pub mod kernels;
#[deny(clippy::all)]
pub mod metrics;
#[deny(clippy::all)]
pub mod model;
#[deny(clippy::all)]
pub mod net;
#[deny(clippy::all)]
pub mod norms;
#[deny(clippy::all)]
pub mod persist;
#[deny(clippy::all)]
pub mod projection;
#[deny(clippy::all)]
pub mod proptest;
#[deny(clippy::all)]
pub mod report;
#[deny(clippy::all)]
pub mod rng;
#[deny(clippy::all)]
pub mod runtime;
#[deny(clippy::all)]
pub mod scalar;
#[deny(clippy::all)]
pub mod serve;
#[deny(clippy::all)]
pub mod sparse;
#[deny(clippy::all)]
pub mod sync;
#[deny(clippy::all)]
pub mod tensor;

/// Convenience re-exports covering the most common entry points.
#[deny(clippy::all)]
pub mod prelude {
    pub use crate::kernels::Workspace;
    pub use crate::norms::{
        l11_norm, l12_norm, l1inf_norm, l21_norm, linf1_norm, frobenius_norm,
    };
    pub use crate::persist::{Checkpoint, ModelBundle, PersistError};
    pub use crate::projection::bilevel::{
        bilevel_l11, bilevel_l12, bilevel_l1inf, bilevel_l1inf_into,
    };
    pub use crate::projection::l1::{project_l1, L1Algorithm};
    pub use crate::projection::l1inf::{project_l1inf, L1InfAlgorithm};
    pub use crate::projection::l21::{project_l21, project_l21_into};
    pub use crate::projection::linf1::{project_linf1, project_linf1_into};
    pub use crate::projection::multilevel::{
        project_multilevel, project_multilevel_into, tree_norm, MultilevelSpec,
        MultilevelWorkspace,
    };
    pub use crate::rng::{Rng, SplitMix64, Xoshiro256pp};
    pub use crate::scalar::Scalar;
    pub use crate::serve::{Engine, ProjectionRequest, ProjectionResponse};
    pub use crate::sparse::{compact_params, decompact_params, CompactEncoder, CompactPlan};
    pub use crate::tensor::{Matrix, Vector};
}
