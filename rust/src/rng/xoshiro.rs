//! Generator implementations: SplitMix64 and xoshiro256++.
//!
//! References: Steele, Lea, Flood (SplitMix64); Blackman & Vigna 2019
//! (xoshiro256++). Both are public-domain algorithms; implemented from the
//! published recurrences.

use super::Rng;

/// SplitMix64 — tiny, fast, passes BigCrush when used as a 64-bit stream.
/// Primarily used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the repo-wide default generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Expand a 64-bit seed via SplitMix64 (the construction recommended by
    /// the xoshiro authors; avoids the all-zero state).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    /// Jump function: advances the stream by 2^128 draws. Used to derive
    /// independent per-worker streams from one seed.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180e_c6d3_3cfd_0aba,
            0xd5a6_1266_f0c9_392c,
            0xa958_2618_e03f_c9aa,
            0x39ab_dc45_29b1_661c,
        ];
        let mut t = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    for (ti, si) in t.iter_mut().zip(self.s.iter()) {
                        *ti ^= si;
                    }
                }
                self.next_u64();
            }
        }
        self.s = t;
    }

    /// A fresh generator 2^128 draws ahead; `self` is also advanced.
    pub fn split(&mut self) -> Self {
        let child = self.clone();
        self.jump();
        child
    }
}

impl Rng for Xoshiro256pp {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values from the canonical C implementation with seed 0.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(sm.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn xoshiro_nonzero_state() {
        let g = Xoshiro256pp::seed_from_u64(0);
        assert!(g.s.iter().any(|&x| x != 0));
    }

    #[test]
    fn jump_changes_stream() {
        let mut a = Xoshiro256pp::seed_from_u64(7);
        let mut b = a.clone();
        b.jump();
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn split_streams_diverge() {
        let mut root = Xoshiro256pp::seed_from_u64(9);
        let mut c1 = root.split();
        let mut c2 = root.split();
        let xs: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn mean_of_uniform_near_half() {
        let mut g = Xoshiro256pp::seed_from_u64(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| g.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }
}
