//! Pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we carry our own generators:
//! [`SplitMix64`] (seeding / stateless streams) and [`Xoshiro256pp`]
//! (general-purpose, the default throughout the repo). Distributions cover
//! everything the data substrates need: uniform, standard normal
//! (Box–Muller with caching), gamma (Marsaglia–Tsang), Poisson
//! (inversion + PTRS for large mean), Bernoulli, permutation sampling.
//!
//! Every consumer takes `&mut impl Rng`, so experiments are reproducible
//! from a single `u64` seed recorded in the experiment config.

mod distributions;
mod xoshiro;

pub use distributions::*;
pub use xoshiro::{SplitMix64, Xoshiro256pp};

/// Abstract source of uniform random bits plus derived draws.
pub trait Rng {
    /// Next 64 uniformly random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        // Take the top 53 bits — the bottom bits of some generators are weaker.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as `f32`.
    #[inline]
    fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (Lemire's debiased multiply-shift).
    #[inline]
    fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below: bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices drawn from `0..n` (partial Fisher–Yates).
    fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_f64_in_unit_interval() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_unbiased_small_bound() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let mut counts = [0usize; 7];
        let draws = 70_000;
        for _ in 0..draws {
            counts[rng.next_below(7) as usize] += 1;
        }
        let expect = draws / 7;
        for c in counts {
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "bucket count {c} too far from {expect}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let idx = rng.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut seen = [false; 50];
        for &i in &idx {
            assert!(i < 50);
            assert!(!seen[i], "duplicate index {i}");
            seen[i] = true;
        }
    }

    #[test]
    fn deterministic_from_seed() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
