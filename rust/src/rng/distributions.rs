//! Distribution samplers built on top of [`Rng`].
//!
//! Everything the dataset substrates need: normal (Box–Muller, cached
//! second draw through `Normal`), gamma (Marsaglia–Tsang), Poisson
//! (inversion for small mean, PTRS transformed-rejection for large mean),
//! Bernoulli, and negative binomial (gamma–Poisson mixture — the standard
//! scRNA-seq count model used by the HIF2 simulator).

use super::Rng;

/// Standard normal draw (Box–Muller, no caching — see [`Normal`] for the
/// cached stateful variant used in bulk generation).
pub fn standard_normal<R: Rng + ?Sized>(rng: &mut R) -> f64 {
    loop {
        let u1 = rng.next_f64();
        let u2 = rng.next_f64();
        if u1 > f64::MIN_POSITIVE {
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (std::f64::consts::TAU * u2).cos();
        }
    }
}

/// Stateful normal sampler with mean/std and Box–Muller pair caching.
#[derive(Clone, Debug)]
pub struct Normal {
    mean: f64,
    std: f64,
    cache: Option<f64>,
}

impl Normal {
    pub fn new(mean: f64, std: f64) -> Self {
        assert!(std >= 0.0, "Normal: std must be non-negative");
        Self { mean, std, cache: None }
    }

    pub fn standard() -> Self {
        Self::new(0.0, 1.0)
    }

    pub fn sample<R: Rng + ?Sized>(&mut self, rng: &mut R) -> f64 {
        let z = if let Some(z) = self.cache.take() {
            z
        } else {
            let (u1, u2) = loop {
                let u1 = rng.next_f64();
                let u2 = rng.next_f64();
                if u1 > f64::MIN_POSITIVE {
                    break (u1, u2);
                }
            };
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = std::f64::consts::TAU * u2;
            self.cache = Some(r * theta.sin());
            r * theta.cos()
        };
        self.mean + self.std * z
    }
}

/// Gamma(shape, scale) via Marsaglia–Tsang (2000); shape < 1 boosted by the
/// standard `U^(1/shape)` trick.
pub fn gamma<R: Rng + ?Sized>(rng: &mut R, shape: f64, scale: f64) -> f64 {
    assert!(shape > 0.0 && scale > 0.0, "gamma: parameters must be positive");
    if shape < 1.0 {
        let u = rng.next_f64().max(f64::MIN_POSITIVE);
        return gamma(rng, shape + 1.0, scale) * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = standard_normal(rng);
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.next_f64();
        if u < 1.0 - 0.0331 * x.powi(4)
            || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
        {
            return d * v * scale;
        }
    }
}

/// Poisson(lambda): Knuth inversion below 30, PTRS rejection above.
pub fn poisson<R: Rng + ?Sized>(rng: &mut R, lambda: f64) -> u64 {
    assert!(lambda >= 0.0, "poisson: lambda must be non-negative");
    if lambda == 0.0 {
        return 0;
    }
    if lambda < 30.0 {
        // Inversion by sequential search.
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= rng.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }
    // PTRS (Hörmann 1993 transformed rejection).
    let b = 0.931 + 2.53 * lambda.sqrt();
    let a = -0.059 + 0.02483 * b;
    let inv_alpha = 1.1239 + 1.1328 / (b - 3.4);
    let v_r = 0.9277 - 3.6224 / (b - 2.0);
    loop {
        let u = rng.next_f64() - 0.5;
        let v = rng.next_f64();
        let us = 0.5 - u.abs();
        let k = ((2.0 * a / us + b) * u + lambda + 0.43).floor();
        if us >= 0.07 && v <= v_r && k >= 0.0 {
            return k as u64;
        }
        if k < 0.0 || (us < 0.013 && v > us) {
            continue;
        }
        let log_v = v.ln();
        let rhs = k * lambda.ln() - lambda - ln_factorial(k as u64);
        if (inv_alpha / (a / (us * us) + b)).ln() + log_v <= rhs {
            return k as u64;
        }
    }
}

/// Negative binomial via gamma–Poisson mixture: mean `mu`, dispersion `r`
/// (variance = mu + mu²/r). The canonical over-dispersed count model for
/// scRNA-seq simulation.
pub fn negative_binomial<R: Rng + ?Sized>(rng: &mut R, mu: f64, r: f64) -> u64 {
    assert!(mu >= 0.0 && r > 0.0, "negative_binomial: mu>=0, r>0 required");
    if mu == 0.0 {
        return 0;
    }
    let lambda = gamma(rng, r, mu / r);
    poisson(rng, lambda)
}

/// Bernoulli(p).
pub fn bernoulli<R: Rng + ?Sized>(rng: &mut R, p: f64) -> bool {
    rng.next_f64() < p
}

/// ln(k!) via Stirling series for k ≥ 10, table lookup below.
fn ln_factorial(k: u64) -> f64 {
    const TABLE: [f64; 10] = [
        0.0,
        0.0,
        0.693147180559945,
        1.791759469228055,
        3.178053830347946,
        4.787491742782046,
        6.579251212010101,
        8.525161361065415,
        10.604602902745251,
        12.801827480081469,
    ];
    if (k as usize) < TABLE.len() {
        return TABLE[k as usize];
    }
    let x = (k + 1) as f64;
    (x - 0.5) * x.ln() - x + 0.5 * (std::f64::consts::TAU).ln()
        + 1.0 / (12.0 * x)
        - 1.0 / (360.0 * x * x * x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn mean_var(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let m = xs.iter().sum::<f64>() / n;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n;
        (m, v)
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256pp::seed_from_u64(100);
        let mut d = Normal::new(2.0, 3.0);
        let xs: Vec<f64> = (0..100_000).map(|_| d.sample(&mut rng)).collect();
        let (m, v) = mean_var(&xs);
        assert!((m - 2.0).abs() < 0.05, "mean={m}");
        assert!((v - 9.0).abs() < 0.3, "var={v}");
    }

    #[test]
    fn gamma_moments() {
        let mut rng = Xoshiro256pp::seed_from_u64(101);
        let (shape, scale) = (3.0, 2.0);
        let xs: Vec<f64> = (0..100_000).map(|_| gamma(&mut rng, shape, scale)).collect();
        let (m, v) = mean_var(&xs);
        assert!((m - 6.0).abs() < 0.1, "mean={m}");
        assert!((v - 12.0).abs() < 0.6, "var={v}");
    }

    #[test]
    fn gamma_small_shape() {
        let mut rng = Xoshiro256pp::seed_from_u64(102);
        let xs: Vec<f64> = (0..100_000).map(|_| gamma(&mut rng, 0.5, 1.0)).collect();
        let (m, _) = mean_var(&xs);
        assert!((m - 0.5).abs() < 0.05, "mean={m}");
        assert!(xs.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn poisson_small_mean() {
        let mut rng = Xoshiro256pp::seed_from_u64(103);
        let xs: Vec<f64> = (0..100_000).map(|_| poisson(&mut rng, 4.5) as f64).collect();
        let (m, v) = mean_var(&xs);
        assert!((m - 4.5).abs() < 0.08, "mean={m}");
        assert!((v - 4.5).abs() < 0.3, "var={v}");
    }

    #[test]
    fn poisson_large_mean_ptrs() {
        let mut rng = Xoshiro256pp::seed_from_u64(104);
        let xs: Vec<f64> = (0..100_000).map(|_| poisson(&mut rng, 120.0) as f64).collect();
        let (m, v) = mean_var(&xs);
        assert!((m - 120.0).abs() < 0.6, "mean={m}");
        assert!((v - 120.0).abs() < 6.0, "var={v}");
    }

    #[test]
    fn negative_binomial_overdispersion() {
        let mut rng = Xoshiro256pp::seed_from_u64(105);
        let (mu, r) = (10.0, 2.0);
        let xs: Vec<f64> = (0..100_000)
            .map(|_| negative_binomial(&mut rng, mu, r) as f64)
            .collect();
        let (m, v) = mean_var(&xs);
        let expect_var = mu + mu * mu / r; // 60
        assert!((m - mu).abs() < 0.2, "mean={m}");
        assert!((v - expect_var).abs() < 4.0, "var={v}, expected {expect_var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Xoshiro256pp::seed_from_u64(106);
        let hits = (0..100_000).filter(|_| bernoulli(&mut rng, 0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn ln_factorial_matches_direct() {
        let direct: f64 = (1..=20u64).map(|i| (i as f64).ln()).sum();
        assert!((ln_factorial(20) - direct).abs() < 1e-9);
        assert_eq!(ln_factorial(0), 0.0);
        assert_eq!(ln_factorial(1), 0.0);
    }
}
