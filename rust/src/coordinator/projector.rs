//! Projection dispatch for the trainer: native Rust vs the Pallas artifact.

use std::cell::RefCell;

use anyhow::{anyhow, Result};

use crate::config::{ProjectionBackend, TrainConfig};
use crate::kernels::Workspace;
use crate::model::SaeParams;
use crate::projection::bilevel::{bilevel, bilevel_l1inf_inplace_cols, BilevelVariant};
use crate::projection::l1inf::{project_l1inf_with, L1InfAlgorithm};
use crate::projection::ProjectionKind;
use crate::runtime::{to_vec_f32, HostArg, Runtime};

/// What a projection pass did to W1.
#[derive(Clone, Debug)]
pub struct ProjectionOutcome {
    /// Per-feature thresholds/levels (zero ⇒ feature removed).
    pub thresholds: Vec<f32>,
    /// Features still alive after this projection.
    pub alive: usize,
}

/// Project `params.w1` in place according to the config. Returns the
/// per-feature thresholds (the structured-sparsity signal).
pub fn project_w1(
    runtime: &Runtime,
    preset: &str,
    cfg: &TrainConfig,
    params: &mut SaeParams,
) -> Result<ProjectionOutcome> {
    let eta = cfg.eta as f32;
    match (cfg.backend, cfg.projection) {
        (_, ProjectionKind::None) => {
            let thresholds = params.feature_scores().iter().map(|&s| s as f32).collect();
            Ok(ProjectionOutcome { alive: params.alive_features(), thresholds })
        }
        (ProjectionBackend::Pallas, ProjectionKind::BilevelL1Inf) => {
            let d = params.dims;
            let w1_dims = [d.features, d.hidden];
            let outputs = runtime.execute_args(
                &format!("{preset}_project"),
                &[HostArg::tensor(&params.tensors[0], &w1_dims), HostArg::Scalar(eta)],
            )?;
            if outputs.len() != 2 {
                return Err(anyhow!("project artifact returned {} outputs", outputs.len()));
            }
            params.tensors[0] = to_vec_f32(&outputs[0])?;
            let thresholds = to_vec_f32(&outputs[1])?;
            let alive = thresholds.iter().filter(|&&u| u > 0.0).count();
            Ok(ProjectionOutcome { thresholds, alive })
        }
        (ProjectionBackend::Pallas, other) => Err(anyhow!(
            "projection {:?} has no Pallas artifact (only bilevel-l1inf); use backend=native",
            other.name()
        )),
        (ProjectionBackend::Native, ProjectionKind::BilevelL1Inf) => {
            // The paper's projection — and every training step's — runs
            // **in place** on the flat W1 tensor ((F,H) row-major == (H,F)
            // column-major, columns are features) through a per-thread
            // workspace: the steady-state step allocates only the returned
            // threshold vector.
            thread_local! {
                static SCRATCH: RefCell<Workspace<f32>> = RefCell::new(Workspace::new());
            }
            let d = params.dims;
            let thresholds = SCRATCH.with(|cell| {
                let ws = &mut *cell.borrow_mut();
                bilevel_l1inf_inplace_cols(
                    &mut params.tensors[0],
                    d.hidden,
                    eta,
                    cfg.l1_algorithm,
                    ws,
                );
                ws.thresholds().to_vec()
            });
            let alive = thresholds.iter().filter(|&&u| u > 0.0).count();
            Ok(ProjectionOutcome { thresholds, alive })
        }
        (ProjectionBackend::Native, kind) => {
            // W1 (F,H) row-major reinterprets as (H,F) column-major:
            // columns are features — the library's native orientation.
            let w = params.w1_as_feature_columns();
            let (x, thresholds): (_, Vec<f32>) = match kind {
                ProjectionKind::BilevelL11 | ProjectionKind::BilevelL12 => {
                    let variant = match kind {
                        ProjectionKind::BilevelL11 => BilevelVariant::L11,
                        _ => BilevelVariant::L12,
                    };
                    let r = bilevel(&w, eta, variant, cfg.l1_algorithm);
                    (r.x, r.thresholds)
                }
                ProjectionKind::ExactL1InfQuattoni
                | ProjectionKind::ExactL1InfNewton
                | ProjectionKind::ExactL1InfSsn => {
                    let algo = match kind {
                        ProjectionKind::ExactL1InfQuattoni => L1InfAlgorithm::Quattoni,
                        ProjectionKind::ExactL1InfNewton => L1InfAlgorithm::Newton,
                        _ => L1InfAlgorithm::Ssn,
                    };
                    let r = project_l1inf_with(&w, eta, algo);
                    (r.x, r.mu)
                }
                ProjectionKind::None | ProjectionKind::BilevelL1Inf => unreachable!(),
            };
            let alive = thresholds.iter().filter(|&&u| u > 0.0).count();
            params.set_w1_from_feature_columns(x);
            Ok(ProjectionOutcome { thresholds, alive })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetKind;
    use crate::model::SaeDims;
    use crate::rng::Xoshiro256pp;

    fn params() -> SaeParams {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        SaeParams::init(SaeDims { features: 30, hidden: 8, classes: 2 }, &mut rng)
    }

    fn cfg(kind: ProjectionKind) -> TrainConfig {
        TrainConfig {
            dataset: DatasetKind::Tiny,
            projection: kind,
            backend: ProjectionBackend::Native,
            eta: 0.5,
            ..TrainConfig::default()
        }
    }

    // Native paths need no runtime; build a Runtime only in the
    // runtime_integration tests. Here we call through a stub runtime-less
    // entry by exercising the native arm directly.
    fn project_native(kind: ProjectionKind, p: &mut SaeParams) -> ProjectionOutcome {
        // Minimal fake runtime is impossible (PJRT); the native arm never
        // touches it, so route through a lazily-opened runtime only for
        // pallas tests (none here).
        let rt = std::ptr::null::<Runtime>();
        let _ = rt;
        // Re-implement dispatch inline via the public fn with a panic guard:
        // we cannot construct Runtime without artifacts, so assert the arm.
        let c = cfg(kind);
        assert_ne!(c.backend, ProjectionBackend::Pallas);
        // SAFETY-free path: call the same logic through a local copy.
        let w = p.w1_as_feature_columns();
        let r = match kind {
            ProjectionKind::BilevelL1Inf => {
                let r = bilevel(&w, 0.5, BilevelVariant::L1Inf, c.l1_algorithm);
                (r.x, r.thresholds)
            }
            ProjectionKind::ExactL1InfSsn => {
                let r = project_l1inf_with(&w, 0.5, L1InfAlgorithm::Ssn);
                (r.x, r.mu)
            }
            _ => {
                let r = bilevel(&w, 0.5, BilevelVariant::L11, c.l1_algorithm);
                (r.x, r.thresholds)
            }
        };
        let alive = r.1.iter().filter(|&&u| u > 0.0).count();
        p.set_w1_from_feature_columns(r.0);
        ProjectionOutcome { thresholds: r.1, alive }
    }

    #[test]
    fn native_bilevel_reduces_norm_and_reports_alive() {
        let mut p = params();
        let before = crate::norms::l1inf_norm(&p.w1_as_feature_columns());
        let out = project_native(ProjectionKind::BilevelL1Inf, &mut p);
        let after = crate::norms::l1inf_norm(&p.w1_as_feature_columns());
        assert!(after <= 0.5 + 1e-5, "{after} vs eta");
        assert!(after <= before);
        assert_eq!(out.thresholds.len(), 30);
        assert_eq!(out.alive, p.alive_features());
    }

    #[test]
    fn native_exact_matches_constraint() {
        let mut p = params();
        let _ = project_native(ProjectionKind::ExactL1InfSsn, &mut p);
        let after = crate::norms::l1inf_norm(&p.w1_as_feature_columns());
        assert!(after <= 0.5 + 1e-4);
    }
}
