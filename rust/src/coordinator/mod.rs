//! L3 coordinator: the double-descent training orchestrator.
//!
//! The paper (§V.C) trains a supervised autoencoder under the constraint
//! `BP^{1,∞}(W1) ≤ η` using *projected* Adam plus the double-descent /
//! lottery-ticket scheme ([42], [43]):
//!
//! * **phase 1** — train with the projection applied to the first-layer
//!   weights (projected gradient descent); columns (features) whose
//!   threshold hits zero are structurally removed;
//! * **mask** — derive the feature mask from the zero columns of the
//!   projected `W1`;
//! * **phase 2** — rewind to the initial weights, apply the mask, retrain
//!   dense (no projection) on the surviving features.
//!
//! The compute runs through the AOT artifacts (`train_epoch` /
//! `train_step` / `eval`) on PJRT; the projection runs either natively
//! (Rust, [`crate::projection`]) or through the Pallas kernel artifact —
//! `config::ProjectionBackend` selects, and both paths are tested to agree.

mod projector;
mod trainer;

pub use projector::{project_w1, ProjectionOutcome};
pub use trainer::{EpochStat, RunOptions, SaeTrainer, TrainOutcome};

use crate::config::TrainConfig;
use crate::metrics::mean_std;
use crate::runtime::Runtime;

/// Aggregate of one configuration across seeds (a row of Tables II–IV).
#[derive(Clone, Debug)]
pub struct MultiSeedSummary {
    pub mean_accuracy: f64,
    pub std_accuracy: f64,
    pub mean_sparsity: f64,
    pub std_sparsity: f64,
    pub outcomes: Vec<TrainOutcome>,
}

/// Run a configuration across several seeds and aggregate (paper reports
/// `accuracy ± std`).
pub fn run_seeds(
    runtime: &Runtime,
    cfg: &TrainConfig,
    seeds: &[u64],
) -> anyhow::Result<MultiSeedSummary> {
    run_seeds_with(runtime, cfg, seeds, |_| Ok(RunOptions::default()))
}

/// [`run_seeds`] with per-seed lifecycle options (`opts_of(seed)` builds
/// the [`RunOptions`] — per-seed checkpoint paths, a resume checkpoint,
/// …). This is the single owner of the per-seed loop and the
/// `accuracy ± std` aggregation for every train entry point.
pub fn run_seeds_with(
    runtime: &Runtime,
    cfg: &TrainConfig,
    seeds: &[u64],
    mut opts_of: impl FnMut(u64) -> anyhow::Result<RunOptions>,
) -> anyhow::Result<MultiSeedSummary> {
    let trainer = SaeTrainer::new(runtime, cfg.clone())?;
    let mut outcomes = Vec::with_capacity(seeds.len());
    for &seed in seeds {
        outcomes.push(trainer.run_with(seed, &opts_of(seed)?)?);
    }
    let accs: Vec<f64> = outcomes.iter().map(|o| o.final_accuracy * 100.0).collect();
    let sps: Vec<f64> = outcomes.iter().map(|o| o.sparsity_percent).collect();
    let (mean_accuracy, std_accuracy) = mean_std(&accs);
    let (mean_sparsity, std_sparsity) = mean_std(&sps);
    Ok(MultiSeedSummary {
        mean_accuracy,
        std_accuracy,
        mean_sparsity,
        std_sparsity,
        outcomes,
    })
}
