//! The SAE trainer: double-descent training through PJRT artifacts, with
//! optional rolling checkpoints and deterministic resume (see
//! [`RunOptions`] and [`crate::persist`]).

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::{DatasetKind, TrainConfig};
use crate::data::{hif2_sim, make_classification, Dataset, Hif2Config, MakeClassificationConfig,
                  StandardScaler};
use crate::metrics::accuracy_from_logits;
use crate::model::{SaeDims, SaeParams};
use crate::persist::{Checkpoint, ModelBundle, TrainStateSnapshot};
use crate::projection::ProjectionKind;
use crate::rng::{Rng, Xoshiro256pp};
use crate::runtime::{to_scalar_f32, to_vec_f32, ArtifactEntry, HostArg, Runtime};
use crate::sparse::{compact_params, CompactPlan};

/// Per-epoch statistics.
#[derive(Clone, Debug, PartialEq)]
pub struct EpochStat {
    pub phase: u8,
    pub epoch: usize,
    pub train_loss: f64,
    pub train_accuracy: f64,
    pub test_accuracy: f64,
    pub alive_features: usize,
}

/// Result of one full double-descent run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub seed: u64,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub sparsity_percent: f64,
    /// Indices of the surviving (selected) features.
    pub selected_features: Vec<usize>,
    pub history: Vec<EpochStat>,
    pub train_seconds: f64,
    /// Final first-layer weights (for Fig. 9-style dumps).
    pub w1: Vec<f32>,
    /// The complete final dense model (original feature space) — what
    /// `bilevel export` persists alongside the compacted one.
    pub params: SaeParams,
    pub dims: SaeDims,
    /// Support set of the final mask: compact ↔ original feature indices.
    pub plan: CompactPlan,
    /// The final model with pruned features structurally removed
    /// (`compact.dims.features == plan.alive()`) — ready for
    /// [`crate::sparse::CompactEncoder`] / sparse serving.
    pub compact: SaeParams,
}

impl TrainOutcome {
    /// Package the outcome as an exportable model checkpoint (plan +
    /// compacted model, plus the dense parameters when `include_dense`).
    pub fn to_checkpoint(&self, config_digest: u64, include_dense: bool) -> Checkpoint {
        Checkpoint {
            seed: self.seed,
            config_digest,
            dims: self.dims,
            history: self.history.clone(),
            model: Some(ModelBundle {
                plan: self.plan.clone(),
                compact: self.compact.clone(),
                dense: include_dense.then(|| self.params.clone()),
            }),
            train_state: None,
        }
    }
}

/// Lifecycle options for one training run. `Default` is a plain
/// in-memory run (no checkpoint IO).
#[derive(Clone, Debug, Default)]
pub struct RunOptions {
    /// Write a rolling checkpoint after every this many completed epochs
    /// (counted across both phases; 0 disables).
    pub checkpoint_every: usize,
    /// Rolling checkpoint file (written atomically via tmp + rename;
    /// after the run it holds the last cadence snapshot — the final
    /// *model* export is [`TrainOutcome::to_checkpoint`]'s job).
    pub checkpoint_path: Option<PathBuf>,
    /// Resume from this checkpoint. The seed, config digest, and dims
    /// must match the current run; the resumed trajectory is
    /// **bit-identical** to an uninterrupted one (optimizer state is
    /// restored exactly and the shuffle RNG is replayed to its position).
    pub resume_from: Option<Checkpoint>,
}

/// Double-descent SAE trainer bound to one artifact preset.
pub struct SaeTrainer<'rt> {
    runtime: &'rt Runtime,
    cfg: TrainConfig,
    entry: ArtifactEntry,
    dims: SaeDims,
}

impl<'rt> SaeTrainer<'rt> {
    pub fn new(runtime: &'rt Runtime, cfg: TrainConfig) -> Result<Self> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        let preset = cfg.dataset.preset();
        let entry = runtime
            .manifest()
            .get(&format!("{preset}_train_step"))
            .ok_or_else(|| anyhow!("preset {preset} not in manifest (run `make artifacts`)"))?
            .clone();
        let dims = SaeDims {
            features: entry.features,
            hidden: entry.hidden,
            classes: entry.classes,
        };
        Ok(Self { runtime, cfg, entry, dims })
    }

    pub fn dims(&self) -> SaeDims {
        self.dims
    }

    /// Digest binding a *resumable* run's full identity: the
    /// [`TrainConfig::digest`] mixed with the artifact batch shape.
    /// `batch` / `epoch_batches` / `eval_batch` live in the manifest, not
    /// the config, yet they change how the shuffled order is sliced — so
    /// resuming against regenerated artifacts with a different batch
    /// size must be refused, not allowed to silently diverge from the
    /// bit-identical-trajectory guarantee.
    pub fn run_digest(&self) -> u64 {
        let canon = format!(
            "{:016x}|{}|{}|{}",
            self.cfg.digest(),
            self.entry.batch,
            self.entry.epoch_batches,
            self.entry.eval_batch
        );
        crate::persist::fnv1a64(canon.as_bytes())
    }

    /// Generate the dataset for this config (seeded).
    pub fn make_dataset(&self, seed: u64) -> Dataset {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        match self.cfg.dataset {
            DatasetKind::Synth64 => make_classification(&MakeClassificationConfig::data64(), &mut rng),
            DatasetKind::Synth16 => make_classification(&MakeClassificationConfig::data16(), &mut rng),
            DatasetKind::Hif2 => hif2_sim(&Hif2Config::default(), &mut rng),
            DatasetKind::Tiny => {
                make_classification(&MakeClassificationConfig::tiny(), &mut rng)
            }
        }
    }

    /// Full double-descent run for one seed.
    pub fn run(&self, seed: u64) -> Result<TrainOutcome> {
        self.run_with(seed, &RunOptions::default())
    }

    /// Full double-descent run with lifecycle options: rolling
    /// checkpoints every `opts.checkpoint_every` epochs and/or resume
    /// from a prior checkpoint. A resumed run reproduces the
    /// uninterrupted trajectory exactly: the dataset, split, scaler, and
    /// initial weights are re-derived from the seed, the optimizer state
    /// (params/m/v/step) is restored bit-exactly, and the shuffle RNG is
    /// replayed past the completed epochs.
    pub fn run_with(&self, seed: u64, opts: &RunOptions) -> Result<TrainOutcome> {
        let t0 = Instant::now();
        let cfg = &self.cfg;
        // Rolling checkpoints are stamped with the run digest (config ⊕
        // artifact batch shape), which is what resume validates against.
        let config_digest = self.run_digest();
        let ds = self.make_dataset(seed);
        if ds.n_features != self.dims.features {
            return Err(anyhow!(
                "dataset features {} != artifact features {}",
                ds.n_features,
                self.dims.features
            ));
        }
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0x5AE5_AE5A);
        let mut split = ds.split(cfg.test_fraction, &mut rng);
        let scaler = StandardScaler::fit(&split.train);
        scaler.transform(&mut split.train);
        scaler.transform(&mut split.test);

        let mut init_rng = Xoshiro256pp::seed_from_u64(seed ^ 0x1417);
        let params0 = SaeParams::init(self.dims, &mut init_rng);
        let mut history = Vec::new();

        let no_projection = cfg.projection == ProjectionKind::None;
        let (p1, p2) = if no_projection {
            (cfg.epochs_phase1 + cfg.epochs_phase2, 0)
        } else {
            (cfg.epochs_phase1, cfg.epochs_phase2)
        };

        let mask_all = vec![1.0f32; self.dims.features];
        let mut shuffle_rng = Xoshiro256pp::seed_from_u64(seed ^ 0xEF0C);
        let mut state = TrainState::new(params0.clone());
        // Which phase/epoch the run (re)starts from, and the phase-2 mask
        // once derived.
        let mut resume_phase = 1u8;
        let mut resume_done = 0usize;
        let mut mask = mask_all.clone();

        if let Some(ck) = &opts.resume_from {
            let snap = self.validate_resume(ck, seed, config_digest, p1, p2)?;
            state = TrainState::from_snapshot(snap);
            history = ck.history.clone();
            resume_phase = snap.phase;
            resume_done = snap.epochs_done;
            if snap.phase == 2 {
                mask = snap.mask.clone();
            }
            // Each completed epoch consumed exactly one shuffle of the
            // train order (both epoch modes); replay them so the next
            // epoch draws the batches an uninterrupted run would.
            let consumed =
                if snap.phase == 1 { snap.epochs_done } else { p1 + snap.epochs_done };
            let mut order: Vec<usize> = (0..split.train.n_samples).collect();
            for _ in 0..consumed {
                shuffle_rng.shuffle(&mut order);
            }
        }
        // Epochs completed since the (original) run start — drives the
        // checkpoint cadence across resumes.
        let mut epochs_total =
            if resume_phase == 1 { resume_done } else { p1 + resume_done };

        // ---------------- phase 1: projected training ----------------
        if resume_phase == 1 {
            for epoch in resume_done..p1 {
                let (loss, tacc) =
                    self.train_one_epoch(&mut state, &split.train, &mask_all, &mut shuffle_rng)?;
                if !no_projection {
                    crate::coordinator::project_w1(
                        self.runtime,
                        cfg.dataset.preset(),
                        cfg,
                        &mut state.params,
                    )?;
                }
                let test_acc = self.evaluate(&state.params, &split.test)?;
                history.push(EpochStat {
                    phase: 1,
                    epoch,
                    train_loss: loss,
                    train_accuracy: tacc,
                    test_accuracy: test_acc,
                    alive_features: state.params.alive_features(),
                });
                epochs_total += 1;
                self.maybe_checkpoint(
                    opts, seed, config_digest, epochs_total, &history,
                    &state, 1, epoch + 1, &mask_all,
                )?;
            }

            // ------------- mask derivation (end of phase 1) -----------
            mask = if no_projection {
                mask_all.clone()
            } else {
                // Final projection defines the mask.
                let out = crate::coordinator::project_w1(
                    self.runtime,
                    cfg.dataset.preset(),
                    cfg,
                    &mut state.params,
                )?;
                crate::model::mask_from_thresholds(&out.thresholds, 0.0)
            };

            if p2 > 0 {
                // Lottery-ticket rewind: initial weights, masked features.
                let mut rewound = params0.clone();
                rewound.apply_feature_mask(&mask);
                state = TrainState::new(rewound);
            }
        }

        // ---------------- phase 2: rewound retrain --------------------
        if p2 > 0 {
            let start = if resume_phase == 2 { resume_done } else { 0 };
            for epoch in start..p2 {
                let (loss, tacc) =
                    self.train_one_epoch(&mut state, &split.train, &mask, &mut shuffle_rng)?;
                let test_acc = self.evaluate(&state.params, &split.test)?;
                history.push(EpochStat {
                    phase: 2,
                    epoch,
                    train_loss: loss,
                    train_accuracy: tacc,
                    test_accuracy: test_acc,
                    alive_features: state.params.alive_features(),
                });
                epochs_total += 1;
                self.maybe_checkpoint(
                    opts, seed, config_digest, epochs_total, &history,
                    &state, 2, epoch + 1, &mask,
                )?;
            }
        }

        let final_accuracy = self.evaluate(&state.params, &split.test)?;
        let best_accuracy = history
            .iter()
            .map(|h| h.test_accuracy)
            .fold(final_accuracy, f64::max);
        // Structured-sparse artifacts: the mask's support set and the
        // compacted final model. The mask keeps pruned W1 rows exactly
        // zero through phase 2, so the *encoder* loses nothing; the
        // decoder weights of pruned features (W4 columns / b4 entries,
        // which phase 2 still trains to reconstruct those inputs) are
        // dropped by design — the compacted model reconstructs pruned
        // features as zero.
        let plan = CompactPlan::from_mask(&mask);
        let selected_features = plan.alive_indices().to_vec();
        let compact = compact_params(&state.params, &plan);
        Ok(TrainOutcome {
            seed,
            final_accuracy,
            best_accuracy,
            sparsity_percent: state.params.sparsity_percent(),
            selected_features,
            history,
            train_seconds: t0.elapsed().as_secs_f64(),
            w1: state.params.tensors[0].clone(),
            params: state.params.clone(),
            dims: self.dims,
            plan,
            compact,
        })
    }

    /// Check a resume checkpoint against this run's identity and return
    /// its train-state snapshot.
    fn validate_resume<'ck>(
        &self,
        ck: &'ck Checkpoint,
        seed: u64,
        config_digest: u64,
        p1: usize,
        p2: usize,
    ) -> Result<&'ck TrainStateSnapshot> {
        if ck.seed != seed {
            return Err(anyhow!("resume: checkpoint seed {} != requested seed {seed}", ck.seed));
        }
        if ck.config_digest != config_digest {
            return Err(anyhow!(
                "resume: checkpoint run digest {:016x} != current {config_digest:016x} \
                 (training config or artifact batch shape changed since the checkpoint)",
                ck.config_digest
            ));
        }
        if ck.dims != self.dims {
            return Err(anyhow!(
                "resume: checkpoint dims {:?} != artifact dims {:?}",
                ck.dims,
                self.dims
            ));
        }
        let snap = ck.train_state.as_ref().ok_or_else(|| {
            anyhow!("resume: checkpoint carries no train state (completed-run model export?)")
        })?;
        let limit = if snap.phase == 1 { p1 } else { p2 };
        if snap.phase == 2 && p2 == 0 {
            return Err(anyhow!("resume: checkpoint is in phase 2 but config has no phase-2 epochs"));
        }
        if snap.epochs_done > limit {
            return Err(anyhow!(
                "resume: {} epochs done exceeds phase {} budget {limit}",
                snap.epochs_done,
                snap.phase
            ));
        }
        Ok(snap)
    }

    /// Write the rolling checkpoint when the cadence says so.
    #[allow(clippy::too_many_arguments)]
    fn maybe_checkpoint(
        &self,
        opts: &RunOptions,
        seed: u64,
        config_digest: u64,
        epochs_total: usize,
        history: &[EpochStat],
        state: &TrainState,
        phase: u8,
        epochs_done: usize,
        mask: &[f32],
    ) -> Result<()> {
        let Some(path) = &opts.checkpoint_path else { return Ok(()) };
        if opts.checkpoint_every == 0 || epochs_total % opts.checkpoint_every != 0 {
            return Ok(());
        }
        let ck = Checkpoint {
            seed,
            config_digest,
            dims: self.dims,
            history: history.to_vec(),
            model: None,
            train_state: Some(state.snapshot(phase, epochs_done, mask)),
        };
        save_checkpoint(&ck, path)
    }

    /// One epoch through the train artifacts. Returns (mean loss, accuracy).
    fn train_one_epoch<R: Rng + ?Sized>(
        &self,
        state: &mut TrainState,
        train: &Dataset,
        mask: &[f32],
        rng: &mut R,
    ) -> Result<(f64, f64)> {
        if self.cfg.use_epoch_artifact {
            self.train_epoch_scan(state, train, mask, rng)
        } else {
            self.train_epoch_steps(state, train, mask, rng)
        }
    }

    /// Epoch via the `lax.scan` artifact: one PJRT dispatch.
    fn train_epoch_scan<R: Rng + ?Sized>(
        &self,
        state: &mut TrainState,
        train: &Dataset,
        mask: &[f32],
        rng: &mut R,
    ) -> Result<(f64, f64)> {
        let e = &self.entry;
        let (nb, b, f, k) = (e.epoch_batches, e.batch, e.features, e.classes);
        let mut order: Vec<usize> = (0..train.n_samples).collect();
        rng.shuffle(&mut order);

        // Fill (NB, B, F) / (NB, B, K), recycling samples if the train set
        // is smaller than NB*B (keeps artifact shapes static).
        let mut xs = vec![0.0f32; nb * b * f];
        let mut ys = vec![0.0f32; nb * b * k];
        let total = nb * b;
        for r in 0..total {
            let i = order[r % order.len()];
            xs[r * f..(r + 1) * f].copy_from_slice(train.row(i));
            ys[r * k + train.labels[i] as usize] = 1.0;
        }

        let shapes = state.params.dims.shapes();
        let mut inputs = Vec::with_capacity(30);
        push_params(&mut inputs, &state.params, &shapes);
        push_params(&mut inputs, &state.m, &shapes);
        push_params(&mut inputs, &state.v, &shapes);
        inputs.push(HostArg::Scalar(state.step));
        let xs_dims = [nb, b, f];
        let ys_dims = [nb, b, k];
        let mask_dims = [f];
        inputs.push(HostArg::tensor(&xs, &xs_dims));
        inputs.push(HostArg::tensor(&ys, &ys_dims));
        inputs.push(HostArg::tensor(mask, &mask_dims));
        inputs.push(HostArg::Scalar(self.cfg.lr as f32));
        inputs.push(HostArg::Scalar(self.cfg.alpha as f32));

        let name = format!("{}_train_epoch", e.preset);
        let outputs = self.runtime.execute_args(&name, &inputs).context("train_epoch")?;
        if outputs.len() != 27 {
            return Err(anyhow!("train_epoch returned {} outputs, want 27", outputs.len()));
        }
        state.absorb(&outputs[..24])?;
        state.step = to_scalar_f32(&outputs[24])?;
        let loss = to_scalar_f32(&outputs[25])? as f64;
        let ncorrect = to_scalar_f32(&outputs[26])? as f64;
        Ok((loss, ncorrect / total as f64))
    }

    /// Epoch as individual `train_step` dispatches (fallback / ablation).
    ///
    /// Covers **every** sample: the final partial batch is padded by
    /// recycling shuffled samples from the top of the order (the same
    /// rule [`Self::train_epoch_scan`] uses to keep artifact shapes
    /// static), and the reported loss/accuracy means are weighted by each
    /// batch's real (non-recycled) rows.
    fn train_epoch_steps<R: Rng + ?Sized>(
        &self,
        state: &mut TrainState,
        train: &Dataset,
        mask: &[f32],
        rng: &mut R,
    ) -> Result<(f64, f64)> {
        let e = &self.entry;
        let (b, f, k) = (e.batch, e.features, e.classes);
        let mut order: Vec<usize> = (0..train.n_samples).collect();
        rng.shuffle(&mut order);
        let n_batches = step_batch_count(train.n_samples, b);

        let mut x = vec![0.0f32; b * f];
        let mut y = vec![0.0f32; b * k];
        let mut loss_wsum = 0.0;
        let mut acc_wsum = 0.0;
        let mut weight = 0.0;
        let name = format!("{}_train_step", e.preset);
        for bi in 0..n_batches {
            x.fill(0.0);
            y.fill(0.0);
            for r in 0..b {
                let i = order[(bi * b + r) % order.len()];
                x[r * f..(r + 1) * f].copy_from_slice(train.row(i));
                y[r * k + train.labels[i] as usize] = 1.0;
            }
            let shapes = state.params.dims.shapes();
            let mut inputs = Vec::with_capacity(30);
            push_params(&mut inputs, &state.params, &shapes);
            push_params(&mut inputs, &state.m, &shapes);
            push_params(&mut inputs, &state.v, &shapes);
            inputs.push(HostArg::Scalar(state.step));
            let x_dims = [b, f];
            let y_dims = [b, k];
            let mask_dims = [f];
            inputs.push(HostArg::tensor(&x, &x_dims));
            inputs.push(HostArg::tensor(&y, &y_dims));
            inputs.push(HostArg::tensor(mask, &mask_dims));
            inputs.push(HostArg::Scalar(self.cfg.lr as f32));
            inputs.push(HostArg::Scalar(self.cfg.alpha as f32));
            let outputs = self.runtime.execute_args(&name, &inputs).context("train_step")?;
            if outputs.len() != 26 {
                return Err(anyhow!("train_step returned {} outputs", outputs.len()));
            }
            state.absorb(&outputs[..24])?;
            state.step += 1.0;
            // The artifact reports batch-level aggregates over all `b`
            // rows (recycled ones included), so a padded tail batch
            // contributes its per-row mean scaled by real rows only.
            let real = step_batch_real_rows(train.n_samples, b, bi) as f64;
            loss_wsum += to_scalar_f32(&outputs[24])? as f64 * real;
            acc_wsum += to_scalar_f32(&outputs[25])? as f64 / b as f64 * real;
            weight += real;
        }
        Ok((loss_wsum / weight, acc_wsum / weight))
    }

    /// Test-set accuracy through the eval artifact (padded batches).
    pub fn evaluate(&self, params: &SaeParams, test: &Dataset) -> Result<f64> {
        let e = &self.entry;
        let (be, f, k) = (e.eval_batch, e.features, e.classes);
        let name = format!("{}_eval", e.preset);
        let mut x = vec![0.0f32; be * f];
        let mut y = vec![0.0f32; be * k]; // scratch (fill_batch API)
        let mut correct = 0.0f64;
        for bi in 0..test.padded_batches(be) {
            let real = test.fill_batch(bi, be, &mut x, &mut y);
            let shapes = params.dims.shapes();
            let mut inputs = Vec::with_capacity(9);
            push_params(&mut inputs, params, &shapes);
            let x_dims = [be, f];
            inputs.push(HostArg::tensor(&x, &x_dims));
            let outputs = self.runtime.execute_args(&name, &inputs).context("eval")?;
            let logits = to_vec_f32(&outputs[0])?;
            let labels = &test.labels[bi * be..bi * be + real];
            correct += accuracy_from_logits(&logits, real, k, labels) * real as f64;
        }
        Ok(correct / test.n_samples.max(1) as f64)
    }
}

/// Write a checkpoint, creating its parent directory on demand.
fn save_checkpoint(ck: &Checkpoint, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating checkpoint dir {}", parent.display()))?;
        }
    }
    ck.save(path)
        .map_err(|e| anyhow!("writing checkpoint {}: {e}", path.display()))
}

/// `train_step` dispatches needed to show every sample once per epoch:
/// `ceil(n_samples / batch)`, never 0. The old `n_samples / batch`
/// silently dropped up to `batch - 1` tail samples every epoch, making
/// step-mode and scan-mode epochs see different data.
pub(crate) fn step_batch_count(n_samples: usize, batch: usize) -> usize {
    (n_samples.div_ceil(batch)).max(1)
}

/// Real (non-recycled) rows of step batch `bi`: `batch` for full batches,
/// the remainder for the final partial one. Recycled padding rows repeat
/// shuffled samples and are excluded from the loss/accuracy weighting.
pub(crate) fn step_batch_real_rows(n_samples: usize, batch: usize, bi: usize) -> usize {
    n_samples.saturating_sub(bi * batch).min(batch)
}

/// Mutable optimizer state.
struct TrainState {
    params: SaeParams,
    m: SaeParams,
    v: SaeParams,
    step: f32,
}

impl TrainState {
    fn new(params: SaeParams) -> Self {
        let m = params.zeros_like();
        let v = params.zeros_like();
        Self { params, m, v, step: 0.0 }
    }

    /// Restore from a checkpoint snapshot (exact: same tensors, same
    /// Adam step).
    fn from_snapshot(s: &TrainStateSnapshot) -> Self {
        Self { params: s.params.clone(), m: s.m.clone(), v: s.v.clone(), step: s.step }
    }

    /// Freeze for a checkpoint (taken after an epoch fully completes,
    /// including the in-loop projection).
    fn snapshot(&self, phase: u8, epochs_done: usize, mask: &[f32]) -> TrainStateSnapshot {
        TrainStateSnapshot {
            phase,
            epochs_done,
            step: self.step,
            mask: mask.to_vec(),
            params: self.params.clone(),
            m: self.m.clone(),
            v: self.v.clone(),
        }
    }

    /// Absorb 24 output literals (params, m, v).
    fn absorb(&mut self, outputs: &[xla::Literal]) -> Result<()> {
        let take = |lits: &[xla::Literal]| -> Result<Vec<Vec<f32>>> {
            lits.iter().map(to_vec_f32).collect()
        };
        self.params.set_from(take(&outputs[0..8])?);
        self.m.set_from(take(&outputs[8..16])?);
        self.v.set_from(take(&outputs[16..24])?);
        Ok(())
    }
}

fn push_params<'a>(
    inputs: &mut Vec<HostArg<'a>>,
    p: &'a SaeParams,
    shapes: &'a [Vec<usize>; 8],
) {
    for (tensor, shape) in p.tensors.iter().zip(shapes.iter()) {
        inputs.push(HostArg::tensor(tensor, shape));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_epoch_covers_the_tail() {
        // Regression: `n_samples / b` dropped up to b-1 tail samples per
        // epoch; `n = 10, b = 4` must now dispatch 3 batches, not 2.
        assert_eq!(step_batch_count(10, 4), 3);
        assert_eq!(step_batch_count(8, 4), 2); // divisible: unchanged
        assert_eq!(step_batch_count(3, 4), 1); // tiny set: one padded batch
        assert_eq!(step_batch_count(1, 4), 1);
    }

    #[test]
    fn real_rows_partition_the_epoch() {
        for (n, b) in [(10usize, 4usize), (7, 3), (16, 4), (1, 8), (9, 2)] {
            let nb = step_batch_count(n, b);
            let total: usize = (0..nb).map(|bi| step_batch_real_rows(n, b, bi)).sum();
            assert_eq!(total, n, "weights must sum to n_samples for n={n} b={b}");
            for bi in 0..nb.saturating_sub(1) {
                assert_eq!(step_batch_real_rows(n, b, bi), b, "only the tail is partial");
            }
            assert!(step_batch_real_rows(n, b, nb - 1) >= 1);
        }
    }

    #[test]
    fn recycle_rule_fills_real_rows_with_distinct_samples() {
        // Mirror the fill loop: real rows address distinct positions of
        // the shuffled order (full epoch coverage), padding rows recycle
        // from the top — the exact rule `train_epoch_scan` uses.
        let (n, b) = (10usize, 4usize);
        let order: Vec<usize> = (0..n).rev().collect(); // any permutation
        let mut seen = vec![0usize; n];
        for bi in 0..step_batch_count(n, b) {
            let real = step_batch_real_rows(n, b, bi);
            for r in 0..b {
                let i = order[(bi * b + r) % order.len()];
                if r < real {
                    seen[i] += 1;
                } else {
                    // padding recycles an already-seen sample
                    assert_eq!((bi * b + r) % n, bi * b + r - n);
                }
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "every sample exactly once: {seen:?}");
    }
}
