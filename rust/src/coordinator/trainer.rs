//! The SAE trainer: double-descent training through PJRT artifacts.

use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::{DatasetKind, TrainConfig};
use crate::data::{hif2_sim, make_classification, Dataset, Hif2Config, MakeClassificationConfig,
                  StandardScaler};
use crate::metrics::accuracy_from_logits;
use crate::model::{SaeDims, SaeParams};
use crate::projection::ProjectionKind;
use crate::rng::{Rng, Xoshiro256pp};
use crate::runtime::{to_scalar_f32, to_vec_f32, ArtifactEntry, HostArg, Runtime};
use crate::sparse::{compact_params, CompactPlan};

/// Per-epoch statistics.
#[derive(Clone, Debug)]
pub struct EpochStat {
    pub phase: u8,
    pub epoch: usize,
    pub train_loss: f64,
    pub train_accuracy: f64,
    pub test_accuracy: f64,
    pub alive_features: usize,
}

/// Result of one full double-descent run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub seed: u64,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub sparsity_percent: f64,
    /// Indices of the surviving (selected) features.
    pub selected_features: Vec<usize>,
    pub history: Vec<EpochStat>,
    pub train_seconds: f64,
    /// Final first-layer weights (for Fig. 9-style dumps).
    pub w1: Vec<f32>,
    pub dims: SaeDims,
    /// Support set of the final mask: compact ↔ original feature indices.
    pub plan: CompactPlan,
    /// The final model with pruned features structurally removed
    /// (`compact.dims.features == plan.alive()`) — ready for
    /// [`crate::sparse::CompactEncoder`] / sparse serving.
    pub compact: SaeParams,
}

/// Double-descent SAE trainer bound to one artifact preset.
pub struct SaeTrainer<'rt> {
    runtime: &'rt Runtime,
    cfg: TrainConfig,
    entry: ArtifactEntry,
    dims: SaeDims,
}

impl<'rt> SaeTrainer<'rt> {
    pub fn new(runtime: &'rt Runtime, cfg: TrainConfig) -> Result<Self> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        let preset = cfg.dataset.preset();
        let entry = runtime
            .manifest()
            .get(&format!("{preset}_train_step"))
            .ok_or_else(|| anyhow!("preset {preset} not in manifest (run `make artifacts`)"))?
            .clone();
        let dims = SaeDims {
            features: entry.features,
            hidden: entry.hidden,
            classes: entry.classes,
        };
        Ok(Self { runtime, cfg, entry, dims })
    }

    pub fn dims(&self) -> SaeDims {
        self.dims
    }

    /// Generate the dataset for this config (seeded).
    pub fn make_dataset(&self, seed: u64) -> Dataset {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        match self.cfg.dataset {
            DatasetKind::Synth64 => make_classification(&MakeClassificationConfig::data64(), &mut rng),
            DatasetKind::Synth16 => make_classification(&MakeClassificationConfig::data16(), &mut rng),
            DatasetKind::Hif2 => hif2_sim(&Hif2Config::default(), &mut rng),
            DatasetKind::Tiny => {
                make_classification(&MakeClassificationConfig::tiny(), &mut rng)
            }
        }
    }

    /// Full double-descent run for one seed.
    pub fn run(&self, seed: u64) -> Result<TrainOutcome> {
        let t0 = Instant::now();
        let cfg = &self.cfg;
        let ds = self.make_dataset(seed);
        if ds.n_features != self.dims.features {
            return Err(anyhow!(
                "dataset features {} != artifact features {}",
                ds.n_features,
                self.dims.features
            ));
        }
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0x5AE5_AE5A);
        let mut split = ds.split(cfg.test_fraction, &mut rng);
        let scaler = StandardScaler::fit(&split.train);
        scaler.transform(&mut split.train);
        scaler.transform(&mut split.test);

        let mut init_rng = Xoshiro256pp::seed_from_u64(seed ^ 0x1417);
        let params0 = SaeParams::init(self.dims, &mut init_rng);
        let mut history = Vec::new();

        let no_projection = cfg.projection == ProjectionKind::None;
        let (p1, p2) = if no_projection {
            (cfg.epochs_phase1 + cfg.epochs_phase2, 0)
        } else {
            (cfg.epochs_phase1, cfg.epochs_phase2)
        };

        // ---------------- phase 1: projected training ----------------
        let mut state = TrainState::new(params0.clone());
        let mask_all = vec![1.0f32; self.dims.features];
        let mut shuffle_rng = Xoshiro256pp::seed_from_u64(seed ^ 0xEF0C);
        for epoch in 0..p1 {
            let (loss, tacc) =
                self.train_one_epoch(&mut state, &split.train, &mask_all, &mut shuffle_rng)?;
            if !no_projection {
                crate::coordinator::project_w1(
                    self.runtime,
                    cfg.dataset.preset(),
                    cfg,
                    &mut state.params,
                )?;
            }
            let test_acc = self.evaluate(&state.params, &split.test)?;
            history.push(EpochStat {
                phase: 1,
                epoch,
                train_loss: loss,
                train_accuracy: tacc,
                test_accuracy: test_acc,
                alive_features: state.params.alive_features(),
            });
        }

        // ---------------- mask + phase 2: rewound retrain -------------
        let mask = if no_projection {
            mask_all.clone()
        } else {
            // Final projection defines the mask.
            let out = crate::coordinator::project_w1(
                self.runtime,
                cfg.dataset.preset(),
                cfg,
                &mut state.params,
            )?;
            crate::model::mask_from_thresholds(&out.thresholds, 0.0)
        };

        if p2 > 0 {
            // Lottery-ticket rewind: initial weights, masked features.
            let mut rewound = params0.clone();
            rewound.apply_feature_mask(&mask);
            state = TrainState::new(rewound);
            for epoch in 0..p2 {
                let (loss, tacc) =
                    self.train_one_epoch(&mut state, &split.train, &mask, &mut shuffle_rng)?;
                let test_acc = self.evaluate(&state.params, &split.test)?;
                history.push(EpochStat {
                    phase: 2,
                    epoch,
                    train_loss: loss,
                    train_accuracy: tacc,
                    test_accuracy: test_acc,
                    alive_features: state.params.alive_features(),
                });
            }
        }

        let final_accuracy = self.evaluate(&state.params, &split.test)?;
        let best_accuracy = history
            .iter()
            .map(|h| h.test_accuracy)
            .fold(final_accuracy, f64::max);
        // Structured-sparse artifacts: the mask's support set and the
        // compacted final model. The mask keeps pruned W1 rows exactly
        // zero through phase 2, so the *encoder* loses nothing; the
        // decoder weights of pruned features (W4 columns / b4 entries,
        // which phase 2 still trains to reconstruct those inputs) are
        // dropped by design — the compacted model reconstructs pruned
        // features as zero.
        let plan = CompactPlan::from_mask(&mask);
        let selected_features = plan.alive_indices().to_vec();
        let compact = compact_params(&state.params, &plan);
        Ok(TrainOutcome {
            seed,
            final_accuracy,
            best_accuracy,
            sparsity_percent: state.params.sparsity_percent(),
            selected_features,
            history,
            train_seconds: t0.elapsed().as_secs_f64(),
            w1: state.params.tensors[0].clone(),
            dims: self.dims,
            plan,
            compact,
        })
    }

    /// One epoch through the train artifacts. Returns (mean loss, accuracy).
    fn train_one_epoch<R: Rng + ?Sized>(
        &self,
        state: &mut TrainState,
        train: &Dataset,
        mask: &[f32],
        rng: &mut R,
    ) -> Result<(f64, f64)> {
        if self.cfg.use_epoch_artifact {
            self.train_epoch_scan(state, train, mask, rng)
        } else {
            self.train_epoch_steps(state, train, mask, rng)
        }
    }

    /// Epoch via the `lax.scan` artifact: one PJRT dispatch.
    fn train_epoch_scan<R: Rng + ?Sized>(
        &self,
        state: &mut TrainState,
        train: &Dataset,
        mask: &[f32],
        rng: &mut R,
    ) -> Result<(f64, f64)> {
        let e = &self.entry;
        let (nb, b, f, k) = (e.epoch_batches, e.batch, e.features, e.classes);
        let mut order: Vec<usize> = (0..train.n_samples).collect();
        rng.shuffle(&mut order);

        // Fill (NB, B, F) / (NB, B, K), recycling samples if the train set
        // is smaller than NB*B (keeps artifact shapes static).
        let mut xs = vec![0.0f32; nb * b * f];
        let mut ys = vec![0.0f32; nb * b * k];
        let total = nb * b;
        for r in 0..total {
            let i = order[r % order.len()];
            xs[r * f..(r + 1) * f].copy_from_slice(train.row(i));
            ys[r * k + train.labels[i] as usize] = 1.0;
        }

        let shapes = state.params.dims.shapes();
        let mut inputs = Vec::with_capacity(30);
        push_params(&mut inputs, &state.params, &shapes);
        push_params(&mut inputs, &state.m, &shapes);
        push_params(&mut inputs, &state.v, &shapes);
        inputs.push(HostArg::Scalar(state.step));
        let xs_dims = [nb, b, f];
        let ys_dims = [nb, b, k];
        let mask_dims = [f];
        inputs.push(HostArg::tensor(&xs, &xs_dims));
        inputs.push(HostArg::tensor(&ys, &ys_dims));
        inputs.push(HostArg::tensor(mask, &mask_dims));
        inputs.push(HostArg::Scalar(self.cfg.lr as f32));
        inputs.push(HostArg::Scalar(self.cfg.alpha as f32));

        let name = format!("{}_train_epoch", e.preset);
        let outputs = self.runtime.execute_args(&name, &inputs).context("train_epoch")?;
        if outputs.len() != 27 {
            return Err(anyhow!("train_epoch returned {} outputs, want 27", outputs.len()));
        }
        state.absorb(&outputs[..24])?;
        state.step = to_scalar_f32(&outputs[24])?;
        let loss = to_scalar_f32(&outputs[25])? as f64;
        let ncorrect = to_scalar_f32(&outputs[26])? as f64;
        Ok((loss, ncorrect / total as f64))
    }

    /// Epoch as individual `train_step` dispatches (fallback / ablation).
    fn train_epoch_steps<R: Rng + ?Sized>(
        &self,
        state: &mut TrainState,
        train: &Dataset,
        mask: &[f32],
        rng: &mut R,
    ) -> Result<(f64, f64)> {
        let e = &self.entry;
        let (b, f, k) = (e.batch, e.features, e.classes);
        let mut order: Vec<usize> = (0..train.n_samples).collect();
        rng.shuffle(&mut order);
        let n_batches = (train.n_samples / b).max(1);

        let mut x = vec![0.0f32; b * f];
        let mut y = vec![0.0f32; b * k];
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        let name = format!("{}_train_step", e.preset);
        for bi in 0..n_batches {
            x.fill(0.0);
            y.fill(0.0);
            for r in 0..b {
                let i = order[(bi * b + r) % order.len()];
                x[r * f..(r + 1) * f].copy_from_slice(train.row(i));
                y[r * k + train.labels[i] as usize] = 1.0;
            }
            let shapes = state.params.dims.shapes();
            let mut inputs = Vec::with_capacity(30);
            push_params(&mut inputs, &state.params, &shapes);
            push_params(&mut inputs, &state.m, &shapes);
            push_params(&mut inputs, &state.v, &shapes);
            inputs.push(HostArg::Scalar(state.step));
            let x_dims = [b, f];
            let y_dims = [b, k];
            let mask_dims = [f];
            inputs.push(HostArg::tensor(&x, &x_dims));
            inputs.push(HostArg::tensor(&y, &y_dims));
            inputs.push(HostArg::tensor(mask, &mask_dims));
            inputs.push(HostArg::Scalar(self.cfg.lr as f32));
            inputs.push(HostArg::Scalar(self.cfg.alpha as f32));
            let outputs = self.runtime.execute_args(&name, &inputs).context("train_step")?;
            if outputs.len() != 26 {
                return Err(anyhow!("train_step returned {} outputs", outputs.len()));
            }
            state.absorb(&outputs[..24])?;
            state.step += 1.0;
            loss_sum += to_scalar_f32(&outputs[24])? as f64;
            correct += to_scalar_f32(&outputs[25])? as f64;
        }
        Ok((loss_sum / n_batches as f64, correct / (n_batches * b) as f64))
    }

    /// Test-set accuracy through the eval artifact (padded batches).
    pub fn evaluate(&self, params: &SaeParams, test: &Dataset) -> Result<f64> {
        let e = &self.entry;
        let (be, f, k) = (e.eval_batch, e.features, e.classes);
        let name = format!("{}_eval", e.preset);
        let mut x = vec![0.0f32; be * f];
        let mut y = vec![0.0f32; be * k]; // scratch (fill_batch API)
        let mut correct = 0.0f64;
        for bi in 0..test.padded_batches(be) {
            let real = test.fill_batch(bi, be, &mut x, &mut y);
            let shapes = params.dims.shapes();
            let mut inputs = Vec::with_capacity(9);
            push_params(&mut inputs, params, &shapes);
            let x_dims = [be, f];
            inputs.push(HostArg::tensor(&x, &x_dims));
            let outputs = self.runtime.execute_args(&name, &inputs).context("eval")?;
            let logits = to_vec_f32(&outputs[0])?;
            let labels = &test.labels[bi * be..bi * be + real];
            correct += accuracy_from_logits(&logits, real, k, labels) * real as f64;
        }
        Ok(correct / test.n_samples.max(1) as f64)
    }
}

/// Mutable optimizer state.
struct TrainState {
    params: SaeParams,
    m: SaeParams,
    v: SaeParams,
    step: f32,
}

impl TrainState {
    fn new(params: SaeParams) -> Self {
        let m = params.zeros_like();
        let v = params.zeros_like();
        Self { params, m, v, step: 0.0 }
    }

    /// Absorb 24 output literals (params, m, v).
    fn absorb(&mut self, outputs: &[xla::Literal]) -> Result<()> {
        let take = |lits: &[xla::Literal]| -> Result<Vec<Vec<f32>>> {
            lits.iter().map(to_vec_f32).collect()
        };
        self.params.set_from(take(&outputs[0..8])?);
        self.m.set_from(take(&outputs[8..16])?);
        self.v.set_from(take(&outputs[16..24])?);
        Ok(())
    }
}

fn push_params<'a>(
    inputs: &mut Vec<HostArg<'a>>,
    p: &'a SaeParams,
    shapes: &'a [Vec<usize>; 8],
) {
    for (tensor, shape) in p.tensors.iter().zip(shapes.iter()) {
        inputs.push(HostArg::tensor(tensor, shape));
    }
}
