//! `bilevel` — the leader binary: CLI over the projection library, the SAE
//! trainer, and the experiment harness.

use std::process::ExitCode;
use std::sync::Arc;
use std::time::Instant;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};
use bilevel_sparse::analysis;
use bilevel_sparse::cli::{Args, USAGE};
use bilevel_sparse::config::{
    DatasetKind, HttpConfig, ProjectionBackend, ProjectionConfig, ProjectionMethod, RunConfig,
    ServeConfig, TomlDoc, TrainConfig,
};
use bilevel_sparse::coordinator::{run_seeds, run_seeds_with, RunOptions, SaeTrainer};
use bilevel_sparse::experiments::{self, ExpContext};
use bilevel_sparse::fault::{self, FaultPlan, FaultSite};
use bilevel_sparse::net::Server;
use bilevel_sparse::norms::{column_sparsity, l1inf_norm};
use bilevel_sparse::persist::{read_header, recover_latest, Checkpoint};
use bilevel_sparse::projection::bilevel::ParallelPolicy;
use bilevel_sparse::projection::multilevel::{project_multilevel_with, tree_norm};
use bilevel_sparse::projection::{l1::L1Algorithm, ProjectionKind};
use bilevel_sparse::rng::Xoshiro256pp;
use bilevel_sparse::runtime::Runtime;
use bilevel_sparse::serve::{
    run_loadgen, run_loadgen_net, Dtype, Engine, LoadgenConfig, Payload,
};
use bilevel_sparse::tensor::Matrix;

fn main() -> ExitCode {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    let result = match args.subcommand.as_str() {
        "project" => cmd_project(&args),
        "train" => cmd_train(&args),
        "experiment" => cmd_experiment(&args),
        "artifacts" => cmd_artifacts(&args),
        "bench" => cmd_bench(&args),
        "sparsify" => cmd_sparsify(&args),
        "export" => cmd_export(&args),
        "import" => cmd_import(&args),
        "inspect" => cmd_inspect(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "chaos" => cmd_chaos(&args),
        "audit" => cmd_audit(&args),
        "help" | "" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow!("unknown command {other:?}\n\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_project(args: &Args) -> Result<()> {
    // A `--config` file's `[projection]` section seeds the defaults;
    // individual flags override (same idiom as `train_configs`).
    let proj_cfg = match args.opt("config") {
        Some(path) => RunConfig::from_file(path).map_err(|e| anyhow!(e))?.projection,
        None => ProjectionConfig::default(),
    };
    let rows = args.usize_or("rows", 1000).map_err(|e| anyhow!(e))?;
    let cols = args.usize_or("cols", 1000).map_err(|e| anyhow!(e))?;
    let eta = args.f64_or("eta", proj_cfg.eta).map_err(|e| anyhow!(e))?;
    let seed = args.usize_or("seed", 42).map_err(|e| anyhow!(e))? as u64;
    let algo = L1Algorithm::parse(&args.str_or("algo", proj_cfg.algo.name()))
        .ok_or_else(|| anyhow!("unknown --algo"))?;
    let threads = args.usize_or("threads", proj_cfg.threads).map_err(|e| anyhow!(e))?;

    let default_method = match &proj_cfg.method {
        ProjectionMethod::Kind(k) => k.name().to_string(),
        ProjectionMethod::Multilevel(_) => "multilevel".to_string(),
    };
    let method_s = args.str_or("method", &default_method);
    let method = if method_s.eq_ignore_ascii_case("multilevel") {
        let levels = match args.opt("levels") {
            Some(spec) => spec.to_string(),
            None => match &proj_cfg.method {
                ProjectionMethod::Multilevel(spec) => spec.format(),
                ProjectionMethod::Kind(_) => {
                    return Err(anyhow!(
                        "--method multilevel needs --levels (root→leaf, e.g. \"l1/l2:8/linf\")"
                    ))
                }
            },
        };
        ProjectionMethod::parse("multilevel", Some(&levels)).map_err(|e| anyhow!(e))?
    } else {
        ProjectionMethod::parse(&method_s, None).map_err(|e| anyhow!(e))?
    };

    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let y = Matrix::<f64>::randn(rows, cols, &mut rng);
    let before = l1inf_norm(&y);
    let t0 = Instant::now();
    let x = match &method {
        ProjectionMethod::Kind(kind) => kind.apply_with(&y, eta, algo),
        ProjectionMethod::Multilevel(spec) => {
            let policy = ParallelPolicy { threads, ..ParallelPolicy::from_env_or_default() };
            project_multilevel_with(&y, eta, spec, algo, policy)
        }
    };
    let dt = t0.elapsed();
    // The method's own ball norm: `None` only for the radius-free
    // identity baseline (`ProjectionKind::None`), which has no ball.
    let matched = |m: &Matrix<f64>| -> Option<f64> {
        match &method {
            ProjectionMethod::Kind(kind) => kind.matched_norm(m),
            ProjectionMethod::Multilevel(spec) => Some(tree_norm(m, spec)),
        }
    };
    println!("matrix         : {rows} x {cols} (seed {seed})");
    println!("method         : {} (inner l1: {})", method.label(), algo.name());
    println!("eta            : {eta}");
    println!("||Y||_1inf     : {before:.6}");
    println!("||P(Y)||_1inf  : {:.6}", l1inf_norm(&x));
    match (matched(&y), matched(&x)) {
        (Some(ny), Some(nx)) => {
            println!("matched norm   : {ny:.6} -> {nx:.6}");
            let resid = y.sub(&x);
            let nr = matched(&resid).unwrap_or(0.0);
            println!(
                "identity check : ||Y-P||+||P|| = {:.6} vs ||Y|| = {:.6}",
                nr + nx,
                ny
            );
        }
        _ => println!("matched norm   : n/a (identity baseline projects onto no ball)"),
    }
    println!("column sparsity: {:.2} %", column_sparsity(&x, 1e-12) * 100.0);
    println!("time           : {:.3} ms", dt.as_secs_f64() * 1e3);
    Ok(())
}

/// Shared `train` / `export` config assembly: a `--config` file seeds the
/// defaults, individual flags override.
fn train_configs(args: &Args) -> Result<(TrainConfig, RunConfig)> {
    let mut run_cfg = match args.opt("config") {
        Some(path) => RunConfig::from_file(path).map_err(|e| anyhow!(e))?,
        None => RunConfig::default(),
    };
    let d = run_cfg.train.clone();
    let cfg = TrainConfig {
        dataset: DatasetKind::parse(&args.str_or("dataset", d.dataset.name()))
            .ok_or_else(|| anyhow!("unknown --dataset"))?,
        projection: ProjectionKind::parse(&args.str_or("projection", d.projection.name()))
            .ok_or_else(|| anyhow!("unknown --projection"))?,
        backend: ProjectionBackend::parse(&args.str_or("backend", d.backend.name()))
            .ok_or_else(|| anyhow!("unknown --backend"))?,
        eta: args.f64_or("eta", d.eta).map_err(|e| anyhow!(e))?,
        epochs_phase1: args.usize_or("epochs1", d.epochs_phase1).map_err(|e| anyhow!(e))?,
        epochs_phase2: args.usize_or("epochs2", d.epochs_phase2).map_err(|e| anyhow!(e))?,
        lr: args.f64_or("lr", d.lr).map_err(|e| anyhow!(e))?,
        alpha: args.f64_or("alpha", d.alpha).map_err(|e| anyhow!(e))?,
        ..d
    };
    cfg.validate().map_err(|e| anyhow!(e))?;
    run_cfg.seeds = args.u64_list_or("seeds", &run_cfg.seeds).map_err(|e| anyhow!(e))?;
    run_cfg.train = cfg.clone();
    Ok((cfg, run_cfg))
}

fn cmd_train(args: &Args) -> Result<()> {
    let (cfg, run_cfg) = train_configs(args)?;
    let dir = args.str_or("artifacts-dir", &run_cfg.artifacts_dir);

    // Model lifecycle flags (config `[persist]` supplies the defaults).
    let ck_every = args
        .usize_or("checkpoint-every", run_cfg.persist.checkpoint_every)
        .map_err(|e| anyhow!(e))?;
    let ck_dir = args.str_or("checkpoint-dir", &run_cfg.persist.dir);
    let export = args.opt("export").map(PathBuf::from);
    let resume = args.opt("resume").map(PathBuf::from);
    let export_dense = args.flag("export-dense") || run_cfg.persist.export_dense;
    let lifecycle = ck_every > 0 || export.is_some() || resume.is_some();

    println!(
        "training SAE: dataset={} projection={} backend={} eta={} epochs={}+{} seeds={:?}",
        cfg.dataset.name(),
        cfg.projection.name(),
        cfg.backend.name(),
        cfg.eta,
        cfg.epochs_phase1,
        cfg.epochs_phase2,
        run_cfg.seeds
    );
    let rt = Runtime::open(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let summary = if lifecycle {
        if (export.is_some() || resume.is_some()) && run_cfg.seeds.len() != 1 {
            return Err(anyhow!("--export / --resume require exactly one seed (use --seeds S)"));
        }
        run_seeds_with(&rt, &cfg, &run_cfg.seeds, |seed| {
            let mut opts = RunOptions { checkpoint_every: ck_every, ..RunOptions::default() };
            if ck_every > 0 {
                let path = Path::new(&ck_dir)
                    .join(format!("{}_seed{}.ckpt", cfg.dataset.name(), seed));
                println!(
                    "  seed {seed}: rolling checkpoint every {ck_every} epochs -> {}",
                    path.display()
                );
                opts.checkpoint_path = Some(path);
            }
            if let Some(p) = &resume {
                let ck = Checkpoint::load(p).map_err(|e| anyhow!("{}: {e}", p.display()))?;
                match &ck.train_state {
                    Some(ts) => println!(
                        "  seed {seed}: resuming from {} (phase {}, {} epochs done)",
                        p.display(),
                        ts.phase,
                        ts.epochs_done
                    ),
                    None => println!("  seed {seed}: resuming from {}", p.display()),
                }
                opts.resume_from = Some(ck);
            }
            Ok(opts)
        })?
    } else {
        run_seeds(&rt, &cfg, &run_cfg.seeds)?
    };
    if let Some(p) = &export {
        // exactly one seed, enforced above
        let outcome = &summary.outcomes[0];
        outcome
            .to_checkpoint(cfg.digest(), export_dense)
            .save(p)
            .map_err(|e| anyhow!("{}: {e}", p.display()))?;
        println!("exported model checkpoint -> {}", p.display());
    }
    for o in &summary.outcomes {
        println!(
            "  seed {:>4}: accuracy {:.2} % (best {:.2} %), sparsity {:.1} %, {} features, {:.1}s",
            o.seed,
            o.final_accuracy * 100.0,
            o.best_accuracy * 100.0,
            o.sparsity_percent,
            o.selected_features.len(),
            o.train_seconds
        );
    }
    println!(
        "=> accuracy {:.2} ± {:.2} %   sparsity {:.1} ± {:.1} %",
        summary.mean_accuracy, summary.std_accuracy, summary.mean_sparsity, summary.std_sparsity
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .ok_or_else(|| {
            anyhow!(
                "usage: bilevel experiment <id> (fig1..fig9, table1..table4, sparse, family, all)"
            )
        })?;
    let seeds = args.u64_list_or("seeds", &[42, 43, 44, 45]).map_err(|e| anyhow!(e))?;
    let ctx = ExpContext::new(
        args.flag("quick"),
        seeds,
        args.str_or("artifacts-dir", "artifacts"),
    );
    let t0 = Instant::now();
    experiments::run(id, &ctx)?;
    println!("experiment {id} done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

/// Load the `--config` TOML document (empty doc when the flag is absent).
fn config_doc(args: &Args) -> Result<TomlDoc> {
    match args.opt("config") {
        Some(path) => {
            let text =
                std::fs::read_to_string(path).map_err(|e| anyhow!("{path}: {e}"))?;
            bilevel_sparse::config::parse(&text).map_err(|e| anyhow!("{path}: {e}"))
        }
        None => Ok(TomlDoc::default()),
    }
}

/// Assemble a fault plan for the chaos-capable subcommands. An explicit
/// `--faults "site:spec;..."` list (with `--fault-seed S`) wins outright;
/// otherwise the `[fault]` section of the `--config` document is used.
/// `Ok(None)` means no injection anywhere — the failpoint layer stays a
/// no-op.
fn fault_plan_arg(args: &Args, doc: &TomlDoc) -> Result<Option<FaultPlan>> {
    if let Some(list) = args.opt("faults") {
        let seed = args.usize_or("fault-seed", 7).map_err(|e| anyhow!(e))? as u64;
        let plan = FaultPlan::parse_sites(seed, list).map_err(|e| anyhow!(e))?;
        return Ok((!plan.is_empty()).then_some(plan));
    }
    FaultPlan::from_doc(doc).map_err(|e| anyhow!(e))
}

/// Shared flag/config plumbing for `serve`, `loadgen`, and `chaos`:
/// `--config` seeds all three sections (`[serve]`, `[serve.http]`,
/// `[loadgen]`), individual flags override. The parsed document is
/// returned too so callers can pull the `[fault]` section from the same
/// file.
fn serve_configs(args: &Args) -> Result<(ServeConfig, LoadgenConfig, HttpConfig, TomlDoc)> {
    let doc = config_doc(args)?;
    let mut serve = ServeConfig::from_doc(&doc).map_err(|e| anyhow!(e))?;
    serve.shards = args.usize_or("shards", serve.shards).map_err(|e| anyhow!(e))?;
    serve.workers_per_shard = args
        .usize_or("workers-per-shard", serve.workers_per_shard)
        .map_err(|e| anyhow!(e))?;
    serve.queue_capacity =
        args.usize_or("queue", serve.queue_capacity).map_err(|e| anyhow!(e))?;
    serve.max_batch = args.usize_or("batch", serve.max_batch).map_err(|e| anyhow!(e))?;
    serve.min_fill = args.usize_or("min-fill", serve.min_fill).map_err(|e| anyhow!(e))?;
    serve.max_wait_micros = args
        .usize_or("wait-us", serve.max_wait_micros as usize)
        .map_err(|e| anyhow!(e))? as u64;
    serve.cache_capacity =
        args.usize_or("cache", serve.cache_capacity).map_err(|e| anyhow!(e))?;
    serve.validate().map_err(|e| anyhow!(e))?;

    let mut load = LoadgenConfig::from_doc(&doc).map_err(|e| anyhow!(e))?;
    load.clients = args.usize_or("clients", load.clients).map_err(|e| anyhow!(e))?;
    load.requests_per_client =
        args.usize_or("requests", load.requests_per_client).map_err(|e| anyhow!(e))?;
    load.rows = args.usize_or("rows", load.rows).map_err(|e| anyhow!(e))?;
    load.cols = args.usize_or("cols", load.cols).map_err(|e| anyhow!(e))?;
    load.eta = args.f64_or("eta", load.eta).map_err(|e| anyhow!(e))?;
    load.pool = args.usize_or("pool", load.pool).map_err(|e| anyhow!(e))?;
    load.f32_every = args.usize_or("f32-every", load.f32_every).map_err(|e| anyhow!(e))?;
    load.seed = args.usize_or("seed", load.seed as usize).map_err(|e| anyhow!(e))? as u64;
    load.retry_budget = args
        .usize_or("retry-budget", load.retry_budget as usize)
        .map_err(|e| anyhow!(e))? as u32;
    load.backoff_cap_ms = args
        .usize_or("backoff-cap-ms", load.backoff_cap_ms as usize)
        .map_err(|e| anyhow!(e))? as u64;
    if let Some(mix) = args.opt("mix") {
        load.mix = mix
            .split(',')
            .map(|p| {
                ProjectionKind::parse(p.trim())
                    .ok_or_else(|| anyhow!("--mix: unknown projection {p:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
    }
    load.validate().map_err(|e| anyhow!(e))?;

    let mut http = HttpConfig::from_doc(&doc).map_err(|e| anyhow!(e))?;
    if let Some(listen) = args.opt("listen") {
        http.listen = listen.to_string();
    }
    http.validate().map_err(|e| anyhow!(e))?;
    Ok((serve, load, http, doc))
}

/// Parse `--model <path>` (+ `--model-dtype f32|f64`, default f32) for the
/// engine subcommands.
fn model_arg(args: &Args) -> Result<Option<(PathBuf, Dtype)>> {
    let Some(p) = args.opt("model") else { return Ok(None) };
    let dtype = match args.str_or("model-dtype", "f32").as_str() {
        "f32" => Dtype::F32,
        "f64" => Dtype::F64,
        other => return Err(anyhow!("--model-dtype: expected f32 or f64, got {other:?}")),
    };
    Ok(Some((PathBuf::from(p), dtype)))
}

/// Load a checkpoint into a running engine and prove the serve path: one
/// `SparseEncode` request against the loaded model must match the
/// checkpoint's in-memory encoder byte for byte. The file is read and
/// validated once; the registered encoder and the reference encoder come
/// from the same parsed bundle.
fn load_and_verify_model(engine: &Engine, path: &Path, dtype: Dtype) -> Result<u64> {
    let ck = Checkpoint::load(path).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    let mb = ck.model.ok_or_else(|| anyhow!("{}: no model bundle", path.display()))?;
    let mut rng = Xoshiro256pp::seed_from_u64(0x5EED);
    let (id, rows, cols, identical) = match dtype {
        Dtype::F64 => {
            let reference = mb.encoder::<f64>();
            let id = engine.register_encoder_f64(reference.clone());
            let x = Matrix::<f64>::randn(mb.plan.features(), 8, &mut rng);
            let resp = engine
                .submit_encode_wait(id, Payload::F64(x.clone()))
                .map_err(|e| anyhow!("verify encode: {e}"))?;
            let Payload::F64(h) = &resp.payload else { return Err(anyhow!("dtype changed")) };
            let direct = reference.encode(&x);
            (id, h.rows(), h.cols(), h.max_abs_diff(&direct) == 0.0)
        }
        Dtype::F32 => {
            let reference = mb.encoder::<f32>();
            let id = engine.register_encoder_f32(reference.clone());
            let x: Matrix<f32> = Matrix::<f64>::randn(mb.plan.features(), 8, &mut rng).cast();
            let resp = engine
                .submit_encode_wait(id, Payload::F32(x.clone()))
                .map_err(|e| anyhow!("verify encode: {e}"))?;
            let Payload::F32(h) = &resp.payload else { return Err(anyhow!("dtype changed")) };
            let direct = reference.encode(&x);
            (id, h.rows(), h.cols(), h.max_abs_diff(&direct) == 0.0)
        }
    };
    if !identical {
        return Err(anyhow!("loaded model diverged from the checkpoint's in-memory encoder"));
    }
    println!(
        "model   : {} -> id {id} ({} dtype, {rows}x{cols} activations, serve == in-memory bit-identical)",
        path.display(),
        dtype.name(),
    );
    Ok(id)
}

fn run_engine_workload(
    serve_cfg: &ServeConfig,
    load_cfg: &LoadgenConfig,
    model: Option<(PathBuf, Dtype)>,
) -> Result<()> {
    let mix_names: Vec<&str> = load_cfg.mix.iter().map(|k| k.name()).collect();
    println!(
        "engine  : {} shards x {} workers, queue {}, batch <= {} (min-fill {}, wait {} us), cache {}",
        serve_cfg.effective_shards(),
        serve_cfg.workers_per_shard,
        serve_cfg.queue_capacity,
        serve_cfg.max_batch,
        serve_cfg.min_fill,
        serve_cfg.max_wait_micros,
        serve_cfg.cache_capacity,
    );
    println!(
        "workload: {} clients x {} requests, {}x{} eta={} pool={} mix=[{}]",
        load_cfg.clients,
        load_cfg.requests_per_client,
        load_cfg.rows,
        load_cfg.cols,
        load_cfg.eta,
        load_cfg.pool,
        mix_names.join(", "),
    );
    let engine = Engine::start(serve_cfg).map_err(|e| anyhow!(e))?;
    if let Some((path, dtype)) = &model {
        load_and_verify_model(&engine, path, *dtype)?;
    }
    let report = run_loadgen(&engine, load_cfg);
    println!(
        "client  : {} completed, {} failed, {} backpressure retries, {} redials",
        report.completed, report.failed, report.retries, report.redials
    );
    println!(
        "          {:.0} req/s, latency mean {:.0} us / max {} us, cache hits {} ({:.1} %)",
        report.throughput_rps(),
        report.mean_latency_micros(),
        report.max_latency_micros,
        report.cache_hits,
        report.hit_fraction() * 100.0,
    );
    println!("          {}", report.latency_summary());
    let stats = engine.shutdown();
    print!("{stats}");
    if report.failed > 0 {
        return Err(anyhow!("{} requests failed", report.failed));
    }
    Ok(())
}

/// Network mode for `serve --listen`: start the engine, put the HTTP
/// front-end on it, and block until something drains us (`POST /v1/drain`
/// over the wire, or [`Server::drain`] via signal-free shutdown paths).
fn run_http_server(
    serve_cfg: &ServeConfig,
    http_cfg: &HttpConfig,
    model: Option<(PathBuf, Dtype)>,
    addr_file: Option<&str>,
) -> Result<()> {
    let engine = Arc::new(Engine::start(serve_cfg).map_err(|e| anyhow!(e))?);
    if let Some((path, dtype)) = &model {
        load_and_verify_model(&engine, path, *dtype)?;
    }
    let server = Server::start(Arc::clone(&engine), http_cfg).map_err(|e| anyhow!(e))?;
    let addr = server.addr();
    println!("listening: http://{addr} (drain with: curl -X POST http://{addr}/v1/drain)");
    if http_cfg.quota_rps > 0.0 {
        println!(
            "quota    : {} req/s per client, burst {}",
            http_cfg.quota_rps, http_cfg.quota_burst
        );
    }
    if let Some(f) = addr_file {
        // written last so a watcher that sees the file can connect at once
        std::fs::write(f, addr.to_string()).map_err(|e| anyhow!("{f}: {e}"))?;
        println!("addr file: {f}");
    }
    server.wait_for_drain();
    let report = server.join();
    println!("{report}");
    let stats = Arc::try_unwrap(engine)
        .map_err(|_| anyhow!("server leaked an engine reference"))?
        .shutdown();
    print!("{stats}");
    if let Some(injector) = fault::installed() {
        println!("{}", injector.report());
        fault::clear();
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (serve_cfg, mut load_cfg, http_cfg, doc) = serve_configs(args)?;
    if args.opt("listen").is_some() {
        println!("bilevel serve — HTTP projection service");
        if let Some(plan) = fault_plan_arg(args, &doc)? {
            println!("fault plan: {}", plan.summary());
            fault::install(plan);
        }
        return run_http_server(
            &serve_cfg,
            &http_cfg,
            model_arg(args)?,
            args.opt("addr-file"),
        );
    }
    // `serve` validates a configuration with a short smoke workload unless
    // the caller asked for specific volumes.
    if args.opt("requests").is_none() {
        load_cfg.requests_per_client = 16;
    }
    if args.opt("clients").is_none() {
        load_cfg.clients = 2;
    }
    println!("bilevel serve — projection service engine self-test");
    run_engine_workload(&serve_cfg, &load_cfg, model_arg(args)?)
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    let (serve_cfg, mut load_cfg, _http_cfg, doc) = serve_configs(args)?;
    if args.flag("chaos") {
        load_cfg.chaos = true;
        // client-side sites (conn.slow_read) need a plan installed in the
        // loadgen process; server-side sites belong to the serve process.
        if let Some(plan) = fault_plan_arg(args, &doc)? {
            println!("fault plan: {}", plan.summary());
            fault::install(plan);
        }
    }
    let result = if let Some(addr) = args.opt("connect") {
        println!("bilevel loadgen — network closed-loop benchmark against {addr}");
        let report = run_loadgen_net(addr, &load_cfg).map_err(|e| anyhow!(e))?;
        println!(
            "client  : {} completed, {} failed, {} backpressure retries, {} redials",
            report.completed, report.failed, report.retries, report.redials
        );
        println!(
            "          {:.0} req/s, latency mean {:.0} us, cache hits {} ({:.1} %)",
            report.throughput_rps(),
            report.mean_latency_micros(),
            report.cache_hits,
            report.hit_fraction() * 100.0,
        );
        println!("          {}", report.latency_summary());
        if report.failed > 0 {
            Err(anyhow!("{} requests failed", report.failed))
        } else {
            Ok(())
        }
    } else {
        println!("bilevel loadgen — closed-loop engine benchmark");
        run_engine_workload(&serve_cfg, &load_cfg, model_arg(args)?)
    };
    if let Some(injector) = fault::installed() {
        println!("{}", injector.report());
        fault::clear();
    }
    result
}

/// The small synthetic checkpoint used by the chaos persist drill: the
/// artifact-free sparsify pipeline (init → BP¹,∞ project → plan →
/// compact) at a fixed shape, fully determined by `seed`.
fn chaos_checkpoint(seed: u64) -> Checkpoint {
    use bilevel_sparse::kernels::Workspace;
    use bilevel_sparse::model::{SaeDims, SaeParams};
    use bilevel_sparse::persist::ModelBundle;
    use bilevel_sparse::projection::bilevel::bilevel_l1inf_inplace_cols;
    use bilevel_sparse::sparse::{compact_params, CompactPlan};

    let (features, hidden, eta) = (32, 8, 1.0);
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let dims = SaeDims { features, hidden, classes: 2 };
    let mut params = SaeParams::init(dims, &mut rng);
    let mut ws = Workspace::new();
    bilevel_l1inf_inplace_cols(
        &mut params.tensors[0],
        hidden,
        eta as f32,
        L1Algorithm::Condat,
        &mut ws,
    );
    let plan = CompactPlan::from_thresholds(ws.thresholds(), 0.0);
    let compact = compact_params(&params, &plan);
    Checkpoint {
        seed,
        config_digest: synthetic_digest(features, hidden, eta),
        dims,
        history: Vec::new(),
        model: Some(ModelBundle { plan, compact, dense: None }),
        train_state: None,
    }
}

/// `bilevel chaos` — deterministic fault-injection drill in one process.
///
/// Installs the seeded fault plan (from `--faults`/`--fault-seed`, the
/// `--config` `[fault]` section, or a built-in default), serves over a
/// real socket while the chaos loadgen hammers it, drains, and then runs
/// the persist recovery drill (corrupt the newest rolling checkpoint on
/// disk, prove the recovery chain falls back bit-exactly). Exits nonzero
/// if any robustness invariant is violated: every accepted request must
/// complete, injected worker panics must produce respawns, and recovery
/// must land on the prior snapshot byte for byte.
fn cmd_chaos(args: &Args) -> Result<()> {
    let (serve_cfg, mut load_cfg, mut http_cfg, doc) = serve_configs(args)?;
    load_cfg.chaos = true;
    let plan = match fault_plan_arg(args, &doc)? {
        Some(p) => p,
        None => FaultPlan::parse_sites(
            args.usize_or("fault-seed", 7).map_err(|e| anyhow!(e))? as u64,
            "worker.panic:every=16,limit=2;\
             conn.reset:every=9,param=512,limit=4;\
             conn.slow_read:every=7,param=10,limit=6",
        )
        .map_err(|e| anyhow!(e))?,
    };
    println!("bilevel chaos — seeded fault-injection drill");
    println!("fault plan: {}", plan.summary());
    let fault_seed = plan.seed;
    let expect_restart = plan.site(FaultSite::WorkerPanic).is_some();
    let injector = fault::install(plan);

    // ---- serve drill: engine + HTTP front-end + chaos loadgen ----
    if args.opt("listen").is_none() {
        http_cfg.listen = "127.0.0.1:0".to_string();
    }
    let engine = Arc::new(Engine::start(&serve_cfg).map_err(|e| anyhow!(e))?);
    if let Some((path, dtype)) = model_arg(args)? {
        load_and_verify_model(&engine, &path, dtype)?;
    }
    let server = Server::start(Arc::clone(&engine), &http_cfg).map_err(|e| anyhow!(e))?;
    let addr = server.addr().to_string();
    println!("serving  : http://{addr} under injected faults");
    let report = run_loadgen_net(&addr, &load_cfg).map_err(|e| anyhow!(e))?;
    server.drain();
    server.wait_for_drain();
    let net_report = server.join();
    let stats = Arc::try_unwrap(engine)
        .map_err(|_| anyhow!("server leaked an engine reference"))?
        .shutdown();
    println!(
        "client  : {} completed, {} failed, {} backpressure retries, {} redials",
        report.completed, report.failed, report.retries, report.redials
    );
    println!("{net_report}");
    print!("{stats}");
    println!("{}", injector.report());
    fault::clear();

    let total = (load_cfg.clients * load_cfg.requests_per_client) as u64;
    let mut violations = Vec::new();
    if report.completed != total {
        violations.push(format!(
            "lost requests: {} of {total} completed ({} failed)",
            report.completed, report.failed
        ));
    }
    if expect_restart {
        let panics = injector.fired(FaultSite::WorkerPanic);
        if panics == 0 {
            violations.push(
                "worker.panic never fired — plan schedule too sparse for this workload".into(),
            );
        } else if stats.worker_restarts() == 0 {
            violations.push(format!(
                "{panics} worker panics fired but no restart was recorded"
            ));
        } else {
            println!(
                "supervise: {} worker panics -> {} respawns, shard capacity restored",
                stats.worker_panics(),
                stats.worker_restarts()
            );
        }
    }

    // ---- persist drill: corrupt the newest rolling checkpoint, recover ----
    let dir = std::env::temp_dir().join(format!("bilevel-chaos-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).map_err(|e| anyhow!("{}: {e}", dir.display()))?;
    let save = |ck: &Checkpoint, name: &str| -> Result<()> {
        let p = dir.join(name);
        ck.save(&p).map_err(|e| anyhow!("{}: {e}", p.display()))
    };
    let survivor = chaos_checkpoint(21);
    save(&chaos_checkpoint(20), "epoch-0001.ckpt")?;
    save(&survivor, "epoch-0002.ckpt")?;
    // the newest checkpoint is written through a checksum-flip failpoint:
    // save() reports success but the bytes on disk are corrupt
    fault::install(
        FaultPlan::parse_sites(fault_seed, "persist.checksum_flip:every=1,limit=1")
            .map_err(|e| anyhow!(e))?,
    );
    save(&chaos_checkpoint(22), "epoch-0003.ckpt")?;
    fault::clear();
    let outcome = recover_latest(&dir).map_err(|e| anyhow!("{}: {e}", dir.display()))?;
    match &outcome.recovered {
        None => violations.push("recovery chain found no valid checkpoint".into()),
        Some((path, ck)) => {
            if !path.ends_with("epoch-0002.ckpt") {
                violations.push(format!(
                    "recovered from {} instead of the prior snapshot",
                    path.display()
                ));
            }
            if ck.to_bytes() != survivor.to_bytes() {
                violations.push("recovered checkpoint is not bit-exact".into());
            }
            if outcome.quarantined.len() != 1 {
                violations.push(format!(
                    "expected 1 quarantined file, found {}",
                    outcome.quarantined.len()
                ));
            } else {
                println!(
                    "recover  : {} quarantined, resumed bit-exactly from {}",
                    outcome.quarantined[0].0.display(),
                    path.display()
                );
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);

    if violations.is_empty() {
        println!("chaos drill passed: no lost requests, supervision and recovery held");
        Ok(())
    } else {
        for v in &violations {
            eprintln!("violation: {v}");
        }
        Err(anyhow!("{} robustness invariant(s) violated", violations.len()))
    }
}

fn cmd_bench(args: &Args) -> Result<()> {
    let target = args.positional.first().map(String::as_str).unwrap_or("kernels");
    let quick = args.flag("quick") || std::env::var("BILEVEL_BENCH_QUICK").is_ok();
    match target {
        "kernels" => {
            println!(
                "bilevel bench kernels — SIMD kernel layer vs scalar baseline{}",
                if quick { " (quick)" } else { "" }
            );
            println!("kernel isa: {}", bilevel_sparse::kernels::active_isa().name());
            let report = bilevel_sparse::bench::kernels::run(quick);
            println!("{}", report.markdown());
            let out = args.str_or("out", "BENCH_kernels.json");
            std::fs::write(&out, report.to_json()).map_err(|e| anyhow!("{out}: {e}"))?;
            println!("wrote {out}");
            Ok(())
        }
        "sparse" => {
            println!(
                "bilevel bench sparse — dense vs compacted structured-sparse encode{}",
                if quick { " (quick)" } else { "" }
            );
            let report = bilevel_sparse::bench::sparse::run(quick);
            println!("{}", report.markdown());
            let out = args.str_or("out", "BENCH_sparse.json");
            std::fs::write(&out, report.to_json()).map_err(|e| anyhow!("{out}: {e}"))?;
            println!("wrote {out}");
            if !report.all_bit_identical() {
                return Err(anyhow!("sparse encode diverged bitwise from dense encode"));
            }
            Ok(())
        }
        "projection-family" => {
            println!(
                "bilevel bench projection-family — flat projection kinds x dtypes x shapes \
                 + multilevel depth-vs-threads curve{}",
                if quick { " (quick)" } else { "" }
            );
            println!("kernel isa: {}", bilevel_sparse::kernels::active_isa().name());
            let report = bilevel_sparse::bench::projection_family::run(quick);
            println!("{}", report.markdown());
            let out = args.str_or("out", "BENCH_projection_family.json");
            std::fs::write(&out, report.to_json()).map_err(|e| anyhow!("{out}: {e}"))?;
            println!("wrote {out}");
            Ok(())
        }
        "compare" => {
            // Perf-regression gate: fresh quick runs vs the committed
            // BENCH_*.json snapshots, matched on overlapping (name, shape)
            // keys. Regressed = committed_ms >= min_ms AND
            // fresh_ms > tolerance × committed_ms.
            use bilevel_sparse::bench::compare::{
                compare_kernels, compare_projection_family, compare_sparse,
            };
            let tolerance = args.f64_or("tolerance", 2.0).map_err(|e| anyhow!(e))?;
            let min_ms = args.f64_or("min-ms", 0.02).map_err(|e| anyhow!(e))?;
            let kernels_path = args.str_or("kernels", "BENCH_kernels.json");
            let sparse_path = args.str_or("sparse", "BENCH_sparse.json");
            let family_path = args.str_or("projection-family", "BENCH_projection_family.json");
            println!(
                "bilevel bench compare — fresh quick run vs committed snapshots \
                 (tolerance {tolerance}x, min {min_ms} ms)"
            );
            println!("kernel isa: {}", bilevel_sparse::kernels::active_isa().name());

            let committed_kernels = std::fs::read_to_string(&kernels_path)
                .map_err(|e| anyhow!("{kernels_path}: {e}"))?;
            let fresh_kernels = bilevel_sparse::bench::kernels::run(true);
            let kernels_report =
                compare_kernels(&committed_kernels, &fresh_kernels, tolerance, min_ms)
                    .map_err(|e| anyhow!("kernels compare: {e}"))?;
            println!("{}", kernels_report.markdown());

            let committed_sparse = std::fs::read_to_string(&sparse_path)
                .map_err(|e| anyhow!("{sparse_path}: {e}"))?;
            let fresh_sparse = bilevel_sparse::bench::sparse::run(true);
            let sparse_report = compare_sparse(&committed_sparse, &fresh_sparse, tolerance, min_ms)
                .map_err(|e| anyhow!("sparse compare: {e}"))?;
            println!("{}", sparse_report.markdown());

            let committed_family = std::fs::read_to_string(&family_path)
                .map_err(|e| anyhow!("{family_path}: {e}"))?;
            let fresh_family = bilevel_sparse::bench::projection_family::run(true);
            let family_report =
                compare_projection_family(&committed_family, &fresh_family, tolerance, min_ms)
                    .map_err(|e| anyhow!("projection-family compare: {e}"))?;
            println!("{}", family_report.markdown());

            let mut regressions: Vec<String> = Vec::new();
            for rep in [&kernels_report, &sparse_report, &family_report] {
                for row in rep.regressions() {
                    regressions.push(format!(
                        "{} {}: {:.4} ms committed -> {:.4} ms fresh ({:.2}x)",
                        row.name,
                        row.shape,
                        row.committed_ms,
                        row.fresh_ms,
                        row.ratio()
                    ));
                }
            }
            if regressions.is_empty() {
                println!("perf gate passed: no row regressed beyond {tolerance}x");
                Ok(())
            } else {
                for r in &regressions {
                    eprintln!("regression: {r}");
                }
                Err(anyhow!("{} bench row(s) regressed beyond {tolerance}x", regressions.len()))
            }
        }
        other => Err(anyhow!(
            "unknown bench target {other:?} (try: kernels, sparse, projection-family, compare)"
        )),
    }
}

/// `bilevel sparsify` — the project → plan → compact → verify → time
/// pipeline on a synthetic SAE (no artifacts needed): projects W1 with
/// BP¹,∞ at `--eta`, derives the support plan from the thresholds,
/// compacts the model, proves sparse encode ≡ dense encode bitwise on a
/// random batch, and reports parameter/time savings.
fn cmd_sparsify(args: &Args) -> Result<()> {
    use bilevel_sparse::kernels::Workspace;
    use bilevel_sparse::model::{SaeDims, SaeParams};
    use bilevel_sparse::projection::bilevel::bilevel_l1inf_inplace_cols;
    use bilevel_sparse::sparse::{compact_params, linalg, CompactEncoder, CompactPlan};

    let features = args.usize_or("features", 4096).map_err(|e| anyhow!(e))?;
    let hidden = args.usize_or("hidden", 128).map_err(|e| anyhow!(e))?;
    let batch = args.usize_or("batch", 32).map_err(|e| anyhow!(e))?;
    let eta = args.f64_or("eta", 1.0).map_err(|e| anyhow!(e))?;
    let seed = args.usize_or("seed", 42).map_err(|e| anyhow!(e))? as u64;
    let reps = args.usize_or("reps", 20).map_err(|e| anyhow!(e))?;

    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let dims = SaeDims { features, hidden, classes: 2 };
    let mut params = SaeParams::init(dims, &mut rng);

    // Project W1 in place (the trainer's native path) and read the
    // per-column thresholds — zero threshold ⇒ feature pruned.
    let mut ws = Workspace::new();
    bilevel_l1inf_inplace_cols(
        &mut params.tensors[0],
        hidden,
        eta as f32,
        L1Algorithm::Condat,
        &mut ws,
    );
    let plan = CompactPlan::from_thresholds(ws.thresholds(), 0.0);
    let compact = compact_params(&params, &plan);

    println!("model          : {features} features x {hidden} hidden (seed {seed})");
    println!("projection     : bilevel-l1inf, eta = {eta}");
    println!(
        "support        : {} / {} features alive ({:.1} % column sparsity)",
        plan.alive(),
        features,
        plan.sparsity_percent()
    );
    println!(
        "params         : {} -> {} ({:.1} % smaller)",
        params.n_params(),
        compact.n_params(),
        100.0 * (params.n_params() - compact.n_params()) as f64 / params.n_params() as f64
    );

    // Bitwise verification: sparse encode of the compacted encoder vs the
    // dense encode of the projected (still-dense) weights.
    let x = Matrix::<f32>::randn(features, batch, &mut rng);
    let enc = CompactEncoder::<f32>::from_params(&params, &plan);
    let sparse_h = enc.encode(&x);
    let mut dense_h = Matrix::<f32>::zeros(0, 0);
    linalg::encode_batch_dense_into(
        &x,
        &params.tensors[0],
        &params.tensors[1],
        hidden,
        &mut dense_h,
    );
    let bitwise = sparse_h
        .as_slice()
        .iter()
        .zip(dense_h.as_slice().iter())
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "verify         : sparse encode vs dense encode on a {features}x{batch} batch: {}",
        if bitwise { "bit-identical" } else { "MISMATCH" }
    );

    // Timing: median of `reps` encodes each.
    let time_median = |f: &mut dyn FnMut()| -> f64 {
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        samples[samples.len() / 2]
    };
    let mut out = Matrix::<f32>::zeros(hidden, batch);
    let dense_s = time_median(&mut || {
        let (w1, b1) = (&params.tensors[0], &params.tensors[1]);
        linalg::encode_batch_dense_into(&x, w1, b1, hidden, &mut out)
    });
    let compact_s = time_median(&mut || enc.encode_into(&x, &mut out));
    println!(
        "encode         : dense {:.3} ms, compact {:.3} ms ({:.2}x)",
        dense_s * 1e3,
        compact_s * 1e3,
        if compact_s > 0.0 { dense_s / compact_s } else { 0.0 }
    );
    if !bitwise {
        return Err(anyhow!("sparse encode diverged bitwise from dense encode"));
    }
    Ok(())
}

/// Digest stamped into synthetic (artifact-free) exports, so resume /
/// import tooling can still detect configuration drift.
fn synthetic_digest(features: usize, hidden: usize, eta: f64) -> u64 {
    let canon = format!("synthetic|{features}|{hidden}|{:016x}", eta.to_bits());
    bilevel_sparse::persist::fnv1a64(canon.as_bytes())
}

/// `bilevel export` — persist a model checkpoint. `--synthetic` runs the
/// artifact-free sparsify pipeline (init → BP¹,∞ project → plan →
/// compact) and exports the result; without it, a full single-seed
/// training run (needs `make artifacts`) is trained and exported.
fn cmd_export(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.str_or("out", "model.ckpt"));
    let dense = args.flag("dense") || args.flag("export-dense");
    if args.flag("synthetic") {
        use bilevel_sparse::kernels::Workspace;
        use bilevel_sparse::model::{SaeDims, SaeParams};
        use bilevel_sparse::persist::ModelBundle;
        use bilevel_sparse::projection::bilevel::bilevel_l1inf_inplace_cols;
        use bilevel_sparse::sparse::{compact_params, CompactPlan};

        let features = args.usize_or("features", 256).map_err(|e| anyhow!(e))?;
        let hidden = args.usize_or("hidden", 32).map_err(|e| anyhow!(e))?;
        let eta = args.f64_or("eta", 1.0).map_err(|e| anyhow!(e))?;
        let seed = args.usize_or("seed", 42).map_err(|e| anyhow!(e))? as u64;

        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let dims = SaeDims { features, hidden, classes: 2 };
        let mut params = SaeParams::init(dims, &mut rng);
        let mut ws = Workspace::new();
        bilevel_l1inf_inplace_cols(
            &mut params.tensors[0],
            hidden,
            eta as f32,
            L1Algorithm::Condat,
            &mut ws,
        );
        let plan = CompactPlan::from_thresholds(ws.thresholds(), 0.0);
        let compact = compact_params(&params, &plan);
        let ck = Checkpoint {
            seed,
            config_digest: synthetic_digest(features, hidden, eta),
            dims,
            history: Vec::new(),
            model: Some(ModelBundle {
                plan: plan.clone(),
                compact,
                dense: dense.then(|| params.clone()),
            }),
            train_state: None,
        };
        ck.save(&out).map_err(|e| anyhow!("{}: {e}", out.display()))?;
        println!(
            "exported synthetic model: {} / {features} features alive ({:.1} % sparsity, eta {eta}) -> {}",
            plan.alive(),
            plan.sparsity_percent(),
            out.display()
        );
        Ok(())
    } else {
        let (cfg, run_cfg) = train_configs(args)?;
        // honour the config's [persist] export_dense like cmd_train does
        let dense = dense || run_cfg.persist.export_dense;
        if run_cfg.seeds.len() != 1 {
            return Err(anyhow!("export trains exactly one seed (use --seeds S)"));
        }
        let seed = run_cfg.seeds[0];
        let dir = args.str_or("artifacts-dir", &run_cfg.artifacts_dir);
        let rt = Runtime::open(&dir)?;
        let trainer = SaeTrainer::new(&rt, cfg.clone())?;
        println!(
            "export: training dataset={} eta={} seed={seed}, then writing {}",
            cfg.dataset.name(),
            cfg.eta,
            out.display()
        );
        let outcome = trainer.run(seed)?;
        outcome
            .to_checkpoint(cfg.digest(), dense)
            .save(&out)
            .map_err(|e| anyhow!("{}: {e}", out.display()))?;
        println!(
            "exported trained model: accuracy {:.2} %, {} / {} features alive -> {}",
            outcome.final_accuracy * 100.0,
            outcome.plan.alive(),
            outcome.dims.features,
            out.display()
        );
        Ok(())
    }
}

/// `bilevel import <path>` — load and fully validate a checkpoint
/// (checksum + structure) and print its contents. `--verify` additionally
/// re-derives the compact tensors from the dense model (when present) and
/// exercises both encoder dtypes on a seeded batch.
fn cmd_import(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: bilevel import <model.ckpt> [--verify]"))?;
    let path = Path::new(path);
    let ck = Checkpoint::load(path).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    println!("checkpoint : {} (checksum ok)", path.display());
    println!("seed       : {}", ck.seed);
    println!("config     : digest {:016x}", ck.config_digest);
    println!(
        "dims       : {} features x {} hidden x {} classes",
        ck.dims.features, ck.dims.hidden, ck.dims.classes
    );
    println!("history    : {} epochs", ck.history.len());
    match &ck.model {
        Some(mb) => println!(
            "model      : {} / {} features alive ({:.1} % sparsity), dense params {}",
            mb.plan.alive(),
            mb.plan.features(),
            mb.plan.sparsity_percent(),
            if mb.dense.is_some() { "included" } else { "omitted" }
        ),
        None => println!("model      : none (mid-train state checkpoint)"),
    }
    match &ck.train_state {
        Some(ts) => println!(
            "train state: phase {}, {} epochs done, step {}",
            ts.phase, ts.epochs_done, ts.step
        ),
        None => println!("train state: none"),
    }
    if args.flag("verify") {
        let mb = ck
            .model
            .as_ref()
            .ok_or_else(|| anyhow!("--verify: checkpoint has no model bundle"))?;
        if let Some(dense) = &mb.dense {
            let rec = bilevel_sparse::sparse::compact_params(dense, &mb.plan);
            let ok = rec.tensors.iter().zip(mb.compact.tensors.iter()).all(|(a, b)| {
                a.len() == b.len()
                    && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
            });
            if !ok {
                return Err(anyhow!(
                    "verify FAILED: re-compacted dense model differs from stored compact tensors"
                ));
            }
            println!("verify     : dense -> compact re-derivation bit-identical");
        }
        let mut rng = Xoshiro256pp::seed_from_u64(ck.seed);
        let x = Matrix::<f64>::randn(ck.dims.features, 4, &mut rng);
        let enc64 = mb.encoder::<f64>();
        let h64 = enc64.encode(&x);
        let h32 = mb.encoder::<f32>().encode(&x.cast::<f32>());
        println!(
            "verify     : f64 encode {}x{}, f32 encode {}x{}, fingerprint {:016x}",
            h64.rows(),
            h64.cols(),
            h32.rows(),
            h32.cols(),
            enc64.fingerprint()
        );
    }
    Ok(())
}

/// `bilevel inspect <path>` — dump the fixed 72-byte header without
/// reading the payload (no checksum pass; `bilevel import` does that).
fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: bilevel inspect <model.ckpt>"))?;
    let path = Path::new(path);
    let h = read_header(path).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    let file_len = std::fs::metadata(path)?.len();
    println!("checkpoint : {}", path.display());
    println!("format     : version {}, tensor dtype {}", h.version, h.dtype_name());
    println!(
        "dims       : {} features x {} hidden x {} classes",
        h.dims.features, h.dims.hidden, h.dims.classes
    );
    println!("seed       : {}", h.seed);
    println!("config     : digest {:016x}", h.config_digest);
    println!(
        "sections   : model={} dense={} train-state={}",
        h.has_model(),
        h.has_dense(),
        h.has_train_state()
    );
    println!(
        "size       : {} bytes declared, {file_len} on disk{}",
        h.expected_file_len(),
        if h.expected_file_len() == file_len { "" } else { "  (MISMATCH — corrupt/truncated)" }
    );
    println!("note       : header-only dump; `bilevel import` verifies the checksum");
    Ok(())
}

/// `bilevel audit` — run the repo-aware static-analysis pass and exit
/// nonzero on any finding. The same rules gate `cargo test` through
/// `rust/tests/audit_integration.rs`; the CLI form exists for pre-push
/// hooks and the blocking CI step.
fn cmd_audit(args: &Args) -> Result<()> {
    let root = PathBuf::from(args.str_or("root", "."));
    let report = analysis::audit_repo(&root)?;
    print!("{}", analysis::render(&report));
    if report.is_clean() {
        Ok(())
    } else {
        Err(anyhow!("audit failed with {} finding(s)", report.findings.len()))
    }
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.str_or("dir", "artifacts");
    let rt = Runtime::open(&dir)?;
    println!("platform: {}", rt.platform());
    println!("{} artifacts in {dir}/manifest.txt:", rt.manifest().len());
    for name in rt.manifest().names() {
        let e = rt.manifest().get(name).unwrap();
        println!(
            "  {name:<22} {:<12} F={:<6} H={:<4} K={} B={}",
            e.kind, e.features, e.hidden, e.classes, e.batch
        );
    }
    Ok(())
}
