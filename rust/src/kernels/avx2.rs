//! Explicit AVX2 kernels (`core::arch::x86_64`, stable Rust).
//!
//! Each kernel is an `unsafe fn` annotated `#[target_feature(enable =
//! "avx2")]` plus a safe public wrapper that asserts runtime support; the
//! dispatch table points at the wrappers, and only after
//! `is_x86_feature_detected!("avx2")` succeeded, so the assertion is a
//! cached atomic load in practice.
//!
//! Bit-identity contract with the portable/reference kernels (see the
//! [`crate::kernels`] module docs for the full statement):
//!
//! * `colmax` — exact: `max` over non-negative magnitudes is
//!   order-independent, and `vmaxpd` ties return identical bits.
//! * `sum_abs` / `sumsq` — exact: the two 4-lane (`f64`) or one 8-lane
//!   (`f32`) accumulators reproduce the portable lane decomposition
//!   (element `i` → accumulator `i % LANES`) add-for-add, and finish with
//!   the same [`combine8`](super::combine8) tree.
//! * `scale` / `axpy` — exact: same IEEE multiply/add per element, no FMA
//!   contraction (`vmulpd` + `vaddpd`, never `vfmadd`).
//! * `clip` / `soft-threshold` — exact except the **sign of a zero output
//!   when the threshold is exactly 0**: `vmaxpd`/`vminpd` resolve `±0.0`
//!   ties to the second operand, so clipping at `c == 0` yields `+0.0`
//!   for every element, while the scalar `f64::max`/`min` lowering leaves
//!   that sign unspecified. Magnitudes always agree; thresholds > 0 are
//!   bit-exact.
//!
//! Remainders (`len % width`) are handled by copying the tail into a
//! stack pad, running the same packed instruction, and writing back only
//! the valid lanes — so tail elements see *vector* semantics, not a
//! second scalar code path, and the per-kernel semantics are uniform over
//! the whole slice. Zero padding is exact for the reductions because
//! their accumulator lanes are never `-0.0` (they start at `+0.0` and
//! only ever add non-negative terms, and `x + 0.0 == x` bitwise for every
//! `x` except `-0.0`).

use core::arch::x86_64::*;

use super::dispatch::{Isa, KernelOps};
use super::{combine8, LANES};

#[inline]
fn have_avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

macro_rules! assert_avx2 {
    () => {
        assert!(have_avx2(), "AVX2 kernel called on a CPU without AVX2");
    };
}

/// The dispatch table for this ISA (see [`super::dispatch`]).
pub static OPS: KernelOps = KernelOps {
    isa: Isa::Avx2,
    colmax_f32,
    colmax_f64,
    sum_abs_f32,
    sum_abs_f64,
    sumsq_f32,
    sumsq_f64,
    clip_into_f32,
    clip_into_f64,
    clip_inplace_f32,
    clip_inplace_f64,
    soft_threshold_f32,
    soft_threshold_f64,
    scale_f32,
    scale_f64,
    axpy_f32,
    axpy_f64,
};

// ------------------------------------------------------------------- f64

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn colmax_f64_imp(xs: &[f64]) -> f64 {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let sign = _mm256_set1_pd(-0.0);
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut chunks = xs.chunks_exact(LANES);
        for ch in chunks.by_ref() {
            acc0 = _mm256_max_pd(acc0, _mm256_andnot_pd(sign, _mm256_loadu_pd(ch.as_ptr())));
            acc1 = _mm256_max_pd(acc1, _mm256_andnot_pd(sign, _mm256_loadu_pd(ch.as_ptr().add(4))));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut pad = [0.0f64; LANES];
            pad[..rem.len()].copy_from_slice(rem);
            let lo = _mm256_loadu_pd(pad.as_ptr());
            let hi = _mm256_loadu_pd(pad.as_ptr().add(4));
            acc0 = _mm256_max_pd(acc0, _mm256_andnot_pd(sign, lo));
            acc1 = _mm256_max_pd(acc1, _mm256_andnot_pd(sign, hi));
        }
        let mut lanes = [0.0f64; LANES];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc0);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc1);
        lanes.iter().fold(0.0f64, |m, &x| m.max(x))
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn sum_abs_f64_imp(xs: &[f64]) -> f64 {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let sign = _mm256_set1_pd(-0.0);
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut chunks = xs.chunks_exact(LANES);
        for ch in chunks.by_ref() {
            acc0 = _mm256_add_pd(acc0, _mm256_andnot_pd(sign, _mm256_loadu_pd(ch.as_ptr())));
            acc1 = _mm256_add_pd(acc1, _mm256_andnot_pd(sign, _mm256_loadu_pd(ch.as_ptr().add(4))));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut pad = [0.0f64; LANES];
            pad[..rem.len()].copy_from_slice(rem);
            let lo = _mm256_loadu_pd(pad.as_ptr());
            let hi = _mm256_loadu_pd(pad.as_ptr().add(4));
            acc0 = _mm256_add_pd(acc0, _mm256_andnot_pd(sign, lo));
            acc1 = _mm256_add_pd(acc1, _mm256_andnot_pd(sign, hi));
        }
        let mut lanes = [0.0f64; LANES];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc0);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc1);
        combine8(&lanes)
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn sumsq_f64_imp(xs: &[f64]) -> f64 {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut chunks = xs.chunks_exact(LANES);
        for ch in chunks.by_ref() {
            let a = _mm256_loadu_pd(ch.as_ptr());
            let b = _mm256_loadu_pd(ch.as_ptr().add(4));
            acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(a, a));
            acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(b, b));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut pad = [0.0f64; LANES];
            pad[..rem.len()].copy_from_slice(rem);
            let a = _mm256_loadu_pd(pad.as_ptr());
            let b = _mm256_loadu_pd(pad.as_ptr().add(4));
            acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(a, a));
            acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(b, b));
        }
        let mut lanes = [0.0f64; LANES];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc0);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc1);
        combine8(&lanes)
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn clip_into_f64_imp(src: &[f64], c: f64, dst: &mut [f64]) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        debug_assert_eq!(src.len(), dst.len());
        let lo = _mm256_set1_pd(-c);
        let hi = _mm256_set1_pd(c);
        let n = src.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let x = _mm256_loadu_pd(src.as_ptr().add(i));
            _mm256_storeu_pd(dst.as_mut_ptr().add(i), _mm256_min_pd(_mm256_max_pd(x, lo), hi));
            i += 4;
        }
        if i < n {
            let mut pad = [0.0f64; 4];
            pad[..n - i].copy_from_slice(&src[i..]);
            let x = _mm256_loadu_pd(pad.as_ptr());
            _mm256_storeu_pd(pad.as_mut_ptr(), _mm256_min_pd(_mm256_max_pd(x, lo), hi));
            dst[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn clip_inplace_f64_imp(xs: &mut [f64], c: f64) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let lo = _mm256_set1_pd(-c);
        let hi = _mm256_set1_pd(c);
        let n = xs.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let x = _mm256_loadu_pd(xs.as_ptr().add(i));
            _mm256_storeu_pd(xs.as_mut_ptr().add(i), _mm256_min_pd(_mm256_max_pd(x, lo), hi));
            i += 4;
        }
        if i < n {
            let mut pad = [0.0f64; 4];
            pad[..n - i].copy_from_slice(&xs[i..]);
            let x = _mm256_loadu_pd(pad.as_ptr());
            _mm256_storeu_pd(pad.as_mut_ptr(), _mm256_min_pd(_mm256_max_pd(x, lo), hi));
            xs[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn soft_threshold_f64_imp(xs: &mut [f64], tau: f64) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let t = _mm256_set1_pd(tau);
        let z = _mm256_setzero_pd();
        let sign = _mm256_set1_pd(-0.0);
        let n = xs.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let x = _mm256_loadu_pd(xs.as_ptr().add(i));
            let a = _mm256_max_pd(_mm256_sub_pd(x, t), z);
            let b = _mm256_max_pd(_mm256_sub_pd(_mm256_xor_pd(x, sign), t), z);
            _mm256_storeu_pd(xs.as_mut_ptr().add(i), _mm256_sub_pd(a, b));
            i += 4;
        }
        if i < n {
            let mut pad = [0.0f64; 4];
            pad[..n - i].copy_from_slice(&xs[i..]);
            let x = _mm256_loadu_pd(pad.as_ptr());
            let a = _mm256_max_pd(_mm256_sub_pd(x, t), z);
            let b = _mm256_max_pd(_mm256_sub_pd(_mm256_xor_pd(x, sign), t), z);
            _mm256_storeu_pd(pad.as_mut_ptr(), _mm256_sub_pd(a, b));
            xs[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn scale_f64_imp(xs: &mut [f64], s: f64) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let sv = _mm256_set1_pd(s);
        let n = xs.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let x = _mm256_loadu_pd(xs.as_ptr().add(i));
            _mm256_storeu_pd(xs.as_mut_ptr().add(i), _mm256_mul_pd(x, sv));
            i += 4;
        }
        if i < n {
            let mut pad = [0.0f64; 4];
            pad[..n - i].copy_from_slice(&xs[i..]);
            let x = _mm256_loadu_pd(pad.as_ptr());
            _mm256_storeu_pd(pad.as_mut_ptr(), _mm256_mul_pd(x, sv));
            xs[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn axpy_f64_imp(acc: &mut [f64], a: f64, row: &[f64]) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        debug_assert_eq!(acc.len(), row.len());
        let av = _mm256_set1_pd(a);
        let n = acc.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let d = _mm256_loadu_pd(acc.as_ptr().add(i));
            let r = _mm256_loadu_pd(row.as_ptr().add(i));
            _mm256_storeu_pd(acc.as_mut_ptr().add(i), _mm256_add_pd(d, _mm256_mul_pd(av, r)));
            i += 4;
        }
        if i < n {
            let mut pad_d = [0.0f64; 4];
            let mut pad_r = [0.0f64; 4];
            pad_d[..n - i].copy_from_slice(&acc[i..]);
            pad_r[..n - i].copy_from_slice(&row[i..]);
            let d = _mm256_loadu_pd(pad_d.as_ptr());
            let r = _mm256_loadu_pd(pad_r.as_ptr());
            _mm256_storeu_pd(pad_d.as_mut_ptr(), _mm256_add_pd(d, _mm256_mul_pd(av, r)));
            acc[i..].copy_from_slice(&pad_d[..n - i]);
        }
    }
}

// ------------------------------------------------------------------- f32

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn colmax_f32_imp(xs: &[f32]) -> f32 {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let sign = _mm256_set1_ps(-0.0);
        let mut acc = _mm256_setzero_ps();
        let mut chunks = xs.chunks_exact(LANES);
        for ch in chunks.by_ref() {
            acc = _mm256_max_ps(acc, _mm256_andnot_ps(sign, _mm256_loadu_ps(ch.as_ptr())));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut pad = [0.0f32; LANES];
            pad[..rem.len()].copy_from_slice(rem);
            acc = _mm256_max_ps(acc, _mm256_andnot_ps(sign, _mm256_loadu_ps(pad.as_ptr())));
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        lanes.iter().fold(0.0f32, |m, &x| m.max(x))
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn sum_abs_f32_imp(xs: &[f32]) -> f32 {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let sign = _mm256_set1_ps(-0.0);
        let mut acc = _mm256_setzero_ps();
        let mut chunks = xs.chunks_exact(LANES);
        for ch in chunks.by_ref() {
            acc = _mm256_add_ps(acc, _mm256_andnot_ps(sign, _mm256_loadu_ps(ch.as_ptr())));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut pad = [0.0f32; LANES];
            pad[..rem.len()].copy_from_slice(rem);
            acc = _mm256_add_ps(acc, _mm256_andnot_ps(sign, _mm256_loadu_ps(pad.as_ptr())));
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        combine8(&lanes)
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn sumsq_f32_imp(xs: &[f32]) -> f32 {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let mut acc = _mm256_setzero_ps();
        let mut chunks = xs.chunks_exact(LANES);
        for ch in chunks.by_ref() {
            let a = _mm256_loadu_ps(ch.as_ptr());
            acc = _mm256_add_ps(acc, _mm256_mul_ps(a, a));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut pad = [0.0f32; LANES];
            pad[..rem.len()].copy_from_slice(rem);
            let a = _mm256_loadu_ps(pad.as_ptr());
            acc = _mm256_add_ps(acc, _mm256_mul_ps(a, a));
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        combine8(&lanes)
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn clip_into_f32_imp(src: &[f32], c: f32, dst: &mut [f32]) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        debug_assert_eq!(src.len(), dst.len());
        let lo = _mm256_set1_ps(-c);
        let hi = _mm256_set1_ps(c);
        let n = src.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_min_ps(_mm256_max_ps(x, lo), hi));
            i += 8;
        }
        if i < n {
            let mut pad = [0.0f32; 8];
            pad[..n - i].copy_from_slice(&src[i..]);
            let x = _mm256_loadu_ps(pad.as_ptr());
            _mm256_storeu_ps(pad.as_mut_ptr(), _mm256_min_ps(_mm256_max_ps(x, lo), hi));
            dst[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn clip_inplace_f32_imp(xs: &mut [f32], c: f32) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let lo = _mm256_set1_ps(-c);
        let hi = _mm256_set1_ps(c);
        let n = xs.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(xs.as_ptr().add(i));
            _mm256_storeu_ps(xs.as_mut_ptr().add(i), _mm256_min_ps(_mm256_max_ps(x, lo), hi));
            i += 8;
        }
        if i < n {
            let mut pad = [0.0f32; 8];
            pad[..n - i].copy_from_slice(&xs[i..]);
            let x = _mm256_loadu_ps(pad.as_ptr());
            _mm256_storeu_ps(pad.as_mut_ptr(), _mm256_min_ps(_mm256_max_ps(x, lo), hi));
            xs[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn soft_threshold_f32_imp(xs: &mut [f32], tau: f32) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let t = _mm256_set1_ps(tau);
        let z = _mm256_setzero_ps();
        let sign = _mm256_set1_ps(-0.0);
        let n = xs.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(xs.as_ptr().add(i));
            let a = _mm256_max_ps(_mm256_sub_ps(x, t), z);
            let b = _mm256_max_ps(_mm256_sub_ps(_mm256_xor_ps(x, sign), t), z);
            _mm256_storeu_ps(xs.as_mut_ptr().add(i), _mm256_sub_ps(a, b));
            i += 8;
        }
        if i < n {
            let mut pad = [0.0f32; 8];
            pad[..n - i].copy_from_slice(&xs[i..]);
            let x = _mm256_loadu_ps(pad.as_ptr());
            let a = _mm256_max_ps(_mm256_sub_ps(x, t), z);
            let b = _mm256_max_ps(_mm256_sub_ps(_mm256_xor_ps(x, sign), t), z);
            _mm256_storeu_ps(pad.as_mut_ptr(), _mm256_sub_ps(a, b));
            xs[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn scale_f32_imp(xs: &mut [f32], s: f32) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let sv = _mm256_set1_ps(s);
        let n = xs.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(xs.as_ptr().add(i));
            _mm256_storeu_ps(xs.as_mut_ptr().add(i), _mm256_mul_ps(x, sv));
            i += 8;
        }
        if i < n {
            let mut pad = [0.0f32; 8];
            pad[..n - i].copy_from_slice(&xs[i..]);
            let x = _mm256_loadu_ps(pad.as_ptr());
            _mm256_storeu_ps(pad.as_mut_ptr(), _mm256_mul_ps(x, sv));
            xs[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports AVX2 (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "avx2")]
unsafe fn axpy_f32_imp(acc: &mut [f32], a: f32, row: &[f32]) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        debug_assert_eq!(acc.len(), row.len());
        let av = _mm256_set1_ps(a);
        let n = acc.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(acc.as_ptr().add(i));
            let r = _mm256_loadu_ps(row.as_ptr().add(i));
            _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(d, _mm256_mul_ps(av, r)));
            i += 8;
        }
        if i < n {
            let mut pad_d = [0.0f32; 8];
            let mut pad_r = [0.0f32; 8];
            pad_d[..n - i].copy_from_slice(&acc[i..]);
            pad_r[..n - i].copy_from_slice(&row[i..]);
            let d = _mm256_loadu_ps(pad_d.as_ptr());
            let r = _mm256_loadu_ps(pad_r.as_ptr());
            _mm256_storeu_ps(pad_d.as_mut_ptr(), _mm256_add_ps(d, _mm256_mul_ps(av, r)));
            acc[i..].copy_from_slice(&pad_d[..n - i]);
        }
    }
}

// ------------------------------------------------- safe public wrappers

/// Safe entry: `max_i |x_i|` with AVX2 (panics without AVX2 support).
pub fn colmax_f64(xs: &[f64]) -> f64 {
    assert_avx2!();
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { colmax_f64_imp(xs) }
}

/// Safe entry: `max_i |x_i|` with AVX2 (panics without AVX2 support).
pub fn colmax_f32(xs: &[f32]) -> f32 {
    assert_avx2!();
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { colmax_f32_imp(xs) }
}

/// Safe entry: lane-decomposed `Σ|x_i|` with AVX2.
pub fn sum_abs_f64(xs: &[f64]) -> f64 {
    assert_avx2!();
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { sum_abs_f64_imp(xs) }
}

/// Safe entry: lane-decomposed `Σ|x_i|` with AVX2.
pub fn sum_abs_f32(xs: &[f32]) -> f32 {
    assert_avx2!();
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { sum_abs_f32_imp(xs) }
}

/// Safe entry: lane-decomposed `Σx_i²` with AVX2.
pub fn sumsq_f64(xs: &[f64]) -> f64 {
    assert_avx2!();
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { sumsq_f64_imp(xs) }
}

/// Safe entry: lane-decomposed `Σx_i²` with AVX2.
pub fn sumsq_f32(xs: &[f32]) -> f32 {
    assert_avx2!();
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { sumsq_f32_imp(xs) }
}

/// Safe entry: `dst = clamp(src, -c, c)` with AVX2.
pub fn clip_into_f64(src: &[f64], c: f64, dst: &mut [f64]) {
    assert_avx2!();
    assert_eq!(src.len(), dst.len(), "clip_into: length mismatch");
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { clip_into_f64_imp(src, c, dst) }
}

/// Safe entry: `dst = clamp(src, -c, c)` with AVX2.
pub fn clip_into_f32(src: &[f32], c: f32, dst: &mut [f32]) {
    assert_avx2!();
    assert_eq!(src.len(), dst.len(), "clip_into: length mismatch");
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { clip_into_f32_imp(src, c, dst) }
}

/// Safe entry: in-place `clamp(x, -c, c)` with AVX2.
pub fn clip_inplace_f64(xs: &mut [f64], c: f64) {
    assert_avx2!();
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { clip_inplace_f64_imp(xs, c) }
}

/// Safe entry: in-place `clamp(x, -c, c)` with AVX2.
pub fn clip_inplace_f32(xs: &mut [f32], c: f32) {
    assert_avx2!();
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { clip_inplace_f32_imp(xs, c) }
}

/// Safe entry: in-place `(x-τ)₊ − (-x-τ)₊` with AVX2.
pub fn soft_threshold_f64(xs: &mut [f64], tau: f64) {
    assert_avx2!();
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { soft_threshold_f64_imp(xs, tau) }
}

/// Safe entry: in-place `(x-τ)₊ − (-x-τ)₊` with AVX2.
pub fn soft_threshold_f32(xs: &mut [f32], tau: f32) {
    assert_avx2!();
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { soft_threshold_f32_imp(xs, tau) }
}

/// Safe entry: in-place `x·s` with AVX2.
pub fn scale_f64(xs: &mut [f64], s: f64) {
    assert_avx2!();
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { scale_f64_imp(xs, s) }
}

/// Safe entry: in-place `x·s` with AVX2.
pub fn scale_f32(xs: &mut [f32], s: f32) {
    assert_avx2!();
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { scale_f32_imp(xs, s) }
}

/// Safe entry: `acc += a·row` with AVX2 (no FMA — see module docs).
pub fn axpy_f64(acc: &mut [f64], a: f64, row: &[f64]) {
    assert_avx2!();
    assert_eq!(acc.len(), row.len(), "axpy: length mismatch");
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { axpy_f64_imp(acc, a, row) }
}

/// Safe entry: `acc += a·row` with AVX2 (no FMA — see module docs).
pub fn axpy_f32(acc: &mut [f32], a: f32, row: &[f32]) {
    assert_avx2!();
    assert_eq!(acc.len(), row.len(), "axpy: length mismatch");
    // SAFETY: `assert_avx2!` above just proved AVX2 support at runtime.
    unsafe { axpy_f32_imp(acc, a, row) }
}
