//! Reusable projection scratch — the allocation side of the zero-alloc
//! kernel story.
//!
//! A [`Workspace`] owns every intermediate buffer a bi-level projection
//! needs: the column-norm vector, the per-column threshold vector, and the
//! inner Condat solver's candidate/waste lists. All of them are `clear()`ed
//! and refilled on each call, so their capacity is retained across calls
//! and a steady-state projection (same shape, any contents) performs
//! **zero heap allocations** — see `bilevel_l1inf_into` in
//! `projection/bilevel` and the `kernels_alloc` integration test that
//! proves it with a counting global allocator.
//!
//! The serve engine keeps one workspace per worker thread (a per-shard
//! pool, since workers are pinned to shards), so sustained traffic only
//! allocates the response payloads.

use crate::scalar::Scalar;

/// Scratch for Condat's ℓ1 threshold (`projection::l1::condat`): the
/// candidate active set `v` and the once-revisited `waste` list. Both are
/// bounded by the input length, so `threshold_with` reserves them to that
/// worst case up front and never reallocates mid-scan.
#[derive(Clone, Debug, Default)]
pub struct CondatScratch<T: Scalar> {
    pub v: Vec<T>,
    pub waste: Vec<T>,
}

impl<T: Scalar> CondatScratch<T> {
    pub fn new() -> Self {
        Self { v: Vec::new(), waste: Vec::new() }
    }
}

/// Reusable buffers for the workspace-based (`*_into`) projection entry
/// points. Create once, feed to every call; shapes may vary between calls
/// (buffers grow monotonically to the largest column count seen).
#[derive(Clone, Debug, Default)]
pub struct Workspace<T: Scalar> {
    /// Stage-1 column aggregates (`‖y_j‖∞` for `BP¹,∞`).
    pub norms: Vec<T>,
    /// Inner-stage solution `û` — the per-column clip thresholds. After a
    /// `bilevel_l1inf_into` call this holds the same vector
    /// `BilevelResult::thresholds` would.
    pub thresholds: Vec<T>,
    /// Inner Condat solver scratch.
    pub condat: CondatScratch<T>,
}

impl<T: Scalar> Workspace<T> {
    pub fn new() -> Self {
        Self { norms: Vec::new(), thresholds: Vec::new(), condat: CondatScratch::new() }
    }

    /// Pre-size every buffer for matrices with `cols` columns, so even the
    /// first projection through this workspace is allocation-free.
    pub fn for_cols(cols: usize) -> Self {
        Self {
            norms: Vec::with_capacity(cols),
            thresholds: Vec::with_capacity(cols),
            condat: CondatScratch {
                v: Vec::with_capacity(cols),
                waste: Vec::with_capacity(cols),
            },
        }
    }

    /// The per-column thresholds of the last `*_into` projection.
    pub fn thresholds(&self) -> &[T] {
        &self.thresholds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_cols_preallocates() {
        let ws = Workspace::<f64>::for_cols(32);
        assert!(ws.norms.capacity() >= 32);
        assert!(ws.thresholds.capacity() >= 32);
        assert!(ws.condat.v.capacity() >= 32);
        assert!(ws.condat.waste.capacity() >= 32);
        assert!(ws.thresholds().is_empty());
    }

    #[test]
    fn default_is_empty() {
        let ws = Workspace::<f32>::new();
        assert_eq!(ws.norms.capacity(), 0);
        let cs = CondatScratch::<f32>::new();
        assert_eq!(cs.v.capacity(), 0);
    }
}
