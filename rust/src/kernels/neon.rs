//! Explicit NEON kernels (`core::arch::aarch64`, stable Rust).
//!
//! Mirror of [`super::avx2`] for aarch64: 128-bit registers, so `f64`
//! runs at stride 2 (`float64x2_t`) and `f32` at stride 4 (`float32x4_t`).
//! Reductions chunk by [`LANES`] with one accumulator register per pair
//! (`f64`: four accumulators covering lanes 0..8; `f32`: two), matching
//! the portable lane decomposition add-for-add, and finish with the same
//! [`combine8`](super::combine8) tree, so they are bit-exact against the
//! portable kernels.
//!
//! One semantic difference from AVX2 worth pinning: `FMAX`/`FMIN` treat
//! `-0.0 < +0.0`, so clipping at a threshold of exactly `0` preserves the
//! *direction* of the input sign (negative inputs clamp to `-0.0`,
//! non-negative to `+0.0`), where AVX2 always emits `+0.0`. Both are
//! covered by the documented zero-sign delta for `threshold == 0`;
//! thresholds > 0 are bit-exact. Remainders use the same padded-tail
//! technique as the AVX2 module (copy tail into a stack pad, run the
//! identical vector op, write back valid lanes only).

use core::arch::aarch64::*;

use super::dispatch::{Isa, KernelOps};
use super::{combine8, LANES};

#[inline]
fn have_neon() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

macro_rules! assert_neon {
    () => {
        assert!(have_neon(), "NEON kernel called on a CPU without NEON");
    };
}

/// The dispatch table for this ISA (see [`super::dispatch`]).
pub static OPS: KernelOps = KernelOps {
    isa: Isa::Neon,
    colmax_f32,
    colmax_f64,
    sum_abs_f32,
    sum_abs_f64,
    sumsq_f32,
    sumsq_f64,
    clip_into_f32,
    clip_into_f64,
    clip_inplace_f32,
    clip_inplace_f64,
    soft_threshold_f32,
    soft_threshold_f64,
    scale_f32,
    scale_f64,
    axpy_f32,
    axpy_f64,
};

// ------------------------------------------------------------------- f64

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn colmax_f64_imp(xs: &[f64]) -> f64 {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let mut acc = [vdupq_n_f64(0.0); 4];
        let mut chunks = xs.chunks_exact(LANES);
        for ch in chunks.by_ref() {
            for (k, a) in acc.iter_mut().enumerate() {
                *a = vmaxq_f64(*a, vabsq_f64(vld1q_f64(ch.as_ptr().add(2 * k))));
            }
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut pad = [0.0f64; LANES];
            pad[..rem.len()].copy_from_slice(rem);
            for (k, a) in acc.iter_mut().enumerate() {
                *a = vmaxq_f64(*a, vabsq_f64(vld1q_f64(pad.as_ptr().add(2 * k))));
            }
        }
        let mut lanes = [0.0f64; LANES];
        for (k, a) in acc.iter().enumerate() {
            vst1q_f64(lanes.as_mut_ptr().add(2 * k), *a);
        }
        lanes.iter().fold(0.0f64, |m, &x| m.max(x))
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn sum_abs_f64_imp(xs: &[f64]) -> f64 {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let mut acc = [vdupq_n_f64(0.0); 4];
        let mut chunks = xs.chunks_exact(LANES);
        for ch in chunks.by_ref() {
            for (k, a) in acc.iter_mut().enumerate() {
                *a = vaddq_f64(*a, vabsq_f64(vld1q_f64(ch.as_ptr().add(2 * k))));
            }
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut pad = [0.0f64; LANES];
            pad[..rem.len()].copy_from_slice(rem);
            for (k, a) in acc.iter_mut().enumerate() {
                *a = vaddq_f64(*a, vabsq_f64(vld1q_f64(pad.as_ptr().add(2 * k))));
            }
        }
        let mut lanes = [0.0f64; LANES];
        for (k, a) in acc.iter().enumerate() {
            vst1q_f64(lanes.as_mut_ptr().add(2 * k), *a);
        }
        combine8(&lanes)
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn sumsq_f64_imp(xs: &[f64]) -> f64 {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let mut acc = [vdupq_n_f64(0.0); 4];
        let mut chunks = xs.chunks_exact(LANES);
        for ch in chunks.by_ref() {
            for (k, a) in acc.iter_mut().enumerate() {
                let x = vld1q_f64(ch.as_ptr().add(2 * k));
                *a = vaddq_f64(*a, vmulq_f64(x, x));
            }
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut pad = [0.0f64; LANES];
            pad[..rem.len()].copy_from_slice(rem);
            for (k, a) in acc.iter_mut().enumerate() {
                let x = vld1q_f64(pad.as_ptr().add(2 * k));
                *a = vaddq_f64(*a, vmulq_f64(x, x));
            }
        }
        let mut lanes = [0.0f64; LANES];
        for (k, a) in acc.iter().enumerate() {
            vst1q_f64(lanes.as_mut_ptr().add(2 * k), *a);
        }
        combine8(&lanes)
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn clip_into_f64_imp(src: &[f64], c: f64, dst: &mut [f64]) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        debug_assert_eq!(src.len(), dst.len());
        let lo = vdupq_n_f64(-c);
        let hi = vdupq_n_f64(c);
        let n = src.len();
        let mut i = 0usize;
        while i + 2 <= n {
            let x = vld1q_f64(src.as_ptr().add(i));
            vst1q_f64(dst.as_mut_ptr().add(i), vminq_f64(vmaxq_f64(x, lo), hi));
            i += 2;
        }
        if i < n {
            let mut pad = [0.0f64; 2];
            pad[..n - i].copy_from_slice(&src[i..]);
            let x = vld1q_f64(pad.as_ptr());
            vst1q_f64(pad.as_mut_ptr(), vminq_f64(vmaxq_f64(x, lo), hi));
            dst[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn clip_inplace_f64_imp(xs: &mut [f64], c: f64) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let lo = vdupq_n_f64(-c);
        let hi = vdupq_n_f64(c);
        let n = xs.len();
        let mut i = 0usize;
        while i + 2 <= n {
            let x = vld1q_f64(xs.as_ptr().add(i));
            vst1q_f64(xs.as_mut_ptr().add(i), vminq_f64(vmaxq_f64(x, lo), hi));
            i += 2;
        }
        if i < n {
            let mut pad = [0.0f64; 2];
            pad[..n - i].copy_from_slice(&xs[i..]);
            let x = vld1q_f64(pad.as_ptr());
            vst1q_f64(pad.as_mut_ptr(), vminq_f64(vmaxq_f64(x, lo), hi));
            xs[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn soft_threshold_f64_imp(xs: &mut [f64], tau: f64) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let t = vdupq_n_f64(tau);
        let z = vdupq_n_f64(0.0);
        let n = xs.len();
        let mut i = 0usize;
        while i + 2 <= n {
            let x = vld1q_f64(xs.as_ptr().add(i));
            let a = vmaxq_f64(vsubq_f64(x, t), z);
            let b = vmaxq_f64(vsubq_f64(vnegq_f64(x), t), z);
            vst1q_f64(xs.as_mut_ptr().add(i), vsubq_f64(a, b));
            i += 2;
        }
        if i < n {
            let mut pad = [0.0f64; 2];
            pad[..n - i].copy_from_slice(&xs[i..]);
            let x = vld1q_f64(pad.as_ptr());
            let a = vmaxq_f64(vsubq_f64(x, t), z);
            let b = vmaxq_f64(vsubq_f64(vnegq_f64(x), t), z);
            vst1q_f64(pad.as_mut_ptr(), vsubq_f64(a, b));
            xs[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn scale_f64_imp(xs: &mut [f64], s: f64) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let sv = vdupq_n_f64(s);
        let n = xs.len();
        let mut i = 0usize;
        while i + 2 <= n {
            let x = vld1q_f64(xs.as_ptr().add(i));
            vst1q_f64(xs.as_mut_ptr().add(i), vmulq_f64(x, sv));
            i += 2;
        }
        if i < n {
            let mut pad = [0.0f64; 2];
            pad[..n - i].copy_from_slice(&xs[i..]);
            let x = vld1q_f64(pad.as_ptr());
            vst1q_f64(pad.as_mut_ptr(), vmulq_f64(x, sv));
            xs[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn axpy_f64_imp(acc: &mut [f64], a: f64, row: &[f64]) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        debug_assert_eq!(acc.len(), row.len());
        let av = vdupq_n_f64(a);
        let n = acc.len();
        let mut i = 0usize;
        while i + 2 <= n {
            let d = vld1q_f64(acc.as_ptr().add(i));
            let r = vld1q_f64(row.as_ptr().add(i));
            vst1q_f64(acc.as_mut_ptr().add(i), vaddq_f64(d, vmulq_f64(av, r)));
            i += 2;
        }
        if i < n {
            let mut pad_d = [0.0f64; 2];
            let mut pad_r = [0.0f64; 2];
            pad_d[..n - i].copy_from_slice(&acc[i..]);
            pad_r[..n - i].copy_from_slice(&row[i..]);
            let d = vld1q_f64(pad_d.as_ptr());
            let r = vld1q_f64(pad_r.as_ptr());
            vst1q_f64(pad_d.as_mut_ptr(), vaddq_f64(d, vmulq_f64(av, r)));
            acc[i..].copy_from_slice(&pad_d[..n - i]);
        }
    }
}

// ------------------------------------------------------------------- f32

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn colmax_f32_imp(xs: &[f32]) -> f32 {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let mut acc = [vdupq_n_f32(0.0); 2];
        let mut chunks = xs.chunks_exact(LANES);
        for ch in chunks.by_ref() {
            for (k, a) in acc.iter_mut().enumerate() {
                *a = vmaxq_f32(*a, vabsq_f32(vld1q_f32(ch.as_ptr().add(4 * k))));
            }
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut pad = [0.0f32; LANES];
            pad[..rem.len()].copy_from_slice(rem);
            for (k, a) in acc.iter_mut().enumerate() {
                *a = vmaxq_f32(*a, vabsq_f32(vld1q_f32(pad.as_ptr().add(4 * k))));
            }
        }
        let mut lanes = [0.0f32; LANES];
        for (k, a) in acc.iter().enumerate() {
            vst1q_f32(lanes.as_mut_ptr().add(4 * k), *a);
        }
        lanes.iter().fold(0.0f32, |m, &x| m.max(x))
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn sum_abs_f32_imp(xs: &[f32]) -> f32 {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let mut acc = [vdupq_n_f32(0.0); 2];
        let mut chunks = xs.chunks_exact(LANES);
        for ch in chunks.by_ref() {
            for (k, a) in acc.iter_mut().enumerate() {
                *a = vaddq_f32(*a, vabsq_f32(vld1q_f32(ch.as_ptr().add(4 * k))));
            }
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut pad = [0.0f32; LANES];
            pad[..rem.len()].copy_from_slice(rem);
            for (k, a) in acc.iter_mut().enumerate() {
                *a = vaddq_f32(*a, vabsq_f32(vld1q_f32(pad.as_ptr().add(4 * k))));
            }
        }
        let mut lanes = [0.0f32; LANES];
        for (k, a) in acc.iter().enumerate() {
            vst1q_f32(lanes.as_mut_ptr().add(4 * k), *a);
        }
        combine8(&lanes)
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn sumsq_f32_imp(xs: &[f32]) -> f32 {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let mut acc = [vdupq_n_f32(0.0); 2];
        let mut chunks = xs.chunks_exact(LANES);
        for ch in chunks.by_ref() {
            for (k, a) in acc.iter_mut().enumerate() {
                let x = vld1q_f32(ch.as_ptr().add(4 * k));
                *a = vaddq_f32(*a, vmulq_f32(x, x));
            }
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut pad = [0.0f32; LANES];
            pad[..rem.len()].copy_from_slice(rem);
            for (k, a) in acc.iter_mut().enumerate() {
                let x = vld1q_f32(pad.as_ptr().add(4 * k));
                *a = vaddq_f32(*a, vmulq_f32(x, x));
            }
        }
        let mut lanes = [0.0f32; LANES];
        for (k, a) in acc.iter().enumerate() {
            vst1q_f32(lanes.as_mut_ptr().add(4 * k), *a);
        }
        combine8(&lanes)
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn clip_into_f32_imp(src: &[f32], c: f32, dst: &mut [f32]) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        debug_assert_eq!(src.len(), dst.len());
        let lo = vdupq_n_f32(-c);
        let hi = vdupq_n_f32(c);
        let n = src.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let x = vld1q_f32(src.as_ptr().add(i));
            vst1q_f32(dst.as_mut_ptr().add(i), vminq_f32(vmaxq_f32(x, lo), hi));
            i += 4;
        }
        if i < n {
            let mut pad = [0.0f32; 4];
            pad[..n - i].copy_from_slice(&src[i..]);
            let x = vld1q_f32(pad.as_ptr());
            vst1q_f32(pad.as_mut_ptr(), vminq_f32(vmaxq_f32(x, lo), hi));
            dst[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn clip_inplace_f32_imp(xs: &mut [f32], c: f32) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let lo = vdupq_n_f32(-c);
        let hi = vdupq_n_f32(c);
        let n = xs.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let x = vld1q_f32(xs.as_ptr().add(i));
            vst1q_f32(xs.as_mut_ptr().add(i), vminq_f32(vmaxq_f32(x, lo), hi));
            i += 4;
        }
        if i < n {
            let mut pad = [0.0f32; 4];
            pad[..n - i].copy_from_slice(&xs[i..]);
            let x = vld1q_f32(pad.as_ptr());
            vst1q_f32(pad.as_mut_ptr(), vminq_f32(vmaxq_f32(x, lo), hi));
            xs[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn soft_threshold_f32_imp(xs: &mut [f32], tau: f32) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let t = vdupq_n_f32(tau);
        let z = vdupq_n_f32(0.0);
        let n = xs.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let x = vld1q_f32(xs.as_ptr().add(i));
            let a = vmaxq_f32(vsubq_f32(x, t), z);
            let b = vmaxq_f32(vsubq_f32(vnegq_f32(x), t), z);
            vst1q_f32(xs.as_mut_ptr().add(i), vsubq_f32(a, b));
            i += 4;
        }
        if i < n {
            let mut pad = [0.0f32; 4];
            pad[..n - i].copy_from_slice(&xs[i..]);
            let x = vld1q_f32(pad.as_ptr());
            let a = vmaxq_f32(vsubq_f32(x, t), z);
            let b = vmaxq_f32(vsubq_f32(vnegq_f32(x), t), z);
            vst1q_f32(pad.as_mut_ptr(), vsubq_f32(a, b));
            xs[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn scale_f32_imp(xs: &mut [f32], s: f32) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        let sv = vdupq_n_f32(s);
        let n = xs.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let x = vld1q_f32(xs.as_ptr().add(i));
            vst1q_f32(xs.as_mut_ptr().add(i), vmulq_f32(x, sv));
            i += 4;
        }
        if i < n {
            let mut pad = [0.0f32; 4];
            pad[..n - i].copy_from_slice(&xs[i..]);
            let x = vld1q_f32(pad.as_ptr());
            vst1q_f32(pad.as_mut_ptr(), vmulq_f32(x, sv));
            xs[i..].copy_from_slice(&pad[..n - i]);
        }
    }
}

/// # Safety
///
/// Caller must ensure the CPU supports NEON (the safe wrappers below
/// assert it, and the dispatch table is installed only after runtime
/// feature detection).
#[target_feature(enable = "neon")]
unsafe fn axpy_f32_imp(acc: &mut [f32], a: f32, row: &[f32]) {
    // SAFETY: `#[target_feature]` matches the caller-guaranteed CPU
    // feature, and every pointer dereference stays in bounds of the
    // borrowed slices: full chunks are exact multiples of the vector
    // width, and tails go through a fixed-size stack pad.
    unsafe {
        debug_assert_eq!(acc.len(), row.len());
        let av = vdupq_n_f32(a);
        let n = acc.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let d = vld1q_f32(acc.as_ptr().add(i));
            let r = vld1q_f32(row.as_ptr().add(i));
            vst1q_f32(acc.as_mut_ptr().add(i), vaddq_f32(d, vmulq_f32(av, r)));
            i += 4;
        }
        if i < n {
            let mut pad_d = [0.0f32; 4];
            let mut pad_r = [0.0f32; 4];
            pad_d[..n - i].copy_from_slice(&acc[i..]);
            pad_r[..n - i].copy_from_slice(&row[i..]);
            let d = vld1q_f32(pad_d.as_ptr());
            let r = vld1q_f32(pad_r.as_ptr());
            vst1q_f32(pad_d.as_mut_ptr(), vaddq_f32(d, vmulq_f32(av, r)));
            acc[i..].copy_from_slice(&pad_d[..n - i]);
        }
    }
}

// ------------------------------------------------- safe public wrappers

/// Safe entry: `max_i |x_i|` with NEON (panics without NEON support).
pub fn colmax_f64(xs: &[f64]) -> f64 {
    assert_neon!();
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { colmax_f64_imp(xs) }
}

/// Safe entry: `max_i |x_i|` with NEON (panics without NEON support).
pub fn colmax_f32(xs: &[f32]) -> f32 {
    assert_neon!();
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { colmax_f32_imp(xs) }
}

/// Safe entry: lane-decomposed `Σ|x_i|` with NEON.
pub fn sum_abs_f64(xs: &[f64]) -> f64 {
    assert_neon!();
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { sum_abs_f64_imp(xs) }
}

/// Safe entry: lane-decomposed `Σ|x_i|` with NEON.
pub fn sum_abs_f32(xs: &[f32]) -> f32 {
    assert_neon!();
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { sum_abs_f32_imp(xs) }
}

/// Safe entry: lane-decomposed `Σx_i²` with NEON.
pub fn sumsq_f64(xs: &[f64]) -> f64 {
    assert_neon!();
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { sumsq_f64_imp(xs) }
}

/// Safe entry: lane-decomposed `Σx_i²` with NEON.
pub fn sumsq_f32(xs: &[f32]) -> f32 {
    assert_neon!();
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { sumsq_f32_imp(xs) }
}

/// Safe entry: `dst = clamp(src, -c, c)` with NEON.
pub fn clip_into_f64(src: &[f64], c: f64, dst: &mut [f64]) {
    assert_neon!();
    assert_eq!(src.len(), dst.len(), "clip_into: length mismatch");
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { clip_into_f64_imp(src, c, dst) }
}

/// Safe entry: `dst = clamp(src, -c, c)` with NEON.
pub fn clip_into_f32(src: &[f32], c: f32, dst: &mut [f32]) {
    assert_neon!();
    assert_eq!(src.len(), dst.len(), "clip_into: length mismatch");
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { clip_into_f32_imp(src, c, dst) }
}

/// Safe entry: in-place `clamp(x, -c, c)` with NEON.
pub fn clip_inplace_f64(xs: &mut [f64], c: f64) {
    assert_neon!();
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { clip_inplace_f64_imp(xs, c) }
}

/// Safe entry: in-place `clamp(x, -c, c)` with NEON.
pub fn clip_inplace_f32(xs: &mut [f32], c: f32) {
    assert_neon!();
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { clip_inplace_f32_imp(xs, c) }
}

/// Safe entry: in-place `(x-τ)₊ − (-x-τ)₊` with NEON.
pub fn soft_threshold_f64(xs: &mut [f64], tau: f64) {
    assert_neon!();
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { soft_threshold_f64_imp(xs, tau) }
}

/// Safe entry: in-place `(x-τ)₊ − (-x-τ)₊` with NEON.
pub fn soft_threshold_f32(xs: &mut [f32], tau: f32) {
    assert_neon!();
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { soft_threshold_f32_imp(xs, tau) }
}

/// Safe entry: in-place `x·s` with NEON.
pub fn scale_f64(xs: &mut [f64], s: f64) {
    assert_neon!();
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { scale_f64_imp(xs, s) }
}

/// Safe entry: in-place `x·s` with NEON.
pub fn scale_f32(xs: &mut [f32], s: f32) {
    assert_neon!();
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { scale_f32_imp(xs, s) }
}

/// Safe entry: `acc += a·row` with NEON (no FMA — see module docs).
pub fn axpy_f64(acc: &mut [f64], a: f64, row: &[f64]) {
    assert_neon!();
    assert_eq!(acc.len(), row.len(), "axpy: length mismatch");
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { axpy_f64_imp(acc, a, row) }
}

/// Safe entry: `acc += a·row` with NEON (no FMA — see module docs).
pub fn axpy_f32(acc: &mut [f32], a: f32, row: &[f32]) {
    assert_neon!();
    assert_eq!(acc.len(), row.len(), "axpy: length mismatch");
    // SAFETY: `assert_neon!` above just proved NEON support at runtime.
    unsafe { axpy_f32_imp(acc, a, row) }
}
