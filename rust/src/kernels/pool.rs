//! Persistent parking worker pool for the column-parallel kernel stages.
//!
//! The seed's `bilevel_l1inf_parallel` spawned scoped OS threads on every
//! call; at ~20–50 µs per spawn that overhead forced the
//! sequential/parallel crossover up to 65 536 elements. This pool spawns
//! its workers **once** (first use), parks them on a condvar between jobs,
//! and hands each job out as `parts` independently-claimable chunks — a
//! dispatch costs one mutex/condvar wake (~1–5 µs), which moves the
//! crossover down an order of magnitude (see `ParallelPolicy::min_elems`
//! and EXPERIMENTS.md §Perf).
//!
//! Design:
//!
//! * [`KernelPool::run`]`(parts, f)` publishes `f` and blocks until every
//!   part index in `0..parts` has been executed exactly once. The calling
//!   thread participates in the work, so a pool of `N` workers yields
//!   `N + 1`-way parallelism and a zero-worker pool degrades to an inline
//!   loop.
//! * Submissions are serialized by a try-lock: if another thread is
//!   already running a job, `run` executes its own parts inline instead of
//!   queueing — graceful degradation under concurrent callers (e.g. many
//!   serve workers projecting large matrices at once), never convoying.
//! * The closure is shared with workers as a type-erased raw pointer; the
//!   completion barrier (`completed == parts`) makes that sound: `run`
//!   cannot return — and the closure cannot be dropped — while any claimed
//!   part is still executing, and workers only dereference the pointer for
//!   parts they claimed.
//!
//! [`SendPtr`] is the companion utility callers use to hand *disjoint*
//! mutable regions of one buffer to different parts (each part derives its
//! own chunk from the part index, so the regions never alias).

use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::sync::{lock_unpoisoned, wait_unpoisoned};

/// Copyable raw pointer that may cross thread boundaries. Used by pool
/// callers to give each part index access to its own disjoint slice of a
/// shared output buffer.
///
/// Safety contract (on the *user*, not this type): parts must derive
/// non-overlapping regions from their part index, and the pointee must
/// outlive the `run` call (guaranteed when it borrows from the caller's
/// stack, since `run` blocks until completion).
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);

// SAFETY: a raw pointer is thread-neutral by itself; what makes
// cross-thread use sound is the safety contract documented on the type
// (disjoint regions per part, pointee outlives the blocking `run` call).
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: same argument as Send — the type-level contract above.
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    #[inline(always)]
    pub fn get(self) -> *mut T {
        self.0
    }
}

/// Type-erased job: a borrowed `Fn(part_index)` with its lifetime hidden.
/// Only dereferenced between publication and the completion barrier.
type Job = *const (dyn Fn(usize) + Sync + 'static);

struct SharedJob(Job);

// SAFETY: the pointer is only dereferenced while the submitting `run`
// call keeps the closure alive (see module docs), and the closure itself
// is `Sync`, so shared calls from worker threads are sound.
unsafe impl Send for SharedJob {}

struct PoolState {
    /// Bumped once per published job; workers use it to tell jobs apart.
    epoch: u64,
    job: Option<SharedJob>,
    parts: usize,
    /// Next unclaimed part index of the current job.
    next_part: usize,
    /// Parts whose closure call has finished (returned or panicked).
    completed: usize,
    /// Worker threads currently inside a closure call. The unwind guard
    /// waits on this so the closure can never be dropped mid-call.
    active_workers: usize,
    /// A worker's closure call panicked; re-raised on the submitter.
    panicked: bool,
    shutdown: bool,
}

struct Inner {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// `run` waits here for the completion barrier.
    done_cv: Condvar,
    /// Serializes submitters (`run` falls back to inline when contended).
    submit: Mutex<()>,
}

/// A persistent pool of parked worker threads executing part-indexed jobs.
pub struct KernelPool {
    inner: Arc<Inner>,
    handles: Vec<JoinHandle<()>>,
}

impl KernelPool {
    /// Spawn a pool with `workers` parked threads. Zero workers is valid:
    /// every `run` then executes inline on the caller.
    pub fn with_workers(workers: usize) -> Self {
        let inner = Arc::new(Inner {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                parts: 0,
                next_part: 0,
                completed: 0,
                active_workers: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            submit: Mutex::new(()),
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let w = Arc::clone(&inner);
            let spawned = std::thread::Builder::new()
                .name(format!("bilevel-kernel-{i}"))
                .spawn(move || worker_loop(&w));
            match spawned {
                Ok(h) => handles.push(h),
                // A failed spawn just leaves the pool smaller; the caller
                // always participates, so jobs still complete.
                Err(_) => break,
            }
        }
        Self { inner, handles }
    }

    /// Number of parked worker threads (the caller adds one more lane).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Execute `f(0), f(1), …, f(parts-1)`, each exactly once, spread
    /// across the pool plus the calling thread. Blocks until all parts
    /// finished. Falls back to a plain inline loop when `parts < 2`, the
    /// pool has no workers, or another thread is mid-submission.
    pub fn run<F: Fn(usize) + Sync>(&self, parts: usize, f: F) {
        if parts == 0 {
            return;
        }
        if parts == 1 || self.handles.is_empty() {
            for i in 0..parts {
                f(i);
            }
            return;
        }
        let _submit_guard = match self.inner.submit.try_lock() {
            Ok(g) => g,
            // A previous job panicked out of `run` while holding the
            // submit lock; the pool state is consistent (the unwind guard
            // cleaned up), so poison is not contention — take the lock.
            Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
            Err(std::sync::TryLockError::WouldBlock) => {
                for i in 0..parts {
                    f(i);
                }
                return;
            }
        };
        let obj: &(dyn Fn(usize) + Sync) = &f;
        let raw = obj as *const (dyn Fn(usize) + Sync);
        // SAFETY: this only erases the borrow's lifetime; the completion
        // barrier (and, on the unwind path, `UnwindGuard`) keeps the
        // pointee alive for as long as workers can dereference it.
        let job = unsafe {
            std::mem::transmute::<*const (dyn Fn(usize) + Sync + '_), Job>(raw)
        };
        {
            let mut st = lock_unpoisoned(&self.inner.state);
            st.epoch = st.epoch.wrapping_add(1);
            st.job = Some(SharedJob(job));
            st.parts = parts;
            st.next_part = 0;
            st.completed = 0;
            st.panicked = false;
            self.inner.work_cv.notify_all();
        }
        // From here on the closure must outlive every worker dereference —
        // even if `f(part)` panics on *this* thread: the guard blocks the
        // unwind until no worker is inside a call and no further part can
        // be claimed.
        let guard = UnwindGuard(&self.inner);
        // Participate: claim parts exactly like a worker.
        loop {
            let part = {
                let mut st = lock_unpoisoned(&self.inner.state);
                if st.next_part >= st.parts {
                    break;
                }
                let p = st.next_part;
                st.next_part += 1;
                p
            };
            f(part);
            let mut st = lock_unpoisoned(&self.inner.state);
            st.completed += 1;
            if st.completed == st.parts {
                self.inner.done_cv.notify_all();
            }
        }
        // Completion barrier: wait out parts claimed by workers.
        let mut st = lock_unpoisoned(&self.inner.state);
        while st.completed < st.parts {
            st = wait_unpoisoned(&self.inner.done_cv, st);
        }
        let panicked = st.panicked;
        st.panicked = false;
        drop(st);
        drop(guard);
        if panicked {
            panic!("kernel pool: a worker's closure call panicked");
        }
    }
}

/// Blocks unwinding out of [`KernelPool::run`] until the published job can
/// no longer be dereferenced: further claims are cut off and every worker
/// has left its closure call. Runs on the normal path too (where it is a
/// no-op beyond clearing the job slot).
struct UnwindGuard<'a>(&'a Inner);

impl Drop for UnwindGuard<'_> {
    fn drop(&mut self) {
        let mut st = lock_unpoisoned(&self.0.state);
        // No new claims for this job.
        st.next_part = st.parts;
        while st.active_workers > 0 {
            st = wait_unpoisoned(&self.0.done_cv, st);
        }
        st.job = None;
    }
}

impl Drop for KernelPool {
    fn drop(&mut self) {
        {
            let mut st = lock_unpoisoned(&self.inner.state);
            st.shutdown = true;
            self.inner.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: &Inner) {
    let mut seen = 0u64;
    loop {
        // Park until a job from an unseen epoch is published.
        let (job, epoch) = {
            let mut st = lock_unpoisoned(&inner.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    if let Some(sj) = &st.job {
                        break (sj.0, st.epoch);
                    }
                }
                st = wait_unpoisoned(&inner.work_cv, st);
            }
        };
        seen = epoch;
        // Claim and execute parts until this job runs dry (or a newer job
        // replaces it — then our claims no longer apply).
        loop {
            let part = {
                let mut st = lock_unpoisoned(&inner.state);
                if st.epoch != epoch || st.next_part >= st.parts {
                    break;
                }
                let p = st.next_part;
                st.next_part += 1;
                // Claim and the in-flight marker are one atomic step, so
                // the submitter's unwind guard can never miss this call.
                st.active_workers += 1;
                p
            };
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // SAFETY: the part was claimed from the job of `epoch`;
                // the submitter blocks (via the completion barrier or its
                // unwind guard) until `active_workers` drops, so the
                // closure outlives this call.
                unsafe { (&*job)(part) }
            }));
            let mut st = lock_unpoisoned(&inner.state);
            st.active_workers -= 1;
            if outcome.is_err() {
                st.panicked = true;
            }
            if st.epoch == epoch {
                st.completed += 1;
                if st.completed == st.parts {
                    inner.done_cv.notify_all();
                }
            }
            if st.active_workers == 0 {
                inner.done_cv.notify_all();
            }
        }
    }
}

/// The process-wide pool used by the projection library: hardware threads
/// minus one (the submitting thread is the extra lane). Created lazily on
/// first parallel projection, parked forever after.
pub fn global() -> &'static KernelPool {
    static POOL: OnceLock<KernelPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        KernelPool::with_workers(hw.saturating_sub(1))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_every_part_exactly_once() {
        let pool = KernelPool::with_workers(3);
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "part {i}");
        }
    }

    #[test]
    fn reuse_across_many_jobs() {
        // Interpreter-speed dispatches are expensive under Miri; a handful
        // of rounds already exercises the park/wake reuse path.
        let rounds = if cfg!(miri) { 10 } else { 200 };
        let pool = KernelPool::with_workers(2);
        let total = AtomicUsize::new(0);
        for _ in 0..rounds {
            pool.run(8, |i| {
                total.fetch_add(i + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), rounds * (1 + 2 + 3 + 4 + 5 + 6 + 7 + 8));
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = KernelPool::with_workers(0);
        let hits: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn disjoint_mutable_writes_via_sendptr() {
        let pool = KernelPool::with_workers(3);
        let mut buf = vec![0usize; 1024];
        let chunk = 64;
        let parts = buf.len() / chunk;
        {
            let ptr = SendPtr(buf.as_mut_ptr());
            pool.run(parts, |t| {
                // SAFETY: each part derives its own disjoint chunk from
                // `t`, and `buf` outlives the blocking `run` call.
                let s = unsafe { std::slice::from_raw_parts_mut(ptr.get().add(t * chunk), chunk) };
                for (k, x) in s.iter_mut().enumerate() {
                    *x = t * chunk + k;
                }
            });
        }
        for (i, &x) in buf.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn concurrent_submitters_all_complete() {
        let pool = std::sync::Arc::new(KernelPool::with_workers(2));
        let mut joins = Vec::new();
        let rounds = if cfg!(miri) { 5 } else { 50 };
        for t in 0..4u64 {
            let p = std::sync::Arc::clone(&pool);
            joins.push(std::thread::spawn(move || {
                for _ in 0..rounds {
                    let sum = AtomicUsize::new(0);
                    p.run(6, |i| {
                        sum.fetch_add(i, Ordering::Relaxed);
                    });
                    assert_eq!(sum.load(Ordering::Relaxed), 15, "submitter {t}");
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn panicking_job_propagates_and_pool_survives() {
        let pool = KernelPool::with_workers(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, |i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "panic inside a part must reach the submitter");
        // The pool stays fully usable afterwards.
        let hits = AtomicUsize::new(0);
        pool.run(4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = KernelPool::with_workers(4);
        pool.run(16, |_| {});
        drop(pool); // must not hang or leak
    }

    #[test]
    fn global_pool_is_usable() {
        let hits = AtomicUsize::new(0);
        global().run(10, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }
}
