//! Zero-allocation SIMD kernel layer — the four hot loops of the bi-level
//! projections.
//!
//! Every kernel comes in **three flavours**:
//!
//! * a **scalar reference** (`*_ref`) that defines the semantics with a
//!   naive loop — the bit-identity oracle;
//! * the **portable chunked** path (`*_portable`) that processes
//!   [`LANES`] elements per inner-loop iteration over `chunks_exact`,
//!   with a scalar tail, written branch-free so LLVM's autovectorizer
//!   turns it into packed min/max/add sequences on any target; and
//! * an **explicit SIMD** path — stable-Rust `core::arch` intrinsics,
//!   AVX2 on `x86_64` ([`avx2`]) and NEON on `aarch64` ([`neon`]) —
//!   selected once per process by runtime CPU detection ([`dispatch`]).
//!
//! The unsuffixed production names (`colmax`, `clip_into`, …) dispatch:
//! they consult the cached [`dispatch::active`] table and fall through to
//! the portable body when no explicit table applies (unsupported CPU,
//! non-`f32`/`f64` scalar, or `BILEVEL_FORCE_SCALAR=1` in the
//! environment — see the [`dispatch`] docs). `active_isa()` reports which
//! path the process is on.
//!
//! All three flavours are **bit-identical** for every input the
//! projections feed them (finite floats), with one documented corner:
//!
//! * `colmax` reduces with `max` over non-negative magnitudes —
//!   order-independent, so any chunking returns the same bits;
//! * `sum_abs` / `sumsq` define their semantics as a *lane-decomposed*
//!   sum (element `i` goes to accumulator `i % LANES`, accumulators are
//!   combined by the fixed [`combine8`] tree); the reference implements
//!   exactly that order with scalar code, the chunked and explicit-SIMD
//!   paths implement it with stride-`LANES` accumulation — same additions
//!   in the same order, so **no** reassociation delta;
//! * `clip1` / `soft1` are elementwise; every path applies the identical
//!   per-element formula, and `axpy`/`scale` never use FMA contraction.
//!
//! **The documented delta:** when the clip/soft-threshold parameter is
//! *exactly* `0`, the sign of a zero output is path-dependent (AVX2
//! `vmaxpd`/`vminpd` ties resolve to the second operand ⇒ always `+0.0`;
//! NEON `FMAX`/`FMIN` order `-0.0 < +0.0` ⇒ sign-direction-preserving;
//! the scalar `f64::max`/`min` lowering leaves it unspecified). Magnitudes
//! always agree, every norm, sparsity count, and comparison in this repo
//! treats `-0.0 == +0.0`, and all production entry points route through
//! the *same* dispatched kernel, so cross-entry-point bit-identity (cache
//! replay, sparse ≡ dense, serve) is unaffected. Thresholds > 0 are
//! bit-exact everywhere. The conformance suite in
//! `tests/kernels_integration.rs` pins exactly this contract.
//!
//! The clip kernel replaces the seed's branchy
//! `signum_s() * abs().min_s(c)` with the two-instruction clamp
//! `max(x, -c).min(c)` — mathematically identical for `c ≥ 0` (it is the
//! ℓ∞-ball projection, eq. 13 of the paper) and a straight `vmaxp*` /
//! `vminp*` pair.
//!
//! [`workspace`] adds the reusable scratch that makes the steady-state
//! projection allocation-free; [`pool`] adds the persistent worker pool
//! that replaced the spawn-per-call threading (see
//! `projection/bilevel/parallel.rs` and EXPERIMENTS.md §Perf).

#[cfg(target_arch = "x86_64")]
pub mod avx2;
pub mod dispatch;
#[cfg(target_arch = "aarch64")]
pub mod neon;
pub mod pool;
pub mod workspace;

pub use dispatch::{active_isa, Isa};
pub use workspace::{CondatScratch, Workspace};

use crate::scalar::Scalar;

/// Elements per inner-loop iteration. Eight keeps two 256-bit vectors of
/// `f64` (or one of `f32`) in flight, which is enough independent
/// accumulators to hide FP latency on every target we run on.
pub const LANES: usize = 8;

/// `P^∞_c` applied to one element: `clamp(x, -c, c)` ≡ `sign(x)·min(|x|, c)`
/// for `c ≥ 0`.
#[inline(always)]
pub fn clip1<T: Scalar>(x: T, c: T) -> T {
    x.max_s(-c).min_s(c)
}

/// Soft-threshold one element: `(x-τ)₊ - (-x-τ)₊` ≡ `sign(x)·(|x|-τ)₊`,
/// without the data-dependent sign branch. Precondition: `τ ≥ 0` (the two
/// formulas diverge for negative τ; every ℓ1 threshold in this repo is
/// clamped non-negative).
#[inline(always)]
pub fn soft1<T: Scalar>(x: T, tau: T) -> T {
    debug_assert!(tau >= T::ZERO, "soft-threshold requires tau >= 0");
    (x - tau).pos() - (-x - tau).pos()
}

/// The fixed combination tree for the `LANES` partial accumulators of the
/// sum kernels. The reference, the portable chunked path, and the
/// explicit-SIMD paths all end with this exact reduction, so their
/// results match bit-for-bit.
#[inline(always)]
pub(crate) fn combine8<T: Scalar>(acc: &[T; LANES]) -> T {
    let s04 = acc[0] + acc[4];
    let s15 = acc[1] + acc[5];
    let s26 = acc[2] + acc[6];
    let s37 = acc[3] + acc[7];
    (s04 + s26) + (s15 + s37)
}

// ---------------------------------------------------------------- colmax

/// Column ∞-norm reduction: `max_i |x_i|` (0 for empty). Dispatched
/// production path.
#[inline]
pub fn colmax<T: Scalar>(xs: &[T]) -> T {
    if let Some(r) = dispatch::colmax(xs) {
        return r;
    }
    colmax_portable(xs)
}

/// Portable chunked fallback for [`colmax`].
#[inline]
pub fn colmax_portable<T: Scalar>(xs: &[T]) -> T {
    let mut acc = [T::ZERO; LANES];
    let mut it = xs.chunks_exact(LANES);
    for ch in it.by_ref() {
        for (a, &x) in acc.iter_mut().zip(ch) {
            *a = a.max_s(x.abs());
        }
    }
    let mut m = T::ZERO;
    for a in acc {
        m = m.max_s(a);
    }
    for &x in it.remainder() {
        m = m.max_s(x.abs());
    }
    m
}

/// Scalar reference for [`colmax`].
#[inline]
pub fn colmax_ref<T: Scalar>(xs: &[T]) -> T {
    xs.iter().fold(T::ZERO, |acc, &x| acc.max_s(x.abs()))
}

// --------------------------------------------------------------- sum_abs

/// Lane-decomposed `Σ|x_i|`. Dispatched production path.
#[inline]
pub fn sum_abs<T: Scalar>(xs: &[T]) -> T {
    if let Some(r) = dispatch::sum_abs(xs) {
        return r;
    }
    sum_abs_portable(xs)
}

/// Portable chunked fallback for [`sum_abs`].
#[inline]
pub fn sum_abs_portable<T: Scalar>(xs: &[T]) -> T {
    let mut acc = [T::ZERO; LANES];
    let mut it = xs.chunks_exact(LANES);
    for ch in it.by_ref() {
        for (a, &x) in acc.iter_mut().zip(ch) {
            *a += x.abs();
        }
    }
    for (a, &x) in acc.iter_mut().zip(it.remainder()) {
        *a += x.abs();
    }
    combine8(&acc)
}

/// Scalar reference for [`sum_abs`] (same lane-decomposed order).
#[inline]
pub fn sum_abs_ref<T: Scalar>(xs: &[T]) -> T {
    let mut acc = [T::ZERO; LANES];
    for (i, &x) in xs.iter().enumerate() {
        acc[i % LANES] += x.abs();
    }
    combine8(&acc)
}

// ----------------------------------------------------------------- sumsq

/// Lane-decomposed `Σ x_i²`. Dispatched production path.
#[inline]
pub fn sumsq<T: Scalar>(xs: &[T]) -> T {
    if let Some(r) = dispatch::sumsq(xs) {
        return r;
    }
    sumsq_portable(xs)
}

/// Portable chunked fallback for [`sumsq`].
#[inline]
pub fn sumsq_portable<T: Scalar>(xs: &[T]) -> T {
    let mut acc = [T::ZERO; LANES];
    let mut it = xs.chunks_exact(LANES);
    for ch in it.by_ref() {
        for (a, &x) in acc.iter_mut().zip(ch) {
            *a += x * x;
        }
    }
    for (a, &x) in acc.iter_mut().zip(it.remainder()) {
        *a += x * x;
    }
    combine8(&acc)
}

/// Scalar reference for [`sumsq`] (same lane-decomposed order).
#[inline]
pub fn sumsq_ref<T: Scalar>(xs: &[T]) -> T {
    let mut acc = [T::ZERO; LANES];
    for (i, &x) in xs.iter().enumerate() {
        acc[i % LANES] += x * x;
    }
    combine8(&acc)
}

/// `√Σx²` — the ℓ2 column aggregate of `BP¹,²`.
#[inline]
pub fn l2_norm<T: Scalar>(xs: &[T]) -> T {
    sumsq(xs).sqrt()
}

// ------------------------------------------------------------------ clip

/// Fused column clip: `dst_i = clamp(src_i, -c, c)` — a single read of the
/// source and a single write of the destination. Dispatched production
/// path.
#[inline]
pub fn clip_into<T: Scalar>(src: &[T], c: T, dst: &mut [T]) {
    assert_eq!(src.len(), dst.len(), "clip_into: length mismatch");
    if dispatch::clip_into(src, c, dst) {
        return;
    }
    clip_into_portable(src, c, dst);
}

/// Portable chunked fallback for [`clip_into`].
#[inline]
pub fn clip_into_portable<T: Scalar>(src: &[T], c: T, dst: &mut [T]) {
    assert_eq!(src.len(), dst.len(), "clip_into: length mismatch");
    let mut s_it = src.chunks_exact(LANES);
    let mut d_it = dst.chunks_exact_mut(LANES);
    for (dc, sc) in d_it.by_ref().zip(s_it.by_ref()) {
        for (d, &s) in dc.iter_mut().zip(sc) {
            *d = clip1(s, c);
        }
    }
    for (d, &s) in d_it.into_remainder().iter_mut().zip(s_it.remainder()) {
        *d = clip1(s, c);
    }
}

/// Scalar reference for [`clip_into`].
#[inline]
pub fn clip_into_ref<T: Scalar>(src: &[T], c: T, dst: &mut [T]) {
    assert_eq!(src.len(), dst.len(), "clip_into_ref: length mismatch");
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = clip1(s, c);
    }
}

/// The fused copy-or-clip over contiguous equal-length groups — **the**
/// outer stage of `BP¹,∞`: group `j` is copied verbatim when its
/// threshold clears its ∞-norm (`thresholds[j] >= norms[j]`, untouched
/// column) and clipped through [`clip_into`] otherwise.
///
/// Every consumer of the matrix form (sequential `bilevel_l1inf_into`,
/// each part of the pool-parallel stage 2) goes through this one
/// definition, and the Vec-building form ([`extend_clipped`]) applies the
/// same tie-break and element op — that single source of truth is what
/// keeps the serve cache replay bit-identical to cold execution.
#[inline]
pub fn clip_groups_into<T: Scalar>(
    src: &[T],
    group: usize,
    thresholds: &[T],
    norms: &[T],
    dst: &mut [T],
) {
    assert_eq!(src.len(), dst.len(), "clip_groups_into: length mismatch");
    debug_assert!(
        src.len() % group == 0,
        "clip_groups_into: buffer is not a whole number of groups"
    );
    for (j, (d, s)) in dst
        .chunks_exact_mut(group)
        .zip(src.chunks_exact(group))
        .enumerate()
    {
        if thresholds[j] >= norms[j] {
            d.copy_from_slice(s);
        } else {
            clip_into(s, thresholds[j], d);
        }
    }
}

/// Vec-building sibling of [`clip_groups_into`]: append one group's fused
/// copy-or-clip to `dst` (single write, no zero-fill pass). Same `>=`
/// tie-break, same per-element [`clip1`].
#[inline]
pub fn extend_clipped<T: Scalar>(dst: &mut Vec<T>, src: &[T], threshold: T, norm: T) {
    if threshold >= norm {
        dst.extend_from_slice(src);
    } else {
        // Resize-then-clip so this Vec-building form runs the *same*
        // dispatched clip kernel as `clip_groups_into` — that shared path
        // is what keeps cache replay bit-identical to cold execution on
        // every ISA.
        let start = dst.len();
        dst.resize(start + src.len(), T::ZERO);
        clip_into(src, threshold, &mut dst[start..]);
    }
}

/// In-place variant of [`clip_into`]. Dispatched production path.
#[inline]
pub fn clip_inplace<T: Scalar>(xs: &mut [T], c: T) {
    if dispatch::clip_inplace(xs, c) {
        return;
    }
    clip_inplace_portable(xs, c);
}

/// Portable chunked fallback for [`clip_inplace`].
#[inline]
pub fn clip_inplace_portable<T: Scalar>(xs: &mut [T], c: T) {
    let mut it = xs.chunks_exact_mut(LANES);
    for ch in it.by_ref() {
        for x in ch {
            *x = clip1(*x, c);
        }
    }
    for x in it.into_remainder() {
        *x = clip1(*x, c);
    }
}

// ------------------------------------------------------------------ axpy

/// Fused multiply-accumulate row update: `acc_j += a · row_j`. Dispatched
/// production path — the inner loop of the structured-sparse encoder
/// ([`crate::sparse::linalg`]): one call per (alive) weight row, `acc` is
/// the hidden-unit accumulator.
///
/// Elementwise (every `acc_j` is touched exactly once per call), so every
/// path is bit-identical to [`axpy_ref`] by construction. No `mul_add` —
/// a fused contraction would change the rounding and break the
/// sparse ≡ dense bit-identity argument in `sparse::linalg`.
#[inline]
pub fn axpy<T: Scalar>(acc: &mut [T], a: T, row: &[T]) {
    assert_eq!(acc.len(), row.len(), "axpy: length mismatch");
    if dispatch::axpy(acc, a, row) {
        return;
    }
    axpy_portable(acc, a, row);
}

/// Portable chunked fallback for [`axpy`].
#[inline]
pub fn axpy_portable<T: Scalar>(acc: &mut [T], a: T, row: &[T]) {
    assert_eq!(acc.len(), row.len(), "axpy: length mismatch");
    let mut a_it = acc.chunks_exact_mut(LANES);
    let mut r_it = row.chunks_exact(LANES);
    for (ac, rc) in a_it.by_ref().zip(r_it.by_ref()) {
        for (d, &r) in ac.iter_mut().zip(rc) {
            *d += a * r;
        }
    }
    for (d, &r) in a_it.into_remainder().iter_mut().zip(r_it.remainder()) {
        *d += a * r;
    }
}

/// Scalar reference for [`axpy`].
#[inline]
pub fn axpy_ref<T: Scalar>(acc: &mut [T], a: T, row: &[T]) {
    assert_eq!(acc.len(), row.len(), "axpy_ref: length mismatch");
    for (d, &r) in acc.iter_mut().zip(row) {
        *d += a * r;
    }
}

// -------------------------------------------------------- soft-threshold

/// ℓ1 soft-threshold in place: `x_i ← sign(x_i)·(|x_i|-τ)₊`. Dispatched
/// production path.
#[inline]
pub fn soft_threshold_inplace<T: Scalar>(xs: &mut [T], tau: T) {
    debug_assert!(tau >= T::ZERO, "soft-threshold requires tau >= 0");
    if dispatch::soft_threshold_inplace(xs, tau) {
        return;
    }
    soft_threshold_inplace_portable(xs, tau);
}

/// Portable chunked fallback for [`soft_threshold_inplace`].
#[inline]
pub fn soft_threshold_inplace_portable<T: Scalar>(xs: &mut [T], tau: T) {
    let mut it = xs.chunks_exact_mut(LANES);
    for ch in it.by_ref() {
        for x in ch {
            *x = soft1(*x, tau);
        }
    }
    for x in it.into_remainder() {
        *x = soft1(*x, tau);
    }
}

/// Scalar reference for [`soft_threshold_inplace`].
#[inline]
pub fn soft_threshold_inplace_ref<T: Scalar>(xs: &mut [T], tau: T) {
    for x in xs.iter_mut() {
        *x = soft1(*x, tau);
    }
}

// ----------------------------------------------------------------- scale

/// ℓ2 rescale in place: `x_i ← x_i · s` (the outer stage of `BP¹,²`).
/// Dispatched production path.
#[inline]
pub fn scale_inplace<T: Scalar>(xs: &mut [T], s: T) {
    if dispatch::scale_inplace(xs, s) {
        return;
    }
    scale_inplace_portable(xs, s);
}

/// Portable chunked fallback for [`scale_inplace`].
#[inline]
pub fn scale_inplace_portable<T: Scalar>(xs: &mut [T], s: T) {
    let mut it = xs.chunks_exact_mut(LANES);
    for ch in it.by_ref() {
        for x in ch {
            *x *= s;
        }
    }
    for x in it.into_remainder() {
        *x *= s;
    }
}

/// Scalar reference for [`scale_inplace`].
#[inline]
pub fn scale_inplace_ref<T: Scalar>(xs: &mut [T], s: T) {
    for x in xs.iter_mut() {
        *x *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    fn randvec(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..n).map(|_| rng.uniform(-5.0, 5.0)).collect()
    }

    /// Every length around the lane boundaries, plus empty and length 1.
    fn edge_lens() -> Vec<usize> {
        let mut lens = vec![0, 1, 2, 3];
        for k in 1..=3 {
            lens.extend([k * LANES - 1, k * LANES, k * LANES + 1]);
        }
        lens.push(257);
        lens
    }

    #[test]
    fn colmax_chunked_bit_identical_to_ref() {
        for (i, n) in edge_lens().into_iter().enumerate() {
            let v = randvec(n, 100 + i as u64);
            assert_eq!(colmax(&v).to_bits(), colmax_ref(&v).to_bits(), "n={n}");
            let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
            assert_eq!(colmax(&v32).to_bits(), colmax_ref(&v32).to_bits(), "f32 n={n}");
        }
    }

    #[test]
    fn sum_kernels_bit_identical_to_ref() {
        for (i, n) in edge_lens().into_iter().enumerate() {
            let v = randvec(n, 200 + i as u64);
            assert_eq!(sum_abs(&v).to_bits(), sum_abs_ref(&v).to_bits(), "sum_abs n={n}");
            assert_eq!(sumsq(&v).to_bits(), sumsq_ref(&v).to_bits(), "sumsq n={n}");
        }
    }

    #[test]
    fn clip_portable_bit_identical_to_ref() {
        // The portable chunked path applies the identical scalar formula
        // per element, so it matches the reference strictly — including
        // the degenerate threshold c = 0.0.
        for (i, n) in edge_lens().into_iter().enumerate() {
            let v = randvec(n, 300 + i as u64);
            for c in [0.0, 0.5, 2.0, colmax(&v)] {
                let mut a = vec![0.0; n];
                let mut b = vec![0.0; n];
                clip_into_portable(&v, c, &mut a);
                clip_into_ref(&v, c, &mut b);
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "n={n} c={c}");
                }
                let mut inplace = v.clone();
                clip_inplace_portable(&mut inplace, c);
                for (x, y) in inplace.iter().zip(a.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "inplace n={n} c={c}");
                }
            }
        }
    }

    /// Bits equal, or both zero (the documented zero-sign delta of the
    /// explicit-SIMD clip at threshold exactly 0 — see the module docs).
    fn eq_mod_zero_sign(x: f64, y: f64) -> bool {
        x.to_bits() == y.to_bits() || (x == 0.0 && y == 0.0)
    }

    #[test]
    fn clip_dispatched_matches_portable_mod_zero_sign() {
        for (i, n) in edge_lens().into_iter().enumerate() {
            let v = randvec(n, 300 + i as u64);
            for c in [0.0, 0.5, 2.0, colmax(&v)] {
                let mut a = vec![0.0; n];
                let mut b = vec![0.0; n];
                clip_into(&v, c, &mut a);
                clip_into_portable(&v, c, &mut b);
                for (x, y) in a.iter().zip(b.iter()) {
                    assert!(eq_mod_zero_sign(*x, *y), "n={n} c={c} {x} vs {y}");
                    if c > 0.0 {
                        assert_eq!(x.to_bits(), y.to_bits(), "n={n} c={c}");
                    }
                }
                let mut inplace = v.clone();
                clip_inplace(&mut inplace, c);
                for (x, y) in inplace.iter().zip(a.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "inplace n={n} c={c}");
                }
            }
        }
    }

    #[test]
    fn clip_groups_and_extend_clipped_agree() {
        let group = 7;
        let v = randvec(group * 5, 600);
        let norms: Vec<f64> = v.chunks_exact(group).map(colmax).collect();
        // Mix of untouched (threshold == norm) and clipped groups.
        let thresholds: Vec<f64> =
            norms.iter().enumerate().map(|(i, &n)| if i % 2 == 0 { n } else { n * 0.5 }).collect();
        let mut a = vec![0.0; v.len()];
        clip_groups_into(&v, group, &thresholds, &norms, &mut a);
        let mut b = Vec::with_capacity(v.len());
        for (g, chunk) in v.chunks_exact(group).enumerate() {
            extend_clipped(&mut b, chunk, thresholds[g], norms[g]);
        }
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // Untouched groups are verbatim copies.
        assert_eq!(&a[..group], &v[..group]);
    }

    #[test]
    fn clip1_matches_signum_formula() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..1000 {
            let x: f64 = rng.uniform(-10.0, 10.0);
            let c: f64 = rng.uniform(0.0, 5.0);
            let old = x.signum_s() * x.abs().min_s(c);
            assert_eq!(clip1(x, c), old, "x={x} c={c}");
        }
        // Exactly at the threshold: the clip is the identity.
        assert_eq!(clip1(2.0, 2.0), 2.0);
        assert_eq!(clip1(-2.0, 2.0), -2.0);
    }

    #[test]
    fn soft1_matches_signum_formula() {
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        for _ in 0..1000 {
            let x: f64 = rng.uniform(-10.0, 10.0);
            let tau: f64 = rng.uniform(0.0, 5.0);
            let old = x.signum_s() * (x.abs() - tau).pos();
            assert!((soft1(x, tau) - old).abs() == 0.0, "x={x} tau={tau}");
        }
    }

    #[test]
    fn soft_threshold_chunked_bit_identical_to_ref() {
        for (i, n) in edge_lens().into_iter().enumerate() {
            let v = randvec(n, 400 + i as u64);
            let mut a = v.clone();
            let mut b = v.clone();
            soft_threshold_inplace(&mut a, 0.7);
            soft_threshold_inplace_ref(&mut b, 0.7);
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn scale_chunked_bit_identical_to_ref() {
        for (i, n) in edge_lens().into_iter().enumerate() {
            let v = randvec(n, 500 + i as u64);
            let mut a = v.clone();
            let mut b = v.clone();
            scale_inplace(&mut a, 0.37);
            scale_inplace_ref(&mut b, 0.37);
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn axpy_chunked_bit_identical_to_ref() {
        for (i, n) in edge_lens().into_iter().enumerate() {
            let v = randvec(n, 700 + i as u64);
            let row = randvec(n, 800 + i as u64);
            for a in [0.0, -1.5, 0.37] {
                let mut x = v.clone();
                let mut y = v.clone();
                axpy(&mut x, a, &row);
                axpy_ref(&mut y, a, &row);
                for (p, q) in x.iter().zip(y.iter()) {
                    assert_eq!(p.to_bits(), q.to_bits(), "n={n} a={a}");
                }
            }
        }
    }

    #[test]
    fn axpy_zero_row_is_identity_from_nonnegative_zero_acc() {
        // The sparse-encode bit-identity rests on this: adding a ±0.0 term
        // never disturbs an accumulator that is +0.0 or non-zero.
        let zeros = vec![0.0f64, -0.0, 0.0, -0.0];
        let mut acc = vec![0.0f64, 0.0, 3.5, -2.0];
        let before = acc.clone();
        for a in [2.0, -2.0, 0.0] {
            axpy(&mut acc, a, &zeros);
            for (p, q) in acc.iter().zip(before.iter()) {
                assert_eq!(p.to_bits(), q.to_bits(), "a={a}");
            }
        }
    }

    #[test]
    fn empty_inputs_are_safe() {
        let v: Vec<f64> = Vec::new();
        assert_eq!(colmax(&v), 0.0);
        assert_eq!(sum_abs(&v), 0.0);
        assert_eq!(sumsq(&v), 0.0);
        let mut d: Vec<f64> = Vec::new();
        clip_into(&v, 1.0, &mut d);
        soft_threshold_inplace(&mut d, 1.0);
        scale_inplace(&mut d, 2.0);
    }

    #[test]
    fn l2_norm_matches_hypot() {
        let v = [3.0f64, -4.0];
        assert_eq!(l2_norm(&v), 5.0);
    }
}
