//! One-time runtime CPU detection and the kernel dispatch table.
//!
//! The public kernels in [`crate::kernels`] route through a process-wide
//! table of concrete `f32`/`f64` function pointers selected **once** (a
//! `OnceLock`): AVX2 on `x86_64` when `is_x86_feature_detected!("avx2")`
//! holds, NEON on `aarch64`, and `None` otherwise — in which case the
//! callers fall through to the portable lane-chunked implementations
//! (`*_portable`), which LLVM still autovectorizes.
//!
//! Setting `BILEVEL_FORCE_SCALAR` to any value other than `0`/empty pins
//! the process to the portable path regardless of what the CPU supports
//! (the detection result is cached on first use, so set it before the
//! first projection). CI runs the whole test suite once per path.
//!
//! The generic shims below bridge `T: Scalar` call sites to the concrete
//! tables with a `TypeId` check — the comparison is against a constant per
//! monomorphization, so the branch folds away and the shim compiles to a
//! direct indirect call for `f32`/`f64` and to `None`/`false` for any
//! other scalar.

use std::any::TypeId;
use std::sync::OnceLock;

use crate::scalar::Scalar;

/// Instruction set the dispatched kernels execute on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Lane-chunked portable Rust (autovectorized by LLVM).
    Portable,
    /// Explicit 256-bit `core::arch::x86_64` intrinsics.
    Avx2,
    /// Explicit 128-bit `core::arch::aarch64` intrinsics.
    Neon,
}

impl Isa {
    /// Lower-case name used in bench reports and `BENCH_*.json` metadata.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Portable => "portable",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }
}

/// Concrete kernel entry points for one ISA. Fields are plain safe `fn`
/// pointers (the per-ISA wrappers check feature support on entry), so a
/// table is a `'static` constant and dispatch is one indirect call.
pub struct KernelOps {
    pub isa: Isa,
    pub colmax_f32: fn(&[f32]) -> f32,
    pub colmax_f64: fn(&[f64]) -> f64,
    pub sum_abs_f32: fn(&[f32]) -> f32,
    pub sum_abs_f64: fn(&[f64]) -> f64,
    pub sumsq_f32: fn(&[f32]) -> f32,
    pub sumsq_f64: fn(&[f64]) -> f64,
    pub clip_into_f32: fn(&[f32], f32, &mut [f32]),
    pub clip_into_f64: fn(&[f64], f64, &mut [f64]),
    pub clip_inplace_f32: fn(&mut [f32], f32),
    pub clip_inplace_f64: fn(&mut [f64], f64),
    pub soft_threshold_f32: fn(&mut [f32], f32),
    pub soft_threshold_f64: fn(&mut [f64], f64),
    pub scale_f32: fn(&mut [f32], f32),
    pub scale_f64: fn(&mut [f64], f64),
    pub axpy_f32: fn(&mut [f32], f32, &[f32]),
    pub axpy_f64: fn(&mut [f64], f64, &[f64]),
}

static ACTIVE: OnceLock<Option<&'static KernelOps>> = OnceLock::new();

fn force_scalar() -> bool {
    matches!(std::env::var("BILEVEL_FORCE_SCALAR"), Ok(v) if !v.is_empty() && v != "0")
}

fn detect() -> Option<&'static KernelOps> {
    if force_scalar() {
        return None;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Some(&super::avx2::OPS);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Some(&super::neon::OPS);
        }
    }
    None
}

/// The cached dispatch table; `None` means the portable fallback.
#[inline]
pub(crate) fn active() -> Option<&'static KernelOps> {
    *ACTIVE.get_or_init(detect)
}

/// The ISA the process dispatched to (cached on first use). Surfaced by
/// `bilevel bench kernels` and the `BENCH_*.json` machine metadata.
pub fn active_isa() -> Isa {
    active().map(|ops| ops.isa).unwrap_or(Isa::Portable)
}

#[inline(always)]
fn is<T: 'static, U: 'static>() -> bool {
    TypeId::of::<T>() == TypeId::of::<U>()
}

/// Reinterpret `&[T]` as `&[U]`.
///
/// # Safety
/// Caller must have proved `T` and `U` are the same type (via [`is`]).
#[inline(always)]
unsafe fn cast_slice<T, U>(xs: &[T]) -> &[U] {
    // SAFETY: T == U per the caller contract, so layout, validity, and
    // provenance are untouched; the length is the original slice length.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const U, xs.len()) }
}

/// Reinterpret `&mut [T]` as `&mut [U]`.
///
/// # Safety
/// Caller must have proved `T` and `U` are the same type (via [`is`]).
#[inline(always)]
unsafe fn cast_slice_mut<T, U>(xs: &mut [T]) -> &mut [U] {
    // SAFETY: T == U per the caller contract; exclusivity carries over
    // from the `&mut` borrow this function consumes.
    unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr() as *mut U, xs.len()) }
}

/// Reinterpret a scalar `T` as `U`.
///
/// # Safety
/// Caller must have proved `T` and `U` are the same type (via [`is`]).
#[inline(always)]
unsafe fn cast_val<T: Copy + 'static, U: 'static>(v: T) -> U {
    debug_assert!(is::<T, U>());
    // SAFETY: T == U per the caller contract, so this is an identity copy
    // of a `Copy` value.
    unsafe { std::mem::transmute_copy(&v) }
}

macro_rules! reduce_shim {
    ($name:ident, $f32field:ident, $f64field:ident) => {
        /// Dispatched reduction; `None` ⇒ caller runs the portable body.
        #[inline]
        pub(crate) fn $name<T: Scalar>(xs: &[T]) -> Option<T> {
            let ops = active()?;
            if is::<T, f64>() {
                // SAFETY: the TypeId guard above proved T == f64.
                let r = (ops.$f64field)(unsafe { cast_slice::<T, f64>(xs) });
                // SAFETY: same guard, identity cast back to T.
                Some(unsafe { cast_val::<f64, T>(r) })
            } else if is::<T, f32>() {
                // SAFETY: the TypeId guard above proved T == f32.
                let r = (ops.$f32field)(unsafe { cast_slice::<T, f32>(xs) });
                // SAFETY: same guard, identity cast back to T.
                Some(unsafe { cast_val::<f32, T>(r) })
            } else {
                None
            }
        }
    };
}

reduce_shim!(colmax, colmax_f32, colmax_f64);
reduce_shim!(sum_abs, sum_abs_f32, sum_abs_f64);
reduce_shim!(sumsq, sumsq_f32, sumsq_f64);

macro_rules! inplace_shim {
    ($name:ident, $f32field:ident, $f64field:ident) => {
        /// Dispatched in-place map; `false` ⇒ caller runs the portable body.
        #[inline]
        pub(crate) fn $name<T: Scalar>(xs: &mut [T], p: T) -> bool {
            let Some(ops) = active() else {
                return false;
            };
            if is::<T, f64>() {
                // SAFETY: the TypeId guard above proved T == f64, so both
                // reinterpretations are identity casts.
                let (xs64, p64) = unsafe { (cast_slice_mut::<T, f64>(xs), cast_val::<T, f64>(p)) };
                (ops.$f64field)(xs64, p64);
                true
            } else if is::<T, f32>() {
                // SAFETY: the TypeId guard above proved T == f32, so both
                // reinterpretations are identity casts.
                let (xs32, p32) = unsafe { (cast_slice_mut::<T, f32>(xs), cast_val::<T, f32>(p)) };
                (ops.$f32field)(xs32, p32);
                true
            } else {
                false
            }
        }
    };
}

inplace_shim!(clip_inplace, clip_inplace_f32, clip_inplace_f64);
inplace_shim!(soft_threshold_inplace, soft_threshold_f32, soft_threshold_f64);
inplace_shim!(scale_inplace, scale_f32, scale_f64);

/// Dispatched `clip_into`; `false` ⇒ caller runs the portable body.
#[inline]
pub(crate) fn clip_into<T: Scalar>(src: &[T], c: T, dst: &mut [T]) -> bool {
    let Some(ops) = active() else {
        return false;
    };
    if is::<T, f64>() {
        // SAFETY: the TypeId guard above proved T == f64, so all three
        // reinterpretations are identity casts.
        let (src64, c64) = unsafe { (cast_slice::<T, f64>(src), cast_val::<T, f64>(c)) };
        // SAFETY: same guard; `dst` is an independent exclusive borrow.
        let dst64 = unsafe { cast_slice_mut::<T, f64>(dst) };
        (ops.clip_into_f64)(src64, c64, dst64);
        true
    } else if is::<T, f32>() {
        // SAFETY: the TypeId guard above proved T == f32, so all three
        // reinterpretations are identity casts.
        let (src32, c32) = unsafe { (cast_slice::<T, f32>(src), cast_val::<T, f32>(c)) };
        // SAFETY: same guard; `dst` is an independent exclusive borrow.
        let dst32 = unsafe { cast_slice_mut::<T, f32>(dst) };
        (ops.clip_into_f32)(src32, c32, dst32);
        true
    } else {
        false
    }
}

/// Dispatched `axpy`; `false` ⇒ caller runs the portable body.
#[inline]
pub(crate) fn axpy<T: Scalar>(acc: &mut [T], a: T, row: &[T]) -> bool {
    let Some(ops) = active() else {
        return false;
    };
    if is::<T, f64>() {
        // SAFETY: the TypeId guard above proved T == f64, so all three
        // reinterpretations are identity casts.
        let (acc64, a64) = unsafe { (cast_slice_mut::<T, f64>(acc), cast_val::<T, f64>(a)) };
        // SAFETY: same guard; `row` is an independent shared borrow.
        let row64 = unsafe { cast_slice::<T, f64>(row) };
        (ops.axpy_f64)(acc64, a64, row64);
        true
    } else if is::<T, f32>() {
        // SAFETY: the TypeId guard above proved T == f32, so all three
        // reinterpretations are identity casts.
        let (acc32, a32) = unsafe { (cast_slice_mut::<T, f32>(acc), cast_val::<T, f32>(a)) };
        // SAFETY: same guard; `row` is an independent shared borrow.
        let row32 = unsafe { cast_slice::<T, f32>(row) };
        (ops.axpy_f32)(acc32, a32, row32);
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_names_are_stable() {
        assert_eq!(Isa::Portable.name(), "portable");
        assert_eq!(Isa::Avx2.name(), "avx2");
        assert_eq!(Isa::Neon.name(), "neon");
    }

    #[test]
    fn active_isa_is_consistent_with_table() {
        match active() {
            Some(ops) => assert_eq!(active_isa(), ops.isa),
            None => assert_eq!(active_isa(), Isa::Portable),
        }
    }

    #[test]
    fn active_isa_matches_target_capabilities() {
        // The cached decision must be one this target can actually take.
        match active_isa() {
            Isa::Portable => {}
            Isa::Avx2 => {
                #[cfg(not(target_arch = "x86_64"))]
                panic!("avx2 selected on a non-x86_64 target");
                #[cfg(target_arch = "x86_64")]
                assert!(std::arch::is_x86_feature_detected!("avx2"));
            }
            Isa::Neon => {
                #[cfg(not(target_arch = "aarch64"))]
                panic!("neon selected on a non-aarch64 target");
                #[cfg(target_arch = "aarch64")]
                assert!(std::arch::is_aarch64_feature_detected!("neon"));
            }
        }
    }
}
