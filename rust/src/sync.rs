//! Poison-tolerant synchronization helpers.
//!
//! `Mutex::lock().unwrap()` turns one panicking thread into a process-wide
//! panic cascade: the first panic while holding the guard poisons the
//! lock, and every later `unwrap` — in serve workers, the HTTP accept
//! loop, the quota gate — then panics too, so a single bad request can
//! take down every subsequent one. All non-test code in this crate goes
//! through [`lock_unpoisoned`] (and the condvar variants below) instead;
//! the `lock-unwrap` rule of `bilevel audit` (see [`crate::analysis`])
//! enforces it.
//!
//! Recovering a poisoned guard is sound here because every mutex-guarded
//! structure in this crate keeps *operational* state (queues, token
//! buckets, breaker gates, telemetry maps) whose invariants hold after
//! each statement — a panic mid-critical-section can at worst lose one
//! in-flight update, never leave a torn aggregate that later code would
//! misinterpret. New lock sites must keep that property (or wrap their
//! state in an explicit validity flag) before using these helpers.

use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
use std::time::Duration;

/// Lock `m`, recovering the guard if a previous holder panicked.
///
/// Equivalent to `m.lock().unwrap()` except that a poisoned lock yields
/// the inner guard instead of propagating the old panic to this thread.
#[inline]
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// [`Condvar::wait`] that recovers a poisoned re-acquired guard.
#[inline]
pub fn wait_unpoisoned<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// [`Condvar::wait_timeout`] that recovers a poisoned re-acquired guard.
#[inline]
pub fn wait_timeout_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur)
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};
    use std::time::Duration;

    fn poison<T: Send + 'static>(m: &Arc<Mutex<T>>) {
        let m2 = Arc::clone(m);
        let joined = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("deliberate poison");
        })
        .join();
        assert!(joined.is_err(), "poisoning thread must have panicked");
        assert!(m.is_poisoned(), "lock must be poisoned after the panic");
    }

    #[test]
    fn lock_unpoisoned_recovers_the_guard() {
        let m = Arc::new(Mutex::new(7u32));
        poison(&m);
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, 7, "state written before the panic is intact");
        *g = 8;
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), 8, "lock stays usable afterwards");
    }

    #[test]
    fn condvar_waits_recover_on_a_poisoned_mutex() {
        // Poison the waited-on mutex first, then prove both wait variants
        // still hand back a usable guard and observe writes made by the
        // waking thread.
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        {
            let p = Arc::clone(&pair);
            let joined = std::thread::spawn(move || {
                let _guard = p.0.lock().unwrap();
                panic!("deliberate poison");
            })
            .join();
            assert!(joined.is_err());
            assert!(pair.0.is_poisoned());
        }
        let waker = {
            let p = Arc::clone(&pair);
            std::thread::spawn(move || {
                *lock_unpoisoned(&p.0) = true;
                p.1.notify_all();
            })
        };
        let (m, cv) = &*pair;
        let mut g = lock_unpoisoned(m);
        while !*g {
            g = wait_unpoisoned(cv, g);
        }
        assert!(*g);
        drop(g);
        waker.join().unwrap();
        // The timeout variant recovers too (flag already set: returns at
        // once regardless of whether the deadline fired).
        let g = lock_unpoisoned(m);
        let (g, _timeout) = wait_timeout_unpoisoned(cv, g, Duration::from_millis(5));
        assert!(*g);
    }
}
