//! # `fault` — deterministic, seeded fault injection
//!
//! A chaos-engineering layer in the spirit of `fail-rs`: named *sites* in
//! persist I/O, serve workers, and net connections consult a globally
//! installed [`FaultPlan`] and, per the plan's schedule, simulate a
//! failure (short read/write, torn rename, checksum flip, worker panic or
//! stall, connection reset, slow reader). With no plan installed the
//! check is one relaxed atomic load — production code pays a branch, not
//! a lock.
//!
//! ## Determinism
//!
//! Every decision is a pure function of `(plan.seed, site, hit index)`:
//! each site keeps a monotonically increasing hit counter, and hit `n`
//! fires according to the site's [`SiteSpec`] — either a deterministic
//! `every=K` stride (exactly replayable regardless of thread
//! interleaving) or a seeded per-hit Bernoulli draw (`p=0.1`) hashed from
//! `seed ^ site ^ n` with SplitMix64, so the *set of firing hit indices*
//! is identical across replays. `limit=` caps total fires in arrival
//! order; combine it with `every=` when byte-for-byte replay matters.
//!
//! ## Spec grammar
//!
//! A site spec is a comma list of `key=value` pairs:
//!
//! | key     | meaning                                            |
//! |---------|----------------------------------------------------|
//! | `p`     | fire probability per hit (seeded, in `[0,1]`)      |
//! | `every` | fire every `K`-th hit (takes precedence over `p`)  |
//! | `after` | skip the first `N` hits                            |
//! | `limit` | fire at most `N` times (0 = unlimited)             |
//! | `param` | site parameter: bytes to cut / keep, millis, bits  |
//!
//! Plans come from the `[fault]` config section
//! ([`FaultPlan::from_doc`]), from the CLI (`bilevel chaos
//! --faults "worker.panic:every=8,limit=2;conn.reset:p=0.1,param=256"`),
//! or programmatically ([`FaultPlan::with_site`]). Install with
//! [`install`], tear down with [`clear`]; sites call [`fire`].

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::TomlDoc;
use crate::rng::{Rng, SplitMix64};
use crate::sync::lock_unpoisoned;

/// Number of named injection sites.
pub const SITE_COUNT: usize = 8;

/// A named fault-injection point.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// Checkpoint save writes fewer bytes than intended (torn tail).
    PersistShortWrite,
    /// Checkpoint load observes a truncated byte stream.
    PersistShortRead,
    /// Checkpoint save crashes between the tmp write and the rename: the
    /// tmp file is left behind and the save reports an I/O error.
    PersistTornRename,
    /// One payload bit of a saved checkpoint is flipped on disk.
    PersistChecksumFlip,
    /// A serve worker panics mid-job.
    WorkerPanic,
    /// A serve worker stalls for `param` milliseconds before executing.
    WorkerStall,
    /// The server resets a connection after writing `param` response bytes.
    ConnReset,
    /// A chaos loadgen client sleeps `param` milliseconds before reading
    /// the response (exercises the server's write timeout).
    ConnSlowRead,
}

impl FaultSite {
    /// Every site, in declaration order (stable indices).
    pub const ALL: [FaultSite; SITE_COUNT] = [
        FaultSite::PersistShortWrite,
        FaultSite::PersistShortRead,
        FaultSite::PersistTornRename,
        FaultSite::PersistChecksumFlip,
        FaultSite::WorkerPanic,
        FaultSite::WorkerStall,
        FaultSite::ConnReset,
        FaultSite::ConnSlowRead,
    ];

    /// The dotted name used by config keys, CLI specs, and reports.
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::PersistShortWrite => "persist.short_write",
            FaultSite::PersistShortRead => "persist.short_read",
            FaultSite::PersistTornRename => "persist.torn_rename",
            FaultSite::PersistChecksumFlip => "persist.checksum_flip",
            FaultSite::WorkerPanic => "worker.panic",
            FaultSite::WorkerStall => "worker.stall",
            FaultSite::ConnReset => "conn.reset",
            FaultSite::ConnSlowRead => "conn.slow_read",
        }
    }

    /// Inverse of [`FaultSite::name`].
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|site| site.name() == s)
    }

    fn index(self) -> usize {
        Self::ALL.iter().position(|&s| s == self).expect("site in ALL")
    }

    /// Stable per-site tag mixed into the decision hash so two sites with
    /// the same hit index draw independent Bernoulli streams.
    fn tag(self) -> u64 {
        crate::persist::fnv1a64(self.name().as_bytes())
    }
}

/// When (and how hard) one site fires. See the module docs for the
/// spec grammar.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SiteSpec {
    /// Per-hit fire probability (seeded; ignored when `every > 0`).
    pub probability: f64,
    /// Fire deterministically every `every`-th eligible hit (0 = off).
    pub every: u64,
    /// Skip the first `after` hits.
    pub after: u64,
    /// Fire at most `limit` times (0 = unlimited).
    pub limit: u64,
    /// Site-specific parameter (bytes, millis, bit index).
    pub param: u64,
}

impl SiteSpec {
    /// Parse `"p=0.5,every=3,after=10,limit=2,param=64"`.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut out = Self::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("fault spec entry {part:?}: expected key=value"))?;
            match k.trim() {
                "p" | "prob" | "probability" => {
                    let p: f64 = v
                        .trim()
                        .parse()
                        .map_err(|_| format!("fault spec p: bad number {v:?}"))?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!("fault spec p={p} outside [0,1]"));
                    }
                    out.probability = p;
                }
                "every" => out.every = parse_u64("every", v)?,
                "after" => out.after = parse_u64("after", v)?,
                "limit" => out.limit = parse_u64("limit", v)?,
                "param" => out.param = parse_u64("param", v)?,
                other => return Err(format!("fault spec: unknown key {other:?}")),
            }
        }
        if out.probability == 0.0 && out.every == 0 {
            return Err(format!("fault spec {spec:?} never fires: set p= or every="));
        }
        Ok(out)
    }

    /// Does the schedule pass for 0-based hit `n` (ignoring `limit`)?
    /// Pure: identical across replays for the same `(seed, site, n)`.
    pub fn schedule_fires(&self, seed: u64, site: FaultSite, n: u64) -> bool {
        if n < self.after {
            return false;
        }
        if self.every > 0 {
            return (n - self.after) % self.every == 0;
        }
        if self.probability > 0.0 {
            let mut h =
                SplitMix64::new(seed ^ site.tag() ^ n.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            return h.next_f64() < self.probability;
        }
        false
    }
}

fn parse_u64(key: &str, v: &str) -> Result<u64, String> {
    v.trim().parse().map_err(|_| format!("fault spec {key}: bad integer {v:?}"))
}

/// A seeded schedule of faults across any subset of the named sites.
/// Empty plans are inert; the default is empty.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    sites: Vec<(FaultSite, SiteSpec)>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        Self { seed, sites: Vec::new() }
    }

    /// Add (or replace) one site's spec.
    pub fn with_site(mut self, site: FaultSite, spec: SiteSpec) -> Self {
        self.sites.retain(|(s, _)| *s != site);
        self.sites.push((site, spec));
        self
    }

    pub fn is_empty(&self) -> bool {
        self.sites.is_empty()
    }

    pub fn site(&self, site: FaultSite) -> Option<&SiteSpec> {
        self.sites.iter().find(|(s, _)| *s == site).map(|(_, spec)| spec)
    }

    pub fn sites(&self) -> impl Iterator<Item = (FaultSite, &SiteSpec)> {
        self.sites.iter().map(|(s, spec)| (*s, spec))
    }

    /// Parse a CLI spec list:
    /// `"worker.panic:every=8,limit=2;conn.reset:p=0.1,param=256"`.
    pub fn parse_sites(seed: u64, list: &str) -> Result<Self, String> {
        let mut plan = Self::new(seed);
        for entry in list.split(';') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (name, spec) = entry
                .split_once(':')
                .ok_or_else(|| format!("fault entry {entry:?}: expected site:spec"))?;
            let site = FaultSite::parse(name.trim())
                .ok_or_else(|| format!("unknown fault site {:?}", name.trim()))?;
            plan = plan.with_site(site, SiteSpec::parse(spec)?);
        }
        Ok(plan)
    }

    /// Build from the `[fault]` config section: `fault.seed` plus one
    /// string spec per site, e.g.
    ///
    /// ```toml
    /// [fault]
    /// seed = 7
    /// [fault.worker]
    /// panic = "every=64,limit=2"
    /// [fault.conn]
    /// reset = "p=0.05,param=256"
    /// ```
    ///
    /// Returns `Ok(None)` when the section configures no sites.
    pub fn from_doc(doc: &TomlDoc) -> Result<Option<Self>, String> {
        let seed = doc.usize_or("fault.seed", 0) as u64;
        let mut plan = Self::new(seed);
        for site in FaultSite::ALL {
            let key = format!("fault.{}", site.name());
            if let Some(v) = doc.get(&key) {
                let spec = v
                    .as_str()
                    .ok_or_else(|| format!("{key} must be a string fault spec"))?;
                plan = plan.with_site(site, SiteSpec::parse(spec)?);
            }
        }
        if plan.is_empty() {
            Ok(None)
        } else {
            Ok(Some(plan))
        }
    }

    /// One-line human summary, e.g.
    /// `seed 7: worker.panic[every=8 limit=2] conn.reset[p=0.05 param=256]`.
    pub fn summary(&self) -> String {
        let mut out = format!("seed {}:", self.seed);
        for (site, spec) in &self.sites {
            out.push(' ');
            out.push_str(site.name());
            out.push('[');
            let mut parts = Vec::new();
            if spec.every > 0 {
                parts.push(format!("every={}", spec.every));
            } else {
                parts.push(format!("p={}", spec.probability));
            }
            if spec.after > 0 {
                parts.push(format!("after={}", spec.after));
            }
            if spec.limit > 0 {
                parts.push(format!("limit={}", spec.limit));
            }
            if spec.param > 0 {
                parts.push(format!("param={}", spec.param));
            }
            out.push_str(&parts.join(" "));
            out.push(']');
        }
        out
    }
}

/// An installed plan plus per-site hit/fire telemetry.
#[derive(Debug)]
pub struct Injector {
    plan: FaultPlan,
    hits: [AtomicU64; SITE_COUNT],
    fired: [AtomicU64; SITE_COUNT],
}

impl Injector {
    pub fn new(plan: FaultPlan) -> Self {
        Self {
            plan,
            hits: std::array::from_fn(|_| AtomicU64::new(0)),
            fired: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Total times `site` was reached (configured sites only count when a
    /// plan is installed — unconfigured sites short-circuit).
    pub fn hits(&self, site: FaultSite) -> u64 {
        // Relaxed: point-in-time telemetry snapshot; no data is published
        // through this counter, so atomicity alone suffices.
        self.hits[site.index()].load(Ordering::Relaxed)
    }

    /// Total times `site` actually fired.
    pub fn fired(&self, site: FaultSite) -> u64 {
        // Relaxed: same as `hits` — a statistic, not a synchronization edge.
        self.fired[site.index()].load(Ordering::Relaxed)
    }

    /// Decide whether this hit of `site` fires; returns the site param.
    fn decide(&self, site: FaultSite) -> Option<u64> {
        let spec = self.plan.site(site)?;
        let i = site.index();
        // Relaxed: each thread only needs a unique ticket value; the
        // fetch_add's atomicity guarantees that without any ordering.
        let n = self.hits[i].fetch_add(1, Ordering::Relaxed);
        if !spec.schedule_fires(self.plan.seed, site, n) {
            return None;
        }
        if spec.limit > 0 {
            // Exact cap: only count a fire we actually claim. Relaxed is
            // enough for the whole CAS loop — the loop's correctness rests
            // on the atomicity of compare_exchange (at most `limit` claims
            // ever succeed), not on ordering with any other location.
            let mut cur = self.fired[i].load(Ordering::Relaxed);
            loop {
                if cur >= spec.limit {
                    return None;
                }
                match self.fired[i].compare_exchange_weak(
                    cur,
                    cur + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(actual) => cur = actual,
                }
            }
        } else {
            // Relaxed: unlimited site — pure statistic, as in `hits`.
            self.fired[i].fetch_add(1, Ordering::Relaxed);
        }
        Some(spec.param)
    }

    /// `"  site: fired F / hits H"` lines for every configured site.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (site, _) in self.plan.sites() {
            out.push_str(&format!(
                "  {:<22} fired {:>4} / {:>6} hits\n",
                site.name(),
                self.fired(site),
                self.hits(site)
            ));
        }
        out
    }
}

// Memory-ordering protocol: `ENABLED` is only a fast-path *hint* — it never
// publishes data by itself. Any thread that sees it `true` goes on to lock
// `INSTALLED`, and that mutex acquire synchronizes with the unlock in
// `install`/`clear`, so the injector read under the lock is always current.
// A stale hint is benign in both directions: a stale `false` skips injection
// for a hit that raced installation (indistinguishable from the hit landing
// a moment earlier), and a stale `true` costs one mutex round-trip that
// finds `None`. Stores use `Release` so the flag itself is conservatively
// ordered after the plan swap; loads stay `Relaxed` per the above.
static ENABLED: AtomicBool = AtomicBool::new(false);
static INSTALLED: Mutex<Option<Arc<Injector>>> = Mutex::new(None);

/// Install `plan` globally (replacing any previous one) and return a
/// handle for reading its telemetry. An empty plan disables injection
/// (equivalent to [`clear`], but still returns an inert handle).
pub fn install(plan: FaultPlan) -> Arc<Injector> {
    let inj = Arc::new(Injector::new(plan));
    let enable = !inj.plan.is_empty();
    *lock_unpoisoned(&INSTALLED) = Some(Arc::clone(&inj));
    // Release: flips the hint only after the mutex above published the
    // plan (see the protocol note on `ENABLED`).
    ENABLED.store(enable, Ordering::Release);
    inj
}

/// Remove the installed plan; every subsequent [`fire`] is a no-op.
pub fn clear() {
    // Release: hint off first so new hits short-circuit; stragglers that
    // already read `true` find `None` under the `INSTALLED` lock.
    ENABLED.store(false, Ordering::Release);
    *lock_unpoisoned(&INSTALLED) = None;
}

/// Is a non-empty plan installed?
pub fn active() -> bool {
    // Relaxed: hint only — see the protocol note on `ENABLED`.
    ENABLED.load(Ordering::Relaxed)
}

/// The currently installed injector, if any.
pub fn installed() -> Option<Arc<Injector>> {
    lock_unpoisoned(&INSTALLED).clone()
}

/// The hook production code calls at a site: `None` (overwhelmingly, and
/// with only an atomic load when no plan is installed) or `Some(param)`
/// when the installed plan says this hit fires.
#[inline]
pub fn fire(site: FaultSite) -> Option<u64> {
    // Relaxed: fast-path hint; the `INSTALLED` mutex below is the real
    // synchronization point (see the protocol note on `ENABLED`).
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    let inj = lock_unpoisoned(&INSTALLED).clone()?;
    inj.decide(site)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn site_names_round_trip() {
        for site in FaultSite::ALL {
            assert_eq!(FaultSite::parse(site.name()), Some(site));
            assert_eq!(site.index(), FaultSite::ALL.iter().position(|&s| s == site).unwrap());
        }
        assert_eq!(FaultSite::parse("bogus.site"), None);
    }

    #[test]
    fn spec_parses_and_rejects() {
        let s = SiteSpec::parse("p=0.5,after=10,limit=2,param=64").unwrap();
        assert_eq!(
            s,
            SiteSpec { probability: 0.5, every: 0, after: 10, limit: 2, param: 64 }
        );
        let s = SiteSpec::parse("every=3").unwrap();
        assert_eq!(s.every, 3);
        assert!(SiteSpec::parse("p=1.5").is_err());
        assert!(SiteSpec::parse("nope=1").is_err());
        assert!(SiteSpec::parse("after=2").is_err(), "schedule that never fires");
        assert!(SiteSpec::parse("p=abc").is_err());
    }

    #[test]
    fn every_schedule_is_exact() {
        let spec = SiteSpec::parse("every=3,after=2").unwrap();
        let fires: Vec<u64> = (0..12)
            .filter(|&n| spec.schedule_fires(1, FaultSite::WorkerPanic, n))
            .collect();
        assert_eq!(fires, vec![2, 5, 8, 11]);
    }

    #[test]
    fn probability_schedule_is_deterministic_and_calibrated() {
        let spec = SiteSpec::parse("p=0.25").unwrap();
        let draws = |seed: u64| -> Vec<u64> {
            (0..4000)
                .filter(|&n| spec.schedule_fires(seed, FaultSite::ConnReset, n))
                .collect()
        };
        let a = draws(7);
        assert_eq!(a, draws(7), "same seed => identical firing set");
        assert_ne!(a, draws(8), "different seed => different firing set");
        let frac = a.len() as f64 / 4000.0;
        assert!((frac - 0.25).abs() < 0.05, "fire fraction {frac} far from p");
        // sites draw independent streams under one seed
        let b: Vec<u64> = (0..4000)
            .filter(|&n| spec.schedule_fires(7, FaultSite::WorkerStall, n))
            .collect();
        assert_ne!(a, b);
    }

    #[test]
    fn injector_respects_limit_and_counts() {
        let plan = FaultPlan::new(3).with_site(
            FaultSite::WorkerPanic,
            SiteSpec::parse("every=2,limit=3,param=9").unwrap(),
        );
        let inj = Injector::new(plan);
        let fired: Vec<Option<u64>> =
            (0..10).map(|_| inj.decide(FaultSite::WorkerPanic)).collect();
        let n_fired = fired.iter().filter(|f| f.is_some()).count();
        assert_eq!(n_fired, 3, "limit=3 caps fires");
        assert!(fired.iter().flatten().all(|&p| p == 9));
        assert_eq!(inj.hits(FaultSite::WorkerPanic), 10);
        assert_eq!(inj.fired(FaultSite::WorkerPanic), 3);
        // unconfigured site never fires and never counts
        assert_eq!(inj.decide(FaultSite::ConnReset), None);
        assert_eq!(inj.hits(FaultSite::ConnReset), 0);
        assert!(inj.report().contains("worker.panic"));
    }

    #[test]
    fn plan_parsing_doc_and_cli_agree() {
        let doc = crate::config::parse(
            r#"
            [fault]
            seed = 7
            [fault.worker]
            panic = "every=8,limit=2"
            [fault.conn]
            reset = "p=0.05,param=256"
            "#,
        )
        .unwrap();
        let from_doc = FaultPlan::from_doc(&doc).unwrap().unwrap();
        let from_cli = FaultPlan::parse_sites(
            7,
            "worker.panic:every=8,limit=2; conn.reset:p=0.05,param=256",
        )
        .unwrap();
        assert_eq!(from_doc.seed, 7);
        assert_eq!(from_doc.site(FaultSite::WorkerPanic), from_cli.site(FaultSite::WorkerPanic));
        assert_eq!(from_doc.site(FaultSite::ConnReset), from_cli.site(FaultSite::ConnReset));
        assert!(from_doc.summary().contains("worker.panic[every=8 limit=2]"));
        // empty section => no plan
        let empty = crate::config::parse("[serve]\nshards = 1").unwrap();
        assert!(FaultPlan::from_doc(&empty).unwrap().is_none());
        // bad spec => error, unknown key => error
        let bad = crate::config::parse("[fault.worker]\npanic = \"nope=1\"").unwrap();
        assert!(FaultPlan::from_doc(&bad).is_err());
    }

    #[test]
    fn global_install_clear_plumbing() {
        // Uses a schedule that can never fire, so parallel lib tests that
        // reach real sites are unaffected while the plan is installed.
        let plan = FaultPlan::new(1).with_site(
            FaultSite::WorkerStall,
            SiteSpec { probability: 1.0, every: 0, after: u64::MAX, limit: 0, param: 1 },
        );
        let inj = install(plan);
        assert!(active());
        assert_eq!(fire(FaultSite::WorkerStall), None, "after=MAX never fires");
        assert_eq!(fire(FaultSite::ConnReset), None, "unconfigured site");
        assert!(inj.hits(FaultSite::WorkerStall) >= 1);
        clear();
        assert!(!active());
        assert_eq!(fire(FaultSite::WorkerStall), None);
        assert!(installed().is_none());
    }
}
