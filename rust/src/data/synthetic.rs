//! Rust port of scikit-learn's `make_classification` (Guyon 2003 "Madelon"
//! generator) — the paper's §V.B "two artificial biological datasets":
//! n=1000 samples, m=1000 features, 64 (data-64) or 16 (data-16)
//! informative features, 2 classes.
//!
//! Generator semantics (matching sklearn):
//! 1. class centroids on the vertices of an `n_informative`-dimensional
//!    hypercube at distance `class_sep`;
//! 2. informative block: standard normal around the class centroid, then a
//!    random linear mixing within the block (random covariance);
//! 3. redundant block: random linear combinations of informative features;
//! 4. the rest: pure standard-normal noise;
//! 5. feature columns shuffled, fraction `flip_y` of labels randomised.

use super::dataset::Dataset;
use crate::rng::{bernoulli, Normal, Rng};

#[derive(Clone, Debug)]
pub struct MakeClassificationConfig {
    pub n_samples: usize,
    pub n_features: usize,
    pub n_informative: usize,
    pub n_redundant: usize,
    pub n_classes: usize,
    pub class_sep: f64,
    pub flip_y: f64,
    /// Shuffle the feature columns (sklearn default true). The informative
    /// indices are reported post-shuffle either way.
    pub shuffle_features: bool,
}

impl MakeClassificationConfig {
    /// Paper "data-64": 1000×1000 with 64 informative features. `class_sep`
    /// / `flip_y` are tuned so the no-projection baseline lands near the
    /// paper's ~80% and feature selection buys ~+10% (the paper does not
    /// report the generator arguments; these reproduce its difficulty).
    pub fn data64() -> Self {
        Self {
            n_samples: 1000,
            n_features: 1000,
            n_informative: 64,
            n_redundant: 0,
            n_classes: 2,
            class_sep: 0.35,
            flip_y: 0.04,
            shuffle_features: true,
        }
    }

    /// Paper "data-16": 1000×1000 with 16 informative features.
    pub fn data16() -> Self {
        Self { n_informative: 16, class_sep: 0.75, ..Self::data64() }
    }

    /// Small config for tests/examples.
    pub fn tiny() -> Self {
        Self {
            n_samples: 64,
            n_features: 64,
            n_informative: 8,
            n_redundant: 4,
            n_classes: 2,
            class_sep: 2.0,
            flip_y: 0.0,
            shuffle_features: true,
        }
    }
}

/// Generate the dataset. Classes are balanced (`n_samples` split evenly).
pub fn make_classification<R: Rng + ?Sized>(
    cfg: &MakeClassificationConfig,
    rng: &mut R,
) -> Dataset {
    let MakeClassificationConfig {
        n_samples,
        n_features,
        n_informative,
        n_redundant,
        n_classes,
        class_sep,
        flip_y,
        shuffle_features,
    } = *cfg;
    assert!(n_informative + n_redundant <= n_features);
    assert!(n_classes >= 2);
    assert!(
        n_informative >= 63 || n_classes <= 1usize << n_informative,
        "need 2^informative >= classes for hypercube vertices"
    );

    let mut normal = Normal::standard();

    // 1. Hypercube centroids: each class gets a RANDOM vertex of the
    //    n_informative-cube (sklearn semantics) — distinct classes then
    //    differ in ~half of the informative dimensions. (A binary-expansion
    //    assignment would make classes 0/1 differ in a single dimension,
    //    collapsing the separation to 2·class_sep·1σ.)
    let mut class_vertices: Vec<Vec<bool>> = Vec::with_capacity(n_classes);
    while class_vertices.len() < n_classes {
        let v: Vec<bool> = (0..n_informative).map(|_| rng.next_u64() & 1 == 1).collect();
        if !class_vertices.contains(&v) {
            class_vertices.push(v);
        }
    }
    let centroid = |class: usize, dim: usize| -> f64 {
        if class_vertices[class][dim] {
            class_sep
        } else {
            -class_sep
        }
    };

    // 2. Random mixing matrix A (informative x informative) to induce a
    //    random covariance, as sklearn does per class. One shared A keeps
    //    the port simple while preserving anisotropy.
    let mut mix = vec![0.0f64; n_informative * n_informative];
    for v in &mut mix {
        *v = normal.sample(rng);
    }
    // Blend toward identity so the mixing never collapses directions.
    for d in 0..n_informative {
        mix[d * n_informative + d] += 2.0;
    }

    // 3. Redundant combination matrix B (redundant x informative).
    let mut comb = vec![0.0f64; n_redundant * n_informative];
    for v in &mut comb {
        *v = normal.sample(rng) / (n_informative as f64).sqrt();
    }

    // Feature position shuffle.
    let mut positions: Vec<usize> = (0..n_features).collect();
    if shuffle_features {
        rng.shuffle(&mut positions);
    }

    let mut x = vec![0.0f32; n_samples * n_features];
    let mut labels = Vec::with_capacity(n_samples);
    let mut raw_inf = vec![0.0f64; n_informative];
    let mut mixed = vec![0.0f64; n_informative];

    for i in 0..n_samples {
        let class = i % n_classes;
        labels.push(class as u32);

        // informative block
        for (d, r) in raw_inf.iter_mut().enumerate() {
            *r = centroid(class, d) + normal.sample(rng);
        }
        for d in 0..n_informative {
            let mut acc = 0.0;
            for e in 0..n_informative {
                acc += mix[d * n_informative + e] * raw_inf[e];
            }
            mixed[d] = acc / (n_informative as f64).sqrt();
        }

        let row = &mut x[i * n_features..(i + 1) * n_features];
        for d in 0..n_informative {
            row[positions[d]] = mixed[d] as f32;
        }
        for rix in 0..n_redundant {
            let mut acc = 0.0;
            for e in 0..n_informative {
                acc += comb[rix * n_informative + e] * mixed[e];
            }
            row[positions[n_informative + rix]] = acc as f32;
        }
        for d in (n_informative + n_redundant)..n_features {
            row[positions[d]] = normal.sample(rng) as f32;
        }
    }

    // 5. Label flipping.
    if flip_y > 0.0 {
        for l in labels.iter_mut() {
            if bernoulli(rng, flip_y) {
                *l = rng.next_below(n_classes as u64) as u32;
            }
        }
    }

    let informative: Vec<usize> = positions[..n_informative].to_vec();
    Dataset {
        x,
        labels,
        n_samples,
        n_features,
        n_classes,
        informative,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn shapes_and_balance() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let ds = make_classification(&MakeClassificationConfig::tiny(), &mut rng);
        assert_eq!(ds.n_samples, 64);
        assert_eq!(ds.n_features, 64);
        assert_eq!(ds.x.len(), 64 * 64);
        let counts = ds.class_counts();
        assert_eq!(counts, vec![32, 32]);
        assert_eq!(ds.informative.len(), 8);
    }

    #[test]
    fn informative_features_separate_classes() {
        // Mean difference between classes should be much larger on
        // informative features than on noise features.
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let cfg = MakeClassificationConfig {
            n_samples: 400,
            n_features: 50,
            n_informative: 5,
            n_redundant: 0,
            n_classes: 2,
            class_sep: 2.0,
            flip_y: 0.0,
            shuffle_features: true,
        };
        let ds = make_classification(&cfg, &mut rng);
        let mut sep = vec![0.0f64; 50];
        let mut counts = [0usize; 2];
        let mut means = vec![[0.0f64; 2]; 50];
        for i in 0..ds.n_samples {
            let c = ds.labels[i] as usize;
            counts[c] += 1;
            for (f, &v) in ds.row(i).iter().enumerate() {
                means[f][c] += v as f64;
            }
        }
        for f in 0..50 {
            sep[f] = (means[f][0] / counts[0] as f64 - means[f][1] / counts[1] as f64).abs();
        }
        let inf_sep: f64 =
            ds.informative.iter().map(|&f| sep[f]).sum::<f64>() / ds.informative.len() as f64;
        let noise_sep: f64 = (0..50)
            .filter(|f| !ds.informative.contains(f))
            .map(|f| sep[f])
            .sum::<f64>()
            / 45.0;
        assert!(
            inf_sep > 5.0 * noise_sep,
            "informative separation {inf_sep} vs noise {noise_sep}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = MakeClassificationConfig::tiny();
        let mut r1 = Xoshiro256pp::seed_from_u64(5);
        let mut r2 = Xoshiro256pp::seed_from_u64(5);
        let a = make_classification(&cfg, &mut r1);
        let b = make_classification(&cfg, &mut r2);
        assert_eq!(a.x, b.x);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn flip_y_randomises_some_labels() {
        let mut base_cfg = MakeClassificationConfig::tiny();
        base_cfg.n_samples = 1000;
        base_cfg.flip_y = 0.0;
        let mut r1 = Xoshiro256pp::seed_from_u64(6);
        let clean = make_classification(&base_cfg, &mut r1);
        base_cfg.flip_y = 0.3;
        let mut r2 = Xoshiro256pp::seed_from_u64(6);
        let flipped = make_classification(&base_cfg, &mut r2);
        let diffs = clean
            .labels
            .iter()
            .zip(flipped.labels.iter())
            .filter(|(a, b)| a != b)
            .count();
        // ~30% * 50% stay-same ≈ 15% expected to differ.
        assert!(diffs > 50, "flip_y had no effect ({diffs} diffs)");
    }

    #[test]
    fn paper_configs_shapes() {
        assert_eq!(MakeClassificationConfig::data64().n_informative, 64);
        assert_eq!(MakeClassificationConfig::data16().n_informative, 16);
        assert_eq!(MakeClassificationConfig::data64().n_features, 1000);
    }
}
