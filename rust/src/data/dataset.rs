//! Common dataset container + preprocessing.

use crate::rng::Rng;

/// A labelled dataset. `x` is row-major `(n_samples, n_features)` — the
/// layout PJRT literals use, so batches upload without transposition.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub labels: Vec<u32>,
    pub n_samples: usize,
    pub n_features: usize,
    pub n_classes: usize,
    /// Ground-truth informative feature indices (known for the simulators;
    /// used by the feature-selection example to score recovery).
    pub informative: Vec<usize>,
}

impl Dataset {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.n_features..(i + 1) * self.n_features]
    }

    /// One-hot encode labels as f32 `(n_samples, n_classes)` row-major.
    pub fn one_hot(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n_samples * self.n_classes];
        for (i, &c) in self.labels.iter().enumerate() {
            out[i * self.n_classes + c as usize] = 1.0;
        }
        out
    }

    /// Shuffled train/test split (stratification-free; class balance comes
    /// from the generators being balanced by construction).
    pub fn split<R: Rng + ?Sized>(&self, test_fraction: f64, rng: &mut R) -> Split {
        assert!((0.0..1.0).contains(&test_fraction));
        let mut idx: Vec<usize> = (0..self.n_samples).collect();
        rng.shuffle(&mut idx);
        let n_test = ((self.n_samples as f64) * test_fraction).round() as usize;
        let (test_idx, train_idx) = idx.split_at(n_test);
        Split {
            train: self.subset(train_idx),
            test: self.subset(test_idx),
        }
    }

    /// K-fold split; fold `k` of `folds` becomes the test set.
    pub fn kfold<R: Rng + ?Sized>(&self, folds: usize, k: usize, seed_rng: &mut R) -> Split {
        assert!(folds >= 2 && k < folds);
        let mut idx: Vec<usize> = (0..self.n_samples).collect();
        seed_rng.shuffle(&mut idx);
        let fold_size = self.n_samples.div_ceil(folds);
        let lo = k * fold_size;
        let hi = ((k + 1) * fold_size).min(self.n_samples);
        let test_idx: Vec<usize> = idx[lo..hi].to_vec();
        let train_idx: Vec<usize> =
            idx[..lo].iter().chain(idx[hi..].iter()).copied().collect();
        Split {
            train: self.subset(&train_idx),
            test: self.subset(&test_idx),
        }
    }

    pub fn subset(&self, indices: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(indices.len() * self.n_features);
        let mut labels = Vec::with_capacity(indices.len());
        for &i in indices {
            x.extend_from_slice(self.row(i));
            labels.push(self.labels[i]);
        }
        Dataset {
            x,
            labels,
            n_samples: indices.len(),
            n_features: self.n_features,
            n_classes: self.n_classes,
            informative: self.informative.clone(),
        }
    }

    /// Batches of exactly `batch` rows (last partial batch dropped for the
    /// fixed-shape train artifacts; use [`Dataset::padded_batches`] for
    /// evaluation where every sample must be scored).
    pub fn batches(&self, batch: usize) -> Batches {
        Batches { n_batches: self.n_samples / batch, batch }
    }

    /// Number of padded batches needed to cover every sample.
    pub fn padded_batches(&self, batch: usize) -> usize {
        self.n_samples.div_ceil(batch)
    }

    /// Copy batch `b` (of size `batch`) into row-major buffers, zero-padding
    /// past the end. Returns the number of real rows.
    pub fn fill_batch(
        &self,
        b: usize,
        batch: usize,
        x_out: &mut [f32],
        y_out: &mut [f32],
    ) -> usize {
        assert_eq!(x_out.len(), batch * self.n_features);
        assert_eq!(y_out.len(), batch * self.n_classes);
        x_out.fill(0.0);
        y_out.fill(0.0);
        let lo = b * batch;
        let hi = ((b + 1) * batch).min(self.n_samples);
        for (r, i) in (lo..hi).enumerate() {
            x_out[r * self.n_features..(r + 1) * self.n_features]
                .copy_from_slice(self.row(i));
            y_out[r * self.n_classes + self.labels[i] as usize] = 1.0;
        }
        hi - lo
    }

    /// Class frequency vector.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.n_classes];
        for &l in &self.labels {
            c[l as usize] += 1;
        }
        c
    }
}

/// Train/test pair.
#[derive(Clone, Debug)]
pub struct Split {
    pub train: Dataset,
    pub test: Dataset,
}

/// Fixed-size batching plan.
#[derive(Clone, Copy, Debug)]
pub struct Batches {
    pub n_batches: usize,
    pub batch: usize,
}

/// Per-feature standardisation fitted on train, applied to both splits
/// (the SAE expects roughly unit-scale inputs).
#[derive(Clone, Debug)]
pub struct StandardScaler {
    pub mean: Vec<f32>,
    pub std: Vec<f32>,
}

impl StandardScaler {
    pub fn fit(ds: &Dataset) -> Self {
        let f = ds.n_features;
        let n = ds.n_samples.max(1) as f64;
        let mut mean = vec![0.0f64; f];
        for i in 0..ds.n_samples {
            for (m, &v) in mean.iter_mut().zip(ds.row(i).iter()) {
                *m += v as f64;
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        let mut var = vec![0.0f64; f];
        for i in 0..ds.n_samples {
            for ((vv, &v), &m) in var.iter_mut().zip(ds.row(i).iter()).zip(mean.iter()) {
                let d = v as f64 - m;
                *vv += d * d;
            }
        }
        let std = var
            .iter()
            .map(|&v| ((v / n).sqrt().max(1e-8)) as f32)
            .collect();
        Self { mean: mean.iter().map(|&m| m as f32).collect(), std }
    }

    pub fn transform(&self, ds: &mut Dataset) {
        let f = ds.n_features;
        for i in 0..ds.n_samples {
            let row = &mut ds.x[i * f..(i + 1) * f];
            for ((v, &m), &s) in row.iter_mut().zip(self.mean.iter()).zip(self.std.iter()) {
                *v = (*v - m) / s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn toy(n: usize, f: usize) -> Dataset {
        Dataset {
            x: (0..n * f).map(|i| i as f32).collect(),
            labels: (0..n).map(|i| (i % 2) as u32).collect(),
            n_samples: n,
            n_features: f,
            n_classes: 2,
            informative: vec![0, 1],
        }
    }

    #[test]
    fn one_hot_layout() {
        let ds = toy(3, 2);
        assert_eq!(ds.one_hot(), vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn split_partitions_all_samples() {
        let ds = toy(100, 4);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let sp = ds.split(0.25, &mut rng);
        assert_eq!(sp.test.n_samples, 25);
        assert_eq!(sp.train.n_samples, 75);
        assert_eq!(sp.train.n_features, 4);
    }

    #[test]
    fn kfold_covers_everything_once() {
        let ds = toy(97, 2);
        let folds = 4;
        let mut total_test = 0;
        for k in 0..folds {
            // Same shuffle seed per fold => disjoint folds.
            let mut rng = Xoshiro256pp::seed_from_u64(9);
            let sp = ds.kfold(folds, k, &mut rng);
            total_test += sp.test.n_samples;
            assert_eq!(sp.test.n_samples + sp.train.n_samples, 97);
        }
        assert_eq!(total_test, 97);
    }

    #[test]
    fn fill_batch_pads_tail() {
        let ds = toy(5, 2);
        let mut x = vec![9.0f32; 4 * 2];
        let mut y = vec![9.0f32; 4 * 2];
        let real = ds.fill_batch(1, 4, &mut x, &mut y);
        assert_eq!(real, 1); // only sample 4 remains
        assert_eq!(&x[0..2], &[8.0, 9.0]); // row 4 data
        assert_eq!(&x[2..], &[0.0; 6]); // padding
        assert_eq!(&y[2..], &[0.0; 6]);
    }

    #[test]
    fn scaler_zero_mean_unit_std() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut ds = Dataset {
            x: (0..2000).map(|_| rng.uniform(5.0, 15.0) as f32).collect(),
            labels: vec![0; 200],
            n_samples: 200,
            n_features: 10,
            n_classes: 2,
            informative: vec![],
        };
        let sc = StandardScaler::fit(&ds);
        sc.transform(&mut ds);
        let again = StandardScaler::fit(&ds);
        for (m, s) in again.mean.iter().zip(again.std.iter()) {
            assert!(m.abs() < 1e-4, "mean {m}");
            assert!((s - 1.0).abs() < 1e-3, "std {s}");
        }
    }

    #[test]
    fn batches_counts() {
        let ds = toy(100, 2);
        assert_eq!(ds.batches(32).n_batches, 3);
        assert_eq!(ds.padded_batches(32), 4);
    }

    #[test]
    fn class_counts_balanced_toy() {
        let ds = toy(10, 2);
        assert_eq!(ds.class_counts(), vec![5, 5]);
    }
}
