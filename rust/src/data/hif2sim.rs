//! HIF2-sim: synthetic stand-in for the HIF2 single-cell CRISPRi dataset
//! (Truchi et al., Frontiers in Bioinformatics 2024 — paper ref. [45]).
//!
//! The real data (779 cells × 10,000 genes, control vs HIF2α-knockdown,
//! not publicly bundled) is replaced by a standard scRNA-seq generative
//! model that preserves the properties the paper's experiment depends on:
//!
//! * high dimension (10,000 genes), few samples (779 cells);
//! * a *small* set of truly informative genes (~30 — CRISPRi knockdowns
//!   perturb a focused regulon) with modest fold changes ("subtle
//!   transcriptomic perturbations" per the source paper's title);
//! * heavy-tailed, over-dispersed counts: per-gene log-normal baseline →
//!   per-cell library size → negative-binomial sampling → dropout;
//! * log1p + per-gene standardisation, the usual pipeline input.
//!
//! DESIGN.md §Substitutions documents why this preserves the paper's
//! claim (accuracy-vs-η shape, bilevel ≥ usual projection, ~+10% over the
//! no-projection baseline).

use super::dataset::Dataset;
use crate::rng::{bernoulli, gamma, negative_binomial, Normal, Rng};

#[derive(Clone, Debug)]
pub struct Hif2Config {
    pub n_cells: usize,
    pub n_genes: usize,
    pub n_informative: usize,
    /// log2 fold-change magnitude on informative genes.
    pub effect_log2fc: f64,
    /// NB dispersion (smaller = noisier).
    pub dispersion: f64,
    /// Extra dropout probability applied to low-expression entries.
    pub dropout: f64,
}

impl Default for Hif2Config {
    fn default() -> Self {
        Self {
            n_cells: 779,
            n_genes: 10_000,
            n_informative: 30,
            effect_log2fc: 1.2,
            dispersion: 1.5,
            dropout: 0.3,
        }
    }
}

impl Hif2Config {
    /// Small config for tests.
    pub fn tiny() -> Self {
        Self { n_cells: 60, n_genes: 64, n_informative: 6, ..Self::default() }
    }
}

/// Generate the simulated screen. Returns log1p-standardised expression.
pub fn hif2_sim<R: Rng + ?Sized>(cfg: &Hif2Config, rng: &mut R) -> Dataset {
    let Hif2Config {
        n_cells,
        n_genes,
        n_informative,
        effect_log2fc,
        dispersion,
        dropout,
    } = *cfg;
    assert!(n_informative <= n_genes);

    let mut normal = Normal::standard();

    // Per-gene baseline mean expression: log-normal, median ~1 count.
    let base_mean: Vec<f64> = (0..n_genes)
        .map(|_| (normal.sample(rng) * 1.5).exp())
        .collect();

    // Informative genes: the strongest-expressed get the perturbation
    // (knockdown effects are detectable on expressed genes).
    let mut order: Vec<usize> = (0..n_genes).collect();
    order.sort_by(|&a, &b| base_mean[b].partial_cmp(&base_mean[a]).unwrap());
    let informative: Vec<usize> = order[..n_informative].to_vec();
    // Half down-regulated (the knockdown target + regulon), half up.
    let fold: Vec<f64> = (0..n_informative)
        .map(|k| {
            let sign = if k % 2 == 0 { -1.0 } else { 1.0 };
            (sign * effect_log2fc * std::f64::consts::LN_2).exp()
        })
        .collect();

    let mut x = vec![0.0f32; n_cells * n_genes];
    let mut labels = Vec::with_capacity(n_cells);

    for i in 0..n_cells {
        let class = (i % 2) as u32; // 0 = control, 1 = knockdown
        labels.push(class);
        // Per-cell library size factor (gamma around 1).
        let lib = gamma(rng, 8.0, 0.125);
        let row = &mut x[i * n_genes..(i + 1) * n_genes];
        for (g, r) in row.iter_mut().enumerate() {
            let mut mu = base_mean[g] * lib;
            if class == 1 {
                if let Some(k) = informative.iter().position(|&gi| gi == g) {
                    mu *= fold[k];
                }
            }
            let count = negative_binomial(rng, mu, dispersion);
            let mut v = count as f64;
            // Dropout: technical zeros, more likely at low expression.
            if v > 0.0 && bernoulli(rng, dropout / (1.0 + mu)) {
                v = 0.0;
            }
            *r = (v.ln_1p()) as f32;
        }
    }

    let mut ds = Dataset {
        x,
        labels,
        n_samples: n_cells,
        n_features: n_genes,
        n_classes: 2,
        informative,
    };
    // Per-gene standardisation (fit on everything: the trainer re-splits
    // and re-scales on train only; this just tames the dynamic range).
    let scaler = super::dataset::StandardScaler::fit(&ds);
    scaler.transform(&mut ds);
    ds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn shape_matches_paper() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let cfg = Hif2Config { n_genes: 500, n_cells: 100, ..Hif2Config::tiny() };
        let ds = hif2_sim(&cfg, &mut rng);
        assert_eq!(ds.n_samples, 100);
        assert_eq!(ds.n_features, 500);
        assert_eq!(ds.informative.len(), 6);
        assert_eq!(ds.class_counts(), vec![50, 50]);
    }

    #[test]
    fn informative_genes_discriminate() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let cfg = Hif2Config {
            n_cells: 400,
            n_genes: 200,
            n_informative: 8,
            effect_log2fc: 2.0,
            ..Hif2Config::default()
        };
        let ds = hif2_sim(&cfg, &mut rng);
        // t-like statistic per gene.
        let mut stat = vec![0.0f64; 200];
        for g in 0..200 {
            let (mut s0, mut s1, mut n0, mut n1) = (0.0, 0.0, 0, 0);
            for i in 0..ds.n_samples {
                let v = ds.row(i)[g] as f64;
                if ds.labels[i] == 0 {
                    s0 += v;
                    n0 += 1;
                } else {
                    s1 += v;
                    n1 += 1;
                }
            }
            stat[g] = (s0 / n0 as f64 - s1 / n1 as f64).abs();
        }
        let inf_mean: f64 =
            ds.informative.iter().map(|&g| stat[g]).sum::<f64>() / ds.informative.len() as f64;
        let rest_mean: f64 = (0..200)
            .filter(|g| !ds.informative.contains(g))
            .map(|g| stat[g])
            .sum::<f64>()
            / 192.0;
        assert!(
            inf_mean > 3.0 * rest_mean,
            "informative {inf_mean} vs background {rest_mean}"
        );
    }

    #[test]
    fn standardised_output() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let ds = hif2_sim(&Hif2Config::tiny(), &mut rng);
        // post-scaler: roughly zero mean per feature
        let f = ds.n_features;
        for g in 0..f.min(10) {
            let mean: f64 =
                (0..ds.n_samples).map(|i| ds.row(i)[g] as f64).sum::<f64>() / ds.n_samples as f64;
            assert!(mean.abs() < 1e-3, "gene {g} mean {mean}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = Hif2Config::tiny();
        let mut r1 = Xoshiro256pp::seed_from_u64(9);
        let mut r2 = Xoshiro256pp::seed_from_u64(9);
        assert_eq!(hif2_sim(&cfg, &mut r1).x, hif2_sim(&cfg, &mut r2).x);
    }

    #[test]
    fn default_is_paper_shape() {
        let cfg = Hif2Config::default();
        assert_eq!(cfg.n_cells, 779);
        assert_eq!(cfg.n_genes, 10_000);
    }
}
