//! Dataset substrates.
//!
//! The paper evaluates on (a) two synthetic classification sets produced by
//! scikit-learn's `make_classification` (1000 samples × 1000 features, 64
//! and 16 informative features — §V.B) and (b) the HIF2 single-cell CRISPRi
//! screen (779 cells × 10,000 genes — §V.C.2). Neither sklearn nor the HIF2
//! data exist in this environment, so both substrates are built here:
//!
//! * [`synthetic`] — a faithful Rust port of `make_classification`
//!   (hypercube class centroids, informative/redundant/noise feature split,
//!   label flipping);
//! * [`hif2sim`] — an scRNA-seq simulator (log-normal baseline expression,
//!   negative-binomial counts, dropout, class-conditional fold changes on a
//!   small informative gene set), matched to the HIF2 shape;
//! * [`dataset`] — the common container: row-major sample matrix, labels,
//!   train/test splits, standardisation, one-hot encoding, padded batching
//!   (PJRT artifacts have static shapes).

pub mod dataset;
pub mod hif2sim;
pub mod synthetic;

pub use dataset::{Batches, Dataset, Split, StandardScaler};
pub use hif2sim::{hif2_sim, Hif2Config};
pub use synthetic::{make_classification, MakeClassificationConfig};
