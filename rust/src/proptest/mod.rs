//! Minimal property-based testing harness.
//!
//! The offline crate set has no `proptest`, so invariants are checked with
//! this in-repo harness: seeded generators + a `forall` runner that, on
//! failure, *shrinks* matrices/vectors by halving dimensions and magnitudes
//! before reporting the smallest failing case. Deliberately tiny — enough
//! to express "for 500 random (Y, η): feasibility + identity hold".

use crate::rng::{Rng, Xoshiro256pp};
use crate::tensor::Matrix;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 200, seed: 0xBAD5EED, max_shrink_steps: 32 }
    }
}

/// A generated value plus the recipe to shrink it.
pub trait Arbitrary: Clone {
    fn generate(rng: &mut Xoshiro256pp) -> Self;
    /// Candidate simpler values (empty = fully shrunk).
    fn shrink(&self) -> Vec<Self>;
    /// Short human description for failure reports.
    fn describe(&self) -> String;
}

/// Run `prop` on `cfg.cases` random inputs; panic with the smallest failing
/// input's description on violation.
pub fn forall<A: Arbitrary>(cfg: PropConfig, prop: impl Fn(&A) -> Result<(), String>) {
    let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed);
    for case in 0..cfg.cases {
        let input = A::generate(&mut rng);
        if let Err(msg) = prop(&input) {
            // Shrink.
            let mut best = input;
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in best.shrink() {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        steps += 1;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {:#x}) after {steps} shrink steps\n\
                 input: {}\nerror: {best_msg}",
                cfg.seed,
                best.describe()
            );
        }
    }
}

/// Random matrix + radius pair — the canonical input of every projection
/// property.
#[derive(Clone, Debug)]
pub struct MatrixAndRadius {
    pub y: Matrix<f64>,
    pub eta: f64,
}

impl Arbitrary for MatrixAndRadius {
    fn generate(rng: &mut Xoshiro256pp) -> Self {
        let n = 1 + rng.next_below(48) as usize;
        let m = 1 + rng.next_below(48) as usize;
        // Mix of scales: some columns amplified, some zeroed, occasional
        // exact duplicates to exercise tie-handling.
        let mut y = Matrix::<f64>::randn(n, m, rng);
        for j in 0..m {
            let roll = rng.next_below(10);
            if roll == 0 {
                for v in y.col_mut(j) {
                    *v = 0.0;
                }
            } else if roll == 1 {
                for v in y.col_mut(j) {
                    *v *= 100.0;
                }
            } else if roll == 2 && j > 0 {
                let src = y.col(j - 1).to_vec();
                y.col_mut(j).copy_from_slice(&src);
            }
        }
        let norm = crate::norms::l1inf_norm(&y);
        let eta = if norm > 0.0 {
            rng.uniform(1e-4, 1.3) * norm
        } else {
            1.0
        };
        Self { y, eta }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let (n, m) = (self.y.rows(), self.y.cols());
        if n > 1 {
            // Keep the top half of the rows.
            let mut y = Matrix::zeros(n / 2, m);
            for j in 0..m {
                for i in 0..n / 2 {
                    y.set(i, j, self.y.get(i, j));
                }
            }
            out.push(Self { y, eta: self.eta });
        }
        if m > 1 {
            let mut y = Matrix::zeros(n, m / 2);
            for j in 0..m / 2 {
                for i in 0..n {
                    y.set(i, j, self.y.get(i, j));
                }
            }
            out.push(Self { y, eta: self.eta });
        }
        // Halve magnitudes (moves values toward ties at zero).
        out.push(Self { y: self.y.map(|v| v * 0.5), eta: self.eta });
        // Halve the radius.
        if self.eta > 1e-6 {
            out.push(Self { y: self.y.clone(), eta: self.eta * 0.5 });
        }
        out
    }

    fn describe(&self) -> String {
        format!(
            "Matrix {}x{} (||Y||_1inf = {:.6}), eta = {:.6}",
            self.y.rows(),
            self.y.cols(),
            crate::norms::l1inf_norm(&self.y),
            self.eta
        )
    }
}

/// Random pruned SAE + input batch — the canonical input of the sparse
/// subsystem's properties (compact round-trip, sparse ≡ dense encode,
/// plan/mask consistency). The mask is already applied to the params, and
/// the sparsity level spans the extremes: roll 0 forces 0% pruned, roll 1
/// forces 100%, otherwise each feature dies with probability ~1/3.
#[derive(Clone, Debug)]
pub struct SparseSaeCase {
    pub params: crate::model::SaeParams,
    pub mask: Vec<f32>,
    /// Input batch, `(features, batch)` column-major (one sample per
    /// column).
    pub x: Matrix<f64>,
}

impl Arbitrary for SparseSaeCase {
    fn generate(rng: &mut Xoshiro256pp) -> Self {
        use crate::model::{SaeDims, SaeParams};
        let features = 1 + rng.next_below(32) as usize;
        let hidden = 1 + rng.next_below(12) as usize;
        let dims = SaeDims { features, hidden, classes: 2 };
        let mut params = SaeParams::init(dims, rng);
        let roll = rng.next_below(6);
        let mask: Vec<f32> = (0..features)
            .map(|_| match roll {
                0 => 1.0,
                1 => 0.0,
                _ => {
                    if rng.next_below(3) == 0 {
                        0.0
                    } else {
                        1.0
                    }
                }
            })
            .collect();
        params.apply_feature_mask(&mask);
        let batch = 1 + rng.next_below(8) as usize;
        let x = Matrix::randn(features, batch, rng);
        Self { params, mask, x }
    }

    fn shrink(&self) -> Vec<Self> {
        // Fewer batch columns only: shrinking the model would invalidate
        // the mask/params pairing.
        let cols = self.x.cols();
        if cols <= 1 {
            return Vec::new();
        }
        let mut x = Matrix::zeros(self.x.rows(), cols / 2);
        for j in 0..cols / 2 {
            for i in 0..self.x.rows() {
                x.set(i, j, self.x.get(i, j));
            }
        }
        vec![Self { params: self.params.clone(), mask: self.mask.clone(), x }]
    }

    fn describe(&self) -> String {
        format!(
            "SAE {}x{} ({} alive of {}), batch {}",
            self.params.dims.features,
            self.params.dims.hidden,
            self.mask.iter().filter(|&&m| m > 0.0).count(),
            self.params.dims.features,
            self.x.cols()
        )
    }
}

/// Random non-negative vector + radius for ℓ1 projection properties.
#[derive(Clone, Debug)]
pub struct VectorAndRadius {
    pub v: Vec<f64>,
    pub eta: f64,
}

impl Arbitrary for VectorAndRadius {
    fn generate(rng: &mut Xoshiro256pp) -> Self {
        let n = 1 + rng.next_below(512) as usize;
        let v: Vec<f64> = (0..n).map(|_| rng.uniform(-10.0, 10.0)).collect();
        let norm: f64 = v.iter().map(|x| x.abs()).sum();
        let eta = rng.uniform(1e-5, 1.2) * norm.max(1.0);
        Self { v, eta }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.v.len() > 1 {
            out.push(Self { v: self.v[..self.v.len() / 2].to_vec(), eta: self.eta });
        }
        out.push(Self { v: self.v.iter().map(|x| x * 0.5).collect(), eta: self.eta });
        if self.eta > 1e-6 {
            out.push(Self { v: self.v.clone(), eta: self.eta * 0.5 });
        }
        out
    }

    fn describe(&self) -> String {
        format!("Vector len {} , eta = {:.6}", self.v.len(), self.eta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially_true_property() {
        forall::<VectorAndRadius>(PropConfig { cases: 50, ..Default::default() }, |x| {
            if x.eta >= 0.0 {
                Ok(())
            } else {
                Err("negative eta".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall::<VectorAndRadius>(PropConfig { cases: 50, ..Default::default() }, |x| {
            if x.v.len() < 4 {
                Ok(())
            } else {
                Err("too long".into())
            }
        });
    }

    #[test]
    fn shrinking_reduces_dimensions() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let m = MatrixAndRadius::generate(&mut rng);
        for s in m.shrink() {
            assert!(
                s.y.rows() <= m.y.rows() && s.y.cols() <= m.y.cols(),
                "shrink must not grow"
            );
        }
    }
}
