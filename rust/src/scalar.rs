//! Floating-point scalar abstraction.
//!
//! The projection library is generic over `f32`/`f64`: the training runtime
//! feeds `f32` weight matrices straight from PJRT buffers, while the
//! numerical experiments (identity verification, algorithm cross-checks) run
//! in `f64`. No external num-traits dependency — the offline crate set is
//! restricted to the `xla` closure, so we carry our own minimal trait.

use std::fmt::{Debug, Display};
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// Minimal float trait implemented for `f32` and `f64`.
pub trait Scalar:
    Copy
    + PartialOrd
    + Debug
    + Display
    + Default
    + Send
    + Sync
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + DivAssign
    + Sum
    + 'static
{
    const ZERO: Self;
    const ONE: Self;
    const EPSILON: Self;
    const MIN_POSITIVE: Self;
    const INFINITY: Self;
    const NEG_INFINITY: Self;

    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;
    fn from_usize(v: usize) -> Self {
        Self::from_f64(v as f64)
    }
    fn abs(self) -> Self;
    fn sqrt(self) -> Self;
    fn ln(self) -> Self;
    fn exp(self) -> Self;
    fn powi(self, n: i32) -> Self;
    fn is_finite(self) -> bool;
    fn is_nan(self) -> bool;
    fn max_s(self, other: Self) -> Self;
    fn min_s(self, other: Self) -> Self;
    fn signum_s(self) -> Self;
    /// `max(self, 0)` — the positive part, ubiquitous in thresholding.
    fn pos(self) -> Self {
        self.max_s(Self::ZERO)
    }
}

macro_rules! impl_scalar {
    ($t:ty) => {
        impl Scalar for $t {
            const ZERO: Self = 0.0;
            const ONE: Self = 1.0;
            const EPSILON: Self = <$t>::EPSILON;
            const MIN_POSITIVE: Self = <$t>::MIN_POSITIVE;
            const INFINITY: Self = <$t>::INFINITY;
            const NEG_INFINITY: Self = <$t>::NEG_INFINITY;

            #[inline(always)]
            fn from_f64(v: f64) -> Self {
                v as $t
            }
            #[inline(always)]
            fn to_f64(self) -> f64 {
                self as f64
            }
            #[inline(always)]
            fn abs(self) -> Self {
                <$t>::abs(self)
            }
            #[inline(always)]
            fn sqrt(self) -> Self {
                <$t>::sqrt(self)
            }
            #[inline(always)]
            fn ln(self) -> Self {
                <$t>::ln(self)
            }
            #[inline(always)]
            fn exp(self) -> Self {
                <$t>::exp(self)
            }
            #[inline(always)]
            fn powi(self, n: i32) -> Self {
                <$t>::powi(self, n)
            }
            #[inline(always)]
            fn is_finite(self) -> bool {
                <$t>::is_finite(self)
            }
            #[inline(always)]
            fn is_nan(self) -> bool {
                <$t>::is_nan(self)
            }
            #[inline(always)]
            fn max_s(self, other: Self) -> Self {
                <$t>::max(self, other)
            }
            #[inline(always)]
            fn min_s(self, other: Self) -> Self {
                <$t>::min(self, other)
            }
            #[inline(always)]
            fn signum_s(self) -> Self {
                if self > 0.0 {
                    1.0
                } else if self < 0.0 {
                    -1.0
                } else {
                    0.0
                }
            }
        }
    };
}

impl_scalar!(f32);
impl_scalar!(f64);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_roundtrip() {
        assert_eq!(f64::ZERO, 0.0);
        assert_eq!(f32::ONE, 1.0);
        assert_eq!(f64::from_f64(2.5), 2.5);
        assert_eq!(f32::from_f64(2.5).to_f64(), 2.5);
    }

    #[test]
    fn signum_handles_zero() {
        assert_eq!(0.0f64.signum_s(), 0.0);
        assert_eq!((-3.0f64).signum_s(), -1.0);
        assert_eq!(3.0f32.signum_s(), 1.0);
    }

    #[test]
    fn pos_part() {
        assert_eq!((-1.5f64).pos(), 0.0);
        assert_eq!(1.5f64.pos(), 1.5);
    }

    #[test]
    fn from_usize_exact_for_small() {
        assert_eq!(f64::from_usize(12345), 12345.0);
        assert_eq!(f32::from_usize(1024), 1024.0);
    }
}
