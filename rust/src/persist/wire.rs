//! Little-endian wire primitives for the checkpoint format.
//!
//! A [`Writer`] appends fixed-width little-endian fields to a growable
//! byte buffer; a [`Reader`] consumes them back with explicit truncation
//! errors (no panics on malformed input — every length is validated
//! against the remaining bytes *before* any allocation, so a corrupted
//! length field cannot OOM the loader).

use super::PersistError;

/// Append-only little-endian byte sink.
#[derive(Default)]
pub(crate) struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Bit-exact: writes `to_bits`, never a decimal round-trip.
    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    /// Bit-exact: writes `to_bits`, never a decimal round-trip.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Length-prefixed f32 slice (u64 count + raw bit patterns).
    pub fn f32_slice(&mut self, xs: &[f32]) {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.f32(x);
        }
    }

    /// Length-prefixed u64 slice.
    pub fn u64_slice(&mut self, xs: &[u64]) {
        self.u64(xs.len() as u64);
        for &x in xs {
            self.u64(x);
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor over a byte slice with truncation-checked reads.
pub(crate) struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], PersistError> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or(PersistError::Truncated { need: usize::MAX, have: self.bytes.len() })?;
        if end > self.bytes.len() {
            return Err(PersistError::Truncated { need: end, have: self.bytes.len() });
        }
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub fn u32(&mut self) -> Result<u32, PersistError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, PersistError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32, PersistError> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64, PersistError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// A u64 count validated to describe at most the remaining bytes when
    /// each element occupies `elem_bytes` — the pre-allocation guard.
    pub fn checked_len(&mut self, elem_bytes: usize) -> Result<usize, PersistError> {
        let n = self.u64()? as usize;
        let need = n
            .checked_mul(elem_bytes)
            .ok_or(PersistError::Truncated { need: usize::MAX, have: self.remaining() })?;
        if need > self.remaining() {
            return Err(PersistError::Truncated {
                need: self.pos + need,
                have: self.bytes.len(),
            });
        }
        Ok(n)
    }

    /// Length-prefixed f32 slice written by [`Writer::f32_slice`].
    pub fn f32_vec(&mut self) -> Result<Vec<f32>, PersistError> {
        let n = self.checked_len(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    /// Length-prefixed u64 slice written by [`Writer::u64_slice`].
    pub fn u64_vec(&mut self) -> Result<Vec<u64>, PersistError> {
        let n = self.checked_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_roundtrip_bit_exact() {
        let mut w = Writer::new();
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 1);
        w.f32(-0.0f32);
        w.f64(f64::from_bits(0x7FF8_0000_0000_0001)); // a specific NaN payload
        w.f32_slice(&[1.5, -2.5, f32::MIN_POSITIVE]);
        w.u64_slice(&[0, 7, 42]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.f64().unwrap().to_bits(), 0x7FF8_0000_0000_0001);
        assert_eq!(r.f32_vec().unwrap(), vec![1.5, -2.5, f32::MIN_POSITIVE]);
        assert_eq!(r.u64_vec().unwrap(), vec![0, 7, 42]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = Writer::new();
        w.u64(5);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes[..6]);
        assert!(matches!(r.u64(), Err(PersistError::Truncated { .. })));
    }

    #[test]
    fn absurd_length_prefix_rejected_before_allocation() {
        // A corrupted length field claiming 2^60 elements must fail the
        // remaining-bytes check, not attempt a huge Vec::with_capacity.
        let mut w = Writer::new();
        w.u64(1u64 << 60);
        w.u32(0);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(matches!(r.f32_vec(), Err(PersistError::Truncated { .. })));
    }
}
