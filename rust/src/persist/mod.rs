//! # `persist` — versioned, checksummed model checkpoints
//!
//! The ROADMAP's train-once / serve-forever step: a trained model (the
//! [`crate::sparse::CompactPlan`] + compacted [`crate::model::SaeParams`]
//! of a [`crate::coordinator::TrainOutcome`], and optionally the full
//! dense parameters and the mid-run optimizer state) survives the process
//! as one self-describing binary file, so the serve engine can load and
//! hot-swap models across restarts and fleet deploys.
//!
//! ## Wire format (version 1, all little-endian)
//!
//! | offset | field |
//! |--------|-------|
//! | 0      | magic `b"BLVLCKPT"` (8 bytes) |
//! | 8      | format version (u32) |
//! | 12     | tensor dtype tag (u32; 0 = f32) |
//! | 16     | dims: features, hidden, classes (3 × u64) |
//! | 40     | seed (u64) |
//! | 48     | training-config digest (u64) |
//! | 56     | section flags (u32) + reserved (u32) |
//! | 64     | payload length (u64) |
//! | 72     | payload: history, model bundle, train state (per flags) |
//! | 72 + payload | checksum: 128-bit integrity hash (2 × u64) |
//!
//! The 72-byte header is self-contained — `bilevel inspect` dumps it
//! without touching the payload. Tensor payloads are raw `f32` bit
//! patterns (length-prefixed, validated against the header dims before
//! any allocation), so export → import round-trips are **bit-exact**; the
//! footer is the same two-lane 128-bit hash the serve threshold cache
//! keys matrices with ([`crate::serve::cache::hash128_words`]), computed
//! over every byte that precedes it.
//!
//! ## Lifecycle wiring
//!
//! * the trainer writes rolling checkpoints every
//!   `[persist] checkpoint_every` epochs and resumes from one
//!   deterministically ([`crate::coordinator::SaeTrainer::run_with`]);
//! * the serve engine loads a checkpoint into its encoder registry
//!   (`Engine::load_model`) and hot-swaps a model id under live traffic
//!   (`Engine::swap_model`) — in-flight batches finish on the old `Arc`;
//! * the CLI speaks `bilevel export` / `bilevel import` /
//!   `bilevel inspect` / `bilevel serve --model` (see EXPERIMENTS.md
//!   §Model lifecycle);
//! * [`recover_latest`] implements the **recovery chain**: scan a rolling
//!   checkpoint directory newest → oldest, step over (and quarantine as
//!   `<name>.corrupt`) anything that fails validation — truncated tails,
//!   flipped bits, torn renames — and resume from the newest snapshot
//!   that checks out, bit-exactly. The [`crate::fault`] sites
//!   `persist.short_write` / `persist.short_read` / `persist.torn_rename`
//!   / `persist.checksum_flip` inject exactly these damages.

mod checkpoint;
mod recover;
mod wire;

pub use checkpoint::{
    read_header, Checkpoint, CheckpointHeader, ModelBundle, TrainStateSnapshot, FORMAT_VERSION,
    MAGIC,
};
pub use recover::{recover_latest, RecoveryOutcome};

use std::fmt;

/// Why a checkpoint could not be read (or written).
#[derive(Debug)]
pub enum PersistError {
    /// Filesystem failure (open/read/write/rename).
    Io(std::io::Error),
    /// The file does not start with the checkpoint magic.
    BadMagic,
    /// The file's format version is newer (or older) than this build
    /// understands.
    UnsupportedVersion(u32),
    /// The file ends before a declared field/section does.
    Truncated { need: usize, have: usize },
    /// The integrity footer does not match the file contents.
    ChecksumMismatch,
    /// Structurally invalid contents (dims/section mismatch, bad plan,
    /// unknown dtype tag) — the checksum passed but the data lies.
    Malformed(String),
}

impl fmt::Display for PersistError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Io(e) => write!(f, "checkpoint io: {e}"),
            Self::BadMagic => write!(f, "not a bilevel checkpoint (bad magic)"),
            Self::UnsupportedVersion(v) => {
                write!(f, "unsupported checkpoint format version {v} (this build reads {})",
                    FORMAT_VERSION)
            }
            Self::Truncated { need, have } => {
                write!(f, "checkpoint truncated: need {need} bytes, have {have}")
            }
            Self::ChecksumMismatch => write!(f, "checkpoint checksum mismatch (corrupted file)"),
            Self::Malformed(msg) => write!(f, "malformed checkpoint: {msg}"),
        }
    }
}

impl std::error::Error for PersistError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for PersistError {
    fn from(e: std::io::Error) -> Self {
        Self::Io(e)
    }
}

/// 64-bit FNV-1a over bytes — the digest primitive for configuration /
/// identity stamps ([`crate::config::TrainConfig::digest`], the CLI's
/// synthetic-export digest). The integrity *footer* uses the stronger
/// [`hash128_bytes`]; this one exists so every identity stamp shares one
/// implementation instead of hand-rolled copies that could drift.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// 128-bit integrity hash over a byte stream: the byte length followed by
/// the zero-padded 8-byte little-endian words, fed through the serve
/// cache's two-lane word hash. Shared by the checkpoint footer and its
/// tests.
pub fn hash128_bytes(bytes: &[u8]) -> u128 {
    crate::serve::cache::hash128_words(std::iter::once(bytes.len() as u64).chain(
        bytes.chunks(8).map(|c| {
            let mut w = [0u8; 8];
            w[..c.len()].copy_from_slice(c);
            u64::from_le_bytes(w)
        }),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash128_is_length_and_content_sensitive() {
        assert_ne!(hash128_bytes(b""), hash128_bytes(b"\0"));
        assert_ne!(hash128_bytes(b"\0"), hash128_bytes(b"\0\0"));
        assert_ne!(hash128_bytes(b"abcdefgh"), hash128_bytes(b"abcdefgi"));
        assert_eq!(hash128_bytes(b"abcdefghij"), hash128_bytes(b"abcdefghij"));
        // padding cannot alias: 8 bytes vs the same 8 bytes + a zero byte
        assert_ne!(hash128_bytes(b"abcdefgh"), hash128_bytes(b"abcdefgh\0"));
    }

    #[test]
    fn errors_display_usefully() {
        let s = PersistError::Truncated { need: 100, have: 7 }.to_string();
        assert!(s.contains("100") && s.contains("7"));
        assert!(PersistError::BadMagic.to_string().contains("magic"));
        assert!(PersistError::UnsupportedVersion(9).to_string().contains('9'));
    }
}
