//! The checkpoint object model and its (de)serialization.
//!
//! See the [`super`] module docs for the wire layout. Everything here is
//! deliberately boring: fixed field order, length-prefixed tensors,
//! validation before allocation, and bit-pattern float IO so round-trips
//! are exact for every value including `-0.0` and NaN payloads.

use std::path::Path;

use crate::coordinator::EpochStat;
use crate::model::{SaeDims, SaeParams};
use crate::scalar::Scalar;
use crate::sparse::{CompactEncoder, CompactPlan};

use super::wire::{Reader, Writer};
use super::{hash128_bytes, PersistError};

/// First 8 bytes of every checkpoint file.
pub const MAGIC: [u8; 8] = *b"BLVLCKPT";

/// Current wire format version.
pub const FORMAT_VERSION: u32 = 1;

/// Tensor storage dtype tag: the model's native f32.
const DTYPE_F32: u32 = 0;

/// Fixed header length (magic through payload_len).
const HEADER_LEN: usize = 72;

/// Sanity cap on any declared dimension / index-list length. The
/// checksum gates random corruption, but a deliberately re-signed file
/// (the footer hash is not cryptographic) must still fail with
/// [`PersistError::Malformed`] rather than attempt a huge allocation —
/// plan/mask buffers scale with `features` even when no tensor data
/// backs them.
const MAX_DIM: usize = 1 << 28;

/// Footer length (128-bit checksum as two u64 words).
const FOOTER_LEN: usize = 16;

const FLAG_MODEL: u32 = 1 << 0;
const FLAG_DENSE: u32 = 1 << 1;
const FLAG_TRAIN_STATE: u32 = 1 << 2;

/// The self-contained fixed header — everything `bilevel inspect` prints
/// without reading the payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CheckpointHeader {
    pub version: u32,
    /// Tensor dtype tag (0 = f32).
    pub dtype: u32,
    /// Original (dense) model dimensions.
    pub dims: SaeDims,
    pub seed: u64,
    /// Digest of the training configuration that produced the model.
    pub config_digest: u64,
    flags: u32,
    /// Bytes between the header and the checksum footer.
    pub payload_len: u64,
}

impl CheckpointHeader {
    pub fn has_model(&self) -> bool {
        self.flags & FLAG_MODEL != 0
    }

    pub fn has_dense(&self) -> bool {
        self.flags & FLAG_DENSE != 0
    }

    pub fn has_train_state(&self) -> bool {
        self.flags & FLAG_TRAIN_STATE != 0
    }

    /// Total file size this header declares (saturating: an absurd
    /// `payload_len` from a corrupt header yields `u64::MAX`, which every
    /// caller turns into a Truncated/size-mismatch report — never an
    /// arithmetic panic).
    pub fn expected_file_len(&self) -> u64 {
        (HEADER_LEN as u64)
            .saturating_add(self.payload_len)
            .saturating_add(FOOTER_LEN as u64)
    }

    pub fn dtype_name(&self) -> &'static str {
        match self.dtype {
            DTYPE_F32 => "f32",
            _ => "unknown",
        }
    }

    /// Parse (and validate magic/version/dtype of) the first
    /// [`HEADER_LEN`] bytes.
    pub fn parse(bytes: &[u8]) -> Result<Self, PersistError> {
        if bytes.len() < HEADER_LEN {
            return Err(PersistError::Truncated { need: HEADER_LEN, have: bytes.len() });
        }
        if bytes[..8] != MAGIC {
            return Err(PersistError::BadMagic);
        }
        let mut r = Reader::new(&bytes[8..HEADER_LEN]);
        let version = r.u32()?;
        if version != FORMAT_VERSION {
            return Err(PersistError::UnsupportedVersion(version));
        }
        let dtype = r.u32()?;
        if dtype != DTYPE_F32 {
            return Err(PersistError::Malformed(format!("unknown tensor dtype tag {dtype}")));
        }
        let features = checked_dim(r.u64()?, "features")?;
        let hidden = checked_dim(r.u64()?, "hidden")?;
        let classes = checked_dim(r.u64()?, "classes")?;
        let seed = r.u64()?;
        let config_digest = r.u64()?;
        let flags = r.u32()?;
        let _reserved = r.u32()?;
        let payload_len = r.u64()?;
        Ok(Self {
            version,
            dtype,
            dims: SaeDims { features, hidden, classes },
            seed,
            config_digest,
            flags,
            payload_len,
        })
    }
}

/// The servable half of a checkpoint: the frozen support set plus the
/// compacted model (and optionally the full dense parameters it was cut
/// from).
#[derive(Clone, Debug)]
pub struct ModelBundle {
    pub plan: CompactPlan,
    /// Compacted model: `dims.features == plan.alive()`.
    pub compact: SaeParams,
    /// Full dense final model (original feature space), when exported
    /// with it.
    pub dense: Option<SaeParams>,
}

impl ModelBundle {
    /// Build the inference encoder straight from the compacted tensors —
    /// bit-identical to `CompactEncoder::from_params` on the dense model
    /// the bundle was compacted from.
    pub fn encoder<T: Scalar>(&self) -> CompactEncoder<T> {
        CompactEncoder::from_compact(&self.compact, &self.plan)
    }
}

/// Mid-run optimizer state: everything the trainer needs to continue a
/// run deterministically (the data/shuffle RNGs are reconstructed from
/// the seed; see `SaeTrainer::run_with`).
#[derive(Clone, Debug)]
pub struct TrainStateSnapshot {
    /// Double-descent phase the snapshot was taken in (1 or 2).
    pub phase: u8,
    /// Epochs already completed *within that phase*.
    pub epochs_done: usize,
    /// Adam step counter.
    pub step: f32,
    /// The feature mask in force (all-ones during phase 1; the derived
    /// lottery-ticket mask during phase 2).
    pub mask: Vec<f32>,
    pub params: SaeParams,
    /// Adam first moment.
    pub m: SaeParams,
    /// Adam second moment.
    pub v: SaeParams,
}

/// One on-disk model lifecycle record.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub seed: u64,
    pub config_digest: u64,
    /// Original (dense) model dimensions.
    pub dims: SaeDims,
    /// Per-epoch training history up to the moment of the snapshot.
    pub history: Vec<EpochStat>,
    pub model: Option<ModelBundle>,
    pub train_state: Option<TrainStateSnapshot>,
}

impl Checkpoint {
    /// Serialize to the versioned wire format (header + payload +
    /// checksum footer).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut p = Writer::new();
        write_history(&mut p, &self.history);
        let mut flags = 0u32;
        if let Some(model) = &self.model {
            flags |= FLAG_MODEL;
            write_plan(&mut p, &model.plan);
            write_params(&mut p, &model.compact);
            if let Some(dense) = &model.dense {
                flags |= FLAG_DENSE;
                write_params(&mut p, dense);
            }
        }
        if let Some(ts) = &self.train_state {
            flags |= FLAG_TRAIN_STATE;
            p.u32(ts.phase as u32);
            p.u64(ts.epochs_done as u64);
            p.f32(ts.step);
            p.f32_slice(&ts.mask);
            write_params(&mut p, &ts.params);
            write_params(&mut p, &ts.m);
            write_params(&mut p, &ts.v);
        }
        let payload = p.into_bytes();

        let mut h = Writer::new();
        // header
        let mut out = MAGIC.to_vec();
        h.u32(FORMAT_VERSION);
        h.u32(DTYPE_F32);
        h.u64(self.dims.features as u64);
        h.u64(self.dims.hidden as u64);
        h.u64(self.dims.classes as u64);
        h.u64(self.seed);
        h.u64(self.config_digest);
        h.u32(flags);
        h.u32(0); // reserved
        h.u64(payload.len() as u64);
        out.extend_from_slice(&h.into_bytes());
        debug_assert_eq!(out.len(), HEADER_LEN);
        out.extend_from_slice(&payload);
        // footer
        let sum = hash128_bytes(&out);
        let mut f = Writer::new();
        f.u64(sum as u64);
        f.u64((sum >> 64) as u64);
        out.extend_from_slice(&f.into_bytes());
        out
    }

    /// Parse and fully validate (checksum, structure, dims) a checkpoint.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, PersistError> {
        let header = CheckpointHeader::parse(bytes)?;
        let expected = header.expected_file_len() as usize;
        if bytes.len() < expected {
            return Err(PersistError::Truncated { need: expected, have: bytes.len() });
        }
        if bytes.len() > expected {
            return Err(PersistError::Malformed(format!(
                "{} trailing bytes after declared footer",
                bytes.len() - expected
            )));
        }
        let body_end = expected - FOOTER_LEN;
        let mut fr = Reader::new(&bytes[body_end..]);
        let stored = (fr.u64()? as u128) | ((fr.u64()? as u128) << 64);
        if hash128_bytes(&bytes[..body_end]) != stored {
            return Err(PersistError::ChecksumMismatch);
        }

        let dims = header.dims;
        let mut r = Reader::new(&bytes[HEADER_LEN..body_end]);
        let history = read_history(&mut r)?;
        let model = if header.has_model() {
            let plan = read_plan(&mut r, dims.features)?;
            let compact_dims =
                SaeDims { features: plan.alive(), hidden: dims.hidden, classes: dims.classes };
            let compact = read_params(&mut r, compact_dims, "compact model")?;
            let dense = if header.has_dense() {
                Some(read_params(&mut r, dims, "dense model")?)
            } else {
                None
            };
            Some(ModelBundle { plan, compact, dense })
        } else {
            None
        };
        let train_state = if header.has_train_state() {
            let phase = r.u32()?;
            if !(1..=2).contains(&phase) {
                return Err(PersistError::Malformed(format!("train-state phase {phase}")));
            }
            let epochs_done = r.u64()? as usize;
            let step = r.f32()?;
            let mask = r.f32_vec()?;
            if mask.len() != dims.features {
                return Err(PersistError::Malformed(format!(
                    "train-state mask length {} != features {}",
                    mask.len(),
                    dims.features
                )));
            }
            let params = read_params(&mut r, dims, "train-state params")?;
            let m = read_params(&mut r, dims, "train-state m")?;
            let v = read_params(&mut r, dims, "train-state v")?;
            Some(TrainStateSnapshot { phase: phase as u8, epochs_done, step, mask, params, m, v })
        } else {
            None
        };
        if r.remaining() != 0 {
            return Err(PersistError::Malformed(format!(
                "{} undeclared payload bytes",
                r.remaining()
            )));
        }
        Ok(Self {
            seed: header.seed,
            config_digest: header.config_digest,
            dims,
            history,
            model,
            train_state,
        })
    }

    /// Atomic, durable write: serialize to a dot-tmp sibling, fsync it,
    /// rename into place, then fsync the parent directory — readers never
    /// observe a partial checkpoint, a power cut cannot leave an
    /// empty/partial file under the final name (the data blocks are on
    /// disk before the name flips), and once `save` returns the rename
    /// itself is durable, so a reported snapshot is never lost. A failed
    /// write cleans up its tmp file.
    pub fn save(&self, path: &Path) -> Result<(), PersistError> {
        use crate::fault::{self, FaultSite};
        use std::io::Write;
        let mut bytes = self.to_bytes();
        // Fault sites (no-ops unless a `FaultPlan` is installed): the
        // on-disk corruptions the recovery chain must survive, injected
        // *after* serialization so the in-memory checkpoint stays intact.
        if let Some(param) = fault::fire(FaultSite::PersistChecksumFlip) {
            // Flip one payload bit; the save "succeeds", the next load
            // fails its checksum.
            let idx = HEADER_LEN + (param as usize) % (bytes.len() - HEADER_LEN);
            bytes[idx] ^= 1u8 << ((param % 8) as u32);
        }
        if let Some(param) = fault::fire(FaultSite::PersistShortWrite) {
            // Drop the file's tail (at least one byte), as if the write
            // was cut mid-stream.
            let cut = (param as usize).clamp(1, bytes.len() - 1);
            bytes.truncate(bytes.len() - cut);
        }
        let torn = fault::fire(FaultSite::PersistTornRename).is_some();
        let name = path
            .file_name()
            .and_then(|n| n.to_str())
            .ok_or_else(|| PersistError::Malformed("checkpoint path has no file name".into()))?;
        let tmp = path.with_file_name(format!(".{name}.tmp"));
        let write_and_rename = || -> Result<(), PersistError> {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
            drop(f);
            if torn {
                // Simulate a crash between the tmp write and the rename:
                // the tmp file stays on disk, the final name is never
                // created/replaced.
                return Err(PersistError::Io(std::io::Error::new(
                    std::io::ErrorKind::Interrupted,
                    "injected fault: persist.torn_rename (tmp written, rename skipped)",
                )));
            }
            std::fs::rename(&tmp, path)?;
            Ok(())
        };
        if let Err(e) = write_and_rename() {
            if !torn {
                let _ = std::fs::remove_file(&tmp);
            }
            return Err(e);
        }
        // Durability of the rename: sync the directory entry (best-effort
        // on filesystems/platforms where directories cannot be synced).
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                if let Ok(d) = std::fs::File::open(parent) {
                    let _ = d.sync_all();
                }
            }
        }
        Ok(())
    }

    /// Read and fully validate a checkpoint file.
    pub fn load(path: &Path) -> Result<Self, PersistError> {
        let mut bytes = std::fs::read(path)?;
        // Fault site (no-op unless a `FaultPlan` is installed): a read
        // that returns fewer bytes than the file holds.
        if let Some(param) = crate::fault::fire(crate::fault::FaultSite::PersistShortRead) {
            let cut = (param as usize).clamp(1, bytes.len());
            bytes.truncate(bytes.len() - cut);
        }
        Self::from_bytes(&bytes)
    }
}

/// Read only the fixed header of a checkpoint file — the `bilevel
/// inspect` path; cost is one 72-byte read however large the model is.
pub fn read_header(path: &Path) -> Result<CheckpointHeader, PersistError> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)?;
    let mut buf = [0u8; HEADER_LEN];
    let mut read = 0;
    while read < HEADER_LEN {
        let n = f.read(&mut buf[read..])?;
        if n == 0 {
            return Err(PersistError::Truncated { need: HEADER_LEN, have: read });
        }
        read += n;
    }
    CheckpointHeader::parse(&buf)
}

/// Reject file-declared dimensions beyond the sanity cap before anything
/// allocates proportionally to them.
fn checked_dim(v: u64, what: &str) -> Result<usize, PersistError> {
    if v > MAX_DIM as u64 {
        return Err(PersistError::Malformed(format!("{what} {v} exceeds the {MAX_DIM} cap")));
    }
    Ok(v as usize)
}

fn write_params(w: &mut Writer, p: &SaeParams) {
    w.u64(p.dims.features as u64);
    w.u64(p.dims.hidden as u64);
    w.u64(p.dims.classes as u64);
    for t in &p.tensors {
        w.f32_slice(t);
    }
}

fn read_params(
    r: &mut Reader<'_>,
    expected: SaeDims,
    what: &str,
) -> Result<SaeParams, PersistError> {
    let features = r.u64()? as usize;
    let hidden = r.u64()? as usize;
    let classes = r.u64()? as usize;
    let dims = SaeDims { features, hidden, classes };
    if dims != expected {
        return Err(PersistError::Malformed(format!(
            "{what}: stored dims {dims:?} != expected {expected:?}"
        )));
    }
    let shapes = dims.shapes();
    let mut tensors = Vec::with_capacity(8);
    for shape in shapes.iter() {
        let t = r.f32_vec()?;
        let want: usize = shape.iter().product();
        if t.len() != want {
            return Err(PersistError::Malformed(format!(
                "{what}: tensor length {} != shape {shape:?}",
                t.len()
            )));
        }
        tensors.push(t);
    }
    Ok(SaeParams { dims, tensors })
}

fn write_plan(w: &mut Writer, plan: &CompactPlan) {
    w.u64(plan.features() as u64);
    w.u64_slice(&plan.alive_indices().iter().map(|&f| f as u64).collect::<Vec<_>>());
}

/// Read a plan, insisting its feature count matches the (already
/// cap-checked) header dims *before* any feature-proportional allocation.
fn read_plan(r: &mut Reader<'_>, expected_features: usize) -> Result<CompactPlan, PersistError> {
    let features = r.u64()? as usize;
    if features != expected_features {
        return Err(PersistError::Malformed(format!(
            "plan features {features} != header features {expected_features}"
        )));
    }
    let alive_u64 = r.u64_vec()?;
    let alive: Vec<usize> = alive_u64.iter().map(|&f| f as usize).collect();
    // Validate before `from_alive` so malformed files error instead of
    // panicking.
    for w in alive.windows(2) {
        if w[0] >= w[1] {
            return Err(PersistError::Malformed(
                "plan alive indices not strictly increasing".into(),
            ));
        }
    }
    if let Some(&last) = alive.last() {
        if last >= features {
            return Err(PersistError::Malformed(format!(
                "plan alive index {last} out of range {features}"
            )));
        }
    }
    Ok(CompactPlan::from_alive(features, alive))
}

fn write_history(w: &mut Writer, history: &[EpochStat]) {
    w.u64(history.len() as u64);
    for h in history {
        w.u32(h.phase as u32);
        w.u64(h.epoch as u64);
        w.f64(h.train_loss);
        w.f64(h.train_accuracy);
        w.f64(h.test_accuracy);
        w.u64(h.alive_features as u64);
    }
}

fn read_history(r: &mut Reader<'_>) -> Result<Vec<EpochStat>, PersistError> {
    // 44 bytes per entry: u32 + u64 + 3×f64 + u64.
    let n = r.checked_len(44)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(EpochStat {
            phase: r.u32()? as u8,
            epoch: r.u64()? as usize,
            train_loss: r.f64()?,
            train_accuracy: r.f64()?,
            test_accuracy: r.f64()?,
            alive_features: r.u64()? as usize,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;
    use crate::sparse::compact_params;

    fn sample_checkpoint(seed: u64, with_dense: bool, with_state: bool) -> Checkpoint {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let dims = SaeDims { features: 14, hidden: 5, classes: 3 };
        let mut params = SaeParams::init(dims, &mut rng);
        let mut mask = vec![1.0f32; 14];
        for f in [0usize, 3, 7, 8, 13] {
            mask[f] = 0.0;
        }
        params.apply_feature_mask(&mask);
        let plan = CompactPlan::from_mask(&mask);
        let compact = compact_params(&params, &plan);
        let history = vec![
            EpochStat {
                phase: 1,
                epoch: 0,
                train_loss: 0.75,
                train_accuracy: 0.5,
                test_accuracy: 0.25,
                alive_features: 14,
            },
            EpochStat {
                phase: 2,
                epoch: 1,
                train_loss: -0.0,
                train_accuracy: 1.0,
                test_accuracy: 0.875,
                alive_features: 9,
            },
        ];
        let train_state = with_state.then(|| TrainStateSnapshot {
            phase: 2,
            epochs_done: 1,
            step: 17.0,
            mask: mask.clone(),
            params: params.clone(),
            m: params.zeros_like(),
            v: params.zeros_like(),
        });
        Checkpoint {
            seed,
            config_digest: 0xABCD_EF01_2345_6789,
            dims,
            history,
            model: Some(ModelBundle {
                plan,
                compact,
                dense: with_dense.then(|| params.clone()),
            }),
            train_state,
        }
    }

    fn assert_params_bit_eq(a: &SaeParams, b: &SaeParams) {
        assert_eq!(a.dims, b.dims);
        for (ta, tb) in a.tensors.iter().zip(b.tensors.iter()) {
            assert_eq!(ta.len(), tb.len());
            for (x, y) in ta.iter().zip(tb.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let ck = sample_checkpoint(11, true, true);
        let bytes = ck.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back.seed, ck.seed);
        assert_eq!(back.config_digest, ck.config_digest);
        assert_eq!(back.dims, ck.dims);
        assert_eq!(back.history, ck.history);
        let (m0, m1) = (ck.model.as_ref().unwrap(), back.model.as_ref().unwrap());
        assert_eq!(m0.plan, m1.plan);
        assert_params_bit_eq(&m0.compact, &m1.compact);
        assert_params_bit_eq(m0.dense.as_ref().unwrap(), m1.dense.as_ref().unwrap());
        let (s0, s1) =
            (ck.train_state.as_ref().unwrap(), back.train_state.as_ref().unwrap());
        assert_eq!((s0.phase, s0.epochs_done), (s1.phase, s1.epochs_done));
        assert_eq!(s0.step.to_bits(), s1.step.to_bits());
        assert_eq!(s0.mask, s1.mask);
        assert_params_bit_eq(&s0.params, &s1.params);
        assert_params_bit_eq(&s0.m, &s1.m);
        assert_params_bit_eq(&s0.v, &s1.v);
        // serialization is deterministic
        assert_eq!(bytes, back.to_bytes());
    }

    #[test]
    fn optional_sections_roundtrip() {
        for (dense, state) in [(false, false), (true, false), (false, true)] {
            let ck = sample_checkpoint(12, dense, state);
            let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
            assert_eq!(back.model.as_ref().unwrap().dense.is_some(), dense);
            assert_eq!(back.train_state.is_some(), state);
        }
        // model-less (pure train-state) checkpoint
        let mut ck = sample_checkpoint(13, false, true);
        ck.model = None;
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert!(back.model.is_none() && back.train_state.is_some());
    }

    #[test]
    fn header_parses_without_payload() {
        let ck = sample_checkpoint(14, true, false);
        let bytes = ck.to_bytes();
        let header = CheckpointHeader::parse(&bytes[..HEADER_LEN]).unwrap();
        assert_eq!(header.version, FORMAT_VERSION);
        assert_eq!(header.dims, ck.dims);
        assert_eq!(header.seed, 14);
        assert_eq!(header.config_digest, ck.config_digest);
        assert!(header.has_model() && header.has_dense() && !header.has_train_state());
        assert_eq!(header.expected_file_len() as usize, bytes.len());
        assert_eq!(header.dtype_name(), "f32");
    }

    #[test]
    fn bad_magic_and_version_rejected() {
        let ck = sample_checkpoint(15, false, false);
        let mut bytes = ck.to_bytes();
        let mut wrong_magic = bytes.clone();
        wrong_magic[0] ^= 0xFF;
        assert!(matches!(Checkpoint::from_bytes(&wrong_magic), Err(PersistError::BadMagic)));
        // bump the version field (offset 8)
        bytes[8] = 99;
        assert!(matches!(
            Checkpoint::from_bytes(&bytes),
            Err(PersistError::UnsupportedVersion(99))
        ));
    }

    #[test]
    fn corruption_and_truncation_detected() {
        let ck = sample_checkpoint(16, true, true);
        let bytes = ck.to_bytes();
        // flip one payload bit
        let mut corrupt = bytes.clone();
        corrupt[HEADER_LEN + 9] ^= 0x01;
        assert!(matches!(
            Checkpoint::from_bytes(&corrupt),
            Err(PersistError::ChecksumMismatch)
        ));
        // flip one footer bit
        let mut bad_footer = bytes.clone();
        let last = bad_footer.len() - 1;
        bad_footer[last] ^= 0x80;
        assert!(matches!(
            Checkpoint::from_bytes(&bad_footer),
            Err(PersistError::ChecksumMismatch)
        ));
        // cut the file short
        assert!(matches!(
            Checkpoint::from_bytes(&bytes[..bytes.len() - 17]),
            Err(PersistError::Truncated { .. })
        ));
        // trailing junk
        let mut long = bytes.clone();
        long.push(0);
        assert!(matches!(Checkpoint::from_bytes(&long), Err(PersistError::Malformed(_))));
    }

    #[test]
    fn dims_tampering_is_malformed() {
        // Change the header's feature count and re-sign the checksum: the
        // structural validation (stored params dims vs header dims) must
        // still reject it.
        let ck = sample_checkpoint(17, false, false);
        let mut bytes = ck.to_bytes();
        bytes[16] = bytes[16].wrapping_add(1); // features LE low byte
        let body_end = bytes.len() - FOOTER_LEN;
        let sum = hash128_bytes(&bytes[..body_end]);
        bytes[body_end..body_end + 8].copy_from_slice(&(sum as u64).to_le_bytes());
        bytes[body_end + 8..].copy_from_slice(&((sum >> 64) as u64).to_le_bytes());
        assert!(matches!(Checkpoint::from_bytes(&bytes), Err(PersistError::Malformed(_))));
    }

    #[test]
    #[cfg_attr(miri, ignore = "real-filesystem test; interpreter-speed I/O adds no UB coverage")]
    fn save_load_and_read_header() {
        let dir = std::env::temp_dir().join(format!("bilevel-ckpt-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.ckpt");
        let ck = sample_checkpoint(18, true, false);
        ck.save(&path).unwrap();
        // no tmp file left behind
        assert!(!dir.join(".model.ckpt.tmp").exists());
        let header = read_header(&path).unwrap();
        assert_eq!(header.dims, ck.dims);
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.history, ck.history);
        // overwrite is atomic-rename too
        let ck2 = sample_checkpoint(19, false, false);
        ck2.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap().seed, 19);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_file_is_io_error() {
        let p = Path::new("/nonexistent/dir/model.ckpt");
        assert!(matches!(Checkpoint::load(p), Err(PersistError::Io(_))));
        assert!(matches!(read_header(p), Err(PersistError::Io(_))));
    }
}
