//! Checkpoint recovery chain: resume from the newest *valid* snapshot.
//!
//! A rolling-checkpoint directory accumulates snapshots over a training
//! run; any of them can be damaged — a torn write, a flipped bit, a
//! truncated tail. [`recover_latest`] scans the directory newest → oldest
//! and returns the first checkpoint that parses and passes its checksum,
//! so one corrupt file costs at most one checkpoint interval of progress
//! and **never** yields wrong bits: a file either validates end-to-end
//! (magic, structure, 128-bit checksum) or is stepped over.
//!
//! Corrupt files are **quarantined** — renamed to `<name>.corrupt` — so
//! the next scan does not re-parse them and an operator can inspect what
//! was damaged. Files that fail with a plain IO error (unreadable, racing
//! deletion) are skipped but left in place: the file may be fine, the
//! reader was not.
//!
//! Ordering is by modification time, newest first, with the file name
//! (descending) as the tie-break — rolling checkpoints carry monotonic
//! names (`epoch-0004.ckpt`), so same-second snapshots still resolve to
//! the latest one.

use std::path::{Path, PathBuf};

use super::checkpoint::Checkpoint;
use super::PersistError;

/// What a recovery scan found.
#[derive(Debug, Default)]
pub struct RecoveryOutcome {
    /// The newest checkpoint that validated end-to-end, with its path.
    /// `None` when the directory holds no loadable checkpoint.
    pub recovered: Option<(PathBuf, Checkpoint)>,
    /// Corrupt files stepped over, each renamed to `<name>.corrupt`
    /// (recorded under its *original* path) with the validation error.
    pub quarantined: Vec<(PathBuf, String)>,
    /// Files skipped on IO errors — not quarantined, the bytes were
    /// never judged.
    pub skipped_io: Vec<(PathBuf, String)>,
}

/// Scan `dir` for `*.ckpt` files and load the newest valid one, falling
/// back past (and quarantining) corrupt files. Errors only when the
/// directory itself cannot be listed; an empty or all-corrupt directory
/// is `Ok` with `recovered: None`.
pub fn recover_latest(dir: &Path) -> Result<RecoveryOutcome, PersistError> {
    let mut candidates: Vec<(std::time::SystemTime, PathBuf)> = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.extension().and_then(|e| e.to_str()) != Some("ckpt") {
            continue;
        }
        // Skip in-flight tmp files (dot-prefixed) defensively; their
        // extension is `.tmp` so the filter above already drops them.
        let modified = entry
            .metadata()
            .and_then(|m| m.modified())
            .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
        candidates.push((modified, path));
    }
    // Newest first; name (descending) breaks same-timestamp ties.
    candidates.sort_by(|a, b| b.cmp(a));

    let mut outcome = RecoveryOutcome::default();
    for (_, path) in candidates {
        match Checkpoint::load(&path) {
            Ok(ck) => {
                outcome.recovered = Some((path, ck));
                break;
            }
            Err(PersistError::Io(e)) => {
                outcome.skipped_io.push((path, e.to_string()));
            }
            Err(e) => {
                // Corrupt class (BadMagic / UnsupportedVersion /
                // Truncated / ChecksumMismatch / Malformed): quarantine
                // so the next scan skips straight past it.
                let msg = e.to_string();
                let corrupt = quarantine_name(&path);
                if let Err(re) = std::fs::rename(&path, &corrupt) {
                    outcome
                        .quarantined
                        .push((path, format!("{msg} (quarantine rename failed: {re})")));
                } else {
                    outcome.quarantined.push((path, msg));
                }
            }
        }
    }
    Ok(outcome)
}

/// `<name>.corrupt` sibling of a quarantined checkpoint.
fn quarantine_name(path: &Path) -> PathBuf {
    let name = path.file_name().map(|n| n.to_string_lossy()).unwrap_or_default();
    path.with_file_name(format!("{name}.corrupt"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EpochStat;
    use crate::model::{SaeDims, SaeParams};
    use crate::persist::ModelBundle;
    use crate::rng::Xoshiro256pp;
    use crate::sparse::{compact_params, CompactPlan};

    fn sample_checkpoint(seed: u64) -> Checkpoint {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let dims = SaeDims { features: 6, hidden: 3, classes: 2 };
        let mut params = SaeParams::init(dims, &mut rng);
        let mut mask = vec![1.0f32; 6];
        mask[1] = 0.0;
        mask[4] = 0.0;
        params.apply_feature_mask(&mask);
        let plan = CompactPlan::from_mask(&mask);
        let compact = compact_params(&params, &plan);
        Checkpoint {
            seed,
            config_digest: 7,
            dims,
            history: vec![EpochStat {
                phase: 1,
                epoch: 0,
                train_loss: 0.5,
                train_accuracy: 0.5,
                test_accuracy: 0.5,
                alive_features: 4,
            }],
            model: Some(ModelBundle { plan, compact, dense: None }),
            train_state: None,
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("bilevel-recover-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    #[cfg_attr(miri, ignore = "real-filesystem test; relies on tmp dirs and mtimes")]
    fn empty_directory_recovers_nothing() {
        let dir = tmp_dir("empty");
        let out = recover_latest(&dir).unwrap();
        assert!(out.recovered.is_none());
        assert!(out.quarantined.is_empty() && out.skipped_io.is_empty());
        // a missing directory is an IO error, not a silent None
        assert!(recover_latest(&dir.join("nope")).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore = "real-filesystem test; relies on tmp dirs and mtimes")]
    fn picks_the_newest_valid_checkpoint() {
        let dir = tmp_dir("newest");
        for (i, seed) in [(1u32, 10u64), (2, 11), (3, 12)] {
            sample_checkpoint(seed).save(&dir.join(format!("epoch-{i:04}.ckpt"))).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(15));
        }
        let out = recover_latest(&dir).unwrap();
        let (path, ck) = out.recovered.expect("should recover");
        assert_eq!(ck.seed, 12);
        assert!(path.ends_with("epoch-0003.ckpt"), "{path:?}");
        assert!(out.quarantined.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore = "real-filesystem test; relies on tmp dirs and mtimes")]
    fn falls_back_past_corruption_and_quarantines() {
        let dir = tmp_dir("fallback");
        let good = sample_checkpoint(20);
        good.save(&dir.join("epoch-0001.ckpt")).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(15));
        sample_checkpoint(21).save(&dir.join("epoch-0002.ckpt")).unwrap();
        // Corrupt the newest on disk: flip one payload bit.
        let newest = dir.join("epoch-0002.ckpt");
        let mut bytes = std::fs::read(&newest).unwrap();
        let idx = bytes.len() - 30;
        bytes[idx] ^= 0x10;
        std::fs::write(&newest, &bytes).unwrap();

        let out = recover_latest(&dir).unwrap();
        let (path, ck) = out.recovered.expect("older snapshot must be recovered");
        assert_eq!(ck.seed, 20, "must fall back to the prior snapshot");
        assert!(path.ends_with("epoch-0001.ckpt"));
        // Bit-exact fallback: the recovered bytes equal the good save.
        assert_eq!(ck.to_bytes(), good.to_bytes());
        assert_eq!(out.quarantined.len(), 1);
        assert!(out.quarantined[0].1.contains("checksum"), "{:?}", out.quarantined);
        assert!(!newest.exists(), "corrupt file must be moved aside");
        assert!(dir.join("epoch-0002.ckpt.corrupt").exists());
        // A second scan does not re-judge the quarantined file.
        let again = recover_latest(&dir).unwrap();
        assert_eq!(again.recovered.unwrap().1.seed, 20);
        assert!(again.quarantined.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore = "real-filesystem test; relies on tmp dirs and mtimes")]
    fn all_corrupt_yields_none_and_quarantines_everything() {
        let dir = tmp_dir("allbad");
        for i in 1..=2 {
            let p = dir.join(format!("epoch-{i:04}.ckpt"));
            sample_checkpoint(30 + i).save(&p).unwrap();
            let bytes = std::fs::read(&p).unwrap();
            std::fs::write(&p, &bytes[..40]).unwrap(); // truncate into the header
        }
        let out = recover_latest(&dir).unwrap();
        assert!(out.recovered.is_none());
        assert_eq!(out.quarantined.len(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
