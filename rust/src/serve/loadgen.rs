//! Closed-loop load generator for the engine.
//!
//! Drives an [`Engine`] with a mixed-[`ProjectionKind`] workload from `N`
//! client threads, each cycling a shared pool of matrices (a small pool is
//! how the benches and tests provoke threshold-cache hits) and obeying the
//! engine's backpressure protocol: an `Overloaded` rejection sleeps for the
//! suggested `retry_after` and resubmits. Used by the `loadgen` and `serve`
//! CLI subcommands and `benches/serve_throughput.rs`.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::TomlDoc;
use crate::projection::ProjectionKind;
use crate::tensor::Matrix;

use super::engine::Engine;
use super::request::{ProjectionRequest, SubmitError};

/// Shape of the generated workload.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Concurrent closed-loop clients (threads).
    pub clients: usize,
    pub requests_per_client: usize,
    pub rows: usize,
    pub cols: usize,
    pub eta: f64,
    /// Kinds cycled per request.
    pub mix: Vec<ProjectionKind>,
    /// Distinct matrices shared by all clients; small pools repeat
    /// requests and exercise the threshold cache.
    pub pool: usize,
    /// Every `f32_every`-th request (per client) carries an `f32` payload;
    /// 0 keeps the workload pure `f64`.
    pub f32_every: usize,
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            clients: 4,
            requests_per_client: 64,
            rows: 128,
            cols: 128,
            eta: 1.0,
            mix: vec![
                ProjectionKind::BilevelL1Inf,
                ProjectionKind::BilevelL11,
                ProjectionKind::BilevelL12,
                ProjectionKind::ExactL1InfSsn,
            ],
            pool: 8,
            f32_every: 4,
            seed: 42,
        }
    }
}

impl LoadgenConfig {
    /// Build from a parsed TOML doc (`[loadgen]` section), defaults
    /// elsewhere.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self, String> {
        let d = Self::default();
        let mix = match doc.get("loadgen.mix") {
            Some(v) => v
                .as_str_array()
                .ok_or("loadgen.mix must be an array of strings")?
                .iter()
                .map(|s| {
                    ProjectionKind::parse(s)
                        .ok_or_else(|| format!("loadgen.mix: unknown projection {s:?}"))
                })
                .collect::<Result<Vec<_>, _>>()?,
            None => d.mix,
        };
        let cfg = Self {
            clients: doc.usize_or("loadgen.clients", d.clients),
            requests_per_client: doc
                .usize_or("loadgen.requests_per_client", d.requests_per_client),
            rows: doc.usize_or("loadgen.rows", d.rows),
            cols: doc.usize_or("loadgen.cols", d.cols),
            eta: doc.f64_or("loadgen.eta", d.eta),
            mix,
            pool: doc.usize_or("loadgen.pool", d.pool),
            f32_every: doc.usize_or("loadgen.f32_every", d.f32_every),
            seed: doc.usize_or("loadgen.seed", d.seed as usize) as u64,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn total_requests(&self) -> usize {
        self.clients * self.requests_per_client
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.clients == 0 {
            return Err("loadgen.clients must be >= 1".into());
        }
        if self.mix.is_empty() {
            return Err("loadgen.mix must not be empty".into());
        }
        if self.rows == 0 || self.cols == 0 {
            return Err("loadgen matrix shape must be non-empty".into());
        }
        if self.pool == 0 {
            return Err("loadgen.pool must be >= 1".into());
        }
        Ok(())
    }
}

/// Client-side view of a load run (the engine's own counters are reported
/// separately via [`Engine::stats`]).
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub completed: u64,
    /// Backpressure rejections that were retried.
    pub retries: u64,
    /// Requests abandoned (engine shut down or retry budget exhausted).
    pub failed: u64,
    pub cache_hits: u64,
    pub total_latency_micros: u64,
    pub max_latency_micros: u64,
    pub elapsed: Duration,
}

impl LoadReport {
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.elapsed.as_secs_f64();
        if secs > 0.0 {
            self.completed as f64 / secs
        } else {
            0.0
        }
    }

    pub fn mean_latency_micros(&self) -> f64 {
        if self.completed > 0 {
            self.total_latency_micros as f64 / self.completed as f64
        } else {
            0.0
        }
    }

    pub fn hit_fraction(&self) -> f64 {
        if self.completed > 0 {
            self.cache_hits as f64 / self.completed as f64
        } else {
            0.0
        }
    }

    fn absorb(&mut self, other: &LoadReport) {
        self.completed += other.completed;
        self.retries += other.retries;
        self.failed += other.failed;
        self.cache_hits += other.cache_hits;
        self.total_latency_micros += other.total_latency_micros;
        self.max_latency_micros = self.max_latency_micros.max(other.max_latency_micros);
    }
}

/// Run the closed-loop workload to completion and aggregate the clients'
/// local tallies.
pub fn run_loadgen(engine: &Engine, cfg: &LoadgenConfig) -> LoadReport {
    cfg.validate().expect("invalid loadgen config");
    let pool: Vec<Matrix<f64>> = {
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(cfg.seed);
        (0..cfg.pool).map(|_| Matrix::randn(cfg.rows, cfg.cols, &mut rng)).collect()
    };
    let pool32: Vec<Matrix<f32>> = if cfg.f32_every > 0 {
        pool.iter().map(|m| m.cast()).collect()
    } else {
        Vec::new()
    };
    let aggregate = Mutex::new(LoadReport::default());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for client in 0..cfg.clients {
            let pool = &pool;
            let pool32 = &pool32;
            let aggregate = &aggregate;
            s.spawn(move || {
                let mut local = LoadReport::default();
                for i in 0..cfg.requests_per_client {
                    let idx = (client + i) % pool.len();
                    let kind = cfg.mix[(client + i) % cfg.mix.len()];
                    let use_f32 = cfg.f32_every > 0 && (i + 1) % cfg.f32_every == 0;
                    let request = if use_f32 {
                        ProjectionRequest::f32(kind, cfg.eta, pool32[idx].clone())
                    } else {
                        ProjectionRequest::f64(kind, cfg.eta, pool[idx].clone())
                    };
                    let t = Instant::now();
                    let mut attempts = 0u32;
                    loop {
                        match engine.submit_wait(request.clone()) {
                            Ok(resp) => {
                                let micros = t.elapsed().as_micros() as u64;
                                local.completed += 1;
                                if resp.cache_hit {
                                    local.cache_hits += 1;
                                }
                                local.total_latency_micros += micros;
                                local.max_latency_micros = local.max_latency_micros.max(micros);
                                break;
                            }
                            Err(SubmitError::Overloaded { retry_after, .. }) => {
                                attempts += 1;
                                if attempts > 10_000 {
                                    local.failed += 1;
                                    break;
                                }
                                local.retries += 1;
                                std::thread::sleep(retry_after);
                            }
                            Err(_) => {
                                local.failed += 1;
                                break;
                            }
                        }
                    }
                }
                aggregate.lock().unwrap().absorb(&local);
            });
        }
    });
    let mut report = aggregate.into_inner().unwrap();
    report.elapsed = t0.elapsed();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{parse, ServeConfig};

    #[test]
    fn from_doc_parses_mix_and_sizes() {
        let doc = parse(
            r#"
            [loadgen]
            clients = 2
            requests_per_client = 3
            rows = 16
            cols = 8
            eta = 0.5
            pool = 2
            f32_every = 0
            seed = 7
            mix = ["bilevel-l1inf", "none"]
            "#,
        )
        .unwrap();
        let cfg = LoadgenConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.clients, 2);
        assert_eq!(cfg.total_requests(), 6);
        assert_eq!(cfg.mix, vec![ProjectionKind::BilevelL1Inf, ProjectionKind::None]);
        assert_eq!(cfg.eta, 0.5);
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn from_doc_rejects_unknown_kind() {
        let doc = parse("[loadgen]\nmix = [\"bogus\"]").unwrap();
        assert!(LoadgenConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn small_closed_loop_completes_every_request() {
        let engine = Engine::start(&ServeConfig {
            shards: 2,
            cache_capacity: 32,
            ..ServeConfig::default()
        })
        .unwrap();
        let cfg = LoadgenConfig {
            clients: 3,
            requests_per_client: 10,
            rows: 16,
            cols: 12,
            pool: 2,
            ..LoadgenConfig::default()
        };
        let report = run_loadgen(&engine, &cfg);
        assert_eq!(report.completed, 30);
        assert_eq!(report.failed, 0);
        assert!(report.elapsed > Duration::ZERO);
        assert!(report.throughput_rps() > 0.0);
        let stats = engine.shutdown();
        assert_eq!(stats.completed(), 30);
    }
}
