//! Closed-loop load generator for the engine.
//!
//! Drives an [`Engine`] with a mixed-[`ProjectionKind`] workload from `N`
//! client threads, each cycling a shared pool of matrices (a small pool is
//! how the benches and tests provoke threshold-cache hits) and obeying the
//! engine's backpressure protocol: an `Overloaded` rejection sleeps for the
//! suggested `retry_after` and resubmits. Used by the `loadgen` and `serve`
//! CLI subcommands and `benches/serve_throughput.rs`.
//!
//! Two drivers share the [`LoadgenConfig`] workload shape and the
//! [`LoadReport`] tally (mean + log-bucketed p50/p99/p999 latency):
//! [`run_loadgen`] calls the engine in-process; [`run_loadgen_net`] speaks
//! the `net` wire protocol over real sockets, honouring HTTP 429
//! backpressure via the `X-Retry-After-Micros` / `Retry-After` headers.
//!
//! Backoff is **jittered, capped exponential** layered on the advertised
//! retry-after: the server's hint is the base, doubled per consecutive
//! retry of the same request, capped at `backoff_cap_ms`, with equal
//! jitter (half fixed + half seeded-random) so synchronized clients
//! spread out. Every request has an explicit abandon budget
//! (`retry_budget` attempts) and the report distinguishes backoff
//! `retries` from connection `redials`. `chaos: true` additionally fires
//! the client-side [`crate::fault`] site `conn.slow_read` (stall between
//! request and response read — provoking server write timeouts) and
//! retries transient 5xx responses (`worker_panic`, `circuit_open`)
//! within the same budget.

use std::io::BufReader;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::TomlDoc;
use crate::fault::{self, FaultSite};
use crate::metrics::LatencyHistogram;
use crate::net::http::{self, HttpError, HttpLimits, Response};
use crate::net::wire;
use crate::projection::ProjectionKind;
use crate::rng::{Rng, Xoshiro256pp};
use crate::sync::lock_unpoisoned;
use crate::tensor::Matrix;

use super::engine::Engine;
use super::request::{ProjectionRequest, SubmitError};

/// Shape of the generated workload.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Concurrent closed-loop clients (threads).
    pub clients: usize,
    pub requests_per_client: usize,
    pub rows: usize,
    pub cols: usize,
    pub eta: f64,
    /// Kinds cycled per request.
    pub mix: Vec<ProjectionKind>,
    /// Distinct matrices shared by all clients; small pools repeat
    /// requests and exercise the threshold cache.
    pub pool: usize,
    /// Every `f32_every`-th request (per client) carries an `f32` payload;
    /// 0 keeps the workload pure `f64`.
    pub f32_every: usize,
    pub seed: u64,
    /// Abandon budget: attempts per request (first try + retries) before
    /// it is counted as `failed`.
    pub retry_budget: u32,
    /// Ceiling on one backoff sleep; the exponential doubling never
    /// exceeds it.
    pub backoff_cap_ms: u64,
    /// Chaos mode (`loadgen --chaos`): fire the client-side
    /// `conn.slow_read` fault site and retry transient 5xx within the
    /// budget. CLI-set; not a `[loadgen]` key.
    pub chaos: bool,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            clients: 4,
            requests_per_client: 64,
            rows: 128,
            cols: 128,
            eta: 1.0,
            mix: vec![
                ProjectionKind::BilevelL1Inf,
                ProjectionKind::BilevelL11,
                ProjectionKind::BilevelL12,
                ProjectionKind::ExactL1InfSsn,
            ],
            pool: 8,
            f32_every: 4,
            seed: 42,
            retry_budget: 10_000,
            backoff_cap_ms: 250,
            chaos: false,
        }
    }
}

impl LoadgenConfig {
    /// Build from a parsed TOML doc (`[loadgen]` section), defaults
    /// elsewhere.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self, String> {
        let d = Self::default();
        let mix = match doc.get("loadgen.mix") {
            Some(v) => v
                .as_str_array()
                .ok_or("loadgen.mix must be an array of strings")?
                .iter()
                .map(|s| {
                    ProjectionKind::parse(s)
                        .ok_or_else(|| format!("loadgen.mix: unknown projection {s:?}"))
                })
                .collect::<Result<Vec<_>, _>>()?,
            None => d.mix,
        };
        let cfg = Self {
            clients: doc.usize_or("loadgen.clients", d.clients),
            requests_per_client: doc
                .usize_or("loadgen.requests_per_client", d.requests_per_client),
            rows: doc.usize_or("loadgen.rows", d.rows),
            cols: doc.usize_or("loadgen.cols", d.cols),
            eta: doc.f64_or("loadgen.eta", d.eta),
            mix,
            pool: doc.usize_or("loadgen.pool", d.pool),
            f32_every: doc.usize_or("loadgen.f32_every", d.f32_every),
            seed: doc.usize_or("loadgen.seed", d.seed as usize) as u64,
            retry_budget: doc.usize_or("loadgen.retry_budget", d.retry_budget as usize) as u32,
            backoff_cap_ms: doc.usize_or("loadgen.backoff_cap_ms", d.backoff_cap_ms as usize)
                as u64,
            chaos: d.chaos,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn total_requests(&self) -> usize {
        self.clients * self.requests_per_client
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.clients == 0 {
            return Err("loadgen.clients must be >= 1".into());
        }
        if self.mix.is_empty() {
            return Err("loadgen.mix must not be empty".into());
        }
        if self.rows == 0 || self.cols == 0 {
            return Err("loadgen matrix shape must be non-empty".into());
        }
        if self.pool == 0 {
            return Err("loadgen.pool must be >= 1".into());
        }
        if self.retry_budget == 0 {
            return Err("loadgen.retry_budget must be >= 1".into());
        }
        if self.backoff_cap_ms == 0 {
            return Err("loadgen.backoff_cap_ms must be >= 1".into());
        }
        Ok(())
    }
}

/// Client-side view of a load run (the engine's own counters are reported
/// separately via [`Engine::stats`]).
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub completed: u64,
    /// Backpressure / transient-error rejections that were retried after
    /// a backoff sleep (the connection stayed up).
    pub retries: u64,
    /// Broken connections that were re-dialed (network mode only) —
    /// deliberately distinct from `retries`: a redial means the transport
    /// failed, not that the server pushed back.
    pub redials: u64,
    /// Requests abandoned (engine shut down or retry budget exhausted).
    pub failed: u64,
    pub cache_hits: u64,
    pub total_latency_micros: u64,
    pub max_latency_micros: u64,
    /// Log-bucketed per-request latency (≤12.5% relative error) for
    /// p50/p99/p999 tail reporting.
    pub latency: LatencyHistogram,
    pub elapsed: Duration,
}

impl LoadReport {
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.elapsed.as_secs_f64();
        if secs > 0.0 {
            self.completed as f64 / secs
        } else {
            0.0
        }
    }

    pub fn mean_latency_micros(&self) -> f64 {
        if self.completed > 0 {
            self.total_latency_micros as f64 / self.completed as f64
        } else {
            0.0
        }
    }

    pub fn hit_fraction(&self) -> f64 {
        if self.completed > 0 {
            self.cache_hits as f64 / self.completed as f64
        } else {
            0.0
        }
    }

    pub fn p50_micros(&self) -> u64 {
        self.latency.p50_micros()
    }

    pub fn p99_micros(&self) -> u64 {
        self.latency.p99_micros()
    }

    pub fn p999_micros(&self) -> u64 {
        self.latency.p999_micros()
    }

    /// `"p50 .. us, p99 .. us, p999 .. us, max .. us"`.
    pub fn latency_summary(&self) -> String {
        self.latency.summary()
    }

    fn record(&mut self, micros: u64, cache_hit: bool) {
        self.completed += 1;
        if cache_hit {
            self.cache_hits += 1;
        }
        self.total_latency_micros += micros;
        self.max_latency_micros = self.max_latency_micros.max(micros);
        self.latency.record_micros(micros);
    }

    fn absorb(&mut self, other: &LoadReport) {
        self.completed += other.completed;
        self.retries += other.retries;
        self.redials += other.redials;
        self.failed += other.failed;
        self.cache_hits += other.cache_hits;
        self.total_latency_micros += other.total_latency_micros;
        self.max_latency_micros = self.max_latency_micros.max(other.max_latency_micros);
        self.latency.merge(&other.latency);
    }
}

/// Run the closed-loop workload to completion and aggregate the clients'
/// local tallies.
pub fn run_loadgen(engine: &Engine, cfg: &LoadgenConfig) -> LoadReport {
    cfg.validate().expect("invalid loadgen config");
    let pool: Vec<Matrix<f64>> = {
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(cfg.seed);
        (0..cfg.pool).map(|_| Matrix::randn(cfg.rows, cfg.cols, &mut rng)).collect()
    };
    let pool32: Vec<Matrix<f32>> = if cfg.f32_every > 0 {
        pool.iter().map(|m| m.cast()).collect()
    } else {
        Vec::new()
    };
    let aggregate = Mutex::new(LoadReport::default());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for client in 0..cfg.clients {
            let pool = &pool;
            let pool32 = &pool32;
            let aggregate = &aggregate;
            s.spawn(move || {
                let mut local = LoadReport::default();
                let mut rng = client_rng(cfg.seed, client);
                let cap = Duration::from_millis(cfg.backoff_cap_ms);
                for i in 0..cfg.requests_per_client {
                    let idx = (client + i) % pool.len();
                    let kind = cfg.mix[(client + i) % cfg.mix.len()];
                    let use_f32 = cfg.f32_every > 0 && (i + 1) % cfg.f32_every == 0;
                    let request = if use_f32 {
                        ProjectionRequest::f32(kind, cfg.eta, pool32[idx].clone())
                    } else {
                        ProjectionRequest::f64(kind, cfg.eta, pool[idx].clone())
                    };
                    let t = Instant::now();
                    let mut retries = 0u32;
                    loop {
                        match engine.submit_wait(request.clone()) {
                            Ok(resp) => {
                                local.record(t.elapsed().as_micros() as u64, resp.cache_hit);
                                break;
                            }
                            Err(SubmitError::Overloaded { retry_after, .. }) => {
                                if retries + 1 >= cfg.retry_budget {
                                    local.failed += 1;
                                    break;
                                }
                                local.retries += 1;
                                let delay = backoff_delay(retry_after, retries, cap, &mut rng);
                                retries += 1;
                                std::thread::sleep(delay);
                            }
                            Err(_) => {
                                local.failed += 1;
                                break;
                            }
                        }
                    }
                }
                lock_unpoisoned(&aggregate).absorb(&local);
            });
        }
    });
    let mut report = aggregate.into_inner().unwrap();
    report.elapsed = t0.elapsed();
    report
}

/// One keep-alive client connection to a `net::Server`.
struct NetConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    limits: HttpLimits,
}

impl NetConn {
    fn connect(addr: &str) -> Result<Self, String> {
        let stream =
            TcpStream::connect(addr).map_err(|e| format!("connecting to {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
        let read_half = stream.try_clone().map_err(|e| format!("cloning socket: {e}"))?;
        Ok(Self {
            reader: BufReader::new(read_half),
            writer: stream,
            limits: HttpLimits::default(),
        })
    }

    fn post(
        &mut self,
        path: &str,
        headers: &[(String, String)],
        body: &[u8],
        stall: Option<Duration>,
    ) -> Result<Response, HttpError> {
        http::write_request(&mut self.writer, "POST", path, headers, body)?;
        // `conn.slow_read` (chaos mode): the request is written but this
        // client dawdles before reading the response — the server-side
        // view is a slow reader, provoking its write timeout.
        if let Some(d) = stall {
            std::thread::sleep(d);
        }
        http::read_response(&mut self.reader, &self.limits)
    }
}

/// Backoff suggested by a 429: the exact `X-Retry-After-Micros` header
/// when present, else `Retry-After` (whole seconds), else 1ms.
fn retry_after_of(resp: &Response) -> Duration {
    if let Some(us) = resp.header("x-retry-after-micros").and_then(|v| v.parse::<u64>().ok()) {
        return Duration::from_micros(us);
    }
    if let Some(secs) = resp.header("retry-after").and_then(|v| v.parse::<u64>().ok()) {
        return Duration::from_secs(secs);
    }
    Duration::from_millis(1)
}

/// One backoff sleep: the advertised hint (floored at 100µs) doubled per
/// consecutive retry of the same request, capped at `cap`, with equal
/// jitter — half the capped delay is fixed, half uniformly random from
/// the client's seeded stream, so synchronized clients fan out
/// deterministically per seed.
fn backoff_delay(
    advertised: Duration,
    retry_index: u32,
    cap: Duration,
    rng: &mut Xoshiro256pp,
) -> Duration {
    let base = advertised.max(Duration::from_micros(100));
    let doubled = base.saturating_mul(1u32 << retry_index.min(20));
    let capped = doubled.min(cap);
    let half = capped / 2;
    let span = (half.as_micros() as u64).max(1);
    half + Duration::from_micros(rng.next_u64() % span)
}

/// Per-client backoff RNG stream, decorrelated from the matrix-pool seed.
fn client_rng(seed: u64, client: usize) -> Xoshiro256pp {
    Xoshiro256pp::seed_from_u64(
        seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(client as u64 + 1),
    )
}

/// Network-mode driver: the same closed-loop workload as [`run_loadgen`],
/// but through a `net::Server` at `addr` over real sockets (`POST
/// /v1/project`, one keep-alive connection per client, distinct
/// `X-Client-Id`s so quota buckets are per client). 429 responses sleep
/// for the advertised retry-after and resubmit; a broken connection is
/// re-dialed and the request retried. `Err` only if a client never
/// manages to connect at all.
pub fn run_loadgen_net(addr: &str, cfg: &LoadgenConfig) -> Result<LoadReport, String> {
    cfg.validate()?;
    let pool: Vec<Matrix<f64>> = {
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(cfg.seed);
        (0..cfg.pool).map(|_| Matrix::randn(cfg.rows, cfg.cols, &mut rng)).collect()
    };
    let pool32: Vec<Matrix<f32>> = if cfg.f32_every > 0 {
        pool.iter().map(|m| m.cast()).collect()
    } else {
        Vec::new()
    };
    let aggregate = Mutex::new(LoadReport::default());
    let connect_errors = Mutex::new(Vec::<String>::new());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for client in 0..cfg.clients {
            let pool = &pool;
            let pool32 = &pool32;
            let aggregate = &aggregate;
            let connect_errors = &connect_errors;
            s.spawn(move || {
                let mut conn = match NetConn::connect(addr) {
                    Ok(c) => c,
                    Err(e) => {
                        lock_unpoisoned(&connect_errors).push(e);
                        return;
                    }
                };
                let headers =
                    vec![("X-Client-Id".to_string(), format!("loadgen-{client}"))];
                let mut local = LoadReport::default();
                let mut rng = client_rng(cfg.seed, client);
                let cap = Duration::from_millis(cfg.backoff_cap_ms);
                for i in 0..cfg.requests_per_client {
                    let idx = (client + i) % pool.len();
                    let kind = cfg.mix[(client + i) % cfg.mix.len()];
                    let use_f32 = cfg.f32_every > 0 && (i + 1) % cfg.f32_every == 0;
                    let request = if use_f32 {
                        ProjectionRequest::f32(kind, cfg.eta, pool32[idx].clone())
                    } else {
                        ProjectionRequest::f64(kind, cfg.eta, pool[idx].clone())
                    };
                    let body = wire::project_request_body(&request);
                    let t = Instant::now();
                    let mut attempts = 0u32;
                    let mut retries = 0u32;
                    loop {
                        attempts += 1;
                        if attempts > cfg.retry_budget {
                            local.failed += 1;
                            break;
                        }
                        let stall = if cfg.chaos {
                            fault::fire(FaultSite::ConnSlowRead).map(Duration::from_millis)
                        } else {
                            None
                        };
                        match conn.post("/v1/project", &headers, body.as_bytes(), stall) {
                            Ok(resp) if resp.status == 200 => {
                                let micros = t.elapsed().as_micros() as u64;
                                // wire-format-aware fast path:
                                // `wire::response_body` always emits this
                                // exact key, so a substring check avoids
                                // re-parsing the matrix payload per request
                                let needle: &[u8] = b"\"cache_hit\":true";
                                let hit =
                                    resp.body.windows(needle.len()).any(|w| w == needle);
                                local.record(micros, hit);
                                break;
                            }
                            Ok(resp)
                                if resp.status == 429
                                    || (cfg.chaos
                                        && (resp.status == 500 || resp.status == 503)) =>
                            {
                                // 429 always backs off; chaos mode also
                                // treats worker_panic (500) and
                                // circuit_open / draining (503) as
                                // transient within the same budget.
                                local.retries += 1;
                                let delay = backoff_delay(
                                    retry_after_of(&resp),
                                    retries,
                                    cap,
                                    &mut rng,
                                );
                                retries += 1;
                                std::thread::sleep(delay);
                            }
                            Ok(_) => {
                                // 4xx/5xx other than backpressure: no retry
                                local.failed += 1;
                                break;
                            }
                            Err(_) => match NetConn::connect(addr) {
                                Ok(c) => {
                                    local.redials += 1;
                                    conn = c;
                                }
                                Err(_) => {
                                    local.failed += 1;
                                    break;
                                }
                            },
                        }
                    }
                }
                lock_unpoisoned(&aggregate).absorb(&local);
            });
        }
    });
    let errors = connect_errors.into_inner().unwrap();
    if let Some(e) = errors.into_iter().next() {
        return Err(e);
    }
    let mut report = aggregate.into_inner().unwrap();
    report.elapsed = t0.elapsed();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{parse, ServeConfig};

    #[test]
    fn from_doc_parses_mix_and_sizes() {
        let doc = parse(
            r#"
            [loadgen]
            clients = 2
            requests_per_client = 3
            rows = 16
            cols = 8
            eta = 0.5
            pool = 2
            f32_every = 0
            seed = 7
            retry_budget = 12
            backoff_cap_ms = 40
            mix = ["bilevel-l1inf", "none"]
            "#,
        )
        .unwrap();
        let cfg = LoadgenConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.clients, 2);
        assert_eq!(cfg.total_requests(), 6);
        assert_eq!(cfg.mix, vec![ProjectionKind::BilevelL1Inf, ProjectionKind::None]);
        assert_eq!(cfg.eta, 0.5);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.retry_budget, 12);
        assert_eq!(cfg.backoff_cap_ms, 40);
        assert!(!cfg.chaos, "chaos is CLI-set, never a config default");
    }

    #[test]
    fn backoff_is_capped_jittered_and_deterministic() {
        let cap = Duration::from_millis(10);
        let hint = Duration::from_millis(1);
        let mut a = client_rng(7, 0);
        let mut b = client_rng(7, 0);
        assert_eq!(
            backoff_delay(hint, 0, cap, &mut a),
            backoff_delay(hint, 0, cap, &mut b),
            "same seed, same jitter"
        );
        // the exponential doubling never escapes the cap, and equal
        // jitter keeps at least half of it
        let d = backoff_delay(hint, 30, cap, &mut a);
        assert!(d <= cap, "{d:?}");
        assert!(d >= cap / 2, "{d:?}");
        // a zero advertised hint still sleeps a little
        assert!(backoff_delay(Duration::ZERO, 0, cap, &mut a) > Duration::ZERO);
    }

    #[test]
    fn zero_budgets_are_rejected() {
        let bad = LoadgenConfig { retry_budget: 0, ..LoadgenConfig::default() };
        assert!(bad.validate().is_err());
        let bad = LoadgenConfig { backoff_cap_ms: 0, ..LoadgenConfig::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn from_doc_rejects_unknown_kind() {
        let doc = parse("[loadgen]\nmix = [\"bogus\"]").unwrap();
        assert!(LoadgenConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn small_closed_loop_completes_every_request() {
        let engine = Engine::start(&ServeConfig {
            shards: 2,
            cache_capacity: 32,
            ..ServeConfig::default()
        })
        .unwrap();
        let cfg = LoadgenConfig {
            clients: 3,
            requests_per_client: 10,
            rows: 16,
            cols: 12,
            pool: 2,
            ..LoadgenConfig::default()
        };
        let report = run_loadgen(&engine, &cfg);
        assert_eq!(report.completed, 30);
        assert_eq!(report.failed, 0);
        assert!(report.elapsed > Duration::ZERO);
        assert!(report.throughput_rps() > 0.0);
        // the histogram tallies every completion and its quantiles are
        // ordered and bounded by the exact max
        assert_eq!(report.latency.count(), 30);
        assert!(report.p50_micros() <= report.p99_micros());
        assert!(report.p99_micros() <= report.p999_micros());
        assert!(report.p999_micros() <= report.max_latency_micros);
        assert!(report.latency_summary().contains("p99"));
        let stats = engine.shutdown();
        assert_eq!(stats.completed(), 30);
    }
}
