//! Per-model circuit breaker for the sparse-encode path.
//!
//! Classic three-state machine, one gate per registered model id:
//!
//! * **Closed** — traffic flows; consecutive execution failures are
//!   counted, successes reset the count.
//! * **Open** — tripped after `threshold` consecutive failures; encode
//!   admissions are refused with the remaining cooldown as the suggested
//!   retry-after (the net layer turns this into a 503 + `Retry-After`).
//! * **Half-open** — after the cooldown one probe request is admitted;
//!   success closes the gate, failure re-opens it for a full cooldown.
//!
//! Failures here mean *execution* failures the supervisor caught (a
//! worker panic inside an encode job) — admission rejections like
//! overload or invalid dims never touch the breaker.
//!
//! Memory ordering: deliberately none to audit. All shared state lives
//! behind the single `gates` mutex — state transitions read-modify-write a
//! whole `Gate`, which a lone atomic cannot express without races between
//! the failure counter and the trip decision, so this module uses no
//! atomics at all.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::sync::lock_unpoisoned;

/// Public view of one gate's state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

impl BreakerState {
    pub fn name(self) -> &'static str {
        match self {
            Self::Closed => "closed",
            Self::Open => "open",
            Self::HalfOpen => "half-open",
        }
    }
}

#[derive(Clone, Copy, Debug)]
enum Gate {
    Closed { failures: u32 },
    Open { until: Instant },
    HalfOpen,
}

/// Per-model circuit breaker shared by the engine's admission path and
/// its workers.
#[derive(Debug)]
pub struct CircuitBreaker {
    threshold: u32,
    cooldown: Duration,
    gates: Mutex<HashMap<u64, Gate>>,
}

impl CircuitBreaker {
    /// `threshold` consecutive failures trip a gate open for `cooldown`.
    pub fn new(threshold: u32, cooldown: Duration) -> Self {
        Self {
            threshold: threshold.max(1),
            cooldown,
            gates: Mutex::new(HashMap::new()),
        }
    }

    /// Admission check for `model`. `Ok(())` lets the request through
    /// (including the single half-open probe); `Err(retry_after)` refuses
    /// it with the suggested backoff.
    pub fn admit(&self, model: u64) -> Result<(), Duration> {
        let mut gates = lock_unpoisoned(&self.gates);
        let gate = gates.entry(model).or_insert(Gate::Closed { failures: 0 });
        match *gate {
            Gate::Closed { .. } => Ok(()),
            Gate::HalfOpen => Err(self.cooldown),
            Gate::Open { until } => {
                let now = Instant::now();
                if now >= until {
                    *gate = Gate::HalfOpen;
                    Ok(())
                } else {
                    Err(until - now)
                }
            }
        }
    }

    /// Record a successful encode execution: closes the gate and resets
    /// the failure count.
    pub fn record_success(&self, model: u64) {
        let mut gates = lock_unpoisoned(&self.gates);
        gates.insert(model, Gate::Closed { failures: 0 });
    }

    /// Record an execution failure: counts toward the trip threshold, and
    /// re-opens immediately from half-open.
    pub fn record_failure(&self, model: u64) {
        let mut gates = lock_unpoisoned(&self.gates);
        let gate = gates.entry(model).or_insert(Gate::Closed { failures: 0 });
        *gate = match *gate {
            Gate::Closed { failures } => {
                let failures = failures + 1;
                if failures >= self.threshold {
                    Gate::Open { until: Instant::now() + self.cooldown }
                } else {
                    Gate::Closed { failures }
                }
            }
            Gate::HalfOpen => Gate::Open { until: Instant::now() + self.cooldown },
            open @ Gate::Open { .. } => open,
        };
    }

    /// Current state of `model`'s gate (`Closed` if never seen).
    pub fn state(&self, model: u64) -> BreakerState {
        match lock_unpoisoned(&self.gates).get(&model) {
            None | Some(Gate::Closed { .. }) => BreakerState::Closed,
            Some(Gate::Open { .. }) => BreakerState::Open,
            Some(Gate::HalfOpen) => BreakerState::HalfOpen,
        }
    }

    /// Drop the gate for an unregistered model.
    pub fn forget(&self, model: u64) {
        lock_unpoisoned(&self.gates).remove(&model);
    }

    /// Models whose gate is not closed, for health reporting.
    pub fn impaired(&self) -> Vec<(u64, BreakerState)> {
        let gates = lock_unpoisoned(&self.gates);
        let mut out: Vec<(u64, BreakerState)> = gates
            .iter()
            .filter_map(|(&model, gate)| match gate {
                Gate::Closed { .. } => None,
                Gate::Open { .. } => Some((model, BreakerState::Open)),
                Gate::HalfOpen => Some((model, BreakerState::HalfOpen)),
            })
            .collect();
        out.sort_by_key(|(model, _)| *model);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trips_after_threshold_consecutive_failures() {
        let b = CircuitBreaker::new(3, Duration::from_secs(60));
        assert!(b.admit(1).is_ok());
        b.record_failure(1);
        b.record_failure(1);
        assert_eq!(b.state(1), BreakerState::Closed);
        assert!(b.admit(1).is_ok(), "still closed below threshold");
        b.record_failure(1);
        assert_eq!(b.state(1), BreakerState::Open);
        let retry = b.admit(1).unwrap_err();
        assert!(retry > Duration::ZERO && retry <= Duration::from_secs(60));
        // other models unaffected
        assert!(b.admit(2).is_ok());
        assert_eq!(b.impaired(), vec![(1, BreakerState::Open)]);
    }

    #[test]
    fn success_resets_the_failure_count() {
        let b = CircuitBreaker::new(2, Duration::from_secs(60));
        b.record_failure(5);
        b.record_success(5);
        b.record_failure(5);
        assert_eq!(b.state(5), BreakerState::Closed, "count reset by success");
        b.record_failure(5);
        assert_eq!(b.state(5), BreakerState::Open);
    }

    #[test]
    fn half_open_probe_closes_on_success_reopens_on_failure() {
        let b = CircuitBreaker::new(1, Duration::from_millis(20));
        b.record_failure(9);
        assert_eq!(b.state(9), BreakerState::Open);
        assert!(b.admit(9).is_err(), "inside cooldown");
        std::thread::sleep(Duration::from_millis(30));
        assert!(b.admit(9).is_ok(), "cooldown elapsed: probe admitted");
        assert_eq!(b.state(9), BreakerState::HalfOpen);
        assert!(b.admit(9).is_err(), "only one probe at a time");
        b.record_failure(9);
        assert_eq!(b.state(9), BreakerState::Open, "probe failure re-opens");
        std::thread::sleep(Duration::from_millis(30));
        assert!(b.admit(9).is_ok());
        b.record_success(9);
        assert_eq!(b.state(9), BreakerState::Closed, "probe success closes");
        assert!(b.admit(9).is_ok());
    }

    #[test]
    fn poisoned_lock_keeps_breaker_answering() {
        // Regression for the `lock_unpoisoned` migration: a worker panic
        // while holding the gates lock must not turn every subsequent
        // admission check into a poison panic.
        let b = CircuitBreaker::new(2, Duration::from_secs(60));
        b.record_failure(7);
        let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = b.gates.lock().unwrap();
            panic!("poison the gates lock");
        }));
        assert!(unwound.is_err());
        assert!(b.gates.lock().is_err(), "lock must actually be poisoned");
        assert!(b.admit(7).is_ok(), "admit must answer on a poisoned lock");
        b.record_failure(7);
        assert_eq!(b.state(7), BreakerState::Open, "state machine still works");
    }

    #[test]
    fn forget_drops_the_gate() {
        let b = CircuitBreaker::new(1, Duration::from_secs(60));
        b.record_failure(3);
        assert_eq!(b.state(3), BreakerState::Open);
        b.forget(3);
        assert_eq!(b.state(3), BreakerState::Closed);
        assert!(b.impaired().is_empty());
        assert_eq!(BreakerState::HalfOpen.name(), "half-open");
    }
}
