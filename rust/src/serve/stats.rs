//! Per-shard telemetry, published through [`crate::metrics::counters`].
//!
//! Workers bump relaxed atomic counters on the hot path; [`ShardStats`] /
//! [`EngineStats`] are point-in-time snapshots with the derived rates
//! (hit-rate, mean batch size, throughput) the CLI and benches report.

use std::fmt;
use std::time::Duration;

use crate::metrics::{Counter, LatencyStat};

/// Live (atomic) counters owned by one shard.
#[derive(Debug, Default)]
pub(crate) struct ShardCounters {
    pub submitted: Counter,
    pub completed: Counter,
    pub rejected: Counter,
    pub batches: Counter,
    pub batched_jobs: Counter,
    pub cache_hits: Counter,
    pub cache_misses: Counter,
    /// Jobs that failed because their worker panicked mid-execution.
    pub worker_panics: Counter,
    /// Times a supervised worker was respawned after a panic.
    pub worker_restarts: Counter,
    pub queue_wait: LatencyStat,
    pub exec: LatencyStat,
}

impl ShardCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn snapshot(&self, shard: usize, depth: usize) -> ShardStats {
        let batches = self.batches.get();
        let batched_jobs = self.batched_jobs.get();
        let hits = self.cache_hits.get();
        let misses = self.cache_misses.get();
        ShardStats {
            shard,
            depth,
            submitted: self.submitted.get(),
            completed: self.completed.get(),
            rejected: self.rejected.get(),
            batches,
            batched_jobs,
            cache_hits: hits,
            cache_misses: misses,
            worker_panics: self.worker_panics.get(),
            worker_restarts: self.worker_restarts.get(),
            mean_batch: if batches > 0 { batched_jobs as f64 / batches as f64 } else { 0.0 },
            hit_rate: if hits + misses > 0 { hits as f64 / (hits + misses) as f64 } else { 0.0 },
            mean_queue_micros: self.queue_wait.mean_micros(),
            mean_exec_micros: self.exec.mean_micros(),
            max_exec_micros: self.exec.max_micros(),
        }
    }
}

/// Snapshot of one shard's counters.
#[derive(Clone, Debug)]
pub struct ShardStats {
    pub shard: usize,
    /// Queue depth at snapshot time.
    pub depth: usize,
    pub submitted: u64,
    pub completed: u64,
    /// Submissions rejected at the backpressure high-water mark.
    pub rejected: u64,
    /// Execution batches run.
    pub batches: u64,
    /// Jobs executed across all batches (= completed, kept separate so the
    /// mean batch size is self-describing).
    pub batched_jobs: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Jobs failed by a mid-execution worker panic.
    pub worker_panics: u64,
    /// Supervised worker respawns after panics.
    pub worker_restarts: u64,
    pub mean_batch: f64,
    pub hit_rate: f64,
    pub mean_queue_micros: f64,
    pub mean_exec_micros: f64,
    pub max_exec_micros: u64,
}

/// The serve health machine's three states, surfaced through `/healthz`
/// and `/v1/stats`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// Full capacity, no tripped breakers, no recent worker restarts.
    Healthy,
    /// Serving, but impaired: an open/probing circuit breaker or a recent
    /// worker restart. Reasons are listed in [`HealthReport::reasons`].
    Degraded,
    /// Graceful drain in progress; new work is refused.
    Draining,
}

impl HealthState {
    pub fn name(self) -> &'static str {
        match self {
            Self::Healthy => "healthy",
            Self::Degraded => "degraded",
            Self::Draining => "draining",
        }
    }
}

/// A health state plus the human-readable reasons behind it (empty when
/// healthy).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HealthReport {
    pub state: HealthState,
    pub reasons: Vec<String>,
}

impl HealthReport {
    pub fn healthy() -> Self {
        Self { state: HealthState::Healthy, reasons: Vec::new() }
    }

    pub fn degraded(reasons: Vec<String>) -> Self {
        Self { state: HealthState::Degraded, reasons }
    }

    pub fn draining() -> Self {
        Self { state: HealthState::Draining, reasons: vec!["drain in progress".into()] }
    }
}

impl Default for HealthReport {
    fn default() -> Self {
        Self::healthy()
    }
}

/// Snapshot of a whole engine.
#[derive(Clone, Debug)]
pub struct EngineStats {
    pub uptime: Duration,
    pub shards: Vec<ShardStats>,
    /// The engine-level health machine state at snapshot time (the net
    /// layer overrides this to `Draining` while a drain is in progress).
    pub health: HealthReport,
}

impl EngineStats {
    pub fn submitted(&self) -> u64 {
        self.shards.iter().map(|s| s.submitted).sum()
    }

    pub fn completed(&self) -> u64 {
        self.shards.iter().map(|s| s.completed).sum()
    }

    pub fn rejected(&self) -> u64 {
        self.shards.iter().map(|s| s.rejected).sum()
    }

    pub fn cache_hits(&self) -> u64 {
        self.shards.iter().map(|s| s.cache_hits).sum()
    }

    pub fn cache_misses(&self) -> u64 {
        self.shards.iter().map(|s| s.cache_misses).sum()
    }

    pub fn worker_panics(&self) -> u64 {
        self.shards.iter().map(|s| s.worker_panics).sum()
    }

    pub fn worker_restarts(&self) -> u64 {
        self.shards.iter().map(|s| s.worker_restarts).sum()
    }

    /// Cache hit-rate over the cacheable (bi-level) traffic.
    pub fn hit_rate(&self) -> f64 {
        let hits = self.cache_hits();
        let total = hits + self.cache_misses();
        if total > 0 {
            hits as f64 / total as f64
        } else {
            0.0
        }
    }

    /// Mean executed batch size across shards.
    pub fn mean_batch(&self) -> f64 {
        let batches: u64 = self.shards.iter().map(|s| s.batches).sum();
        let jobs: u64 = self.shards.iter().map(|s| s.batched_jobs).sum();
        if batches > 0 {
            jobs as f64 / batches as f64
        } else {
            0.0
        }
    }

    /// Completed requests per second of engine uptime.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.uptime.as_secs_f64();
        if secs > 0.0 {
            self.completed() as f64 / secs
        } else {
            0.0
        }
    }
}

impl fmt::Display for EngineStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "serve: uptime {:.2}s | completed {} | rejected {} | {:.0} req/s | mean batch {:.2} | cache hit-rate {:.1}%",
            self.uptime.as_secs_f64(),
            self.completed(),
            self.rejected(),
            self.throughput_rps(),
            self.mean_batch(),
            self.hit_rate() * 100.0,
        )?;
        write!(f, "health: {}", self.health.state.name())?;
        if self.worker_panics() > 0 || self.worker_restarts() > 0 {
            write!(
                f,
                " | worker panics {} | restarts {}",
                self.worker_panics(),
                self.worker_restarts()
            )?;
        }
        if self.health.reasons.is_empty() {
            writeln!(f)?;
        } else {
            writeln!(f, " ({})", self.health.reasons.join("; "))?;
        }
        writeln!(
            f,
            "  {:>5} {:>6} {:>9} {:>9} {:>8} {:>7} {:>7} {:>7} {:>10} {:>10}",
            "shard", "depth", "submitted", "completed", "rejected", "batches", "mbatch", "hits", "queue(us)", "exec(us)"
        )?;
        for s in &self.shards {
            writeln!(
                f,
                "  {:>5} {:>6} {:>9} {:>9} {:>8} {:>7} {:>7.2} {:>7} {:>10.0} {:>10.0}",
                s.shard,
                s.depth,
                s.submitted,
                s.completed,
                s.rejected,
                s.batches,
                s.mean_batch,
                s.cache_hits,
                s.mean_queue_micros,
                s.mean_exec_micros,
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_derives_rates() {
        let c = ShardCounters::new();
        c.submitted.add(10);
        c.completed.add(8);
        c.rejected.add(2);
        c.batches.add(4);
        c.batched_jobs.add(8);
        c.cache_hits.add(3);
        c.cache_misses.add(1);
        c.queue_wait.record_micros(100);
        c.exec.record_micros(50);
        c.exec.record_micros(150);
        let s = c.snapshot(1, 5);
        assert_eq!(s.shard, 1);
        assert_eq!(s.depth, 5);
        assert_eq!(s.mean_batch, 2.0);
        assert_eq!(s.hit_rate, 0.75);
        assert_eq!(s.mean_exec_micros, 100.0);
        assert_eq!(s.max_exec_micros, 150);
    }

    #[test]
    fn engine_stats_aggregate_and_render() {
        let a = ShardCounters::new();
        a.completed.add(6);
        a.cache_hits.add(2);
        a.cache_misses.add(2);
        a.batches.add(3);
        a.batched_jobs.add(6);
        let b = ShardCounters::new();
        b.completed.add(4);
        b.cache_misses.add(4);
        b.batches.add(4);
        b.batched_jobs.add(4);
        let stats = EngineStats {
            uptime: Duration::from_secs(2),
            shards: vec![a.snapshot(0, 0), b.snapshot(1, 1)],
            health: HealthReport::healthy(),
        };
        assert_eq!(stats.completed(), 10);
        assert_eq!(stats.cache_hits(), 2);
        assert!((stats.hit_rate() - 0.25).abs() < 1e-12);
        assert!((stats.throughput_rps() - 5.0).abs() < 1e-12);
        assert!((stats.mean_batch() - 10.0 / 7.0).abs() < 1e-12);
        let rendered = format!("{stats}");
        assert!(rendered.contains("shard"), "{rendered}");
        assert!(rendered.contains("hit-rate"), "{rendered}");
    }

    #[test]
    fn empty_engine_stats_are_zero() {
        let stats = EngineStats {
            uptime: Duration::ZERO,
            shards: vec![],
            health: HealthReport::default(),
        };
        assert_eq!(stats.completed(), 0);
        assert_eq!(stats.hit_rate(), 0.0);
        assert_eq!(stats.mean_batch(), 0.0);
        assert_eq!(stats.throughput_rps(), 0.0);
        assert_eq!(stats.worker_panics(), 0);
        assert_eq!(stats.health.state, HealthState::Healthy);
    }

    #[test]
    fn health_states_render_with_reasons() {
        let c = ShardCounters::new();
        c.worker_panics.inc();
        c.worker_restarts.inc();
        let snap = c.snapshot(0, 0);
        assert_eq!((snap.worker_panics, snap.worker_restarts), (1, 1));
        let stats = EngineStats {
            uptime: Duration::from_secs(1),
            shards: vec![snap],
            health: HealthReport::degraded(vec!["worker restarted 0.1s ago".into()]),
        };
        let rendered = format!("{stats}");
        assert!(rendered.contains("health: degraded"), "{rendered}");
        assert!(rendered.contains("worker restarted"), "{rendered}");
        assert!(rendered.contains("restarts 1"), "{rendered}");
        assert_eq!(HealthState::Draining.name(), "draining");
        assert_eq!(HealthReport::draining().state, HealthState::Draining);
    }
}
