//! LRU threshold cache.
//!
//! The expensive half of a bi-level projection is the column aggregation +
//! inner ℓ1 projection that produces the per-column thresholds `û`
//! ([`crate::projection::bilevel::BilevelResult::thresholds`]). For a
//! repeated (matrix, η) pair the thresholds are identical, so caching them
//! lets the engine skip straight to the O(nm) outer column stage — and the
//! replay (`scheduler::replay`) mirrors the library loop bit-for-bit, so a
//! hit returns exactly the matrix a cold call would.
//!
//! Keys combine a 128-bit fingerprint of the matrix contents (see
//! [`fingerprint`]) with the radius bits, kind, inner solver, dtype, and
//! shape. Entries carry a monotonic last-used tick; eviction removes the
//! stalest entry (classic LRU, implemented as an O(capacity) scan —
//! capacities are small).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::projection::l1::L1Algorithm;
use crate::projection::ProjectionKind;
use crate::scalar::Scalar;
use crate::sync::lock_unpoisoned;
use crate::tensor::Matrix;

use super::request::Dtype;

/// 128-bit content fingerprint over the matrix shape and element bit
/// patterns (`f32` widens to `f64` exactly, so the fingerprint is
/// dtype-stable; the cache key carries the dtype separately).
///
/// Two independent 64-bit lanes: plain FNV-1a, and FNV-1a over
/// splitmix64-finalized words from a different basis. A hit is **not**
/// re-verified against the matrix contents (that would cost the same
/// O(nm) pass the cache exists to save), so correctness rests on the
/// ~2⁻⁶⁴ accidental collision probability of the combined 128 bits — fine
/// for trusted traffic, not a defence against adversarially crafted
/// payloads.
pub fn fingerprint<T: Scalar>(y: &Matrix<T>) -> u128 {
    hash128_words(
        [y.rows() as u64, y.cols() as u64]
            .into_iter()
            .chain(y.as_slice().iter().map(|&x| x.to_f64().to_bits())),
    )
}

/// The two-lane word hash behind [`fingerprint`], exposed so other
/// integrity checks (notably the [`crate::persist`] checkpoint footer)
/// share the exact same collision characteristics instead of inventing a
/// weaker ad-hoc hash.
pub fn hash128_words(words: impl IntoIterator<Item = u64>) -> u128 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h1: u64 = 0xcbf2_9ce4_8422_2325; // FNV offset basis
    let mut h2: u64 = 0x9e37_79b9_7f4a_7c15; // independent lane basis
    for v in words {
        h1 = (h1 ^ v).wrapping_mul(PRIME);
        h2 = (h2 ^ splitmix64(v)).wrapping_mul(PRIME);
    }
    ((h1 as u128) << 64) | h2 as u128
}

/// splitmix64 finalizer (the word scrambler of the second lane).
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Full identity of a cached threshold vector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub fingerprint: u128,
    /// `f64::to_bits` of the request η (bit-exact matching, no epsilon).
    pub eta_bits: u64,
    pub kind: ProjectionKind,
    pub algo: L1Algorithm,
    pub dtype: Dtype,
    pub rows: usize,
    pub cols: usize,
}

impl CacheKey {
    /// Build the key for a request payload.
    pub fn for_matrix<T: Scalar>(
        y: &Matrix<T>,
        eta: f64,
        kind: ProjectionKind,
        algo: L1Algorithm,
        dtype: Dtype,
    ) -> Self {
        Self {
            fingerprint: fingerprint(y),
            eta_bits: eta.to_bits(),
            kind,
            algo,
            dtype,
            rows: y.rows(),
            cols: y.cols(),
        }
    }
}

/// Threshold vector stored in the dtype it was computed in, so replays are
/// bit-identical (no f32 ↔ f64 round-trips).
#[derive(Clone, Debug, PartialEq)]
pub enum CachedThresholds {
    F32(Vec<f32>),
    F64(Vec<f64>),
}

impl CachedThresholds {
    pub fn len(&self) -> usize {
        match self {
            Self::F32(v) => v.len(),
            Self::F64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Scalar types whose threshold vectors the cache can store natively.
/// `unwrap` borrows through the cached entry — a hit never copies the
/// threshold vector (the `Arc` handed out by [`ThresholdCache::get`]
/// keeps the storage alive while the replay reads it).
pub trait ThresholdScalar: Scalar {
    fn wrap(v: Vec<Self>) -> CachedThresholds;
    fn unwrap(ct: &CachedThresholds) -> Option<&[Self]>;
}

impl ThresholdScalar for f32 {
    fn wrap(v: Vec<Self>) -> CachedThresholds {
        CachedThresholds::F32(v)
    }
    fn unwrap(ct: &CachedThresholds) -> Option<&[Self]> {
        match ct {
            CachedThresholds::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl ThresholdScalar for f64 {
    fn wrap(v: Vec<Self>) -> CachedThresholds {
        CachedThresholds::F64(v)
    }
    fn unwrap(ct: &CachedThresholds) -> Option<&[Self]> {
        match ct {
            CachedThresholds::F64(v) => Some(v),
            _ => None,
        }
    }
}

struct Entry {
    /// Shared, not owned: a hit hands out a clone of this `Arc` while the
    /// shard-shared mutex is held, so the lock covers a pointer bump, not
    /// an O(cols) vector copy (which serialized every hit on large
    /// models).
    thresholds: Arc<CachedThresholds>,
    last_used: u64,
}

struct Inner {
    map: HashMap<CacheKey, Entry>,
    tick: u64,
}

/// Thread-safe LRU cache shared by every shard of an engine.
pub struct ThresholdCache {
    capacity: usize,
    inner: Mutex<Inner>,
}

impl ThresholdCache {
    /// `capacity = 0` builds a disabled cache (every lookup misses, inserts
    /// are dropped).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity,
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0 }),
        }
    }

    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up and touch (refresh LRU recency of) an entry. The returned
    /// `Arc` clones in O(1); callers read the thresholds lock-free.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<CachedThresholds>> {
        if !self.enabled() {
            return None;
        }
        let mut inner = lock_unpoisoned(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.get_mut(key).map(|e| {
            e.last_used = tick;
            Arc::clone(&e.thresholds)
        })
    }

    /// Insert (or refresh) an entry, evicting the least-recently-used one
    /// when at capacity.
    pub fn insert(&self, key: CacheKey, thresholds: CachedThresholds) {
        if !self.enabled() {
            return;
        }
        let thresholds = Arc::new(thresholds);
        let mut inner = lock_unpoisoned(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        if inner.map.len() >= self.capacity && !inner.map.contains_key(&key) {
            if let Some(stalest) =
                inner.map.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| *k)
            {
                inner.map.remove(&stalest);
            }
        }
        inner.map.insert(key, Entry { thresholds, last_used: tick });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn key(fp: u128) -> CacheKey {
        CacheKey {
            fingerprint: fp,
            eta_bits: 1.0f64.to_bits(),
            kind: ProjectionKind::BilevelL1Inf,
            algo: L1Algorithm::Condat,
            dtype: Dtype::F64,
            rows: 2,
            cols: 2,
        }
    }

    #[test]
    fn fingerprint_sensitive_to_content_and_shape() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let a = Matrix::<f64>::randn(6, 5, &mut rng);
        assert_eq!(fingerprint(&a), fingerprint(&a.clone()));
        let mut b = a.clone();
        b.set(3, 2, b.get(3, 2) + 1e-12);
        assert_ne!(fingerprint(&a), fingerprint(&b));
        // same data, transposed shape
        assert_ne!(fingerprint(&a), fingerprint(&a.transpose()));
    }

    #[test]
    fn hit_and_miss() {
        let c = ThresholdCache::new(4);
        assert!(c.get(&key(1)).is_none());
        c.insert(key(1), CachedThresholds::F64(vec![0.5, 0.25]));
        match c.get(&key(1)).as_deref() {
            Some(CachedThresholds::F64(v)) => assert_eq!(v, &vec![0.5, 0.25]),
            other => panic!("expected hit, got {other:?}"),
        }
        // eta participates in the key
        let mut k2 = key(1);
        k2.eta_bits = 2.0f64.to_bits();
        assert!(c.get(&k2).is_none());
    }

    #[test]
    fn hits_share_one_allocation() {
        // Regression: `get` used to clone the whole threshold vector while
        // holding the shard-shared mutex. Two hits must now hand out the
        // same `Arc` allocation (an O(1) pointer clone under the lock).
        let c = ThresholdCache::new(4);
        c.insert(key(7), CachedThresholds::F64(vec![1.0; 4096]));
        let a = c.get(&key(7)).expect("hit");
        let b = c.get(&key(7)).expect("hit");
        assert!(Arc::ptr_eq(&a, &b), "hits must share the cached allocation");
        // re-inserting the key swaps the allocation (fresh thresholds win)
        c.insert(key(7), CachedThresholds::F64(vec![2.0; 4096]));
        let d = c.get(&key(7)).expect("hit");
        assert!(!Arc::ptr_eq(&a, &d));
    }

    #[test]
    fn lru_evicts_stalest() {
        let c = ThresholdCache::new(2);
        c.insert(key(1), CachedThresholds::F64(vec![1.0]));
        c.insert(key(2), CachedThresholds::F64(vec![2.0]));
        // touch 1 so 2 becomes stalest
        assert!(c.get(&key(1)).is_some());
        c.insert(key(3), CachedThresholds::F64(vec![3.0]));
        assert_eq!(c.len(), 2);
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(2)).is_none());
        assert!(c.get(&key(3)).is_some());
    }

    #[test]
    fn zero_capacity_disables() {
        let c = ThresholdCache::new(0);
        assert!(!c.enabled());
        c.insert(key(1), CachedThresholds::F64(vec![1.0]));
        assert!(c.get(&key(1)).is_none());
        assert!(c.is_empty());
    }

    #[test]
    fn threshold_scalar_roundtrip() {
        let ct = <f64 as ThresholdScalar>::wrap(vec![1.0, 2.0]);
        assert_eq!(ct.len(), 2);
        assert_eq!(<f64 as ThresholdScalar>::unwrap(&ct), Some(&[1.0, 2.0][..]));
        assert_eq!(<f32 as ThresholdScalar>::unwrap(&ct), None);
    }
}
