//! Bounded MPMC job queue: `Mutex<VecDeque>` + `Condvar`, in the same
//! no-external-deps spirit as `projection::bilevel::parallel` (no
//! crossbeam offline).
//!
//! Producers [`JobQueue::try_push`] and never block: a full queue is the
//! backpressure signal the engine turns into reject-with-retry-after.
//! Consumers block in [`JobQueue::pop_wait`], and the micro-batching
//! scheduler uses [`JobQueue::await_push`] / [`JobQueue::drain_matching`]
//! to coalesce same-key jobs that arrive inside its wait window.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::sync::{lock_unpoisoned, wait_timeout_unpoisoned, wait_unpoisoned};

/// Push failure, handing the item back to the caller.
#[derive(Debug)]
pub enum PushError<T> {
    /// Queue is at capacity (the backpressure high-water mark).
    Full(T),
    /// Queue was closed; no further work is accepted.
    Closed(T),
}

struct State<T> {
    items: VecDeque<T>,
    /// Total successful pushes ever — lets waiters detect arrivals without
    /// confusing them with concurrent consumption by sibling workers.
    pushes: u64,
    closed: bool,
}

/// Bounded multi-producer / multi-consumer FIFO.
pub struct JobQueue<T> {
    state: Mutex<State<T>>,
    signal: Condvar,
    capacity: usize,
}

impl<T> JobQueue<T> {
    /// `capacity` is the high-water mark; must be at least 1.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "JobQueue capacity must be >= 1");
        Self {
            state: Mutex::new(State {
                items: VecDeque::with_capacity(capacity.min(1024)),
                pushes: 0,
                closed: false,
            }),
            signal: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.state).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        lock_unpoisoned(&self.state).closed
    }

    /// Number of successful pushes so far (see [`JobQueue::await_push`]).
    pub fn push_count(&self) -> u64 {
        lock_unpoisoned(&self.state).pushes
    }

    /// Non-blocking bounded push; returns the queue depth after the push.
    pub fn try_push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut s = lock_unpoisoned(&self.state);
        if s.closed {
            return Err(PushError::Closed(item));
        }
        if s.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        s.items.push_back(item);
        s.pushes += 1;
        let depth = s.items.len();
        drop(s);
        // notify_all: pop_wait and await_push waiters share the condvar, so
        // a single notify could be swallowed by a batch-fill waiter while a
        // popper sleeps on an available item.
        self.signal.notify_all();
        Ok(depth)
    }

    /// Stop accepting work and wake every waiter. Items already queued are
    /// still handed out by `pop_wait` (graceful drain).
    pub fn close(&self) {
        lock_unpoisoned(&self.state).closed = true;
        self.signal.notify_all();
    }

    /// Block until an item is available (`Some`) or the queue is closed and
    /// fully drained (`None`).
    pub fn pop_wait(&self) -> Option<T> {
        let mut s = lock_unpoisoned(&self.state);
        loop {
            if let Some(item) = s.items.pop_front() {
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = wait_unpoisoned(&self.signal, s);
        }
    }

    /// Block until a push lands after the `seen` counter value, the queue
    /// closes, or `deadline` passes. Returns the current push count.
    pub fn await_push(&self, seen: u64, deadline: Instant) -> u64 {
        let mut s = lock_unpoisoned(&self.state);
        while s.pushes == seen && !s.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _timeout) = wait_timeout_unpoisoned(&self.signal, s, deadline - now);
            s = guard;
        }
        s.pushes
    }

    /// Remove up to `max` items satisfying `pred`, scanning front to back;
    /// the relative order of the remaining items is preserved.
    pub fn drain_matching(&self, max: usize, mut pred: impl FnMut(&T) -> bool) -> Vec<T> {
        let mut out = Vec::new();
        if max == 0 {
            return out;
        }
        let mut s = lock_unpoisoned(&self.state);
        let mut i = 0;
        while i < s.items.len() && out.len() < max {
            if pred(&s.items[i]) {
                out.push(s.items.remove(i).expect("index in bounds"));
            } else {
                i += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fifo_push_pop() {
        let q = JobQueue::new(4);
        assert_eq!(q.try_push(1).unwrap(), 1);
        assert_eq!(q.try_push(2).unwrap(), 2);
        assert_eq!(q.pop_wait(), Some(1));
        assert_eq!(q.pop_wait(), Some(2));
        assert_eq!(q.push_count(), 2);
    }

    #[test]
    fn rejects_beyond_capacity() {
        let q = JobQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        match q.try_push(3) {
            Err(PushError::Full(item)) => assert_eq!(item, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_ends() {
        let q = JobQueue::new(4);
        q.try_push(7).unwrap();
        q.close();
        match q.try_push(8) {
            Err(PushError::Closed(item)) => assert_eq!(item, 8),
            other => panic!("expected Closed, got {other:?}"),
        }
        assert_eq!(q.pop_wait(), Some(7));
        assert_eq!(q.pop_wait(), None);
        assert!(q.is_closed());
    }

    #[test]
    fn pop_wait_blocks_until_push() {
        let q = std::sync::Arc::new(JobQueue::new(4));
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_wait());
        std::thread::sleep(Duration::from_millis(20));
        q.try_push(42).unwrap();
        assert_eq!(h.join().unwrap(), Some(42));
    }

    #[test]
    fn await_push_times_out_and_detects_arrivals() {
        let q = JobQueue::new(4);
        let seen = q.push_count();
        let t0 = Instant::now();
        let after = q.await_push(seen, Instant::now() + Duration::from_millis(30));
        assert_eq!(after, seen);
        assert!(t0.elapsed() >= Duration::from_millis(25));
        q.try_push(1).unwrap();
        // already-arrived pushes return immediately
        let after = q.await_push(seen, Instant::now() + Duration::from_secs(10));
        assert_eq!(after, seen + 1);
    }

    #[test]
    fn drain_matching_preserves_other_items() {
        let q = JobQueue::new(8);
        for i in 0..6 {
            q.try_push(i).unwrap();
        }
        let evens = q.drain_matching(2, |x| x % 2 == 0);
        assert_eq!(evens, vec![0, 2]);
        let rest: Vec<i32> = std::iter::from_fn(|| {
            if q.is_empty() {
                None
            } else {
                q.pop_wait()
            }
        })
        .collect();
        assert_eq!(rest, vec![1, 3, 4, 5]);
    }
}
