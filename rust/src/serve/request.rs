//! The serve job model: requests, responses, batch keys, and submit errors.
//!
//! A [`ProjectionRequest`] pairs a [`ProjectionKind`] (any of the paper's
//! bi-level projections, the exact ℓ1,∞ baselines, or the identity) with a
//! radius η, an inner ℓ1 solver, and an owned matrix payload in either
//! dtype the projection library supports. Requests that agree on
//! (kind, algo, dtype, shape) share a [`BatchKey`] and are eligible for
//! coalescing by the micro-batching scheduler.
//!
//! The engine also serves **sparse encode** jobs ([`JobKind::SparseEncode`],
//! `Engine::submit_encode`): a batch of samples run through a registered
//! [`crate::sparse::CompactEncoder`] — the structured-sparse inference
//! workload the projection's column sparsity exists to enable. Encode jobs
//! share the queue/batching/stats machinery; they carry the registered
//! model id in their batch key, so same-model same-shape traffic coalesces
//! exactly like same-key projections.

use std::fmt;
use std::time::Duration;

use crate::projection::l1::L1Algorithm;
use crate::projection::ProjectionKind;
use crate::tensor::Matrix;

/// Element type of a request payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dtype {
    F32,
    F64,
}

impl Dtype {
    pub fn name(&self) -> &'static str {
        match self {
            Self::F32 => "f32",
            Self::F64 => "f64",
        }
    }
}

/// An owned matrix in one of the supported dtypes.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    F32(Matrix<f32>),
    F64(Matrix<f64>),
}

impl Payload {
    pub fn dtype(&self) -> Dtype {
        match self {
            Self::F32(_) => Dtype::F32,
            Self::F64(_) => Dtype::F64,
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            Self::F32(m) => m.rows(),
            Self::F64(m) => m.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Self::F32(m) => m.cols(),
            Self::F64(m) => m.cols(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Self::F32(m) => m.len(),
            Self::F64(m) => m.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&Matrix<f32>> {
        match self {
            Self::F32(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<&Matrix<f64>> {
        match self {
            Self::F64(m) => Some(m),
            _ => None,
        }
    }
}

/// What a submitted job asks the engine to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum JobKind {
    /// One of the library's matrix-ball projections.
    Project(ProjectionKind),
    /// Structured-sparse encode through the registered compacted encoder
    /// with this engine-local model id.
    SparseEncode { model: u64 },
}

impl JobKind {
    pub fn name(&self) -> &'static str {
        match self {
            Self::Project(kind) => kind.name(),
            Self::SparseEncode { .. } => "sparse-encode",
        }
    }
}

/// Coalescing key: requests with equal keys may execute in one batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub kind: JobKind,
    pub algo: L1Algorithm,
    pub dtype: Dtype,
    pub rows: usize,
    pub cols: usize,
}

/// One projection job submitted to the engine.
#[derive(Clone, Debug)]
pub struct ProjectionRequest {
    pub kind: ProjectionKind,
    /// Inner ℓ1 solver for the bi-level kinds (ignored by the exact ones).
    pub algo: L1Algorithm,
    /// Projection radius η (converted to the payload dtype at execution).
    pub eta: f64,
    pub payload: Payload,
}

impl ProjectionRequest {
    /// An `f64` request with the default (Condat) inner solver.
    pub fn f64(kind: ProjectionKind, eta: f64, y: Matrix<f64>) -> Self {
        Self { kind, algo: L1Algorithm::Condat, eta, payload: Payload::F64(y) }
    }

    /// An `f32` request with the default (Condat) inner solver.
    pub fn f32(kind: ProjectionKind, eta: f64, y: Matrix<f32>) -> Self {
        Self { kind, algo: L1Algorithm::Condat, eta, payload: Payload::F32(y) }
    }

    /// Override the inner ℓ1 solver.
    pub fn with_algo(mut self, algo: L1Algorithm) -> Self {
        self.algo = algo;
        self
    }

    pub fn batch_key(&self) -> BatchKey {
        BatchKey {
            kind: JobKind::Project(self.kind),
            algo: self.algo,
            dtype: self.payload.dtype(),
            rows: self.payload.rows(),
            cols: self.payload.cols(),
        }
    }

    /// Admission checks applied before a request is enqueued.
    pub fn validate(&self) -> Result<(), String> {
        if !self.eta.is_finite() {
            return Err(format!("eta must be finite, got {}", self.eta));
        }
        if self.eta < 0.0 {
            return Err(format!("eta must be non-negative, got {}", self.eta));
        }
        if self.payload.is_empty() {
            return Err("empty matrix payload".into());
        }
        Ok(())
    }
}

/// A completed job (projection or sparse encode).
#[derive(Clone, Debug)]
pub struct ProjectionResponse {
    pub kind: JobKind,
    /// The result matrix: the projected matrix (same shape as the request
    /// payload) for projections, the `(hidden, batch)` activations for
    /// sparse encodes. Same dtype as the request payload either way.
    pub payload: Payload,
    /// Per-column thresholds `û` for the bi-level kinds (as `f64`).
    pub thresholds: Option<Vec<f64>>,
    /// Whether the result was replayed from the threshold cache.
    pub cache_hit: bool,
    /// Size of the execution batch this job was coalesced into.
    pub batch_size: usize,
    /// Shard that executed the job.
    pub shard: usize,
    /// Time spent queued before a worker picked the job up.
    pub queue_micros: u64,
    /// Execution time of this job inside its batch.
    pub exec_micros: u64,
}

/// Why an *accepted* job failed to produce a result. Delivered through
/// the response channel in place of a [`ProjectionResponse`], so a
/// poisoned job surfaces as a typed error instead of a hung or dropped
/// waiter.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobError {
    /// The worker executing the job panicked; the supervisor respawned it
    /// (see `Engine` worker supervision) and failed the batch's jobs with
    /// this error.
    WorkerPanic { shard: usize },
}

impl fmt::Display for JobError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::WorkerPanic { shard } => {
                write!(f, "worker on shard {shard} panicked executing the job")
            }
        }
    }
}

impl std::error::Error for JobError {}

/// Why a submission was not accepted (or, for the `_wait` entry points,
/// why an accepted job did not complete).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The request failed admission checks (bad η, empty payload).
    Invalid(String),
    /// The target shard's queue is at its high-water mark; retry after the
    /// suggested backoff.
    Overloaded { shard: usize, depth: usize, retry_after: Duration },
    /// The model's circuit breaker is open after repeated encode
    /// failures; retry after the suggested cooldown.
    CircuitOpen { model: u64, retry_after: Duration },
    /// The job was accepted but failed during execution.
    Failed(JobError),
    /// The engine is shutting down and no longer accepts work.
    ShuttingDown,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Invalid(msg) => write!(f, "invalid request: {msg}"),
            Self::Overloaded { shard, depth, retry_after } => write!(
                f,
                "shard {shard} overloaded (queue depth {depth}); retry after {retry_after:?}"
            ),
            Self::CircuitOpen { model, retry_after } => write!(
                f,
                "model {model} circuit breaker open; retry after {retry_after:?}"
            ),
            Self::Failed(e) => write!(f, "job failed: {e}"),
            Self::ShuttingDown => write!(f, "engine is shutting down"),
        }
    }
}

impl std::error::Error for SubmitError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn batch_key_groups_same_shape_kind_dtype() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let a = Matrix::<f64>::randn(8, 4, &mut rng);
        let b = Matrix::<f64>::randn(8, 4, &mut rng);
        let r1 = ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, a.clone());
        let r2 = ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 2.5, b);
        // different eta, same key: eta does not block coalescing
        assert_eq!(r1.batch_key(), r2.batch_key());

        let r3 = ProjectionRequest::f64(ProjectionKind::BilevelL11, 1.0, a.clone());
        assert_ne!(r1.batch_key(), r3.batch_key());
        let r4 = ProjectionRequest::f32(ProjectionKind::BilevelL1Inf, 1.0, a.cast());
        assert_ne!(r1.batch_key(), r4.batch_key());
        let r5 = ProjectionRequest::f64(
            ProjectionKind::BilevelL1Inf,
            1.0,
            Matrix::<f64>::zeros(4, 8),
        );
        assert_ne!(r1.batch_key(), r5.batch_key());
        let r6 = r1.clone().with_algo(L1Algorithm::Sort);
        assert_ne!(r1.batch_key(), r6.batch_key());
    }

    #[test]
    fn validation_rejects_bad_requests() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let y = Matrix::<f64>::randn(3, 3, &mut rng);
        assert!(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, y.clone())
            .validate()
            .is_ok());
        assert!(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, -1.0, y.clone())
            .validate()
            .is_err());
        assert!(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, f64::NAN, y)
            .validate()
            .is_err());
        assert!(ProjectionRequest::f64(
            ProjectionKind::BilevelL1Inf,
            1.0,
            Matrix::<f64>::zeros(0, 0)
        )
        .validate()
        .is_err());
    }

    #[test]
    fn encode_job_kinds_key_by_model() {
        let a = JobKind::SparseEncode { model: 1 };
        let b = JobKind::SparseEncode { model: 2 };
        assert_ne!(a, b);
        assert_eq!(a.name(), "sparse-encode");
        assert_ne!(a, JobKind::Project(ProjectionKind::BilevelL1Inf));
        assert_eq!(JobKind::Project(ProjectionKind::BilevelL11).name(), "bilevel-l11");
    }

    #[test]
    fn typed_errors_display() {
        let e = SubmitError::Failed(JobError::WorkerPanic { shard: 2 });
        assert!(e.to_string().contains("shard 2"), "{e}");
        let c = SubmitError::CircuitOpen { model: 7, retry_after: Duration::from_millis(50) };
        assert!(c.to_string().contains("model 7"), "{c}");
    }

    #[test]
    fn payload_accessors() {
        let m = Matrix::<f64>::zeros(3, 5);
        let p = Payload::F64(m);
        assert_eq!(p.dtype(), Dtype::F64);
        assert_eq!(p.dtype().name(), "f64");
        assert_eq!((p.rows(), p.cols(), p.len()), (3, 5, 15));
        assert!(p.as_f64().is_some());
        assert!(p.as_f32().is_none());
        let p32 = Payload::F32(Matrix::<f32>::zeros(2, 2));
        assert_eq!(p32.dtype().name(), "f32");
        assert!(p32.as_f32().is_some());
    }
}
