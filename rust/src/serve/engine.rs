//! The engine: sharded worker pool + submission front-end.
//!
//! `Engine::start` spawns `shards × workers_per_shard` OS threads (scoped
//! `std::thread`, consistent with the crate's no-rayon policy), each shard
//! owning a bounded [`JobQueue`]. `submit` round-robins requests across
//! shards — same-key traffic still coalesces inside each shard's queue —
//! and converts a full queue into [`SubmitError::Overloaded`] with a
//! retry-after hint instead of blocking the caller (load-shedding, not
//! convoying). Responses travel over a per-request `mpsc` channel wrapped
//! in a [`ResponseHandle`].
//!
//! Shutdown is graceful: queues are closed, already-accepted jobs execute,
//! workers drain and exit, and `Drop` performs the same sequence so an
//! engine can never leak threads.
//!
//! Besides projections, the engine runs **sparse encode** jobs: compacted
//! encoders ([`crate::sparse::CompactEncoder`]) are registered once
//! ([`Engine::register_encoder_f32`] / [`Engine::register_encoder_f64`]),
//! then [`Engine::submit_encode`] submits input batches against the
//! returned model id. Encode jobs ride the same queues, batching, and
//! telemetry; the encoder is resolved to an `Arc` at submission, so
//! workers never touch the registry lock.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::persist::Checkpoint;
use crate::sparse::CompactEncoder;
use crate::sync::lock_unpoisoned;
use crate::tensor::Matrix;

use super::breaker::CircuitBreaker;
use super::cache::ThresholdCache;
use super::queue::{JobQueue, PushError};
use super::request::{
    BatchKey, Dtype, JobError, JobKind, Payload, ProjectionRequest, ProjectionResponse,
    SubmitError,
};
use super::scheduler::{self, BatchPolicy, ExecOutcome};
use super::stats::{EngineStats, HealthReport, ShardCounters};

/// How long after a worker respawn the engine reports itself `Degraded`.
const RESTART_DEGRADED_WINDOW: Duration = Duration::from_secs(5);

/// A registered encoder, typed at registration so workers dispatch without
/// a dtype check.
enum RegisteredEncoder {
    F32(Arc<CompactEncoder<f32>>),
    F64(Arc<CompactEncoder<f64>>),
}

/// Snapshot of one registry entry, for telemetry surfaces (`GET
/// /v1/models`, CLI stats) that must not hold the registry lock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelInfo {
    pub id: u64,
    pub dtype: Dtype,
    /// Input features the encoder expects (payload rows).
    pub features: usize,
    /// Hidden units produced per sample.
    pub hidden: usize,
    /// Surviving (non-pruned) input columns in the compacted plan.
    pub alive: usize,
}

/// What a queued job executes.
enum Work {
    Project(ProjectionRequest),
    Encode32 { enc: Arc<CompactEncoder<f32>>, x: Matrix<f32> },
    Encode64 { enc: Arc<CompactEncoder<f64>>, x: Matrix<f64> },
}

/// A queued unit of work. The job's [`JobKind`] lives in `key.kind`.
/// Accepted jobs always answer: a successful execution sends
/// `Ok(response)`, a supervised panic sends `Err(JobError)` — waiters
/// never hang on a job a dead worker dropped.
struct Job {
    work: Work,
    key: BatchKey,
    tx: mpsc::Sender<Result<ProjectionResponse, JobError>>,
    enqueued: Instant,
}

struct Shard {
    index: usize,
    queue: JobQueue<Job>,
    counters: ShardCounters,
}

/// Receiver side of a submitted request.
pub struct ResponseHandle {
    rx: mpsc::Receiver<Result<ProjectionResponse, JobError>>,
}

impl ResponseHandle {
    /// Block until the job resolves. An accepted job that failed in
    /// execution (its worker panicked) resolves to
    /// [`SubmitError::Failed`]; a channel closed by engine teardown
    /// before the job executed resolves to [`SubmitError::ShuttingDown`].
    pub fn wait(self) -> Result<ProjectionResponse, SubmitError> {
        match self.rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(e)) => Err(SubmitError::Failed(e)),
            Err(_) => Err(SubmitError::ShuttingDown),
        }
    }
}

/// Sharded, micro-batching projection service engine.
pub struct Engine {
    shards: Vec<Arc<Shard>>,
    cache: Arc<ThresholdCache>,
    workers: Vec<JoinHandle<()>>,
    rr: AtomicUsize,
    retry_after: Duration,
    started: Instant,
    /// Registered sparse encoders, keyed by engine-local model id.
    encoders: RwLock<HashMap<u64, RegisteredEncoder>>,
    next_model: AtomicU64,
    /// Per-model circuit breaker gating the sparse-encode admission path.
    breaker: Arc<CircuitBreaker>,
    /// When the supervisor last respawned a panicked worker (health: a
    /// recent respawn reports the engine `Degraded`).
    last_restart: Arc<Mutex<Option<Instant>>>,
}

impl Engine {
    /// Validate `cfg`, spawn the worker pool, and return a running engine.
    pub fn start(cfg: &ServeConfig) -> Result<Self, String> {
        cfg.validate()?;
        let nshards = cfg.effective_shards();
        let policy = BatchPolicy {
            max_batch: cfg.max_batch,
            min_fill: cfg.min_fill,
            max_wait: cfg.max_wait(),
        };
        let cache = Arc::new(ThresholdCache::new(cfg.cache_capacity));
        let breaker = Arc::new(CircuitBreaker::new(
            cfg.breaker_threshold as u32,
            Duration::from_millis(cfg.breaker_cooldown_ms),
        ));
        let last_restart = Arc::new(Mutex::new(None));
        let mut shards = Vec::with_capacity(nshards);
        let mut workers = Vec::with_capacity(nshards * cfg.workers_per_shard);
        for index in 0..nshards {
            let shard = Arc::new(Shard {
                index,
                queue: JobQueue::new(cfg.queue_capacity),
                counters: ShardCounters::new(),
            });
            for w in 0..cfg.workers_per_shard {
                let worker_shard = Arc::clone(&shard);
                let worker_cache = Arc::clone(&cache);
                let worker_breaker = Arc::clone(&breaker);
                let worker_restart = Arc::clone(&last_restart);
                let spawned = std::thread::Builder::new()
                    .name(format!("serve-{index}.{w}"))
                    .spawn(move || {
                        supervised_worker(
                            &worker_shard,
                            &worker_cache,
                            policy,
                            &worker_breaker,
                            &worker_restart,
                        )
                    });
                match spawned {
                    Ok(handle) => workers.push(handle),
                    Err(e) => {
                        // Unwind cleanly: close every queue (including this
                        // shard's) so already-spawned workers exit instead
                        // of parking in pop_wait forever, then join them.
                        shard.queue.close();
                        for s in &shards {
                            s.queue.close();
                        }
                        for handle in workers.drain(..) {
                            let _ = handle.join();
                        }
                        return Err(format!("spawning serve worker: {e}"));
                    }
                }
            }
            shards.push(shard);
        }
        // Retry hint: one full batch window plus a floor, so a backoff
        // sleep outlives the congestion that caused the rejection.
        let retry_after = (cfg.max_wait() * 2).max(Duration::from_micros(100));
        Ok(Self {
            shards,
            cache,
            workers,
            rr: AtomicUsize::new(0),
            retry_after,
            started: Instant::now(),
            encoders: RwLock::new(HashMap::new()),
            next_model: AtomicU64::new(1),
            breaker,
            last_restart,
        })
    }

    /// The engine's per-model circuit breaker (read-only view for
    /// telemetry and tests; the engine itself records outcomes).
    pub fn breaker(&self) -> &CircuitBreaker {
        &self.breaker
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Entries currently held by the shared threshold cache.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Enqueue a request; returns a handle to wait on, or an admission /
    /// backpressure error. Never blocks.
    pub fn submit(&self, req: ProjectionRequest) -> Result<ResponseHandle, SubmitError> {
        req.validate().map_err(SubmitError::Invalid)?;
        let key = req.batch_key();
        self.enqueue(Work::Project(req), key)
    }

    /// Submit and block for the response.
    pub fn submit_wait(&self, req: ProjectionRequest) -> Result<ProjectionResponse, SubmitError> {
        self.submit(req)?.wait()
    }

    /// Register a compacted f32 encoder; returns the model id to encode
    /// against. Registration is cheap (one registry write); the encoder is
    /// shared by `Arc` from then on.
    pub fn register_encoder_f32(&self, enc: CompactEncoder<f32>) -> u64 {
        self.register(RegisteredEncoder::F32(Arc::new(enc)))
    }

    /// Register a compacted f64 encoder; returns the model id.
    pub fn register_encoder_f64(&self, enc: CompactEncoder<f64>) -> u64 {
        self.register(RegisteredEncoder::F64(Arc::new(enc)))
    }

    fn register(&self, enc: RegisteredEncoder) -> u64 {
        let id = self.next_model.fetch_add(1, Ordering::Relaxed);
        self.encoders.write().unwrap().insert(id, enc);
        id
    }

    /// Load a model checkpoint (see [`crate::persist`]) into the encoder
    /// registry under a fresh model id. The checkpoint must carry a model
    /// bundle (plan + compacted tensors); the encoder is built straight
    /// from the compacted tensors, so it is bit-identical to the
    /// in-memory encoder of the training run that exported it.
    pub fn load_model(&self, path: &Path, dtype: Dtype) -> Result<u64, String> {
        Ok(self.register(load_encoder(path, dtype)?))
    }

    /// Hot-swap: load a checkpoint and atomically replace the encoder
    /// behind an existing model id, under live traffic. Submissions
    /// resolve the registry entry to an `Arc` at admission, so every job
    /// accepted before the swap completes on the old encoder; jobs
    /// admitted after it run on the new one. Nothing is rejected by the
    /// swap itself.
    pub fn swap_model(&self, id: u64, path: &Path, dtype: Dtype) -> Result<(), String> {
        self.swap(id, load_encoder(path, dtype)?)
    }

    /// Hot-swap an in-memory f32 encoder behind an existing model id.
    pub fn swap_encoder_f32(&self, id: u64, enc: CompactEncoder<f32>) -> Result<(), String> {
        self.swap(id, RegisteredEncoder::F32(Arc::new(enc)))
    }

    /// Hot-swap an in-memory f64 encoder behind an existing model id.
    pub fn swap_encoder_f64(&self, id: u64, enc: CompactEncoder<f64>) -> Result<(), String> {
        self.swap(id, RegisteredEncoder::F64(Arc::new(enc)))
    }

    fn swap(&self, id: u64, enc: RegisteredEncoder) -> Result<(), String> {
        let mut encoders = self.encoders.write().unwrap();
        match encoders.get_mut(&id) {
            Some(slot) => {
                *slot = enc;
                Ok(())
            }
            None => Err(format!("swap: unknown encoder model {id}")),
        }
    }

    /// Drop a model id from the registry. Jobs already admitted still
    /// complete (they hold the `Arc`); new submissions get
    /// `SubmitError::Invalid`. Returns whether the id existed.
    pub fn unregister_encoder(&self, id: u64) -> bool {
        self.breaker.forget(id);
        self.encoders.write().unwrap().remove(&id).is_some()
    }

    /// Number of registered encoders.
    pub fn encoder_count(&self) -> usize {
        self.encoders.read().unwrap().len()
    }

    /// Snapshot of every registered model, sorted by id.
    pub fn models(&self) -> Vec<ModelInfo> {
        let encoders = self.encoders.read().unwrap();
        let mut out: Vec<ModelInfo> = encoders
            .iter()
            .map(|(&id, enc)| match enc {
                RegisteredEncoder::F32(e) => ModelInfo {
                    id,
                    dtype: Dtype::F32,
                    features: e.features(),
                    hidden: e.hidden(),
                    alive: e.alive(),
                },
                RegisteredEncoder::F64(e) => ModelInfo {
                    id,
                    dtype: Dtype::F64,
                    features: e.features(),
                    hidden: e.hidden(),
                    alive: e.alive(),
                },
            })
            .collect();
        out.sort_by_key(|m| m.id);
        out
    }

    /// Enqueue a sparse-encode job: run `x` (one sample per **column**, in
    /// the original feature space) through the registered encoder `model`.
    /// Validates model id, dtype, and shape up front; never blocks.
    pub fn submit_encode(&self, model: u64, x: Payload) -> Result<ResponseHandle, SubmitError> {
        if x.is_empty() {
            return Err(SubmitError::Invalid("empty encode payload".into()));
        }
        // Circuit-breaker gate: a model tripped by repeated execution
        // failures sheds load here (503 + Retry-After at the net layer)
        // instead of feeding more jobs to a failing path. The single
        // half-open probe after the cooldown passes this check.
        if let Err(retry_after) = self.breaker.admit(model) {
            return Err(SubmitError::CircuitOpen { model, retry_after });
        }
        let (rows, cols, dtype) = (x.rows(), x.cols(), x.dtype());
        let work = {
            let encoders = self.encoders.read().unwrap();
            let Some(enc) = encoders.get(&model) else {
                return Err(SubmitError::Invalid(format!("unknown encoder model {model}")));
            };
            match (enc, x) {
                (RegisteredEncoder::F32(enc), Payload::F32(x)) => {
                    check_features(rows, enc.features())?;
                    Work::Encode32 { enc: Arc::clone(enc), x }
                }
                (RegisteredEncoder::F64(enc), Payload::F64(x)) => {
                    check_features(rows, enc.features())?;
                    Work::Encode64 { enc: Arc::clone(enc), x }
                }
                (RegisteredEncoder::F32(_), _) | (RegisteredEncoder::F64(_), _) => {
                    return Err(SubmitError::Invalid(format!(
                        "encoder model {model} dtype mismatch ({} payload)",
                        dtype.name()
                    )))
                }
            }
        };
        // `algo` is inert for encode jobs (it only discriminates projection
        // batches); pinning it to the default keeps every same-model,
        // same-shape encode under one key.
        let key = BatchKey {
            kind: JobKind::SparseEncode { model },
            algo: crate::projection::l1::L1Algorithm::Condat,
            dtype,
            rows,
            cols,
        };
        self.enqueue(work, key)
    }

    /// Submit an encode and block for the response.
    pub fn submit_encode_wait(
        &self,
        model: u64,
        x: Payload,
    ) -> Result<ProjectionResponse, SubmitError> {
        self.submit_encode(model, x)?.wait()
    }

    /// Shared tail of every submit path: pick a shard round-robin, attach
    /// the response channel, and convert queue pressure into errors.
    fn enqueue(&self, work: Work, key: BatchKey) -> Result<ResponseHandle, SubmitError> {
        let shard = &self.shards[self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len()];
        let (tx, rx) = mpsc::channel();
        let job = Job { work, key, tx, enqueued: Instant::now() };
        match shard.queue.try_push(job) {
            Ok(_depth) => {
                shard.counters.submitted.inc();
                Ok(ResponseHandle { rx })
            }
            Err(PushError::Full(_)) => {
                shard.counters.rejected.inc();
                Err(SubmitError::Overloaded {
                    shard: shard.index,
                    depth: shard.queue.capacity(),
                    retry_after: self.retry_after,
                })
            }
            Err(PushError::Closed(_)) => Err(SubmitError::ShuttingDown),
        }
    }

    /// Point-in-time snapshot of every shard's counters, including the
    /// health machine's verdict: `Degraded` while any model's circuit
    /// breaker is not closed or a worker respawned within the last few
    /// seconds, `Healthy` otherwise. (The net layer overrides the state
    /// to `Draining` during a graceful drain.)
    pub fn stats(&self) -> EngineStats {
        let mut reasons = Vec::new();
        for (model, state) in self.breaker.impaired() {
            reasons.push(format!("model {model} circuit {}", state.name()));
        }
        if let Some(at) = *lock_unpoisoned(&self.last_restart) {
            let ago = at.elapsed();
            if ago < RESTART_DEGRADED_WINDOW {
                reasons.push(format!("worker restarted {:.1}s ago", ago.as_secs_f64()));
            }
        }
        let health = if reasons.is_empty() {
            HealthReport::healthy()
        } else {
            HealthReport::degraded(reasons)
        };
        EngineStats {
            uptime: self.started.elapsed(),
            shards: self
                .shards
                .iter()
                .map(|s| s.counters.snapshot(s.index, s.queue.len()))
                .collect(),
            health,
        }
    }

    /// Stop accepting work, execute everything already queued, join the
    /// workers, and return the final stats.
    pub fn shutdown(mut self) -> EngineStats {
        self.finish();
        self.stats()
    }

    fn finish(&mut self) {
        for shard in &self.shards {
            shard.queue.close();
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.finish();
    }
}

/// Read a checkpoint's model bundle as a typed registry entry.
fn load_encoder(path: &Path, dtype: Dtype) -> Result<RegisteredEncoder, String> {
    let ck = Checkpoint::load(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let bundle = ck.model.ok_or_else(|| {
        format!("{}: checkpoint has no model bundle (mid-train state only)", path.display())
    })?;
    Ok(match dtype {
        Dtype::F32 => RegisteredEncoder::F32(Arc::new(bundle.encoder::<f32>())),
        Dtype::F64 => RegisteredEncoder::F64(Arc::new(bundle.encoder::<f64>())),
    })
}

/// Validate the feature (row) count of an encode payload.
fn check_features(rows: usize, features: usize) -> Result<(), SubmitError> {
    if rows != features {
        return Err(SubmitError::Invalid(format!(
            "encode payload has {rows} rows, encoder expects {features} features"
        )));
    }
    Ok(())
}

/// Why a `worker_loop` call returned.
enum WorkerExit {
    /// The shard queue closed and drained: clean shutdown.
    Drained,
    /// A job panicked mid-execution; the loop failed the affected jobs
    /// with typed errors and unwound so the supervisor can respawn it.
    Panicked,
}

/// The supervisor wrapping every worker thread: run the worker loop,
/// and when a job execution panics, respawn the loop in place with a
/// fresh scratch workspace — the thread (and the shard's capacity)
/// survives any panicking job. Each respawn bumps the shard's
/// `worker_restarts` counter and stamps the engine's last-restart clock
/// for health reporting.
fn supervised_worker(
    shard: &Shard,
    cache: &ThresholdCache,
    policy: BatchPolicy,
    breaker: &CircuitBreaker,
    last_restart: &Mutex<Option<Instant>>,
) {
    loop {
        match worker_loop(shard, cache, policy, breaker) {
            WorkerExit::Drained => return,
            WorkerExit::Panicked => {
                shard.counters.worker_restarts.inc();
                *lock_unpoisoned(&last_restart) = Some(Instant::now());
            }
        }
    }
}

/// Fail one job with a typed worker-panic error: the waiter gets
/// `SubmitError::Failed(JobError::WorkerPanic)` instead of a hung or
/// dropped channel, and encode failures count against the model's
/// circuit breaker.
fn fail_job(shard: &Shard, breaker: &CircuitBreaker, job: &Job) {
    shard.counters.worker_panics.inc();
    if let JobKind::SparseEncode { model } = job.key.kind {
        breaker.record_failure(model);
    }
    let _ = job.tx.send(Err(JobError::WorkerPanic { shard: shard.index }));
}

fn worker_loop(
    shard: &Shard,
    cache: &ThresholdCache,
    policy: BatchPolicy,
    breaker: &CircuitBreaker,
) -> WorkerExit {
    // Per-worker reusable projection workspace (the per-shard workspace
    // pool: workers are pinned to their shard). Steady-state bi-level
    // traffic allocates only the response payloads. A respawn after a
    // panic rebuilds it from scratch — a panicking job may have left it
    // mid-mutation.
    let mut scratch = scheduler::WorkerScratch::new();
    while let Some(first) = shard.queue.pop_wait() {
        let batch = scheduler::collect_batch(&shard.queue, first, policy, |j: &Job| j.key);
        let batch_size = batch.len();
        shard.counters.batches.inc();
        shard.counters.batched_jobs.add(batch_size as u64);
        // Manual iteration (not a `for` loop) so the panic arm can fail
        // the *remaining* jobs of the batch before unwinding.
        let mut jobs = batch.into_iter();
        loop {
            let Some(job) = jobs.next() else { break };
            let queue_micros = job.enqueued.elapsed().as_micros() as u64;
            let t0 = Instant::now();
            // Supervision boundary: a panic inside execution (a library
            // bug, a poisoned payload, or an injected `worker.panic`
            // fault) is caught here instead of killing the thread.
            let caught = catch_unwind(AssertUnwindSafe(|| {
                scheduler::fire_worker_faults();
                match &job.work {
                    Work::Project(req) => scheduler::execute(req, cache, &mut scratch),
                    // Encodes allocate exactly the response payload (the
                    // per-sample kernel writes straight into it).
                    Work::Encode32 { enc, x } => ExecOutcome {
                        payload: Payload::F32(enc.encode(x)),
                        thresholds: None,
                        cache_hit: false,
                    },
                    Work::Encode64 { enc, x } => ExecOutcome {
                        payload: Payload::F64(enc.encode(x)),
                        thresholds: None,
                        cache_hit: false,
                    },
                }
            }));
            let out = match caught {
                Ok(out) => out,
                Err(_) => {
                    // Fail the panicked job and the rest of its batch
                    // (the shared scratch is suspect), then unwind to
                    // the supervisor for a respawn.
                    fail_job(shard, breaker, &job);
                    for j in jobs {
                        fail_job(shard, breaker, &j);
                    }
                    return WorkerExit::Panicked;
                }
            };
            let exec_micros = t0.elapsed().as_micros() as u64;
            shard.counters.completed.inc();
            if let Work::Project(req) = &job.work {
                if scheduler::cacheable(req.kind) {
                    if out.cache_hit {
                        shard.counters.cache_hits.inc();
                    } else {
                        shard.counters.cache_misses.inc();
                    }
                }
            }
            if let JobKind::SparseEncode { model } = job.key.kind {
                breaker.record_success(model);
            }
            shard.counters.queue_wait.record_micros(queue_micros);
            shard.counters.exec.record_micros(exec_micros);
            // A dropped handle just means the client stopped caring.
            let _ = job.tx.send(Ok(ProjectionResponse {
                kind: job.key.kind,
                payload: out.payload,
                thresholds: out.thresholds,
                cache_hit: out.cache_hit,
                batch_size,
                shard: shard.index,
                queue_micros,
                exec_micros,
            }));
        }
    }
    WorkerExit::Drained
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::ProjectionKind;
    use crate::rng::Xoshiro256pp;
    use crate::serve::request::Payload;
    use crate::tensor::Matrix;

    fn small_cfg() -> ServeConfig {
        ServeConfig {
            shards: 2,
            workers_per_shard: 1,
            queue_capacity: 32,
            max_batch: 4,
            min_fill: 1,
            max_wait_micros: 100,
            cache_capacity: 8,
            breaker_threshold: 3,
            breaker_cooldown_ms: 50,
        }
    }

    #[test]
    fn round_trips_a_request() {
        let engine = Engine::start(&small_cfg()).unwrap();
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        let y = Matrix::<f64>::randn(12, 9, &mut rng);
        let resp = engine
            .submit_wait(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, y.clone()))
            .unwrap();
        let direct = crate::projection::bilevel::bilevel_l1inf(&y, 1.0);
        let Payload::F64(x) = &resp.payload else { panic!("dtype changed") };
        assert_eq!(x.max_abs_diff(&direct), 0.0);
        assert!(resp.batch_size >= 1);
        let stats = engine.shutdown();
        assert_eq!(stats.completed(), 1);
        assert_eq!(stats.submitted(), 1);
    }

    #[test]
    fn invalid_request_is_rejected_up_front() {
        let engine = Engine::start(&small_cfg()).unwrap();
        let err = engine
            .submit(ProjectionRequest::f64(
                ProjectionKind::BilevelL1Inf,
                -1.0,
                Matrix::<f64>::zeros(2, 2),
            ))
            .unwrap_err();
        assert!(matches!(err, SubmitError::Invalid(_)));
        assert_eq!(engine.stats().submitted(), 0);
    }

    #[test]
    fn invalid_config_refused() {
        let cfg = ServeConfig { queue_capacity: 0, ..ServeConfig::default() };
        assert!(Engine::start(&cfg).is_err());
    }

    fn masked_encoder<T: crate::scalar::Scalar>(
        seed: u64,
    ) -> (crate::model::SaeParams, CompactEncoder<T>) {
        use crate::model::{SaeDims, SaeParams};
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut p = SaeParams::init(SaeDims { features: 10, hidden: 4, classes: 2 }, &mut rng);
        let mut mask = vec![1.0f32; 10];
        for f in [1usize, 3, 8] {
            mask[f] = 0.0;
        }
        p.apply_feature_mask(&mask);
        let plan = crate::sparse::CompactPlan::from_mask(&mask);
        let enc = CompactEncoder::<T>::from_params(&p, &plan);
        (p, enc)
    }

    #[test]
    fn sparse_encode_round_trips_and_matches_library() {
        let engine = Engine::start(&small_cfg()).unwrap();
        let (_, enc) = masked_encoder::<f64>(31);
        let direct_enc = enc.clone();
        let model = engine.register_encoder_f64(enc);
        assert_eq!(engine.encoder_count(), 1);
        let mut rng = Xoshiro256pp::seed_from_u64(32);
        let x = Matrix::<f64>::randn(10, 6, &mut rng);
        let resp = engine
            .submit_encode_wait(model, Payload::F64(x.clone()))
            .unwrap();
        assert_eq!(resp.kind, JobKind::SparseEncode { model });
        assert!(resp.thresholds.is_none());
        assert!(!resp.cache_hit);
        let Payload::F64(h) = &resp.payload else { panic!("dtype changed") };
        assert_eq!((h.rows(), h.cols()), (4, 6));
        let direct = direct_enc.encode(&x);
        assert_eq!(h.max_abs_diff(&direct), 0.0);
        let stats = engine.shutdown();
        assert_eq!(stats.completed(), 1);
        // encode jobs never touch the threshold cache counters
        assert_eq!(stats.cache_hits() + stats.cache_misses(), 0);
    }

    #[test]
    fn sparse_encode_f32_and_mixed_with_projections() {
        let engine = Engine::start(&small_cfg()).unwrap();
        let (_, enc) = masked_encoder::<f32>(33);
        let model = engine.register_encoder_f32(enc.clone());
        let mut rng = Xoshiro256pp::seed_from_u64(34);
        let x32: Matrix<f32> = Matrix::<f64>::randn(10, 3, &mut rng).cast();
        let y = Matrix::<f64>::randn(8, 8, &mut rng);
        let he = engine.submit_encode(model, Payload::F32(x32.clone())).unwrap();
        let hp = engine
            .submit(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, y))
            .unwrap();
        let re = he.wait().unwrap();
        let rp = hp.wait().unwrap();
        let Payload::F32(h) = &re.payload else { panic!("dtype changed") };
        assert_eq!(h.max_abs_diff(&enc.encode(&x32)), 0.0);
        assert!(matches!(rp.kind, JobKind::Project(ProjectionKind::BilevelL1Inf)));
        let stats = engine.shutdown();
        assert_eq!(stats.completed(), 2);
    }

    #[test]
    fn encode_submissions_are_validated() {
        let engine = Engine::start(&small_cfg()).unwrap();
        let (_, enc) = masked_encoder::<f64>(35);
        let model = engine.register_encoder_f64(enc);
        let mut rng = Xoshiro256pp::seed_from_u64(36);
        // unknown model
        let err = engine
            .submit_encode(999, Payload::F64(Matrix::randn(10, 2, &mut rng)))
            .unwrap_err();
        assert!(matches!(err, SubmitError::Invalid(_)), "unknown model accepted");
        // dtype mismatch
        let x32: Matrix<f32> = Matrix::<f64>::randn(10, 2, &mut rng).cast();
        let err = engine.submit_encode(model, Payload::F32(x32)).unwrap_err();
        assert!(matches!(err, SubmitError::Invalid(_)), "dtype mismatch accepted");
        // wrong feature count
        let err = engine
            .submit_encode(model, Payload::F64(Matrix::randn(7, 2, &mut rng)))
            .unwrap_err();
        assert!(matches!(err, SubmitError::Invalid(_)), "wrong rows accepted");
        // empty batch
        let err = engine
            .submit_encode(model, Payload::F64(Matrix::zeros(10, 0)))
            .unwrap_err();
        assert!(matches!(err, SubmitError::Invalid(_)), "empty batch accepted");
        assert_eq!(engine.stats().submitted(), 0);
        engine.shutdown();
    }

    #[test]
    fn breaker_trips_encode_admission_and_degrades_health() {
        let engine = Engine::start(&small_cfg()).unwrap();
        let (_, enc) = masked_encoder::<f64>(81);
        let model = engine.register_encoder_f64(enc);
        assert_eq!(engine.stats().health.state, crate::serve::stats::HealthState::Healthy);
        // Trip the gate directly (threshold 3 in small_cfg).
        for _ in 0..3 {
            engine.breaker().record_failure(model);
        }
        let mut rng = Xoshiro256pp::seed_from_u64(82);
        let err = engine
            .submit_encode(model, Payload::F64(Matrix::randn(10, 2, &mut rng)))
            .unwrap_err();
        assert!(
            matches!(err, SubmitError::CircuitOpen { model: m, .. } if m == model),
            "expected CircuitOpen, got {err}"
        );
        let stats = engine.stats();
        assert_eq!(stats.health.state, crate::serve::stats::HealthState::Degraded);
        assert!(
            stats.health.reasons.iter().any(|r| r.contains("circuit")),
            "{:?}",
            stats.health.reasons
        );
        // After the cooldown the half-open probe is admitted; its success
        // closes the gate and health returns to Healthy.
        std::thread::sleep(Duration::from_millis(60));
        let resp = engine.submit_encode_wait(model, Payload::F64(Matrix::randn(10, 2, &mut rng)));
        assert!(resp.is_ok(), "half-open probe should be admitted and succeed");
        assert_eq!(engine.stats().health.state, crate::serve::stats::HealthState::Healthy);
        // Unregistering drops the gate too.
        engine.unregister_encoder(model);
        assert!(engine.breaker().impaired().is_empty());
        engine.shutdown();
    }

    fn write_checkpoint<T: crate::scalar::Scalar>(
        seed: u64,
        path: &std::path::Path,
    ) -> CompactEncoder<T> {
        use crate::persist::{Checkpoint, ModelBundle};
        let (p, enc) = masked_encoder::<T>(seed);
        let plan = enc.plan().clone();
        let compact = crate::sparse::compact_params(&p, &plan);
        Checkpoint {
            seed,
            config_digest: 0,
            dims: p.dims,
            history: Vec::new(),
            model: Some(ModelBundle { plan, compact, dense: None }),
            train_state: None,
        }
        .save(path)
        .unwrap();
        enc
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("bilevel-engine-test-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn load_model_serves_checkpointed_encoder_bit_identically() {
        let dir = tmp_dir("load");
        let path = dir.join("m.ckpt");
        let enc_mem = write_checkpoint::<f64>(41, &path);
        let engine = Engine::start(&small_cfg()).unwrap();
        let model = engine.load_model(&path, Dtype::F64).unwrap();
        assert_eq!(engine.encoder_count(), 1);
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let x = Matrix::<f64>::randn(10, 5, &mut rng);
        let resp = engine.submit_encode_wait(model, Payload::F64(x.clone())).unwrap();
        let Payload::F64(h) = &resp.payload else { panic!("dtype changed") };
        assert_eq!(h.max_abs_diff(&enc_mem.encode(&x)), 0.0, "loaded model must serve bit-identically");
        // a model-less path errors cleanly
        assert!(engine.load_model(&dir.join("missing.ckpt"), Dtype::F64).is_err());
        engine.shutdown();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn hot_swap_replaces_under_live_arcs() {
        let engine = Engine::start(&small_cfg()).unwrap();
        let (_, old_enc) = masked_encoder::<f64>(51);
        let model = engine.register_encoder_f64(old_enc.clone());
        let mut rng = Xoshiro256pp::seed_from_u64(52);
        let x = Matrix::<f64>::randn(10, 4, &mut rng);
        // Admit a job, then swap before waiting: the job resolved its Arc
        // at submission, so it must complete on the OLD encoder.
        let inflight = engine.submit_encode(model, Payload::F64(x.clone())).unwrap();
        let (_, new_enc) = masked_encoder::<f64>(53);
        engine.swap_encoder_f64(model, new_enc.clone()).unwrap();
        let resp = inflight.wait().unwrap();
        let Payload::F64(h) = &resp.payload else { panic!("dtype changed") };
        assert_eq!(h.max_abs_diff(&old_enc.encode(&x)), 0.0, "in-flight job must finish on old Arc");
        // Jobs admitted after the swap run on the new encoder.
        let resp = engine.submit_encode_wait(model, Payload::F64(x.clone())).unwrap();
        let Payload::F64(h) = &resp.payload else { panic!("dtype changed") };
        assert_eq!(h.max_abs_diff(&new_enc.encode(&x)), 0.0, "post-swap job must use new encoder");
        // Swap of an unknown id is an error; the registry size is stable.
        assert!(engine.swap_encoder_f64(999, new_enc).is_err());
        assert_eq!(engine.encoder_count(), 1);
        engine.shutdown();
    }

    #[test]
    fn unregister_rejects_new_but_not_inflight() {
        let engine = Engine::start(&small_cfg()).unwrap();
        let (_, enc) = masked_encoder::<f64>(61);
        let model = engine.register_encoder_f64(enc.clone());
        let mut rng = Xoshiro256pp::seed_from_u64(62);
        let x = Matrix::<f64>::randn(10, 2, &mut rng);
        let inflight = engine.submit_encode(model, Payload::F64(x.clone())).unwrap();
        assert!(engine.unregister_encoder(model));
        assert!(!engine.unregister_encoder(model), "second unregister is a no-op");
        assert!(inflight.wait().is_ok(), "admitted job must still complete");
        let err = engine.submit_encode(model, Payload::F64(x)).unwrap_err();
        assert!(matches!(err, SubmitError::Invalid(_)));
        assert_eq!(engine.encoder_count(), 0);
        engine.shutdown();
    }

    #[test]
    fn models_snapshot_reports_registry() {
        let engine = Engine::start(&small_cfg()).unwrap();
        assert!(engine.models().is_empty());
        let (_, e64) = masked_encoder::<f64>(71);
        let (_, e32) = masked_encoder::<f32>(72);
        let id64 = engine.register_encoder_f64(e64);
        let id32 = engine.register_encoder_f32(e32);
        let models = engine.models();
        assert_eq!(models.len(), 2);
        assert!(models.windows(2).all(|w| w[0].id < w[1].id), "sorted by id");
        let m64 = models.iter().find(|m| m.id == id64).unwrap();
        assert_eq!(m64.dtype, Dtype::F64);
        assert_eq!((m64.features, m64.hidden, m64.alive), (10, 4, 7));
        assert_eq!(models.iter().find(|m| m.id == id32).unwrap().dtype, Dtype::F32);
        engine.unregister_encoder(id64);
        assert_eq!(engine.models().len(), 1);
        engine.shutdown();
    }

    #[test]
    fn drop_joins_workers() {
        let engine = Engine::start(&small_cfg()).unwrap();
        let mut rng = Xoshiro256pp::seed_from_u64(22);
        let mut handles = Vec::new();
        for _ in 0..8 {
            let y = Matrix::<f64>::randn(8, 8, &mut rng);
            handles.push(
                engine
                    .submit(ProjectionRequest::f64(ProjectionKind::BilevelL11, 0.5, y))
                    .unwrap(),
            );
        }
        drop(engine); // graceful: queued jobs still execute
        let mut got = 0;
        for h in handles {
            if h.wait().is_ok() {
                got += 1;
            }
        }
        assert_eq!(got, 8);
    }
}
