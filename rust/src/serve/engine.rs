//! The engine: sharded worker pool + submission front-end.
//!
//! `Engine::start` spawns `shards × workers_per_shard` OS threads (scoped
//! `std::thread`, consistent with the crate's no-rayon policy), each shard
//! owning a bounded [`JobQueue`]. `submit` round-robins requests across
//! shards — same-key traffic still coalesces inside each shard's queue —
//! and converts a full queue into [`SubmitError::Overloaded`] with a
//! retry-after hint instead of blocking the caller (load-shedding, not
//! convoying). Responses travel over a per-request `mpsc` channel wrapped
//! in a [`ResponseHandle`].
//!
//! Shutdown is graceful: queues are closed, already-accepted jobs execute,
//! workers drain and exit, and `Drop` performs the same sequence so an
//! engine can never leak threads.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;

use super::cache::ThresholdCache;
use super::queue::{JobQueue, PushError};
use super::request::{BatchKey, ProjectionRequest, ProjectionResponse, SubmitError};
use super::scheduler::{self, BatchPolicy};
use super::stats::{EngineStats, ShardCounters};

/// A queued unit of work.
struct Job {
    req: ProjectionRequest,
    key: BatchKey,
    tx: mpsc::Sender<ProjectionResponse>,
    enqueued: Instant,
}

struct Shard {
    index: usize,
    queue: JobQueue<Job>,
    counters: ShardCounters,
}

/// Receiver side of a submitted request.
pub struct ResponseHandle {
    rx: mpsc::Receiver<ProjectionResponse>,
}

impl ResponseHandle {
    /// Block until the response arrives. `None` only if the engine was
    /// torn down before the job executed.
    pub fn wait(self) -> Option<ProjectionResponse> {
        self.rx.recv().ok()
    }
}

/// Sharded, micro-batching projection service engine.
pub struct Engine {
    shards: Vec<Arc<Shard>>,
    cache: Arc<ThresholdCache>,
    workers: Vec<JoinHandle<()>>,
    rr: AtomicUsize,
    retry_after: Duration,
    started: Instant,
}

impl Engine {
    /// Validate `cfg`, spawn the worker pool, and return a running engine.
    pub fn start(cfg: &ServeConfig) -> Result<Self, String> {
        cfg.validate()?;
        let nshards = cfg.effective_shards();
        let policy = BatchPolicy {
            max_batch: cfg.max_batch,
            min_fill: cfg.min_fill,
            max_wait: cfg.max_wait(),
        };
        let cache = Arc::new(ThresholdCache::new(cfg.cache_capacity));
        let mut shards = Vec::with_capacity(nshards);
        let mut workers = Vec::with_capacity(nshards * cfg.workers_per_shard);
        for index in 0..nshards {
            let shard = Arc::new(Shard {
                index,
                queue: JobQueue::new(cfg.queue_capacity),
                counters: ShardCounters::new(),
            });
            for w in 0..cfg.workers_per_shard {
                let worker_shard = Arc::clone(&shard);
                let worker_cache = Arc::clone(&cache);
                let spawned = std::thread::Builder::new()
                    .name(format!("serve-{index}.{w}"))
                    .spawn(move || worker_loop(&worker_shard, &worker_cache, policy));
                match spawned {
                    Ok(handle) => workers.push(handle),
                    Err(e) => {
                        // Unwind cleanly: close every queue (including this
                        // shard's) so already-spawned workers exit instead
                        // of parking in pop_wait forever, then join them.
                        shard.queue.close();
                        for s in &shards {
                            s.queue.close();
                        }
                        for handle in workers.drain(..) {
                            let _ = handle.join();
                        }
                        return Err(format!("spawning serve worker: {e}"));
                    }
                }
            }
            shards.push(shard);
        }
        // Retry hint: one full batch window plus a floor, so a backoff
        // sleep outlives the congestion that caused the rejection.
        let retry_after = (cfg.max_wait() * 2).max(Duration::from_micros(100));
        Ok(Self {
            shards,
            cache,
            workers,
            rr: AtomicUsize::new(0),
            retry_after,
            started: Instant::now(),
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Entries currently held by the shared threshold cache.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Enqueue a request; returns a handle to wait on, or an admission /
    /// backpressure error. Never blocks.
    pub fn submit(&self, req: ProjectionRequest) -> Result<ResponseHandle, SubmitError> {
        req.validate().map_err(SubmitError::Invalid)?;
        let shard = &self.shards[self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len()];
        let (tx, rx) = mpsc::channel();
        let job = Job { key: req.batch_key(), req, tx, enqueued: Instant::now() };
        match shard.queue.try_push(job) {
            Ok(_depth) => {
                shard.counters.submitted.inc();
                Ok(ResponseHandle { rx })
            }
            Err(PushError::Full(_)) => {
                shard.counters.rejected.inc();
                Err(SubmitError::Overloaded {
                    shard: shard.index,
                    depth: shard.queue.capacity(),
                    retry_after: self.retry_after,
                })
            }
            Err(PushError::Closed(_)) => Err(SubmitError::ShuttingDown),
        }
    }

    /// Submit and block for the response.
    pub fn submit_wait(&self, req: ProjectionRequest) -> Result<ProjectionResponse, SubmitError> {
        self.submit(req)?.wait().ok_or(SubmitError::ShuttingDown)
    }

    /// Point-in-time snapshot of every shard's counters.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            uptime: self.started.elapsed(),
            shards: self
                .shards
                .iter()
                .map(|s| s.counters.snapshot(s.index, s.queue.len()))
                .collect(),
        }
    }

    /// Stop accepting work, execute everything already queued, join the
    /// workers, and return the final stats.
    pub fn shutdown(mut self) -> EngineStats {
        self.finish();
        self.stats()
    }

    fn finish(&mut self) {
        for shard in &self.shards {
            shard.queue.close();
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.finish();
    }
}

fn worker_loop(shard: &Shard, cache: &ThresholdCache, policy: BatchPolicy) {
    // Per-worker reusable projection workspace (the per-shard workspace
    // pool: workers are pinned to their shard). Steady-state bi-level
    // traffic allocates only the response payloads.
    let mut scratch = scheduler::WorkerScratch::new();
    while let Some(first) = shard.queue.pop_wait() {
        let batch = scheduler::collect_batch(&shard.queue, first, policy, |j: &Job| j.key);
        let batch_size = batch.len();
        shard.counters.batches.inc();
        shard.counters.batched_jobs.add(batch_size as u64);
        for job in batch {
            let queue_micros = job.enqueued.elapsed().as_micros() as u64;
            let t0 = Instant::now();
            let out = scheduler::execute(&job.req, cache, &mut scratch);
            let exec_micros = t0.elapsed().as_micros() as u64;
            shard.counters.completed.inc();
            if scheduler::cacheable(job.req.kind) {
                if out.cache_hit {
                    shard.counters.cache_hits.inc();
                } else {
                    shard.counters.cache_misses.inc();
                }
            }
            shard.counters.queue_wait.record_micros(queue_micros);
            shard.counters.exec.record_micros(exec_micros);
            // A dropped handle just means the client stopped caring.
            let _ = job.tx.send(ProjectionResponse {
                kind: job.req.kind,
                payload: out.payload,
                thresholds: out.thresholds,
                cache_hit: out.cache_hit,
                batch_size,
                shard: shard.index,
                queue_micros,
                exec_micros,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::ProjectionKind;
    use crate::rng::Xoshiro256pp;
    use crate::serve::request::Payload;
    use crate::tensor::Matrix;

    fn small_cfg() -> ServeConfig {
        ServeConfig {
            shards: 2,
            workers_per_shard: 1,
            queue_capacity: 32,
            max_batch: 4,
            min_fill: 1,
            max_wait_micros: 100,
            cache_capacity: 8,
        }
    }

    #[test]
    fn round_trips_a_request() {
        let engine = Engine::start(&small_cfg()).unwrap();
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        let y = Matrix::<f64>::randn(12, 9, &mut rng);
        let resp = engine
            .submit_wait(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, y.clone()))
            .unwrap();
        let direct = crate::projection::bilevel::bilevel_l1inf(&y, 1.0);
        let Payload::F64(x) = &resp.payload else { panic!("dtype changed") };
        assert_eq!(x.max_abs_diff(&direct), 0.0);
        assert!(resp.batch_size >= 1);
        let stats = engine.shutdown();
        assert_eq!(stats.completed(), 1);
        assert_eq!(stats.submitted(), 1);
    }

    #[test]
    fn invalid_request_is_rejected_up_front() {
        let engine = Engine::start(&small_cfg()).unwrap();
        let err = engine
            .submit(ProjectionRequest::f64(
                ProjectionKind::BilevelL1Inf,
                -1.0,
                Matrix::<f64>::zeros(2, 2),
            ))
            .unwrap_err();
        assert!(matches!(err, SubmitError::Invalid(_)));
        assert_eq!(engine.stats().submitted(), 0);
    }

    #[test]
    fn invalid_config_refused() {
        let cfg = ServeConfig { queue_capacity: 0, ..ServeConfig::default() };
        assert!(Engine::start(&cfg).is_err());
    }

    #[test]
    fn drop_joins_workers() {
        let engine = Engine::start(&small_cfg()).unwrap();
        let mut rng = Xoshiro256pp::seed_from_u64(22);
        let mut handles = Vec::new();
        for _ in 0..8 {
            let y = Matrix::<f64>::randn(8, 8, &mut rng);
            handles.push(
                engine
                    .submit(ProjectionRequest::f64(ProjectionKind::BilevelL11, 0.5, y))
                    .unwrap(),
            );
        }
        drop(engine); // graceful: queued jobs still execute
        let mut got = 0;
        for h in handles {
            if h.wait().is_some() {
                got += 1;
            }
        }
        assert_eq!(got, 8);
    }
}
