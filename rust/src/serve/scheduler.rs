//! Micro-batching policy and request execution.
//!
//! **Batching.** A worker that pops a job coalesces further same-key jobs
//! into one execution batch under a [`BatchPolicy`]: up to `max_batch`
//! jobs, waiting at most `max_wait` and only while the batch holds fewer
//! than `min_fill` jobs. The default `min_fill = 1` is *opportunistic*
//! batching — drain whatever compatible work is already queued, never
//! idle-wait — so batching can amortize queue traffic without taxing
//! latency when the queue is shallow.
//!
//! **Execution.** One request = one library projection call, dispatched by
//! dtype and [`ProjectionKind`]. Bi-level kinds go through the threshold
//! cache: a hit replays the cached per-column thresholds through the outer
//! column stage only (the O(nm) clip / shrink / rescale), skipping the
//! aggregation + inner ℓ1 solve; the replay mirrors the library loops
//! bit-for-bit so cached results are indistinguishable from cold ones.

use std::time::{Duration, Instant};

use crate::kernels::{self, Workspace};
use crate::projection::bilevel::{self, BilevelResult, BilevelVariant};
use crate::projection::l1::{self, L1Algorithm};
use crate::projection::ProjectionKind;
use crate::projection::l2;
use crate::scalar::Scalar;
use crate::tensor::{vec_ops, Matrix};

use super::cache::{CacheKey, ThresholdCache, ThresholdScalar};
use super::queue::JobQueue;
use super::request::{BatchKey, Payload, ProjectionRequest};

/// How aggressively a worker coalesces same-key jobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Hard cap on jobs per execution batch.
    pub max_batch: usize,
    /// Keep waiting (up to `max_wait`) while the batch holds fewer jobs
    /// than this. 1 = opportunistic (never wait).
    pub min_fill: usize,
    /// Wait budget for filling a batch to `min_fill`.
    pub max_wait: Duration,
}

impl BatchPolicy {
    /// Batching disabled: every job executes alone, no waiting.
    pub fn unbatched() -> Self {
        Self { max_batch: 1, min_fill: 1, max_wait: Duration::ZERO }
    }
}

/// Coalesce `first` with queued same-key jobs under `policy`.
///
/// Drains compatible jobs immediately; if the batch is still below
/// `min_fill`, blocks for further arrivals until the wait budget runs out,
/// the queue closes, or the batch fills.
pub(crate) fn collect_batch<T>(
    queue: &JobQueue<T>,
    first: T,
    policy: BatchPolicy,
    key_of: impl Fn(&T) -> BatchKey,
) -> Vec<T> {
    let mut batch = Vec::with_capacity(policy.max_batch.max(1));
    let key = key_of(&first);
    batch.push(first);
    if policy.max_batch <= 1 {
        return batch;
    }
    let min_fill = policy.min_fill.clamp(1, policy.max_batch);
    let deadline = Instant::now() + policy.max_wait;
    loop {
        // Snapshot the push counter *before* draining so an arrival that
        // races the drain wakes the next await instead of being missed.
        let seen = queue.push_count();
        let want = policy.max_batch - batch.len();
        batch.extend(queue.drain_matching(want, |j| key_of(j) == key));
        if batch.len() >= policy.max_batch || batch.len() >= min_fill {
            break;
        }
        if queue.is_closed() || Instant::now() >= deadline {
            break;
        }
        queue.await_push(seen, deadline);
    }
    batch
}

/// Fire the worker-level fault sites. Called by the engine's worker loop
/// at the top of every job execution, **inside** the supervised
/// `catch_unwind` scope, so an injected `worker.panic` exercises exactly
/// the recovery path a real execution panic would: the batch's jobs fail
/// with a typed [`JobError`](super::request::JobError) and the supervisor
/// respawns the worker. `worker.stall` sleeps for the site's `param`
/// milliseconds to simulate a wedged kernel.
pub(crate) fn fire_worker_faults() {
    use crate::fault::{self, FaultSite};
    if let Some(ms) = fault::fire(FaultSite::WorkerStall) {
        std::thread::sleep(Duration::from_millis(ms));
    }
    if fault::fire(FaultSite::WorkerPanic).is_some() {
        panic!("injected fault: worker.panic");
    }
}

/// Result of executing one request.
pub(crate) struct ExecOutcome {
    pub payload: Payload,
    pub thresholds: Option<Vec<f64>>,
    pub cache_hit: bool,
}

/// Per-worker reusable projection scratch (the engine's per-shard
/// workspace pool: workers are pinned to shards, so one scratch per worker
/// is one pool slot per shard worker). With it warm, the steady-state cost
/// of a bi-level request is the response payload allocation and nothing
/// else — norm vector, threshold vector, and Condat scratch are all
/// reused.
pub(crate) struct WorkerScratch {
    ws32: Workspace<f32>,
    ws64: Workspace<f64>,
}

impl WorkerScratch {
    pub(crate) fn new() -> Self {
        Self { ws32: Workspace::new(), ws64: Workspace::new() }
    }
}

/// Whether results of this kind can be replayed from cached thresholds.
pub fn cacheable(kind: ProjectionKind) -> bool {
    kind.bilevel_variant().is_some()
}

/// Execute one request against the projection library, consulting (and
/// feeding) the threshold cache for the bi-level kinds. `scratch` is the
/// calling worker's reusable workspace.
pub(crate) fn execute(
    req: &ProjectionRequest,
    cache: &ThresholdCache,
    scratch: &mut WorkerScratch,
) -> ExecOutcome {
    match &req.payload {
        Payload::F64(y) => {
            let (x, thresholds, cache_hit) = exec_typed(y, req, cache, &mut scratch.ws64);
            ExecOutcome { payload: Payload::F64(x), thresholds, cache_hit }
        }
        Payload::F32(y) => {
            let (x, thresholds, cache_hit) = exec_typed(y, req, cache, &mut scratch.ws32);
            ExecOutcome { payload: Payload::F32(x), thresholds, cache_hit }
        }
    }
}

/// Run a bi-level projection through the worker's workspace. `BP¹,∞` uses
/// the allocation-free `_into` path (the output matrix is the response
/// payload, so it is the one allocation left); the generic variants go
/// through the library dispatch.
fn run_bilevel<T: ThresholdScalar>(
    y: &Matrix<T>,
    eta: T,
    variant: BilevelVariant,
    algo: L1Algorithm,
    ws: &mut Workspace<T>,
) -> BilevelResult<T> {
    match variant {
        BilevelVariant::L1Inf => {
            let mut out = Matrix::zeros(y.rows(), y.cols());
            bilevel::bilevel_l1inf_into(y, eta, algo, ws, &mut out);
            // Clone (not take) so the workspace keeps its capacity.
            BilevelResult { x: out, thresholds: ws.thresholds.clone() }
        }
        _ => bilevel::bilevel(y, eta, variant, algo),
    }
}

fn exec_typed<T: ThresholdScalar>(
    y: &Matrix<T>,
    req: &ProjectionRequest,
    cache: &ThresholdCache,
    ws: &mut Workspace<T>,
) -> (Matrix<T>, Option<Vec<f64>>, bool) {
    let eta = T::from_f64(req.eta);
    let Some(variant) = req.kind.bilevel_variant() else {
        // Exact ℓ1,∞ kinds and the identity: no thresholds, nothing to cache.
        return (req.kind.apply_with(y, eta, req.algo), None, false);
    };
    if !cache.enabled() {
        let r = run_bilevel(y, eta, variant, req.algo, ws);
        return (r.x, Some(to_f64_vec(&r.thresholds)), false);
    }
    let key = CacheKey::for_matrix(y, req.eta, req.kind, req.algo, req.payload.dtype());
    if let Some(cached) = cache.get(&key) {
        // Borrow straight through the Arc: a hit replays without copying
        // the threshold vector; the only allocation is the response's
        // f64 view.
        if let Some(u) = T::unwrap(&cached) {
            if u.len() == y.cols() {
                let x = replay(y, variant, req.algo, u);
                return (x, Some(to_f64_vec(u)), true);
            }
        }
    }
    let r = run_bilevel(y, eta, variant, req.algo, ws);
    let thresholds = to_f64_vec(&r.thresholds);
    // The cache takes ownership of the native-dtype vector — no clone.
    cache.insert(key, T::wrap(r.thresholds));
    (r.x, Some(thresholds), false)
}

/// The response-facing `f64` view of a threshold vector.
fn to_f64_vec<T: Scalar>(u: &[T]) -> Vec<f64> {
    u.iter().map(|t| t.to_f64()).collect()
}

/// Re-run only the outer column stage with known thresholds `û`.
///
/// Each arm mirrors the corresponding library code path exactly —
/// `bilevel_l1inf_with`'s fused copy-or-clip loop, `bilevel_generic`'s
/// per-column ℓ1 shrink / ℓ2 rescale — so that, fed the thresholds a cold
/// call produced, it returns the bit-identical matrix.
fn replay<T: Scalar>(
    y: &Matrix<T>,
    variant: BilevelVariant,
    algo: L1Algorithm,
    u: &[T],
) -> Matrix<T> {
    match variant {
        BilevelVariant::L1Inf => {
            let (n, m) = (y.rows(), y.cols());
            let mut data: Vec<T> = Vec::with_capacity(n * m);
            for (j, col) in y.columns().enumerate() {
                // `vec_ops::linf` is the same kernel reduction the cold
                // path stored in `ws.norms`, and `extend_clipped` shares
                // the cold path's tie-break and element op, so the replay
                // resolves bit-identically; extend keeps the output
                // single-write.
                kernels::extend_clipped(&mut data, col, u[j], vec_ops::linf(col));
            }
            Matrix::from_col_major(n, m, data)
        }
        BilevelVariant::L11 => {
            let mut x = y.clone();
            for j in 0..y.cols() {
                l1::project_l1_inplace(x.col_mut(j), u[j], algo);
            }
            x
        }
        BilevelVariant::L12 => {
            let mut x = y.clone();
            for j in 0..y.cols() {
                l2::project_l2_inplace(x.col_mut(j), u[j]);
            }
            x
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;
    use crate::serve::request::{Dtype, JobKind};

    fn mk_req(kind: ProjectionKind, eta: f64, rows: usize, cols: usize, seed: u64) -> ProjectionRequest {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        ProjectionRequest::f64(kind, eta, Matrix::randn(rows, cols, &mut rng))
    }

    fn key_of_pair(p: &(BatchKey, u32)) -> BatchKey {
        p.0
    }

    fn bk(kind: ProjectionKind, rows: usize) -> BatchKey {
        BatchKey {
            kind: JobKind::Project(kind),
            algo: L1Algorithm::Condat,
            dtype: Dtype::F64,
            rows,
            cols: 4,
        }
    }

    #[test]
    fn collect_batch_coalesces_only_matching_keys() {
        let q: JobQueue<(BatchKey, u32)> = JobQueue::new(16);
        let a = bk(ProjectionKind::BilevelL1Inf, 8);
        let b = bk(ProjectionKind::BilevelL11, 8);
        q.try_push((a, 1)).unwrap();
        q.try_push((b, 2)).unwrap();
        q.try_push((a, 3)).unwrap();
        let policy =
            BatchPolicy { max_batch: 8, min_fill: 1, max_wait: Duration::from_millis(50) };
        let batch = collect_batch(&q, (a, 0), policy, key_of_pair);
        let ids: Vec<u32> = batch.iter().map(|j| j.1).collect();
        assert_eq!(ids, vec![0, 1, 3]);
        // the non-matching job is untouched
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_wait(), Some((b, 2)));
    }

    #[test]
    fn collect_batch_respects_max_batch() {
        let q: JobQueue<(BatchKey, u32)> = JobQueue::new(16);
        let a = bk(ProjectionKind::BilevelL1Inf, 8);
        for i in 1..=6 {
            q.try_push((a, i)).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, min_fill: 1, max_wait: Duration::ZERO };
        let batch = collect_batch(&q, (a, 0), policy, key_of_pair);
        assert_eq!(batch.len(), 4);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn unbatched_policy_takes_single_job() {
        let q: JobQueue<(BatchKey, u32)> = JobQueue::new(16);
        let a = bk(ProjectionKind::BilevelL1Inf, 8);
        q.try_push((a, 1)).unwrap();
        let batch = collect_batch(&q, (a, 0), BatchPolicy::unbatched(), key_of_pair);
        assert_eq!(batch.len(), 1);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn min_fill_waits_for_late_arrivals() {
        let q: std::sync::Arc<JobQueue<(BatchKey, u32)>> =
            std::sync::Arc::new(JobQueue::new(16));
        let a = bk(ProjectionKind::BilevelL1Inf, 8);
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.try_push((a, 1)).unwrap();
        });
        let policy =
            BatchPolicy { max_batch: 2, min_fill: 2, max_wait: Duration::from_millis(500) };
        let batch = collect_batch(&q, (a, 0), policy, key_of_pair);
        h.join().unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn execute_matches_direct_library_call() {
        let cache = ThresholdCache::new(0);
        let mut scratch = WorkerScratch::new();
        for kind in ProjectionKind::all() {
            let req = mk_req(*kind, 2.0, 20, 12, 9);
            let out = execute(&req, &cache, &mut scratch);
            let direct = kind.apply(req.payload.as_f64().unwrap(), 2.0);
            let Payload::F64(x) = &out.payload else { panic!("dtype changed") };
            assert_eq!(x.max_abs_diff(&direct), 0.0, "{} diverges", kind.name());
            assert_eq!(out.thresholds.is_some(), cacheable(*kind));
            assert!(!out.cache_hit);
        }
    }

    #[test]
    fn cache_replay_is_bit_identical() {
        let cache = ThresholdCache::new(8);
        let mut scratch = WorkerScratch::new();
        for kind in [
            ProjectionKind::BilevelL1Inf,
            ProjectionKind::BilevelL11,
            ProjectionKind::BilevelL12,
        ] {
            let req = mk_req(kind, 1.5, 24, 16, 10);
            let cold = execute(&req, &cache, &mut scratch);
            assert!(!cold.cache_hit);
            let warm = execute(&req, &cache, &mut scratch);
            assert!(warm.cache_hit, "{} second call should hit", kind.name());
            let (Payload::F64(a), Payload::F64(b)) = (&cold.payload, &warm.payload) else {
                panic!("dtype changed")
            };
            assert_eq!(a.max_abs_diff(b), 0.0, "{} replay differs", kind.name());
            assert_eq!(cold.thresholds, warm.thresholds);
        }
    }

    #[test]
    fn f32_requests_execute_and_cache_in_f32() {
        let cache = ThresholdCache::new(8);
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let y: Matrix<f32> = Matrix::<f64>::randn(16, 10, &mut rng).cast();
        let req = ProjectionRequest::f32(ProjectionKind::BilevelL1Inf, 1.0, y.clone());
        let mut scratch = WorkerScratch::new();
        let cold = execute(&req, &cache, &mut scratch);
        let warm = execute(&req, &cache, &mut scratch);
        assert!(!cold.cache_hit && warm.cache_hit);
        let (Payload::F32(a), Payload::F32(b)) = (&cold.payload, &warm.payload) else {
            panic!("dtype changed")
        };
        assert_eq!(a.max_abs_diff(b), 0.0);
        let direct = crate::projection::bilevel::bilevel_l1inf(&y, 1.0f32);
        assert_eq!(a.max_abs_diff(&direct), 0.0);
    }
}
