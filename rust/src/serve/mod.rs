//! # `serve` — the sharded, micro-batching projection service engine
//!
//! The ROADMAP's first "library → system" step: a multi-threaded service
//! that accepts, schedules, and executes a sustained stream of
//! heterogeneous projection requests. The paper's O(nm) bi-level ℓ1,∞
//! projection is cheap enough to sit on a hot serving path; this subsystem
//! supplies the machinery around it:
//!
//! * **Job model** ([`request`]) — [`ProjectionRequest`] /
//!   [`ProjectionResponse`] covering every
//!   [`ProjectionKind`](crate::projection::ProjectionKind), radius, and
//!   dtype (`f32`/`f64`); requests agreeing on (kind, algo, dtype, shape)
//!   share a [`BatchKey`].
//! * **Sharded worker pool** ([`engine`]) — `std::thread` workers (the
//!   crate's no-rayon policy) over bounded MPMC [`queue::JobQueue`]s;
//!   round-robin submission; a full queue rejects with
//!   [`SubmitError::Overloaded`] + retry-after instead of blocking.
//! * **Micro-batching scheduler** ([`scheduler`]) — workers coalesce
//!   same-key requests into batches under a configurable
//!   max-batch / min-fill / max-wait [`BatchPolicy`].
//! * **LRU threshold cache** ([`cache`]) — keyed by (matrix fingerprint,
//!   η, kind, algo, dtype, shape); a hit replays the cached per-column
//!   thresholds through the outer column stage only, bit-identical to a
//!   cold call.
//! * **Telemetry** ([`stats`]) — per-shard latency / throughput / batch /
//!   hit-rate counters via [`crate::metrics::counters`].
//! * **Sparse encode jobs** ([`JobKind::SparseEncode`]) — compacted
//!   encoders ([`crate::sparse::CompactEncoder`]) registered on the engine
//!   and driven by `Engine::submit_encode`: the structured-sparse
//!   inference workload, sharing the queues, batching (keyed by model id +
//!   shape + dtype), and telemetry of the projection kinds.
//! * **Load generation** ([`loadgen`]) — the closed-loop driver behind the
//!   `serve` / `loadgen` CLI subcommands and
//!   `benches/serve_throughput.rs`, in two modes: in-process
//!   ([`run_loadgen`]) and over real sockets against the
//!   [`crate::net`] HTTP front-end ([`run_loadgen_net`]), both reporting
//!   p50/p99/p999 from a shared log-bucketed histogram.
//! * **Model lifecycle** — `Engine::load_model` admits a
//!   [`crate::persist`] checkpoint into the encoder registry
//!   (`bilevel serve --model`), and `Engine::swap_model` /
//!   `Engine::swap_encoder_f32/f64` hot-swap the encoder behind a live
//!   model id: submissions resolve the registry entry to an `Arc` at
//!   admission, so in-flight batches finish on the old encoder and the
//!   swap rejects nothing.
//! * **Supervision & recovery** ([`breaker`], worker supervision in
//!   [`engine`]) — workers wrap every job execution in `catch_unwind`: a
//!   panicking job fails its batch with a typed [`JobError::WorkerPanic`]
//!   instead of hanging its waiters, and the supervisor respawns the
//!   worker in place (restart counters in [`stats`]); repeated encode
//!   failures trip a per-model [`CircuitBreaker`] (open → refused with a
//!   retry-after → single half-open probe); the engine reports a
//!   three-state health machine ([`HealthState`]) through `/healthz` and
//!   `/v1/stats`. The [`crate::fault`] sites `worker.panic` /
//!   `worker.stall` exercise exactly these paths.
//!
//! Sizing lives in [`ServeConfig`] (`[serve]` section of the TOML config).
//!
//! ```no_run
//! use bilevel_sparse::config::ServeConfig;
//! use bilevel_sparse::projection::ProjectionKind;
//! use bilevel_sparse::rng::Xoshiro256pp;
//! use bilevel_sparse::serve::{Engine, ProjectionRequest};
//! use bilevel_sparse::tensor::Matrix;
//!
//! let engine = Engine::start(&ServeConfig::default()).unwrap();
//! let mut rng = Xoshiro256pp::seed_from_u64(0);
//! let y = Matrix::<f64>::randn(256, 128, &mut rng);
//! let resp = engine
//!     .submit_wait(ProjectionRequest::f64(ProjectionKind::BilevelL1Inf, 1.0, y))
//!     .unwrap();
//! assert!(resp.thresholds.is_some());
//! println!("{}", engine.shutdown());
//! ```

pub mod breaker;
pub mod cache;
pub mod engine;
pub mod loadgen;
pub mod queue;
pub mod request;
pub mod scheduler;
pub mod stats;

pub use breaker::{BreakerState, CircuitBreaker};
pub use cache::{fingerprint, CacheKey, CachedThresholds, ThresholdCache};
pub use engine::{Engine, ModelInfo, ResponseHandle};
pub use loadgen::{run_loadgen, run_loadgen_net, LoadReport, LoadgenConfig};
pub use queue::{JobQueue, PushError};
pub use request::{
    BatchKey, Dtype, JobError, JobKind, Payload, ProjectionRequest, ProjectionResponse,
    SubmitError,
};
pub use scheduler::{cacheable, BatchPolicy};
pub use stats::{EngineStats, HealthReport, HealthState, ShardStats};

// Convenience re-export (the config type lives with the other schemas).
pub use crate::config::ServeConfig;
